#!/usr/bin/env bash
# Docs consistency gate: the docs must cover what the code actually
# ships.  Extracts ground truth from the Rust sources and asserts:
#
#   1. every ErrorCode wire name (serve/protocol.rs as_str) is documented
#      in docs/http_api.md AND docs/serving.md;
#   2. every metric family registered in rust/src appears in
#      docs/observability.md;
#   3. every `cce serve` CLI option appears as `--flag` somewhere in
#      README.md or docs/;
#   4. every `curl ` example line in README.md and docs/http_api.md is
#      exercised VERBATIM by examples/http_quickstart.sh;
#   5. the stdout announce-line contract is documented in
#      docs/http_api.md (serve) and docs/sharding.md (shard workers);
#   6. the shard wire protocol doc (docs/sharding.md) covers every op in
#      shard/protocol.rs SHARD_OPS, states the pinned protocol version,
#      and every `"$CCE" ` command line in its code blocks is exercised
#      VERBATIM by examples/shard_quickstart.sh.
#
# `--selftest` proves the checks bite: doctored copies of the docs (one
# error code row removed, one metric family removed, one curl line
# dropped from the quickstart, one shard op row removed, one shard
# command dropped) must each FAIL the check.
#
# Runs in CI (./ci.sh, docs stage) with no toolchain needed: bash + grep
# + sed only.
set -euo pipefail
cd "$(dirname "$0")/.."

# Selftest points these at doctored copies; normal runs use the repo files.
HTTP_API=${CHECK_DOCS_HTTP_API:-docs/http_api.md}
SERVING=${CHECK_DOCS_SERVING:-docs/serving.md}
OBSERVABILITY=${CHECK_DOCS_OBSERVABILITY:-docs/observability.md}
README=${CHECK_DOCS_README:-README.md}
QUICKSTART=${CHECK_DOCS_QUICKSTART:-examples/http_quickstart.sh}
SHARDING=${CHECK_DOCS_SHARDING:-docs/sharding.md}
SHARD_QUICKSTART=${CHECK_DOCS_SHARD_QUICKSTART:-examples/shard_quickstart.sh}

fail=0
complain() { echo "check_docs: $*" >&2; fail=1; }

# ---- 1. error codes ---------------------------------------------------
codes=$(sed -n '/fn as_str/,/^    }/p' rust/src/serve/protocol.rs \
    | grep -oE '"[a-z_]+"' | tr -d '"' | sort -u)
n_codes=$(wc -w <<<"$codes")
[[ "$n_codes" -ge 5 ]] || { echo "check_docs: extracted only $n_codes ErrorCode names from protocol.rs — extraction broke" >&2; exit 1; }
for code in $codes; do
    grep -qF "\`$code\`" "$HTTP_API" || complain "error code '$code' missing from $HTTP_API"
    grep -qF "\`$code\`" "$SERVING" || complain "error code '$code' missing from $SERVING"
done

# ---- 2. metric families ----------------------------------------------
# Registrations span lines (name on its own line), so extract by the
# family-name prefixes instead of the .counter("...") call shape.
families=$(grep -rhoE '"(serve|exec|train|shard)_[a-z0-9_]+"' rust/src | tr -d '"' | sort -u)
n_fam=$(wc -w <<<"$families")
[[ "$n_fam" -ge 30 ]] || { echo "check_docs: extracted only $n_fam metric families from rust/src — extraction broke" >&2; exit 1; }
for fam in $families; do
    grep -qF "$fam" "$OBSERVABILITY" || complain "metric family '$fam' missing from $OBSERVABILITY"
done

# ---- 3. serve CLI flags ----------------------------------------------
flags=$(sed -n '/^fn kernel_options(/,/^}/p; /^fn dtype_override(/,/^}/p; /^fn build_engines(/,/^}/p; /^fn cmd_serve(/,/^}/p; /^fn shard_fleet(/,/^}/p; /^fn cmd_shard_worker(/,/^}/p' rust/src/main.rs \
    | grep -oE '\.(get|opt|flag|require|opt_all)\("[a-z-]+"' \
    | grep -oE '"[a-z-]+"' | tr -d '"' | sort -u)
n_flags=$(wc -w <<<"$flags")
[[ "$n_flags" -ge 17 ]] || { echo "check_docs: extracted only $n_flags serve flags from main.rs — extraction broke" >&2; exit 1; }
for flag in $flags; do
    grep -qrF -- "--$flag" "$README" "$HTTP_API" "$SERVING" "$OBSERVABILITY" "$SHARDING" docs/benchmarks.md \
        || complain "serve flag '--$flag' undocumented (README.md or docs/)"
done

# ---- 4. curl examples run verbatim -----------------------------------
n_curl=0
while IFS= read -r line; do
    n_curl=$((n_curl + 1))
    grep -qF -- "$line" "$QUICKSTART" \
        || complain "curl example not exercised verbatim by $QUICKSTART: $line"
done < <(grep -h '^curl ' "$README" "$HTTP_API" | sort -u)
[[ "$n_curl" -ge 5 ]] || { echo "check_docs: found only $n_curl curl examples in the docs — extraction broke" >&2; exit 1; }

# ---- 5. announce-line contract ---------------------------------------
for marker in '[serve] ready proto=line addr=' '[serve] ready proto=http addr=' '[serve] shut down cleanly'; do
    grep -qF -- "$marker" "$HTTP_API" || complain "announce line '$marker' missing from $HTTP_API"
done
for marker in '[shard] ready proto=line addr=' '[shard] shut down cleanly'; do
    grep -qF -- "$marker" "$SHARDING" || complain "announce line '$marker' missing from $SHARDING"
done

# ---- 6. shard wire protocol ------------------------------------------
# Every op in SHARD_OPS must have a section/row in docs/sharding.md
# (backquoted, as `op`), and the doc must state the pinned protocol
# version extracted from the source constant.
ops=$(sed -n '/^pub const SHARD_OPS/,/^\];/p' rust/src/shard/protocol.rs \
    | grep -oE '"[a-z]+"' | tr -d '"' | sort -u)
n_ops=$(wc -w <<<"$ops")
[[ "$n_ops" -ge 8 ]] || { echo "check_docs: extracted only $n_ops shard ops from shard/protocol.rs — extraction broke" >&2; exit 1; }
for op in $ops; do
    grep -qF "\`$op\`" "$SHARDING" || complain "shard op '$op' missing from $SHARDING"
done
proto_ver=$(grep -oE 'SHARD_PROTO_VERSION: i64 = [0-9]+' rust/src/shard/protocol.rs | grep -oE '[0-9]+$')
[[ -n "$proto_ver" ]] || { echo "check_docs: could not extract SHARD_PROTO_VERSION from shard/protocol.rs" >&2; exit 1; }
grep -qE "[Pp]rotocol version.*\b$proto_ver\b|\"proto\":\s*$proto_ver" "$SHARDING" \
    || complain "protocol version $proto_ver not stated in $SHARDING"

# Every command line in docs/sharding.md code blocks that invokes the
# binary must be exercised VERBATIM by examples/shard_quickstart.sh —
# the same docs-don't-rot contract the curl examples live under.
n_shard_cmds=0
while IFS= read -r line; do
    n_shard_cmds=$((n_shard_cmds + 1))
    grep -qF -- "$line" "$SHARD_QUICKSTART" \
        || complain "shard command not exercised verbatim by $SHARD_QUICKSTART: $line"
done < <(grep -hE '^"\$CCE" ' "$SHARDING" | sort -u)
[[ "$n_shard_cmds" -ge 3 ]] || { echo "check_docs: found only $n_shard_cmds \"\$CCE\" command lines in $SHARDING — extraction broke" >&2; exit 1; }

if [[ "$fail" -ne 0 ]]; then
    echo "check_docs: FAILED" >&2
    exit 1
fi

# ---- selftest: the checks must bite -----------------------------------
if [[ "${1:-}" == "--selftest" ]]; then
    tmp=$(mktemp -d)
    trap 'rm -rf "$tmp"' EXIT
    expect_fail() {  # <label> <env assignments...>
        local label=$1; shift
        if env "$@" "$0" >/dev/null 2>&1; then
            echo "check_docs --selftest: $label did NOT fail the check" >&2
            exit 1
        fi
    }

    grep -v 'deadline_exceeded' docs/http_api.md > "$tmp/http_api.md"
    expect_fail "removing an error code from http_api.md" \
        CHECK_DOCS_HTTP_API="$tmp/http_api.md"

    grep -v 'serve_http_sse_events_total' docs/observability.md > "$tmp/observability.md"
    expect_fail "removing a metric family from observability.md" \
        CHECK_DOCS_OBSERVABILITY="$tmp/observability.md"

    grep -v -- '--queue-depth' docs/serving.md > "$tmp/serving.md"
    expect_fail "removing a CLI flag from serving.md" \
        CHECK_DOCS_SERVING="$tmp/serving.md"

    grep -v '/v1/score' examples/http_quickstart.sh > "$tmp/quickstart.sh"
    expect_fail "dropping a curl line from http_quickstart.sh" \
        CHECK_DOCS_QUICKSTART="$tmp/quickstart.sh"

    grep -v '`merge`' docs/sharding.md > "$tmp/sharding_op.md"
    expect_fail "removing a shard op from sharding.md" \
        CHECK_DOCS_SHARDING="$tmp/sharding_op.md"

    grep -v 'shard_exchange_bytes' docs/observability.md > "$tmp/observability_shard.md"
    expect_fail "removing a shard metric family from observability.md" \
        CHECK_DOCS_OBSERVABILITY="$tmp/observability_shard.md"

    grep -v -- 'shard-worker' examples/shard_quickstart.sh > "$tmp/shard_quickstart.sh"
    expect_fail "dropping a command line from shard_quickstart.sh" \
        CHECK_DOCS_SHARD_QUICKSTART="$tmp/shard_quickstart.sh"

    echo "check_docs: selftest OK (all doctored docs failed as designed)"
fi

echo "check_docs: OK ($n_codes error codes, $n_fam metric families, $n_flags serve flags, $n_curl curl examples, $n_ops shard ops, $n_shard_cmds shard commands)"
