#!/usr/bin/env bash
# Cross-PR perf regression gate for the native Table-1 bench.
#
#   tools/check_bench.sh [--update] <fresh.json> [baseline.json]
#
# Compares a freshly measured BENCH_table1.json against the committed
# baseline (default: BENCH_table1.json in the repo root) and prints a
# per-method fwd/bwd delta table.  The build FAILS on a >25% regression in
# either headline metric:
#
#   * the filtered-vs-unfiltered backward gap
#     (bwd_ms[cce_no_filter] / bwd_ms[cce] — the paper's §4.3 win, and the
#     first thing to look at per ROADMAP's perf-tracking section).  The
#     ratio alone also shrinks when the unfiltered reference simply got
#     faster, so the gate only fires when cce's own bwd_ms worsened too;
#   * the cce forward and backward times (fwd_ms[cce] / bwd_ms[cce]),
#     gated absolutely — the ratio is blind to uniform slowdowns;
#   * the small-N decode-shape row ("small_n": cce at N=8), gated
#     absolutely on fwd_ms and fwdbwd_ms — at that shape per-call
#     orchestration overhead (thread spawn/join, dispatch probes), not
#     FLOPs, dominates, so this is the gate that keeps the persistent
#     worker pool honest.
#
# Exit codes: 0 = OK/bootstrap, 1 = regression (suppressible), 2 =
# structural failure (unreadable fresh file, missing gate rows/fields —
# never suppressible).
#
# A missing baseline, or one measured at a different grid/thread count, is
# accepted as a bootstrap (exit 0).  `--update` (or BENCH_UPDATE=1 through
# ci.sh) suppresses a *regression* verdict only, so a deliberate slowdown
# can land — put the justification in the commit message.  Installing the
# accepted numbers as the committed baseline is ci.sh's job (it refreshes
# both BENCH files after the gate).
#
# Timing medians still wobble on shared runners; 25% is chosen to be well
# above normal jitter at the CI budget (see docs/benchmarks.md).

set -euo pipefail

UPDATE=0
if [[ "${1:-}" == "--update" ]]; then
    UPDATE=1
    shift
fi
FRESH="${1:?usage: tools/check_bench.sh [--update] <fresh.json> [baseline.json]}"
BASELINE="${2:-BENCH_table1.json}"

if ! command -v python3 >/dev/null 2>&1; then
    # Fail hard: a silently skipped gate would let regressions land green.
    echo "[check_bench] ERROR: python3 not found — the regression gate cannot run." >&2
    echo "[check_bench] Install python3 on the CI image (the repo's python/ tooling needs it anyway)." >&2
    exit 2
fi

STATUS=0
python3 - "$FRESH" "$BASELINE" <<'PY' || STATUS=$?
import json, sys

THRESHOLD = 1.25     # >25% regression fails
NOISE = 1.05         # median jitter allowance for the gap gate's cce guard
EXIT_REGRESSION = 1  # suppressible via --update
EXIT_STRUCTURAL = 2  # never suppressible


def load(path):
    with open(path) as f:
        doc = json.load(f)
    rows = {r["method"]: r for r in doc.get("rows", []) if "method" in r}
    return doc, rows


def gap(rows):
    """Filtered-vs-unfiltered backward gap (higher is better)."""
    try:
        cce = rows["cce"]["bwd_ms"]
        nof = rows["cce_no_filter"]["bwd_ms"]
    except KeyError:
        return None
    if cce <= 0:
        return None
    return nof / cce


def main(fresh_path, base_path):
    try:
        fresh_doc, fresh = load(fresh_path)
    except (OSError, json.JSONDecodeError, TypeError) as err:
        print(f"[check_bench] STRUCTURAL: fresh bench {fresh_path} unreadable ({err})")
        return EXIT_STRUCTURAL

    try:
        base_doc, base = load(base_path)
    except FileNotFoundError:
        print(f"[check_bench] no committed baseline at {base_path} — "
              "accepting the fresh run as the first data point")
        return 0
    except (OSError, json.JSONDecodeError, TypeError) as err:
        print(f"[check_bench] baseline {base_path} unreadable ({err}) — "
              "accepting the fresh run as the new baseline")
        return 0

    # Comparability key: grid, thread count, schema, and the resolved SIMD
    # dispatch level — a baseline measured on an AVX2 machine must not gate
    # a portable-path runner (or vice versa); such pairs bootstrap instead.
    key = lambda doc: (doc.get("grid"), doc.get("threads"), doc.get("schema"),
                       doc.get("simd"))
    if key(fresh_doc) != key(base_doc):
        print(f"[check_bench] baseline grid/threads/schema/simd {key(base_doc)} "
              f"!= fresh {key(fresh_doc)} — not comparable, accepting fresh run")
        return 0

    # Per-method delta table (always printed).  Missing timing fields show
    # as 0 here; the gates below treat them as structural failures.
    hdr = (f"{'method':<18}{'fwd ms':>10}{'(base)':>10}{'Δ%':>8}"
           f"{'bwd ms':>10}{'(base)':>10}{'Δ%':>8}")
    print(f"[check_bench] {fresh_path} vs {base_path}")
    print("  " + hdr)
    print("  " + "-" * len(hdr))

    def pct(new, old):
        return f"{100.0 * (new - old) / old:+.0f}%" if old > 0 else "n/a"

    for method, row in fresh.items():
        fwd, bwd = row.get("fwd_ms", 0.0), row.get("bwd_ms", 0.0)
        b = base.get(method)
        if b is None:
            print(f"  {method:<18}{fwd:>10.2f}{'new':>10}{'':>8}"
                  f"{bwd:>10.2f}{'new':>10}{'':>8}")
            continue
        bf, bb = b.get("fwd_ms", 0.0), b.get("bwd_ms", 0.0)
        print(f"  {method:<18}{fwd:>10.2f}{bf:>10.2f}{pct(fwd, bf):>8}"
              f"{bwd:>10.2f}{bb:>10.2f}{pct(bwd, bb):>8}")

    failures = []
    structural = []

    # The fresh file must carry the gate rows — a bench run that cannot
    # compute the headline metrics is an error, never a silent pass.
    fresh_gap, base_gap = gap(fresh), gap(base)
    if fresh_gap is None:
        structural.append("fresh bench is missing the cce/cce_no_filter rows "
                          "(or their bwd_ms) — the filter-gap gate cannot run")
    elif base_gap is None:
        print("  baseline lacks cce/cce_no_filter rows — taking the fresh gap "
              f"({fresh_gap:.2f}x) as the new reference")
    else:
        print(f"  filter gap (no_filter/cce bwd): {fresh_gap:.2f}x "
              f"(baseline {base_gap:.2f}x)")
        if fresh_gap * THRESHOLD < base_gap:
            # The ratio also shrinks when cce_no_filter simply got *faster*
            # — a pure improvement.  Only fail when cce's own backward
            # worsened beyond median jitter (a real cce slowdown past 25%
            # is caught by the absolute gate below regardless); otherwise
            # note the narrower gap and move on.
            cce_worse = (fresh["cce"]["bwd_ms"] > base["cce"]["bwd_ms"] * NOISE)
            if cce_worse:
                failures.append(
                    f"filtered-vs-unfiltered bwd gap regressed: "
                    f"{fresh_gap:.2f}x vs baseline {base_gap:.2f}x "
                    f"(>{(THRESHOLD - 1) * 100:.0f}%) with cce bwd itself slower")
            else:
                print("  gap narrowed but cce bwd did not slow down "
                      "(the unfiltered reference got faster) — not a regression")

    # Absolute gates on cce itself: the gap ratio is blind to a *uniform*
    # slowdown (cce and cce_no_filter both regressing by the same factor),
    # so fwd and bwd are each gated against the baseline directly.
    for metric, label in [("fwd_ms", "forward"), ("bwd_ms", "backward")]:
        fresh_ms = fresh.get("cce", {}).get(metric)
        base_ms = base.get("cce", {}).get(metric)
        if fresh_ms is None:
            structural.append(f"fresh bench is missing the cce row (or its "
                              f"{metric}) — the {label}-time gate cannot run")
        elif base_ms is not None and base_ms > 0 and fresh_ms > base_ms * THRESHOLD:
            failures.append(
                f"cce {label} regressed: {fresh_ms:.2f} ms vs baseline "
                f"{base_ms:.2f} ms (>{(THRESHOLD - 1) * 100:.0f}%)")

    # Decode-shape (small-N) gate: absolute, like the cce gates above.  A
    # baseline predating the row bootstraps; a *fresh* run missing the row
    # while the baseline carries it is structural — the orchestration-
    # overhead gate must not silently disappear.
    fresh_sn, base_sn = fresh_doc.get("small_n"), base_doc.get("small_n")
    if fresh_sn is None:
        if base_sn is not None:
            structural.append("fresh bench is missing the small_n (decode-shape) "
                              "row the baseline carries — the orchestration-"
                              "overhead gate cannot run")
    elif base_sn is None:
        print(f"  small-N (N={fresh_sn.get('n')}): fwd "
              f"{fresh_sn.get('fwd_ms', 0.0):.3f} ms, fwd+bwd "
              f"{fresh_sn.get('fwdbwd_ms', 0.0):.3f} ms — baseline has no "
              "decode-shape row yet, taking this as the reference")
    elif base_sn.get("n") != fresh_sn.get("n"):
        print(f"  small-N shape changed ({base_sn.get('n')} -> {fresh_sn.get('n')}) "
              "— not comparable, taking the fresh row as the new reference")
    else:
        for metric, label in [("fwd_ms", "forward"), ("fwdbwd_ms", "forward+backward")]:
            fresh_ms, base_ms = fresh_sn.get(metric), base_sn.get(metric)
            if fresh_ms is None:
                structural.append(f"fresh small_n row is missing {metric} — the "
                                  "orchestration-overhead gate cannot run")
            elif base_ms is not None and base_ms > 0:
                print(f"  small-N {label} (N={fresh_sn.get('n')}): {fresh_ms:.3f} ms "
                      f"(baseline {base_ms:.3f} ms, {pct(fresh_ms, base_ms)})")
                if fresh_ms > base_ms * THRESHOLD:
                    failures.append(
                        f"small-N (decode shape) {label} regressed: "
                        f"{fresh_ms:.3f} ms vs baseline {base_ms:.3f} ms "
                        f"(>{(THRESHOLD - 1) * 100:.0f}%) — per-call "
                        "orchestration overhead is creeping back")

    if structural:
        for f in structural:
            print(f"[check_bench] STRUCTURAL: {f}")
        return EXIT_STRUCTURAL
    if failures:
        for f in failures:
            print(f"[check_bench] REGRESSION: {f}")
        print("[check_bench] rerun with BENCH_UPDATE=1 ./ci.sh (or "
              "tools/check_bench.sh --update) to accept deliberately")
        return EXIT_REGRESSION
    print("[check_bench] OK — no regression beyond the 25% threshold")
    return 0


try:
    sys.exit(main(sys.argv[1], sys.argv[2]))
except SystemExit:
    raise
except Exception as err:  # anything unforeseen is structural, not a "regression"
    print(f"[check_bench] STRUCTURAL: unexpected error: {err!r}")
    sys.exit(EXIT_STRUCTURAL)
PY

# --update forgives a regression verdict only; structural failures (a bench
# that could not even be compared) always propagate.
if [[ "$UPDATE" == "1" && "$STATUS" -eq 1 ]]; then
    echo "[check_bench] --update: regression accepted deliberately"
    STATUS=0
fi
exit "$STATUS"
