#!/usr/bin/env bash
# Cross-PR perf/shape gates for the native bench files.
#
#   tools/check_bench.sh [--update] <fresh.json> [baseline.json]   # table1
#   tools/check_bench.sh --figa1 <fresh.json>                      # scaling shape
#   tools/check_bench.sh --serve [--update] <fresh.json> [baseline.json]
#
# Default mode compares a freshly measured BENCH_table1.json against the
# committed baseline (default: BENCH_table1.json in the repo root) and
# prints a per-method fwd/bwd delta table.  The build FAILS on a >25%
# regression in either headline metric:
#
#   * the filtered-vs-unfiltered backward gap
#     (bwd_ms[cce_no_filter] / bwd_ms[cce] — the paper's §4.3 win, and the
#     first thing to look at per ROADMAP's perf-tracking section).  The
#     ratio alone also shrinks when the unfiltered reference simply got
#     faster, so the gate only fires when cce's own bwd_ms worsened too;
#   * the cce forward and backward times (fwd_ms[cce] / bwd_ms[cce]),
#     gated absolutely — the ratio is blind to uniform slowdowns;
#   * the small-N decode-shape row ("small_n": cce at N=8), gated
#     absolutely on fwd_ms and fwdbwd_ms — at that shape per-call
#     orchestration overhead (thread spawn/join, dispatch probes), not
#     FLOPs, dominates, so this is the gate that keeps the persistent
#     worker pool honest.
#
# `--figa1` is a *structural* shape check on a fresh BENCH_figA1.json (no
# baseline involved, never suppressible): across the N-sweep, cce's
# measured forward workspace must stay ~flat (<= 1.5x over the sweep)
# while the materialized baseline's must grow ~linearly (>= 0.7x the N
# ratio) — the paper's memory-scaling claim, enforced on real measured
# allocations every CI run.
#
# `--serve` gates BENCH_serve.json on the **median** requests/sec over the
# harness repeats: >35% throughput drop fails (suppressible with
# --update).  The threshold is deliberately looser than the kernel gates —
# serving latency on shared runners is noisy even after the median — and
# incomparable runs (different shape/concurrency/simd/dtype) bootstrap.
# When the fresh file carries the additive top-level "sharded" object (a
# second servebench run through a --shards N worker fleet; see
# docs/benchmarks.md), the sharded/single throughput *ratio* is gated the
# same way: a baseline without the field bootstraps, a fresh file missing
# a field the baseline carries is structural — the sharding-overhead gate
# must not silently disappear.
#
# Exit codes: 0 = OK/bootstrap, 1 = regression (suppressible), 2 =
# structural failure (unreadable fresh file, missing gate rows/fields —
# never suppressible).
#
# A missing baseline, or one measured at a different grid/thread count, is
# accepted as a bootstrap (exit 0).  `--update` (or BENCH_UPDATE=1 through
# ci.sh) suppresses a *regression* verdict only, so a deliberate slowdown
# can land — put the justification in the commit message.  Installing the
# accepted numbers as the committed baseline is ci.sh's job (it refreshes
# both BENCH files after the gate).
#
# Timing medians still wobble on shared runners; 25% is chosen to be well
# above normal jitter at the CI budget (see docs/benchmarks.md).

set -euo pipefail

MODE="table1"
UPDATE=0
while [[ "${1:-}" == --* ]]; do
    case "$1" in
        --figa1) MODE="figa1"; shift ;;
        --serve) MODE="serve"; shift ;;
        --update) UPDATE=1; shift ;;
        *) echo "unknown flag $1"; exit 2 ;;
    esac
done
FRESH="${1:?usage: tools/check_bench.sh [--figa1|--serve] [--update] <fresh.json> [baseline.json]}"
DEFAULT_BASELINE="BENCH_table1.json"
[[ "$MODE" == "serve" ]] && DEFAULT_BASELINE="BENCH_serve.json"
BASELINE="${2:-$DEFAULT_BASELINE}"

if ! command -v python3 >/dev/null 2>&1; then
    # Fail hard: a silently skipped gate would let regressions land green.
    echo "[check_bench] ERROR: python3 not found — the regression gate cannot run." >&2
    echo "[check_bench] Install python3 on the CI image (the repo's python/ tooling needs it anyway)." >&2
    exit 2
fi

STATUS=0

if [[ "$MODE" == "figa1" ]]; then
    python3 - "$FRESH" <<'PY' || STATUS=$?
import json, sys

EXIT_STRUCTURAL = 2  # shape gates are never suppressible

try:
    doc = json.load(open(sys.argv[1]))
except (OSError, json.JSONDecodeError) as err:
    print(f"[check_bench] STRUCTURAL: figA1 bench {sys.argv[1]} unreadable ({err})")
    sys.exit(EXIT_STRUCTURAL)

points = doc.get("points", [])
series = {}
for pt in points:
    series.setdefault(pt.get("method"), []).append(pt)
for rows in series.values():
    rows.sort(key=lambda r: r.get("n", 0))

cce, base = series.get("cce", []), series.get("baseline", [])
if len(cce) < 2 or len(base) < 2:
    print("[check_bench] STRUCTURAL: figA1 sweep needs >= 2 cce and baseline points "
          f"(got {len(cce)} / {len(base)}) — the scaling gate cannot run")
    sys.exit(EXIT_STRUCTURAL)
if any("fwd_workspace_bytes" not in r for r in cce + base):
    print("[check_bench] STRUCTURAL: figA1 points lack measured fwd_workspace_bytes")
    sys.exit(EXIT_STRUCTURAL)

n_ratio = base[-1]["n"] / base[0]["n"]
cce_ratio = cce[-1]["fwd_workspace_bytes"] / max(cce[0]["fwd_workspace_bytes"], 1)
base_ratio = base[-1]["fwd_workspace_bytes"] / max(base[0]["fwd_workspace_bytes"], 1)
print(f"[check_bench] figA1 scaling over N x{n_ratio:.0f} "
      f"({base[0]['n']} -> {base[-1]['n']}): cce workspace x{cce_ratio:.2f}, "
      f"baseline x{base_ratio:.2f}")
failures = []
if cce_ratio > 1.5:
    failures.append(f"cce measured forward workspace grew x{cce_ratio:.2f} over the "
                    "sweep — the O(N_B*V_B) bound broke")
if base_ratio < 0.7 * n_ratio:
    failures.append(f"baseline measured workspace grew only x{base_ratio:.2f} over an "
                    f"x{n_ratio:.0f} N sweep — it stopped materializing N x V")
if failures:
    for f in failures:
        print(f"[check_bench] STRUCTURAL: {f}")
    sys.exit(EXIT_STRUCTURAL)
print("[check_bench] OK — memory scaling shape holds (cce flat, baseline linear)")
PY
    exit "$STATUS"
fi

if [[ "$MODE" == "serve" ]]; then
    python3 - "$FRESH" "$BASELINE" <<'PY' || STATUS=$?
import json, sys

MAX_DROP = 0.35      # >35% throughput drop fails (runner-noise allowance)
EXIT_REGRESSION = 1
EXIT_STRUCTURAL = 2

try:
    fresh = json.load(open(sys.argv[1]))
except (OSError, json.JSONDecodeError) as err:
    print(f"[check_bench] STRUCTURAL: fresh serve bench {sys.argv[1]} unreadable ({err})")
    sys.exit(EXIT_STRUCTURAL)

rps = fresh.get("requests_per_sec")
if not isinstance(rps, (int, float)) or rps <= 0:
    print("[check_bench] STRUCTURAL: fresh serve bench has no positive "
          "requests_per_sec — the serve gate cannot run")
    sys.exit(EXIT_STRUCTURAL)
endpoints = {r.get("endpoint") for r in fresh.get("rows", [])}
if endpoints != {"generate", "score"}:
    print(f"[check_bench] STRUCTURAL: fresh serve bench rows cover {sorted(map(str, endpoints))}, "
          "want both 'generate' and 'score' — the trajectory file would be malformed")
    sys.exit(EXIT_STRUCTURAL)
p50 = next((r.get("p50_ms") for r in fresh.get("rows", [])
            if r.get("endpoint") == "generate"), None)
runs = fresh.get("requests_per_sec_runs", [])
print(f"[check_bench] serve: median {rps:.1f} req/s over {max(len(runs), 1)} run(s)"
      + (f", generate p50 {p50:.2f} ms" if p50 is not None else ""))

# Sharded/single throughput ratio (additive "sharded" field: the same
# harness through a --shards N worker fleet).  Computed on the fresh file
# alone first so a malformed sharded row is structural even on bootstrap.
sharded = fresh.get("sharded")
fresh_ratio = None
if sharded is not None:
    srps = sharded.get("requests_per_sec")
    if not isinstance(srps, (int, float)) or srps <= 0:
        print("[check_bench] STRUCTURAL: fresh sharded serve row has no positive "
              "requests_per_sec — the sharding-overhead gate cannot run")
        sys.exit(EXIT_STRUCTURAL)
    fresh_ratio = srps / rps
    print(f"[check_bench] serve sharded ({sharded.get('shards')} shards): "
          f"{srps:.1f} req/s — x{fresh_ratio:.2f} of single-process")

try:
    base = json.load(open(sys.argv[2]))
except FileNotFoundError:
    print(f"[check_bench] no committed serve baseline at {sys.argv[2]} — "
          "accepting the fresh run as the first data point")
    sys.exit(0)
except (OSError, json.JSONDecodeError) as err:
    print(f"[check_bench] serve baseline unreadable ({err}) — accepting fresh run")
    sys.exit(0)

key = lambda d: (d.get("schema"), d.get("vocab"), d.get("d_model"), d.get("threads"),
                 d.get("simd"), d.get("dtype"), d.get("requests"), d.get("concurrency"),
                 d.get("max_tokens"))
if key(fresh) != key(base):
    print(f"[check_bench] serve baseline shape {key(base)} != fresh {key(fresh)} — "
          "not comparable, accepting fresh run")
    sys.exit(0)

base_rps = base.get("requests_per_sec", 0)
if base_rps <= 0:
    print("[check_bench] serve baseline has no throughput — accepting fresh run")
    sys.exit(0)
print(f"[check_bench] serve baseline: {base_rps:.1f} req/s "
      f"({100.0 * (rps - base_rps) / base_rps:+.0f}%)")
failures = []
if rps < base_rps * (1.0 - MAX_DROP):
    failures.append(f"serve throughput dropped: {rps:.1f} req/s vs "
                    f"baseline {base_rps:.1f} (>{MAX_DROP * 100:.0f}% drop)")

# Sharding-overhead gate: the sharded/single ratio, not the absolute
# sharded req/s, so a uniformly slower runner cannot fire it — only the
# fleet's own exchange overhead growing relative to the engine can.
base_sharded = base.get("sharded")
if fresh_ratio is None:
    if base_sharded is not None:
        print("[check_bench] STRUCTURAL: fresh serve bench is missing the sharded "
              "row the baseline carries — the sharding-overhead gate cannot run")
        sys.exit(EXIT_STRUCTURAL)
elif base_sharded is None:
    print("[check_bench] baseline has no sharded row yet — taking the fresh "
          f"ratio (x{fresh_ratio:.2f}) as the reference")
elif base_sharded.get("shards") != sharded.get("shards"):
    print(f"[check_bench] sharded shape changed ({base_sharded.get('shards')} -> "
          f"{sharded.get('shards')} shards) — not comparable, taking the fresh "
          "ratio as the new reference")
else:
    base_srps = base_sharded.get("requests_per_sec", 0)
    base_ratio = (base_srps / base_rps) if base_srps and base_rps else None
    if base_ratio is None:
        print("[check_bench] baseline sharded row has no throughput — "
              "taking the fresh ratio as the reference")
    else:
        print(f"[check_bench] sharded/single ratio: x{fresh_ratio:.2f} "
              f"(baseline x{base_ratio:.2f})")
        if fresh_ratio < base_ratio * (1.0 - MAX_DROP):
            failures.append(
                f"sharded/single throughput ratio regressed: x{fresh_ratio:.2f} vs "
                f"baseline x{base_ratio:.2f} (>{MAX_DROP * 100:.0f}% drop) — the "
                "shard exchange overhead is growing")

if failures:
    for f in failures:
        print(f"[check_bench] REGRESSION: {f}")
    print("[check_bench] rerun with BENCH_UPDATE=1 ./ci.sh (or --update) to accept")
    sys.exit(EXIT_REGRESSION)
print("[check_bench] OK — serve throughput (and sharded ratio) within the 35% gate")
PY
    if [[ "$UPDATE" == "1" && "$STATUS" -eq 1 ]]; then
        echo "[check_bench] --update: serve regression accepted deliberately"
        STATUS=0
    fi
    exit "$STATUS"
fi

python3 - "$FRESH" "$BASELINE" <<'PY' || STATUS=$?
import json, sys

THRESHOLD = 1.25     # >25% regression fails
NOISE = 1.05         # median jitter allowance for the gap gate's cce guard
EXIT_REGRESSION = 1  # suppressible via --update
EXIT_STRUCTURAL = 2  # never suppressible


def load(path):
    with open(path) as f:
        doc = json.load(f)
    rows = {r["method"]: r for r in doc.get("rows", []) if "method" in r}
    return doc, rows


def gap(rows):
    """Filtered-vs-unfiltered backward gap (higher is better)."""
    try:
        cce = rows["cce"]["bwd_ms"]
        nof = rows["cce_no_filter"]["bwd_ms"]
    except KeyError:
        return None
    if cce <= 0:
        return None
    return nof / cce


def main(fresh_path, base_path):
    try:
        fresh_doc, fresh = load(fresh_path)
    except (OSError, json.JSONDecodeError, TypeError) as err:
        print(f"[check_bench] STRUCTURAL: fresh bench {fresh_path} unreadable ({err})")
        return EXIT_STRUCTURAL

    try:
        base_doc, base = load(base_path)
    except FileNotFoundError:
        print(f"[check_bench] no committed baseline at {base_path} — "
              "accepting the fresh run as the first data point")
        return 0
    except (OSError, json.JSONDecodeError, TypeError) as err:
        print(f"[check_bench] baseline {base_path} unreadable ({err}) — "
              "accepting the fresh run as the new baseline")
        return 0

    # Comparability key: grid, thread count, schema, the resolved SIMD
    # dispatch level, and the storage dtype — a baseline measured on an
    # AVX2 machine must not gate a portable-path runner, and f32 timings
    # must not gate a bf16 run (or vice versa); such pairs bootstrap.
    key = lambda doc: (doc.get("grid"), doc.get("threads"), doc.get("schema"),
                       doc.get("simd"), doc.get("dtype"))
    if key(fresh_doc) != key(base_doc):
        print(f"[check_bench] baseline grid/threads/schema/simd/dtype {key(base_doc)} "
              f"!= fresh {key(fresh_doc)} — not comparable, accepting fresh run")
        return 0

    # Per-method delta table (always printed).  Missing timing fields show
    # as 0 here; the gates below treat them as structural failures.
    hdr = (f"{'method':<18}{'fwd ms':>10}{'(base)':>10}{'Δ%':>8}"
           f"{'bwd ms':>10}{'(base)':>10}{'Δ%':>8}")
    print(f"[check_bench] {fresh_path} vs {base_path}")
    print("  " + hdr)
    print("  " + "-" * len(hdr))

    def pct(new, old):
        return f"{100.0 * (new - old) / old:+.0f}%" if old > 0 else "n/a"

    for method, row in fresh.items():
        fwd, bwd = row.get("fwd_ms", 0.0), row.get("bwd_ms", 0.0)
        b = base.get(method)
        if b is None:
            print(f"  {method:<18}{fwd:>10.2f}{'new':>10}{'':>8}"
                  f"{bwd:>10.2f}{'new':>10}{'':>8}")
            continue
        bf, bb = b.get("fwd_ms", 0.0), b.get("bwd_ms", 0.0)
        print(f"  {method:<18}{fwd:>10.2f}{bf:>10.2f}{pct(fwd, bf):>8}"
              f"{bwd:>10.2f}{bb:>10.2f}{pct(bwd, bb):>8}")

    failures = []
    structural = []

    # The fresh file must carry the gate rows — a bench run that cannot
    # compute the headline metrics is an error, never a silent pass.
    fresh_gap, base_gap = gap(fresh), gap(base)
    if fresh_gap is None:
        structural.append("fresh bench is missing the cce/cce_no_filter rows "
                          "(or their bwd_ms) — the filter-gap gate cannot run")
    elif base_gap is None:
        print("  baseline lacks cce/cce_no_filter rows — taking the fresh gap "
              f"({fresh_gap:.2f}x) as the new reference")
    else:
        print(f"  filter gap (no_filter/cce bwd): {fresh_gap:.2f}x "
              f"(baseline {base_gap:.2f}x)")
        if fresh_gap * THRESHOLD < base_gap:
            # The ratio also shrinks when cce_no_filter simply got *faster*
            # — a pure improvement.  Only fail when cce's own backward
            # worsened beyond median jitter (a real cce slowdown past 25%
            # is caught by the absolute gate below regardless); otherwise
            # note the narrower gap and move on.
            cce_worse = (fresh["cce"]["bwd_ms"] > base["cce"]["bwd_ms"] * NOISE)
            if cce_worse:
                failures.append(
                    f"filtered-vs-unfiltered bwd gap regressed: "
                    f"{fresh_gap:.2f}x vs baseline {base_gap:.2f}x "
                    f"(>{(THRESHOLD - 1) * 100:.0f}%) with cce bwd itself slower")
            else:
                print("  gap narrowed but cce bwd did not slow down "
                      "(the unfiltered reference got faster) — not a regression")

    # Absolute gates on cce itself: the gap ratio is blind to a *uniform*
    # slowdown (cce and cce_no_filter both regressing by the same factor),
    # so fwd and bwd are each gated against the baseline directly.
    for metric, label in [("fwd_ms", "forward"), ("bwd_ms", "backward")]:
        fresh_ms = fresh.get("cce", {}).get(metric)
        base_ms = base.get("cce", {}).get(metric)
        if fresh_ms is None:
            structural.append(f"fresh bench is missing the cce row (or its "
                              f"{metric}) — the {label}-time gate cannot run")
        elif base_ms is not None and base_ms > 0 and fresh_ms > base_ms * THRESHOLD:
            failures.append(
                f"cce {label} regressed: {fresh_ms:.2f} ms vs baseline "
                f"{base_ms:.2f} ms (>{(THRESHOLD - 1) * 100:.0f}%)")

    # Decode-shape (small-N) gate: absolute, like the cce gates above.  A
    # baseline predating the row bootstraps; a *fresh* run missing the row
    # while the baseline carries it is structural — the orchestration-
    # overhead gate must not silently disappear.
    fresh_sn, base_sn = fresh_doc.get("small_n"), base_doc.get("small_n")
    if fresh_sn is None:
        if base_sn is not None:
            structural.append("fresh bench is missing the small_n (decode-shape) "
                              "row the baseline carries — the orchestration-"
                              "overhead gate cannot run")
    elif base_sn is None:
        print(f"  small-N (N={fresh_sn.get('n')}): fwd "
              f"{fresh_sn.get('fwd_ms', 0.0):.3f} ms, fwd+bwd "
              f"{fresh_sn.get('fwdbwd_ms', 0.0):.3f} ms — baseline has no "
              "decode-shape row yet, taking this as the reference")
    elif base_sn.get("n") != fresh_sn.get("n"):
        print(f"  small-N shape changed ({base_sn.get('n')} -> {fresh_sn.get('n')}) "
              "— not comparable, taking the fresh row as the new reference")
    else:
        for metric, label in [("fwd_ms", "forward"), ("fwdbwd_ms", "forward+backward")]:
            fresh_ms, base_ms = fresh_sn.get(metric), base_sn.get(metric)
            if fresh_ms is None:
                structural.append(f"fresh small_n row is missing {metric} — the "
                                  "orchestration-overhead gate cannot run")
            elif base_ms is not None and base_ms > 0:
                print(f"  small-N {label} (N={fresh_sn.get('n')}): {fresh_ms:.3f} ms "
                      f"(baseline {base_ms:.3f} ms, {pct(fresh_ms, base_ms)})")
                if fresh_ms > base_ms * THRESHOLD:
                    failures.append(
                        f"small-N (decode shape) {label} regressed: "
                        f"{fresh_ms:.3f} ms vs baseline {base_ms:.3f} ms "
                        f"(>{(THRESHOLD - 1) * 100:.0f}%) — per-call "
                        "orchestration overhead is creeping back")

    if structural:
        for f in structural:
            print(f"[check_bench] STRUCTURAL: {f}")
        return EXIT_STRUCTURAL
    if failures:
        for f in failures:
            print(f"[check_bench] REGRESSION: {f}")
        print("[check_bench] rerun with BENCH_UPDATE=1 ./ci.sh (or "
              "tools/check_bench.sh --update) to accept deliberately")
        return EXIT_REGRESSION
    print("[check_bench] OK — no regression beyond the 25% threshold")
    return 0


try:
    sys.exit(main(sys.argv[1], sys.argv[2]))
except SystemExit:
    raise
except Exception as err:  # anything unforeseen is structural, not a "regression"
    print(f"[check_bench] STRUCTURAL: unexpected error: {err!r}")
    sys.exit(EXIT_STRUCTURAL)
PY

# --update forgives a regression verdict only; structural failures (a bench
# that could not even be compared) always propagate.
if [[ "$UPDATE" == "1" && "$STATUS" -eq 1 ]]; then
    echo "[check_bench] --update: regression accepted deliberately"
    STATUS=0
fi
exit "$STATUS"
