//! CCE backward: blockwise logit rematerialization with the §4.3 gradient
//! filter, optional vocabulary sorting, and **column-parallel** `dC`
//! accumulation.
//!
//! The gradient of the mean NLL splits into a dense indicator part and a
//! softmax part:
//!
//! ```text
//! dE_i = (Σ_j p_ij · c_j − c_{x_i}) / count
//! dC_j = (Σ_i p_ij · e_i − Σ_{i: x_i=j} e_i) / count      p_ij = exp(z_ij − lse_i)
//! ```
//!
//! The pass runs in two phases over the same global `(N_B, V_B)` block
//! grid:
//!
//! * **Phase A — `dE`, row-parallel.**  Threads own contiguous row spans
//!   (whole row-blocks).  Each block's logits are rematerialized once (one
//!   SIMD-matmul-sized pass), turned into probabilities, and — when
//!   filtering is on — the block records whether *every* softmax entry of
//!   every active row is below `eps = 2^-12`
//!   ([`crate::sparsity::FILTER_EPS`]) into a shared **skip mask**; sub-eps
//!   blocks skip the `dE` accumulation.  Since each skipped entry
//!   contributes `< eps/count` to any gradient element, the error is
//!   bounded far below f32 round-off of the surviving terms (the paper's
//!   bf16-truncation argument).
//! * **Phase B — `dC`, column-parallel.**  Threads own disjoint spans of
//!   *permuted vocabulary columns* and accumulate straight into a single
//!   shared `V×D` buffer — no atomics (spans are disjoint) and no
//!   per-thread `V×D` shards, so the backward workspace is `O(V·D)`
//!   *total* instead of `threads·V·D`; with sorting off the permutation
//!   is the identity and phase B writes directly into the `dC` output
//!   (no buffer, no gather — workspace is tiles + mask only).  Sub-eps blocks are consulted from
//!   the phase-A mask, so they skip the rematerialization *and* the
//!   accumulation.  Spans are weighted by surviving-block counts
//!   (`balance_spans`), which counters the head-heavy concentration that
//!   sorting creates.
//!
//! The indicator terms are applied once per token in the phase that owns
//! the output (they can never be filtered away).  Because every output
//! element is accumulated by exactly one thread in a fixed order, `dE` and
//! `dC` are **bitwise invariant in the thread count** (the old
//! shard-reduction changed summation order with `--threads`).
//!
//! **Vocabulary sorting** visits columns through a permutation ordered by
//! descending label frequency, concentrating the Zipf head — the entries
//! that survive filtering — into a few leading column blocks so the
//! remaining blocks die wholesale (§4.3 "sorted gradient filtering"; the
//! survival geometry is modelled by [`crate::sparsity::BlockFilterModel`]).
//!
//! Both phases execute as span tasks on the persistent fork-join pool
//! (`super::pool`) with the SIMD dispatch resolved to a [`Lanes`] token
//! once at kernel entry — no per-call thread spawn/join and no per-`dot`
//! dispatch probe anywhere in the pass.
//!
//! With [`KernelOptions::kahan`] both phases accumulate through
//! `Lanes::axpy_kahan` with per-element compensation buffers (doubling
//! the gradient working set, as the paper's CCE-Kahan memory column
//! records); `full_c` / `full_e` disable filtering for the corresponding
//! phase only (the `CCE-Kahan-FullC` / `-FullE` rows).

use super::simd::{self, Lanes};
use super::{ceil_div, pool, span_rows, BackwardOut, FilterStats, KernelOptions, Problem};
use crate::sparsity::FILTER_EPS;

/// Vocabulary permutation ordered by descending label frequency (stable by
/// token id for reproducibility).  Identity when labels are uniform.
pub fn frequency_permutation(x: &[i32], v: usize) -> Vec<u32> {
    let mut freq = vec![0u32; v];
    for &t in x {
        if t >= 0 {
            freq[t as usize] += 1;
        }
    }
    let mut perm: Vec<u32> = (0..v as u32).collect();
    perm.sort_by(|&a, &b| freq[b as usize].cmp(&freq[a as usize]).then(a.cmp(&b)));
    perm
}

/// Inverse of a permutation: `inv[perm[q]] = q`.
fn invert_permutation(perm: &[u32]) -> Vec<u32> {
    let mut inv = vec![0u32; perm.len()];
    for (q, &j) in perm.iter().enumerate() {
        inv[j as usize] = q as u32;
    }
    inv
}

/// Split `weights.len()` blocks into at most `threads` contiguous spans of
/// roughly equal total weight (boundary `k` sits at the first prefix that
/// reaches `k/threads` of the total).  Deterministic; spans may be empty.
pub(crate) fn balance_spans(weights: &[u64], threads: usize) -> Vec<usize> {
    let t = threads.max(1);
    let total: u64 = weights.iter().sum::<u64>().max(1);
    let mut bounds = vec![0usize; t + 1];
    bounds[t] = weights.len();
    let mut acc = 0u64;
    let mut k = 1;
    for (i, &w) in weights.iter().enumerate() {
        acc += w;
        while k < t && acc * t as u64 >= total * k as u64 {
            bounds[k] = i + 1;
            k += 1;
        }
    }
    while k < t {
        bounds[k] = weights.len();
        k += 1;
    }
    bounds
}

/// Shared read-only state of one backward invocation.
struct BwdCtx<'a> {
    p: &'a Problem<'a>,
    opts: &'a KernelOptions,
    /// Column visit order (frequency-sorted or identity).
    perm: &'a [u32],
    /// `inv_perm[token] = permuted position`.
    inv_perm: &'a [u32],
    lse: &'a [f32],
    inv_count: f32,
    /// Clamped row / column blocking (the global block grid).
    nb: usize,
    vb: usize,
    n_vblocks: usize,
}

/// Run the backward pass.  `lse` is the per-row log-sum-exp from
/// [`super::cce_forward`].
pub fn cce_backward(p: &Problem, opts: &KernelOptions, lse: &[f32]) -> BackwardOut {
    simd::with_lanes!(lanes => backward_with(p, opts, lse, lanes))
}

fn backward_with<L: Lanes>(
    p: &Problem,
    opts: &KernelOptions,
    lse: &[f32],
    lanes: L,
) -> BackwardOut {
    assert_eq!(lse.len(), p.n, "lse length mismatch");
    let (n, d, v) = (p.n, p.d, p.v);
    let count = p.active_count();
    let inv_count = if count == 0 { 0.0f32 } else { 1.0 / count as f32 };
    let perm: Vec<u32> = if opts.sort {
        frequency_permutation(p.x, v)
    } else {
        (0..v as u32).collect()
    };
    let inv_perm = invert_permutation(&perm);
    let nb = opts.n_block.clamp(1, n);
    let vb = opts.v_block.clamp(1, v);
    let n_rblocks = ceil_div(n, nb);
    let n_vblocks = ceil_div(v, vb);

    let mut d_e = vec![0f32; n * d];
    let mut d_c = vec![0f32; v * d];
    // The shared dC accumulator, laid out in *permuted* column order so
    // phase-B threads own contiguous disjoint slices.  With sorting off
    // the permutation is the identity, so phase B writes straight into
    // `d_c` — no extra buffer and no gather.
    let identity = !opts.sort;
    let mut dc_perm = if identity { Vec::new() } else { vec![0f32; v * d] };
    // Skip mask: 1 = every softmax entry of every active row is sub-eps.
    let mut mask = vec![0u8; n_rblocks * n_vblocks];
    let ctx = BwdCtx {
        p,
        opts,
        perm: &perm,
        inv_perm: &inv_perm,
        lse,
        inv_count,
        nb,
        vb,
        n_vblocks,
    };

    // Phase A: row-parallel dE + skip-mask fill.
    let span = span_rows(n, opts.n_block, opts.threads);
    let a_results: Vec<(FilterStats, usize)> = {
        let ctx = &ctx;
        let tasks: Vec<_> = d_e
            .chunks_mut(span * d)
            .zip(mask.chunks_mut((span / nb) * n_vblocks))
            .enumerate()
            .map(|(ti, (de_chunk, mask_chunk))| {
                move || de_phase(ctx, ti * span, de_chunk, mask_chunk, lanes)
            })
            .collect();
        pool::global().run(tasks)
    };

    // Phase B: column-parallel dC over contiguous permuted-column spans.
    // Spans are balanced at *column* granularity (weighted per column by
    // its block's surviving row-blocks), so neither v_block >= V (the
    // chunked methods) nor a sorting-concentrated hot head can serialize
    // the phase onto one thread.
    let surviving: Vec<u64> = (0..n_vblocks)
        .map(|vb_idx| {
            if opts.filter && !opts.full_c {
                (0..n_rblocks).filter(|rb| mask[rb * n_vblocks + vb_idx] == 0).count() as u64
            } else {
                n_rblocks as u64
            }
        })
        .collect();
    let col_weights: Vec<u64> = (0..v).map(|q| surviving[q / vb]).collect();
    let bounds = balance_spans(&col_weights, opts.resolved_threads());
    let b_results: Vec<usize> = {
        let ctx = &ctx;
        let mask = &mask;
        let mut tasks = Vec::new();
        let mut rest: &mut [f32] = if identity { &mut d_c } else { &mut dc_perm };
        for w in bounds.windows(2) {
            let (lo, hi) = (w[0], w[1]);
            let (chunk, tail) = rest.split_at_mut((hi - lo) * d);
            rest = tail;
            if hi > lo {
                tasks.push(move || dc_phase(ctx, lo, hi, chunk, mask, lanes));
            }
        }
        pool::global().run(tasks)
    };

    // Un-permute: every original column was accumulated by exactly one
    // phase-B thread, so this is a straight gather (skipped entirely when
    // the permutation is the identity — phase B already wrote `d_c`).
    if !identity {
        for (q, &j) in perm.iter().enumerate() {
            let j = j as usize;
            d_c[j * d..(j + 1) * d].copy_from_slice(&dc_perm[q * d..(q + 1) * d]);
        }
    }

    let mut stats = FilterStats::default();
    // Working memory beyond the dE/dC outputs: the shared permuted dC
    // accumulator (O(V·D) total — the former per-thread V×D shards are
    // gone), the skip mask, the per-thread probability tiles, and the
    // Kahan compensation buffers.
    let mut workspace = dc_perm.len() * 4 + mask.len();
    for (worker_stats, ws) in &a_results {
        stats.merge(worker_stats);
        workspace += ws;
    }
    for ws in &b_results {
        workspace += ws;
    }
    BackwardOut { d_e, d_c, stats, workspace_bytes: workspace }
}

/// Phase A over rows `[row0, row0 + de_chunk.len()/d)`: indicator + softmax
/// `dE`, filling this span's rows of the skip mask.  Returns the span's
/// filter stats and its buffer bytes (probability tile + Kahan comp).
fn de_phase<L: Lanes>(
    ctx: &BwdCtx,
    row0: usize,
    de_chunk: &mut [f32],
    mask_chunk: &mut [u8],
    lanes: L,
) -> (FilterStats, usize) {
    let p = ctx.p;
    let d = p.d;
    let v = p.v;
    let eps = FILTER_EPS as f32;
    let (nb, vb) = (ctx.nb, ctx.vb);
    let rows_total = de_chunk.len() / d;
    let mut probs = vec![0f32; nb * vb];
    let mut comp = if ctx.opts.kahan {
        vec![0f32; de_chunk.len()]
    } else {
        Vec::new()
    };
    let mut stats = FilterStats::default();

    // Indicator part: dE_i -= c_{x_i} / count.
    for r in 0..rows_total {
        let t = p.x[row0 + r];
        if t < 0 {
            continue;
        }
        let c_row = &p.c[t as usize * d..(t as usize + 1) * d];
        let de_row = &mut de_chunk[r * d..(r + 1) * d];
        if ctx.opts.kahan {
            lanes.axpy_kahan(de_row, &mut comp[r * d..(r + 1) * d], -ctx.inv_count, c_row);
        } else {
            lanes.axpy(de_row, -ctx.inv_count, c_row);
        }
    }

    // Softmax part, blockwise.
    let mut block_start = 0;
    while block_start < rows_total {
        let rows = nb.min(rows_total - block_start);
        let mut j0 = 0;
        let mut vb_idx = 0;
        while j0 < v {
            let cols = vb.min(v - j0);
            // Rematerialize the block's logits as probabilities (SIMD dot).
            let mut sig = 0u64;
            for r in 0..rows {
                let i = row0 + block_start + r;
                let p_row = &mut probs[r * cols..(r + 1) * cols];
                if p.x[i] < 0 {
                    p_row.fill(0.0);
                    continue;
                }
                let e_row = &p.e[i * d..(i + 1) * d];
                let row_lse = ctx.lse[i];
                for (jj, out) in p_row.iter_mut().enumerate() {
                    let j = ctx.perm[j0 + jj] as usize;
                    let z = lanes.dot(e_row, &p.c[j * d..(j + 1) * d]);
                    let prob = (z - row_lse).exp();
                    *out = prob;
                    sig += (prob >= eps) as u64;
                }
            }
            stats.blocks_total += 1;
            stats.sig_entries += sig;
            let sub_eps = sig == 0;
            mask_chunk[(block_start / nb) * ctx.n_vblocks + vb_idx] = sub_eps as u8;
            if ctx.opts.filter && sub_eps {
                stats.blocks_skipped += 1;
                if !ctx.opts.full_e {
                    j0 += cols;
                    vb_idx += 1;
                    continue;
                }
            }
            // dE accumulation: de_row += Σ_jj p·c_perm[jj] / count.
            for r in 0..rows {
                let i = row0 + block_start + r;
                if p.x[i] < 0 {
                    continue;
                }
                let out_row = block_start + r;
                let de_row = &mut de_chunk[out_row * d..(out_row + 1) * d];
                for jj in 0..cols {
                    let g = probs[r * cols + jj] * ctx.inv_count;
                    let j = ctx.perm[j0 + jj] as usize;
                    let c_row = &p.c[j * d..(j + 1) * d];
                    if ctx.opts.kahan {
                        lanes.axpy_kahan(
                            de_row,
                            &mut comp[out_row * d..(out_row + 1) * d],
                            g,
                            c_row,
                        );
                    } else {
                        lanes.axpy(de_row, g, c_row);
                    }
                }
            }
            j0 += cols;
            vb_idx += 1;
        }
        block_start += rows;
    }
    (stats, (probs.len() + comp.len()) * 4)
}

/// Phase B over permuted vocabulary columns `[col_lo, col_hi)` (any
/// contiguous range — spans need not align to `V_B` blocks): indicator +
/// softmax `dC`, accumulated directly into this thread's disjoint slice of
/// the shared permuted accumulator.  Skipped blocks (per the phase-A mask)
/// are never rematerialized.  Returns the buffer bytes (Kahan comp only —
/// this phase streams logits without a tile buffer).
fn dc_phase<L: Lanes>(
    ctx: &BwdCtx,
    col_lo: usize,
    col_hi: usize,
    dc_chunk: &mut [f32],
    mask: &[u8],
    lanes: L,
) -> usize {
    let p = ctx.p;
    let (n, d) = (p.n, p.d);
    let (nb, vb) = (ctx.nb, ctx.vb);
    let col0 = col_lo;
    let cols_owned = dc_chunk.len() / d;
    let mut comp = if ctx.opts.kahan {
        vec![0f32; dc_chunk.len()]
    } else {
        Vec::new()
    };

    // Indicator part: dC_{x_i} -= e_i / count for targets this span owns.
    for i in 0..n {
        let t = p.x[i];
        if t < 0 {
            continue;
        }
        let q = ctx.inv_perm[t as usize] as usize;
        if q < col0 || q >= col0 + cols_owned {
            continue;
        }
        let e_row = &p.e[i * d..(i + 1) * d];
        let dc_row = &mut dc_chunk[(q - col0) * d..(q - col0 + 1) * d];
        if ctx.opts.kahan {
            lanes.axpy_kahan(
                dc_row,
                &mut comp[(q - col0) * d..(q - col0 + 1) * d],
                -ctx.inv_count,
                e_row,
            );
        } else {
            lanes.axpy(dc_row, -ctx.inv_count, e_row);
        }
    }

    // Softmax part: stream surviving row blocks with the block loop
    // *outside* the column loop, so the row-block's E tile (nb×D) stays
    // cache-resident across every column this span owns instead of
    // re-streaming all of E once per column.  Each column still receives
    // its contributions in blocks-ascending, rows-ascending order, so dC
    // stays bitwise identical to the column-outer nest (and bitwise
    // thread-count invariant even though span boundaries move with
    // `--threads`).  `q0..q1` walks the span one V_B-block-aligned
    // segment at a time (a span may start or end mid-block).
    let mut q0 = col_lo;
    while q0 < col_hi {
        let vb_idx = q0 / vb;
        let q1 = ((vb_idx + 1) * vb).min(col_hi);
        let mut block_start = 0;
        while block_start < n {
            let rows = nb.min(n - block_start);
            let rb = block_start / nb;
            if ctx.opts.filter && !ctx.opts.full_c && mask[rb * ctx.n_vblocks + vb_idx] != 0 {
                block_start += rows;
                continue;
            }
            for q in q0..q1 {
                let j = ctx.perm[q] as usize;
                let c_row = &p.c[j * d..(j + 1) * d];
                let dc_row = &mut dc_chunk[(q - col0) * d..(q - col0 + 1) * d];
                for r in 0..rows {
                    let i = block_start + r;
                    if p.x[i] < 0 {
                        continue;
                    }
                    let e_row = &p.e[i * d..(i + 1) * d];
                    let z = lanes.dot(e_row, c_row);
                    let g = (z - ctx.lse[i]).exp() * ctx.inv_count;
                    if ctx.opts.kahan {
                        lanes.axpy_kahan(
                            dc_row,
                            &mut comp[(q - col0) * d..(q - col0 + 1) * d],
                            g,
                            e_row,
                        );
                    } else {
                        lanes.axpy(dc_row, g, e_row);
                    }
                }
            }
            block_start += rows;
        }
        q0 = q1;
    }
    comp.len() * 4
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{baseline_forward_backward, cce_forward, random_problem};
    use crate::util::rng::Rng;

    fn opts(filter: bool, sort: bool) -> KernelOptions {
        KernelOptions {
            n_block: 8,
            v_block: 16,
            threads: 2,
            filter,
            sort,
            ..KernelOptions::default()
        }
    }

    fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
    }

    #[test]
    fn unfiltered_matches_baseline() {
        let mut rng = Rng::new(11);
        let (n, d, v) = (24, 12, 60);
        let (e, c, x) = random_problem(&mut rng, n, d, v, 0.2);
        let p = Problem::new(&e, &c, &x, n, d, v).unwrap();
        let (_, reference) = baseline_forward_backward(&p, &KernelOptions::default());
        for sort in [false, true] {
            let o = opts(false, sort);
            let fwd = cce_forward(&p, &o);
            let bwd = cce_backward(&p, &o, &fwd.lse);
            assert!(
                max_abs_diff(&bwd.d_e, &reference.d_e) < 1e-5,
                "d_e diverges (sort={sort})"
            );
            assert!(
                max_abs_diff(&bwd.d_c, &reference.d_c) < 1e-5,
                "d_c diverges (sort={sort})"
            );
            assert_eq!(bwd.stats.blocks_skipped, 0);
        }
    }

    #[test]
    fn kahan_backward_matches_plain_on_benign_inputs() {
        let mut rng = Rng::new(12);
        let (n, d, v) = (20, 10, 48);
        let (e, c, x) = random_problem(&mut rng, n, d, v, 0.2);
        let p = Problem::new(&e, &c, &x, n, d, v).unwrap();
        let o = opts(true, true);
        let ok = KernelOptions { kahan: true, ..o };
        let fwd = cce_forward(&p, &o);
        let plain = cce_backward(&p, &o, &fwd.lse);
        let kahan = cce_backward(&p, &ok, &fwd.lse);
        assert!(max_abs_diff(&plain.d_e, &kahan.d_e) < 1e-5);
        assert!(max_abs_diff(&plain.d_c, &kahan.d_c) < 1e-5);
        // Compensation buffers are accounted: ~double the gradient-sized
        // working set on top of the shared accumulator.
        assert!(kahan.workspace_bytes > plain.workspace_bytes);
    }

    #[test]
    fn full_variants_disable_filtering_per_output() {
        // Peaked softmax (target 0 dominant) => real skippable blocks.
        let mut rng = Rng::new(14);
        let (n, d, v) = (32, 4, 256);
        let mut c: Vec<f32> = (0..v * d).map(|_| (rng.normal() * 0.1) as f32).collect();
        c[0] = 10.0;
        let mut e = vec![0f32; n * d];
        let x = vec![0i32; n];
        for i in 0..n {
            e[i * d] = 1.5 + rng.f32() * 0.2;
        }
        let p = Problem::new(&e, &c, &x, n, d, v).unwrap();
        let base = KernelOptions { kahan: true, ..opts(true, true) };
        let fwd = cce_forward(&p, &base);
        let exact = cce_backward(&p, &KernelOptions { filter: false, ..base }, &fwd.lse);
        let full_c = cce_backward(&p, &KernelOptions { full_c: true, ..base }, &fwd.lse);
        let full_e = cce_backward(&p, &KernelOptions { full_e: true, ..base }, &fwd.lse);
        // full_c: dC is exact (unfiltered) even though blocks were skipped.
        assert!(full_c.stats.blocks_skipped > 0);
        assert!(max_abs_diff(&full_c.d_c, &exact.d_c) < 1e-6, "full_c dC must be unfiltered");
        // full_e: dE is exact (unfiltered).
        assert!(full_e.stats.blocks_skipped > 0);
        assert!(max_abs_diff(&full_e.d_e, &exact.d_e) < 1e-6, "full_e dE must be unfiltered");
    }

    #[test]
    fn frequency_permutation_orders_hot_tokens_first() {
        let x = vec![3, 3, 3, 1, 1, 7, -1, -1];
        let perm = frequency_permutation(&x, 8);
        assert_eq!(perm[0], 3);
        assert_eq!(perm[1], 1);
        assert_eq!(perm[2], 7);
        // Remaining ids in stable (ascending) order.
        assert_eq!(&perm[3..], &[0, 2, 4, 5, 6]);
        let mut sorted = perm.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..8).collect::<Vec<u32>>());
        // And the inverse really inverts.
        let inv = invert_permutation(&perm);
        for (q, &j) in perm.iter().enumerate() {
            assert_eq!(inv[j as usize] as usize, q);
        }
    }

    #[test]
    fn balance_spans_tracks_weight() {
        // Uniform weights: near-even contiguous split.
        let bounds = balance_spans(&[1; 8], 4);
        assert_eq!(bounds, vec![0, 2, 4, 6, 8]);
        // Head-heavy weights (the sorted-filter shape): the first span
        // stays small so one thread does not own the whole hot head.
        let bounds = balance_spans(&[12, 4, 0, 0, 0, 0, 0, 0], 4);
        assert_eq!(*bounds.first().unwrap(), 0);
        assert_eq!(*bounds.last().unwrap(), 8);
        assert!(bounds[1] <= 2, "hot head must close the first span early: {bounds:?}");
        for w in bounds.windows(2) {
            assert!(w[0] <= w[1]);
        }
        // More threads than blocks: spans stay in range, some empty.
        let bounds = balance_spans(&[5, 5], 8);
        assert_eq!(*bounds.last().unwrap(), 2);
        assert!(bounds.iter().all(|&b| b <= 2));
    }

    #[test]
    fn filtered_error_is_within_eps_bound() {
        // Deterministically peaked softmax: token 0 is a strong shared
        // direction, every label is 0, so every column block except the one
        // holding column 0 is provably sub-eps and must be skipped.
        let mut rng = Rng::new(13);
        let (n, d, v) = (32, 4, 256);
        let mut c: Vec<f32> = (0..v * d).map(|_| (rng.normal() * 0.1) as f32).collect();
        c[0] = 10.0; // c_0 ≈ 10·u_0
        let mut e = vec![0f32; n * d];
        let mut x = vec![0i32; n];
        for i in 0..n {
            e[i * d] = 1.5 + rng.f32() * 0.2; // z_{i,0} ≈ 15..17, others |z| ≲ 1
            if i % 8 == 7 {
                x[i] = -1; // a few ignored rows in the mix
            }
        }
        let p = Problem::new(&e, &c, &x, n, d, v).unwrap();
        let o = opts(true, true);
        let fwd = cce_forward(&p, &o);
        let filtered = cce_backward(&p, &o, &fwd.lse);
        let exact = cce_backward(&p, &opts(false, true), &fwd.lse);
        assert!(
            filtered.stats.blocks_skipped > 0,
            "peaked input skipped no blocks: {:?}",
            filtered.stats
        );
        // Per-element bound: each skipped entry contributes < eps/count
        // times a bounded factor; V·eps·max|input|/count is a loose cap.
        let count = fwd.count as f32;
        let max_in = e
            .iter()
            .chain(c.iter())
            .map(|z| z.abs())
            .fold(0.0f32, f32::max);
        let bound = (v as f32) * (FILTER_EPS as f32) * max_in / count;
        assert!(
            max_abs_diff(&filtered.d_e, &exact.d_e) <= bound,
            "d_e filter error above bound {bound}"
        );
        assert!(
            max_abs_diff(&filtered.d_c, &exact.d_c) <= bound,
            "d_c filter error above bound {bound}"
        );
    }

    #[test]
    fn sorting_skips_more_blocks_on_shuffled_zipf() {
        // Hot tokens with *shuffled ids*: each row's softmax concentrates
        // on its target (an id scattered anywhere in the vocabulary), so
        // unsorted filtering keeps every block that holds some row's
        // target, while frequency sorting pulls all hot ids into the
        // leading column block (the cce vs cce_no_sort ablation).
        let mut rng = Rng::new(17);
        let (n, d, v) = (64, 16, 512);
        let n_hot = 8;
        let mut ids: Vec<usize> = (0..v).collect();
        rng.shuffle(&mut ids);
        let hot: Vec<usize> = ids[..n_hot].to_vec();
        // Hot token r gets classifier row 6·u_r; cold rows are tiny noise.
        let mut c: Vec<f32> = (0..v * d).map(|_| (rng.normal() * 0.05) as f32).collect();
        for (r, &id) in hot.iter().enumerate() {
            c[id * d + r] = 6.0;
        }
        // Row i picks hot rank (Zipf-ish via modulo bias) and aligns with it.
        let mut e = vec![0f32; n * d];
        let mut x = vec![0i32; n];
        for i in 0..n {
            let r = (i % (n_hot + 4)).min(n_hot - 1); // ranks 0..8, head-heavy
            x[i] = hot[r] as i32;
            e[i * d + r] = 2.0; // z_target = 12, every other |z| ≲ 1
        }
        let p = Problem::new(&e, &c, &x, n, d, v).unwrap();
        let o = KernelOptions { n_block: 16, v_block: 32, threads: 2, ..KernelOptions::default() };
        let fwd = cce_forward(&p, &o);
        let sorted = cce_backward(&p, &o, &fwd.lse);
        let unsorted = cce_backward(&p, &KernelOptions { sort: false, ..o }, &fwd.lse);
        assert!(
            sorted.stats.blocks_skipped >= unsorted.stats.blocks_skipped,
            "sorting should not reduce skips: {:?} vs {:?}",
            sorted.stats,
            unsorted.stats
        );
        // Sorted: the significant set is exactly the n_hot hot tokens, all
        // in the first permuted block => at most one surviving vocab block
        // per row-block.
        let total = sorted.stats.blocks_total;
        assert!(
            sorted.stats.blocks_skipped * 2 > total,
            "sorted filtering should skip most blocks: {:?}",
            sorted.stats
        );
        // Both runs compute the same gradients despite different skip sets.
        assert!(max_abs_diff(&sorted.d_e, &unsorted.d_e) < 1e-3);
        assert!(max_abs_diff(&sorted.d_c, &unsorted.d_c) < 1e-3);
    }
}
