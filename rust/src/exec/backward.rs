//! CCE backward: blockwise logit rematerialization with the §4.3 gradient
//! filter and optional vocabulary sorting.
//!
//! The gradient of the mean NLL splits into a dense indicator part and a
//! softmax part:
//!
//! ```text
//! dE_i = (Σ_j p_ij · c_j − c_{x_i}) / count
//! dC_j = (Σ_i p_ij · e_i − Σ_{i: x_i=j} e_i) / count      p_ij = exp(z_ij − lse_i)
//! ```
//!
//! The indicator terms are applied once per token up front (they can never
//! be filtered away).  The softmax part is computed per `(N_B, V_B)` block:
//! rematerialize the block's logits (one matmul-sized pass), form
//! `p = exp(z − lse)`, and — when filtering is on — **skip the two
//! accumulation passes** whenever every `p` of every active row is below
//! `eps = 2^-12` ([`crate::sparsity::FILTER_EPS`]).  Since each skipped
//! entry contributes `< eps/count` to any gradient element, the error is
//! bounded far below f32 round-off of the surviving terms (the paper's
//! bf16-truncation argument).
//!
//! **Vocabulary sorting** visits columns through a permutation ordered by
//! descending label frequency, concentrating the Zipf head — the entries
//! that survive filtering — into a few leading column blocks so the
//! remaining blocks die wholesale (§4.3 "sorted gradient filtering"; the
//! survival geometry is modelled by [`crate::sparsity::BlockFilterModel`]).

use super::{dot, span_rows, BackwardOut, FilterStats, KernelOptions, Problem};
use crate::sparsity::FILTER_EPS;

/// Vocabulary permutation ordered by descending label frequency (stable by
/// token id for reproducibility).  Identity when labels are uniform.
pub fn frequency_permutation(x: &[i32], v: usize) -> Vec<u32> {
    let mut freq = vec![0u32; v];
    for &t in x {
        if t >= 0 {
            freq[t as usize] += 1;
        }
    }
    let mut perm: Vec<u32> = (0..v as u32).collect();
    perm.sort_by(|&a, &b| freq[b as usize].cmp(&freq[a as usize]).then(a.cmp(&b)));
    perm
}

/// Run the backward pass.  `lse` is the per-row log-sum-exp from
/// [`super::cce_forward`].  Multi-threaded over contiguous row spans; each
/// worker accumulates its own `dC` shard, reduced at the end.
pub fn cce_backward(p: &Problem, opts: &KernelOptions, lse: &[f32]) -> BackwardOut {
    assert_eq!(lse.len(), p.n, "lse length mismatch");
    let (n, d, v) = (p.n, p.d, p.v);
    let count = p.active_count();
    let inv_count = if count == 0 { 0.0f32 } else { 1.0 / count as f32 };
    let perm: Vec<u32> = if opts.sort {
        frequency_permutation(p.x, v)
    } else {
        (0..v as u32).collect()
    };

    let mut d_e = vec![0f32; n * d];
    let mut d_c = vec![0f32; v * d];
    let span = span_rows(n, opts.n_block, opts.threads);
    let results: Vec<(Vec<f32>, FilterStats, usize)> = std::thread::scope(|scope| {
        let handles: Vec<_> = d_e
            .chunks_mut(span * d)
            .enumerate()
            .map(|(ti, de_chunk)| {
                let row0 = ti * span;
                let opts = *opts;
                let perm = &perm;
                scope.spawn(move || {
                    backward_span(p, &opts, perm, lse, inv_count, row0, de_chunk)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("backward worker")).collect()
    });

    let mut stats = FilterStats::default();
    // Working memory beyond the dE/dC outputs: per-thread logit-block
    // buffers plus the per-thread dC shards.
    let mut workspace = 0usize;
    for (shard, worker_stats, ws) in &results {
        for (acc, val) in d_c.iter_mut().zip(shard) {
            *acc += *val;
        }
        stats.merge(worker_stats);
        workspace += ws + shard.len() * 4;
    }
    BackwardOut { d_e, d_c, stats, workspace_bytes: workspace }
}

/// Process rows `[row0, row0 + rows_total)`.  Returns this worker's `dC`
/// shard, its filter stats, and its block-buffer bytes.
fn backward_span(
    p: &Problem,
    opts: &KernelOptions,
    perm: &[u32],
    lse: &[f32],
    inv_count: f32,
    row0: usize,
    de_chunk: &mut [f32],
) -> (Vec<f32>, FilterStats, usize) {
    let d = p.d;
    let v = p.v;
    let eps = FILTER_EPS as f32;
    let rows_total = de_chunk.len() / d;
    let n_block = opts.n_block.clamp(1, rows_total.max(1));
    let v_block = opts.v_block.clamp(1, v);
    let mut probs = vec![0f32; n_block * v_block];
    let mut dc_local = vec![0f32; v * d];
    let mut stats = FilterStats::default();

    // Indicator part: dE_i -= c_{x_i}/count, dC_{x_i} -= e_i/count.
    for r in 0..rows_total {
        let i = row0 + r;
        let t = p.x[i];
        if t < 0 {
            continue;
        }
        let t = t as usize;
        let e_row = &p.e[i * d..(i + 1) * d];
        let c_row = &p.c[t * d..(t + 1) * d];
        let de_row = &mut de_chunk[r * d..(r + 1) * d];
        let dc_row = &mut dc_local[t * d..(t + 1) * d];
        for k in 0..d {
            de_row[k] -= inv_count * c_row[k];
            dc_row[k] -= inv_count * e_row[k];
        }
    }

    // Softmax part, blockwise with filtering.
    let mut block_start = 0;
    while block_start < rows_total {
        let rows = n_block.min(rows_total - block_start);
        let mut j0 = 0;
        while j0 < v {
            let cols = v_block.min(v - j0);
            // Rematerialize the block's logits as probabilities.
            let mut sig = 0u64;
            for r in 0..rows {
                let i = row0 + block_start + r;
                let active = p.x[i] >= 0;
                let e_row = &p.e[i * d..(i + 1) * d];
                let p_row = &mut probs[r * cols..(r + 1) * cols];
                if !active {
                    p_row.fill(0.0);
                    continue;
                }
                let row_lse = lse[i];
                for (jj, out) in p_row.iter_mut().enumerate() {
                    let j = perm[j0 + jj] as usize;
                    let z = dot(e_row, &p.c[j * d..(j + 1) * d]);
                    let prob = (z - row_lse).exp();
                    *out = prob;
                    sig += (prob >= eps) as u64;
                }
            }
            stats.blocks_total += 1;
            stats.sig_entries += sig;
            if opts.filter && sig == 0 {
                // Every softmax entry of every active row is sub-eps: the
                // block's two accumulation matmuls are skipped entirely.
                stats.blocks_skipped += 1;
                j0 += cols;
                continue;
            }
            // Accumulation: dE rows and the local dC shard, fused.
            for r in 0..rows {
                let i = row0 + block_start + r;
                if p.x[i] < 0 {
                    continue;
                }
                let e_row = &p.e[i * d..(i + 1) * d];
                let de_row = &mut de_chunk[(block_start + r) * d..(block_start + r + 1) * d];
                for jj in 0..cols {
                    let g = probs[r * cols + jj] * inv_count;
                    let j = perm[j0 + jj] as usize;
                    let c_row = &p.c[j * d..(j + 1) * d];
                    let dc_row = &mut dc_local[j * d..(j + 1) * d];
                    for k in 0..d {
                        de_row[k] += g * c_row[k];
                        dc_row[k] += g * e_row[k];
                    }
                }
            }
            j0 += cols;
        }
        block_start += rows;
    }
    let buffer_bytes = probs.len() * 4;
    (dc_local, stats, buffer_bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{baseline_forward_backward, cce_forward, random_problem};
    use crate::util::rng::Rng;

    fn opts(filter: bool, sort: bool) -> KernelOptions {
        KernelOptions { n_block: 8, v_block: 16, threads: 2, filter, sort }
    }

    fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
    }

    #[test]
    fn unfiltered_matches_baseline() {
        let mut rng = Rng::new(11);
        let (n, d, v) = (24, 12, 60);
        let (e, c, x) = random_problem(&mut rng, n, d, v, 0.2);
        let p = Problem::new(&e, &c, &x, n, d, v).unwrap();
        let (_, reference) = baseline_forward_backward(&p, &KernelOptions::default());
        for sort in [false, true] {
            let o = opts(false, sort);
            let fwd = cce_forward(&p, &o);
            let bwd = cce_backward(&p, &o, &fwd.lse);
            assert!(
                max_abs_diff(&bwd.d_e, &reference.d_e) < 1e-5,
                "d_e diverges (sort={sort})"
            );
            assert!(
                max_abs_diff(&bwd.d_c, &reference.d_c) < 1e-5,
                "d_c diverges (sort={sort})"
            );
            assert_eq!(bwd.stats.blocks_skipped, 0);
        }
    }

    #[test]
    fn frequency_permutation_orders_hot_tokens_first() {
        let x = vec![3, 3, 3, 1, 1, 7, -1, -1];
        let perm = frequency_permutation(&x, 8);
        assert_eq!(perm[0], 3);
        assert_eq!(perm[1], 1);
        assert_eq!(perm[2], 7);
        // Remaining ids in stable (ascending) order.
        assert_eq!(&perm[3..], &[0, 2, 4, 5, 6]);
        let mut sorted = perm.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..8).collect::<Vec<u32>>());
    }

    #[test]
    fn filtered_error_is_within_eps_bound() {
        // Deterministically peaked softmax: token 0 is a strong shared
        // direction, every label is 0, so every column block except the one
        // holding column 0 is provably sub-eps and must be skipped.
        let mut rng = Rng::new(13);
        let (n, d, v) = (32, 4, 256);
        let mut c: Vec<f32> = (0..v * d).map(|_| (rng.normal() * 0.1) as f32).collect();
        c[0] = 10.0; // c_0 ≈ 10·u_0
        let mut e = vec![0f32; n * d];
        let mut x = vec![0i32; n];
        for i in 0..n {
            e[i * d] = 1.5 + rng.f32() * 0.2; // z_{i,0} ≈ 15..17, others |z| ≲ 1
            if i % 8 == 7 {
                x[i] = -1; // a few ignored rows in the mix
            }
        }
        let p = Problem::new(&e, &c, &x, n, d, v).unwrap();
        let o = opts(true, true);
        let fwd = cce_forward(&p, &o);
        let filtered = cce_backward(&p, &o, &fwd.lse);
        let exact = cce_backward(&p, &opts(false, true), &fwd.lse);
        assert!(
            filtered.stats.blocks_skipped > 0,
            "peaked input skipped no blocks: {:?}",
            filtered.stats
        );
        // Per-element bound: each skipped entry contributes < eps/count
        // times a bounded factor; V·eps·max|input|/count is a loose cap.
        let count = fwd.count as f32;
        let max_in = e
            .iter()
            .chain(c.iter())
            .map(|z| z.abs())
            .fold(0.0f32, f32::max);
        let bound = (v as f32) * (FILTER_EPS as f32) * max_in / count;
        assert!(
            max_abs_diff(&filtered.d_e, &exact.d_e) <= bound,
            "d_e filter error above bound {bound}"
        );
        assert!(
            max_abs_diff(&filtered.d_c, &exact.d_c) <= bound,
            "d_c filter error above bound {bound}"
        );
    }

    #[test]
    fn sorting_skips_more_blocks_on_shuffled_zipf() {
        // Hot tokens with *shuffled ids*: each row's softmax concentrates
        // on its target (an id scattered anywhere in the vocabulary), so
        // unsorted filtering keeps every block that holds some row's
        // target, while frequency sorting pulls all hot ids into the
        // leading column block (the cce vs cce_no_sort ablation).
        let mut rng = Rng::new(17);
        let (n, d, v) = (64, 16, 512);
        let n_hot = 8;
        let mut ids: Vec<usize> = (0..v).collect();
        rng.shuffle(&mut ids);
        let hot: Vec<usize> = ids[..n_hot].to_vec();
        // Hot token r gets classifier row 6·u_r; cold rows are tiny noise.
        let mut c: Vec<f32> = (0..v * d).map(|_| (rng.normal() * 0.05) as f32).collect();
        for (r, &id) in hot.iter().enumerate() {
            c[id * d + r] = 6.0;
        }
        // Row i picks hot rank (Zipf-ish via modulo bias) and aligns with it.
        let mut e = vec![0f32; n * d];
        let mut x = vec![0i32; n];
        for i in 0..n {
            let r = (i % (n_hot + 4)).min(n_hot - 1); // ranks 0..8, head-heavy
            x[i] = hot[r] as i32;
            e[i * d + r] = 2.0; // z_target = 12, every other |z| ≲ 1
        }
        let p = Problem::new(&e, &c, &x, n, d, v).unwrap();
        let o = KernelOptions { n_block: 16, v_block: 32, threads: 2, filter: true, sort: true };
        let fwd = cce_forward(&p, &o);
        let sorted = cce_backward(&p, &o, &fwd.lse);
        let unsorted = cce_backward(&p, &KernelOptions { sort: false, ..o }, &fwd.lse);
        assert!(
            sorted.stats.blocks_skipped >= unsorted.stats.blocks_skipped,
            "sorting should not reduce skips: {:?} vs {:?}",
            sorted.stats,
            unsorted.stats
        );
        // Sorted: the significant set is exactly the n_hot hot tokens, all
        // in the first permuted block => at most one surviving vocab block
        // per row-block.
        let total = sorted.stats.blocks_total;
        assert!(
            sorted.stats.blocks_skipped * 2 > total,
            "sorted filtering should skip most blocks: {:?}",
            sorted.stats
        );
        // Both runs compute the same gradients despite different skip sets.
        assert!(max_abs_diff(&sorted.d_e, &unsorted.d_e) < 1e-3);
        assert!(max_abs_diff(&sorted.d_c, &unsorted.d_c) < 1e-3);
    }
}
