//! CCE backward: blockwise logit rematerialization with the §4.3 gradient
//! filter, optional vocabulary sorting, and **column-parallel** `dC`
//! accumulation, generic over the storage dtype.
//!
//! The gradient of the mean NLL splits into a dense indicator part and a
//! softmax part:
//!
//! ```text
//! dE_i = (Σ_j p_ij · c_j − c_{x_i}) / count
//! dC_j = (Σ_i p_ij · e_i − Σ_{i: x_i=j} e_i) / count      p_ij = exp(z_ij − lse_i)
//! ```
//!
//! The pass runs in two phases over the same global `(N_B, V_B)` block
//! grid:
//!
//! * **Phase A — `dE`, row-parallel.**  Threads own contiguous row spans
//!   (whole row-blocks).  Each block's logits are rematerialized once (one
//!   SIMD-matmul-sized pass), turned into probabilities, and — when
//!   filtering is on — the block records whether *every* softmax entry of
//!   every active row is below `eps = 2^-12`
//!   ([`crate::sparsity::FILTER_EPS`]) into a shared **skip mask**; sub-eps
//!   blocks skip the `dE` accumulation.  Since each skipped entry
//!   contributes `< eps/count` to any gradient element, the error is
//!   bounded far below f32 round-off of the surviving terms (the paper's
//!   bf16-truncation argument).  A block's `dE` rows accumulate in an
//!   **f32 staging buffer** (`N_B×D` per thread) and are narrowed into the
//!   stored output once at block end — with `S = f32` the narrow is a
//!   copy and the arithmetic is bit-identical to accumulating in place.
//! * **Phase B — `dC`, column-parallel.**  Threads own disjoint spans of
//!   *permuted vocabulary columns*.  Each task receives the actual `&mut`
//!   row slices of the `dC` output it owns (every row handle moves into
//!   exactly one task through the permutation — no `V×D` side accumulator
//!   and no unpermute gather), accumulates a small segment of columns
//!   ([`GRAD_SEG_COLS`]`×D` f32 scratch) across all surviving row blocks,
//!   and narrows each finished segment straight into its output rows.
//!   Sub-eps blocks are consulted from the phase-A mask, so they skip the
//!   rematerialization *and* the accumulation.  Spans are weighted by
//!   surviving-block counts (`balance_spans`), which counters the
//!   head-heavy concentration that sorting creates.
//!
//! The indicator terms are applied once per token in the phase that owns
//! the output (they can never be filtered away), *before* the softmax
//! contributions of the same element.  Because every output element is
//! accumulated by exactly one thread in a fixed order, `dE` and `dC` are
//! **bitwise invariant in the thread count**.
//!
//! **Vocabulary sorting** visits columns through a permutation ordered by
//! descending label frequency, concentrating the Zipf head — the entries
//! that survive filtering — into a few leading column blocks so the
//! remaining blocks die wholesale (§4.3 "sorted gradient filtering"; the
//! survival geometry is modelled by [`crate::sparsity::BlockFilterModel`]).
//!
//! Both phases execute as span tasks on the persistent fork-join pool
//! (`super::pool`) with the SIMD dispatch resolved to a [`Lanes`] token
//! once at kernel entry.  With `S = BF16` every parameter read widens on
//! load inside the SIMD routines and every gradient store narrows (RNE)
//! from the f32 staging — accumulation is never bf16.
//!
//! With [`KernelOptions::kahan`] both staging buffers carry per-element
//! compensation (`Lanes::axpy_kahan*`); `full_c` / `full_e` disable
//! filtering for the corresponding phase only (the `CCE-Kahan-FullC` /
//! `-FullE` rows).

use super::simd::{self, Lanes};
use super::{ceil_div, pool, span_rows, BackwardOut, FilterStats, KernelOptions, Problem, Store};
use crate::sparsity::FILTER_EPS;

/// Columns per phase-B f32 staging segment.  Chosen small so the measured
/// backward workspace stays a rounding error next to the gradient outputs
/// (the Table-1 memory column), while still amortizing each row-block's
/// `E` tile over 16 columns of rematerialized dots.
pub const GRAD_SEG_COLS: usize = 16;

/// Vocabulary permutation ordered by descending label frequency (stable by
/// token id for reproducibility).  Identity when labels are uniform.
pub fn frequency_permutation(x: &[i32], v: usize) -> Vec<u32> {
    let mut freq = vec![0u32; v];
    for &t in x {
        if t >= 0 {
            freq[t as usize] += 1;
        }
    }
    let mut perm: Vec<u32> = (0..v as u32).collect();
    perm.sort_by(|&a, &b| freq[b as usize].cmp(&freq[a as usize]).then(a.cmp(&b)));
    perm
}

/// Inverse of a permutation: `inv[perm[q]] = q`.
fn invert_permutation(perm: &[u32]) -> Vec<u32> {
    let mut inv = vec![0u32; perm.len()];
    for (q, &j) in perm.iter().enumerate() {
        inv[j as usize] = q as u32;
    }
    inv
}

/// Split `weights.len()` blocks into at most `threads` contiguous spans of
/// roughly equal total weight (boundary `k` sits at the first prefix that
/// reaches `k/threads` of the total).  Deterministic; spans may be empty.
pub(crate) fn balance_spans(weights: &[u64], threads: usize) -> Vec<usize> {
    let t = threads.max(1);
    let total: u64 = weights.iter().sum::<u64>().max(1);
    let mut bounds = vec![0usize; t + 1];
    bounds[t] = weights.len();
    let mut acc = 0u64;
    let mut k = 1;
    for (i, &w) in weights.iter().enumerate() {
        acc += w;
        while k < t && acc * t as u64 >= total * k as u64 {
            bounds[k] = i + 1;
            k += 1;
        }
    }
    while k < t {
        bounds[k] = weights.len();
        k += 1;
    }
    bounds
}

/// Shared read-only state of one backward invocation.
struct BwdCtx<'a, S: Store> {
    p: &'a Problem<'a, S>,
    opts: &'a KernelOptions,
    /// Column visit order (frequency-sorted or identity).
    perm: &'a [u32],
    /// `inv_perm[token] = permuted position`.
    inv_perm: &'a [u32],
    lse: &'a [f32],
    /// Row activity for the *softmax* terms.  In the single-process pass
    /// this is `p.x`; under vocabulary sharding (`cce_backward_sharded`) a
    /// row whose label lives on another shard still contributes softmax
    /// mass here, so its global label (any value `>= 0`) marks it active
    /// even though the shard-local `p.x[i]` is `-1`.  The indicator terms
    /// always consult `p.x` — only the owning shard holds the target
    /// column.
    global_x: &'a [i32],
    inv_count: f32,
    /// Clamped row / column blocking (the global block grid).
    nb: usize,
    vb: usize,
    n_vblocks: usize,
}

/// Run the backward pass.  `lse` is the per-row log-sum-exp from
/// [`super::cce_forward`].
pub fn cce_backward<S: Store>(
    p: &Problem<S>,
    opts: &KernelOptions,
    lse: &[f32],
) -> BackwardOut<S> {
    cce_backward_sharded(p, opts, lse, p.x, p.active_count())
}

/// Shard-local backward for vocabulary-sharded execution (`crate::shard`).
///
/// `p` holds one shard's classifier columns with labels remapped to the
/// shard-local range (`-1` where the label belongs to another shard);
/// `lse` is the **globally merged** per-row log-sum-exp; `global_x`
/// carries the original (global) labels so rows whose target lives
/// elsewhere still accumulate their softmax mass against this shard's
/// columns; `global_count` is the global active-token count (the loss
/// denominator).  Because the softmax probabilities are taken against the
/// global LSE, the §4.3 sub-`eps` filter bound holds per shard exactly as
/// it does in one process.  With `global_x = p.x` and `global_count =
/// p.active_count()` this *is* [`cce_backward`], bit for bit.
pub fn cce_backward_sharded<S: Store>(
    p: &Problem<S>,
    opts: &KernelOptions,
    lse: &[f32],
    global_x: &[i32],
    global_count: usize,
) -> BackwardOut<S> {
    let sweep = crate::obs::Stopwatch::start();
    let out =
        simd::with_lanes!(lanes => backward_with(p, opts, lse, global_x, global_count, lanes));
    if let Some(us) = sweep.elapsed_us() {
        super::record_bwd_sweep(us, &out.stats, out.workspace_bytes, p.n, p.v, opts);
    }
    out
}

fn backward_with<S: Store, L: Lanes>(
    p: &Problem<S>,
    opts: &KernelOptions,
    lse: &[f32],
    global_x: &[i32],
    global_count: usize,
    lanes: L,
) -> BackwardOut<S> {
    assert_eq!(lse.len(), p.n, "lse length mismatch");
    assert_eq!(global_x.len(), p.n, "global_x length mismatch");
    let (n, d, v) = (p.n, p.d, p.v);
    let inv_count = if global_count == 0 { 0.0f32 } else { 1.0 / global_count as f32 };
    let perm: Vec<u32> = if opts.sort {
        frequency_permutation(p.x, v)
    } else {
        (0..v as u32).collect()
    };
    let inv_perm = invert_permutation(&perm);
    let nb = opts.n_block.clamp(1, n);
    let vb = opts.v_block.clamp(1, v);
    let n_rblocks = ceil_div(n, nb);
    let n_vblocks = ceil_div(v, vb);

    let mut d_e = vec![S::ZERO; n * d];
    let mut d_c = vec![S::ZERO; v * d];
    // Skip mask: 1 = every softmax entry of every active row is sub-eps.
    let mut mask = vec![0u8; n_rblocks * n_vblocks];
    let ctx = BwdCtx {
        p,
        opts,
        perm: &perm,
        inv_perm: &inv_perm,
        lse,
        global_x,
        inv_count,
        nb,
        vb,
        n_vblocks,
    };

    // Phase A: row-parallel dE + skip-mask fill.
    let span = span_rows(n, opts.n_block, opts.threads);
    let a_results: Vec<(FilterStats, usize)> = {
        let ctx = &ctx;
        let tasks: Vec<_> = d_e
            .chunks_mut(span * d)
            .zip(mask.chunks_mut((span / nb) * n_vblocks))
            .enumerate()
            .map(|(ti, (de_chunk, mask_chunk))| {
                move || de_phase(ctx, ti * span, de_chunk, mask_chunk, lanes)
            })
            .collect();
        pool::global().run(tasks)
    };

    // Phase B: column-parallel dC over contiguous permuted-column spans.
    // Spans are balanced at *column* granularity (weighted per column by
    // its block's surviving row-blocks), so neither v_block >= V (the
    // chunked methods) nor a sorting-concentrated hot head can serialize
    // the phase onto one thread.
    let surviving: Vec<u64> = (0..n_vblocks)
        .map(|vb_idx| {
            if opts.filter && !opts.full_c {
                (0..n_rblocks).filter(|rb| mask[rb * n_vblocks + vb_idx] == 0).count() as u64
            } else {
                n_rblocks as u64
            }
        })
        .collect();
    let col_weights: Vec<u64> = (0..v).map(|q| surviving[q / vb]).collect();
    let bounds = balance_spans(&col_weights, opts.resolved_threads());
    // Hand each task the `&mut` output rows it owns, in permuted order:
    // `perm` is a bijection, so every row handle moves out of `slots`
    // exactly once and into exactly one task — disjoint mutable access to
    // `d_c` with no side accumulator and no gather (the old sorted path
    // paid a second V×D buffer here).
    let b_results: Vec<usize> = {
        let mut slots: Vec<Option<&mut [S]>> = d_c.chunks_mut(d).map(Some).collect();
        let rows_perm: Vec<&mut [S]> = perm
            .iter()
            .map(|&j| slots[j as usize].take().expect("perm is a bijection"))
            .collect();
        drop(slots);
        let ctx = &ctx;
        let mask = &mask;
        let mut handles = rows_perm.into_iter();
        let mut tasks = Vec::new();
        for w in bounds.windows(2) {
            let (lo, hi) = (w[0], w[1]);
            let rows: Vec<&mut [S]> = handles.by_ref().take(hi - lo).collect();
            if hi > lo {
                tasks.push(move || {
                    let mut rows = rows;
                    dc_phase(ctx, lo, hi, &mut rows, mask, lanes)
                });
            }
        }
        pool::global().run(tasks)
    };

    let mut stats = FilterStats::default();
    // Peak *concurrent* working memory beyond the outputs: the phases run
    // sequentially, so it is the larger of the two.  Both hold the
    // permutation tables and the mask; phase A adds per-thread probability
    // tiles + f32 staging (+ Kahan comp), phase B adds the per-row output
    // handles (fat pointers, counted honestly — they are real transient
    // memory) and the per-thread segment scratch.
    let common = perm.len() * 4 + inv_perm.len() * 4 + mask.len();
    let phase_a = common + a_results.iter().map(|(_, ws)| ws).sum::<usize>();
    let phase_b =
        common + v * std::mem::size_of::<&mut [S]>() + b_results.iter().sum::<usize>();
    for (worker_stats, _) in &a_results {
        stats.merge(worker_stats);
    }
    BackwardOut { d_e, d_c, stats, workspace_bytes: phase_a.max(phase_b) }
}

/// Phase A over rows `[row0, row0 + de_chunk.len()/d)`: indicator + softmax
/// `dE` through an f32 staging block, filling this span's rows of the skip
/// mask.  Returns the span's filter stats and its buffer bytes
/// (probability tile + staging + Kahan comp).
fn de_phase<S: Store, L: Lanes>(
    ctx: &BwdCtx<S>,
    row0: usize,
    de_chunk: &mut [S],
    mask_chunk: &mut [u8],
    lanes: L,
) -> (FilterStats, usize) {
    let p = ctx.p;
    let d = p.d;
    let v = p.v;
    let eps = FILTER_EPS as f32;
    let (nb, vb) = (ctx.nb, ctx.vb);
    let rows_total = de_chunk.len() / d;
    let mut probs = vec![0f32; nb * vb];
    // f32 staging for one row-block of dE: a row's entire vocab sweep
    // (indicator first, then every surviving tile in j order) accumulates
    // here and is narrowed into the stored output once per block.
    let mut acc = vec![0f32; nb * d];
    let mut comp = if ctx.opts.kahan {
        vec![0f32; nb * d]
    } else {
        Vec::new()
    };
    let mut stats = FilterStats::default();

    let mut block_start = 0;
    while block_start < rows_total {
        let rows = nb.min(rows_total - block_start);
        acc[..rows * d].fill(0.0);
        if ctx.opts.kahan {
            comp[..rows * d].fill(0.0);
        }

        // Indicator part: dE_i -= c_{x_i} / count.
        for r in 0..rows {
            let t = p.x[row0 + block_start + r];
            if t < 0 {
                continue;
            }
            let c_row = &p.c[t as usize * d..(t as usize + 1) * d];
            let acc_row = &mut acc[r * d..(r + 1) * d];
            if ctx.opts.kahan {
                S::lanes_axpy_kahan_acc(
                    lanes,
                    acc_row,
                    &mut comp[r * d..(r + 1) * d],
                    -ctx.inv_count,
                    c_row,
                );
            } else {
                S::lanes_axpy_acc(lanes, acc_row, -ctx.inv_count, c_row);
            }
        }

        // Softmax part, blockwise over the vocabulary.
        let mut j0 = 0;
        let mut vb_idx = 0;
        while j0 < v {
            let cols = vb.min(v - j0);
            // Rematerialize the block's logits as probabilities (SIMD dot,
            // widen-on-load for bf16 storage).
            let mut sig = 0u64;
            for r in 0..rows {
                let i = row0 + block_start + r;
                let p_row = &mut probs[r * cols..(r + 1) * cols];
                if ctx.global_x[i] < 0 {
                    p_row.fill(0.0);
                    continue;
                }
                let e_row = &p.e[i * d..(i + 1) * d];
                let row_lse = ctx.lse[i];
                for (jj, out) in p_row.iter_mut().enumerate() {
                    let j = ctx.perm[j0 + jj] as usize;
                    let z = S::lanes_dot(lanes, e_row, &p.c[j * d..(j + 1) * d]);
                    let prob = (z - row_lse).exp();
                    *out = prob;
                    sig += (prob >= eps) as u64;
                }
            }
            stats.blocks_total += 1;
            stats.sig_entries += sig;
            let sub_eps = sig == 0;
            mask_chunk[(block_start / nb) * ctx.n_vblocks + vb_idx] = sub_eps as u8;
            if ctx.opts.filter && sub_eps {
                stats.blocks_skipped += 1;
                if !ctx.opts.full_e {
                    j0 += cols;
                    vb_idx += 1;
                    continue;
                }
            }
            // dE accumulation: acc_row += Σ_jj p·c_perm[jj] / count.
            for r in 0..rows {
                let i = row0 + block_start + r;
                if ctx.global_x[i] < 0 {
                    continue;
                }
                for jj in 0..cols {
                    let g = probs[r * cols + jj] * ctx.inv_count;
                    let j = ctx.perm[j0 + jj] as usize;
                    let c_row = &p.c[j * d..(j + 1) * d];
                    let acc_row = &mut acc[r * d..(r + 1) * d];
                    if ctx.opts.kahan {
                        S::lanes_axpy_kahan_acc(
                            lanes,
                            acc_row,
                            &mut comp[r * d..(r + 1) * d],
                            g,
                            c_row,
                        );
                    } else {
                        S::lanes_axpy_acc(lanes, acc_row, g, c_row);
                    }
                }
            }
            j0 += cols;
            vb_idx += 1;
        }
        // Narrow the finished block into the stored output (copy for f32).
        for r in 0..rows {
            let out_row = block_start + r;
            S::narrow_into(&mut de_chunk[out_row * d..(out_row + 1) * d], &acc[r * d..(r + 1) * d]);
        }
        block_start += rows;
    }
    (stats, (probs.len() + acc.len() + comp.len()) * 4)
}

/// Phase B over permuted vocabulary columns `[col_lo, col_hi)` (any
/// contiguous range — spans need not align to `V_B` blocks): indicator +
/// softmax `dC`, accumulated in an f32 segment scratch
/// ([`GRAD_SEG_COLS`]`×D`) across all surviving row blocks, then narrowed
/// straight into `rows[q - col_lo]` — the task's own `&mut` slices of the
/// `dC` output.  Skipped blocks (per the phase-A mask) are never
/// rematerialized.  The block loop sits *outside* the column loop within
/// each segment, so a row-block's `E` tile stays cache-resident across the
/// segment's columns; each column still receives its contributions in
/// blocks-ascending, rows-ascending order, so `dC` is bitwise identical to
/// the column-outer nest and bitwise thread-count invariant.  Returns the
/// span's buffer bytes (segment scratch + Kahan comp + the sorted
/// indicator-visit list).
fn dc_phase<S: Store, L: Lanes>(
    ctx: &BwdCtx<S>,
    col_lo: usize,
    col_hi: usize,
    rows: &mut [&mut [S]],
    mask: &[u8],
    lanes: L,
) -> usize {
    let p = ctx.p;
    let (n, d) = (p.n, p.d);
    let (nb, vb) = (ctx.nb, ctx.vb);
    let seg_w = GRAD_SEG_COLS.min(col_hi - col_lo).max(1);
    let mut acc = vec![0f32; seg_w * d];
    let mut comp = if ctx.opts.kahan {
        vec![0f32; seg_w * d]
    } else {
        Vec::new()
    };
    // Indicator visits owned by this span, gathered in ONE O(N) scan and
    // sorted by (permuted column, token position): segments then drain a
    // cursor instead of rescanning all N targets per segment (which would
    // cost O(N·V/GRAD_SEG_COLS) — unskippable by the filter).  Sorting by
    // (q, i) keeps each column's contributions in ascending-i order — the
    // sequential accumulation order, so bitwise behavior is unchanged.
    let mut targets: Vec<(u32, u32)> = Vec::new();
    for i in 0..n {
        let t = p.x[i];
        if t < 0 {
            continue;
        }
        let q = ctx.inv_perm[t as usize] as usize;
        if q >= col_lo && q < col_hi {
            targets.push((q as u32, i as u32));
        }
    }
    targets.sort_unstable();
    let mut cursor = 0usize;

    let mut q0 = col_lo;
    while q0 < col_hi {
        // One segment: at most GRAD_SEG_COLS columns, never crossing a
        // V_B block boundary (the mask is per block).
        let vb_idx = q0 / vb;
        let q1 = (q0 + seg_w).min((vb_idx + 1) * vb).min(col_hi);
        let cols = q1 - q0;
        acc[..cols * d].fill(0.0);
        if ctx.opts.kahan {
            comp[..cols * d].fill(0.0);
        }

        // Indicator part: dC_{x_i} -= e_i / count for targets in this
        // segment, applied before any softmax contribution.  Segments
        // walk [col_lo, col_hi) in ascending q, so the presorted cursor
        // drains each segment's targets exactly once.
        while cursor < targets.len() && (targets[cursor].0 as usize) < q1 {
            let (q, i) = targets[cursor];
            cursor += 1;
            let (q, i) = (q as usize, i as usize);
            let e_row = &p.e[i * d..(i + 1) * d];
            let acc_col = &mut acc[(q - q0) * d..(q - q0 + 1) * d];
            if ctx.opts.kahan {
                S::lanes_axpy_kahan_acc(
                    lanes,
                    acc_col,
                    &mut comp[(q - q0) * d..(q - q0 + 1) * d],
                    -ctx.inv_count,
                    e_row,
                );
            } else {
                S::lanes_axpy_acc(lanes, acc_col, -ctx.inv_count, e_row);
            }
        }

        // Softmax part: stream surviving row blocks, block loop outside
        // the segment's column loop.
        let mut block_start = 0;
        while block_start < n {
            let brows = nb.min(n - block_start);
            let rb = block_start / nb;
            if ctx.opts.filter && !ctx.opts.full_c && mask[rb * ctx.n_vblocks + vb_idx] != 0 {
                block_start += brows;
                continue;
            }
            for q in q0..q1 {
                let j = ctx.perm[q] as usize;
                let c_row = &p.c[j * d..(j + 1) * d];
                for r in 0..brows {
                    let i = block_start + r;
                    if ctx.global_x[i] < 0 {
                        continue;
                    }
                    let e_row = &p.e[i * d..(i + 1) * d];
                    let z = S::lanes_dot(lanes, e_row, c_row);
                    let g = (z - ctx.lse[i]).exp() * ctx.inv_count;
                    let acc_col = &mut acc[(q - q0) * d..(q - q0 + 1) * d];
                    if ctx.opts.kahan {
                        S::lanes_axpy_kahan_acc(
                            lanes,
                            acc_col,
                            &mut comp[(q - q0) * d..(q - q0 + 1) * d],
                            g,
                            e_row,
                        );
                    } else {
                        S::lanes_axpy_acc(lanes, acc_col, g, e_row);
                    }
                }
            }
            block_start += brows;
        }

        // Narrow the finished segment into the owned output rows.
        for q in q0..q1 {
            S::narrow_into(&mut rows[q - col_lo], &acc[(q - q0) * d..(q - q0 + 1) * d]);
        }
        q0 = q1;
    }
    (acc.len() + comp.len()) * 4 + targets.len() * 8
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{baseline_forward_backward, cce_forward, random_problem};
    use crate::util::rng::Rng;

    fn opts(filter: bool, sort: bool) -> KernelOptions {
        KernelOptions {
            n_block: 8,
            v_block: 16,
            threads: 2,
            filter,
            sort,
            ..KernelOptions::default()
        }
    }

    fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
    }

    #[test]
    fn unfiltered_matches_baseline() {
        let mut rng = Rng::new(11);
        let (n, d, v) = (24, 12, 60);
        let (e, c, x) = random_problem(&mut rng, n, d, v, 0.2);
        let p = Problem::new(&e, &c, &x, n, d, v).unwrap();
        let (_, reference) = baseline_forward_backward(&p, &KernelOptions::default());
        for sort in [false, true] {
            let o = opts(false, sort);
            let fwd = cce_forward(&p, &o);
            let bwd = cce_backward(&p, &o, &fwd.lse);
            assert!(
                max_abs_diff(&bwd.d_e, &reference.d_e) < 1e-5,
                "d_e diverges (sort={sort})"
            );
            assert!(
                max_abs_diff(&bwd.d_c, &reference.d_c) < 1e-5,
                "d_c diverges (sort={sort})"
            );
            assert_eq!(bwd.stats.blocks_skipped, 0);
        }
    }

    #[test]
    fn kahan_backward_matches_plain_on_benign_inputs() {
        let mut rng = Rng::new(12);
        let (n, d, v) = (20, 10, 48);
        let (e, c, x) = random_problem(&mut rng, n, d, v, 0.2);
        let p = Problem::new(&e, &c, &x, n, d, v).unwrap();
        let o = opts(true, true);
        let ok = KernelOptions { kahan: true, ..o };
        let fwd = cce_forward(&p, &o);
        let plain = cce_backward(&p, &o, &fwd.lse);
        let kahan = cce_backward(&p, &ok, &fwd.lse);
        assert!(max_abs_diff(&plain.d_e, &kahan.d_e) < 1e-5);
        assert!(max_abs_diff(&plain.d_c, &kahan.d_c) < 1e-5);
        // Compensation buffers ride on the staging blocks and are
        // accounted in the workspace.
        assert!(kahan.workspace_bytes > plain.workspace_bytes);
    }

    #[test]
    fn full_variants_disable_filtering_per_output() {
        // Peaked softmax (target 0 dominant) => real skippable blocks.
        let mut rng = Rng::new(14);
        let (n, d, v) = (32, 4, 256);
        let mut c: Vec<f32> = (0..v * d).map(|_| (rng.normal() * 0.1) as f32).collect();
        c[0] = 10.0;
        let mut e = vec![0f32; n * d];
        let x = vec![0i32; n];
        for i in 0..n {
            e[i * d] = 1.5 + rng.f32() * 0.2;
        }
        let p = Problem::new(&e, &c, &x, n, d, v).unwrap();
        let base = KernelOptions { kahan: true, ..opts(true, true) };
        let fwd = cce_forward(&p, &base);
        let exact = cce_backward(&p, &KernelOptions { filter: false, ..base }, &fwd.lse);
        let full_c = cce_backward(&p, &KernelOptions { full_c: true, ..base }, &fwd.lse);
        let full_e = cce_backward(&p, &KernelOptions { full_e: true, ..base }, &fwd.lse);
        // full_c: dC is exact (unfiltered) even though blocks were skipped.
        assert!(full_c.stats.blocks_skipped > 0);
        assert!(max_abs_diff(&full_c.d_c, &exact.d_c) < 1e-6, "full_c dC must be unfiltered");
        // full_e: dE is exact (unfiltered).
        assert!(full_e.stats.blocks_skipped > 0);
        assert!(max_abs_diff(&full_e.d_e, &exact.d_e) < 1e-6, "full_e dE must be unfiltered");
    }

    #[test]
    fn frequency_permutation_orders_hot_tokens_first() {
        let x = vec![3, 3, 3, 1, 1, 7, -1, -1];
        let perm = frequency_permutation(&x, 8);
        assert_eq!(perm[0], 3);
        assert_eq!(perm[1], 1);
        assert_eq!(perm[2], 7);
        // Remaining ids in stable (ascending) order.
        assert_eq!(&perm[3..], &[0, 2, 4, 5, 6]);
        let mut sorted = perm.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..8).collect::<Vec<u32>>());
        // And the inverse really inverts.
        let inv = invert_permutation(&perm);
        for (q, &j) in perm.iter().enumerate() {
            assert_eq!(inv[j as usize] as usize, q);
        }
    }

    #[test]
    fn balance_spans_tracks_weight() {
        // Uniform weights: near-even contiguous split.
        let bounds = balance_spans(&[1; 8], 4);
        assert_eq!(bounds, vec![0, 2, 4, 6, 8]);
        // Head-heavy weights (the sorted-filter shape): the first span
        // stays small so one thread does not own the whole hot head.
        let bounds = balance_spans(&[12, 4, 0, 0, 0, 0, 0, 0], 4);
        assert_eq!(*bounds.first().unwrap(), 0);
        assert_eq!(*bounds.last().unwrap(), 8);
        assert!(bounds[1] <= 2, "hot head must close the first span early: {bounds:?}");
        for w in bounds.windows(2) {
            assert!(w[0] <= w[1]);
        }
        // More threads than blocks: spans stay in range, some empty.
        let bounds = balance_spans(&[5, 5], 8);
        assert_eq!(*bounds.last().unwrap(), 2);
        assert!(bounds.iter().all(|&b| b <= 2));
    }

    #[test]
    fn filtered_error_is_within_eps_bound() {
        // Deterministically peaked softmax: token 0 is a strong shared
        // direction, every label is 0, so every column block except the one
        // holding column 0 is provably sub-eps and must be skipped.
        let mut rng = Rng::new(13);
        let (n, d, v) = (32, 4, 256);
        let mut c: Vec<f32> = (0..v * d).map(|_| (rng.normal() * 0.1) as f32).collect();
        c[0] = 10.0; // c_0 ≈ 10·u_0
        let mut e = vec![0f32; n * d];
        let mut x = vec![0i32; n];
        for i in 0..n {
            e[i * d] = 1.5 + rng.f32() * 0.2; // z_{i,0} ≈ 15..17, others |z| ≲ 1
            if i % 8 == 7 {
                x[i] = -1; // a few ignored rows in the mix
            }
        }
        let p = Problem::new(&e, &c, &x, n, d, v).unwrap();
        let o = opts(true, true);
        let fwd = cce_forward(&p, &o);
        let filtered = cce_backward(&p, &o, &fwd.lse);
        let exact = cce_backward(&p, &opts(false, true), &fwd.lse);
        assert!(
            filtered.stats.blocks_skipped > 0,
            "peaked input skipped no blocks: {:?}",
            filtered.stats
        );
        // Per-element bound: each skipped entry contributes < eps/count
        // times a bounded factor; V·eps·max|input|/count is a loose cap.
        let count = fwd.count as f32;
        let max_in = e
            .iter()
            .chain(c.iter())
            .map(|z| z.abs())
            .fold(0.0f32, f32::max);
        let bound = (v as f32) * (FILTER_EPS as f32) * max_in / count;
        assert!(
            max_abs_diff(&filtered.d_e, &exact.d_e) <= bound,
            "d_e filter error above bound {bound}"
        );
        assert!(
            max_abs_diff(&filtered.d_c, &exact.d_c) <= bound,
            "d_c filter error above bound {bound}"
        );
    }

    #[test]
    fn sorting_skips_more_blocks_on_shuffled_zipf() {
        // Hot tokens with *shuffled ids*: each row's softmax concentrates
        // on its target (an id scattered anywhere in the vocabulary), so
        // unsorted filtering keeps every block that holds some row's
        // target, while frequency sorting pulls all hot ids into the
        // leading column block (the cce vs cce_no_sort ablation).
        let mut rng = Rng::new(17);
        let (n, d, v) = (64, 16, 512);
        let n_hot = 8;
        let mut ids: Vec<usize> = (0..v).collect();
        rng.shuffle(&mut ids);
        let hot: Vec<usize> = ids[..n_hot].to_vec();
        // Hot token r gets classifier row 6·u_r; cold rows are tiny noise.
        let mut c: Vec<f32> = (0..v * d).map(|_| (rng.normal() * 0.05) as f32).collect();
        for (r, &id) in hot.iter().enumerate() {
            c[id * d + r] = 6.0;
        }
        // Row i picks hot rank (Zipf-ish via modulo bias) and aligns with it.
        let mut e = vec![0f32; n * d];
        let mut x = vec![0i32; n];
        for i in 0..n {
            let r = (i % (n_hot + 4)).min(n_hot - 1); // ranks 0..8, head-heavy
            x[i] = hot[r] as i32;
            e[i * d + r] = 2.0; // z_target = 12, every other |z| ≲ 1
        }
        let p = Problem::new(&e, &c, &x, n, d, v).unwrap();
        let o = KernelOptions { n_block: 16, v_block: 32, threads: 2, ..KernelOptions::default() };
        let fwd = cce_forward(&p, &o);
        let sorted = cce_backward(&p, &o, &fwd.lse);
        let unsorted = cce_backward(&p, &KernelOptions { sort: false, ..o }, &fwd.lse);
        assert!(
            sorted.stats.blocks_skipped >= unsorted.stats.blocks_skipped,
            "sorting should not reduce skips: {:?} vs {:?}",
            sorted.stats,
            unsorted.stats
        );
        // Sorted: the significant set is exactly the n_hot hot tokens, all
        // in the first permuted block => at most one surviving vocab block
        // per row-block.
        let total = sorted.stats.blocks_total;
        assert!(
            sorted.stats.blocks_skipped * 2 > total,
            "sorted filtering should skip most blocks: {:?}",
            sorted.stats
        );
        // Both runs compute the same gradients despite different skip sets.
        assert!(max_abs_diff(&sorted.d_e, &unsorted.d_e) < 1e-3);
        assert!(max_abs_diff(&sorted.d_c, &unsorted.d_c) < 1e-3);
    }

    #[test]
    fn bf16_backward_tracks_f32_within_storage_rounding() {
        // The same problem narrowed to bf16 storage must give gradients
        // within the storage-rounding envelope of the f32 run: inputs are
        // rounded once (2^-9 relative) and outputs once more on store.
        use crate::exec::BF16;
        let mut rng = Rng::new(0xBF);
        let (n, d, v) = (32, 16, 96);
        let (e, c, x) = random_problem(&mut rng, n, d, v, 0.15);
        let o = opts(true, true);
        let p = Problem::new(&e, &c, &x, n, d, v).unwrap();
        let fwd = cce_forward(&p, &o);
        let f32_bwd = cce_backward(&p, &o, &fwd.lse);

        let eb: Vec<BF16> = e.iter().map(|&z| BF16::from_f32(z)).collect();
        let cb: Vec<BF16> = c.iter().map(|&z| BF16::from_f32(z)).collect();
        let pb = Problem::new(&eb, &cb, &x, n, d, v).unwrap();
        let fwd_b = cce_forward(&pb, &o);
        let bf_bwd = cce_backward(&pb, &o, &fwd_b.lse);
        assert!(
            (fwd.loss - fwd_b.loss).abs() < 0.01 * fwd.loss.abs().max(1.0),
            "bf16 loss {} vs f32 {}",
            fwd_b.loss,
            fwd.loss
        );
        let scale_e = f32_bwd.d_e.iter().fold(0.0f32, |m, &g| m.max(g.abs()));
        let scale_c = f32_bwd.d_c.iter().fold(0.0f32, |m, &g| m.max(g.abs()));
        let diff_e = f32_bwd
            .d_e
            .iter()
            .zip(&bf_bwd.d_e)
            .map(|(a, b)| (a - b.to_f32()).abs())
            .fold(0.0f32, f32::max);
        let diff_c = f32_bwd
            .d_c
            .iter()
            .zip(&bf_bwd.d_c)
            .map(|(a, b)| (a - b.to_f32()).abs())
            .fold(0.0f32, f32::max);
        assert!(diff_e <= 0.02 * scale_e + 1e-5, "d_e drift {diff_e} (scale {scale_e})");
        assert!(diff_c <= 0.02 * scale_c + 1e-5, "d_c drift {diff_c} (scale {scale_c})");
        // Output gradients really are half-width.
        assert_eq!(std::mem::size_of_val(&bf_bwd.d_e[0]), 2);
    }
}
