//! Logit-free inference kernels: per-token top-k, sampling, and scoring
//! with the same `(N_B, V_B)` tiling as the training kernels.
//!
//! The paper's blocked online-LSE trick is not training-only.  At inference
//! time the same single sweep over `C` that computes the log-sum-exp can
//! simultaneously maintain, per row:
//!
//! * a **bounded top-k heap** of `(logit, token)` pairs — argmax/top-k
//!   decoding without ever holding more than `k` candidates per row;
//! * an **online Gumbel-max sampler** — temperature sampling via
//!   `argmax_j (z_j/T + g_j)` where `g_j` is deterministic Gumbel noise
//!   hashed from `(seed, j)`, so no `N×V` noise tensor exists either;
//! * the running `(max, rescaled sum)` LSE pair, which converts the winning
//!   logit into a proper log-probability at the end of the sweep.
//!
//! All three paths keep the training kernels' workspace guarantee: peak
//! working memory is `O(N + threads·N_B·(V_B + k))` floats — the `N×V`
//! logit matrix is never materialized.  [`score`] is the third serving
//! path: per-token log-probabilities / perplexity of a forced continuation,
//! a thin wrapper over [`cce_forward`] (loss ≡ mean NLL).
#![allow(clippy::needless_range_loop)]

use anyhow::{bail, Result};

use super::lse::cce_forward;
use super::simd::{self, Lanes};
use super::{pool, span_rows, KernelOptions, Problem, Store};

/// One inference problem: hidden states `E (N×D)` against a classifier
/// `C (V×D)` — a [`Problem`] without labels.  The hidden states are
/// always f32 (they are computed per decode step from the context bag);
/// the classifier carries the checkpoint's storage dtype and is widened
/// on load inside the SIMD dot.
#[derive(Debug, Clone, Copy)]
pub struct InferProblem<'a, S: Store = f32> {
    pub e: &'a [f32],
    pub c: &'a [S],
    pub n: usize,
    pub d: usize,
    pub v: usize,
}

impl<'a, S: Store> InferProblem<'a, S> {
    pub fn new(e: &'a [f32], c: &'a [S], n: usize, d: usize, v: usize) -> Result<Self> {
        if n == 0 || d == 0 || v == 0 {
            bail!("empty inference problem: n={n} d={d} v={v}");
        }
        if e.len() != n * d {
            bail!("e has {} elements, want {n}x{d}", e.len());
        }
        if c.len() != v * d {
            bail!("c has {} elements, want {v}x{d}", c.len());
        }
        Ok(InferProblem { e, c, n, d, v })
    }
}

// ------------------------------------------------------------------- top-k

/// Top-k result for one row, sorted best-first.  `logprobs[r] =
/// z_{tokens[r]} − lse` are full-softmax log-probabilities.
#[derive(Debug, Clone, Default)]
pub struct TopKRow {
    pub tokens: Vec<i32>,
    pub logprobs: Vec<f32>,
    pub lse: f32,
}

/// [`topk`] output.
#[derive(Debug, Clone)]
pub struct TopKOut {
    pub rows: Vec<TopKRow>,
    /// Peak working memory allocated by the kernel (inputs excluded).
    pub workspace_bytes: usize,
}

/// Blocked top-k: one sweep over `C` per row span, folding each `(N_B,
/// V_B)` logit tile into a bounded min-heap of the `k` best candidates and
/// the online LSE.  Ties break toward the smaller token id, so the result
/// is deterministic across blockings and thread counts.
pub fn topk<S: Store>(p: &InferProblem<S>, opts: &KernelOptions, k: usize) -> Result<TopKOut> {
    if k == 0 || k > p.v {
        bail!("top-k k={k} out of range for vocab {}", p.v);
    }
    let sweep = crate::obs::Stopwatch::start();
    let out = simd::with_lanes!(lanes => topk_with(p, opts, k, lanes));
    if let Some(us) = sweep.elapsed_us() {
        super::record_infer_sweep(us);
    }
    Ok(out)
}

fn topk_with<S: Store, L: Lanes>(
    p: &InferProblem<S>,
    opts: &KernelOptions,
    k: usize,
    lanes: L,
) -> TopKOut {
    let n = p.n;
    let mut rows: Vec<TopKRow> = vec![TopKRow::default(); n];
    let span = span_rows(n, opts.n_block, opts.threads);
    let buffer_bytes: usize = {
        let tasks: Vec<_> = rows
            .chunks_mut(span)
            .enumerate()
            .map(|(ti, chunk)| {
                let row0 = ti * span;
                let opts = *opts;
                move || topk_span(p, &opts, k, row0, chunk, lanes)
            })
            .collect();
        pool::global().run(tasks).into_iter().sum()
    };
    // O(N) output rows (k entries each) + per-thread block buffers.
    let workspace_bytes = n * k * 8 + buffer_bytes;
    TopKOut { rows, workspace_bytes }
}

/// Per-kernel accumulation hooks over the shared [`tile_sweep`].  The
/// sweep owns the tile matmul and the online-LSE fold — the part that must
/// stay numerically identical across the inference kernels (and to
/// [`cce_forward`]'s recurrence) for the blocking-invariance guarantees
/// the tests pin.  Visitors only see finished logit tiles.
trait TileVisitor {
    /// A new row block of `rows` rows begins; reset per-row state.
    fn begin_block(&mut self, rows: usize);
    /// Block-local row `r` (global row `i`) produced logits `z_row` for
    /// columns `[j0, j0 + z_row.len())`.
    fn visit_tile_row(&mut self, r: usize, i: usize, j0: usize, z_row: &[f32]);
    /// Block-local row `r` (span-local row `span_row`) finished its sweep
    /// with log-sum-exp `lse`.
    fn end_row(&mut self, r: usize, span_row: usize, lse: f32);
}

/// One `(N_B, V_B)`-tiled sweep over the classifier for a contiguous span
/// of rows: compute each logit tile once, fold the online LSE, and hand
/// the tile to the visitor.  Returns the bytes of tile/LSE buffers this
/// span allocated (visitor state is accounted by the caller).
fn tile_sweep<S: Store, L: Lanes, V: TileVisitor>(
    p: &InferProblem<S>,
    opts: &KernelOptions,
    row0: usize,
    rows_total: usize,
    visitor: &mut V,
    lanes: L,
) -> usize {
    let d = p.d;
    let v = p.v;
    let n_block = opts.n_block.clamp(1, rows_total.max(1));
    let v_block = opts.v_block.clamp(1, v);
    let mut logits = vec![0f32; n_block * v_block];
    let mut run_max = vec![f32::NEG_INFINITY; n_block];
    let mut run_sum = vec![0f32; n_block];

    let mut block_start = 0;
    while block_start < rows_total {
        let rows = n_block.min(rows_total - block_start);
        run_max[..rows].fill(f32::NEG_INFINITY);
        run_sum[..rows].fill(0.0);
        visitor.begin_block(rows);

        let mut j0 = 0;
        while j0 < v {
            let cols = v_block.min(v - j0);
            for r in 0..rows {
                let i = row0 + block_start + r;
                let e_row = &p.e[i * d..(i + 1) * d];
                let z_row = &mut logits[r * cols..(r + 1) * cols];
                for (jj, z) in z_row.iter_mut().enumerate() {
                    *z = S::lanes_dot_mixed(lanes, e_row, &p.c[(j0 + jj) * d..(j0 + jj + 1) * d]);
                }
            }
            for r in 0..rows {
                let i = row0 + block_start + r;
                let z_row = &logits[r * cols..(r + 1) * cols];
                let tile_max = lanes.vmax(z_row);
                let m_old = run_max[r];
                let m_new = m_old.max(tile_max);
                let mut s = if m_old == f32::NEG_INFINITY {
                    0.0
                } else {
                    run_sum[r] * (m_old - m_new).exp()
                };
                for &z in z_row {
                    s += (z - m_new).exp();
                }
                run_max[r] = m_new;
                run_sum[r] = s;
                visitor.visit_tile_row(r, i, j0, z_row);
            }
            j0 += cols;
        }
        for r in 0..rows {
            visitor.end_row(r, block_start + r, run_max[r] + run_sum[r].ln());
        }
        block_start += rows;
    }
    (logits.len() + run_max.len() + run_sum.len()) * 4
}

struct TopKVisitor<'a> {
    heaps: Vec<BoundedTopK>,
    out: &'a mut [TopKRow],
}

impl TileVisitor for TopKVisitor<'_> {
    fn begin_block(&mut self, rows: usize) {
        for heap in self.heaps[..rows].iter_mut() {
            heap.clear();
        }
    }

    fn visit_tile_row(&mut self, r: usize, _i: usize, j0: usize, z_row: &[f32]) {
        for (jj, &z) in z_row.iter().enumerate() {
            self.heaps[r].push(z, (j0 + jj) as i32);
        }
    }

    fn end_row(&mut self, r: usize, span_row: usize, lse: f32) {
        let best = self.heaps[r].sorted_desc();
        let row = &mut self.out[span_row];
        row.lse = lse;
        row.tokens = best.iter().map(|&(_, t)| t).collect();
        row.logprobs = best.iter().map(|&(z, _)| z - lse).collect();
    }
}

fn topk_span<S: Store, L: Lanes>(
    p: &InferProblem<S>,
    opts: &KernelOptions,
    k: usize,
    row0: usize,
    out: &mut [TopKRow],
    lanes: L,
) -> usize {
    let rows_total = out.len();
    let n_block = opts.n_block.clamp(1, rows_total.max(1));
    let mut visitor = TopKVisitor {
        heaps: (0..n_block).map(|_| BoundedTopK::new(k)).collect(),
        out,
    };
    let sweep_bytes = tile_sweep(p, opts, row0, rows_total, &mut visitor, lanes);
    sweep_bytes + visitor.heaps.len() * k * 8
}

/// Bounded min-heap of the `k` best `(logit, token)` pairs: the root is the
/// worst kept candidate.  Ordering prefers higher logit, then smaller token
/// id — a total order, so results are blocking-invariant.
struct BoundedTopK {
    k: usize,
    heap: Vec<(f32, i32)>,
}

impl BoundedTopK {
    fn new(k: usize) -> BoundedTopK {
        BoundedTopK { k, heap: Vec::with_capacity(k) }
    }

    fn clear(&mut self) {
        self.heap.clear();
    }

    /// `a` strictly worse than `b`?
    fn worse(a: (f32, i32), b: (f32, i32)) -> bool {
        a.0 < b.0 || (a.0 == b.0 && a.1 > b.1)
    }

    fn push(&mut self, z: f32, token: i32) {
        let cand = (z, token);
        if self.heap.len() < self.k {
            self.heap.push(cand);
            self.sift_up(self.heap.len() - 1);
        } else if Self::worse(self.heap[0], cand) {
            self.heap[0] = cand;
            self.sift_down(0);
        }
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if Self::worse(self.heap[i], self.heap[parent]) {
                self.heap.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        loop {
            let (l, r) = (2 * i + 1, 2 * i + 2);
            let mut worst = i;
            if l < self.heap.len() && Self::worse(self.heap[l], self.heap[worst]) {
                worst = l;
            }
            if r < self.heap.len() && Self::worse(self.heap[r], self.heap[worst]) {
                worst = r;
            }
            if worst == i {
                return;
            }
            self.heap.swap(i, worst);
            i = worst;
        }
    }

    /// Kept candidates, best first.
    fn sorted_desc(&self) -> Vec<(f32, i32)> {
        let mut out = self.heap.clone();
        out.sort_by(|a, b| {
            b.0.partial_cmp(&a.0).unwrap_or(std::cmp::Ordering::Equal).then(a.1.cmp(&b.1))
        });
        out
    }
}

// ----------------------------------------------------------------- sampler

/// [`sample`] output: one token per row plus its full-softmax (T=1)
/// log-probability.
#[derive(Debug, Clone)]
pub struct SampleOut {
    pub tokens: Vec<i32>,
    pub logprobs: Vec<f32>,
    pub workspace_bytes: usize,
}

/// Online softmax sampling via the Gumbel-max trick, blocked: the sampled
/// token is `argmax_j (z_j/T + g_j)` with `g_j = −ln(−ln u_j)` and `u_j`
/// hashed deterministically from `(seeds[i], j)`, which is distributed as
/// `Categorical(softmax(z/T))` — no `N×V` logits, no `N×V` noise.
/// `temperature == 0` degenerates to exact argmax (greedy).
///
/// The same sweep folds the *untempered* online LSE so the returned
/// log-probability is the model's T=1 `log p(token)`, comparable across
/// temperatures and with [`topk`] / [`score`].
pub fn sample<S: Store>(
    p: &InferProblem<S>,
    opts: &KernelOptions,
    temperature: f32,
    seeds: &[u64],
) -> Result<SampleOut> {
    if seeds.len() != p.n {
        bail!("sample needs one seed per row: {} seeds for n={}", seeds.len(), p.n);
    }
    if !temperature.is_finite() || temperature < 0.0 {
        bail!("temperature must be finite and >= 0, got {temperature}");
    }
    let sweep = crate::obs::Stopwatch::start();
    let out = simd::with_lanes!(lanes => sample_with(p, opts, temperature, seeds, lanes));
    if let Some(us) = sweep.elapsed_us() {
        super::record_infer_sweep(us);
    }
    Ok(out)
}

fn sample_with<S: Store, L: Lanes>(
    p: &InferProblem<S>,
    opts: &KernelOptions,
    temperature: f32,
    seeds: &[u64],
    lanes: L,
) -> SampleOut {
    let n = p.n;
    let mut tokens = vec![0i32; n];
    let mut logprobs = vec![0f32; n];
    let span = span_rows(n, opts.n_block, opts.threads);
    let buffer_bytes: usize = {
        let tasks: Vec<_> = tokens
            .chunks_mut(span)
            .zip(logprobs.chunks_mut(span))
            .enumerate()
            .map(|(ti, (tok_chunk, lp_chunk))| {
                let row0 = ti * span;
                let opts = *opts;
                move || {
                    sample_span(p, &opts, temperature, seeds, row0, (tok_chunk, lp_chunk), lanes)
                }
            })
            .collect();
        pool::global().run(tasks).into_iter().sum()
    };
    let workspace_bytes = n * 8 + buffer_bytes;
    SampleOut { tokens, logprobs, workspace_bytes }
}

struct SampleVisitor<'a> {
    temperature: f32,
    seeds: &'a [u64],
    // Per-row perturbed-argmax state: (best score, best token, best raw z).
    best_score: Vec<f32>,
    best_token: Vec<i32>,
    best_logit: Vec<f32>,
    tok_out: &'a mut [i32],
    lp_out: &'a mut [f32],
}

impl TileVisitor for SampleVisitor<'_> {
    fn begin_block(&mut self, rows: usize) {
        self.best_score[..rows].fill(f32::NEG_INFINITY);
    }

    fn visit_tile_row(&mut self, r: usize, i: usize, j0: usize, z_row: &[f32]) {
        let seed = self.seeds[i];
        for (jj, &z) in z_row.iter().enumerate() {
            let j = j0 + jj;
            let score = if self.temperature == 0.0 {
                z
            } else {
                z / self.temperature + gumbel_noise(seed, j as u64)
            };
            // Strict > keeps the first (smallest j) on exact ties, making
            // greedy deterministic across blockings.
            if score > self.best_score[r] {
                self.best_score[r] = score;
                self.best_token[r] = j as i32;
                self.best_logit[r] = z;
            }
        }
    }

    fn end_row(&mut self, r: usize, span_row: usize, lse: f32) {
        self.tok_out[span_row] = self.best_token[r];
        self.lp_out[span_row] = self.best_logit[r] - lse;
    }
}

fn sample_span<S: Store, L: Lanes>(
    p: &InferProblem<S>,
    opts: &KernelOptions,
    temperature: f32,
    seeds: &[u64],
    row0: usize,
    (tok_out, lp_out): (&mut [i32], &mut [f32]),
    lanes: L,
) -> usize {
    let rows_total = tok_out.len();
    let n_block = opts.n_block.clamp(1, rows_total.max(1));
    let mut visitor = SampleVisitor {
        temperature,
        seeds,
        best_score: vec![f32::NEG_INFINITY; n_block],
        best_token: vec![0i32; n_block],
        best_logit: vec![0f32; n_block],
        tok_out,
        lp_out,
    };
    let sweep_bytes = tile_sweep(p, opts, row0, rows_total, &mut visitor, lanes);
    sweep_bytes
        + visitor.best_score.len() * 4
        + visitor.best_token.len() * 4
        + visitor.best_logit.len() * 4
}

// ----------------------------------------------------------- shard entries
//
// Vocabulary-sharded variants (`crate::shard`): each worker owns a
// contiguous slice `C[col0 .. col0+v)` of the global classifier and runs
// the same tile sweep over it.  Two things change at the boundary so the
// coordinator's merge is *exact* over the union:
//
// * top-k returns **raw logits** (not logprobs) and globally-offset token
//   ids — reconstructing `z = logprob + lse` at the coordinator would
//   reintroduce a rounding step that can flip cross-shard ties, so the
//   comparison key crosses the wire untouched;
// * sampling keys its Gumbel noise on the **global** column index, so the
//   per-(row, token) perturbed scores are bitwise identical to the
//   single-process sweep and the cross-shard argmax picks the same winner.

/// Per-row shard-local top-k candidates: raw logits (the cross-shard merge
/// key), globally-offset tokens, and this shard's partial LSE.
#[derive(Debug, Clone, Default)]
pub struct ShardTopKRow {
    /// Global token ids (`col0` already added), best-first.
    pub tokens: Vec<i32>,
    /// Raw logits `z`, best-first — *not* normalized by any LSE.
    pub logits: Vec<f32>,
    /// This shard's partial log-sum-exp over its own columns.
    pub lse: f32,
}

/// [`topk_shard`] output.
#[derive(Debug, Clone)]
pub struct ShardTopKOut {
    pub rows: Vec<ShardTopKRow>,
    pub workspace_bytes: usize,
}

/// Shard-local blocked top-k over classifier columns `[col0, col0 + p.v)`
/// of the global vocabulary.  Identical sweep and candidate order to
/// [`topk`]; only the emitted row format differs (see module note above).
pub fn topk_shard<S: Store>(
    p: &InferProblem<S>,
    opts: &KernelOptions,
    k: usize,
    col0: usize,
) -> Result<ShardTopKOut> {
    if k == 0 || k > p.v {
        bail!("top-k k={k} out of range for shard width {}", p.v);
    }
    let sweep = crate::obs::Stopwatch::start();
    let out = simd::with_lanes!(lanes => topk_shard_with(p, opts, k, col0, lanes));
    if let Some(us) = sweep.elapsed_us() {
        super::record_infer_sweep(us);
    }
    Ok(out)
}

fn topk_shard_with<S: Store, L: Lanes>(
    p: &InferProblem<S>,
    opts: &KernelOptions,
    k: usize,
    col0: usize,
    lanes: L,
) -> ShardTopKOut {
    let n = p.n;
    let mut rows: Vec<ShardTopKRow> = vec![ShardTopKRow::default(); n];
    let span = span_rows(n, opts.n_block, opts.threads);
    let buffer_bytes: usize = {
        let tasks: Vec<_> = rows
            .chunks_mut(span)
            .enumerate()
            .map(|(ti, chunk)| {
                let row0 = ti * span;
                let opts = *opts;
                move || {
                    let rows_total = chunk.len();
                    let n_block = opts.n_block.clamp(1, rows_total.max(1));
                    let mut visitor = ShardTopKVisitor {
                        col0,
                        heaps: (0..n_block).map(|_| BoundedTopK::new(k)).collect(),
                        out: chunk,
                    };
                    let sweep_bytes = tile_sweep(p, &opts, row0, rows_total, &mut visitor, lanes);
                    sweep_bytes + visitor.heaps.len() * k * 8
                }
            })
            .collect();
        pool::global().run(tasks).into_iter().sum()
    };
    let workspace_bytes = n * k * 8 + buffer_bytes;
    ShardTopKOut { rows, workspace_bytes }
}

struct ShardTopKVisitor<'a> {
    col0: usize,
    heaps: Vec<BoundedTopK>,
    out: &'a mut [ShardTopKRow],
}

impl TileVisitor for ShardTopKVisitor<'_> {
    fn begin_block(&mut self, rows: usize) {
        for heap in self.heaps[..rows].iter_mut() {
            heap.clear();
        }
    }

    fn visit_tile_row(&mut self, r: usize, _i: usize, j0: usize, z_row: &[f32]) {
        for (jj, &z) in z_row.iter().enumerate() {
            // Global ids preserve the within-shard order (col0 is
            // constant), so the heap's tie-break behaves exactly as the
            // single-process sweep over these columns.
            self.heaps[r].push(z, (self.col0 + j0 + jj) as i32);
        }
    }

    fn end_row(&mut self, r: usize, span_row: usize, lse: f32) {
        let best = self.heaps[r].sorted_desc();
        let row = &mut self.out[span_row];
        row.lse = lse;
        row.tokens = best.iter().map(|&(_, t)| t).collect();
        row.logits = best.iter().map(|&(z, _)| z).collect();
    }
}

/// [`sample_shard`] output: this shard's per-row Gumbel-max candidate.
#[derive(Debug, Clone)]
pub struct ShardSampleOut {
    /// Global token id of the shard-local winner.
    pub tokens: Vec<i32>,
    /// Perturbed score of the winner (`z` when `temperature == 0`) — the
    /// cross-shard comparison key, bitwise equal to the single-process
    /// sweep's score for the same `(row, token)`.
    pub scores: Vec<f32>,
    /// Raw logit of the winner (for the final `log p` against the merged
    /// LSE).
    pub logits: Vec<f32>,
    /// This shard's partial log-sum-exp per row.
    pub lse: Vec<f32>,
    pub workspace_bytes: usize,
}

/// Shard-local Gumbel-max sampling over classifier columns `[col0, col0 +
/// p.v)`: the noise is keyed on the **global** column index, so merging
/// the per-shard winners (ascending shard order, strict `>`) reproduces
/// the single-process [`sample`] token exactly.
pub fn sample_shard<S: Store>(
    p: &InferProblem<S>,
    opts: &KernelOptions,
    temperature: f32,
    seeds: &[u64],
    col0: usize,
) -> Result<ShardSampleOut> {
    if seeds.len() != p.n {
        bail!("sample needs one seed per row: {} seeds for n={}", seeds.len(), p.n);
    }
    if !temperature.is_finite() || temperature < 0.0 {
        bail!("temperature must be finite and >= 0, got {temperature}");
    }
    let sweep = crate::obs::Stopwatch::start();
    let out = simd::with_lanes!(lanes => sample_shard_with(p, opts, temperature, seeds, col0, lanes));
    if let Some(us) = sweep.elapsed_us() {
        super::record_infer_sweep(us);
    }
    Ok(out)
}

fn sample_shard_with<S: Store, L: Lanes>(
    p: &InferProblem<S>,
    opts: &KernelOptions,
    temperature: f32,
    seeds: &[u64],
    col0: usize,
    lanes: L,
) -> ShardSampleOut {
    let n = p.n;
    let mut tokens = vec![0i32; n];
    let mut scores = vec![0f32; n];
    let mut logits = vec![0f32; n];
    let mut lse = vec![0f32; n];
    let span = span_rows(n, opts.n_block, opts.threads);
    let buffer_bytes: usize = {
        let tasks: Vec<_> = tokens
            .chunks_mut(span)
            .zip(scores.chunks_mut(span))
            .zip(logits.chunks_mut(span).zip(lse.chunks_mut(span)))
            .enumerate()
            .map(|(ti, ((tok_chunk, sc_chunk), (lg_chunk, lse_chunk)))| {
                let row0 = ti * span;
                let opts = *opts;
                move || {
                    let rows_total = tok_chunk.len();
                    let n_block = opts.n_block.clamp(1, rows_total.max(1));
                    let mut visitor = ShardSampleVisitor {
                        temperature,
                        seeds,
                        col0,
                        best_score: vec![f32::NEG_INFINITY; n_block],
                        best_token: vec![0i32; n_block],
                        best_logit: vec![0f32; n_block],
                        tok_out: tok_chunk,
                        sc_out: sc_chunk,
                        lg_out: lg_chunk,
                        lse_out: lse_chunk,
                    };
                    let sweep_bytes = tile_sweep(p, &opts, row0, rows_total, &mut visitor, lanes);
                    sweep_bytes + visitor.best_score.len() * 12
                }
            })
            .collect();
        pool::global().run(tasks).into_iter().sum()
    };
    let workspace_bytes = n * 16 + buffer_bytes;
    ShardSampleOut { tokens, scores, logits, lse, workspace_bytes }
}

struct ShardSampleVisitor<'a> {
    temperature: f32,
    seeds: &'a [u64],
    col0: usize,
    best_score: Vec<f32>,
    best_token: Vec<i32>,
    best_logit: Vec<f32>,
    tok_out: &'a mut [i32],
    sc_out: &'a mut [f32],
    lg_out: &'a mut [f32],
    lse_out: &'a mut [f32],
}

impl TileVisitor for ShardSampleVisitor<'_> {
    fn begin_block(&mut self, rows: usize) {
        self.best_score[..rows].fill(f32::NEG_INFINITY);
    }

    fn visit_tile_row(&mut self, r: usize, i: usize, j0: usize, z_row: &[f32]) {
        let seed = self.seeds[i];
        for (jj, &z) in z_row.iter().enumerate() {
            let j = self.col0 + j0 + jj;
            let score = if self.temperature == 0.0 {
                z
            } else {
                z / self.temperature + gumbel_noise(seed, j as u64)
            };
            // Strict > keeps the first (smallest global j) on exact ties —
            // the same rule the coordinator applies across shards.
            if score > self.best_score[r] {
                self.best_score[r] = score;
                self.best_token[r] = j as i32;
                self.best_logit[r] = z;
            }
        }
    }

    fn end_row(&mut self, r: usize, span_row: usize, lse: f32) {
        self.tok_out[span_row] = self.best_token[r];
        self.sc_out[span_row] = self.best_score[r];
        self.lg_out[span_row] = self.best_logit[r];
        self.lse_out[span_row] = lse;
    }
}

/// The total order [`topk`] keeps its candidates in: higher logit first,
/// then smaller token id.  Public so the shard coordinator merges
/// per-shard candidate lists under *exactly* the kernel's order.
pub fn topk_candidate_order(a: (f32, i32), b: (f32, i32)) -> std::cmp::Ordering {
    b.0.partial_cmp(&a.0).unwrap_or(std::cmp::Ordering::Equal).then(a.1.cmp(&b.1))
}

/// splitmix64 finalizer.
fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Deterministic standard Gumbel noise for `(seed, j)`: hash to a uniform
/// in (0, 1), then `g = −ln(−ln u)`.
fn gumbel_noise(seed: u64, j: u64) -> f32 {
    let h = mix64(seed ^ j.wrapping_add(1).wrapping_mul(0x9E3779B97F4A7C15));
    // 53-bit mantissa, offset by 0.5 so u is never exactly 0 or 1.
    let u = ((h >> 11) as f64 + 0.5) * (1.0 / 9_007_199_254_740_992.0);
    (-(-u.ln()).ln()) as f32
}

// ------------------------------------------------------------------- score

/// [`score`] output: per-token log-probabilities of the forced labels.
#[derive(Debug, Clone)]
pub struct ScoreOut {
    /// `log p(x_i)` per row; `0.0` where the label is ignored (`-1`).
    pub logprobs: Vec<f32>,
    /// Mean NLL over non-ignored tokens (== [`cce_forward`] loss).
    pub nll: f64,
    /// `exp(nll)`.
    pub perplexity: f64,
    pub count: usize,
    pub workspace_bytes: usize,
}

/// Teacher-forced scoring: per-token `log p(x_i) = z_{x_i} − lse_i` from
/// one blocked forward sweep.  The mean NLL is definitionally the CCE loss,
/// which the tests pin against [`cce_forward`].
pub fn score<S: Store>(p: &Problem<S>, opts: &KernelOptions) -> ScoreOut {
    let fwd = cce_forward(p, opts);
    let logprobs: Vec<f32> = (0..p.n)
        .map(|i| {
            if p.x[i] >= 0 {
                fwd.target_logit[i] - fwd.lse[i]
            } else {
                0.0
            }
        })
        .collect();
    ScoreOut {
        logprobs,
        nll: fwd.loss,
        perplexity: fwd.loss.exp(),
        count: fwd.count,
        // The O(N) logprob vector rides on the forward's workspace.
        workspace_bytes: fwd.workspace_bytes + p.n * 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{random_problem, KernelOptions};
    use crate::util::rng::Rng;

    fn opts(n_block: usize, v_block: usize, threads: usize) -> KernelOptions {
        KernelOptions { n_block, v_block, threads, ..KernelOptions::default() }
    }

    /// Materialized reference: full logits, argsort descending.
    fn reference_topk(e: &[f32], c: &[f32], n: usize, d: usize, v: usize, k: usize)
        -> Vec<Vec<(f32, i32)>> {
        (0..n)
            .map(|i| {
                let mut z: Vec<(f32, i32)> = (0..v)
                    .map(|j| {
                        (simd::dot(&e[i * d..(i + 1) * d], &c[j * d..(j + 1) * d]), j as i32)
                    })
                    .collect();
                z.sort_by(|a, b| {
                    b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1))
                });
                z.truncate(k);
                z
            })
            .collect()
    }

    #[test]
    fn topk_matches_materialized_argsort() {
        let mut rng = Rng::new(31);
        let (n, d, v) = (20, 8, 70);
        let (e, c, _) = random_problem(&mut rng, n, d, v, 0.0);
        let p = InferProblem::new(&e, &c, n, d, v).unwrap();
        for (k, nb, vb, th) in [(1, 4, 16, 1), (5, 8, 7, 2), (70, 32, 128, 3)] {
            let out = topk(&p, &opts(nb, vb, th), k).unwrap();
            let reference = reference_topk(&e, &c, n, d, v, k);
            for i in 0..n {
                let row = &out.rows[i];
                assert_eq!(row.tokens.len(), k);
                for (r, &(z, t)) in reference[i].iter().enumerate() {
                    assert_eq!(row.tokens[r], t, "row {i} rank {r} (k={k})");
                    let lp = row.logprobs[r];
                    assert!(
                        (lp - (z - row.lse)).abs() < 1e-4,
                        "row {i} rank {r}: lp {lp} vs {}",
                        z - row.lse
                    );
                }
            }
        }
    }

    #[test]
    fn topk_rejects_bad_k() {
        let mut rng = Rng::new(32);
        let (n, d, v) = (4, 4, 16);
        let (e, c, _) = random_problem(&mut rng, n, d, v, 0.0);
        let p = InferProblem::new(&e, &c, n, d, v).unwrap();
        assert!(topk(&p, &KernelOptions::default(), 0).is_err());
        assert!(topk(&p, &KernelOptions::default(), 17).is_err());
        assert!(topk(&p, &KernelOptions::default(), 16).is_ok());
    }

    #[test]
    fn greedy_sample_is_argmax_across_blockings() {
        let mut rng = Rng::new(33);
        let (n, d, v) = (24, 6, 90);
        let (e, c, _) = random_problem(&mut rng, n, d, v, 0.0);
        let p = InferProblem::new(&e, &c, n, d, v).unwrap();
        let seeds = vec![7u64; n];
        let reference = reference_topk(&e, &c, n, d, v, 1);
        for (nb, vb, th) in [(4, 8, 1), (16, 33, 2), (32, 128, 4)] {
            let out = sample(&p, &opts(nb, vb, th), 0.0, &seeds).unwrap();
            for i in 0..n {
                assert_eq!(out.tokens[i], reference[i][0].1, "nb={nb} vb={vb}");
            }
        }
    }

    #[test]
    fn sampled_logprob_is_full_softmax_logprob() {
        let mut rng = Rng::new(34);
        let (n, d, v) = (10, 5, 40);
        let (e, c, _) = random_problem(&mut rng, n, d, v, 0.0);
        let p = InferProblem::new(&e, &c, n, d, v).unwrap();
        let seeds: Vec<u64> = (0..n as u64).collect();
        let out = sample(&p, &opts(8, 16, 2), 0.8, &seeds).unwrap();
        for i in 0..n {
            let t = out.tokens[i] as usize;
            // Materialized log softmax of the chosen token.
            let z: Vec<f32> = (0..v)
                .map(|j| simd::dot(&e[i * d..(i + 1) * d], &c[j * d..(j + 1) * d]))
                .collect();
            let m = z.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            let lse = m + z.iter().map(|&x| (x - m).exp()).sum::<f32>().ln();
            assert!(
                (out.logprobs[i] - (z[t] - lse)).abs() < 1e-4,
                "row {i}: {} vs {}",
                out.logprobs[i],
                z[t] - lse
            );
        }
    }

    #[test]
    fn sample_is_deterministic_in_seed_and_blocking() {
        let mut rng = Rng::new(35);
        let (n, d, v) = (12, 4, 64);
        let (e, c, _) = random_problem(&mut rng, n, d, v, 0.0);
        let p = InferProblem::new(&e, &c, n, d, v).unwrap();
        let seeds: Vec<u64> = (100..100 + n as u64).collect();
        let a = sample(&p, &opts(4, 16, 1), 1.0, &seeds).unwrap();
        let b = sample(&p, &opts(32, 5, 3), 1.0, &seeds).unwrap();
        assert_eq!(a.tokens, b.tokens);
        let other = sample(&p, &opts(4, 16, 1), 1.0, &vec![999u64; n]).unwrap();
        assert_ne!(a.tokens, other.tokens, "different seeds should move some row");
    }

    #[test]
    fn sample_validates_inputs() {
        let mut rng = Rng::new(36);
        let (n, d, v) = (4, 4, 8);
        let (e, c, _) = random_problem(&mut rng, n, d, v, 0.0);
        let p = InferProblem::new(&e, &c, n, d, v).unwrap();
        assert!(sample(&p, &KernelOptions::default(), 1.0, &[1, 2]).is_err());
        assert!(sample(&p, &KernelOptions::default(), -1.0, &vec![0; n]).is_err());
        assert!(sample(&p, &KernelOptions::default(), f32::NAN, &vec![0; n]).is_err());
    }

    #[test]
    fn score_matches_forward_loss() {
        let mut rng = Rng::new(37);
        let (n, d, v) = (30, 8, 50);
        let (e, c, x) = random_problem(&mut rng, n, d, v, 0.3);
        let p = Problem::new(&e, &c, &x, n, d, v).unwrap();
        let o = opts(8, 16, 2);
        let out = score(&p, &o);
        let fwd = cce_forward(&p, &o);
        assert_eq!(out.count, fwd.count);
        assert!((out.nll - fwd.loss).abs() < 1e-12);
        assert!((out.perplexity - fwd.loss.exp()).abs() < 1e-9);
        // Mean of per-token logprobs == -nll.
        let mean_lp: f64 = x
            .iter()
            .enumerate()
            .filter(|(_, &t)| t >= 0)
            .map(|(i, _)| out.logprobs[i] as f64)
            .sum::<f64>()
            / out.count as f64;
        assert!((mean_lp + out.nll).abs() < 1e-4, "{mean_lp} vs {}", -out.nll);
        for (i, &t) in x.iter().enumerate() {
            if t < 0 {
                assert_eq!(out.logprobs[i], 0.0);
            } else {
                assert!(out.logprobs[i] <= 0.0 + 1e-6);
            }
        }
    }

    #[test]
    fn workspace_is_blocked_not_nv() {
        let mut rng = Rng::new(38);
        let (n, d, v) = (128, 8, 4096);
        let (e, c, _) = random_problem(&mut rng, n, d, v, 0.0);
        let p = InferProblem::new(&e, &c, n, d, v).unwrap();
        let o = opts(32, 128, 2);
        let k = 8;
        let span = crate::exec::span_rows(n, o.n_block, o.threads);
        let workers = crate::exec::ceil_div(n, span);

        let out = topk(&p, &o, k).unwrap();
        let expected = n * k * 8
            + workers * ((o.n_block * o.v_block + 2 * o.n_block) * 4 + o.n_block * k * 8);
        assert_eq!(out.workspace_bytes, expected);
        assert!(out.workspace_bytes < n * v * 4 / 4, "{}", out.workspace_bytes);

        let s = sample(&p, &o, 1.0, &vec![1u64; n]).unwrap();
        let expected_s =
            n * 8 + workers * (o.n_block * o.v_block + 2 * o.n_block + 3 * o.n_block) * 4;
        assert_eq!(s.workspace_bytes, expected_s);
        assert!(s.workspace_bytes < n * v * 4 / 4, "{}", s.workspace_bytes);
    }

    #[test]
    fn bounded_heap_keeps_k_best() {
        let mut h = BoundedTopK::new(3);
        for (z, t) in [(1.0, 0), (5.0, 1), (2.0, 2), (5.0, 3), (0.5, 4), (4.0, 5)] {
            h.push(z, t);
        }
        let best = h.sorted_desc();
        assert_eq!(best.len(), 3);
        // 5.0@1 beats 5.0@3 on the token tie-break.
        assert_eq!(best[0], (5.0, 1));
        assert_eq!(best[1], (5.0, 3));
        assert_eq!(best[2], (4.0, 5));
    }
}
