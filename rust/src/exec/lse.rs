//! CCE forward: blocked indexed-matmul fused with an online log-sum-exp.
//!
//! For each row-block of `N_B` tokens the kernel walks the vocabulary in
//! `V_B`-column tiles, computing the tile's logits into a single reusable
//! `(N_B, V_B)` buffer and folding them into a running `(max, rescaled sum)`
//! pair per row — the standard online-LSE recurrence
//!
//! ```text
//! m' = max(m, max_j z_j)        s' = s·exp(m − m') + Σ_j exp(z_j − m')
//! ```
//!
//! The target logit `e_i · c_{x_i}` is captured in the same sweep when the
//! tile containing column `x_i` passes by, so the whole forward is one scan
//! over `C` with `O(N + threads·N_B·V_B)` working floats — the `N×V` logit
//! matrix never exists (the paper's §4.2 kernel, adapted from flash-memory
//! tiles to cache blocks).
//!
//! The tile matmul and the max reduction run on the SIMD layer
//! (`super::simd`) through a [`Lanes`] token resolved once at kernel entry;
//! the exp-accumulate stays sequential per row so the recurrence is
//! identical across blockings and thread counts.  Row spans execute on the
//! persistent fork-join pool (`super::pool`) — single-span calls (small-N
//! decode steps) run inline on the caller.  With [`KernelOptions::kahan`]
//! the running sum `s` (and the final loss reduction) carry Kahan
//! compensation terms — the `cce_kahan` long-tail rows of Table 1, for
//! softmaxes whose mass hides below f32 round-off of the head.

use super::simd::{self, Lanes};
use super::{pool, span_rows, ForwardOut, KernelOptions, Problem, Store};

/// Run the forward pass.  Multi-threaded over contiguous row spans.
/// Generic over the storage dtype: with `S = BF16` the tile matmul widens
/// `E`/`C` on load inside the SIMD dot; the logit tile, the LSE
/// recurrence, and the loss reduction are f32/f64 as always.
pub fn cce_forward<S: Store>(p: &Problem<S>, opts: &KernelOptions) -> ForwardOut {
    let sweep = crate::obs::Stopwatch::start();
    let out = simd::with_lanes!(lanes => forward_with(p, opts, lanes));
    if let Some(us) = sweep.elapsed_us() {
        super::record_fwd_sweep(us, out.workspace_bytes);
    }
    out
}

fn forward_with<S: Store, L: Lanes>(p: &Problem<S>, opts: &KernelOptions, lanes: L) -> ForwardOut {
    let n = p.n;
    let mut lse = vec![0f32; n];
    let mut tgt = vec![0f32; n];
    let span = span_rows(n, opts.n_block, opts.threads);
    let buffer_bytes: usize = {
        let tasks: Vec<_> = lse
            .chunks_mut(span)
            .zip(tgt.chunks_mut(span))
            .enumerate()
            .map(|(ti, (lse_chunk, tgt_chunk))| {
                let row0 = ti * span;
                let opts = *opts;
                move || forward_span(p, &opts, row0, lse_chunk, tgt_chunk, lanes)
            })
            .collect();
        pool::global().run(tasks).into_iter().sum()
    };
    let count = p.active_count();
    let terms = p
        .x
        .iter()
        .enumerate()
        .filter(|(_, &t)| t >= 0)
        .map(|(i, _)| (lse[i] - tgt[i]) as f64);
    let loss_sum: f64 = if opts.kahan { kahan_sum(terms) } else { terms.sum() };
    let loss = if count == 0 { 0.0 } else { loss_sum / count as f64 };
    let workspace_bytes = n * 8 + buffer_bytes;
    ForwardOut { loss, count, lse, target_logit: tgt, workspace_bytes }
}

/// Kahan-compensated sum (used for the loss reduction when
/// [`KernelOptions::kahan`] is set).
fn kahan_sum(terms: impl Iterator<Item = f64>) -> f64 {
    let (mut sum, mut comp) = (0.0f64, 0.0f64);
    for term in terms {
        let t = term - comp;
        let s = sum + t;
        comp = (s - sum) - t;
        sum = s;
    }
    sum
}

/// Process rows `[row0, row0 + lse_out.len())`; returns the bytes of block
/// buffers this worker allocated (for the O(N_B·V_B) memory assertion).
fn forward_span<S: Store, L: Lanes>(
    p: &Problem<S>,
    opts: &KernelOptions,
    row0: usize,
    lse_out: &mut [f32],
    tgt_out: &mut [f32],
    lanes: L,
) -> usize {
    let d = p.d;
    let v = p.v;
    let rows_total = lse_out.len();
    let n_block = opts.n_block.clamp(1, rows_total.max(1));
    let v_block = opts.v_block.clamp(1, v);
    let mut logits = vec![0f32; n_block * v_block];
    let mut run_max = vec![f32::NEG_INFINITY; n_block];
    let mut run_sum = vec![0f32; n_block];
    // Per-row compensation of `run_sum` (Kahan variants only).
    let mut run_comp = if opts.kahan {
        vec![0f32; n_block]
    } else {
        Vec::new()
    };

    let mut block_start = 0;
    while block_start < rows_total {
        let rows = n_block.min(rows_total - block_start);
        run_max[..rows].fill(f32::NEG_INFINITY);
        run_sum[..rows].fill(0.0);
        if opts.kahan {
            run_comp[..rows].fill(0.0);
        }

        let mut j0 = 0;
        while j0 < v {
            let cols = v_block.min(v - j0);
            // Tile logits: one (rows, cols) blocked matmul (SIMD dot).
            for r in 0..rows {
                let i = row0 + block_start + r;
                let e_row = &p.e[i * d..(i + 1) * d];
                let z_row = &mut logits[r * cols..(r + 1) * cols];
                for (jj, z) in z_row.iter_mut().enumerate() {
                    *z = S::lanes_dot(lanes, e_row, &p.c[(j0 + jj) * d..(j0 + jj + 1) * d]);
                }
            }
            // Online LSE fold + target-logit capture.
            for r in 0..rows {
                let i = row0 + block_start + r;
                let z_row = &logits[r * cols..(r + 1) * cols];
                let tile_max = lanes.vmax(z_row);
                let m_old = run_max[r];
                let m_new = m_old.max(tile_max);
                let rescale = if m_old == f32::NEG_INFINITY {
                    0.0
                } else {
                    (m_old - m_new).exp()
                };
                if opts.kahan {
                    // Rescale the compensated pair, then Kahan-add each
                    // exp term so sub-eps tails are not truncated.
                    let mut s = run_sum[r] * rescale;
                    let mut comp = run_comp[r] * rescale;
                    for &z in z_row {
                        let t = (z - m_new).exp() - comp;
                        let s_new = s + t;
                        comp = (s_new - s) - t;
                        s = s_new;
                    }
                    run_sum[r] = s;
                    run_comp[r] = comp;
                } else {
                    let mut s = run_sum[r] * rescale;
                    for &z in z_row {
                        s += (z - m_new).exp();
                    }
                    run_sum[r] = s;
                }
                run_max[r] = m_new;
                let t = p.x[i];
                if t >= 0 {
                    let t = t as usize;
                    if t >= j0 && t < j0 + cols {
                        tgt_out[block_start + r] = z_row[t - j0];
                    }
                }
            }
            j0 += cols;
        }
        for r in 0..rows {
            lse_out[block_start + r] = run_max[r] + run_sum[r].ln();
        }
        block_start += rows;
    }
    (logits.len() + run_max.len() + run_sum.len() + run_comp.len()) * 4
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{baseline_forward, random_problem};
    use crate::util::rng::Rng;

    fn opts(n_block: usize, v_block: usize, threads: usize) -> KernelOptions {
        KernelOptions { n_block, v_block, threads, ..KernelOptions::default() }
    }

    #[test]
    fn matches_baseline_across_blockings() {
        let mut rng = Rng::new(7);
        let (n, d, v) = (48, 16, 100);
        let (e, c, x) = random_problem(&mut rng, n, d, v, 0.2);
        let p = Problem::new(&e, &c, &x, n, d, v).unwrap();
        let reference = baseline_forward(&p, &KernelOptions::default());
        for (nb, vb, th) in [(8, 32, 1), (16, 7, 2), (64, 128, 3), (1, 1, 4)] {
            let out = cce_forward(&p, &opts(nb, vb, th));
            assert!(
                (out.loss - reference.loss).abs() < 1e-5,
                "nb={nb} vb={vb} th={th}: {} vs {}",
                out.loss,
                reference.loss
            );
            assert_eq!(out.count, reference.count);
            for i in 0..n {
                assert!(
                    (out.lse[i] - reference.lse[i]).abs() < 1e-4,
                    "lse[{i}]: {} vs {}",
                    out.lse[i],
                    reference.lse[i]
                );
            }
        }
    }

    #[test]
    fn workspace_is_blocked_not_nv() {
        let mut rng = Rng::new(8);
        let (n, d, v) = (256, 8, 4096);
        let (e, c, x) = random_problem(&mut rng, n, d, v, 0.0);
        let p = Problem::new(&e, &c, &x, n, d, v).unwrap();
        let o = opts(64, 128, 2);
        let out = cce_forward(&p, &o);
        // O(N) vectors + per-thread (N_B·V_B + 2·N_B) floats.
        let span = crate::exec::span_rows(n, o.n_block, o.threads);
        let workers = crate::exec::ceil_div(n, span);
        let expected = n * 8 + workers * (o.n_block * o.v_block + 2 * o.n_block) * 4;
        assert_eq!(out.workspace_bytes, expected);
        assert!(out.workspace_bytes < n * v * 4 / 4, "{}", out.workspace_bytes);
    }

    #[test]
    fn kahan_forward_matches_plain_on_benign_inputs() {
        // On well-conditioned softmaxes the compensated recurrence is the
        // same sum, just with the round-off carried — losses must agree to
        // round-off (the long-tail divergence test lives in tests/native.rs).
        let mut rng = Rng::new(21);
        let (n, d, v) = (40, 12, 200);
        let (e, c, x) = random_problem(&mut rng, n, d, v, 0.1);
        let p = Problem::new(&e, &c, &x, n, d, v).unwrap();
        let plain = cce_forward(&p, &opts(16, 33, 2));
        let kahan = cce_forward(&p, &KernelOptions { kahan: true, ..opts(16, 33, 2) });
        assert_eq!(plain.count, kahan.count);
        assert!((plain.loss - kahan.loss).abs() < 1e-5, "{} vs {}", plain.loss, kahan.loss);
        for i in 0..n {
            assert!((plain.lse[i] - kahan.lse[i]).abs() < 1e-4);
        }
        // The compensation vector is accounted in the workspace.
        assert!(kahan.workspace_bytes > plain.workspace_bytes);
    }

    #[test]
    fn all_ignored_rows_give_zero_loss() {
        let mut rng = Rng::new(9);
        let (n, d, v) = (8, 4, 16);
        let (e, c, _) = random_problem(&mut rng, n, d, v, 0.0);
        let x = vec![-1i32; n];
        let p = Problem::new(&e, &c, &x, n, d, v).unwrap();
        let out = cce_forward(&p, &KernelOptions::default());
        assert_eq!(out.count, 0);
        assert_eq!(out.loss, 0.0);
    }
}
