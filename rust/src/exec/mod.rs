//! Native compute backend: the paper's kernel suite as cache-blocked,
//! multi-threaded CPU kernels — no XLA, no artifacts, no external
//! crates.  Storage is dtype-generic ([`dtype::Store`]: f32 or software
//! bf16 with widen-on-load / narrow-on-store); accumulation is always
//! f32/f64.
//!
//! This is the "owns the hot path" counterpart to the AOT/PJRT [`crate::runtime`]:
//!
//! * [`lse`]      — CCE forward: per-token indexed dot `x_i · W[y_i]` fused
//!   with a blockwise **online log-sum-exp** over `V_B`-column tiles
//!   (running max + rescaled accumulator).  The `N×V` logit matrix is never
//!   materialized; peak working memory is `O(N + threads·N_B·V_B)` floats.
//! * [`backward`] — CCE backward: rematerializes one `(N_B, V_B)` logit
//!   block at a time, applies the §4.3 **gradient filter** (skip blocks in
//!   which every softmax entry is below `2^-12`) with optional
//!   **vocabulary sorting** by token frequency, and accumulates `dE`
//!   (row-parallel) and `dC` (**column-parallel**: threads own disjoint
//!   permuted column spans of the `dC` output itself — block-local f32
//!   staging, narrow-on-store, no gradient-sized side buffers at all).
//!   The indicator term of the target column is applied separately per
//!   token, so filtering never drops the `−1[j=y_i]` contribution.
//! * [`infer`]    — the logit-free *inference* kernels built on the same
//!   tiling: blocked top-k (bounded per-row heap + online LSE), online
//!   Gumbel-max temperature sampling, and teacher-forced scoring — the
//!   compute layer of [`crate::serve`].
//! * `simd`       — the 8-lane vector layer under all of the above:
//!   runtime-dispatched AVX2+FMA intrinsics with a portable autovectorized
//!   fallback behind one trait (dot / axpy / Kahan-axpy / max, each with a
//!   bf16 widen-on-load variant).
//! * [`dtype`]    — the storage dtypes: software [`BF16`] and the sealed
//!   [`Store`] trait the kernels are generic over.
//! * [`backend`]  — the [`Backend`] trait over loss implementations, with
//!   [`NativeBackend`] (this module) and, behind the `pjrt` feature, a
//!   `PjrtBackend` adapter over the artifact runtime.
//! * this module — the materialized-logits [`baseline_forward`] /
//!   [`baseline_forward_backward`] reference (the Table-1 "Baseline" row)
//!   and the shared [`Problem`] / [`KernelOptions`] / output types.
//!   The "Torch Tune (k chunks)" row is the blocked kernel run with
//!   `N_B = ⌈N/k⌉`, `V_B = V`, and no filtering.
//!
//! Parallelism is the persistent fork-join pool in [`pool`]: contiguous row
//! spans (each a whole number of `N_B` row-blocks), selected by `--threads`
//! (`0` = auto = available parallelism), executed by condvar-parked workers
//! that live for the process — no per-call thread spawn/join, and an inline
//! fast path for single-span (small-N decode) calls.  SIMD dispatch is
//! resolved to a [`simd::Lanes`] token once per kernel entry and the hot
//! loops monomorphize over it.  Kernel loops index by position on purpose —
//! the blocked layouts don't map onto iterator chains cleanly.
#![allow(clippy::needless_range_loop)]

pub mod backend;
pub mod backward;
pub mod dtype;
pub mod infer;
pub mod lse;
pub mod pool;
pub(crate) mod simd;

#[cfg(feature = "pjrt")]
pub use backend::PjrtBackend;
pub use backend::{Backend, NativeBackend, NativeMethod};
pub use backward::{cce_backward, cce_backward_sharded, frequency_permutation};
pub use dtype::{ParamBuf, Store, StoreDtype, BF16};
pub use infer::{
    sample, sample_shard, score, topk, topk_candidate_order, topk_shard, InferProblem, SampleOut,
    ScoreOut, ShardSampleOut, ShardTopKOut, ShardTopKRow, TopKOut, TopKRow,
};
pub use lse::cce_forward;
pub use pool::ThreadPool;

use std::sync::{Arc, OnceLock};

use anyhow::{bail, Result};

use crate::obs;
use crate::runtime::HostTensor;
use crate::sparsity::BlockFilterModel;

/// One loss-layer problem instance: embeddings `E (N×D)`, classifier
/// `C (V×D)`, labels `x (N)` with `-1` marking ignored tokens.
///
/// Generic over the storage dtype `S` of `E`/`C` (default `f32`): with
/// `S = BF16` the kernels read half-width parameters/activations,
/// widening on load inside the SIMD dot/axpy — accumulation stays f32/f64
/// either way (the paper's mixed-precision setting).
#[derive(Debug, Clone, Copy)]
pub struct Problem<'a, S: Store = f32> {
    pub e: &'a [S],
    pub c: &'a [S],
    pub x: &'a [i32],
    pub n: usize,
    pub d: usize,
    pub v: usize,
}

impl<'a, S: Store> Problem<'a, S> {
    pub fn new(
        e: &'a [S],
        c: &'a [S],
        x: &'a [i32],
        n: usize,
        d: usize,
        v: usize,
    ) -> Result<Problem<'a, S>> {
        let p = Problem { e, c, x, n, d, v };
        p.validate()?;
        Ok(p)
    }

    fn validate(&self) -> Result<()> {
        if self.n == 0 || self.d == 0 || self.v == 0 {
            bail!("empty problem: n={} d={} v={}", self.n, self.d, self.v);
        }
        if self.e.len() != self.n * self.d {
            bail!("e has {} elements, want {}x{}", self.e.len(), self.n, self.d);
        }
        if self.c.len() != self.v * self.d {
            bail!("c has {} elements, want {}x{}", self.c.len(), self.v, self.d);
        }
        if self.x.len() != self.n {
            bail!("x has {} labels, want {}", self.x.len(), self.n);
        }
        if let Some(&bad) = self.x.iter().find(|&&t| t >= self.v as i32 || t < -1) {
            bail!(
                "label {bad} out of range for vocab {} (valid: -1 for ignored, or 0..{})",
                self.v,
                self.v
            );
        }
        Ok(())
    }

    /// Non-ignored token count (the loss denominator).
    pub fn active_count(&self) -> usize {
        self.x.iter().filter(|&&t| t >= 0).count()
    }
}

impl<'a> Problem<'a> {
    /// Borrow a problem from `[e (N,D), c (V,D), x (N)]` host tensors — the
    /// input layout of the loss artifacts and of `gen_loss_inputs`.
    pub fn from_tensors(tensors: &'a [HostTensor]) -> Result<Problem<'a>> {
        if tensors.len() != 3 {
            bail!("expected [e, c, x] tensors, got {}", tensors.len());
        }
        let (et, ct, xt) = (&tensors[0], &tensors[1], &tensors[2]);
        if et.shape.len() != 2 || ct.shape.len() != 2 {
            bail!("e/c must be rank-2, got {:?} / {:?}", et.shape, ct.shape);
        }
        Problem::new(
            et.as_f32()?,
            ct.as_f32()?,
            xt.as_i32()?,
            et.shape[0],
            et.shape[1],
            ct.shape[0],
        )
    }
}

/// Blocking / threading configuration of the native kernels.
#[derive(Debug, Clone, Copy)]
pub struct KernelOptions {
    /// Rows per block (`N_B`).
    pub n_block: usize,
    /// Vocabulary columns per tile (`V_B`).
    pub v_block: usize,
    /// Worker threads (contiguous row spans).
    pub threads: usize,
    /// Apply the §4.3 gradient filter in the backward pass.
    pub filter: bool,
    /// Sort vocabulary blocks by token frequency in the backward pass.
    pub sort: bool,
    /// Kahan-compensated accumulation: the forward's online LSE and loss
    /// sums, and the backward's `dE`/`dC` accumulation, carry per-element
    /// compensation terms (the paper's `CCE-Kahan` rows; doubles the
    /// gradient working buffers, see [`crate::memmodel`]).
    pub kahan: bool,
    /// Compute `dC` without the gradient filter even when `filter` is on
    /// (the paper's `CCE-Kahan-FullC`: the full classifier gradient).
    pub full_c: bool,
    /// Compute `dE` without the gradient filter even when `filter` is on
    /// (the paper's `CCE-Kahan-FullE`: the full embedding gradient).
    pub full_e: bool,
    /// Storage dtype of parameters / activations / gradients (`--dtype
    /// f32|bf16`).  The kernels themselves are generic over [`Store`] —
    /// this field is the *driver-level* selection that the trainer, the
    /// benches, and the serve engine dispatch on; accumulation is f32/f64
    /// regardless.
    pub dtype: StoreDtype,
}

impl Default for KernelOptions {
    fn default() -> KernelOptions {
        KernelOptions {
            // 32×128 f32 tiles: small enough that the eps-filter skips at
            // whole-block granularity on realistic softmax sparsity, big
            // enough that the dot-product loops dominate the fold overhead.
            n_block: 32,
            v_block: 128,
            threads: default_threads(),
            filter: true,
            sort: true,
            kahan: false,
            full_c: false,
            full_e: false,
            dtype: StoreDtype::F32,
        }
    }
}

/// Default worker count: the machine's available parallelism.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Resolve a `--threads` request: `0` means "auto" (available parallelism)
/// on every path — train, table1, serve, and the kernels themselves.
pub fn resolve_threads(requested: usize) -> usize {
    if requested == 0 {
        default_threads()
    } else {
        requested
    }
}

/// Spawned workers of the shared kernel pool (the calling thread always
/// participates too, so fork-join parallelism is this plus one).  Starts at
/// 0 — the pool is lazy — and grows with the largest span count requested.
/// Surfaced as `pool_workers` in `cce info`, `{"op":"info"}`, and the
/// BENCH metadata.
pub fn pool_workers() -> usize {
    pool::global().workers()
}

impl KernelOptions {
    /// [`KernelOptions::threads`] with `0` resolved to auto.
    pub fn resolved_threads(&self) -> usize {
        resolve_threads(self.threads)
    }
}

/// Resolved SIMD dispatch level of this process (`"avx2+fma"` or
/// `"portable"`) — surfaced by `cce info` and stamped into
/// `BENCH_table1.json` so perf baselines are only compared within one
/// dispatch level.
pub fn simd_dispatch() -> &'static str {
    simd::dispatch_name()
}

/// Forward-pass result.
#[derive(Debug, Clone)]
pub struct ForwardOut {
    /// Mean NLL over non-ignored tokens.
    pub loss: f64,
    /// Non-ignored token count.
    pub count: usize,
    /// Per-row log-sum-exp (length N) — consumed by the backward pass.
    pub lse: Vec<f32>,
    /// Per-row target logit `e_i · c_{x_i}` (0 where ignored).
    pub target_logit: Vec<f32>,
    /// Peak working memory allocated by the kernel: the `O(N)` lse/target
    /// vectors plus the per-thread logit block buffers.  Inputs excluded.
    pub workspace_bytes: usize,
}

/// Backward-pass result.  Gradients are stored in the problem's dtype
/// (`S = BF16` halves the output-gradient footprint — the paper's `G`
/// lower bound at `act_bytes = 2`); every accumulation happened in f32.
#[derive(Debug, Clone)]
pub struct BackwardOut<S: Store = f32> {
    /// `dE` — gradient of the mean loss wrt the embeddings (N×D).
    pub d_e: Vec<S>,
    /// `dC` — gradient wrt the classifier (V×D).
    pub d_c: Vec<S>,
    pub stats: FilterStats,
    /// Peak *concurrent* working memory beyond the gradient outputs: the
    /// larger of the two phases (each holds the permutation tables + the
    /// skip mask, plus its own per-thread staging — probability tiles and
    /// f32 accumulation scratch for phase A; the per-row output handles
    /// and segment scratch for phase B; Kahan compensation where enabled).
    /// There is no `V×D` side accumulator in either phase.
    pub workspace_bytes: usize,
}

/// Gradient-filter accounting, comparable to
/// [`crate::sparsity::BlockFilterModel`]'s predictions.
#[derive(Debug, Clone, Copy, Default)]
pub struct FilterStats {
    /// `(N_B, V_B)` blocks visited.
    pub blocks_total: u64,
    /// Sub-eps blocks (all softmax entries of active rows below the
    /// `2^-12` threshold) — skipped wholesale by every filter-eligible
    /// phase (`full_c`/`full_e` exempt their phase from the skip but not
    /// from this count).
    pub blocks_skipped: u64,
    /// Softmax entries at or above the threshold (over active rows).
    pub sig_entries: u64,
}

impl FilterStats {
    /// Fraction of blocks that ran their accumulation matmuls.
    pub fn survival(&self) -> f64 {
        if self.blocks_total == 0 {
            return 1.0;
        }
        1.0 - self.blocks_skipped as f64 / self.blocks_total as f64
    }

    pub fn merge(&mut self, other: &FilterStats) {
        self.blocks_total += other.blocks_total;
        self.blocks_skipped += other.blocks_skipped;
        self.sig_entries += other.sig_entries;
    }
}

// ---------------------------------------------------------------- telemetry

/// Handles into the process-global metrics registry, resolved once.  The
/// families are pre-registered by [`obs::global`], so these lookups bind to
/// the exact storage the exporters render — no registration races, no help
/// strings to repeat here.
struct ExecObs {
    fwd_sweep_us: Arc<obs::Histogram>,
    bwd_sweep_us: Arc<obs::Histogram>,
    infer_sweep_us: Arc<obs::Histogram>,
    filter_survival: Arc<obs::GaugeF>,
    filter_survival_predicted: Arc<obs::GaugeF>,
    filter_blocks_total: Arc<obs::Counter>,
    filter_blocks_skipped: Arc<obs::Counter>,
    workspace_peak: Arc<obs::Gauge>,
    pool_workers: Arc<obs::Gauge>,
    pool_inline: Arc<obs::Counter>,
    pool_dispatch: Arc<obs::Counter>,
}

fn exec_obs() -> &'static ExecObs {
    static OBS: OnceLock<ExecObs> = OnceLock::new();
    OBS.get_or_init(|| {
        let r = obs::global();
        ExecObs {
            fwd_sweep_us: r.histogram("exec_fwd_sweep_us", ""),
            bwd_sweep_us: r.histogram("exec_bwd_sweep_us", ""),
            infer_sweep_us: r.histogram("exec_infer_sweep_us", ""),
            filter_survival: r.gauge_f("exec_filter_survival", ""),
            filter_survival_predicted: r.gauge_f("exec_filter_survival_predicted", ""),
            filter_blocks_total: r.counter("exec_filter_blocks_total", ""),
            filter_blocks_skipped: r.counter("exec_filter_blocks_skipped_total", ""),
            workspace_peak: r.gauge("exec_workspace_peak_bytes", ""),
            pool_workers: r.gauge("exec_pool_workers", ""),
            pool_inline: r.counter("exec_pool_inline_total", ""),
            pool_dispatch: r.counter("exec_pool_dispatch_total", ""),
        }
    })
}

/// Per-sweep forward profiling hook.  One enabled-check plus a handful of
/// relaxed atomics; a single relaxed load when tracing is off.
pub(crate) fn record_fwd_sweep(us: u64, workspace_bytes: usize) {
    if !obs::enabled() {
        return;
    }
    let o = exec_obs();
    o.fwd_sweep_us.record(us);
    o.workspace_peak.set_max(workspace_bytes as i64);
}

/// Per-sweep backward profiling hook: sweep time, workspace high-water,
/// filter block accounting, and the measured block-survival ratio next to
/// the [`BlockFilterModel`] prediction for the same shape — the live
/// measured-vs-modelled §4.3 comparison.
pub(crate) fn record_bwd_sweep(
    us: u64,
    stats: &FilterStats,
    workspace_bytes: usize,
    n: usize,
    v: usize,
    opts: &KernelOptions,
) {
    if !obs::enabled() {
        return;
    }
    let o = exec_obs();
    o.bwd_sweep_us.record(us);
    o.workspace_peak.set_max(workspace_bytes as i64);
    o.filter_blocks_total.add(stats.blocks_total);
    o.filter_blocks_skipped.add(stats.blocks_skipped);
    o.filter_survival.set(stats.survival());
    let model = BlockFilterModel {
        vocab: v,
        v_block: opts.v_block,
        n_block: opts.n_block,
        sig_per_row: (stats.sig_entries / n.max(1) as u64) as usize,
        // Nominal Zipf head agreement; the gap between measured and
        // predicted survival is exactly what this pair of gauges surfaces.
        sort_agreement: 0.7,
    };
    let predicted = if opts.sort { model.survival_sorted() } else { model.survival_unsorted() };
    o.filter_survival_predicted.set(predicted);
}

/// Per-sweep inference profiling hook (topk / sample / score).
pub(crate) fn record_infer_sweep(us: u64) {
    if !obs::enabled() {
        return;
    }
    exec_obs().infer_sweep_us.record(us);
}

/// Raise the process-wide kernel-workspace high-water mark.  Public so the
/// serve engine can mirror its per-engine peak into `/metrics`.
pub fn note_workspace_peak(bytes: u64) {
    if !obs::enabled() {
        return;
    }
    exec_obs().workspace_peak.set_max(bytes as i64);
}

/// Ceiling division (formulated to be toolchain-neutral: no `div_ceil`
/// MSRV requirement, no `(a + b - 1) / b` lint pattern).
pub(crate) fn ceil_div(a: usize, b: usize) -> usize {
    let b = b.max(1);
    a / b + usize::from(a % b != 0)
}

/// Rows per worker span: a whole number of `n_block` row-blocks, sized so
/// at most `threads` spans cover `n` rows (`threads == 0` = auto).
pub(crate) fn span_rows(n: usize, n_block: usize, threads: usize) -> usize {
    let nb = n_block.clamp(1, n.max(1));
    let per = ceil_div(ceil_div(n, nb), resolve_threads(threads));
    (per.max(1)) * nb
}

// ---------------------------------------------------------------- baseline

/// Materialized-logits reference forward (the Table-1 "Baseline" row): the
/// full `N×V` logit matrix is allocated **in the storage dtype** — exactly
/// the allocation CCE removes, and exactly the allocation that halves
/// under `--dtype bf16` (the paper's mixed-precision memory column).
/// Multi-threaded over row spans (through the shared [`pool`]) for a fair
/// time comparison.
pub fn baseline_forward<S: Store>(p: &Problem<S>, opts: &KernelOptions) -> ForwardOut {
    let (logits, fwd) = simd::with_lanes!(lanes => baseline_logits_and_forward(p, opts, lanes));
    drop(logits);
    fwd
}

/// Baseline forward + backward from the stored logits.
pub fn baseline_forward_backward<S: Store>(
    p: &Problem<S>,
    opts: &KernelOptions,
) -> (ForwardOut, BackwardOut<S>) {
    simd::with_lanes!(lanes => baseline_forward_backward_with(p, opts, lanes))
}

fn baseline_forward_backward_with<S: Store, L: simd::Lanes>(
    p: &Problem<S>,
    opts: &KernelOptions,
    lanes: L,
) -> (ForwardOut, BackwardOut<S>) {
    let (logits, fwd) = baseline_logits_and_forward(p, opts, lanes);
    let (n, d, v) = (p.n, p.d, p.v);
    let count = fwd.count;
    let inv_count = if count == 0 { 0.0f32 } else { 1.0 / count as f32 };
    let mut d_e = vec![S::ZERO; n * d];
    let mut d_c = vec![S::ZERO; v * d];
    let span = span_rows(n, opts.n_block, opts.threads);
    let lse = &fwd.lse;
    let shards: Vec<Vec<f32>> = {
        let logits = &logits;
        let tasks: Vec<_> = d_e
            .chunks_mut(span * d)
            .enumerate()
            .map(|(ti, de_chunk)| {
                let row0 = ti * span;
                move || {
                    let rows = de_chunk.len() / d;
                    let mut dc_local = vec![0f32; v * d];
                    // f32 staging row for dE: accumulate the full vocab
                    // sweep at f32, narrow once on store.
                    let mut de_acc = vec![0f32; d];
                    for r in 0..rows {
                        let i = row0 + r;
                        if p.x[i] < 0 {
                            continue;
                        }
                        let t = p.x[i] as usize;
                        let e_row = &p.e[i * d..(i + 1) * d];
                        de_acc.fill(0.0);
                        for j in 0..v {
                            let z = logits[i * v + j].to_f32();
                            let mut g = (z - lse[i]).exp() * inv_count;
                            if j == t {
                                g -= inv_count;
                            }
                            let c_row = &p.c[j * d..(j + 1) * d];
                            let dc_row = &mut dc_local[j * d..(j + 1) * d];
                            S::lanes_axpy_acc(lanes, &mut de_acc, g, c_row);
                            S::lanes_axpy_acc(lanes, dc_row, g, e_row);
                        }
                        S::narrow_into(&mut de_chunk[r * d..(r + 1) * d], &de_acc);
                    }
                    dc_local
                }
            })
            .collect();
        pool::global().run(tasks)
    };
    // Merge the f32 shards sequentially, then narrow once into the output.
    let n_shards = shards.len();
    let mut dc_master = vec![0f32; v * d];
    for shard in shards {
        for (acc, val) in dc_master.iter_mut().zip(&shard) {
            *acc += *val;
        }
    }
    S::narrow_into(&mut d_c, &dc_master);
    let workspace = logits.len() * S::BYTES + (n_shards + 1) * v * d * 4 + n_shards * d * 4;
    (
        fwd,
        BackwardOut {
            d_e,
            d_c,
            stats: FilterStats::default(),
            workspace_bytes: workspace,
        },
    )
}

fn baseline_logits_and_forward<S: Store, L: simd::Lanes>(
    p: &Problem<S>,
    opts: &KernelOptions,
    lanes: L,
) -> (Vec<S>, ForwardOut) {
    let (n, d, v) = (p.n, p.d, p.v);
    let mut logits = vec![S::ZERO; n * v];
    let mut lse = vec![0f32; n];
    let mut tgt = vec![0f32; n];
    let span = span_rows(n, opts.n_block, opts.threads);
    let tasks: Vec<_> = logits
        .chunks_mut(span * v)
        .zip(lse.chunks_mut(span))
        .zip(tgt.chunks_mut(span))
        .enumerate()
        .map(|(ti, ((lchunk, lse_chunk), tgt_chunk))| {
            let row0 = ti * span;
            move || {
                let rows = lse_chunk.len();
                // f32 staging row: dots land here, the row is narrowed
                // into the stored matrix, and the softmax reduction reads
                // the *stored* (rounded) values so forward and backward
                // see the same logits — mirroring a bf16 framework, and
                // a pure copy when S = f32.
                let mut zf = vec![0f32; v];
                for r in 0..rows {
                    let i = row0 + r;
                    let e_row = &p.e[i * d..(i + 1) * d];
                    let z_row = &mut lchunk[r * v..(r + 1) * v];
                    for j in 0..v {
                        zf[j] = S::lanes_dot(lanes, e_row, &p.c[j * d..(j + 1) * d]);
                    }
                    S::narrow_into(z_row, &zf);
                    S::widen_into(&mut zf, z_row);
                    let m = lanes.vmax(&zf);
                    let s: f32 = zf.iter().map(|&z| (z - m).exp()).sum();
                    lse_chunk[r] = m + s.ln();
                    if p.x[i] >= 0 {
                        tgt_chunk[r] = zf[p.x[i] as usize];
                    }
                }
            }
        })
        .collect();
    pool::global().run(tasks);
    let count = p.active_count();
    let loss_sum: f64 = p
        .x
        .iter()
        .enumerate()
        .filter(|(_, &t)| t >= 0)
        .map(|(i, _)| (lse[i] - tgt[i]) as f64)
        .sum();
    let loss = if count == 0 { 0.0 } else { loss_sum / count as f64 };
    let workers = ceil_div(n, span);
    let workspace = logits.len() * S::BYTES + n * 8 + workers * v * 4;
    (
        logits,
        ForwardOut { loss, count, lse, target_logit: tgt, workspace_bytes: workspace },
    )
}

/// Deterministic random problem data for unit tests (shared across the
/// exec submodules' test modules).
#[cfg(test)]
pub(crate) fn random_problem(
    rng: &mut crate::util::rng::Rng,
    n: usize,
    d: usize,
    v: usize,
    ignored_frac: f64,
) -> (Vec<f32>, Vec<f32>, Vec<i32>) {
    let e: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32 * 0.5).collect();
    let c: Vec<f32> = (0..v * d).map(|_| rng.normal() as f32 * 0.5).collect();
    let x: Vec<i32> = (0..n)
        .map(|_| {
            if rng.bool(ignored_frac) {
                -1
            } else {
                rng.usize_below(v) as i32
            }
        })
        .collect();
    (e, c, x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn problem_validation() {
        let e = vec![0f32; 8];
        let c = vec![0f32; 12];
        let x = vec![0i32, 1];
        assert!(Problem::new(&e, &c, &x, 2, 4, 3).is_ok());
        assert!(Problem::new(&e, &c, &x, 2, 4, 4).is_err()); // c too small
        assert!(Problem::new(&e, &c, &[0, 3], 2, 4, 3).is_err()); // label oob
        assert!(Problem::new(&e, &c, &[0, -1], 2, 4, 3).is_ok()); // ignored ok
        assert!(Problem::new(&e, &c, &[0, -5], 2, 4, 3).is_err()); // below -1
        assert!(Problem::new(&e, &c, &[0, -2], 2, 4, 3).is_err()); // below -1
    }

    #[test]
    fn baseline_uniform_logits_give_ln_v() {
        // Zero embeddings => uniform softmax => loss = ln(V) exactly.
        let (n, d, v) = (16, 8, 32);
        let e = vec![0f32; n * d];
        let mut rng = Rng::new(1);
        let c: Vec<f32> = (0..v * d).map(|_| rng.normal() as f32).collect();
        let x: Vec<i32> = (0..n).map(|i| (i % v) as i32).collect();
        let p = Problem::new(&e, &c, &x, n, d, v).unwrap();
        let fwd = baseline_forward(&p, &KernelOptions::default());
        assert!((fwd.loss - (v as f64).ln()).abs() < 1e-5, "{}", fwd.loss);
        assert_eq!(fwd.count, n);
    }

    #[test]
    fn baseline_grads_sum_to_zero_over_vocab() {
        // Sum_j dC_j = sum_i (sum_j p_ij - 1) e_i / count = 0.
        let mut rng = Rng::new(2);
        let (n, d, v) = (12, 6, 20);
        let (e, c, x) = random_problem(&mut rng, n, d, v, 0.25);
        let p = Problem::new(&e, &c, &x, n, d, v).unwrap();
        let (_, bwd) = baseline_forward_backward(&p, &KernelOptions::default());
        for k in 0..d {
            let col_sum: f32 = (0..v).map(|j| bwd.d_c[j * d + k]).sum();
            assert!(col_sum.abs() < 1e-4, "col {k}: {col_sum}");
        }
        // Ignored rows get exactly zero dE.
        for (i, &t) in x.iter().enumerate() {
            if t < 0 {
                assert!(bwd.d_e[i * d..(i + 1) * d].iter().all(|&g| g == 0.0));
            }
        }
    }

    #[test]
    fn span_rows_covers_and_aligns() {
        assert_eq!(span_rows(1024, 64, 4), 256);
        assert_eq!(span_rows(100, 64, 4), 64); // 2 blocks over 4 threads
        assert_eq!(span_rows(64, 64, 1), 64);
        assert!(span_rows(7, 64, 3) >= 7); // n_block clamped to n
        let span = span_rows(1000, 64, 3);
        assert_eq!(span % 64, 0);
        assert!(span * 3 >= 1000);
    }
}
