//! Portable 8-lane f32 SIMD for the exec kernels.
//!
//! One trait ([`Lanes`]) abstracts the vector operations the hot loops
//! need — dot products, `y += a·x` accumulation (plain and
//! Kahan-compensated), horizontal max, and the bag-of-context reductions.
//! Two implementations exist:
//!
//! * [`Avx2`] — explicit `std::arch` AVX2 + FMA intrinsics (x86_64 only):
//!   8 f32 lanes, two-way unrolled dot accumulators, fused multiply-add.
//! * [`Portable`] — 8-lane scalar chunks that LLVM autovectorizes to
//!   SSE2 / NEON / whatever the target offers; also the semantics
//!   reference that the parity tests compare the AVX2 path against.
//!
//! Detection happens once per process (a `OnceLock`'d CPUID probe): the
//! AVX2 path is taken only when the CPU reports both `avx2` and `fma`,
//! everything else (and every non-x86_64 target) uses the portable path.
//! No nightly features, no `std::simd`.
//!
//! **Dispatch is resolved once per kernel entry, not once per call.**  The
//! hot loops are generic over `L: Lanes`; each kernel entry point resolves
//! a [`Resolved`] token (via the [`with_lanes!`] macro) and monomorphizes
//! its whole sweep against the concrete implementation, so the per-call
//! `OnceLock` load + `Option` branch the old `simd::dot`-style free
//! functions paid — a few cycles per call, measurable at small `D` — is
//! gone from the kernels.  On the portable path the vector ops now inline
//! fully into the sweep; on the AVX2 path the call becomes a direct jump
//! to the known intrinsic routine (the `#[target_feature]` ABI boundary
//! itself remains non-inlinable on this MSRV, as documented below).  The
//! per-call free functions survive only as `#[cfg(test)]` references that
//! the parity tests compare the token paths against.
//!
//! Numerics: both paths keep 8 independent partial accumulators reduced
//! pairwise at the end, so they differ from a sequential scalar sum only
//! by f32 reassociation round-off (and by FMA's single product rounding
//! on the AVX2 path).  Kernel-level tolerances (1e-4..1e-5 on losses and
//! gradients) absorb this; `tests/native.rs` pins it across
//! remainder-lane shapes (D, V not multiples of 8).  [`Lanes::vmax`] is
//! exact (max is order-independent), and [`Lanes::axpy_kahan`] uses the
//! same `mul → compensated add` sequence on both paths, so the Kahan
//! kernels are bitwise identical across dispatch levels.

#[cfg(target_arch = "x86_64")]
use std::sync::OnceLock;

use super::dtype::BF16;

/// The vector operations the kernels are written against.  Implementors
/// are zero-sized capability tokens: `Copy + Send + Sync` so a resolved
/// token threads freely into the pool's span tasks.
///
/// Declared `pub` inside a crate-private module (the sealed-trait shape):
/// `exec::dtype::Store`'s lane hooks name it in their bounds, which keeps
/// `Store` unimplementable outside this crate without exposing any of the
/// dispatch machinery.
///
/// The `*_bf16` variants widen their bf16 operand **on load** — in
/// registers on the AVX2 path (`u16` load → zero-extend → `<<16` →
/// bitcast, then the same FMA pipeline as the f32 routine), element-wise
/// in the portable path — so bf16 storage never forces a materialized f32
/// copy of a parameter block.
pub trait Lanes: Copy + Send + Sync + 'static {
    /// `Σ a[i]·b[i]` over the common prefix of `a` and `b`.
    fn dot(&self, a: &[f32], b: &[f32]) -> f32;
    /// `y[i] += a·x[i]` over the common prefix.
    fn axpy(&self, y: &mut [f32], a: f32, x: &[f32]);
    /// Kahan-compensated `y[i] += a·x[i]` with per-element compensation
    /// carried in `c` (same length as `y`; zero-initialized by the caller
    /// and reused across calls so the compensation persists over a sweep).
    fn axpy_kahan(&self, y: &mut [f32], c: &mut [f32], a: f32, x: &[f32]);
    /// `max_i z[i]` (`NEG_INFINITY` for an empty slice).  Exact: max is
    /// order-independent, so every path returns the same bits.
    fn vmax(&self, z: &[f32]) -> f32;
    /// `y[i] += x[i]` over the common prefix.
    fn add_assign(&self, y: &mut [f32], x: &[f32]);
    /// `y[i] *= a`.
    fn scale(&self, y: &mut [f32], a: f32);
    /// `Σ widen(a[i])·widen(b[i])` — both operands bf16, widened on load.
    fn dot_bf16(&self, a: &[BF16], b: &[BF16]) -> f32;
    /// `Σ a[i]·widen(b[i])` — f32 activations against bf16 storage.
    fn dot_f32_bf16(&self, a: &[f32], b: &[BF16]) -> f32;
    /// `y[i] += a·widen(x[i])` into an f32 accumulator.
    fn axpy_bf16(&self, y: &mut [f32], a: f32, x: &[BF16]);
    /// Kahan-compensated [`Lanes::axpy_bf16`].  Widening is exact and the
    /// product uses a plain mul on every path, so this is bitwise
    /// identical across dispatch levels (same argument as `axpy_kahan`).
    fn axpy_kahan_bf16(&self, y: &mut [f32], c: &mut [f32], a: f32, x: &[BF16]);
}

/// 8-lane scalar fallback; the shape LLVM autovectorizes on any target.
#[derive(Debug, Clone, Copy)]
pub struct Portable;

impl Lanes for Portable {
    #[inline]
    fn dot(&self, a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let (a, b) = (&a[..n], &b[..n]);
        // Two 8-lane accumulator banks, 16 elements per iteration —
        // mirrors the AVX2 path's unroll so the reduction trees match.
        let mut lo = [0f32; 8];
        let mut hi = [0f32; 8];
        let mut ca = a.chunks_exact(16);
        let mut cb = b.chunks_exact(16);
        for (xa, xb) in ca.by_ref().zip(cb.by_ref()) {
            for k in 0..8 {
                lo[k] += xa[k] * xb[k];
                hi[k] += xa[k + 8] * xb[k + 8];
            }
        }
        let (mut ra, mut rb) = (ca.remainder(), cb.remainder());
        if ra.len() >= 8 {
            for k in 0..8 {
                lo[k] += ra[k] * rb[k];
            }
            ra = &ra[8..];
            rb = &rb[8..];
        }
        let mut lanes = [0f32; 8];
        for k in 0..8 {
            lanes[k] = lo[k] + hi[k];
        }
        // Pairwise reduction in the same order as the AVX2 horizontal sum:
        // fold the upper half onto the lower, then (s0+s1) + (s2+s3).
        let s0 = lanes[0] + lanes[4];
        let s1 = lanes[1] + lanes[5];
        let s2 = lanes[2] + lanes[6];
        let s3 = lanes[3] + lanes[7];
        let mut sum = (s0 + s1) + (s2 + s3);
        for (xa, xb) in ra.iter().zip(rb) {
            sum += xa * xb;
        }
        sum
    }

    #[inline]
    fn axpy(&self, y: &mut [f32], a: f32, x: &[f32]) {
        for (yk, xk) in y.iter_mut().zip(x) {
            *yk += a * *xk;
        }
    }

    #[inline]
    fn axpy_kahan(&self, y: &mut [f32], c: &mut [f32], a: f32, x: &[f32]) {
        let n = y.len().min(c.len()).min(x.len());
        for k in 0..n {
            // Classic Kahan: the product is rounded once (plain mul, no
            // FMA, so every dispatch level computes identical bits), then
            // added with the running compensation.
            let t = a * x[k] - c[k];
            let s = y[k] + t;
            c[k] = (s - y[k]) - t;
            y[k] = s;
        }
    }

    #[inline]
    fn vmax(&self, z: &[f32]) -> f32 {
        let mut lanes = [f32::NEG_INFINITY; 8];
        let mut cz = z.chunks_exact(8);
        for chunk in cz.by_ref() {
            for k in 0..8 {
                lanes[k] = lanes[k].max(chunk[k]);
            }
        }
        let mut m = lanes.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        for &v in cz.remainder() {
            m = m.max(v);
        }
        m
    }

    #[inline]
    fn add_assign(&self, y: &mut [f32], x: &[f32]) {
        for (yk, xk) in y.iter_mut().zip(x) {
            *yk += *xk;
        }
    }

    #[inline]
    fn scale(&self, y: &mut [f32], a: f32) {
        for yk in y.iter_mut() {
            *yk *= a;
        }
    }

    #[inline]
    fn dot_bf16(&self, a: &[BF16], b: &[BF16]) -> f32 {
        // Same two-bank / pairwise-reduction shape as `dot`, with the
        // operands widened element-wise (exact), so the rounding tree
        // matches the AVX2 widen-load path up to FMA.
        let n = a.len().min(b.len());
        let (a, b) = (&a[..n], &b[..n]);
        let mut lo = [0f32; 8];
        let mut hi = [0f32; 8];
        let mut ca = a.chunks_exact(16);
        let mut cb = b.chunks_exact(16);
        for (xa, xb) in ca.by_ref().zip(cb.by_ref()) {
            for k in 0..8 {
                lo[k] += xa[k].to_f32() * xb[k].to_f32();
                hi[k] += xa[k + 8].to_f32() * xb[k + 8].to_f32();
            }
        }
        let (mut ra, mut rb) = (ca.remainder(), cb.remainder());
        if ra.len() >= 8 {
            for k in 0..8 {
                lo[k] += ra[k].to_f32() * rb[k].to_f32();
            }
            ra = &ra[8..];
            rb = &rb[8..];
        }
        let mut lanes = [0f32; 8];
        for k in 0..8 {
            lanes[k] = lo[k] + hi[k];
        }
        let s0 = lanes[0] + lanes[4];
        let s1 = lanes[1] + lanes[5];
        let s2 = lanes[2] + lanes[6];
        let s3 = lanes[3] + lanes[7];
        let mut sum = (s0 + s1) + (s2 + s3);
        for (xa, xb) in ra.iter().zip(rb) {
            sum += xa.to_f32() * xb.to_f32();
        }
        sum
    }

    #[inline]
    fn dot_f32_bf16(&self, a: &[f32], b: &[BF16]) -> f32 {
        let n = a.len().min(b.len());
        let (a, b) = (&a[..n], &b[..n]);
        let mut lo = [0f32; 8];
        let mut hi = [0f32; 8];
        let mut ca = a.chunks_exact(16);
        let mut cb = b.chunks_exact(16);
        for (xa, xb) in ca.by_ref().zip(cb.by_ref()) {
            for k in 0..8 {
                lo[k] += xa[k] * xb[k].to_f32();
                hi[k] += xa[k + 8] * xb[k + 8].to_f32();
            }
        }
        let (mut ra, mut rb) = (ca.remainder(), cb.remainder());
        if ra.len() >= 8 {
            for k in 0..8 {
                lo[k] += ra[k] * rb[k].to_f32();
            }
            ra = &ra[8..];
            rb = &rb[8..];
        }
        let mut lanes = [0f32; 8];
        for k in 0..8 {
            lanes[k] = lo[k] + hi[k];
        }
        let s0 = lanes[0] + lanes[4];
        let s1 = lanes[1] + lanes[5];
        let s2 = lanes[2] + lanes[6];
        let s3 = lanes[3] + lanes[7];
        let mut sum = (s0 + s1) + (s2 + s3);
        for (xa, xb) in ra.iter().zip(rb) {
            sum += xa * xb.to_f32();
        }
        sum
    }

    #[inline]
    fn axpy_bf16(&self, y: &mut [f32], a: f32, x: &[BF16]) {
        for (yk, xk) in y.iter_mut().zip(x) {
            *yk += a * xk.to_f32();
        }
    }

    #[inline]
    fn axpy_kahan_bf16(&self, y: &mut [f32], c: &mut [f32], a: f32, x: &[BF16]) {
        let n = y.len().min(c.len()).min(x.len());
        for k in 0..n {
            // Exact widen, plain mul (no FMA): identical bits on every
            // dispatch level, same as `axpy_kahan`.
            let t = a * x[k].to_f32() - c[k];
            let s = y[k] + t;
            c[k] = (s - y[k]) - t;
            y[k] = s;
        }
    }
}

/// Token type proving `avx2` + `fma` were detected at runtime; the only
/// way to reach the intrinsic paths.
#[cfg(target_arch = "x86_64")]
#[derive(Debug, Clone, Copy)]
pub(crate) struct Avx2(());

#[cfg(target_arch = "x86_64")]
impl Avx2 {
    pub(crate) fn detect() -> Option<Avx2> {
        if std::is_x86_feature_detected!("avx2") && std::is_x86_feature_detected!("fma") {
            Some(Avx2(()))
        } else {
            None
        }
    }
}

#[cfg(target_arch = "x86_64")]
impl Lanes for Avx2 {
    #[inline]
    fn dot(&self, a: &[f32], b: &[f32]) -> f32 {
        // SAFETY: constructing `Avx2` requires runtime detection of
        // avx2+fma (see `Avx2::detect`).
        unsafe { avx2::dot(a, b) }
    }

    #[inline]
    fn axpy(&self, y: &mut [f32], a: f32, x: &[f32]) {
        // SAFETY: as above.
        unsafe { avx2::axpy(y, a, x) }
    }

    #[inline]
    fn axpy_kahan(&self, y: &mut [f32], c: &mut [f32], a: f32, x: &[f32]) {
        // SAFETY: as above.
        unsafe { avx2::axpy_kahan(y, c, a, x) }
    }

    #[inline]
    fn vmax(&self, z: &[f32]) -> f32 {
        // SAFETY: as above.
        unsafe { avx2::vmax(z) }
    }

    #[inline]
    fn add_assign(&self, y: &mut [f32], x: &[f32]) {
        // SAFETY: as above.
        unsafe { avx2::add_assign(y, x) }
    }

    #[inline]
    fn scale(&self, y: &mut [f32], a: f32) {
        // SAFETY: as above.
        unsafe { avx2::scale(y, a) }
    }

    #[inline]
    fn dot_bf16(&self, a: &[BF16], b: &[BF16]) -> f32 {
        // SAFETY: as above.
        unsafe { avx2::dot_bf16(a, b) }
    }

    #[inline]
    fn dot_f32_bf16(&self, a: &[f32], b: &[BF16]) -> f32 {
        // SAFETY: as above.
        unsafe { avx2::dot_f32_bf16(a, b) }
    }

    #[inline]
    fn axpy_bf16(&self, y: &mut [f32], a: f32, x: &[BF16]) {
        // SAFETY: as above.
        unsafe { avx2::axpy_bf16(y, a, x) }
    }

    #[inline]
    fn axpy_kahan_bf16(&self, y: &mut [f32], c: &mut [f32], a: f32, x: &[BF16]) {
        // SAFETY: as above.
        unsafe { avx2::axpy_kahan_bf16(y, c, a, x) }
    }
}

#[cfg(target_arch = "x86_64")]
fn avx2_token() -> Option<Avx2> {
    static DETECTED: OnceLock<Option<Avx2>> = OnceLock::new();
    *DETECTED.get_or_init(Avx2::detect)
}

/// Name of the resolved dispatch level — bench metadata and diagnostics
/// (timings from different levels are not comparable, so
/// `BENCH_table1.json` carries this and `tools/check_bench.sh` refuses to
/// diff across levels).
pub(crate) fn dispatch_name() -> &'static str {
    #[cfg(target_arch = "x86_64")]
    if avx2_token().is_some() {
        return "avx2+fma";
    }
    "portable"
}

// ------------------------------------------------------ once-per-sweep token

/// The dispatch level resolved for this process, carried as a token so the
/// kernels monomorphize their hot loops against the concrete [`Lanes`]
/// implementation (no per-call probe, intrinsics reached by direct call).
#[derive(Clone, Copy)]
pub(crate) enum Resolved {
    #[cfg(target_arch = "x86_64")]
    Avx2(Avx2),
    Portable(Portable),
}

/// Resolve the dispatch level (one `OnceLock` load).  Call once per kernel
/// entry — never inside a loop; the [`with_lanes!`] macro is the intended
/// consumer.
pub(crate) fn resolved() -> Resolved {
    #[cfg(target_arch = "x86_64")]
    if let Some(token) = avx2_token() {
        return Resolved::Avx2(token);
    }
    Resolved::Portable(Portable)
}

/// Resolve the SIMD token once and evaluate `$body` monomorphized over it:
///
/// ```ignore
/// pub fn cce_forward(p: &Problem, opts: &KernelOptions) -> ForwardOut {
///     simd::with_lanes!(lanes => forward_with(p, opts, lanes))
/// }
/// ```
///
/// `$body` is compiled once per dispatch level, with `$lanes` bound to the
/// concrete token type in each arm — the whole sweep under it inlines the
/// portable ops and direct-calls the AVX2 routines.
macro_rules! with_lanes {
    ($lanes:ident => $body:expr) => {
        match $crate::exec::simd::resolved() {
            #[cfg(target_arch = "x86_64")]
            $crate::exec::simd::Resolved::Avx2($lanes) => $body,
            $crate::exec::simd::Resolved::Portable($lanes) => $body,
        }
    };
}
pub(crate) use with_lanes;

// ---------------------------------------------------- dispatched entry points
//
// Per-call dispatched wrappers.  The kernels no longer use these — they
// resolve a token once per sweep ([`with_lanes!`]) — so the wrappers are
// compiled for tests only, as the semantics reference the parity tests
// compare the token paths against.

/// `Σ a[i]·b[i]` — per-call-dispatched reference for tests.
#[cfg(test)]
#[inline]
pub(crate) fn dot(a: &[f32], b: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    if let Some(t) = avx2_token() {
        return t.dot(a, b);
    }
    Portable.dot(a, b)
}

/// `y[i] += a·x[i]` — per-call-dispatched reference for tests.
#[cfg(test)]
#[inline]
pub(crate) fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    #[cfg(target_arch = "x86_64")]
    if let Some(t) = avx2_token() {
        return t.axpy(y, a, x);
    }
    Portable.axpy(y, a, x)
}

/// Kahan-compensated `y[i] += a·x[i]` (compensation in `c`).
#[cfg(test)]
#[inline]
pub(crate) fn axpy_kahan(y: &mut [f32], c: &mut [f32], a: f32, x: &[f32]) {
    #[cfg(target_arch = "x86_64")]
    if let Some(t) = avx2_token() {
        return t.axpy_kahan(y, c, a, x);
    }
    Portable.axpy_kahan(y, c, a, x)
}

/// `max_i z[i]` (`NEG_INFINITY` when empty).
#[cfg(test)]
#[inline]
pub(crate) fn vmax(z: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    if let Some(t) = avx2_token() {
        return t.vmax(z);
    }
    Portable.vmax(z)
}

/// `y[i] += x[i]`.
#[cfg(test)]
#[inline]
pub(crate) fn add_assign(y: &mut [f32], x: &[f32]) {
    #[cfg(target_arch = "x86_64")]
    if let Some(t) = avx2_token() {
        return t.add_assign(y, x);
    }
    Portable.add_assign(y, x)
}

/// `y[i] *= a`.
#[cfg(test)]
#[inline]
pub(crate) fn scale(y: &mut [f32], a: f32) {
    #[cfg(target_arch = "x86_64")]
    if let Some(t) = avx2_token() {
        return t.scale(y, a);
    }
    Portable.scale(y, a)
}

// ------------------------------------------------------------- AVX2 kernels

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    use crate::exec::dtype::BF16;

    /// Widen 8 bf16 values to 8 f32 lanes in registers: zero-extend the
    /// u16s to u32 and shift into the high half — the exact widening, no
    /// lookup and no f32 staging buffer.
    ///
    /// # Safety
    /// Caller must have verified avx2 support and that `p..p+8` is
    /// readable.
    #[target_feature(enable = "avx2")]
    unsafe fn load_bf16_8(p: *const BF16) -> __m256 {
        let raw = _mm_loadu_si128(p as *const __m128i);
        _mm256_castsi256_ps(_mm256_slli_epi32::<16>(_mm256_cvtepu16_epi32(raw)))
    }

    /// Horizontal sum: fold the upper 128-bit half onto the lower, then
    /// (s0+s1) + (s2+s3) — mirrored exactly by `Portable::dot`.
    ///
    /// # Safety
    /// Caller must have verified avx2 support.
    #[target_feature(enable = "avx2")]
    unsafe fn hsum(v: __m256) -> f32 {
        let lo = _mm256_castps256_ps128(v);
        let hi = _mm256_extractf128_ps(v, 1);
        let s = _mm_add_ps(lo, hi); // [s0, s1, s2, s3]
        let odd = _mm_movehdup_ps(s); // [s1, s1, s3, s3]
        let pair = _mm_add_ps(s, odd); // [s0+s1, _, s2+s3, _]
        let upper = _mm_movehl_ps(pair, pair); // [s2+s3, _, _, _]
        _mm_cvtss_f32(_mm_add_ss(pair, upper))
    }

    /// # Safety
    /// Caller must have verified avx2+fma support.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let (ap, bp) = (a.as_ptr(), b.as_ptr());
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 16 <= n {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(ap.add(i)), _mm256_loadu_ps(bp.add(i)), acc0);
            acc1 = _mm256_fmadd_ps(
                _mm256_loadu_ps(ap.add(i + 8)),
                _mm256_loadu_ps(bp.add(i + 8)),
                acc1,
            );
            i += 16;
        }
        if i + 8 <= n {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(ap.add(i)), _mm256_loadu_ps(bp.add(i)), acc0);
            i += 8;
        }
        let mut sum = hsum(_mm256_add_ps(acc0, acc1));
        while i < n {
            sum += a[i] * b[i];
            i += 1;
        }
        sum
    }

    /// # Safety
    /// Caller must have verified avx2+fma support.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
        let n = y.len().min(x.len());
        let va = _mm256_set1_ps(a);
        let (yp, xp) = (y.as_mut_ptr(), x.as_ptr());
        let mut i = 0usize;
        while i + 8 <= n {
            let r = _mm256_fmadd_ps(va, _mm256_loadu_ps(xp.add(i)), _mm256_loadu_ps(yp.add(i)));
            _mm256_storeu_ps(yp.add(i), r);
            i += 8;
        }
        while i < n {
            y[i] += a * x[i];
            i += 1;
        }
    }

    /// Plain mul (no FMA) so the compensation algebra — and therefore the
    /// bits — match `Portable::axpy_kahan` exactly.
    ///
    /// # Safety
    /// Caller must have verified avx2 support.
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy_kahan(y: &mut [f32], c: &mut [f32], a: f32, x: &[f32]) {
        let n = y.len().min(c.len()).min(x.len());
        let va = _mm256_set1_ps(a);
        let (yp, cp, xp) = (y.as_mut_ptr(), c.as_mut_ptr(), x.as_ptr());
        let mut i = 0usize;
        while i + 8 <= n {
            let yi = _mm256_loadu_ps(yp.add(i));
            let ci = _mm256_loadu_ps(cp.add(i));
            let t = _mm256_sub_ps(_mm256_mul_ps(va, _mm256_loadu_ps(xp.add(i))), ci);
            let s = _mm256_add_ps(yi, t);
            let cn = _mm256_sub_ps(_mm256_sub_ps(s, yi), t);
            _mm256_storeu_ps(yp.add(i), s);
            _mm256_storeu_ps(cp.add(i), cn);
            i += 8;
        }
        while i < n {
            let t = a * x[i] - c[i];
            let s = y[i] + t;
            c[i] = (s - y[i]) - t;
            y[i] = s;
            i += 1;
        }
    }

    /// # Safety
    /// Caller must have verified avx2 support.
    #[target_feature(enable = "avx2")]
    pub unsafe fn vmax(z: &[f32]) -> f32 {
        let n = z.len();
        let mut m = f32::NEG_INFINITY;
        let mut i = 0usize;
        if n >= 8 {
            let mut vm = _mm256_loadu_ps(z.as_ptr());
            i = 8;
            while i + 8 <= n {
                vm = _mm256_max_ps(vm, _mm256_loadu_ps(z.as_ptr().add(i)));
                i += 8;
            }
            let lo = _mm256_castps256_ps128(vm);
            let hi = _mm256_extractf128_ps(vm, 1);
            let m4 = _mm_max_ps(lo, hi);
            let m2 = _mm_max_ps(m4, _mm_movehl_ps(m4, m4));
            let m1 = _mm_max_ss(m2, _mm_shuffle_ps(m2, m2, 0b01));
            m = _mm_cvtss_f32(m1);
        }
        while i < n {
            m = m.max(z[i]);
            i += 1;
        }
        m
    }

    /// # Safety
    /// Caller must have verified avx2 support.
    #[target_feature(enable = "avx2")]
    pub unsafe fn add_assign(y: &mut [f32], x: &[f32]) {
        let n = y.len().min(x.len());
        let (yp, xp) = (y.as_mut_ptr(), x.as_ptr());
        let mut i = 0usize;
        while i + 8 <= n {
            let r = _mm256_add_ps(_mm256_loadu_ps(yp.add(i)), _mm256_loadu_ps(xp.add(i)));
            _mm256_storeu_ps(yp.add(i), r);
            i += 8;
        }
        while i < n {
            y[i] += x[i];
            i += 1;
        }
    }

    /// # Safety
    /// Caller must have verified avx2 support.
    #[target_feature(enable = "avx2")]
    pub unsafe fn scale(y: &mut [f32], a: f32) {
        let va = _mm256_set1_ps(a);
        let n = y.len();
        let yp = y.as_mut_ptr();
        let mut i = 0usize;
        while i + 8 <= n {
            _mm256_storeu_ps(yp.add(i), _mm256_mul_ps(va, _mm256_loadu_ps(yp.add(i))));
            i += 8;
        }
        while i < n {
            y[i] *= a;
            i += 1;
        }
    }

    /// `dot` with both operands widened from bf16 on load (same unroll
    /// and horizontal sum as the f32 routine).
    ///
    /// # Safety
    /// Caller must have verified avx2+fma support.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn dot_bf16(a: &[BF16], b: &[BF16]) -> f32 {
        let n = a.len().min(b.len());
        let (ap, bp) = (a.as_ptr(), b.as_ptr());
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 16 <= n {
            acc0 = _mm256_fmadd_ps(load_bf16_8(ap.add(i)), load_bf16_8(bp.add(i)), acc0);
            acc1 = _mm256_fmadd_ps(load_bf16_8(ap.add(i + 8)), load_bf16_8(bp.add(i + 8)), acc1);
            i += 16;
        }
        if i + 8 <= n {
            acc0 = _mm256_fmadd_ps(load_bf16_8(ap.add(i)), load_bf16_8(bp.add(i)), acc0);
            i += 8;
        }
        let mut sum = hsum(_mm256_add_ps(acc0, acc1));
        while i < n {
            sum += a[i].to_f32() * b[i].to_f32();
            i += 1;
        }
        sum
    }

    /// `dot` with only `b` widened from bf16 on load.
    ///
    /// # Safety
    /// Caller must have verified avx2+fma support.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn dot_f32_bf16(a: &[f32], b: &[BF16]) -> f32 {
        let n = a.len().min(b.len());
        let (ap, bp) = (a.as_ptr(), b.as_ptr());
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 16 <= n {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(ap.add(i)), load_bf16_8(bp.add(i)), acc0);
            acc1 = _mm256_fmadd_ps(
                _mm256_loadu_ps(ap.add(i + 8)),
                load_bf16_8(bp.add(i + 8)),
                acc1,
            );
            i += 16;
        }
        if i + 8 <= n {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(ap.add(i)), load_bf16_8(bp.add(i)), acc0);
            i += 8;
        }
        let mut sum = hsum(_mm256_add_ps(acc0, acc1));
        while i < n {
            sum += a[i] * b[i].to_f32();
            i += 1;
        }
        sum
    }

    /// `y += a·widen(x)` into an f32 accumulator.
    ///
    /// # Safety
    /// Caller must have verified avx2+fma support.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn axpy_bf16(y: &mut [f32], a: f32, x: &[BF16]) {
        let n = y.len().min(x.len());
        let va = _mm256_set1_ps(a);
        let (yp, xp) = (y.as_mut_ptr(), x.as_ptr());
        let mut i = 0usize;
        while i + 8 <= n {
            let r = _mm256_fmadd_ps(va, load_bf16_8(xp.add(i)), _mm256_loadu_ps(yp.add(i)));
            _mm256_storeu_ps(yp.add(i), r);
            i += 8;
        }
        while i < n {
            y[i] += a * x[i].to_f32();
            i += 1;
        }
    }

    /// Kahan `y += a·widen(x)`: widening is exact and the product is a
    /// plain mul (no FMA), so the bits match `Portable::axpy_kahan_bf16`.
    ///
    /// # Safety
    /// Caller must have verified avx2 support.
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy_kahan_bf16(y: &mut [f32], c: &mut [f32], a: f32, x: &[BF16]) {
        let n = y.len().min(c.len()).min(x.len());
        let va = _mm256_set1_ps(a);
        let (yp, cp, xp) = (y.as_mut_ptr(), c.as_mut_ptr(), x.as_ptr());
        let mut i = 0usize;
        while i + 8 <= n {
            let yi = _mm256_loadu_ps(yp.add(i));
            let ci = _mm256_loadu_ps(cp.add(i));
            let t = _mm256_sub_ps(_mm256_mul_ps(va, load_bf16_8(xp.add(i))), ci);
            let s = _mm256_add_ps(yi, t);
            let cn = _mm256_sub_ps(_mm256_sub_ps(s, yi), t);
            _mm256_storeu_ps(yp.add(i), s);
            _mm256_storeu_ps(cp.add(i), cn);
            i += 8;
        }
        while i < n {
            let t = a * x[i].to_f32() - c[i];
            let s = y[i] + t;
            c[i] = (s - y[i]) - t;
            y[i] = s;
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Sequential scalar reference (the pre-SIMD kernel semantics).
    fn ref_dot(a: &[f32], b: &[f32]) -> f64 {
        a.iter().zip(b).map(|(x, y)| *x as f64 * *y as f64).sum()
    }

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    /// Every remainder-lane shape around the 8/16 boundaries.
    fn shapes() -> Vec<usize> {
        vec![0, 1, 2, 3, 5, 7, 8, 9, 15, 16, 17, 23, 24, 31, 33, 64, 100, 257]
    }

    #[test]
    fn dot_matches_f64_reference_on_remainder_shapes() {
        let mut rng = Rng::new(0x51D);
        for n in shapes() {
            let a = rand_vec(&mut rng, n);
            let b = rand_vec(&mut rng, n);
            let exact = ref_dot(&a, &b);
            let got = dot(&a, &b) as f64;
            let tol = 1e-5 * (1.0 + exact.abs()) * (1.0 + (n as f64).sqrt());
            assert!((got - exact).abs() < tol, "n={n}: {got} vs {exact}");
        }
    }

    #[test]
    fn dispatched_paths_agree_with_portable() {
        // On AVX2 machines this compares the intrinsic path against the
        // portable one; elsewhere it is trivially true (same path twice).
        let mut rng = Rng::new(0x51D2);
        for n in shapes() {
            let a = rand_vec(&mut rng, n);
            let b = rand_vec(&mut rng, n);
            let p = Portable.dot(&a, &b) as f64;
            let d = dot(&a, &b) as f64;
            assert!((p - d).abs() < 1e-4 * (1.0 + p.abs()), "n={n}: {d} vs portable {p}");

            let mut y1 = rand_vec(&mut rng, n);
            let mut y2 = y1.clone();
            Portable.axpy(&mut y1, 0.37, &a);
            axpy(&mut y2, 0.37, &a);
            for (u, v) in y1.iter().zip(&y2) {
                assert!((u - v).abs() <= 1e-6 * (1.0 + u.abs()), "axpy n={n}");
            }

            assert_eq!(Portable.vmax(&a).to_bits(), vmax(&a).to_bits(), "vmax n={n}");

            // Kahan is specified bitwise-identical across paths.
            let mut yk1 = rand_vec(&mut rng, n);
            let mut yk2 = yk1.clone();
            let mut c1 = vec![0f32; n];
            let mut c2 = vec![0f32; n];
            Portable.axpy_kahan(&mut yk1, &mut c1, -1.25, &b);
            axpy_kahan(&mut yk2, &mut c2, -1.25, &b);
            assert_eq!(yk1, yk2, "axpy_kahan y n={n}");
            assert_eq!(c1, c2, "axpy_kahan c n={n}");
        }
    }

    #[test]
    fn resolved_token_matches_dispatched_free_functions() {
        // The once-per-sweep token and the per-call free functions must be
        // the same implementation — bitwise.
        let mut rng = Rng::new(0x70C);
        for n in shapes() {
            let a = rand_vec(&mut rng, n);
            let b = rand_vec(&mut rng, n);
            let via_token = with_lanes!(lanes => lanes.dot(&a, &b));
            assert_eq!(via_token.to_bits(), dot(&a, &b).to_bits(), "dot n={n}");
            let vm = with_lanes!(lanes => lanes.vmax(&a));
            assert_eq!(vm.to_bits(), vmax(&a).to_bits(), "vmax n={n}");
        }
        // The token and the advertised dispatch name agree.
        match resolved() {
            #[cfg(target_arch = "x86_64")]
            Resolved::Avx2(_) => assert_eq!(dispatch_name(), "avx2+fma"),
            Resolved::Portable(_) => assert_eq!(dispatch_name(), "portable"),
        }
    }

    #[test]
    fn axpy_kahan_recovers_tiny_increments() {
        // 100k additions of 1e-8 into 1.0: plain f32 accumulation loses
        // every term (1e-8 < eps(1.0)/2); Kahan keeps them all.
        let x = [1.0f32];
        let mut plain = [1.0f32];
        let mut kahan = [1.0f32];
        let mut comp = [0.0f32];
        for _ in 0..100_000 {
            axpy(&mut plain, 1e-8, &x);
            axpy_kahan(&mut kahan, &mut comp, 1e-8, &x);
        }
        let exact = 1.0 + 100_000.0 * 1e-8; // 1.001
        assert_eq!(plain[0], 1.0, "plain f32 should drop sub-eps terms");
        assert!(
            (kahan[0] as f64 - exact).abs() < 1e-6,
            "kahan {} vs exact {exact}",
            kahan[0]
        );
    }

    #[test]
    fn bf16_lanes_match_scalar_reference_across_paths() {
        // The widen-on-load ops: dispatched (possibly AVX2) path vs the
        // portable path vs an f64 scalar reference, at remainder shapes.
        let mut rng = Rng::new(0xBF_16);
        for n in shapes() {
            let af = rand_vec(&mut rng, n);
            let bf = rand_vec(&mut rng, n);
            let ab: Vec<BF16> = af.iter().map(|&x| BF16::from_f32(x)).collect();
            let bb: Vec<BF16> = bf.iter().map(|&x| BF16::from_f32(x)).collect();
            // Scalar f64 reference over the widened values.
            let exact: f64 = ab
                .iter()
                .zip(&bb)
                .map(|(x, y)| x.to_f32() as f64 * y.to_f32() as f64)
                .sum();
            let tol = 1e-5 * (1.0 + exact.abs()) * (1.0 + (n as f64).sqrt());
            let got = with_lanes!(lanes => lanes.dot_bf16(&ab, &bb)) as f64;
            let port = Portable.dot_bf16(&ab, &bb) as f64;
            assert!((got - exact).abs() < tol, "dot_bf16 n={n}: {got} vs {exact}");
            assert!((port - exact).abs() < tol, "portable dot_bf16 n={n}");

            let exact_m: f64 = af
                .iter()
                .zip(&bb)
                .map(|(&x, y)| x as f64 * y.to_f32() as f64)
                .sum();
            let got_m = with_lanes!(lanes => lanes.dot_f32_bf16(&af, &bb)) as f64;
            assert!((got_m - exact_m).abs() < tol, "dot_f32_bf16 n={n}");

            let mut y1 = rand_vec(&mut rng, n);
            let mut y2 = y1.clone();
            Portable.axpy_bf16(&mut y1, 0.41, &bb);
            with_lanes!(lanes => lanes.axpy_bf16(&mut y2, 0.41, &bb));
            for (u, v) in y1.iter().zip(&y2) {
                assert!((u - v).abs() <= 1e-6 * (1.0 + u.abs()), "axpy_bf16 n={n}");
            }

            // Kahan is specified bitwise-identical across paths.
            let mut yk1 = rand_vec(&mut rng, n);
            let mut yk2 = yk1.clone();
            let mut c1 = vec![0f32; n];
            let mut c2 = vec![0f32; n];
            Portable.axpy_kahan_bf16(&mut yk1, &mut c1, -0.75, &ab);
            with_lanes!(lanes => lanes.axpy_kahan_bf16(&mut yk2, &mut c2, -0.75, &ab));
            assert_eq!(yk1, yk2, "axpy_kahan_bf16 y n={n}");
            assert_eq!(c1, c2, "axpy_kahan_bf16 c n={n}");
        }
    }

    #[test]
    fn bf16_dot_of_exact_values_is_exact() {
        // Small integers are bf16-exact, so the widen-on-load dot must be
        // exactly the integer dot on every path.
        let af: Vec<f32> = (0..23).map(|i| (i % 7) as f32 - 3.0).collect();
        let bf: Vec<f32> = (0..23).map(|i| (i % 5) as f32).collect();
        let ab: Vec<BF16> = af.iter().map(|&x| BF16::from_f32(x)).collect();
        let bb: Vec<BF16> = bf.iter().map(|&x| BF16::from_f32(x)).collect();
        let expect: f32 = af.iter().zip(&bf).map(|(x, y)| x * y).sum();
        assert_eq!(with_lanes!(lanes => lanes.dot_bf16(&ab, &bb)), expect);
        assert_eq!(with_lanes!(lanes => lanes.dot_f32_bf16(&af, &bb)), expect);
    }

    #[test]
    fn vmax_and_scale_basics() {
        assert_eq!(vmax(&[]), f32::NEG_INFINITY);
        assert_eq!(vmax(&[-3.0]), -3.0);
        let mut rng = Rng::new(9);
        for n in shapes() {
            let z = rand_vec(&mut rng, n);
            let expect = z.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            assert_eq!(vmax(&z), expect, "n={n}");

            let mut y = z.clone();
            scale(&mut y, 2.0);
            for (a, b) in y.iter().zip(&z) {
                assert_eq!(*a, b * 2.0);
            }
            let mut s = z.clone();
            add_assign(&mut s, &z);
            for (a, b) in s.iter().zip(&y) {
                assert_eq!(a.to_bits(), b.to_bits(), "x+x == 2x bitwise");
            }
        }
    }
}
