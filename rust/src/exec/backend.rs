//! The [`Backend`] trait: one contract over every way this repo can compute
//! the cross-entropy loss and its gradients.
//!
//! * [`NativeBackend`] — the pure-Rust kernels in this module tree; runs
//!   anywhere, zero artifacts.  Selected with `--backend native`.
//! * `PjrtBackend` (behind the `pjrt` feature) — adapter over the AOT
//!   artifact runtime, so the same call sites can execute the
//!   Pallas-lowered kernels when `libxla` + artifacts are present.
//!   Selected with `--backend pjrt`.
//!
//! Contract: `forward` returns the mean NLL over non-ignored tokens;
//! `forward_backward` additionally returns `dE`/`dC` of that mean.  Both
//! validate shapes up front and are deterministic for fixed inputs.

use anyhow::{anyhow, Result};

use super::{
    baseline_forward, baseline_forward_backward, cce_backward, cce_forward, pool, BackwardOut,
    ForwardOut, KernelOptions, Problem, Store, ThreadPool,
};

/// A loss-layer compute backend.
pub trait Backend {
    /// Human-readable identifier, e.g. `native/cce`.
    fn name(&self) -> String;
    /// Mean NLL over non-ignored tokens.
    fn forward(&self, p: &Problem) -> Result<ForwardOut>;
    /// Forward plus `dE`/`dC` gradients.
    fn forward_backward(&self, p: &Problem) -> Result<(ForwardOut, BackwardOut)>;
}

/// Which native kernel family computes the loss.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NativeMethod {
    /// Materialize the full `N×V` logit matrix (Table 1 "Baseline").
    Baseline,
    /// Row-chunked materialization with `k` chunks ("Torch Tune" analogue):
    /// the blocked kernel with `N_B = ⌈N/k⌉`, `V_B = V`, no filtering.
    Chunked(usize),
    /// Cut cross-entropy: blocked online-LSE forward, filtered/sorted
    /// blockwise backward per the `filter`/`sort`/`kahan`/`full_*` kernel
    /// options (which also encode the `cce_kahan*` Table-1 variants).
    Cce,
}

impl NativeMethod {
    /// Artifact-style key (matches [`crate::memmodel::LossMethod::key`]).
    pub fn key(&self, opts: &KernelOptions) -> String {
        match self {
            NativeMethod::Baseline => "baseline".into(),
            NativeMethod::Chunked(k) => format!("chunked{k}"),
            NativeMethod::Cce if opts.kahan => match (opts.full_c, opts.full_e) {
                (true, _) => "cce_kahan_fullc".into(),
                (false, true) => "cce_kahan_fulle".into(),
                (false, false) => "cce_kahan".into(),
            },
            NativeMethod::Cce => match (opts.filter, opts.sort) {
                (true, true) => "cce".into(),
                (true, false) => "cce_no_sort".into(),
                (false, _) => "cce_no_filter".into(),
            },
        }
    }
}

/// The native multi-threaded CPU backend.
#[derive(Debug, Clone, Copy)]
pub struct NativeBackend {
    pub method: NativeMethod,
    pub opts: KernelOptions,
}

impl NativeBackend {
    pub fn new(method: NativeMethod, opts: KernelOptions) -> NativeBackend {
        NativeBackend { method, opts }
    }

    /// Build from a Table-1 method key (`baseline`, `chunked8`, `cce`,
    /// `cce_no_filter`, `cce_no_sort`, `cce_kahan`, `cce_kahan_fullc`,
    /// `cce_kahan_fulle`).  `fused`/`liger` are third-party GPU
    /// implementations with no native analogue and are rejected.
    pub fn from_key(key: &str, mut opts: KernelOptions) -> Result<NativeBackend> {
        opts.kahan = false;
        opts.full_c = false;
        opts.full_e = false;
        let method = match key {
            "baseline" => NativeMethod::Baseline,
            "cce" => {
                opts.filter = true;
                opts.sort = true;
                NativeMethod::Cce
            }
            "cce_no_sort" => {
                opts.filter = true;
                opts.sort = false;
                NativeMethod::Cce
            }
            "cce_no_filter" => {
                opts.filter = false;
                opts.sort = false;
                NativeMethod::Cce
            }
            "cce_kahan" | "cce_kahan_fullc" | "cce_kahan_fulle" => {
                opts.filter = true;
                opts.sort = true;
                opts.kahan = true;
                opts.full_c = key == "cce_kahan_fullc";
                opts.full_e = key == "cce_kahan_fulle";
                NativeMethod::Cce
            }
            _ => match key.strip_prefix("chunked").and_then(|k| k.parse::<usize>().ok()) {
                Some(k) if k > 0 => NativeMethod::Chunked(k),
                _ => return Err(anyhow!("no native implementation for method {key:?}")),
            },
        };
        Ok(NativeBackend { method, opts })
    }

    /// The persistent fork-join pool this backend's kernels execute on.
    /// One pool serves the whole process (per-backend pools would
    /// oversubscribe the machine when the trainer, the serve batch
    /// workers, and a bench loop call kernels concurrently) — the backend
    /// holds and reports it: its worker count is the `pool_workers` field
    /// of `cce info`, `{"op":"info"}`, and the BENCH metadata.  Repeated
    /// `NativeBackend` construction spawns nothing (the leak test in
    /// `tests/native.rs` pins this).
    pub fn pool(&self) -> &'static ThreadPool {
        pool::global()
    }

    /// Effective kernel options for a problem of `n` rows / `v` columns
    /// (chunked mode derives its blocking from the chunk count).
    pub fn effective_opts(&self, n: usize, v: usize) -> KernelOptions {
        match self.method {
            NativeMethod::Chunked(k) => KernelOptions {
                n_block: crate::exec::ceil_div(n, k),
                v_block: v,
                filter: false,
                sort: false,
                ..self.opts
            },
            _ => self.opts,
        }
    }

    /// Dtype-generic forward: the [`Backend`] trait stays `f32` (so it
    /// remains object-safe), while drivers that hold a `Problem<BF16>`
    /// call this monomorphized entry directly.
    pub fn forward_t<S: Store>(&self, p: &Problem<S>) -> Result<ForwardOut> {
        Ok(match self.method {
            NativeMethod::Baseline => baseline_forward(p, &self.opts),
            NativeMethod::Chunked(_) | NativeMethod::Cce => {
                cce_forward(p, &self.effective_opts(p.n, p.v))
            }
        })
    }

    /// Dtype-generic forward + backward (see [`NativeBackend::forward_t`]).
    pub fn forward_backward_t<S: Store>(
        &self,
        p: &Problem<S>,
    ) -> Result<(ForwardOut, BackwardOut<S>)> {
        Ok(match self.method {
            NativeMethod::Baseline => baseline_forward_backward(p, &self.opts),
            NativeMethod::Chunked(_) | NativeMethod::Cce => {
                let opts = self.effective_opts(p.n, p.v);
                let fwd = cce_forward(p, &opts);
                let bwd = cce_backward(p, &opts, &fwd.lse);
                (fwd, bwd)
            }
        })
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> String {
        format!("native/{}", self.method.key(&self.opts))
    }

    fn forward(&self, p: &Problem) -> Result<ForwardOut> {
        self.forward_t(p)
    }

    fn forward_backward(&self, p: &Problem) -> Result<(ForwardOut, BackwardOut)> {
        self.forward_backward_t(p)
    }
}

// ------------------------------------------------------------- PJRT adapter

#[cfg(feature = "pjrt")]
pub use pjrt_adapter::PjrtBackend;

#[cfg(feature = "pjrt")]
mod pjrt_adapter {
    use anyhow::{anyhow, Result};

    use super::{Backend, BackwardOut, ForwardOut, Problem};
    use crate::exec::FilterStats;
    use crate::runtime::{HostTensor, Runtime};

    /// [`Backend`] adapter over the AOT artifact runtime: method keys map
    /// to `loss_fwd_{key}_{grid}` / `loss_fwdbwd_{key}_{grid}` artifacts.
    pub struct PjrtBackend<'rt> {
        pub rt: &'rt Runtime,
        pub key: String,
    }

    impl<'rt> PjrtBackend<'rt> {
        pub fn new(rt: &'rt Runtime, key: impl Into<String>) -> PjrtBackend<'rt> {
            PjrtBackend { rt, key: key.into() }
        }

        fn artifact(&self, kind: &str, p: &Problem) -> String {
            format!("loss_{kind}_{}_n{}_d{}_v{}", self.key, p.n, p.d, p.v)
        }

        fn tensors(p: &Problem) -> Result<Vec<HostTensor>> {
            Ok(vec![
                HostTensor::f32(vec![p.n, p.d], p.e.to_vec())?,
                HostTensor::f32(vec![p.v, p.d], p.c.to_vec())?,
                HostTensor::i32(vec![p.n], p.x.to_vec())?,
            ])
        }
    }

    impl Backend for PjrtBackend<'_> {
        fn name(&self) -> String {
            format!("pjrt/{}", self.key)
        }

        fn forward(&self, p: &Problem) -> Result<ForwardOut> {
            let out = self.rt.run(&self.artifact("fwd", p), &Self::tensors(p)?)?;
            let loss = out
                .first()
                .ok_or_else(|| anyhow!("loss artifact returned no outputs"))?
                .scalar()?;
            Ok(ForwardOut {
                loss,
                count: p.active_count(),
                lse: Vec::new(),
                target_logit: Vec::new(),
                workspace_bytes: 0,
            })
        }

        fn forward_backward(&self, p: &Problem) -> Result<(ForwardOut, BackwardOut)> {
            let out = self.rt.run(&self.artifact("fwdbwd", p), &Self::tensors(p)?)?;
            if out.len() < 3 {
                return Err(anyhow!(
                    "fwdbwd artifact returned {} outputs, want [loss, d_e, d_c]",
                    out.len()
                ));
            }
            let loss = out[0].scalar()?;
            let fwd = ForwardOut {
                loss,
                count: p.active_count(),
                lse: Vec::new(),
                target_logit: Vec::new(),
                workspace_bytes: 0,
            };
            let bwd = BackwardOut {
                d_e: out[1].as_f32()?.to_vec(),
                d_c: out[2].as_f32()?.to_vec(),
                stats: FilterStats::default(),
                workspace_bytes: 0,
            };
            Ok((fwd, bwd))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::random_problem;
    use crate::util::rng::Rng;

    #[test]
    fn from_key_maps_methods() {
        let o = KernelOptions::default();
        assert_eq!(NativeBackend::from_key("baseline", o).unwrap().method, NativeMethod::Baseline);
        assert_eq!(
            NativeBackend::from_key("chunked8", o).unwrap().method,
            NativeMethod::Chunked(8)
        );
        let cce = NativeBackend::from_key("cce", o).unwrap();
        assert!(cce.opts.filter && cce.opts.sort);
        let nf = NativeBackend::from_key("cce_no_filter", o).unwrap();
        assert!(!nf.opts.filter);
        let ns = NativeBackend::from_key("cce_no_sort", o).unwrap();
        assert!(ns.opts.filter && !ns.opts.sort);
        let k = NativeBackend::from_key("cce_kahan", o).unwrap();
        assert!(k.opts.kahan && k.opts.filter && k.opts.sort && !k.opts.full_c && !k.opts.full_e);
        assert_eq!(k.name(), "native/cce_kahan");
        let kc = NativeBackend::from_key("cce_kahan_fullc", o).unwrap();
        assert!(kc.opts.kahan && kc.opts.full_c && !kc.opts.full_e);
        assert_eq!(kc.name(), "native/cce_kahan_fullc");
        let ke = NativeBackend::from_key("cce_kahan_fulle", o).unwrap();
        assert!(ke.opts.kahan && ke.opts.full_e && !ke.opts.full_c);
        assert_eq!(ke.name(), "native/cce_kahan_fulle");
        // A stray kahan flag in the caller's opts never leaks into a
        // non-kahan method key.
        let stray = KernelOptions { kahan: true, full_c: true, ..o };
        assert_eq!(NativeBackend::from_key("cce", stray).unwrap().name(), "native/cce");
        assert!(NativeBackend::from_key("fused", o).is_err());
        assert!(NativeBackend::from_key("liger", o).is_err());
        assert!(NativeBackend::from_key("chunked0", o).is_err());
    }

    #[test]
    fn all_native_methods_agree_on_loss_and_grads() {
        let mut rng = Rng::new(23);
        let (n, d, v) = (40, 10, 96);
        let (e, c, x) = random_problem(&mut rng, n, d, v, 0.15);
        let p = Problem::new(&e, &c, &x, n, d, v).unwrap();
        let opts = KernelOptions { n_block: 16, v_block: 32, ..KernelOptions::default() };
        let reference = NativeBackend::from_key("baseline", opts)
            .unwrap()
            .forward_backward(&p)
            .unwrap();
        for key in [
            "chunked8",
            "cce",
            "cce_no_filter",
            "cce_no_sort",
            "cce_kahan",
            "cce_kahan_fullc",
            "cce_kahan_fulle",
        ] {
            let be = NativeBackend::from_key(key, opts).unwrap();
            assert_eq!(be.name(), format!("native/{key}"));
            let fwd = be.forward(&p).unwrap();
            assert!(
                (fwd.loss - reference.0.loss).abs() < 1e-4,
                "{key} loss {} vs {}",
                fwd.loss,
                reference.0.loss
            );
            let (_, bwd) = be.forward_backward(&p).unwrap();
            let max_de = bwd
                .d_e
                .iter()
                .zip(&reference.1.d_e)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            // Near-uniform random softmax: nothing is sub-eps, so even the
            // filtered variants must agree to round-off.
            assert!(max_de < 1e-5, "{key} d_e diverges by {max_de}");
        }
    }

    #[test]
    fn chunked_blocking_follows_chunk_count() {
        let be = NativeBackend::from_key("chunked4", KernelOptions::default()).unwrap();
        let eff = be.effective_opts(100, 64);
        assert_eq!(eff.n_block, 25);
        assert_eq!(eff.v_block, 64);
        assert!(!eff.filter);
    }
}
