//! Persistent fork-join worker pool for the exec kernels.
//!
//! Before this module every kernel invocation paid per-*call* thread
//! orchestration: `cce_forward`, both backward phases, the baseline
//! references, and the inference sweeps each opened a `std::thread::scope`,
//! spawning and joining fresh OS threads per call.  At the bench grid that
//! overhead is noise; at the decode shape (N = micro-batch size, one kernel
//! call per emitted token) it *is* the latency.  This pool makes per-call
//! cost track FLOPs instead of thread churn:
//!
//! * **Persistent, condvar-parked workers.**  Worker threads are spawned
//!   once, park on a [`Condvar`], and wake only when a batch of tasks is
//!   queued.  No OS thread is created or destroyed on the kernel hot path.
//! * **Generation-counted fork-join.**  Each [`ThreadPool::run`] call is
//!   one fork-join generation: the caller enqueues its task batch, helps
//!   drain it (the calling thread always participates, so a pool with `W`
//!   workers gives `W + 1`-way parallelism), then blocks on the batch's
//!   completion barrier.  Independent callers (e.g. two serve batch
//!   workers) can run concurrent generations; their tasks interleave in the
//!   shared queue and complete independently.
//! * **Inline fast path.**  A batch of one task — every small-N decode
//!   step, where `span_rows` collapses the row spans to a single span —
//!   executes directly on the caller with no queue, no locks, and no
//!   wakeup.  Zero orchestration cost at the shape the serving path runs
//!   per token.
//! * **Panic propagation.**  A panicking task is caught on the worker,
//!   recorded in its generation's state, and re-raised on the *caller*
//!   after the barrier — the same observable behavior as the old
//!   `scope.spawn` + `join().expect(..)` sites, with no hang and no
//!   poisoned pool (workers survive and keep serving later generations).
//! * **Lazy sizing.**  The [`global`] pool starts with zero workers and
//!   grows on demand to the largest span count any kernel call has asked
//!   for (driven by `--threads` / available parallelism).  A process that
//!   only ever runs single-span work never spawns a thread.
//!
//! The pool is deliberately a process-wide singleton ([`global`]): kernel
//! calls arrive from trainer steps, serve batch workers, and bench loops
//! concurrently, and per-caller pools would oversubscribe the machine.
//! [`super::NativeBackend`] holds and reports it (`pool_workers` in `cce
//! info` and the BENCH metadata).  Correctness never depends on the pool's
//! size: task partitioning (and therefore every kernel's bitwise output) is
//! fixed by `KernelOptions::threads`, while the pool only bounds how many
//! spans make progress at once.

use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

/// A lifetime-erased unit of work (see the SAFETY argument in
/// [`ThreadPool::run`]).
type ErasedTask = Box<dyn FnOnce() + Send + 'static>;

/// Completion state of one `run` invocation (one fork-join generation).
struct Batch {
    /// Tasks not yet finished (completed or panicked).
    pending: Mutex<usize>,
    /// Signalled when `pending` reaches zero.
    done: Condvar,
    /// First captured panic payload, re-raised on the caller.
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

struct Queue {
    tasks: VecDeque<(Arc<Batch>, ErasedTask)>,
    shutdown: bool,
}

struct Shared {
    queue: Mutex<Queue>,
    /// Wakes parked workers when tasks arrive (or at shutdown).
    work: Condvar,
    /// Worker threads spawned and not yet exited (incremented at spawn
    /// time under the handles lock, decremented by the worker on exit) —
    /// observable race-free by the leak tests, and guaranteed zero once
    /// [`ThreadPool::drop`] returns.
    live: AtomicUsize,
}

/// The persistent fork-join pool.  See the module docs.
pub struct ThreadPool {
    shared: Arc<Shared>,
    handles: Mutex<Vec<JoinHandle<()>>>,
    workers: AtomicUsize,
    generations: AtomicU64,
    /// Mirror occupancy into the process-global metrics registry
    /// (`exec_pool_*` families).  Set only for the [`global`] pool so
    /// test-local pools never pollute the process gauges.
    observed: bool,
}

fn lock<T>(mutex: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    // Task panics are caught before they can poison anything, but stay
    // robust if a lock is ever poisoned by an unforeseen unwind.
    mutex.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

impl ThreadPool {
    /// Pool with `workers` pre-spawned worker threads.  The calling thread
    /// of [`ThreadPool::run`] always participates too, so total fork-join
    /// parallelism is `workers + 1`.
    pub fn new(workers: usize) -> ThreadPool {
        let pool = ThreadPool {
            shared: Arc::new(Shared {
                queue: Mutex::new(Queue { tasks: VecDeque::new(), shutdown: false }),
                work: Condvar::new(),
                live: AtomicUsize::new(0),
            }),
            handles: Mutex::new(Vec::new()),
            workers: AtomicUsize::new(0),
            generations: AtomicU64::new(0),
            observed: false,
        };
        pool.ensure_workers(workers);
        pool
    }

    /// Spawned worker threads (grows lazily, never shrinks).
    pub fn workers(&self) -> usize {
        self.workers.load(Ordering::Relaxed)
    }

    /// Worker threads currently alive (0 after drop — the leak invariant).
    pub fn live_workers(&self) -> usize {
        self.shared.live.load(Ordering::SeqCst)
    }

    /// Fork-join generations dispatched so far (inline fast-path runs are
    /// not generations — they touch no shared state).
    pub fn generations(&self) -> u64 {
        self.generations.load(Ordering::Relaxed)
    }

    /// Grow the pool to at least `target` workers.  Cheap when already
    /// large enough (one relaxed load).
    pub fn ensure_workers(&self, target: usize) {
        if self.workers.load(Ordering::Relaxed) >= target {
            return;
        }
        let mut handles = lock(&self.handles);
        for _ in handles.len()..target {
            // Counted at spawn, not at thread startup: `live` must already
            // reflect this worker when `ensure_workers` returns (the leak
            // tests read it without racing thread scheduling); the worker
            // only ever decrements it, on exit.
            self.shared.live.fetch_add(1, Ordering::SeqCst);
            let shared = self.shared.clone();
            handles.push(std::thread::spawn(move || worker_loop(&shared)));
        }
        self.workers.store(handles.len(), Ordering::Relaxed);
        if self.observed && crate::obs::enabled() {
            super::exec_obs().pool_workers.set(handles.len() as i64);
        }
    }

    /// Run `tasks` to completion and return their results in task order —
    /// the fork-join replacement for the old per-call `std::thread::scope`
    /// sites.  Tasks may borrow from the caller's stack (`F: FnOnce` with
    /// any lifetime): this method does not return until every task has
    /// finished.  If any task panicked, the first payload is re-raised
    /// here after *all* tasks completed (no hang, pool stays usable).
    pub fn run<T, F>(&self, tasks: Vec<F>) -> Vec<T>
    where
        T: Send,
        F: FnOnce() -> T + Send,
    {
        if tasks.len() <= 1 {
            // Inline fast path: a single span (every N=batch-size decode
            // step) never touches the queue, the condvars, or a worker.
            if self.observed && crate::obs::enabled() {
                super::exec_obs().pool_inline.inc();
            }
            return tasks.into_iter().map(|f| f()).collect();
        }
        self.ensure_workers(tasks.len() - 1);
        self.generations.fetch_add(1, Ordering::Relaxed);
        if self.observed && crate::obs::enabled() {
            super::exec_obs().pool_dispatch.inc();
        }
        let batch = Arc::new(Batch {
            pending: Mutex::new(tasks.len()),
            done: Condvar::new(),
            panic: Mutex::new(None),
        });
        let slots: Vec<Mutex<Option<T>>> = tasks.iter().map(|_| Mutex::new(None)).collect();
        {
            let mut queue = lock(&self.shared.queue);
            for (f, slot) in tasks.into_iter().zip(&slots) {
                let task: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                    let out = f();
                    *lock(slot) = Some(out);
                });
                // SAFETY: the erased box only changes the trait object's
                // lifetime bound.  This function does not return (or
                // unwind) before `batch.pending` reaches zero, i.e. before
                // every task has run to completion or been captured as a
                // panic on a worker — so everything the tasks borrow
                // (`slots`, the caller's stack) strictly outlives every
                // use of the erased closures.
                let task: ErasedTask = unsafe { std::mem::transmute(task) };
                queue.tasks.push_back((batch.clone(), task));
            }
        }
        self.shared.work.notify_all();
        // Fork: the caller participates, draining this generation's
        // still-queued tasks...
        loop {
            let unit = {
                let mut queue = lock(&self.shared.queue);
                let pos = queue.tasks.iter().position(|(owner, _)| Arc::ptr_eq(owner, &batch));
                pos.and_then(|i| queue.tasks.remove(i))
            };
            match unit {
                Some((owner, task)) => execute(&owner, task),
                None => break,
            }
        }
        // ...then join: wait for stragglers a worker picked up.
        let mut pending = lock(&batch.pending);
        while *pending > 0 {
            pending = batch.done.wait(pending).unwrap_or_else(|poisoned| poisoned.into_inner());
        }
        drop(pending);
        if let Some(payload) = lock(&batch.panic).take() {
            resume_unwind(payload);
        }
        slots
            .into_iter()
            .map(|slot| {
                slot.into_inner()
                    .unwrap_or_else(|poisoned| poisoned.into_inner())
                    .expect("completed task left no result")
            })
            .collect()
    }
}

impl Drop for ThreadPool {
    /// Joins every worker — constructing and dropping pools leaks nothing.
    fn drop(&mut self) {
        {
            let mut queue = lock(&self.shared.queue);
            queue.shutdown = true;
        }
        self.shared.work.notify_all();
        let mut handles = lock(&self.handles);
        for handle in handles.drain(..) {
            let _ = handle.join();
        }
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        let unit = {
            let mut queue = lock(&shared.queue);
            loop {
                if let Some(unit) = queue.tasks.pop_front() {
                    break Some(unit);
                }
                if queue.shutdown {
                    break None;
                }
                queue = shared.work.wait(queue).unwrap_or_else(|poisoned| poisoned.into_inner());
            }
        };
        match unit {
            Some((batch, task)) => execute(&batch, task),
            None => break,
        }
    }
    shared.live.fetch_sub(1, Ordering::SeqCst);
}

/// Run one task, capturing a panic into its generation, and count it done.
fn execute(batch: &Batch, task: ErasedTask) {
    if let Err(payload) = catch_unwind(AssertUnwindSafe(task)) {
        let mut first = lock(&batch.panic);
        if first.is_none() {
            *first = Some(payload);
        }
    }
    let mut pending = lock(&batch.pending);
    *pending -= 1;
    if *pending == 0 {
        batch.done.notify_all();
    }
}

/// The process-wide pool shared by every kernel, the trainer, and the
/// serving engine.  Created with zero workers on first use; grows on demand
/// (see [`ThreadPool::ensure_workers`]) and lives for the process.
pub fn global() -> &'static ThreadPool {
    static GLOBAL: OnceLock<ThreadPool> = OnceLock::new();
    GLOBAL.get_or_init(|| {
        let mut pool = ThreadPool::new(0);
        pool.observed = true;
        pool
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn results_come_back_in_task_order() {
        let pool = ThreadPool::new(3);
        let tasks: Vec<_> = (0..16).map(|i| move || i * 2).collect();
        assert_eq!(pool.run(tasks), (0..16).map(|i| i * 2).collect::<Vec<_>>());
        assert_eq!(pool.generations(), 1);
    }

    #[test]
    fn single_task_runs_inline_without_a_generation() {
        let pool = ThreadPool::new(0);
        let caller = std::thread::current().id();
        let out = pool.run(vec![move || std::thread::current().id() == caller]);
        assert_eq!(out, vec![true], "single task must run on the caller");
        assert_eq!(pool.generations(), 0, "inline fast path is not a generation");
        assert_eq!(pool.workers(), 0, "inline fast path must not spawn workers");
    }

    #[test]
    fn pool_grows_lazily_to_the_requested_span_count() {
        let pool = ThreadPool::new(0);
        let tasks: Vec<_> = (0..4).map(|i| move || i).collect();
        assert_eq!(pool.run(tasks), vec![0, 1, 2, 3]);
        assert_eq!(pool.workers(), 3, "4 tasks need 3 workers beside the caller");
        // A smaller batch never shrinks it; a larger one grows it.
        let _ = pool.run((0..2).map(|i| move || i).collect::<Vec<_>>());
        assert_eq!(pool.workers(), 3);
        let _ = pool.run((0..7).map(|i| move || i).collect::<Vec<_>>());
        assert_eq!(pool.workers(), 6);
    }

    #[test]
    fn worker_panic_propagates_cleanly_and_pool_survives() {
        let pool = ThreadPool::new(2);
        let result = catch_unwind(AssertUnwindSafe(|| {
            pool.run(
                (0..4)
                    .map(|i| {
                        move || {
                            if i == 2 {
                                panic!("task {i} exploded");
                            }
                            i
                        }
                    })
                    .collect::<Vec<_>>(),
            )
        }));
        assert!(result.is_err(), "panic must propagate to the caller, not hang");
        // The pool keeps serving after a panicking generation.
        let ok = pool.run((0..4).map(|i| move || i + 10).collect::<Vec<_>>());
        assert_eq!(ok, vec![10, 11, 12, 13]);
        assert_eq!(pool.live_workers(), pool.workers(), "no worker died to the panic");
    }

    #[test]
    fn inline_fast_path_panic_propagates_and_pool_survives() {
        // One task takes the inline path (no catch_unwind layer): the
        // panic must reach the caller raw, and the pool must stay usable
        // with no generation consumed and no workers spawned.
        let pool = ThreadPool::new(0);
        let result = catch_unwind(AssertUnwindSafe(|| {
            pool.run(vec![move || -> usize { panic!("inline task exploded") }])
        }));
        assert!(result.is_err(), "inline panic must propagate to the caller");
        let payload = result.unwrap_err();
        let msg = payload.downcast_ref::<&'static str>().copied().unwrap_or("");
        assert!(msg.contains("inline task exploded"), "payload intact, got {msg:?}");
        assert_eq!(pool.generations(), 0, "a panicked inline run is not a generation");
        assert_eq!(pool.workers(), 0, "inline fast path must not spawn workers");
        let ok = pool.run(vec![move || 41 + 1]);
        assert_eq!(ok, vec![42], "pool serves inline work after the panic");
    }

    #[test]
    fn drop_joins_every_worker() {
        let pool = ThreadPool::new(4);
        let shared = pool.shared.clone();
        let _ = pool.run((0..8).map(|i| move || i).collect::<Vec<_>>());
        assert_eq!(shared.live.load(Ordering::SeqCst), pool.workers());
        assert_eq!(pool.workers(), 7, "8 tasks grow the pool to 7 workers");
        drop(pool);
        assert_eq!(shared.live.load(Ordering::SeqCst), 0, "drop must join all workers");
    }

    #[test]
    fn concurrent_generations_from_independent_callers() {
        // Two caller threads (the serve-batcher shape) share one pool.
        let pool = ThreadPool::new(2);
        let hits = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for _ in 0..2 {
                let pool = &pool;
                let hits = &hits;
                scope.spawn(move || {
                    for round in 0..50 {
                        let tasks: Vec<_> = (0..3)
                            .map(|i| {
                                move || {
                                    hits.fetch_add(1, Ordering::Relaxed);
                                    round * 3 + i
                                }
                            })
                            .collect();
                        let out = pool.run(tasks);
                        assert_eq!(out, vec![round * 3, round * 3 + 1, round * 3 + 2]);
                    }
                });
            }
        });
        assert_eq!(hits.load(Ordering::Relaxed), 2 * 50 * 3);
    }

    #[test]
    fn tasks_may_borrow_caller_locals_mutably() {
        // The scoped contract the kernel call sites rely on: disjoint
        // &mut chunks of a caller-owned buffer.
        let pool = ThreadPool::new(2);
        let mut data = vec![0u64; 64];
        let tasks: Vec<_> = data
            .chunks_mut(16)
            .enumerate()
            .map(|(ti, chunk)| {
                move || {
                    for (k, slot) in chunk.iter_mut().enumerate() {
                        *slot = (ti * 16 + k) as u64;
                    }
                    ti
                }
            })
            .collect();
        assert_eq!(pool.run(tasks), vec![0, 1, 2, 3]);
        for (k, &val) in data.iter().enumerate() {
            assert_eq!(val, k as u64);
        }
    }
}
