//! Storage dtypes for parameters, activations, and gradients: a
//! dependency-free software `bfloat16` and the [`Store`] trait the native
//! kernels are generic over.
//!
//! The paper's headline memory numbers are measured under **bf16 mixed
//! precision**: parameters, activations, and gradients are *stored* in
//! bf16 (2 bytes) while every accumulation happens in f32/f64.  This
//! module gives the repo the same storage split:
//!
//! * [`BF16`] — IEEE bfloat16 as a `u16` bit pattern: the top 16 bits of
//!   an f32.  Widening ([`BF16::to_f32`]) is exact (a bit shift);
//!   narrowing ([`BF16::from_f32`]) rounds to nearest, ties to even, and
//!   is correct for subnormals (the encoding is linear across the
//!   f32→bf16 truncation, so carry propagation does the right thing),
//!   infinities (representable exactly, and RNE overflow rounds to
//!   infinity as IEEE requires), and NaN (quieted, sign preserved, never
//!   collapsed to infinity).
//! * [`Store`] — the element trait `Problem`/`BackwardOut` and the
//!   kernels are generic over.  Its `lanes_*` hooks route each hot-loop
//!   operation to the matching SIMD routine (widen-on-load fused into
//!   `dot`/`axpy` — the u16→f32 unpack happens in registers, never as a
//!   materialized f32 copy of the operand), so the bf16 path stays
//!   vectorized.  The hooks take a `Lanes` token that is crate-private,
//!   which seals the trait: only `f32` and [`BF16`] implement it.
//! * [`StoreDtype`] — the runtime tag (`--dtype f32|bf16`) the CLI,
//!   checkpoints, and bench metadata carry.
//! * [`ParamBuf`] — a dtype-tagged parameter buffer (the trainer's
//!   embedding/classifier tables and the serve engine's weights), so the
//!   coordination layer stays enum-dispatched while the kernels
//!   monomorphize.
//!
//! Accumulation is **never** done in bf16: the kernels stage partial sums
//! in f32 scratch (see `exec::backward`) and narrow once on store, which
//! is both the paper's setting and the only numerically sane option — a
//! bf16 accumulator truncates any addend below ~2^-8 of the running sum.

use std::borrow::Cow;

use anyhow::{bail, Result};

use super::simd::Lanes;

// ------------------------------------------------------------------- BF16

/// IEEE bfloat16: sign (1) + exponent (8) + mantissa (7), stored as the
/// raw bit pattern.  Same exponent range as f32, so no overflow/underflow
/// surprises on conversion — only mantissa rounding.
#[repr(transparent)]
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct BF16(pub u16);

impl BF16 {
    pub const ZERO: BF16 = BF16(0);

    /// Narrow an f32 with round-to-nearest-even.
    ///
    /// The bf16 encoding is the top half of the f32 encoding, so RNE is
    /// one add: `bits + 0x7FFF + lsb(upper)` rounds the low 16 bits away
    /// (the carry walks into the exponent exactly when rounding crosses a
    /// binade — or reaches infinity from the top of the finite range,
    /// which is the IEEE-correct overflow result).  NaNs are handled
    /// first: blind rounding could carry a small NaN payload up to the
    /// infinity encoding, so they are truncated and quieted instead.
    #[inline]
    pub fn from_f32(x: f32) -> BF16 {
        let bits = x.to_bits();
        if (bits & 0x7FFF_FFFF) > 0x7F80_0000 {
            // NaN: keep the sign, force a quiet payload bit.
            return BF16(((bits >> 16) as u16) | 0x0040);
        }
        let rounded = bits.wrapping_add(0x7FFF + ((bits >> 16) & 1));
        BF16((rounded >> 16) as u16)
    }

    /// Widen to f32 — exact for every bf16 value (subnormals, infinities,
    /// and NaNs included): the bit pattern is shifted into the f32 slot.
    #[inline]
    pub fn to_f32(self) -> f32 {
        f32::from_bits((self.0 as u32) << 16)
    }
}

// ------------------------------------------------------------- StoreDtype

/// Runtime storage-dtype tag: what `--dtype` selects, what checkpoints
/// record per tensor, and what the BENCH metadata stamps so perf/memory
/// baselines only compare like with like.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StoreDtype {
    F32,
    Bf16,
}

impl StoreDtype {
    pub fn parse(s: &str) -> Result<StoreDtype> {
        Ok(match s {
            "f32" | "float32" => StoreDtype::F32,
            "bf16" | "bfloat16" => StoreDtype::Bf16,
            other => bail!("unknown dtype {other:?} (f32|bf16)"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            StoreDtype::F32 => "f32",
            StoreDtype::Bf16 => "bf16",
        }
    }

    pub fn size_bytes(self) -> usize {
        match self {
            StoreDtype::F32 => 4,
            StoreDtype::Bf16 => 2,
        }
    }
}

// ------------------------------------------------------------------ Store

/// Element type of parameter / activation / gradient storage.  The native
/// kernels are generic over this; accumulation stays f32/f64 regardless.
///
/// Sealed: the `lanes_*` hooks name the crate-private SIMD token, so only
/// the two in-crate implementations (`f32`, [`BF16`]) can exist — which is
/// what lets every hook be `#[inline]`-trivial and the kernels
/// monomorphize to exactly the old f32 code when `S = f32` (bitwise
/// identical, including the FMA/rounding trees).
pub trait Store: Copy + Send + Sync + PartialEq + std::fmt::Debug + 'static {
    const ZERO: Self;
    const BYTES: usize;
    const DTYPE: StoreDtype;

    fn from_f32(x: f32) -> Self;
    fn to_f32(self) -> f32;

    /// `Σ a[i]·b[i]` with both operands widened on load.
    fn lanes_dot<L: Lanes>(lanes: L, a: &[Self], b: &[Self]) -> f32;
    /// `Σ a[i]·b[i]` with only `b` widened (f32 activations × stored
    /// classifier — the inference kernels' shape).
    fn lanes_dot_mixed<L: Lanes>(lanes: L, a: &[f32], b: &[Self]) -> f32;
    /// `y[i] += a·widen(x[i])` into an f32 accumulator.
    fn lanes_axpy_acc<L: Lanes>(lanes: L, y: &mut [f32], a: f32, x: &[Self]);
    /// Kahan-compensated [`Store::lanes_axpy_acc`] (compensation in `c`).
    fn lanes_axpy_kahan_acc<L: Lanes>(lanes: L, y: &mut [f32], c: &mut [f32], a: f32, x: &[Self]);
    /// `y[i] += widen(x[i])` (the bag-of-context reduction).
    fn lanes_add_acc<L: Lanes>(lanes: L, y: &mut [f32], x: &[Self]);
    /// `y[i] = narrow(widen(y[i]) + a·x[i])` — the SGD update on stored
    /// parameters (f32 math, one narrow on store).
    fn lanes_axpy_store<L: Lanes>(lanes: L, y: &mut [Self], a: f32, x: &[f32]);
    /// [`Store::lanes_axpy_store`] with the gradient *also* in storage
    /// dtype (widen-on-load) — the classifier update consumes `dC`
    /// directly, so no widened copy of a gradient ever exists.
    fn lanes_axpy_store_s<L: Lanes>(lanes: L, y: &mut [Self], a: f32, x: &[Self]);

    /// Narrow `src` into `dst` element-wise (RNE; identity for f32).
    fn narrow_into(dst: &mut [Self], src: &[f32]);
    /// Widen `src` into `dst` element-wise (exact).
    fn widen_into(dst: &mut [f32], src: &[Self]);

    /// Narrowed view: borrows for f32, allocates for bf16 — how f32
    /// activations take the storage dtype without a copy on the f32 path.
    fn narrow_cow(v: &[f32]) -> Cow<'_, [Self]>;

    fn widen_vec(v: &[Self]) -> Vec<f32> {
        v.iter().map(|&x| x.to_f32()).collect()
    }

    fn narrow_vec(v: &[f32]) -> Vec<Self> {
        v.iter().map(|&x| Self::from_f32(x)).collect()
    }
}

impl Store for f32 {
    const ZERO: f32 = 0.0;
    const BYTES: usize = 4;
    const DTYPE: StoreDtype = StoreDtype::F32;

    #[inline]
    fn from_f32(x: f32) -> f32 {
        x
    }

    #[inline]
    fn to_f32(self) -> f32 {
        self
    }

    #[inline]
    fn lanes_dot<L: Lanes>(lanes: L, a: &[f32], b: &[f32]) -> f32 {
        lanes.dot(a, b)
    }

    #[inline]
    fn lanes_dot_mixed<L: Lanes>(lanes: L, a: &[f32], b: &[f32]) -> f32 {
        lanes.dot(a, b)
    }

    #[inline]
    fn lanes_axpy_acc<L: Lanes>(lanes: L, y: &mut [f32], a: f32, x: &[f32]) {
        lanes.axpy(y, a, x);
    }

    #[inline]
    fn lanes_axpy_kahan_acc<L: Lanes>(lanes: L, y: &mut [f32], c: &mut [f32], a: f32, x: &[f32]) {
        lanes.axpy_kahan(y, c, a, x);
    }

    #[inline]
    fn lanes_add_acc<L: Lanes>(lanes: L, y: &mut [f32], x: &[f32]) {
        lanes.add_assign(y, x);
    }

    #[inline]
    fn lanes_axpy_store<L: Lanes>(lanes: L, y: &mut [f32], a: f32, x: &[f32]) {
        lanes.axpy(y, a, x);
    }

    #[inline]
    fn lanes_axpy_store_s<L: Lanes>(lanes: L, y: &mut [f32], a: f32, x: &[f32]) {
        lanes.axpy(y, a, x);
    }

    #[inline]
    fn narrow_into(dst: &mut [f32], src: &[f32]) {
        dst.copy_from_slice(src);
    }

    #[inline]
    fn widen_into(dst: &mut [f32], src: &[f32]) {
        dst.copy_from_slice(src);
    }

    fn narrow_cow(v: &[f32]) -> Cow<'_, [f32]> {
        Cow::Borrowed(v)
    }
}

impl Store for BF16 {
    const ZERO: BF16 = BF16::ZERO;
    const BYTES: usize = 2;
    const DTYPE: StoreDtype = StoreDtype::Bf16;

    #[inline]
    fn from_f32(x: f32) -> BF16 {
        BF16::from_f32(x)
    }

    #[inline]
    fn to_f32(self) -> f32 {
        BF16::to_f32(self)
    }

    #[inline]
    fn lanes_dot<L: Lanes>(lanes: L, a: &[BF16], b: &[BF16]) -> f32 {
        lanes.dot_bf16(a, b)
    }

    #[inline]
    fn lanes_dot_mixed<L: Lanes>(lanes: L, a: &[f32], b: &[BF16]) -> f32 {
        lanes.dot_f32_bf16(a, b)
    }

    #[inline]
    fn lanes_axpy_acc<L: Lanes>(lanes: L, y: &mut [f32], a: f32, x: &[BF16]) {
        lanes.axpy_bf16(y, a, x);
    }

    #[inline]
    fn lanes_axpy_kahan_acc<L: Lanes>(lanes: L, y: &mut [f32], c: &mut [f32], a: f32, x: &[BF16]) {
        lanes.axpy_kahan_bf16(y, c, a, x);
    }

    #[inline]
    fn lanes_add_acc<L: Lanes>(lanes: L, y: &mut [f32], x: &[BF16]) {
        lanes.axpy_bf16(y, 1.0, x);
    }

    #[inline]
    fn lanes_axpy_store<L: Lanes>(_lanes: L, y: &mut [BF16], a: f32, x: &[f32]) {
        // Cold path (one pass per optimizer step): widen, f32 FMA-free
        // update, RNE narrow.  Not worth an intrinsic routine.
        for (p, &g) in y.iter_mut().zip(x) {
            *p = BF16::from_f32(p.to_f32() + a * g);
        }
    }

    #[inline]
    fn lanes_axpy_store_s<L: Lanes>(_lanes: L, y: &mut [BF16], a: f32, x: &[BF16]) {
        for (p, &g) in y.iter_mut().zip(x) {
            *p = BF16::from_f32(p.to_f32() + a * g.to_f32());
        }
    }

    #[inline]
    fn narrow_into(dst: &mut [BF16], src: &[f32]) {
        for (d, &s) in dst.iter_mut().zip(src) {
            *d = BF16::from_f32(s);
        }
    }

    #[inline]
    fn widen_into(dst: &mut [f32], src: &[BF16]) {
        for (d, &s) in dst.iter_mut().zip(src) {
            *d = s.to_f32();
        }
    }

    fn narrow_cow(v: &[f32]) -> Cow<'_, [BF16]> {
        Cow::Owned(Self::narrow_vec(v))
    }
}

// --------------------------------------------------------------- ParamBuf

/// A dtype-tagged parameter buffer: the coordination layer (trainer,
/// serving engine, checkpoints) matches on this once per operation and
/// calls into the monomorphized generic kernels — enums at the boundary,
/// generics in the hot loops.
#[derive(Debug, Clone, PartialEq)]
pub enum ParamBuf {
    F32(Vec<f32>),
    Bf16(Vec<BF16>),
}

impl ParamBuf {
    pub fn from_f32_vec(v: Vec<f32>, dtype: StoreDtype) -> ParamBuf {
        match dtype {
            StoreDtype::F32 => ParamBuf::F32(v),
            StoreDtype::Bf16 => ParamBuf::Bf16(BF16::narrow_vec(&v)),
        }
    }

    pub fn to_f32_vec(&self) -> Vec<f32> {
        match self {
            ParamBuf::F32(v) => v.clone(),
            ParamBuf::Bf16(v) => BF16::widen_vec(v),
        }
    }

    /// Convert to `dtype` (clone when already there; up/down-convert
    /// otherwise — the checkpoint-load path).
    pub fn to_dtype(&self, dtype: StoreDtype) -> ParamBuf {
        match (self, dtype) {
            (ParamBuf::F32(v), StoreDtype::F32) => ParamBuf::F32(v.clone()),
            (ParamBuf::Bf16(v), StoreDtype::Bf16) => ParamBuf::Bf16(v.clone()),
            (_, dtype) => ParamBuf::from_f32_vec(self.to_f32_vec(), dtype),
        }
    }

    pub fn dtype(&self) -> StoreDtype {
        match self {
            ParamBuf::F32(_) => StoreDtype::F32,
            ParamBuf::Bf16(_) => StoreDtype::Bf16,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            ParamBuf::F32(v) => v.len(),
            ParamBuf::Bf16(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Storage footprint in bytes — the *measured* parameter memory.
    pub fn size_bytes(&self) -> usize {
        self.len() * self.dtype().size_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rt(x: f32) -> f32 {
        BF16::from_f32(x).to_f32()
    }

    #[test]
    fn widen_narrow_roundtrip_is_identity_for_all_bf16_values() {
        // Every non-NaN bf16 bit pattern survives widen -> narrow exactly
        // (widening is exact, and an exact value rounds to itself); NaNs
        // stay NaNs with the sign preserved.
        for bits in 0..=u16::MAX {
            let b = BF16(bits);
            let wide = b.to_f32();
            let back = BF16::from_f32(wide);
            if wide.is_nan() {
                assert!(back.to_f32().is_nan(), "{bits:04x} lost NaN-ness");
                assert_eq!(back.0 >> 15, bits >> 15, "{bits:04x} lost NaN sign");
            } else {
                assert_eq!(back.0, bits, "{bits:04x} changed under roundtrip");
            }
        }
    }

    #[test]
    fn narrow_rounds_to_nearest_even() {
        // 1.0 has bits 0x3F80_0000; the tie point of its bf16 ulp is at
        // low-half 0x8000.  Upper lsb 0 => tie rounds DOWN (to even)...
        assert_eq!(BF16::from_f32(f32::from_bits(0x3F80_8000)).0, 0x3F80);
        // ...just above the tie rounds up...
        assert_eq!(BF16::from_f32(f32::from_bits(0x3F80_8001)).0, 0x3F81);
        // ...and with upper lsb 1 the tie rounds UP (to even).
        assert_eq!(BF16::from_f32(f32::from_bits(0x3F81_8000)).0, 0x3F82);
        // Just below a tie always truncates.
        assert_eq!(BF16::from_f32(f32::from_bits(0x3F81_7FFF)).0, 0x3F81);
        // Carry across a binade: the top of the 1.x range rounds to 2.0.
        assert_eq!(rt(1.9999999f32), 2.0);
        // RNE error bound: |x - rt(x)| <= 2^-9 |x| for normal x.
        for &x in &[1.0f32, -3.14159, 1234.5678, 1e-3, -2.5e7, 0.3333] {
            let err = (x - rt(x)).abs();
            assert!(err <= x.abs() * 3.9e-3, "x={x} err={err}");
        }
    }

    #[test]
    fn narrow_handles_specials_and_subnormals() {
        assert_eq!(rt(f32::INFINITY), f32::INFINITY);
        assert_eq!(rt(f32::NEG_INFINITY), f32::NEG_INFINITY);
        assert!(rt(f32::NAN).is_nan());
        // A negative NaN stays a NaN (blind bit-rounding could carry its
        // payload into the -inf encoding).
        assert!(rt(f32::from_bits(0xFF80_0001)).is_nan());
        assert_eq!(rt(0.0).to_bits(), 0.0f32.to_bits());
        assert_eq!(rt(-0.0).to_bits(), (-0.0f32).to_bits());
        // f32 values beyond bf16's last finite step overflow to infinity
        // (IEEE RNE overflow), including f32::MAX.
        assert_eq!(rt(f32::MAX), f32::INFINITY);
        assert_eq!(rt(f32::MIN), f32::NEG_INFINITY);
        // Subnormals round within the subnormal range, not to garbage:
        // result must be one of the two neighbouring bf16 values.
        for &x in &[1e-40f32, 3.7e-39, f32::MIN_POSITIVE / 2.0, 1e-44] {
            let lo = f32::from_bits((x.to_bits() >> 16) << 16);
            let hi = f32::from_bits((((x.to_bits() >> 16) + 1) << 16).min(0x7F80_0000));
            let got = rt(x);
            assert!(got == lo || got == hi, "x={x:e} got={got:e} lo={lo:e} hi={hi:e}");
            assert!((got - x).abs() <= (hi - lo), "x={x:e} err too large");
        }
    }

    #[test]
    fn narrow_is_monotonic() {
        // RNE is monotonic; spot-check across sign, magnitude, binades.
        let mut rng = crate::util::rng::Rng::new(0xBF16);
        let mut vals: Vec<f32> = (0..4000).map(|_| (rng.normal() * 10.0) as f32).collect();
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for w in vals.windows(2) {
            assert!(rt(w[0]) <= rt(w[1]), "{} vs {}", w[0], w[1]);
        }
    }

    #[test]
    fn store_dtype_parse_and_meta() {
        assert_eq!(StoreDtype::parse("f32").unwrap(), StoreDtype::F32);
        assert_eq!(StoreDtype::parse("bfloat16").unwrap(), StoreDtype::Bf16);
        assert!(StoreDtype::parse("fp8").is_err());
        assert_eq!(StoreDtype::Bf16.name(), "bf16");
        assert_eq!(StoreDtype::Bf16.size_bytes(), 2);
        assert_eq!(<f32 as Store>::DTYPE, StoreDtype::F32);
        assert_eq!(<BF16 as Store>::BYTES, 2);
    }

    #[test]
    fn param_buf_conversions() {
        let v: Vec<f32> = vec![1.0, -2.5, 0.33333, 4096.0];
        let f = ParamBuf::from_f32_vec(v.clone(), StoreDtype::F32);
        let b = ParamBuf::from_f32_vec(v.clone(), StoreDtype::Bf16);
        assert_eq!(f.len(), 4);
        assert_eq!(f.size_bytes(), 16);
        assert_eq!(b.size_bytes(), 8, "bf16 params are half the footprint");
        assert_eq!(f.to_f32_vec(), v);
        for (orig, wide) in v.iter().zip(b.to_f32_vec()) {
            assert!((orig - wide).abs() <= orig.abs() * 3.9e-3, "{orig} vs {wide}");
        }
        // bf16 -> f32 -> bf16 is lossless (widening is exact).
        let back = b.to_dtype(StoreDtype::F32).to_dtype(StoreDtype::Bf16);
        assert_eq!(back, b);
        assert_eq!(b.to_dtype(StoreDtype::Bf16), b);
    }
}
