//! The `cce serve --supervise` parent: run the listener as a child
//! process, restart it on crash, and give up on crash loops.
//!
//! The supervisor is deliberately dumb plumbing — spawn, watch, restart —
//! because dumb plumbing is what the vocab-shard workers on the ROADMAP
//! will reuse: the same spawn/ready-handshake/heartbeat/drain cycle, one
//! child per shard instead of one listener.  What it guarantees:
//!
//! * **Crash recovery.**  A child that exits nonzero (a panic outside the
//!   batch boundary, an OOM kill, the `supervisor.child_crash` failpoint)
//!   is restarted with exponential backoff (`backoff × 2^k`, capped) plus
//!   deterministic jitter derived from the restart index — no shared-fate
//!   thundering herd when several supervised servers die together, and no
//!   RNG so incidents replay identically.
//! * **Crash-loop detection.**  `max_failures` failures inside `window`
//!   means restarting is not helping (bad checkpoint, port taken by
//!   another process, broken config): the supervisor stops and exits with
//!   the distinct [`CRASH_LOOP_EXIT`] code so orchestration above it can
//!   tell "gave up" from "crashed".
//! * **The ready contract.**  The child's `[serve] ready proto=… addr=…`
//!   stdout lines are *held back* until the child answers a live health
//!   probe (`GET /healthz` 200 when an HTTP listener is expected, a
//!   line-JSON `info` round-trip otherwise), then re-announced verbatim on
//!   the supervisor's stdout.  Scripts that sed the announce lines (ci.sh
//!   does) work unchanged, and never see an address that isn't serving
//!   yet.  After a restart the announce repeats with the child's new
//!   ports — consumers treat the *last* announce as current.
//! * **Drain forwarding.**  SIGTERM/SIGINT to the supervisor
//!   ([`crate::util::signal`]) forwards as SIGTERM to the child, whose own
//!   signal handler runs the PR 6 graceful drain.  `Child::kill` is
//!   SIGKILL and never used except when the drain grace expires.
//!
//! A failed *bind* after a crash (the old port lingering in TIME_WAIT —
//! std listeners don't set SO_REUSEADDR) surfaces as an immediate child
//! exit and takes the same backoff-and-retry path; by the next attempt
//! the port is normally free.  Supervised children see
//! `CCE_SUPERVISED=1` and `CCE_SUPERVISOR_RESTARTS=<n>` in their
//! environment, which seeds the `serve_supervisor_*` metric families so
//! the *child's* `/metrics` exposes its own lifecycle.

use std::collections::VecDeque;
use std::io::{BufRead, BufReader, Write};
use std::process::{Child, Command, Stdio};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::serve::client::Client;
use crate::serve::http::http_call;
use crate::serve::protocol::{Request, Response};
use crate::util::signal;

/// Exit code when the supervisor gives up on a crash loop — distinct from
/// any child exit code the supervisor passes through.
pub const CRASH_LOOP_EXIT: i32 = 86;

/// Poll cadence of every supervisor wait loop (ready handshake, serving
/// watch, backoff sleep): bounds signal-forwarding latency.
const POLL: Duration = Duration::from_millis(50);

/// Supervision knobs (`--supervise-*` flags map 1:1).
#[derive(Debug, Clone)]
pub struct SupervisorConfig {
    /// Give up (exit [`CRASH_LOOP_EXIT`]) after this many failures inside
    /// [`SupervisorConfig::window`].
    pub max_failures: usize,
    /// Crash-loop detection window.
    pub window: Duration,
    /// Base restart backoff; doubles per consecutive failure, capped at
    /// `base × 2^6`.
    pub backoff: Duration,
    /// How long a freshly spawned child may take to announce + pass its
    /// health probe before the supervisor counts it as a failure.
    pub ready_timeout: Duration,
    /// Grace between forwarding SIGTERM and escalating to SIGKILL.
    pub drain_grace: Duration,
}

impl Default for SupervisorConfig {
    fn default() -> SupervisorConfig {
        SupervisorConfig {
            max_failures: 5,
            window: Duration::from_secs(60),
            backoff: Duration::from_millis(200),
            ready_timeout: Duration::from_secs(30),
            drain_grace: Duration::from_secs(30),
        }
    }
}

/// Drop the `--supervise*` flags from an argv so the child runs the plain
/// serve path.  `--supervise` is a bare flag; the other `--supervise-*`
/// knobs each consume one value argument unless given as `--key=value`.
pub fn strip_supervise_flags(args: &[String]) -> Vec<String> {
    let mut out = Vec::with_capacity(args.len());
    let mut skip_value = false;
    for arg in args {
        if skip_value {
            skip_value = false;
            continue;
        }
        if arg == "--supervise" {
            continue;
        }
        if arg.starts_with("--supervise-") {
            skip_value = !arg.contains('=');
            continue;
        }
        out.push(arg.clone());
    }
    out
}

/// `[serve] ready proto=<p> addr=<a>` → `(proto, addr)`.
fn ready_proto_addr(line: &str) -> Option<(String, String)> {
    let rest = line.strip_prefix("[serve] ready ")?;
    let mut proto = None;
    let mut addr = None;
    for field in rest.split_whitespace() {
        if let Some(v) = field.strip_prefix("proto=") {
            proto = Some(v.to_string());
        } else if let Some(v) = field.strip_prefix("addr=") {
            addr = Some(v.to_string());
        }
    }
    Some((proto?, addr?))
}

/// Deterministic jitter for restart `n`: a splitmix64-style hash mapped
/// into `[0, half_ms]`.  No RNG — the same crash history replays the same
/// backoff schedule.
fn jitter_ms(restart: u64, half_ms: u64) -> u64 {
    if half_ms == 0 {
        return 0;
    }
    let mut z = restart.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    (z ^ (z >> 31)) % (half_ms + 1)
}

/// Backoff before restart `k` of a failure streak: `base × 2^min(k, 6)`
/// plus jitter in `[0, base/2]`.
fn backoff_delay(base: Duration, streak: usize, restart: u64) -> Duration {
    let base_ms = base.as_millis().min(u128::from(u32::MAX)) as u64;
    let scaled = base_ms.saturating_mul(1u64 << streak.min(6) as u32);
    Duration::from_millis(scaled + jitter_ms(restart, base_ms / 2))
}

/// What one child incarnation left behind.
enum ChildEnd {
    /// Exited by itself with this code (None = killed by signal).
    Exited(Option<i32>),
    /// We forwarded a drain request; the child exited with this code.
    Drained(Option<i32>),
    /// Never became ready inside the budget (killed by us).
    ReadyTimeout,
}

/// Run the supervision loop: spawn `child_args` as a child of the current
/// executable, hold its ready announce until health passes, restart on
/// crash, forward drain signals.  Returns the process exit code the
/// supervisor should exit with.
pub fn run(child_args: &[String], cfg: &SupervisorConfig) -> Result<i32> {
    if !signal::install() {
        eprintln!("[supervise] warning: no signal shim on this target; drain only via shutdown op");
    }
    let exe = std::env::current_exe().context("resolving current executable")?;
    let expect_http =
        child_args.iter().any(|a| a == "--http-addr" || a == "--metrics-addr");
    let mut restarts: u64 = 0;
    let mut failures: VecDeque<Instant> = VecDeque::new();
    loop {
        let mut child = Command::new(&exe)
            .args(child_args)
            .env("CCE_SUPERVISED", "1")
            .env("CCE_SUPERVISOR_RESTARTS", restarts.to_string())
            .stdout(Stdio::piped())
            .spawn()
            .context("spawning supervised child")?;
        let pid = child.id();
        if restarts > 0 {
            eprintln!("[supervise] restart #{restarts}: child pid {pid}");
        } else {
            eprintln!("[supervise] child pid {pid}");
        }
        let ready: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));
        let reader = child.stdout.take().map(|stdout| {
            let ready = ready.clone();
            std::thread::spawn(move || {
                for line in BufReader::new(stdout).lines() {
                    let Ok(line) = line else { break };
                    if line.starts_with("[serve] ready ") {
                        // Held back until the health probe passes.
                        match ready.lock() {
                            Ok(mut guard) => guard.push(line),
                            Err(poisoned) => poisoned.into_inner().push(line),
                        }
                    } else {
                        println!("{line}");
                        let _ = std::io::stdout().flush();
                    }
                }
            })
        });
        let end = watch_child(&mut child, &ready, expect_http, cfg);
        let _ = child.wait(); // reap if the watch path killed it
        if let Some(handle) = reader {
            let _ = handle.join();
        }
        match end {
            ChildEnd::Drained(code) => {
                eprintln!("[supervise] child drained and exited");
                return Ok(code.unwrap_or(0));
            }
            ChildEnd::Exited(Some(0)) => {
                // A clean exit (shutdown op, drained via its own signal
                // handler) ends supervision too.
                return Ok(0);
            }
            ChildEnd::Exited(code) => {
                eprintln!(
                    "[supervise] child exited {} — restarting",
                    code.map_or("on a signal".to_string(), |c| format!("with code {c}"))
                );
            }
            ChildEnd::ReadyTimeout => {
                eprintln!("[supervise] child never became ready — restarting");
            }
        }
        let now = Instant::now();
        failures.push_back(now);
        while failures.front().is_some_and(|t| now.duration_since(*t) > cfg.window) {
            failures.pop_front();
        }
        if failures.len() >= cfg.max_failures.max(1) {
            eprintln!(
                "[supervise] crash loop: {} failures within {:?}; giving up (exit {})",
                failures.len(),
                cfg.window,
                CRASH_LOOP_EXIT
            );
            return Ok(CRASH_LOOP_EXIT);
        }
        let delay = backoff_delay(cfg.backoff, failures.len() - 1, restarts);
        eprintln!("[supervise] backing off {delay:?} before restart");
        let until = Instant::now() + delay;
        while Instant::now() < until {
            if signal::drain_requested() {
                // Drain during backoff: nothing is running; just stop.
                return Ok(0);
            }
            std::thread::sleep(POLL.min(until.saturating_duration_since(Instant::now())));
        }
        restarts += 1;
    }
}

/// Drive one child incarnation: ready handshake (announce held until the
/// health probe passes), then watch until it exits or a drain signal
/// arrives.
fn watch_child(
    child: &mut Child,
    ready: &Mutex<Vec<String>>,
    expect_http: bool,
    cfg: &SupervisorConfig,
) -> ChildEnd {
    let expected_lines = 1 + usize::from(expect_http);
    let ready_deadline = Instant::now() + cfg.ready_timeout;
    let mut announced = false;
    let mut drain_sent = false;
    let mut drain_deadline = Instant::now(); // meaningful once drain_sent
    loop {
        match child.try_wait() {
            Ok(Some(status)) => {
                let code = status.code();
                return if drain_sent { ChildEnd::Drained(code) } else { ChildEnd::Exited(code) };
            }
            Ok(None) => {}
            Err(_) => return ChildEnd::Exited(None),
        }
        if signal::drain_requested() && !drain_sent {
            eprintln!("[supervise] drain requested; forwarding SIGTERM to child {}", child.id());
            if !signal::send(child.id(), signal::SIGTERM) {
                let _ = child.kill();
            }
            drain_sent = true;
            drain_deadline = Instant::now() + cfg.drain_grace;
        }
        if drain_sent && Instant::now() >= drain_deadline {
            eprintln!("[supervise] drain grace expired; killing child");
            let _ = child.kill();
            let code = child.wait().ok().and_then(|s| s.code());
            return ChildEnd::Drained(code);
        }
        if !announced {
            let lines: Vec<String> = match ready.lock() {
                Ok(guard) => guard.clone(),
                Err(poisoned) => poisoned.into_inner().clone(),
            };
            if lines.len() >= expected_lines && health_passes(&lines, expect_http) {
                // Re-announce verbatim: the ready contract, now true.
                for line in &lines {
                    println!("{line}");
                }
                let _ = std::io::stdout().flush();
                announced = true;
            } else if Instant::now() >= ready_deadline {
                let _ = child.kill();
                let _ = child.wait();
                return ChildEnd::ReadyTimeout;
            }
        }
        std::thread::sleep(POLL);
    }
}

/// One health probe against the child's announced addresses: `/healthz`
/// must answer 200 when an HTTP listener is expected, otherwise a
/// line-JSON `info` round-trip must succeed.
fn health_passes(ready_lines: &[String], expect_http: bool) -> bool {
    let addr_of = |proto: &str| {
        ready_lines
            .iter()
            .filter_map(|l| ready_proto_addr(l))
            .find(|(p, _)| p == proto)
            .map(|(_, a)| a)
    };
    if expect_http {
        let Some(addr) = addr_of("http") else { return false };
        return matches!(
            http_call(&addr, "GET", "/healthz", b"", Duration::from_secs(2)),
            Ok((200, _, _))
        );
    }
    let Some(addr) = addr_of("line") else { return false };
    let Ok(mut client) = Client::connect(addr.as_str()) else { return false };
    matches!(client.call(&Request::Info), Ok(Response::Info(_)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn supervise_flags_are_stripped_with_their_values() {
        let args: Vec<String> = [
            "serve",
            "--port",
            "0",
            "--supervise",
            "--supervise-max-failures",
            "3",
            "--http-addr",
            "127.0.0.1:0",
            "--supervise-backoff-ms",
            "10",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let child = strip_supervise_flags(&args);
        assert_eq!(child, ["serve", "--port", "0", "--http-addr", "127.0.0.1:0"]);

        // `--key=value` spellings carry their value inline: nothing after
        // them is swallowed.
        let args: Vec<String> =
            ["serve", "--supervise-window-ms=5000", "--demo", "--supervise", "--port", "0"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        assert_eq!(strip_supervise_flags(&args), ["serve", "--demo", "--port", "0"]);
    }

    #[test]
    fn ready_lines_parse_proto_and_addr() {
        assert_eq!(
            ready_proto_addr("[serve] ready proto=http addr=127.0.0.1:8080"),
            Some(("http".to_string(), "127.0.0.1:8080".to_string()))
        );
        assert_eq!(
            ready_proto_addr("[serve] ready proto=line addr=127.0.0.1:7343"),
            Some(("line".to_string(), "127.0.0.1:7343".to_string()))
        );
        assert_eq!(ready_proto_addr("[serve] shut down cleanly"), None);
        assert_eq!(ready_proto_addr("[serve] ready proto=line"), None);
    }

    #[test]
    fn backoff_doubles_caps_and_replays_deterministically() {
        let base = Duration::from_millis(100);
        let d0 = backoff_delay(base, 0, 0);
        let d1 = backoff_delay(base, 1, 1);
        let d6 = backoff_delay(base, 6, 6);
        let d9 = backoff_delay(base, 9, 9);
        assert!(d0 >= base && d0 <= base + Duration::from_millis(50), "{d0:?}");
        assert!(d1 >= 2 * base && d1 <= 2 * base + Duration::from_millis(50), "{d1:?}");
        // The exponent caps at 2^6 even for longer streaks.
        assert!(d6 >= 64 * base && d6 <= 64 * base + Duration::from_millis(50), "{d6:?}");
        assert!(d9 >= 64 * base && d9 <= 64 * base + Duration::from_millis(50), "{d9:?}");
        // Deterministic: the same (streak, restart) pair always lands on
        // the same delay.
        assert_eq!(backoff_delay(base, 3, 7), backoff_delay(base, 3, 7));
        assert_eq!(jitter_ms(5, 0), 0);
    }
}
