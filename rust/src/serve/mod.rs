//! `serve` — the logit-free inference subsystem.
//!
//! The paper's blocked online-LSE trick gives serving the same memory
//! property it gives training: per-token log-probabilities, argmax, top-k,
//! and temperature sampling all come out of one `(N_B, V_B)`-tiled sweep
//! over the classifier, so the `N×V` logit matrix never exists at
//! inference either (kernels: [`crate::exec::infer`]).  This module is the
//! system around those kernels:
//!
//! * [`engine`]   — checkpoint + tokenizer + kernels: lockstep batched
//!   decoding (greedy / top-k / temperature) and fused batch scoring, with
//!   peak-workspace accounting.
//! * [`batcher`]  — micro-batching scheduler: bounded queue (backpressure),
//!   batch assembly by deadline/size, `std::thread` workers, per-request
//!   response routing.
//! * [`protocol`] — line-delimited JSON over TCP (`generate` / `score` /
//!   `info` / `shutdown`), built on [`crate::util::json`].
//! * [`http`] / [`sse`] — dependency-free HTTP/1.1 framing and
//!   Server-Sent-Events streaming for the REST front door
//!   (`POST /v1/generate`, `POST /v1/score`, `GET /metrics`,
//!   `GET /healthz`), documented in `docs/http_api.md`.
//! * [`server`]   — `std::net::TcpListener` front end (line-JSON + HTTP on
//!   separate listeners, sharing one batcher); [`client`] — the matching
//!   blocking client.
//! * [`supervisor`] — the `--supervise` parent: spawn the listener as a
//!   child process, restart on crash with backoff + jitter, give up on
//!   crash loops, forward SIGTERM as a drain request.
//!
//! CLI: `cce serve --checkpoint runs/web/final.ckpt --port 7343`, then
//! `cce client --port 7343 --prompt "the"`.  `cce servebench` drives a
//! throughput/latency harness over the full stack
//! ([`crate::bench::serve`]).
//!
//! Failure semantics — structured [`ErrorCode`]s, per-request deadlines,
//! admission control with `retry_after_ms`, client [`RetryPolicy`], panic
//! isolation at the batch boundary, graceful drain — are documented in
//! `docs/serving.md` and exercised by `tests/chaos.rs` via
//! [`crate::util::faults`].

pub mod batcher;
pub mod client;
pub mod engine;
pub mod http;
pub mod protocol;
pub mod server;
pub mod sse;
pub mod supervisor;

pub use batcher::{BatchStats, Batcher, Job, StreamDelta, STREAM_CHANNEL_DEPTH};
pub use client::{Client, ClientConfig, ClientStats, RetryPolicy};
pub use engine::{CancelReason, CancelToken, ContextBag, Engine, GenOut, ScoreRes, StepCtl};
pub use protocol::{ErrorCode, GenParams, Request, Response};
pub use server::{serve, serve_multi, ServeConfig, Server};
pub use supervisor::{SupervisorConfig, CRASH_LOOP_EXIT};
