//! The inference engine: a [`NativeState`] checkpoint + BPE tokenizer +
//! the logit-free kernels of [`crate::exec::infer`], behind thread-safe
//! batch entry points the micro-batcher calls.
//!
//! The model is the trainer's bag-of-context head: the hidden state for a
//! context is the mean of its last `window` token embeddings, and the next
//! token distribution is `softmax(h · clsᵀ)`.  Decoding never materializes
//! an `N×V` logit matrix:
//!
//! * **generate** — requests decode in *lockstep*: each step builds one
//!   hidden row per active request and runs ONE blocked kernel over the
//!   whole batch (top-k heap for greedy/top-k rows, online Gumbel-max for
//!   full-vocabulary sampling rows), so micro-batching reaches the kernel,
//!   not just the queue.  The per-request hidden mean is an **O(D)
//!   incremental [`ContextBag`]** — add the emitted token's embedding,
//!   evict the one leaving the window — not an O(window·D) re-reduction
//!   per step.
//! * **score** — all texts of a batch concatenate into a single
//!   teacher-forced [`exec::score`] problem, then split per request.
//!
//! The engine tracks its peak kernel + hidden-buffer working set
//! (`peak_workspace_bytes`), which `tests/serve.rs` pins to the
//! `O(N·D + threads·N_B·V_B)` bound.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::coordinator::{
    CorpusKind, Metrics, NativeModelConfig, NativeState, NativeTrainer, RunConfig,
};
use crate::exec::{self, InferProblem, KernelOptions, ParamBuf, Problem, Store, StoreDtype};
use crate::serve::protocol::GenParams;
use crate::tokenizer::{Tokenizer, BOS, EOS};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// One generation result.
#[derive(Debug, Clone)]
pub struct GenOut {
    /// Generated token ids (EOS included when the model emitted it).
    pub tokens: Vec<i32>,
    /// Full-softmax (T=1) log-probability of each generated token.
    pub logprobs: Vec<f32>,
    /// Decoded text (specials dropped).
    pub text: String,
    /// `Some(reason)` when the decode stopped early at a lockstep step
    /// boundary (client disconnect or mid-decode deadline); the fields
    /// above hold everything decoded up to that step.
    pub cancelled: Option<CancelReason>,
}

/// Why a cooperative cancel fired (feeds `serve_cancelled_*_total`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CancelReason {
    /// The client went away (SSE write error) — nobody will read the rest.
    Disconnect,
    /// `deadline_ms` expired while decoding — the caller has given up.
    Deadline,
}

/// Shared cancel flag for one in-flight request: the serving layer sets
/// it (dead SSE client), the engine polls it at every lockstep decode-step
/// boundary.  Clone freely — all clones observe the same flag.
#[derive(Debug, Clone, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    pub fn new() -> CancelToken {
        CancelToken::default()
    }

    /// Request cancellation (idempotent, any thread).
    pub fn cancel(&self) {
        self.0.store(true, Ordering::SeqCst);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::SeqCst)
    }
}

/// Per-request step control for [`Engine::generate_batch_ctl`]: both
/// fields optional, both checked once per lockstep decode step.  The
/// `engine.cancel_ignore` failpoint disables the checks (a simulated
/// non-cooperative engine, for the chaos suite).
#[derive(Debug, Clone, Default)]
pub struct StepCtl {
    pub cancel: Option<CancelToken>,
    /// Absolute deadline (same instant the batcher uses for queued
    /// shedding) — enforced mid-decode here.
    pub deadline: Option<Instant>,
}

/// O(D) incremental bag-of-context state for lockstep decoding: the
/// running *sum* of the last `window` token embeddings, rolled forward per
/// emitted token (add the entering embedding, subtract the one leaving the
/// window) instead of re-reducing the whole window each step — the
/// KV-cache analogue of the bag-of-context head (ROADMAP's serve
/// follow-up).
///
/// The accumulator is f64 per dimension, so long decodes stay within f32
/// round-off of the full re-reduction (`tests/serve.rs` pins the equality
/// over multi-thousand-step add/evict streams); [`ContextBag::mean_into`]
/// rounds to f32 once at read time.
#[derive(Debug, Clone)]
pub struct ContextBag {
    sum: Vec<f64>,
    window: usize,
    len: usize,
}

impl ContextBag {
    pub fn new(d: usize, window: usize) -> ContextBag {
        ContextBag { sum: vec![0.0; d], window: window.max(1), len: 0 }
    }

    /// Roll the window forward by one token: `enter` is the embedding row
    /// entering the window; `evict` is the row of the token sliding out,
    /// which the caller must pass exactly when the context already holds
    /// `window` tokens (the caller owns the context and knows which).
    /// Generic over the embedding storage dtype (bf16 rows widen exactly
    /// into the f64 accumulator).
    pub fn push<S: Store>(&mut self, enter: &[S], evict: Option<&[S]>) {
        match evict {
            Some(gone) => {
                debug_assert_eq!(self.len, self.window, "evict implies a full window");
                for ((acc, &add), &sub) in self.sum.iter_mut().zip(enter).zip(gone) {
                    *acc += add.to_f32() as f64 - sub.to_f32() as f64;
                }
            }
            None => {
                debug_assert!(self.len < self.window, "full window needs an evict row");
                for (acc, &add) in self.sum.iter_mut().zip(enter) {
                    *acc += add.to_f32() as f64;
                }
                self.len += 1;
            }
        }
    }

    /// Write the mean over the current window into `out` (length `d`).
    pub fn mean_into(&self, out: &mut [f32]) {
        let inv = 1.0 / self.len.max(1) as f64;
        for (slot, &acc) in out.iter_mut().zip(&self.sum) {
            *slot = (acc * inv) as f32;
        }
    }

    /// Tokens currently in the window (`<= window`).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

/// One scoring result.
#[derive(Debug, Clone)]
pub struct ScoreRes {
    /// Mean NLL over the text's next-token predictions.
    pub nll: f64,
    pub perplexity: f64,
    /// Number of scored (next-token) positions.
    pub count: usize,
    /// Per-position `log p(token_{i+1} | tokens_{..=i})`.
    pub logprobs: Vec<f32>,
}

/// The serving engine.  All entry points take `&self`; the engine is shared
/// across batcher workers behind an `Arc`.
pub struct Engine {
    state: NativeState,
    tokenizer: Tokenizer,
    pub vocab: usize,
    pub d_model: usize,
    pub window: usize,
    pub opts: KernelOptions,
    /// Vocabulary-shard fleet: when attached, the classifier sweeps
    /// (top-k, sampling, scoring) run on the shard workers and merge at
    /// the coordinator; the embedding/bag side stays local.  A worker
    /// failure surfaces as a per-request `internal` error through the
    /// same `Result` path as any kernel error — never a hang (the
    /// transports carry deadlines).
    fleet: Option<std::sync::Arc<crate::shard::Fleet>>,
    /// Hard per-request cap on generated tokens.
    pub max_gen_tokens: usize,
    /// Hard per-request cap on scored positions — without it a single huge
    /// `score` text would allocate an unbounded `N×D` hidden buffer before
    /// any blocked kernel runs, voiding the workspace guarantee.
    pub max_score_tokens: usize,
    peak_workspace: AtomicU64,
    served: AtomicU64,
}

impl Engine {
    /// Wrap a state + tokenizer, validating shapes.  The engine serves in
    /// the state's storage dtype (`opts.dtype` is synced to it, so
    /// `info_json` reports the truth).
    pub fn new(
        state: NativeState,
        tokenizer: Tokenizer,
        d_model: usize,
        window: usize,
        opts: KernelOptions,
    ) -> Result<Engine> {
        let vocab = tokenizer.vocab_size();
        if d_model == 0 || window == 0 {
            bail!("d_model and window must be positive");
        }
        if state.emb.len() != vocab * d_model || state.cls.len() != vocab * d_model {
            bail!(
                "state shapes ({} emb, {} cls) do not match vocab {vocab} x d {d_model}",
                state.emb.len(),
                state.cls.len()
            );
        }
        if state.emb.dtype() != state.cls.dtype() {
            bail!("state mixes storage dtypes (emb vs cls)");
        }
        let opts = KernelOptions { dtype: state.dtype(), ..opts };
        Ok(Engine {
            state,
            tokenizer,
            vocab,
            d_model,
            window,
            opts,
            fleet: None,
            max_gen_tokens: 256,
            max_score_tokens: 4096,
            peak_workspace: AtomicU64::new(0),
            served: AtomicU64::new(0),
        })
    }

    /// Route classifier sweeps through a vocabulary-shard fleet.  Ships
    /// the engine's classifier to the workers immediately; call before
    /// serving starts.
    pub fn attach_fleet(&mut self, fleet: std::sync::Arc<crate::shard::Fleet>) -> Result<()> {
        if fleet.vocab() != self.vocab || fleet.dim() != self.d_model {
            bail!(
                "fleet shape {}×{} does not match model vocab {} × d {}",
                fleet.vocab(),
                fleet.dim(),
                self.vocab,
                self.d_model
            );
        }
        fleet.load(&self.state.cls, &self.opts)?;
        self.fleet = Some(fleet);
        Ok(())
    }

    /// Attached shard count (`0` = single-process).
    pub fn shard_count(&self) -> usize {
        self.fleet.as_ref().map(|f| f.shard_count()).unwrap_or(0)
    }

    /// Open a `cce train --backend native` checkpoint (+ its `.vocab.json`
    /// / `.model.json` siblings).  `(vocab, d)` come from the tensors and
    /// `window` from the model sidecar; `window_override` (an explicit
    /// `--window` flag) wins over both, and pre-sidecar checkpoints fall
    /// back to the trainer default.  The engine serves in the checkpoint's
    /// stored dtype — a bf16 checkpoint decodes at half the parameter
    /// footprint — unless `dtype_override` (an explicit `--dtype` flag)
    /// asks for a load-time conversion.
    pub fn from_checkpoint(
        path: &std::path::Path,
        window_override: Option<usize>,
        dtype_override: Option<StoreDtype>,
        opts: KernelOptions,
    ) -> Result<Engine> {
        let bundle = NativeState::load_bundle(path)?;
        let window = window_override
            .or(bundle.window)
            .unwrap_or(NativeModelConfig::default().window);
        let mut state = bundle.state;
        if let Some(want) = dtype_override {
            state = state.into_dtype(want);
        }
        Engine::new(state, bundle.tokenizer, bundle.d_model, window, opts)
    }

    /// Self-contained demo engine: build the trainer pipeline on the
    /// synthetic web corpus and (optionally) train a few steps — no
    /// artifacts, no files.  Used by `cce serve --demo`, the benches, and
    /// the integration tests.
    pub fn demo(
        vocab_size: usize,
        d_model: usize,
        steps: u64,
        opts: KernelOptions,
    ) -> Result<Engine> {
        let cfg = RunConfig {
            tag: "serve-demo".into(),
            method: "cce".into(),
            steps: steps.max(1),
            seed: 7,
            corpus: CorpusKind::Web,
            corpus_docs: 160,
            vocab_size,
            eval_every: 0,
            checkpoint_every: 0,
            log_every: u64::MAX,
            out_dir: std::env::temp_dir().join("cce_serve_demo").to_string_lossy().into(),
        };
        let model = NativeModelConfig { d_model, window: 4, lr: 0.5, batch: 4, seq_len: 64 };
        let trainer = NativeTrainer::build(cfg, model, opts)?;
        let mut state = trainer.init(7);
        if steps > 0 {
            let mut metrics = Metrics::in_memory();
            state = trainer.train(state, &mut metrics)?;
        }
        Engine::new(state, trainer.tokenizer.clone(), d_model, model.window, opts)
    }

    pub fn step(&self) -> u64 {
        self.state.step
    }

    /// Storage dtype the engine serves in (from the loaded state).
    pub fn dtype(&self) -> StoreDtype {
        self.state.dtype()
    }

    /// Measured parameter footprint (emb + cls) in bytes.
    pub fn param_bytes(&self) -> usize {
        self.state.param_bytes()
    }

    pub fn peak_workspace_bytes(&self) -> u64 {
        self.peak_workspace.load(Ordering::Relaxed)
    }

    pub fn served(&self) -> u64 {
        self.served.load(Ordering::Relaxed)
    }

    /// Model half of the `info` endpoint.
    pub fn info_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::str("bag-of-context")),
            ("vocab", Json::Int(self.vocab as i64)),
            ("d_model", Json::Int(self.d_model as i64)),
            ("window", Json::Int(self.window as i64)),
            ("step", Json::Int(self.state.step as i64)),
            ("dtype", Json::str(self.dtype().name())),
            ("param_bytes", Json::Int(self.param_bytes() as i64)),
            // Resolved worker count (`--threads 0` = auto) plus the shared
            // kernel pool's state — the orchestration-overhead triage trio.
            ("threads", Json::Int(self.opts.resolved_threads() as i64)),
            ("pool_workers", Json::Int(exec::pool_workers() as i64)),
            ("simd", Json::str(exec::simd_dispatch())),
            ("n_block", Json::Int(self.opts.n_block as i64)),
            ("v_block", Json::Int(self.opts.v_block as i64)),
            // 0 = single-process; N = classifier sweeps run on N
            // vocabulary-shard workers (docs/sharding.md).
            ("shards", Json::Int(self.shard_count() as i64)),
            ("max_gen_tokens", Json::Int(self.max_gen_tokens as i64)),
            ("max_score_tokens", Json::Int(self.max_score_tokens as i64)),
            ("peak_workspace_bytes", Json::Int(self.peak_workspace_bytes() as i64)),
            ("served", Json::Int(self.served() as i64)),
        ])
    }

    /// Analytic upper bound on the working set a score request with `rows`
    /// next-token positions needs: the fused `N×D` f32 hidden buffer +
    /// targets, plus the blocked kernel's `threads·N_B·V_B` tile term —
    /// the O(N·D + threads·N_B·V_B) bound `tests/serve.rs` pins, priced
    /// per request so admission control (`--max-workspace-bytes`) can
    /// reject work that would void it *before* any allocation.
    pub fn score_workspace_bound(&self, rows: usize) -> u64 {
        let hidden = rows as u64 * self.d_model as u64 * 4;
        let targets = rows as u64 * 4;
        let tile = self.opts.resolved_threads() as u64
            * self.opts.n_block as u64
            * self.opts.v_block as u64
            * 4;
        hidden + targets + tile
    }

    fn note_workspace(&self, bytes: usize) {
        self.peak_workspace.fetch_max(bytes as u64, Ordering::Relaxed);
        // Mirror into the process-global registry so /metrics sees the
        // high-water mark without reaching into the engine.
        exec::note_workspace_peak(bytes as u64);
    }

    /// Roll `bag` forward by one token (dtype-dispatched embedding rows;
    /// `evict` names the token sliding out of the window, if any).
    fn bag_push(&self, bag: &mut ContextBag, enter: i32, evict: Option<i32>) {
        fn go<S: Store>(bag: &mut ContextBag, emb: &[S], d: usize, enter: i32, evict: Option<i32>) {
            let row = |t: i32| &emb[t as usize * d..(t as usize + 1) * d];
            bag.push(row(enter), evict.map(row));
        }
        match &self.state.emb {
            ParamBuf::F32(emb) => go(bag, emb, self.d_model, enter, evict),
            ParamBuf::Bf16(emb) => go(bag, emb, self.d_model, enter, evict),
        }
    }

    /// Hidden row for one context by full re-reduction: mean embedding of
    /// its last `window` tokens (same recurrence the trainer uses within a
    /// sequence).  The scoring path uses this; decoding rolls a
    /// [`ContextBag`] forward in O(D) instead.
    fn context_row(&self, ctx: &[i32], out: &mut [f32]) {
        fn go<S: Store>(emb: &[S], d: usize, window: usize, ctx: &[i32], out: &mut [f32]) {
            let lo = ctx.len().saturating_sub(window);
            let tail = &ctx[lo..];
            out.fill(0.0);
            for &tok in tail {
                let row = &emb[tok as usize * d..(tok as usize + 1) * d];
                for (acc, &val) in out.iter_mut().zip(row) {
                    *acc += val.to_f32();
                }
            }
            let len = tail.len().max(1) as f32;
            for val in out.iter_mut() {
                *val /= len;
            }
        }
        match &self.state.emb {
            ParamBuf::F32(emb) => go(emb, self.d_model, self.window, ctx, out),
            ParamBuf::Bf16(emb) => go(emb, self.d_model, self.window, ctx, out),
        }
    }

    /// Blocked top-k against the stored classifier (dtype-dispatched; the
    /// hidden rows stay f32, the classifier widens on load in the kernel).
    fn run_topk(&self, h: &[f32], rows: usize, k: usize) -> Result<exec::TopKOut> {
        if let Some(fleet) = &self.fleet {
            return fleet.topk(h, rows, k);
        }
        match &self.state.cls {
            ParamBuf::F32(c) => {
                exec::topk(&InferProblem::new(h, c, rows, self.d_model, self.vocab)?, &self.opts, k)
            }
            ParamBuf::Bf16(c) => {
                exec::topk(&InferProblem::new(h, c, rows, self.d_model, self.vocab)?, &self.opts, k)
            }
        }
    }

    /// Online Gumbel-max sampling against the stored classifier.
    fn run_sample(
        &self,
        h: &[f32],
        rows: usize,
        temperature: f32,
        seeds: &[u64],
    ) -> Result<exec::SampleOut> {
        if let Some(fleet) = &self.fleet {
            return fleet.sample(h, rows, temperature, seeds);
        }
        match &self.state.cls {
            ParamBuf::F32(c) => exec::sample(
                &InferProblem::new(h, c, rows, self.d_model, self.vocab)?,
                &self.opts,
                temperature,
                seeds,
            ),
            ParamBuf::Bf16(c) => exec::sample(
                &InferProblem::new(h, c, rows, self.d_model, self.vocab)?,
                &self.opts,
                temperature,
                seeds,
            ),
        }
    }

    /// Teacher-forced scoring: activations take the storage dtype (one
    /// narrowing pass for bf16 — the same mixed-precision convention as
    /// the trainer), so the fused score problem is storage-homogeneous.
    fn run_score(&self, h: &[f32], targets: &[i32]) -> Result<exec::ScoreOut> {
        fn go<S: Store>(
            h: &[f32],
            c: &[S],
            targets: &[i32],
            d: usize,
            v: usize,
            opts: &KernelOptions,
        ) -> Result<exec::ScoreOut> {
            let h_s = S::narrow_cow(h);
            let p = Problem::new(&h_s, c, targets, targets.len(), d, v)?;
            Ok(exec::score(&p, opts))
        }
        if let Some(fleet) = &self.fleet {
            // Workers narrow the broadcast f32 hidden rows to the storage
            // dtype themselves — the same convention as `go` below.
            return fleet.score(h, targets);
        }
        match &self.state.cls {
            ParamBuf::F32(c) => go(h, c, targets, self.d_model, self.vocab, &self.opts),
            ParamBuf::Bf16(c) => go(h, c, targets, self.d_model, self.vocab, &self.opts),
        }
    }

    /// Tokenize a request text into a decoding context: BOS + BPE ids.
    fn context_tokens(&self, text: &str) -> Vec<i32> {
        let mut ctx = vec![BOS];
        ctx.extend(self.tokenizer.encode(text));
        ctx
    }

    /// Decode one token id to its text piece (specials dropped) — the SSE
    /// per-event `"text"` field.
    pub fn decode_token(&self, token: i32) -> String {
        self.tokenizer.decode(&[token])
    }

    // ------------------------------------------------------------ generate

    /// Decode a batch of requests in lockstep.  Returns one result per
    /// request, in order.
    pub fn generate_batch(&self, reqs: &[GenParams]) -> Vec<Result<GenOut>> {
        self.generate_batch_with(reqs, &mut |_, _, _| {})
    }

    /// [`Engine::generate_batch`] with a per-token observer: after every
    /// lockstep kernel step, `on_token(slot_index, token, logprob)` fires
    /// once per token emitted that step, in request order.  This is the
    /// SSE streaming hook — the callback runs on the decode thread, so it
    /// must be cheap (the HTTP layer just forwards into a bounded
    /// channel).
    pub fn generate_batch_with(
        &self,
        reqs: &[GenParams],
        on_token: &mut dyn FnMut(usize, i32, f32),
    ) -> Vec<Result<GenOut>> {
        self.generate_batch_ctl(reqs, &[], on_token)
    }

    /// [`Engine::generate_batch_with`] plus per-request step control:
    /// `ctls[i]` (when present) carries a cancel token and/or an absolute
    /// deadline for request `i`, both checked at every lockstep decode-step
    /// boundary.  A fired control marks the slot done — its remaining steps
    /// are never decoded, the batch slot frees immediately, and the
    /// returned [`GenOut`] reports the partial output with
    /// [`GenOut::cancelled`] set.  Requests without a control entry decode
    /// to completion exactly as before.
    pub fn generate_batch_ctl(
        &self,
        reqs: &[GenParams],
        ctls: &[StepCtl],
        on_token: &mut dyn FnMut(usize, i32, f32),
    ) -> Vec<Result<GenOut>> {
        let mut slots: Vec<Slot> = reqs.iter().map(|p| self.open_slot(p)).collect();
        let mut streamed = vec![0usize; slots.len()];
        loop {
            // Chaos sites: a mid-decode panic exercises the batcher's
            // catch_unwind boundary; a stall simulates a slow kernel step.
            crate::util::faults::maybe_panic("engine.step.panic");
            crate::util::faults::stall("engine.step.stall_ms");
            // Cooperative cancellation: poll each live slot's control at
            // the step boundary — the only place a slot can stop early, so
            // a fired token costs at most one more kernel step.  The
            // `engine.cancel_ignore` failpoint simulates an engine that
            // never cooperates (chaos coverage for the old behavior).
            if !ctls.is_empty() && crate::util::faults::value("engine.cancel_ignore").is_none() {
                let now = Instant::now();
                for (i, slot) in slots.iter_mut().enumerate() {
                    if slot.done || slot.err.is_some() {
                        continue;
                    }
                    let Some(ctl) = ctls.get(i) else { continue };
                    if ctl.cancel.as_ref().is_some_and(|t| t.is_cancelled()) {
                        slot.done = true;
                        slot.cancelled = Some(CancelReason::Disconnect);
                    } else if ctl.deadline.is_some_and(|dl| now >= dl) {
                        slot.done = true;
                        slot.cancelled = Some(CancelReason::Deadline);
                    }
                }
            }
            let active: Vec<usize> = slots
                .iter()
                .enumerate()
                .filter(|(_, s)| s.err.is_none() && !s.done)
                .map(|(i, _)| i)
                .collect();
            if active.is_empty() {
                break;
            }
            // Partition by kernel: bounded top-k heap vs full-vocab Gumbel.
            let heap_rows: Vec<usize> = active
                .iter()
                .copied()
                .filter(|&i| slots[i].params.temperature == 0.0 || slots[i].params.top_k >= 1)
                .collect();
            let gumbel_rows: Vec<usize> =
                active.iter().copied().filter(|&i| !heap_rows.contains(&i)).collect();
            if !heap_rows.is_empty() {
                if let Err(err) = self.step_heap_rows(&mut slots, &heap_rows) {
                    for &i in &heap_rows {
                        slots[i].err = Some(format!("{err:#}"));
                    }
                }
            }
            if !gumbel_rows.is_empty() {
                if let Err(err) = self.step_gumbel_rows(&mut slots, &gumbel_rows) {
                    for &i in &gumbel_rows {
                        slots[i].err = Some(format!("{err:#}"));
                    }
                }
            }
            // Flush this step's newly emitted tokens to the observer while
            // the next kernel step is still ahead — the streaming path.
            for (i, slot) in slots.iter().enumerate() {
                if slot.err.is_some() {
                    continue;
                }
                while streamed[i] < slot.out_tokens.len() {
                    on_token(i, slot.out_tokens[streamed[i]], slot.out_logprobs[streamed[i]]);
                    streamed[i] += 1;
                }
            }
        }
        self.served.fetch_add(reqs.len() as u64, Ordering::Relaxed);
        slots
            .into_iter()
            .map(|s| match s.err {
                Some(msg) => Err(anyhow!("{msg}")),
                None => Ok(GenOut {
                    text: self.tokenizer.decode(&s.out_tokens),
                    tokens: s.out_tokens,
                    logprobs: s.out_logprobs,
                    cancelled: s.cancelled,
                }),
            })
            .collect()
    }

    fn open_slot<'a>(&self, params: &'a GenParams) -> Slot<'a> {
        let ctx = self.context_tokens(&params.prompt);
        let mut slot = Slot {
            params,
            budget: params.max_tokens.min(self.max_gen_tokens),
            bag: self.bag_of(&ctx),
            ctx,
            out_tokens: Vec::new(),
            out_logprobs: Vec::new(),
            rng: Rng::new(params.seed ^ 0x5E12_7E57),
            done: false,
            cancelled: None,
            err: None,
        };
        if !params.temperature.is_finite() || params.temperature < 0.0 {
            slot.err = Some(format!(
                "temperature must be finite and >= 0, got {}",
                params.temperature
            ));
        } else if params.top_k > self.vocab {
            slot.err = Some(format!("top_k {} exceeds vocab {}", params.top_k, self.vocab));
        } else if slot.budget == 0 {
            slot.done = true;
        }
        slot
    }

    /// Build the incremental bag state for a context: only the last
    /// `window` tokens contribute, so seed the sum from just those — one
    /// O(window·D) pass at slot open (independent of prompt length, and no
    /// pointless add/evict cancellation); every decode step afterwards is
    /// O(D).
    fn bag_of(&self, ctx: &[i32]) -> ContextBag {
        let mut bag = ContextBag::new(self.d_model, self.window);
        let lo = ctx.len().saturating_sub(self.window);
        for &tok in &ctx[lo..] {
            self.bag_push(&mut bag, tok, None);
        }
        bag
    }

    /// Emit one decoded token for `slot` and roll its O(D) bag state: the
    /// new token's embedding enters the window, the embedding of
    /// `ctx[len-1-window]` (if any) leaves it.
    fn advance(&self, slot: &mut Slot, token: i32, logprob: f32) {
        slot.emit(token, logprob);
        let entered = slot.ctx.len() - 1;
        let evict = entered.checked_sub(self.window).map(|lo| slot.ctx[lo]);
        self.bag_push(&mut slot.bag, token, evict);
    }

    /// Hidden-state matrix for the listed slots: one O(D) bag read per
    /// row — no window re-reduction on the decode path.
    fn hidden_for(&self, slots: &[Slot], rows: &[usize]) -> Vec<f32> {
        let d = self.d_model;
        let mut h = vec![0f32; rows.len() * d];
        for (r, &i) in rows.iter().enumerate() {
            slots[i].bag.mean_into(&mut h[r * d..(r + 1) * d]);
        }
        h
    }

    fn step_heap_rows(&self, slots: &mut [Slot], rows: &[usize]) -> Result<()> {
        let k_max = rows
            .iter()
            .map(|&i| {
                let p = slots[i].params;
                if p.temperature == 0.0 {
                    1
                } else {
                    p.top_k.clamp(1, self.vocab)
                }
            })
            .max()
            .unwrap_or(1);
        let h = self.hidden_for(slots, rows);
        let out = self.run_topk(&h, rows.len(), k_max)?;
        self.note_workspace(out.workspace_bytes + h.len() * 4);
        for (r, &i) in rows.iter().enumerate() {
            let slot = &mut slots[i];
            let row = &out.rows[r];
            let (token, logprob) = if slot.params.temperature == 0.0 {
                (row.tokens[0], row.logprobs[0])
            } else {
                let k = slot.params.top_k.clamp(1, self.vocab).min(row.tokens.len());
                let t_inv = 1.0 / slot.params.temperature as f64;
                // Renormalized softmax over the k candidates at temperature
                // T (constant shifts cancel; logprobs are already z − lse).
                let weights: Vec<f64> = row.logprobs[..k]
                    .iter()
                    .map(|&lp| ((lp - row.logprobs[0]) as f64 * t_inv).exp())
                    .collect();
                let total: f64 = weights.iter().sum();
                let mut u = slot.rng.f64() * total;
                let mut pick = k - 1;
                for (c, &w) in weights.iter().enumerate() {
                    if u < w {
                        pick = c;
                        break;
                    }
                    u -= w;
                }
                (row.tokens[pick], row.logprobs[pick])
            };
            self.advance(slot, token, logprob);
        }
        Ok(())
    }

    fn step_gumbel_rows(&self, slots: &mut [Slot], rows: &[usize]) -> Result<()> {
        // `exec::sample` takes one temperature per call; group rows that
        // share a temperature (bitwise, so grouping is exact).
        let mut groups: BTreeMap<u32, Vec<usize>> = BTreeMap::new();
        for &i in rows {
            groups.entry(slots[i].params.temperature.to_bits()).or_default().push(i);
        }
        for (t_bits, group) in groups {
            let temperature = f32::from_bits(t_bits);
            let h = self.hidden_for(slots, &group);
            let seeds: Vec<u64> = group.iter().map(|&i| slots[i].rng.next_u64()).collect();
            let out = self.run_sample(&h, group.len(), temperature, &seeds)?;
            self.note_workspace(out.workspace_bytes + h.len() * 4);
            for (r, &i) in group.iter().enumerate() {
                self.advance(&mut slots[i], out.tokens[r], out.logprobs[r]);
            }
        }
        Ok(())
    }

    // --------------------------------------------------------------- score

    /// Score a batch of texts: all rows concatenate into ONE blocked
    /// teacher-forced problem, then split per request.
    pub fn score_batch(&self, texts: &[String]) -> Vec<Result<ScoreRes>> {
        // Chaos sites mirroring generate_batch (see above).
        crate::util::faults::maybe_panic("engine.step.panic");
        crate::util::faults::stall("engine.step.stall_ms");
        // Per-text token streams and their row spans in the fused problem.
        let mut h_all: Vec<f32> = Vec::new();
        let mut targets: Vec<i32> = Vec::new();
        let mut spans: Vec<Result<(usize, usize), String>> = Vec::with_capacity(texts.len());
        let d = self.d_model;
        let too_large =
            |n: usize| format!("text too large to score: {n} > cap {}", self.max_score_tokens);
        for text in texts {
            // Byte pre-check before tokenizing (< 1 token per byte, so
            // bytes bound the row count from above).
            if text.len() > self.max_score_tokens.saturating_mul(8) {
                spans.push(Err(format!(
                    "text too large to score: {} bytes (cap {} tokens)",
                    text.len(),
                    self.max_score_tokens
                )));
                continue;
            }
            let tokens = self.context_tokens(text);
            if tokens.len() < 2 {
                spans.push(Err("text tokenizes to < 2 tokens; nothing to score".into()));
                continue;
            }
            if tokens.len() - 1 > self.max_score_tokens {
                spans.push(Err(too_large(tokens.len() - 1)));
                continue;
            }
            let rows = tokens.len() - 1;
            let start = targets.len();
            let mut row = vec![0f32; d];
            for i in 0..rows {
                self.context_row(&tokens[..=i], &mut row);
                h_all.extend_from_slice(&row);
                targets.push(tokens[i + 1]);
            }
            spans.push(Ok((start, rows)));
        }
        let scored = if targets.is_empty() {
            None
        } else {
            let run = || -> Result<exec::ScoreOut> {
                let out = self.run_score(&h_all, &targets)?;
                self.note_workspace(out.workspace_bytes + h_all.len() * 4);
                Ok(out)
            };
            Some(run())
        };
        self.served.fetch_add(texts.len() as u64, Ordering::Relaxed);
        spans
            .into_iter()
            .map(|span| match span {
                Err(msg) => Err(anyhow!("{msg}")),
                Ok((start, rows)) => match &scored {
                    Some(Ok(out)) => {
                        let lps = &out.logprobs[start..start + rows];
                        let nll = -(lps.iter().map(|&lp| lp as f64).sum::<f64>())
                            / rows as f64;
                        Ok(ScoreRes {
                            nll,
                            perplexity: nll.exp(),
                            count: rows,
                            logprobs: lps.to_vec(),
                        })
                    }
                    Some(Err(err)) => Err(anyhow!("{err:#}")),
                    None => unreachable!("spans exist only when targets exist"),
                },
            })
            .collect()
    }
}

/// Decoding state of one in-flight generate request.
struct Slot<'a> {
    params: &'a GenParams,
    budget: usize,
    ctx: Vec<i32>,
    /// O(D) running window mean (kept in lockstep with `ctx` by
    /// [`Engine::advance`]).
    bag: ContextBag,
    out_tokens: Vec<i32>,
    out_logprobs: Vec<f32>,
    rng: Rng,
    done: bool,
    /// Set when a step-boundary control stopped the decode early.
    cancelled: Option<CancelReason>,
    err: Option<String>,
}

impl Slot<'_> {
    fn emit(&mut self, token: i32, logprob: f32) {
        self.out_tokens.push(token);
        self.out_logprobs.push(logprob);
        self.ctx.push(token);
        if token == EOS || self.out_tokens.len() >= self.budget {
            self.done = true;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_engine() -> Engine {
        let opts =
            KernelOptions { n_block: 16, v_block: 64, threads: 2, ..KernelOptions::default() };
        Engine::demo(384, 24, 6, opts).unwrap()
    }

    #[test]
    fn greedy_generation_is_deterministic_and_batch_invariant() {
        let engine = tiny_engine();
        let req = GenParams { prompt: "the".into(), max_tokens: 6, ..GenParams::default() };
        let solo = engine.generate_batch(std::slice::from_ref(&req));
        let batch = engine.generate_batch(&[req.clone(), req.clone(), req.clone()]);
        let solo_out = solo[0].as_ref().unwrap();
        assert!(!solo_out.tokens.is_empty());
        assert!(solo_out.tokens.len() <= 6);
        for out in &batch {
            let out = out.as_ref().unwrap();
            assert_eq!(out.tokens, solo_out.tokens, "lockstep batching changed greedy output");
            assert_eq!(out.text, solo_out.text);
        }
        // Greedy logprobs are the max-probability tokens: all <= 0.
        assert!(solo_out.logprobs.iter().all(|&lp| lp <= 1e-6));
    }

    #[test]
    fn sampling_modes_and_validation() {
        let engine = tiny_engine();
        let mk = |top_k, temperature, seed| GenParams {
            prompt: "the cat".into(),
            max_tokens: 4,
            top_k,
            temperature,
            seed,
            ..GenParams::default()
        };
        let outs = engine.generate_batch(&[
            mk(0, 0.0, 0),  // greedy
            mk(4, 0.9, 1),  // top-k sampling
            mk(0, 1.0, 2),  // full-vocab Gumbel sampling
            mk(0, -1.0, 3), // invalid temperature
        ]);
        assert!(outs[0].is_ok() && outs[1].is_ok() && outs[2].is_ok());
        assert!(outs[3].is_err(), "negative temperature must be rejected");
        // Same seed => identical sampled output; different seed may differ.
        let a = engine.generate_batch(&[mk(0, 1.0, 9)]);
        let b = engine.generate_batch(&[mk(0, 1.0, 9)]);
        assert_eq!(
            a[0].as_ref().unwrap().tokens,
            b[0].as_ref().unwrap().tokens,
            "sampling must be reproducible from the seed"
        );
    }

    #[test]
    fn score_batch_splits_correctly() {
        let engine = tiny_engine();
        let texts = vec!["the cat sat on the mat".to_string(), "a dog".to_string()];
        let batch = engine.score_batch(&texts);
        let solo: Vec<_> = texts
            .iter()
            .map(|t| engine.score_batch(std::slice::from_ref(t)).remove(0).unwrap())
            .collect();
        for (b, s) in batch.iter().zip(&solo) {
            let b = b.as_ref().unwrap();
            assert_eq!(b.count, s.count);
            assert!((b.nll - s.nll).abs() < 1e-5, "{} vs {}", b.nll, s.nll);
            assert_eq!(b.logprobs.len(), s.logprobs.len());
        }
        assert!(solo[0].nll > 0.0 && solo[0].perplexity > 1.0);
        // Empty text has nothing to predict.
        let empty = engine.score_batch(&[String::new()]);
        assert!(empty[0].is_err());
        // Oversized text is rejected before any allocation, and does not
        // poison the rest of the batch.
        let huge = "word ".repeat(engine.max_score_tokens * 2);
        let mixed = engine.score_batch(&[huge, "the cat".to_string()]);
        let err = format!("{:#}", mixed[0].as_ref().err().expect("oversized must fail"));
        assert!(err.contains("too large"), "{err}");
        assert!(mixed[1].is_ok());
    }

    #[test]
    fn incremental_bag_tracks_full_rereduction_through_decode() {
        // Drive a real greedy decode through the engine internals and pin
        // the O(D) bag row against a from-scratch window re-reduction at
        // every step (the ROADMAP serve follow-up's correctness contract).
        let engine = tiny_engine();
        let params =
            GenParams { prompt: "the cat sat".into(), max_tokens: 24, ..GenParams::default() };
        let mut slots = vec![engine.open_slot(&params)];
        let d = engine.d_model;
        let mut inc = vec![0f32; d];
        let mut full = vec![0f32; d];
        for _ in 0..24 {
            if slots[0].done {
                break;
            }
            slots[0].bag.mean_into(&mut inc);
            engine.context_row(&slots[0].ctx, &mut full);
            for (a, b) in inc.iter().zip(&full) {
                assert!((a - b).abs() <= 1e-5, "bag {a} vs full {b}");
            }
            assert_eq!(slots[0].bag.len(), slots[0].ctx.len().min(engine.window));
            engine.step_heap_rows(&mut slots, &[0]).unwrap();
        }
        assert!(!slots[0].out_tokens.is_empty());
    }

    #[test]
    fn bf16_demo_engine_decodes_and_scores_at_half_footprint() {
        let f32_engine = tiny_engine();
        let opts = KernelOptions {
            n_block: 16,
            v_block: 64,
            threads: 2,
            dtype: StoreDtype::Bf16,
            ..KernelOptions::default()
        };
        let engine = Engine::demo(384, 24, 6, opts).unwrap();
        assert_eq!(engine.dtype(), StoreDtype::Bf16);
        assert_eq!(
            engine.param_bytes() * 2,
            f32_engine.param_bytes(),
            "bf16 weights must be half the f32 footprint"
        );
        // Greedy decode is deterministic and valid on the bf16 engine.
        let req = GenParams { prompt: "the cat".into(), max_tokens: 6, ..GenParams::default() };
        let a = engine.generate_batch(std::slice::from_ref(&req)).remove(0).unwrap();
        let b = engine.generate_batch(std::slice::from_ref(&req)).remove(0).unwrap();
        assert!(!a.tokens.is_empty());
        assert_eq!(a.tokens, b.tokens, "bf16 greedy decode must be deterministic");
        assert!(a.logprobs.iter().all(|&lp| lp <= 1e-6 && lp.is_finite()));
        // Scoring: finite NLL in the same ballpark as the f32 demo (the
        // two models trained with different storage rounding, so exact
        // equality is not expected — but both trained the same data).
        let text = "the cat sat on the mat".to_string();
        let bf = engine.score_batch(std::slice::from_ref(&text)).remove(0).unwrap();
        let ff = f32_engine.score_batch(&[text]).remove(0).unwrap();
        assert!(bf.nll.is_finite() && bf.nll > 0.0);
        assert!((bf.nll - ff.nll).abs() < 0.15 * ff.nll.abs().max(1.0), "{} vs {}", bf.nll, ff.nll);
        // info reports the dtype.
        let info = engine.info_json();
        assert_eq!(info.get("dtype").and_then(|v| v.as_str()), Some("bf16"));
    }

    #[test]
    fn streaming_observer_sees_every_token_in_order() {
        let engine = tiny_engine();
        let reqs = vec![
            GenParams { prompt: "the".into(), max_tokens: 5, ..GenParams::default() },
            GenParams { prompt: "a dog".into(), max_tokens: 3, ..GenParams::default() },
        ];
        let mut seen: Vec<Vec<(i32, f32)>> = vec![Vec::new(); reqs.len()];
        let outs = engine.generate_batch_with(&reqs, &mut |i, tok, lp| seen[i].push((tok, lp)));
        for (i, out) in outs.iter().enumerate() {
            let out = out.as_ref().unwrap();
            let streamed_tokens: Vec<i32> = seen[i].iter().map(|&(t, _)| t).collect();
            let streamed_lps: Vec<f32> = seen[i].iter().map(|&(_, lp)| lp).collect();
            assert_eq!(streamed_tokens, out.tokens, "stream {i} diverged from batch result");
            assert_eq!(streamed_lps, out.logprobs);
            // Each streamed piece decodes independently.
            for &t in &out.tokens {
                let _ = engine.decode_token(t);
            }
        }
        // The observer must not change the decode itself.
        let plain = engine.generate_batch(&reqs);
        assert_eq!(
            plain[0].as_ref().unwrap().tokens,
            outs[0].as_ref().unwrap().tokens,
            "observer changed greedy decode"
        );
    }

    #[test]
    fn cancel_token_stops_decode_at_the_next_step_boundary() {
        let engine = tiny_engine();
        let reqs =
            vec![GenParams { prompt: "the cat".into(), max_tokens: 32, ..GenParams::default() }];
        // Cancel from inside the per-token observer: fires between kernel
        // steps, so the decode must stop within one step of the signal —
        // deterministic proof, no timing involved.
        let token = CancelToken::new();
        let ctls = vec![StepCtl { cancel: Some(token.clone()), deadline: None }];
        let mut seen = 0usize;
        let outs = engine.generate_batch_ctl(&reqs, &ctls, &mut |_, _, _| {
            seen += 1;
            if seen == 1 {
                token.cancel();
            }
        });
        let out = outs[0].as_ref().unwrap();
        // Cancelled after the first emitted token: at most one more step
        // can decode before the boundary check fires.  (The model may
        // legitimately finish first by emitting EOS — accept that too.)
        let finished_naturally = out.tokens.last() == Some(&crate::tokenizer::EOS);
        assert!(
            out.cancelled == Some(CancelReason::Disconnect) || finished_naturally,
            "decode ran to completion past a cancelled token: {:?}",
            out.tokens
        );
        assert!(
            out.tokens.len() <= 2,
            "cancel after token 1 must stop within one step, got {} tokens",
            out.tokens.len()
        );
        assert_eq!(out.tokens.len(), out.logprobs.len());
        // A pre-cancelled slot never decodes a single token, and does not
        // disturb its batch neighbours.
        let pre = CancelToken::new();
        pre.cancel();
        let pair = vec![
            GenParams { prompt: "the".into(), max_tokens: 4, ..GenParams::default() },
            GenParams { prompt: "the".into(), max_tokens: 4, ..GenParams::default() },
        ];
        let ctls = vec![StepCtl { cancel: Some(pre), deadline: None }, StepCtl::default()];
        let outs = engine.generate_batch_ctl(&pair, &ctls, &mut |_, _, _| {});
        let a = outs[0].as_ref().unwrap();
        let b = outs[1].as_ref().unwrap();
        assert_eq!(a.cancelled, Some(CancelReason::Disconnect));
        assert!(a.tokens.is_empty());
        assert_eq!(a.text, "");
        assert!(b.cancelled.is_none());
        let solo = engine.generate_batch(&pair[1..]);
        assert_eq!(b.tokens, solo[0].as_ref().unwrap().tokens, "cancel leaked into neighbour");
    }

    #[test]
    fn expired_deadline_cancels_mid_decode() {
        let engine = tiny_engine();
        let reqs =
            vec![GenParams { prompt: "the cat".into(), max_tokens: 32, ..GenParams::default() }];
        // An already-expired deadline is caught at the very first step
        // boundary: zero tokens decoded, reason = Deadline.
        let ctls = vec![StepCtl {
            cancel: None,
            deadline: Some(Instant::now() - std::time::Duration::from_millis(1)),
        }];
        let outs = engine.generate_batch_ctl(&reqs, &ctls, &mut |_, _, _| {});
        let out = outs[0].as_ref().unwrap();
        assert_eq!(out.cancelled, Some(CancelReason::Deadline));
        assert!(out.tokens.is_empty(), "expired deadline still decoded {:?}", out.tokens);
        // A generous deadline never fires.
        let ctls = vec![StepCtl {
            cancel: None,
            deadline: Some(Instant::now() + std::time::Duration::from_secs(300)),
        }];
        let outs = engine.generate_batch_ctl(&reqs, &ctls, &mut |_, _, _| {});
        assert!(outs[0].as_ref().unwrap().cancelled.is_none());
        // A disconnect outranks a dead deadline only because it is checked
        // first — either way the slot stops; pin the precedence so the
        // counters stay stable.
        let both = CancelToken::new();
        both.cancel();
        let ctls = vec![StepCtl {
            cancel: Some(both),
            deadline: Some(Instant::now() - std::time::Duration::from_millis(1)),
        }];
        let outs = engine.generate_batch_ctl(&reqs, &ctls, &mut |_, _, _| {});
        assert_eq!(outs[0].as_ref().unwrap().cancelled, Some(CancelReason::Disconnect));
    }

    #[test]
    fn score_workspace_bound_prices_the_fused_problem() {
        let engine = tiny_engine();
        let tile = engine.opts.resolved_threads() as u64
            * engine.opts.n_block as u64
            * engine.opts.v_block as u64
            * 4;
        assert_eq!(engine.score_workspace_bound(0), tile, "zero rows = tile term only");
        let rows = 100u64;
        assert_eq!(
            engine.score_workspace_bound(rows as usize),
            rows * engine.d_model as u64 * 4 + rows * 4 + tile
        );
        // Monotone in rows — admission can binary-search a cap safely.
        assert!(engine.score_workspace_bound(200) > engine.score_workspace_bound(100));
    }

    #[test]
    fn max_tokens_zero_returns_empty() {
        let engine = tiny_engine();
        let out = engine
            .generate_batch(&[GenParams { max_tokens: 0, ..GenParams::default() }])
            .remove(0)
            .unwrap();
        assert!(out.tokens.is_empty());
        assert!(out.text.is_empty());
    }
}
