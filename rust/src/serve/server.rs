//! TCP front end: accept loop, per-connection line protocol, graceful
//! shutdown.
//!
//! Dependency-free: [`std::net::TcpListener`] + one thread per connection
//! reading newline-delimited JSON ([`super::protocol`]).  `generate` and
//! `score` go through the micro-batcher ([`super::batcher`]); `info` and
//! `shutdown` are answered inline.  Binding port 0 picks an ephemeral port
//! (the bound address is reported on [`Server::addr`]) — which is how the
//! CI smoke test and the integration tests avoid port collisions.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::serve::batcher::{Batcher, Job};
use crate::serve::engine::Engine;
use crate::serve::protocol::{Request, Response};
use crate::util::json::Json;

/// Server + batcher knobs (`cce serve` flags map 1:1).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub host: String,
    /// 0 = ephemeral.
    pub port: u16,
    /// Batch workers (kernel threads are a separate knob:
    /// [`crate::exec::KernelOptions::threads`]).
    pub workers: usize,
    /// Largest micro-batch.
    pub max_batch: usize,
    /// How long batch assembly waits for stragglers.
    pub max_wait: Duration,
    /// Bounded request-queue depth (backpressure threshold).
    pub queue_depth: usize,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            host: "127.0.0.1".into(),
            port: 0,
            workers: 2,
            max_batch: 8,
            max_wait: Duration::from_millis(3),
            queue_depth: 64,
        }
    }
}

/// A running server.  Dropping the handle does NOT stop it; call
/// [`Server::stop`] or send a `shutdown` request, then [`Server::join`].
pub struct Server {
    pub addr: SocketAddr,
    accept: Option<JoinHandle<()>>,
    batcher: Arc<Batcher>,
    stop: Arc<AtomicBool>,
}

/// Bind, spawn the batcher + accept loop, and return immediately.
pub fn serve(engine: Arc<Engine>, cfg: &ServeConfig) -> Result<Server> {
    let listener = TcpListener::bind((cfg.host.as_str(), cfg.port))
        .with_context(|| format!("binding {}:{}", cfg.host, cfg.port))?;
    let addr = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let batcher = Arc::new(Batcher::start(
        engine.clone(),
        cfg.workers,
        cfg.max_batch,
        cfg.max_wait,
        cfg.queue_depth,
    ));
    let accept = {
        let batcher = batcher.clone();
        let stop = stop.clone();
        std::thread::spawn(move || accept_loop(listener, addr, engine, batcher, stop))
    };
    Ok(Server { addr, accept: Some(accept), batcher, stop })
}

impl Server {
    /// Request shutdown from this process (equivalent to a client sending
    /// `{"op":"shutdown"}`).
    pub fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
        // Wake the blocking accept() so the loop observes the flag.
        let _ = TcpStream::connect(self.addr);
    }

    /// Wait for the accept loop to exit, then stop the batch workers.
    pub fn join(mut self) -> Result<()> {
        if let Some(handle) = self.accept.take() {
            handle.join().map_err(|_| anyhow::anyhow!("accept thread panicked"))?;
        }
        self.batcher.shutdown();
        Ok(())
    }
}

fn accept_loop(
    listener: TcpListener,
    addr: SocketAddr,
    engine: Arc<Engine>,
    batcher: Arc<Batcher>,
    stop: Arc<AtomicBool>,
) {
    for stream in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let stream = match stream {
            Ok(stream) => stream,
            Err(_) => continue,
        };
        let engine = engine.clone();
        let batcher = batcher.clone();
        let stop = stop.clone();
        // One thread per connection: connections are long-lived and few at
        // this substrate's scale; concurrency inside a connection comes
        // from the batcher, not from here.
        std::thread::spawn(move || connection(stream, addr, &engine, &batcher, &stop));
    }
}

/// Serve one connection until EOF, error, or shutdown.
fn connection(
    stream: TcpStream,
    addr: SocketAddr,
    engine: &Engine,
    batcher: &Batcher,
    stop: &AtomicBool,
) {
    let _ = stream.set_nodelay(true);
    let reader = match stream.try_clone() {
        Ok(clone) => BufReader::new(clone),
        Err(_) => return,
    };
    let mut writer = stream;
    for line in reader.lines() {
        let line = match line {
            Ok(line) => line,
            Err(_) => break,
        };
        if line.trim().is_empty() {
            continue;
        }
        let response = match Request::parse(&line) {
            Err(err) => Response::error(format!("bad request: {err:#}")),
            Ok(Request::Info) => Response::Info(info_fields(engine, batcher)),
            Ok(Request::Shutdown) => {
                let _ = write_line(&mut writer, &Response::Shutdown);
                stop.store(true, Ordering::SeqCst);
                let _ = TcpStream::connect(addr); // wake accept()
                return;
            }
            Ok(request) => dispatch(request, batcher, stop),
        };
        if write_line(&mut writer, &response).is_err() {
            break;
        }
    }
}

/// Route a batchable request through the micro-batcher and wait for its
/// response.
fn dispatch(request: Request, batcher: &Batcher, stop: &AtomicBool) -> Response {
    if stop.load(Ordering::SeqCst) {
        return Response::error("server is shutting down");
    }
    let (tx, rx) = mpsc::channel();
    match batcher.submit(Job { request, respond: tx }) {
        Err(_) => Response::error("queue full (backpressure): retry later"),
        Ok(()) => match rx.recv_timeout(Duration::from_secs(300)) {
            Ok(response) => response,
            // Sender dropped (shutdown raced the job) or server wedged.
            Err(_) => Response::error("request dropped: server shutting down or timed out"),
        },
    }
}

fn info_fields(engine: &Engine, batcher: &Batcher) -> Json {
    let stats = batcher.stats();
    let mut fields: Vec<(String, Json)> = match engine.info_json() {
        Json::Object(entries) => entries,
        other => vec![("model_info".into(), other)],
    };
    fields.push((
        "batches".into(),
        Json::Int(stats.batches.load(Ordering::Relaxed) as i64),
    ));
    fields.push((
        "batched_jobs".into(),
        Json::Int(stats.jobs.load(Ordering::Relaxed) as i64),
    ));
    fields.push((
        "max_batch_observed".into(),
        Json::Int(stats.max_batch.load(Ordering::Relaxed) as i64),
    ));
    Json::Object(fields)
}

fn write_line(writer: &mut TcpStream, response: &Response) -> std::io::Result<()> {
    let mut line = response.to_line();
    line.push('\n');
    writer.write_all(line.as_bytes())?;
    writer.flush()
}
