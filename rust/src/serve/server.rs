//! TCP front end: accept loops, per-connection protocol handling, graceful
//! shutdown.
//!
//! Two listeners share one batcher:
//!
//! * the **line-JSON** listener ([`std::net::TcpListener`] + one thread per
//!   connection reading newline-delimited JSON, [`super::protocol`]) — the
//!   original wire format, kept for back-compat and the lowest-overhead
//!   path for `cce client` / `cce servebench`;
//! * the **HTTP/1.1** listener ([`super::http`] framing) — the REST front
//!   door from the ROADMAP: `POST /v1/generate` (with `"stream":true`
//!   emitting one SSE event per token, [`super::sse`]), `POST /v1/score`,
//!   `GET /metrics` (Prometheus text exposition), and a drain-aware
//!   `GET /healthz`.  This folds the PR 7 standalone metrics exporter into
//!   the full API server; [`ServeConfig::metrics_addr`] survives as an
//!   alias for [`ServeConfig::http_addr`].
//!
//! Binding port 0 picks an ephemeral port (bound addresses are reported on
//! [`Server::addr`] / [`Server::http_addr`]) — which is how the CI smoke
//! test and the integration tests avoid port collisions.
//!
//! Failure domains (PR 6) apply to both protocols: connections poll the
//! socket with a short read timeout instead of blocking forever, so a
//! stalled client holds a thread for at most [`ServeConfig::idle_timeout`]
//! and shutdown never waits on a silent peer; writes are bounded too.
//! Errors carry structured codes ([`super::protocol::ErrorCode`]); the
//! HTTP layer translates them ([`super::http::status_for`]): a full queue
//! answers 429 with a live `Retry-After`, drain answers 503, a
//! queued-past-deadline request 504.  [`Server::join`] drains in-flight
//! work under [`ServeConfig::drain`] before stopping the workers; the HTTP
//! listener keeps answering `/healthz` 503 through the drain window and
//! stops last.
//!
//! Multi-model routing: [`serve_multi`] loads several checkpoints behind
//! one server.  The first entry is the default; requests pick an engine
//! with their `"model"` field (unknown tags are `invalid_request`).  All
//! models share the batcher's queue and admission control — the batcher
//! splits each batch into per-engine kernel sub-batches.

use std::io::{self, BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::obs::{self, Counter, Gauge, Histogram, Registry, StageTimings};
use crate::serve::batcher::{Batcher, Job, STREAM_CHANNEL_DEPTH};
use crate::serve::engine::{CancelToken, Engine};
use crate::serve::http::{self, Conn, HttpError, HttpRequest, Limits};
use crate::serve::protocol::{score_from_json, ErrorCode, GenParams, Request, Response};
use crate::serve::sse::SseWriter;
use crate::util::faults;
use crate::util::json::Json;

/// How often a connection thread wakes from a blocked read to check the
/// stop flag and the idle budget.
const READ_POLL: Duration = Duration::from_millis(200);

/// Bound on a single response write; a client that stops reading cannot
/// wedge its connection thread past this.
const WRITE_TIMEOUT: Duration = Duration::from_secs(10);

/// Accept-poll cadence of the HTTP listener.
const HTTP_ACCEPT_POLL: Duration = Duration::from_millis(50);

/// Server + batcher knobs (`cce serve` flags map 1:1).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub host: String,
    /// 0 = ephemeral.
    pub port: u16,
    /// Batch workers (kernel threads are a separate knob:
    /// [`crate::exec::KernelOptions::threads`]).
    pub workers: usize,
    /// Largest micro-batch.
    pub max_batch: usize,
    /// How long batch assembly waits for stragglers.
    pub max_wait: Duration,
    /// Bounded request-queue depth (backpressure threshold).
    pub queue_depth: usize,
    /// Hang up on a connection that sends no complete request for this
    /// long (slow-loris/stalled-client bound).
    pub idle_timeout: Duration,
    /// Graceful-shutdown budget: how long [`Server::join`] waits for
    /// in-flight jobs to finish before stopping the workers.
    pub drain: Duration,
    /// Legacy alias for [`ServeConfig::http_addr`] (PR 7 shipped the
    /// metrics exporter standalone; it is now one route of the full HTTP
    /// server).  Used only when `http_addr` is `None`.
    pub metrics_addr: Option<String>,
    /// Bind the HTTP/1.1 API listener here (`host:port`, port 0 =
    /// ephemeral): `POST /v1/generate`, `POST /v1/score`, `GET /metrics`,
    /// `GET /healthz`.  `None` = line-JSON only.
    pub http_addr: Option<String>,
    /// Sustained queue-delay threshold (ms of queue-wait EWMA) that
    /// engages brownout: generate requests get clamped (`degraded:true`)
    /// before admission control sheds with 429.  0 disables brownout.
    pub brownout_queue_ms: u64,
    /// Reject score requests whose fused-problem workspace bound
    /// ([`Engine::score_workspace_bound`]) exceeds this many bytes, before
    /// they ever queue.  0 disables the guard.
    pub max_workspace_bytes: u64,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            host: "127.0.0.1".into(),
            port: 0,
            workers: 2,
            max_batch: 8,
            max_wait: Duration::from_millis(3),
            queue_depth: 64,
            idle_timeout: Duration::from_secs(300),
            drain: Duration::from_secs(5),
            metrics_addr: None,
            http_addr: None,
            brownout_queue_ms: 0,
            max_workspace_bytes: 0,
        }
    }
}

/// Model table: ordered `(tag, engine)` pairs; the first entry is the
/// default route.  Shared read-only by both listeners.
struct Router {
    models: Vec<(String, Arc<Engine>)>,
    /// The `--max-workspace-bytes` admission bound (0 = off); carried here
    /// because the router is the one config-derived object both listeners
    /// already share.
    max_workspace_bytes: u64,
}

impl Router {
    fn default_engine(&self) -> &Arc<Engine> {
        &self.models[0].1
    }

    /// Map a request's `"model"` tag onto an engine.  `None` routes to the
    /// default; an unknown tag is the caller's `invalid_request`.
    fn resolve(&self, tag: Option<&str>) -> std::result::Result<Arc<Engine>, String> {
        match tag {
            None => Ok(self.models[0].1.clone()),
            Some(t) => self
                .models
                .iter()
                .find(|(name, _)| name == t)
                .map(|(_, e)| e.clone())
                .ok_or_else(|| {
                    let known: Vec<&str> =
                        self.models.iter().map(|(name, _)| name.as_str()).collect();
                    format!("unknown model {t:?} (loaded: {})", known.join(", "))
                }),
        }
    }
}

/// HTTP front-door telemetry, registered on the batcher's registry so
/// `GET /metrics` and `{"op":"metrics"}` export it with everything else.
struct HttpStats {
    /// `serve_http_requests_total`
    requests: Arc<Counter>,
    /// `serve_http_errors_total`
    errors: Arc<Counter>,
    /// `serve_http_sse_events_total`
    sse_events: Arc<Counter>,
    /// `serve_http_connections`
    connections: Arc<Gauge>,
    /// `serve_http_request_us`
    request_us: Arc<Histogram>,
}

impl HttpStats {
    fn new(r: &Registry) -> HttpStats {
        HttpStats {
            requests: r.counter(
                "serve_http_requests_total",
                "HTTP requests answered, any route or status",
            ),
            errors: r.counter("serve_http_errors_total", "HTTP responses with status >= 400"),
            sse_events: r.counter(
                "serve_http_sse_events_total",
                "SSE events written (per-token deltas + summaries + terminal [DONE])",
            ),
            connections: r.gauge("serve_http_connections", "HTTP connections currently open"),
            request_us: r.histogram(
                "serve_http_request_us",
                "HTTP request latency, request parsed to response written, microseconds",
            ),
        }
    }
}

/// Everything an HTTP connection thread needs, behind one `Arc`.
struct HttpCtx {
    router: Arc<Router>,
    batcher: Arc<Batcher>,
    stats: HttpStats,
    /// The server-wide stop flag: set → `/healthz` answers 503 and API
    /// routes answer `shutting_down`.
    draining: Arc<AtomicBool>,
    /// Stops the HTTP listener — separate from `draining` so `/healthz`
    /// keeps answering through the drain window.
    http_stop: Arc<AtomicBool>,
    idle_timeout: Duration,
    limits: Limits,
}

/// A running server.  Dropping the handle does NOT stop it; call
/// [`Server::stop`] or send a `shutdown` request, then [`Server::join`].
pub struct Server {
    pub addr: SocketAddr,
    accept: Option<JoinHandle<()>>,
    http: Option<JoinHandle<()>>,
    http_addr: Option<SocketAddr>,
    batcher: Arc<Batcher>,
    stop: Arc<AtomicBool>,
    /// Stops the HTTP listener — separate from `stop` so `/healthz`
    /// keeps answering 503 through the drain window.
    http_stop: Arc<AtomicBool>,
    drain: Duration,
}

/// Single-model [`serve_multi`]: the engine serves every request under the
/// tag `"default"`.
pub fn serve(engine: Arc<Engine>, cfg: &ServeConfig) -> Result<Server> {
    serve_multi(vec![("default".to_string(), engine)], cfg)
}

/// Bind, spawn the batcher + accept loop (+ the HTTP listener when
/// configured), and return immediately.  `models` is an ordered
/// `(tag, engine)` table; the first entry is the default route.
pub fn serve_multi(models: Vec<(String, Arc<Engine>)>, cfg: &ServeConfig) -> Result<Server> {
    if models.is_empty() {
        bail!("serve_multi needs at least one model");
    }
    for (i, (tag, _)) in models.iter().enumerate() {
        if models[..i].iter().any(|(seen, _)| seen == tag) {
            bail!("duplicate model tag {tag:?}");
        }
    }
    let router = Arc::new(Router { models, max_workspace_bytes: cfg.max_workspace_bytes });
    let listener = TcpListener::bind((cfg.host.as_str(), cfg.port))
        .with_context(|| format!("binding {}:{}", cfg.host, cfg.port))?;
    let addr = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let http_stop = Arc::new(AtomicBool::new(false));
    let batcher = Arc::new(Batcher::start(
        router.default_engine().clone(),
        cfg.workers,
        cfg.max_batch,
        cfg.max_wait,
        cfg.queue_depth,
        cfg.brownout_queue_ms,
    ));
    let http_spec = cfg.http_addr.as_ref().or(cfg.metrics_addr.as_ref());
    let (http, http_addr) = match http_spec {
        None => (None, None),
        Some(spec) => {
            let http_listener = TcpListener::bind(spec.as_str())
                .with_context(|| format!("binding http listener {spec}"))?;
            let bound = http_listener.local_addr()?;
            let ctx = Arc::new(HttpCtx {
                router: router.clone(),
                batcher: batcher.clone(),
                stats: HttpStats::new(batcher.stats().registry()),
                draining: stop.clone(),
                http_stop: http_stop.clone(),
                idle_timeout: cfg.idle_timeout,
                limits: Limits::default(),
            });
            let handle = std::thread::spawn(move || http_loop(http_listener, &ctx));
            (Some(handle), Some(bound))
        }
    };
    let accept = {
        let router = router.clone();
        let batcher = batcher.clone();
        let stop = stop.clone();
        let idle_timeout = cfg.idle_timeout;
        std::thread::spawn(move || {
            accept_loop(listener, addr, router, batcher, stop, idle_timeout)
        })
    };
    Ok(Server {
        addr,
        accept: Some(accept),
        http,
        http_addr,
        batcher,
        stop,
        http_stop,
        drain: cfg.drain,
    })
}

impl Server {
    /// Request shutdown from this process (equivalent to a client sending
    /// `{"op":"shutdown"}`).
    pub fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
        // Wake the blocking accept() so the loop observes the flag.
        let _ = TcpStream::connect(self.addr);
    }

    /// A detached stop handle: replicates [`Server::stop`] without
    /// borrowing the server, so a signal-watcher thread can hold it while
    /// the main thread blocks in [`Server::join`].
    pub fn stopper(&self) -> Stopper {
        Stopper { stop: self.stop.clone(), addr: self.addr }
    }

    /// Where the HTTP listener is bound, when one was configured.
    pub fn http_addr(&self) -> Option<SocketAddr> {
        self.http_addr
    }

    /// Legacy name for [`Server::http_addr`]: `GET /metrics` now lives on
    /// the full HTTP listener.
    pub fn metrics_addr(&self) -> Option<SocketAddr> {
        self.http_addr
    }

    /// Wait for the accept loop to exit, drain in-flight jobs under the
    /// configured [`ServeConfig::drain`] budget, then stop the workers.
    /// Once the accept loop is down no new work can arrive, so the drain
    /// is monotone; if the budget runs out the remaining jobs are dropped
    /// and their clients observe `shutting_down`.  The HTTP listener
    /// answers `/healthz` 503 through the drain and stops last.
    pub fn join(mut self) -> Result<()> {
        if let Some(handle) = self.accept.take() {
            handle.join().map_err(|_| anyhow::anyhow!("accept thread panicked"))?;
        }
        if !self.batcher.drain(self.drain) {
            eprintln!(
                "[serve] drain budget ({:?}) exhausted with {} job(s) in flight; dropping",
                self.drain,
                self.batcher.in_flight()
            );
        }
        self.batcher.shutdown();
        self.http_stop.store(true, Ordering::SeqCst);
        if let Some(handle) = self.http.take() {
            let _ = handle.join();
        }
        Ok(())
    }
}

/// A clonable, detached handle that can request server shutdown from any
/// thread (the SIGTERM/SIGINT watcher uses one; see `cmd_serve`).
#[derive(Clone)]
pub struct Stopper {
    stop: Arc<AtomicBool>,
    addr: SocketAddr,
}

impl Stopper {
    /// Request shutdown, waking the accept loop (same as [`Server::stop`]).
    pub fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
    }

    /// True once shutdown has been requested by anyone.
    pub fn stopped(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }
}

fn accept_loop(
    listener: TcpListener,
    addr: SocketAddr,
    router: Arc<Router>,
    batcher: Arc<Batcher>,
    stop: Arc<AtomicBool>,
    idle_timeout: Duration,
) {
    for stream in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let stream = match stream {
            Ok(stream) => stream,
            Err(_) => continue,
        };
        let router = router.clone();
        let batcher = batcher.clone();
        let stop = stop.clone();
        // One thread per connection: connections are long-lived and few at
        // this substrate's scale; concurrency inside a connection comes
        // from the batcher, not from here.
        std::thread::spawn(move || {
            connection(stream, addr, &router, &batcher, &stop, idle_timeout)
        });
    }
}

/// Serve one line-JSON connection until EOF, error, idle timeout, or
/// shutdown.
fn connection(
    stream: TcpStream,
    addr: SocketAddr,
    router: &Router,
    batcher: &Batcher,
    stop: &AtomicBool,
    idle_timeout: Duration,
) {
    let _ = stream.set_nodelay(true);
    // Reads poll so this thread can notice stop/idle; writes are bounded so
    // a client that stops reading cannot wedge us.
    let _ = stream.set_read_timeout(Some(READ_POLL));
    let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
    let mut reader = match stream.try_clone() {
        Ok(clone) => BufReader::new(clone),
        Err(_) => return,
    };
    let mut writer = stream;
    // One line buffer across poll iterations: a read that times out
    // mid-line leaves its partial bytes here (read_line appends), so
    // nothing is lost when the next poll resumes.
    let mut line = String::new();
    let mut idle_since = Instant::now();
    loop {
        match reader.read_line(&mut line) {
            Ok(n) => {
                // Without a trailing newline the peer hit EOF mid-line;
                // serve what arrived, then hang up.
                let at_eof = n == 0 || !line.ends_with('\n');
                if !line.trim().is_empty()
                    && handle_line(line.trim(), &mut writer, addr, router, batcher, stop).is_err()
                {
                    return;
                }
                line.clear();
                idle_since = Instant::now();
                if at_eof {
                    return;
                }
            }
            Err(err)
                if matches!(
                    err.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                // Poll tick: no complete line yet (partial bytes, if any,
                // stay in `line`).
                if stop.load(Ordering::SeqCst) || idle_since.elapsed() >= idle_timeout {
                    return;
                }
            }
            Err(_) => return,
        }
    }
}

/// Parse and answer one complete request line.  `Err(())` means the
/// connection is done (write failed or shutdown was requested).
fn handle_line(
    line: &str,
    writer: &mut TcpStream,
    addr: SocketAddr,
    router: &Router,
    batcher: &Batcher,
    stop: &AtomicBool,
) -> std::result::Result<(), ()> {
    // Chaos site: simulate a stalled connection handler.
    faults::stall("conn.stall_ms");
    let received = Instant::now();
    let stats = batcher.stats();
    let (response, timings, degraded) = match Request::parse(line) {
        Err(err) => {
            (Response::err(ErrorCode::InvalidRequest, format!("bad request: {err:#}")), None, false)
        }
        Ok(Request::Info) => (Response::Info(info_fields(router, batcher)), None, false),
        Ok(Request::Metrics) => (Response::Metrics(metrics_fields(router, batcher)), None, false),
        Ok(Request::Shutdown) => {
            stats.requests.inc();
            let _ = write_json(writer, &Response::Shutdown.to_json());
            stop.store(true, Ordering::SeqCst);
            let _ = TcpStream::connect(addr); // wake accept()
            return Err(());
        }
        Ok(request) => dispatch(request, router, batcher, stop),
    };
    // Serialize + write under the stopwatch; the serialize span can only
    // live in the histogram — it cannot be echoed inside the response it
    // measures.
    let mut json = response.to_json();
    if let Json::Object(entries) = &mut json {
        if let Some(t) = timings {
            entries.push(("timings".to_string(), t.to_json()));
        }
        if degraded {
            entries.push(("degraded".to_string(), Json::Bool(true)));
        }
    }
    let serialize_started = Instant::now();
    let wrote = write_json(writer, &json);
    stats.stage_serialize.record(serialize_started.elapsed().as_micros() as u64);
    stats.request_us.record(received.elapsed().as_micros() as u64);
    stats.requests.inc();
    wrote.map_err(|_| ())
}

/// `CCE_FAULTS=supervisor.child_crash=K`: the K-th *work* request
/// (generate/score — never `/healthz`, `/metrics`, or `info`, so the
/// supervisor's own health probes can't trip it) hard-exits the process.
/// Exit code 3 mimics an abrupt crash: no drain, no clean-shutdown line.
/// Every incarnation crashes on its K-th work request, which is what the
/// chaos tests and the CI soak stage key their scenarios on.
fn maybe_child_crash() {
    static TICKS: AtomicU64 = AtomicU64::new(0);
    if let Some(k) = faults::value("supervisor.child_crash") {
        let n = TICKS.fetch_add(1, Ordering::SeqCst) + 1;
        if n == k as u64 {
            eprintln!("[serve] fault supervisor.child_crash fired on work request {n}; exiting");
            std::process::exit(3);
        }
    }
}

/// `--max-workspace-bytes` admission guard: reject a score request whose
/// fused-problem tile math ([`Engine::score_workspace_bound`]) could
/// exceed the configured bound, before it ever queues.  `text.len()`
/// bounds the row count from above (every token costs ≥ 1 byte), so the
/// check is conservative-safe and needs no tokenization.
fn workspace_guard(request: &Request, engine: &Engine, max_bytes: u64) -> Option<Response> {
    if max_bytes == 0 {
        return None;
    }
    if let Request::Score { text, .. } = request {
        let bound = engine.score_workspace_bound(text.len());
        if bound > max_bytes {
            return Some(Response::err(
                ErrorCode::InvalidRequest,
                format!(
                    "score request could need {bound} workspace bytes \
                     (O(N·D + threads·N_B·V_B)); --max-workspace-bytes is {max_bytes}"
                ),
            ));
        }
    }
    None
}

/// Route a batchable request through the micro-batcher and wait for its
/// reply (response + optional stage timings + brownout-degraded flag).
fn dispatch(
    request: Request,
    router: &Router,
    batcher: &Batcher,
    stop: &AtomicBool,
) -> (Response, Option<StageTimings>, bool) {
    maybe_child_crash();
    if stop.load(Ordering::SeqCst) {
        return (Response::err(ErrorCode::ShuttingDown, "server is shutting down"), None, false);
    }
    let engine = match router.resolve(request.model()) {
        Ok(engine) => engine,
        Err(msg) => return (Response::err(ErrorCode::InvalidRequest, msg), None, false),
    };
    if let Some(rejection) = workspace_guard(&request, &engine, router.max_workspace_bytes) {
        return (rejection, None, false);
    }
    wait_reply(request, engine, batcher)
}

/// Submit one already-routed job and block on its reply.  Shared by the
/// line-JSON dispatch and the non-streaming HTTP routes so both protocols
/// see identical admission-control and shutdown semantics.
fn wait_reply(
    request: Request,
    engine: Arc<Engine>,
    batcher: &Batcher,
) -> (Response, Option<StageTimings>, bool) {
    let (tx, rx) = mpsc::channel();
    let mut job = Job::new(request, tx);
    job.engine = Some(engine);
    match batcher.submit(job) {
        // Admission control: shed at the door with a live retry hint
        // rather than buffering unboundedly.
        Err(_) => {
            batcher.stats().overloaded.inc();
            (
                Response::overloaded(
                    "queue full (admission control): retry later",
                    batcher.retry_after_ms(),
                ),
                None,
                false,
            )
        }
        Ok(()) => match rx.recv_timeout(Duration::from_secs(300)) {
            Ok(reply) => (reply.response, reply.timings, reply.degraded),
            // Sender dropped: shutdown raced the job out of the queue.
            Err(mpsc::RecvTimeoutError::Disconnected) => (
                Response::err(ErrorCode::ShuttingDown, "request dropped during shutdown"),
                None,
                false,
            ),
            Err(mpsc::RecvTimeoutError::Timeout) => (
                Response::err(ErrorCode::Internal, "request timed out inside the server"),
                None,
                false,
            ),
        },
    }
}

fn info_fields(router: &Router, batcher: &Batcher) -> Json {
    let stats = batcher.stats();
    let mut fields: Vec<(String, Json)> = match router.default_engine().info_json() {
        Json::Object(entries) => entries,
        other => vec![("model_info".into(), other)],
    };
    fields.push((
        "models".into(),
        Json::Array(router.models.iter().map(|(tag, _)| Json::str(tag)).collect()),
    ));
    fields.push(("batches".into(), Json::Int(stats.batches.get() as i64)));
    fields.push(("batched_jobs".into(), Json::Int(stats.jobs.get() as i64)));
    fields.push(("max_batch_observed".into(), Json::Int(stats.max_batch.get())));
    fields.push(("shed_deadline".into(), Json::Int(stats.shed_deadline.get() as i64)));
    fields.push(("batch_panics".into(), Json::Int(stats.panics.get() as i64)));
    fields.push(("in_flight".into(), Json::Int(batcher.in_flight() as i64)));
    fields.push((
        "cancelled_disconnect".into(),
        Json::Int(stats.cancelled_disconnect.get() as i64),
    ));
    fields.push(("cancelled_deadline".into(), Json::Int(stats.cancelled_deadline.get() as i64)));
    fields.push(("brownout_degraded".into(), Json::Int(stats.brownout_degraded.get() as i64)));
    Json::Object(fields)
}

/// Engine-side totals summed across every loaded model (single-model
/// servers see exactly the old per-engine numbers).
fn engine_totals(router: &Router) -> (u64, u64) {
    let served = router.models.iter().map(|(_, e)| e.served()).sum();
    let peak = router.models.iter().map(|(_, e)| e.peak_workspace_bytes()).sum();
    (served, peak)
}

/// The `{"op":"metrics"}` payload: serve registry + process-global
/// exec/train registry + the engines' own gauges, one field per family.
fn metrics_fields(router: &Router, batcher: &Batcher) -> Json {
    let mut fields = batcher.stats().registry().to_json_fields();
    fields.extend(obs::global().to_json_fields());
    let (served, peak) = engine_totals(router);
    fields.push(("serve_engine_requests_served_total".into(), Json::Int(served as i64)));
    fields.push(("serve_engine_peak_workspace_bytes".into(), Json::Int(peak as i64)));
    Json::Object(fields)
}

/// The `GET /metrics` body: the same three sources in Prometheus text
/// exposition format.
fn metrics_prometheus(router: &Router, batcher: &Batcher) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    batcher.stats().registry().render_prometheus(&mut out);
    obs::global().render_prometheus(&mut out);
    let (served, peak) = engine_totals(router);
    let _ = writeln!(
        out,
        "# HELP serve_engine_requests_served_total Requests the engines finished kernels for"
    );
    let _ = writeln!(out, "# TYPE serve_engine_requests_served_total counter");
    let _ = writeln!(out, "serve_engine_requests_served_total {served}");
    let _ = writeln!(
        out,
        "# HELP serve_engine_peak_workspace_bytes Engine kernel + hidden-buffer high-water mark"
    );
    let _ = writeln!(out, "# TYPE serve_engine_peak_workspace_bytes gauge");
    let _ = writeln!(out, "serve_engine_peak_workspace_bytes {peak}");
    out
}

/// Accept loop of the HTTP listener: nonblocking accept + short sleep so
/// the thread notices `http_stop` promptly.  Keeps accepting through the
/// drain window (that is what makes `/healthz` useful to a load balancer)
/// and exits only once [`Server::join`] sets `http_stop`.
fn http_loop(listener: TcpListener, ctx: &Arc<HttpCtx>) {
    if listener.set_nonblocking(true).is_err() {
        return;
    }
    loop {
        match listener.accept() {
            Ok((stream, _)) => {
                let _ = stream.set_nonblocking(false);
                let ctx = ctx.clone();
                // Thread per connection, like the line listener: an SSE
                // stream holds its connection for the whole generation.
                std::thread::spawn(move || http_conn(stream, &ctx));
            }
            Err(_) => {
                if ctx.http_stop.load(Ordering::SeqCst) {
                    return;
                }
                std::thread::sleep(HTTP_ACCEPT_POLL);
            }
        }
    }
}

/// Serve one HTTP connection: keep-alive request loop with the same
/// poll-for-stop / idle-timeout discipline as the line listener.
fn http_conn(stream: TcpStream, ctx: &HttpCtx) {
    ctx.stats.connections.add(1);
    http_conn_loop(stream, ctx);
    ctx.stats.connections.add(-1);
}

fn http_conn_loop(stream: TcpStream, ctx: &HttpCtx) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(READ_POLL));
    let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
    let reader = match stream.try_clone() {
        Ok(clone) => clone,
        Err(_) => return,
    };
    let mut conn = Conn::new(reader);
    let mut writer = stream;
    let mut idle_since = Instant::now();
    loop {
        match conn.read_request(&ctx.limits) {
            Ok(req) => {
                idle_since = Instant::now();
                match handle_http_request(req, &mut writer, ctx) {
                    Ok(true) => {}
                    _ => return,
                }
            }
            // Quiet keep-alive connection: poll the stop flag and the idle
            // budget, then resume (buffered partial bytes are kept).
            Err(HttpError::Idle) => {
                if ctx.http_stop.load(Ordering::SeqCst)
                    || idle_since.elapsed() >= ctx.idle_timeout
                {
                    return;
                }
            }
            Err(HttpError::Closed) => return,
            // The peer went silent (or EOF'd) mid-request; a request must
            // arrive promptly once its first byte does.
            Err(HttpError::Stalled) => {
                let _ = http::write_response(
                    &mut writer,
                    408,
                    "text/plain; charset=utf-8",
                    &[],
                    b"request timed out\n",
                    false,
                );
                return;
            }
            Err(HttpError::HeadersTooLarge) => {
                ctx.stats.errors.inc();
                let _ = http::write_response(
                    &mut writer,
                    431,
                    "text/plain; charset=utf-8",
                    &[],
                    b"header section too large\n",
                    false,
                );
                return;
            }
            Err(HttpError::BodyTooLarge) => {
                ctx.stats.errors.inc();
                let _ = http::write_response(
                    &mut writer,
                    413,
                    "text/plain; charset=utf-8",
                    &[],
                    b"body too large\n",
                    false,
                );
                return;
            }
            Err(HttpError::Bad(msg)) => {
                ctx.stats.errors.inc();
                let _ = http::write_error(
                    &mut writer,
                    ErrorCode::InvalidRequest,
                    &format!("malformed http request: {msg}"),
                    None,
                    false,
                );
                return;
            }
            Err(HttpError::Io(_)) => return,
        }
    }
}

/// Answer one parsed HTTP request.  `Ok(true)` keeps the connection open
/// for the next request.
fn handle_http_request(
    req: HttpRequest,
    writer: &mut TcpStream,
    ctx: &HttpCtx,
) -> io::Result<bool> {
    // Chaos site: simulate a stalled connection handler (same site as the
    // line listener, so `conn.stall_ms` covers both protocols).
    faults::stall("conn.stall_ms");
    let started = Instant::now();
    ctx.stats.requests.inc();
    let keep_req = req.keep_alive;
    let method = req.method.clone();
    let path = req.path.clone();
    let (status, keep) = match (method.as_str(), path.as_str()) {
        ("GET", "/metrics") => {
            let body = metrics_prometheus(&ctx.router, &ctx.batcher);
            http::write_response(
                writer,
                200,
                "text/plain; version=0.0.4; charset=utf-8",
                &[],
                body.as_bytes(),
                keep_req,
            )?;
            (200, keep_req)
        }
        ("GET", "/healthz") => {
            let (status, body): (u32, &[u8]) = if ctx.draining.load(Ordering::SeqCst) {
                (503, b"draining\n")
            } else {
                (200, b"ok\n")
            };
            http::write_response(writer, status, "text/plain; charset=utf-8", &[], body, keep_req)?;
            (status, keep_req)
        }
        ("POST", "/v1/generate") => handle_generate(req, writer, ctx)?,
        ("POST", "/v1/score") => handle_score(req, writer, ctx)?,
        (_, "/metrics" | "/healthz" | "/v1/generate" | "/v1/score") => {
            http::write_response(
                writer,
                405,
                "text/plain; charset=utf-8",
                &[],
                b"method not allowed\n",
                keep_req,
            )?;
            (405, keep_req)
        }
        _ => {
            http::write_response(
                writer,
                404,
                "text/plain; charset=utf-8",
                &[],
                b"not found\n",
                keep_req,
            )?;
            (404, keep_req)
        }
    };
    if status >= 400 {
        ctx.stats.errors.inc();
    }
    ctx.stats.request_us.record(started.elapsed().as_micros() as u64);
    Ok(keep)
}

/// Decode the JSON body of an API request.
fn parse_body(req: &HttpRequest) -> std::result::Result<Json, String> {
    let text =
        std::str::from_utf8(&req.body).map_err(|_| "request body is not UTF-8".to_string())?;
    Json::parse(text).map_err(|err| format!("bad JSON body: {err:#}"))
}

/// `X-CCE-Deadline-Ms` / `X-CCE-Trace` fill in `deadline_ms` / `trace`
/// when the body left them unset; body fields are canonical and win.
fn apply_header_overrides(req: &HttpRequest, deadline_ms: &mut u64, trace: &mut bool) {
    if *deadline_ms == 0 {
        if let Some(v) =
            req.header("x-cce-deadline-ms").and_then(|v| v.trim().parse::<u64>().ok())
        {
            *deadline_ms = v;
        }
    }
    if !*trace {
        if let Some(v) = req.header("x-cce-trace") {
            let v = v.trim();
            *trace = v == "1" || v.eq_ignore_ascii_case("true");
        }
    }
}

/// Write a batcher [`Response`] as an HTTP response: errors map through
/// [`http::status_for`] (with `Retry-After` on 429), successes are the
/// line-protocol JSON body (plus spliced `timings`) with a trailing
/// newline, status 200.
fn write_api_response(
    writer: &mut TcpStream,
    response: Response,
    timings: Option<StageTimings>,
    degraded: bool,
    keep: bool,
) -> io::Result<(u32, bool)> {
    if let Response::Error { code, message, retry_after_ms } = response {
        let status = http::status_for(code);
        http::write_error(writer, code, &message, retry_after_ms, keep)?;
        return Ok((status, keep));
    }
    let mut json = response.to_json();
    if let Json::Object(entries) = &mut json {
        if let Some(t) = timings {
            entries.push(("timings".to_string(), t.to_json()));
        }
        if degraded {
            entries.push(("degraded".to_string(), Json::Bool(true)));
        }
    }
    let mut body = json.to_string();
    body.push('\n');
    http::write_response(writer, 200, "application/json", &[], body.as_bytes(), keep)?;
    Ok((200, keep))
}

/// An error shipped inside an established SSE stream (the `200 OK` is
/// already on the wire): the non-streaming error body as a single-line
/// `data:` payload.
fn sse_error_event(code: ErrorCode, message: &str, retry_after_ms: Option<u64>) -> String {
    http::error_body(code, message, retry_after_ms).trim_end().to_string()
}

/// `POST /v1/generate`: non-streaming waits the batcher reply out and
/// answers JSON; `"stream":true` switches the connection to SSE and
/// forwards per-token deltas straight off the lockstep decode loop.
fn handle_generate(
    req: HttpRequest,
    writer: &mut TcpStream,
    ctx: &HttpCtx,
) -> io::Result<(u32, bool)> {
    maybe_child_crash();
    let keep = req.keep_alive;
    let body = match parse_body(&req) {
        Ok(j) => j,
        Err(msg) => {
            http::write_error(writer, ErrorCode::InvalidRequest, &msg, None, keep)?;
            return Ok((400, keep));
        }
    };
    let stream = body.get("stream").and_then(|v| v.as_bool()).unwrap_or(false);
    let mut params = match GenParams::from_json(&body) {
        Ok(p) => p,
        Err(err) => {
            let msg = format!("bad request: {err:#}");
            http::write_error(writer, ErrorCode::InvalidRequest, &msg, None, keep)?;
            return Ok((400, keep));
        }
    };
    apply_header_overrides(&req, &mut params.deadline_ms, &mut params.trace);
    let engine = match ctx.router.resolve(params.model.as_deref()) {
        Ok(engine) => engine,
        Err(msg) => {
            http::write_error(writer, ErrorCode::InvalidRequest, &msg, None, keep)?;
            return Ok((400, keep));
        }
    };
    if ctx.draining.load(Ordering::SeqCst) {
        http::write_error(writer, ErrorCode::ShuttingDown, "server is shutting down", None, keep)?;
        return Ok((503, keep));
    }
    if !stream {
        let (response, timings, degraded) =
            wait_reply(Request::Generate(params), engine, &ctx.batcher);
        return write_api_response(writer, response, timings, degraded, keep);
    }

    // Streaming path.  Admission control still answers plain HTTP (the
    // stream has not started); once the SSE head is written every outcome
    // — including errors — travels as events.
    let (reply_tx, reply_rx) = mpsc::channel();
    let (delta_tx, delta_rx) = mpsc::sync_channel(STREAM_CHANNEL_DEPTH);
    let cancel = CancelToken::new();
    let mut job = Job::new(Request::Generate(params), reply_tx);
    job.engine = Some(engine);
    job.stream = Some(delta_tx);
    job.cancel = Some(cancel.clone());
    if ctx.batcher.submit(job).is_err() {
        ctx.batcher.stats().overloaded.inc();
        let hint = ctx.batcher.retry_after_ms();
        http::write_error(
            writer,
            ErrorCode::Overloaded,
            "queue full (admission control): retry later",
            Some(hint),
            keep,
        )?;
        return Ok((429, keep));
    }
    let mut sse = SseWriter::start(&mut *writer)?;
    let mut client_gone = false;
    // Token deltas until the batcher hangs the channel up (its end-of-
    // stream signal).  A dead client cancels the work, not just the
    // writes: the token fires at the engine's next lockstep step
    // boundary, the slot frees, and the (partial) reply still routes so
    // accounting stays uniform.
    while let Ok(delta) = delta_rx.recv() {
        if client_gone {
            continue;
        }
        let event = Json::obj(vec![
            ("token", Json::Int(delta.token as i64)),
            ("logprob", Json::Float(delta.logprob as f64)),
            ("text", Json::str(&delta.text)),
        ])
        .to_string();
        if sse.event(&event).is_err() {
            client_gone = true;
            cancel.cancel();
        }
    }
    let final_event = match reply_rx.recv_timeout(Duration::from_secs(300)) {
        Ok(reply) => match reply.response {
            Response::Generate { text, tokens, .. } => {
                let mut fields = vec![
                    ("done", Json::Bool(true)),
                    ("text", Json::str(&text)),
                    ("tokens", Json::Int(tokens.len() as i64)),
                ];
                if reply.degraded {
                    fields.push(("degraded", Json::Bool(true)));
                }
                Json::obj(fields).to_string()
            }
            Response::Error { code, message, retry_after_ms } => {
                sse_error_event(code, &message, retry_after_ms)
            }
            _ => sse_error_event(ErrorCode::Internal, "unexpected reply to generate", None),
        },
        Err(mpsc::RecvTimeoutError::Disconnected) => {
            sse_error_event(ErrorCode::ShuttingDown, "request dropped during shutdown", None)
        }
        Err(mpsc::RecvTimeoutError::Timeout) => {
            sse_error_event(ErrorCode::Internal, "request timed out inside the server", None)
        }
    };
    if !client_gone {
        let _ = sse.event(&final_event);
    }
    let events = sse.events();
    let events = sse.done().unwrap_or(events);
    ctx.stats.sse_events.add(events);
    // SSE ends by closing the connection; every client treats it as EOF.
    Ok((200, false))
}

/// `POST /v1/score`: same body fields as the line-JSON `score` op.
fn handle_score(
    req: HttpRequest,
    writer: &mut TcpStream,
    ctx: &HttpCtx,
) -> io::Result<(u32, bool)> {
    maybe_child_crash();
    let keep = req.keep_alive;
    let body = match parse_body(&req) {
        Ok(j) => j,
        Err(msg) => {
            http::write_error(writer, ErrorCode::InvalidRequest, &msg, None, keep)?;
            return Ok((400, keep));
        }
    };
    let mut request = match score_from_json(&body) {
        Ok(r) => r,
        Err(err) => {
            let msg = format!("bad request: {err:#}");
            http::write_error(writer, ErrorCode::InvalidRequest, &msg, None, keep)?;
            return Ok((400, keep));
        }
    };
    if let Request::Score { deadline_ms, trace, .. } = &mut request {
        apply_header_overrides(&req, deadline_ms, trace);
    }
    let engine = match ctx.router.resolve(request.model()) {
        Ok(engine) => engine,
        Err(msg) => {
            http::write_error(writer, ErrorCode::InvalidRequest, &msg, None, keep)?;
            return Ok((400, keep));
        }
    };
    if let Some(rejection) = workspace_guard(&request, &engine, ctx.router.max_workspace_bytes) {
        return write_api_response(writer, rejection, None, false, keep);
    }
    if ctx.draining.load(Ordering::SeqCst) {
        http::write_error(writer, ErrorCode::ShuttingDown, "server is shutting down", None, keep)?;
        return Ok((503, keep));
    }
    let (response, timings, degraded) = wait_reply(request, engine, &ctx.batcher);
    write_api_response(writer, response, timings, degraded, keep)
}

fn write_json(writer: &mut TcpStream, json: &Json) -> std::io::Result<()> {
    let mut line = json.to_string();
    line.push('\n');
    writer.write_all(line.as_bytes())?;
    writer.flush()
}
