//! TCP front end: accept loop, per-connection line protocol, graceful
//! shutdown.
//!
//! Dependency-free: [`std::net::TcpListener`] + one thread per connection
//! reading newline-delimited JSON ([`super::protocol`]).  `generate` and
//! `score` go through the micro-batcher ([`super::batcher`]); `info`,
//! `metrics`, and `shutdown` are answered inline.  Binding port 0 picks an
//! ephemeral port (the bound address is reported on [`Server::addr`]) —
//! which is how the CI smoke test and the integration tests avoid port
//! collisions.
//!
//! Failure domains (PR 6): connections poll the socket with a short read
//! timeout instead of blocking forever, so a stalled client holds a thread
//! for at most [`ServeConfig::idle_timeout`] and shutdown never waits on a
//! silent peer; writes are bounded too.  Errors carry structured codes
//! ([`super::protocol::ErrorCode`]): a full queue answers `overloaded`
//! with a live `retry_after_ms` hint, and [`Server::join`] drains in-flight
//! work under [`ServeConfig::drain`] before stopping the workers.
//!
//! Telemetry (PR 7): every answered line feeds the batcher's `serve_*`
//! registry (request count, end-to-end and serialize-time histograms);
//! responses to requests that set `"trace":true` gain a spliced `timings`
//! object.  With [`ServeConfig::metrics_addr`] set, a minimal hand-rolled
//! HTTP/1.1 listener — the first concrete slice of the ROADMAP front door
//! — serves `GET /metrics` (Prometheus text exposition merging the serve
//! registry, the process-global exec/train registry, and engine gauges)
//! and `GET /healthz` (drain-aware: 200 while serving, 503 once shutdown
//! began).  The exporter keeps answering through the drain window and
//! stops only after [`Server::join`] finishes.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::obs::{self, StageTimings};
use crate::serve::batcher::{Batcher, Job};
use crate::serve::engine::Engine;
use crate::serve::protocol::{ErrorCode, Request, Response};
use crate::util::faults;
use crate::util::json::Json;

/// How often a connection thread wakes from a blocked read to check the
/// stop flag and the idle budget.
const READ_POLL: Duration = Duration::from_millis(200);

/// Bound on a single response write; a client that stops reading cannot
/// wedge its connection thread past this.
const WRITE_TIMEOUT: Duration = Duration::from_secs(10);

/// Accept-poll cadence of the metrics HTTP listener.
const METRICS_POLL: Duration = Duration::from_millis(50);

/// Server + batcher knobs (`cce serve` flags map 1:1).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub host: String,
    /// 0 = ephemeral.
    pub port: u16,
    /// Batch workers (kernel threads are a separate knob:
    /// [`crate::exec::KernelOptions::threads`]).
    pub workers: usize,
    /// Largest micro-batch.
    pub max_batch: usize,
    /// How long batch assembly waits for stragglers.
    pub max_wait: Duration,
    /// Bounded request-queue depth (backpressure threshold).
    pub queue_depth: usize,
    /// Hang up on a connection that sends no complete request for this
    /// long (slow-loris/stalled-client bound).
    pub idle_timeout: Duration,
    /// Graceful-shutdown budget: how long [`Server::join`] waits for
    /// in-flight jobs to finish before stopping the workers.
    pub drain: Duration,
    /// Bind an HTTP exporter here (`host:port`, port 0 = ephemeral)
    /// serving `GET /metrics` + `GET /healthz`.  `None` = no exporter.
    pub metrics_addr: Option<String>,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            host: "127.0.0.1".into(),
            port: 0,
            workers: 2,
            max_batch: 8,
            max_wait: Duration::from_millis(3),
            queue_depth: 64,
            idle_timeout: Duration::from_secs(300),
            drain: Duration::from_secs(5),
            metrics_addr: None,
        }
    }
}

/// A running server.  Dropping the handle does NOT stop it; call
/// [`Server::stop`] or send a `shutdown` request, then [`Server::join`].
pub struct Server {
    pub addr: SocketAddr,
    accept: Option<JoinHandle<()>>,
    metrics: Option<JoinHandle<()>>,
    metrics_addr: Option<SocketAddr>,
    batcher: Arc<Batcher>,
    stop: Arc<AtomicBool>,
    /// Stops the metrics exporter — separate from `stop` so `/healthz`
    /// keeps answering 503 through the drain window.
    metrics_stop: Arc<AtomicBool>,
    drain: Duration,
}

/// Bind, spawn the batcher + accept loop (+ the metrics exporter when
/// configured), and return immediately.
pub fn serve(engine: Arc<Engine>, cfg: &ServeConfig) -> Result<Server> {
    let listener = TcpListener::bind((cfg.host.as_str(), cfg.port))
        .with_context(|| format!("binding {}:{}", cfg.host, cfg.port))?;
    let addr = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let metrics_stop = Arc::new(AtomicBool::new(false));
    let batcher = Arc::new(Batcher::start(
        engine.clone(),
        cfg.workers,
        cfg.max_batch,
        cfg.max_wait,
        cfg.queue_depth,
    ));
    let (metrics, metrics_addr) = match &cfg.metrics_addr {
        None => (None, None),
        Some(spec) => {
            let http = TcpListener::bind(spec.as_str())
                .with_context(|| format!("binding metrics listener {spec}"))?;
            let http_addr = http.local_addr()?;
            let engine = engine.clone();
            let batcher = batcher.clone();
            let draining = stop.clone();
            let metrics_stop = metrics_stop.clone();
            let handle = std::thread::spawn(move || {
                metrics_loop(http, &engine, &batcher, &draining, &metrics_stop)
            });
            (Some(handle), Some(http_addr))
        }
    };
    let accept = {
        let batcher = batcher.clone();
        let stop = stop.clone();
        let idle_timeout = cfg.idle_timeout;
        std::thread::spawn(move || {
            accept_loop(listener, addr, engine, batcher, stop, idle_timeout)
        })
    };
    Ok(Server {
        addr,
        accept: Some(accept),
        metrics,
        metrics_addr,
        batcher,
        stop,
        metrics_stop,
        drain: cfg.drain,
    })
}

impl Server {
    /// Request shutdown from this process (equivalent to a client sending
    /// `{"op":"shutdown"}`).
    pub fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
        // Wake the blocking accept() so the loop observes the flag.
        let _ = TcpStream::connect(self.addr);
    }

    /// Where the HTTP exporter listens, when one was configured.
    pub fn metrics_addr(&self) -> Option<SocketAddr> {
        self.metrics_addr
    }

    /// Wait for the accept loop to exit, drain in-flight jobs under the
    /// configured [`ServeConfig::drain`] budget, then stop the workers.
    /// Once the accept loop is down no new work can arrive, so the drain
    /// is monotone; if the budget runs out the remaining jobs are dropped
    /// and their clients observe `shutting_down`.  The metrics exporter
    /// answers `/healthz` 503 through the drain and stops last.
    pub fn join(mut self) -> Result<()> {
        if let Some(handle) = self.accept.take() {
            handle.join().map_err(|_| anyhow::anyhow!("accept thread panicked"))?;
        }
        if !self.batcher.drain(self.drain) {
            eprintln!(
                "[serve] drain budget ({:?}) exhausted with {} job(s) in flight; dropping",
                self.drain,
                self.batcher.in_flight()
            );
        }
        self.batcher.shutdown();
        self.metrics_stop.store(true, Ordering::SeqCst);
        if let Some(handle) = self.metrics.take() {
            let _ = handle.join();
        }
        Ok(())
    }
}

fn accept_loop(
    listener: TcpListener,
    addr: SocketAddr,
    engine: Arc<Engine>,
    batcher: Arc<Batcher>,
    stop: Arc<AtomicBool>,
    idle_timeout: Duration,
) {
    for stream in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let stream = match stream {
            Ok(stream) => stream,
            Err(_) => continue,
        };
        let engine = engine.clone();
        let batcher = batcher.clone();
        let stop = stop.clone();
        // One thread per connection: connections are long-lived and few at
        // this substrate's scale; concurrency inside a connection comes
        // from the batcher, not from here.
        std::thread::spawn(move || {
            connection(stream, addr, &engine, &batcher, &stop, idle_timeout)
        });
    }
}

/// Serve one connection until EOF, error, idle timeout, or shutdown.
fn connection(
    stream: TcpStream,
    addr: SocketAddr,
    engine: &Engine,
    batcher: &Batcher,
    stop: &AtomicBool,
    idle_timeout: Duration,
) {
    let _ = stream.set_nodelay(true);
    // Reads poll so this thread can notice stop/idle; writes are bounded so
    // a client that stops reading cannot wedge us.
    let _ = stream.set_read_timeout(Some(READ_POLL));
    let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
    let mut reader = match stream.try_clone() {
        Ok(clone) => BufReader::new(clone),
        Err(_) => return,
    };
    let mut writer = stream;
    // One line buffer across poll iterations: a read that times out
    // mid-line leaves its partial bytes here (read_line appends), so
    // nothing is lost when the next poll resumes.
    let mut line = String::new();
    let mut idle_since = Instant::now();
    loop {
        match reader.read_line(&mut line) {
            Ok(n) => {
                // Without a trailing newline the peer hit EOF mid-line;
                // serve what arrived, then hang up.
                let at_eof = n == 0 || !line.ends_with('\n');
                if !line.trim().is_empty()
                    && handle_line(line.trim(), &mut writer, addr, engine, batcher, stop).is_err()
                {
                    return;
                }
                line.clear();
                idle_since = Instant::now();
                if at_eof {
                    return;
                }
            }
            Err(err)
                if matches!(
                    err.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                // Poll tick: no complete line yet (partial bytes, if any,
                // stay in `line`).
                if stop.load(Ordering::SeqCst) || idle_since.elapsed() >= idle_timeout {
                    return;
                }
            }
            Err(_) => return,
        }
    }
}

/// Parse and answer one complete request line.  `Err(())` means the
/// connection is done (write failed or shutdown was requested).
fn handle_line(
    line: &str,
    writer: &mut TcpStream,
    addr: SocketAddr,
    engine: &Engine,
    batcher: &Batcher,
    stop: &AtomicBool,
) -> std::result::Result<(), ()> {
    // Chaos site: simulate a stalled connection handler.
    faults::stall("conn.stall_ms");
    let received = Instant::now();
    let stats = batcher.stats();
    let (response, timings) = match Request::parse(line) {
        Err(err) => {
            (Response::err(ErrorCode::InvalidRequest, format!("bad request: {err:#}")), None)
        }
        Ok(Request::Info) => (Response::Info(info_fields(engine, batcher)), None),
        Ok(Request::Metrics) => (Response::Metrics(metrics_fields(engine, batcher)), None),
        Ok(Request::Shutdown) => {
            stats.requests.inc();
            let _ = write_json(writer, &Response::Shutdown.to_json());
            stop.store(true, Ordering::SeqCst);
            let _ = TcpStream::connect(addr); // wake accept()
            return Err(());
        }
        Ok(request) => dispatch(request, batcher, stop),
    };
    // Serialize + write under the stopwatch; the serialize span can only
    // live in the histogram — it cannot be echoed inside the response it
    // measures.
    let mut json = response.to_json();
    if let Some(t) = timings {
        if let Json::Object(entries) = &mut json {
            entries.push(("timings".to_string(), t.to_json()));
        }
    }
    let serialize_started = Instant::now();
    let wrote = write_json(writer, &json);
    stats.stage_serialize.record(serialize_started.elapsed().as_micros() as u64);
    stats.request_us.record(received.elapsed().as_micros() as u64);
    stats.requests.inc();
    wrote.map_err(|_| ())
}

/// Route a batchable request through the micro-batcher and wait for its
/// reply (response + optional stage timings).
fn dispatch(
    request: Request,
    batcher: &Batcher,
    stop: &AtomicBool,
) -> (Response, Option<StageTimings>) {
    if stop.load(Ordering::SeqCst) {
        return (Response::err(ErrorCode::ShuttingDown, "server is shutting down"), None);
    }
    let (tx, rx) = mpsc::channel();
    match batcher.submit(Job::new(request, tx)) {
        // Admission control: shed at the door with a live retry hint
        // rather than buffering unboundedly.
        Err(_) => {
            batcher.stats().overloaded.inc();
            (
                Response::overloaded(
                    "queue full (admission control): retry later",
                    batcher.retry_after_ms(),
                ),
                None,
            )
        }
        Ok(()) => match rx.recv_timeout(Duration::from_secs(300)) {
            Ok(reply) => (reply.response, reply.timings),
            // Sender dropped: shutdown raced the job out of the queue.
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                (Response::err(ErrorCode::ShuttingDown, "request dropped during shutdown"), None)
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                (Response::err(ErrorCode::Internal, "request timed out inside the server"), None)
            }
        },
    }
}

fn info_fields(engine: &Engine, batcher: &Batcher) -> Json {
    let stats = batcher.stats();
    let mut fields: Vec<(String, Json)> = match engine.info_json() {
        Json::Object(entries) => entries,
        other => vec![("model_info".into(), other)],
    };
    fields.push(("batches".into(), Json::Int(stats.batches.get() as i64)));
    fields.push(("batched_jobs".into(), Json::Int(stats.jobs.get() as i64)));
    fields.push(("max_batch_observed".into(), Json::Int(stats.max_batch.get())));
    fields.push(("shed_deadline".into(), Json::Int(stats.shed_deadline.get() as i64)));
    fields.push(("batch_panics".into(), Json::Int(stats.panics.get() as i64)));
    fields.push(("in_flight".into(), Json::Int(batcher.in_flight() as i64)));
    Json::Object(fields)
}

/// The `{"op":"metrics"}` payload: serve registry + process-global
/// exec/train registry + the engine's own gauges, one field per family.
fn metrics_fields(engine: &Engine, batcher: &Batcher) -> Json {
    let mut fields = batcher.stats().registry().to_json_fields();
    fields.extend(obs::global().to_json_fields());
    fields.push((
        "serve_engine_requests_served_total".into(),
        Json::Int(engine.served() as i64),
    ));
    fields.push((
        "serve_engine_peak_workspace_bytes".into(),
        Json::Int(engine.peak_workspace_bytes() as i64),
    ));
    Json::Object(fields)
}

/// The `GET /metrics` body: the same three sources in Prometheus text
/// exposition format.
fn metrics_prometheus(engine: &Engine, batcher: &Batcher) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    batcher.stats().registry().render_prometheus(&mut out);
    obs::global().render_prometheus(&mut out);
    let _ = writeln!(
        out,
        "# HELP serve_engine_requests_served_total Requests the engine finished kernels for"
    );
    let _ = writeln!(out, "# TYPE serve_engine_requests_served_total counter");
    let _ = writeln!(out, "serve_engine_requests_served_total {}", engine.served());
    let _ = writeln!(
        out,
        "# HELP serve_engine_peak_workspace_bytes Engine kernel + hidden-buffer high-water mark"
    );
    let _ = writeln!(out, "# TYPE serve_engine_peak_workspace_bytes gauge");
    let _ = writeln!(
        out,
        "serve_engine_peak_workspace_bytes {}",
        engine.peak_workspace_bytes()
    );
    out
}

/// Accept loop of the metrics exporter: nonblocking accept + short sleep
/// so the thread notices `metrics_stop` promptly, one request per
/// connection (`Connection: close`).
fn metrics_loop(
    listener: TcpListener,
    engine: &Engine,
    batcher: &Batcher,
    draining: &AtomicBool,
    metrics_stop: &AtomicBool,
) {
    if listener.set_nonblocking(true).is_err() {
        return;
    }
    loop {
        match listener.accept() {
            Ok((stream, _)) => {
                // Blocking per-request I/O with bounded timeouts; requests
                // are tiny and rare (scrapes), so inline handling is fine.
                let _ = stream.set_nonblocking(false);
                serve_http(stream, engine, batcher, draining);
            }
            Err(_) => {
                if metrics_stop.load(Ordering::SeqCst) {
                    return;
                }
                std::thread::sleep(METRICS_POLL);
            }
        }
    }
}

/// Answer one HTTP/1.1 request: `GET /metrics`, `GET /healthz`, else 404.
fn serve_http(stream: TcpStream, engine: &Engine, batcher: &Batcher, draining: &AtomicBool) {
    let _ = stream.set_read_timeout(Some(Duration::from_secs(2)));
    let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
    let mut reader = match stream.try_clone() {
        Ok(clone) => BufReader::new(clone),
        Err(_) => return,
    };
    let mut request_line = String::new();
    if reader.read_line(&mut request_line).is_err() {
        return;
    }
    // Drain the (bounded) header block so the peer observes a clean close.
    let mut header = String::new();
    for _ in 0..64 {
        header.clear();
        match reader.read_line(&mut header) {
            Ok(n) if n > 0 && !header.trim().is_empty() => continue,
            _ => break,
        }
    }
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("");
    let path = parts.next().unwrap_or("");
    let (status, content_type, body) = match (method, path) {
        ("GET", "/metrics") => (
            "200 OK",
            "text/plain; version=0.0.4; charset=utf-8",
            metrics_prometheus(engine, batcher),
        ),
        ("GET", "/healthz") => {
            if draining.load(Ordering::SeqCst) {
                ("503 Service Unavailable", "text/plain; charset=utf-8", "draining\n".into())
            } else {
                ("200 OK", "text/plain; charset=utf-8", "ok\n".into())
            }
        }
        _ => ("404 Not Found", "text/plain; charset=utf-8", "not found\n".into()),
    };
    let mut writer = stream;
    let head = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    let _ = writer.write_all(head.as_bytes());
    let _ = writer.write_all(body.as_bytes());
    let _ = writer.flush();
}

fn write_json(writer: &mut TcpStream, json: &Json) -> std::io::Result<()> {
    let mut line = json.to_string();
    line.push('\n');
    writer.write_all(line.as_bytes())?;
    writer.flush()
}
