//! TCP front end: accept loop, per-connection line protocol, graceful
//! shutdown.
//!
//! Dependency-free: [`std::net::TcpListener`] + one thread per connection
//! reading newline-delimited JSON ([`super::protocol`]).  `generate` and
//! `score` go through the micro-batcher ([`super::batcher`]); `info` and
//! `shutdown` are answered inline.  Binding port 0 picks an ephemeral port
//! (the bound address is reported on [`Server::addr`]) — which is how the
//! CI smoke test and the integration tests avoid port collisions.
//!
//! Failure domains (PR 6): connections poll the socket with a short read
//! timeout instead of blocking forever, so a stalled client holds a thread
//! for at most [`ServeConfig::idle_timeout`] and shutdown never waits on a
//! silent peer; writes are bounded too.  Errors carry structured codes
//! ([`super::protocol::ErrorCode`]): a full queue answers `overloaded`
//! with a live `retry_after_ms` hint, and [`Server::join`] drains in-flight
//! work under [`ServeConfig::drain`] before stopping the workers.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::serve::batcher::{Batcher, Job};
use crate::serve::engine::Engine;
use crate::serve::protocol::{ErrorCode, Request, Response};
use crate::util::faults;
use crate::util::json::Json;

/// How often a connection thread wakes from a blocked read to check the
/// stop flag and the idle budget.
const READ_POLL: Duration = Duration::from_millis(200);

/// Bound on a single response write; a client that stops reading cannot
/// wedge its connection thread past this.
const WRITE_TIMEOUT: Duration = Duration::from_secs(10);

/// Server + batcher knobs (`cce serve` flags map 1:1).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub host: String,
    /// 0 = ephemeral.
    pub port: u16,
    /// Batch workers (kernel threads are a separate knob:
    /// [`crate::exec::KernelOptions::threads`]).
    pub workers: usize,
    /// Largest micro-batch.
    pub max_batch: usize,
    /// How long batch assembly waits for stragglers.
    pub max_wait: Duration,
    /// Bounded request-queue depth (backpressure threshold).
    pub queue_depth: usize,
    /// Hang up on a connection that sends no complete request for this
    /// long (slow-loris/stalled-client bound).
    pub idle_timeout: Duration,
    /// Graceful-shutdown budget: how long [`Server::join`] waits for
    /// in-flight jobs to finish before stopping the workers.
    pub drain: Duration,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            host: "127.0.0.1".into(),
            port: 0,
            workers: 2,
            max_batch: 8,
            max_wait: Duration::from_millis(3),
            queue_depth: 64,
            idle_timeout: Duration::from_secs(300),
            drain: Duration::from_secs(5),
        }
    }
}

/// A running server.  Dropping the handle does NOT stop it; call
/// [`Server::stop`] or send a `shutdown` request, then [`Server::join`].
pub struct Server {
    pub addr: SocketAddr,
    accept: Option<JoinHandle<()>>,
    batcher: Arc<Batcher>,
    stop: Arc<AtomicBool>,
    drain: Duration,
}

/// Bind, spawn the batcher + accept loop, and return immediately.
pub fn serve(engine: Arc<Engine>, cfg: &ServeConfig) -> Result<Server> {
    let listener = TcpListener::bind((cfg.host.as_str(), cfg.port))
        .with_context(|| format!("binding {}:{}", cfg.host, cfg.port))?;
    let addr = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let batcher = Arc::new(Batcher::start(
        engine.clone(),
        cfg.workers,
        cfg.max_batch,
        cfg.max_wait,
        cfg.queue_depth,
    ));
    let accept = {
        let batcher = batcher.clone();
        let stop = stop.clone();
        let idle_timeout = cfg.idle_timeout;
        std::thread::spawn(move || {
            accept_loop(listener, addr, engine, batcher, stop, idle_timeout)
        })
    };
    Ok(Server { addr, accept: Some(accept), batcher, stop, drain: cfg.drain })
}

impl Server {
    /// Request shutdown from this process (equivalent to a client sending
    /// `{"op":"shutdown"}`).
    pub fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
        // Wake the blocking accept() so the loop observes the flag.
        let _ = TcpStream::connect(self.addr);
    }

    /// Wait for the accept loop to exit, drain in-flight jobs under the
    /// configured [`ServeConfig::drain`] budget, then stop the workers.
    /// Once the accept loop is down no new work can arrive, so the drain
    /// is monotone; if the budget runs out the remaining jobs are dropped
    /// and their clients observe `shutting_down`.
    pub fn join(mut self) -> Result<()> {
        if let Some(handle) = self.accept.take() {
            handle.join().map_err(|_| anyhow::anyhow!("accept thread panicked"))?;
        }
        if !self.batcher.drain(self.drain) {
            eprintln!(
                "[serve] drain budget ({:?}) exhausted with {} job(s) in flight; dropping",
                self.drain,
                self.batcher.in_flight()
            );
        }
        self.batcher.shutdown();
        Ok(())
    }
}

fn accept_loop(
    listener: TcpListener,
    addr: SocketAddr,
    engine: Arc<Engine>,
    batcher: Arc<Batcher>,
    stop: Arc<AtomicBool>,
    idle_timeout: Duration,
) {
    for stream in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let stream = match stream {
            Ok(stream) => stream,
            Err(_) => continue,
        };
        let engine = engine.clone();
        let batcher = batcher.clone();
        let stop = stop.clone();
        // One thread per connection: connections are long-lived and few at
        // this substrate's scale; concurrency inside a connection comes
        // from the batcher, not from here.
        std::thread::spawn(move || {
            connection(stream, addr, &engine, &batcher, &stop, idle_timeout)
        });
    }
}

/// Serve one connection until EOF, error, idle timeout, or shutdown.
fn connection(
    stream: TcpStream,
    addr: SocketAddr,
    engine: &Engine,
    batcher: &Batcher,
    stop: &AtomicBool,
    idle_timeout: Duration,
) {
    let _ = stream.set_nodelay(true);
    // Reads poll so this thread can notice stop/idle; writes are bounded so
    // a client that stops reading cannot wedge us.
    let _ = stream.set_read_timeout(Some(READ_POLL));
    let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
    let mut reader = match stream.try_clone() {
        Ok(clone) => BufReader::new(clone),
        Err(_) => return,
    };
    let mut writer = stream;
    // One line buffer across poll iterations: a read that times out
    // mid-line leaves its partial bytes here (read_line appends), so
    // nothing is lost when the next poll resumes.
    let mut line = String::new();
    let mut idle_since = Instant::now();
    loop {
        match reader.read_line(&mut line) {
            Ok(n) => {
                // Without a trailing newline the peer hit EOF mid-line;
                // serve what arrived, then hang up.
                let at_eof = n == 0 || !line.ends_with('\n');
                if !line.trim().is_empty()
                    && handle_line(line.trim(), &mut writer, addr, engine, batcher, stop).is_err()
                {
                    return;
                }
                line.clear();
                idle_since = Instant::now();
                if at_eof {
                    return;
                }
            }
            Err(err)
                if matches!(
                    err.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                // Poll tick: no complete line yet (partial bytes, if any,
                // stay in `line`).
                if stop.load(Ordering::SeqCst) || idle_since.elapsed() >= idle_timeout {
                    return;
                }
            }
            Err(_) => return,
        }
    }
}

/// Parse and answer one complete request line.  `Err(())` means the
/// connection is done (write failed or shutdown was requested).
fn handle_line(
    line: &str,
    writer: &mut TcpStream,
    addr: SocketAddr,
    engine: &Engine,
    batcher: &Batcher,
    stop: &AtomicBool,
) -> std::result::Result<(), ()> {
    // Chaos site: simulate a stalled connection handler.
    faults::stall("conn.stall_ms");
    let response = match Request::parse(line) {
        Err(err) => Response::err(ErrorCode::InvalidRequest, format!("bad request: {err:#}")),
        Ok(Request::Info) => Response::Info(info_fields(engine, batcher)),
        Ok(Request::Shutdown) => {
            let _ = write_line(writer, &Response::Shutdown);
            stop.store(true, Ordering::SeqCst);
            let _ = TcpStream::connect(addr); // wake accept()
            return Err(());
        }
        Ok(request) => dispatch(request, batcher, stop),
    };
    write_line(writer, &response).map_err(|_| ())
}

/// Route a batchable request through the micro-batcher and wait for its
/// response.
fn dispatch(request: Request, batcher: &Batcher, stop: &AtomicBool) -> Response {
    if stop.load(Ordering::SeqCst) {
        return Response::err(ErrorCode::ShuttingDown, "server is shutting down");
    }
    let (tx, rx) = mpsc::channel();
    match batcher.submit(Job::new(request, tx)) {
        // Admission control: shed at the door with a live retry hint
        // rather than buffering unboundedly.
        Err(_) => Response::overloaded(
            "queue full (admission control): retry later",
            batcher.retry_after_ms(),
        ),
        Ok(()) => match rx.recv_timeout(Duration::from_secs(300)) {
            Ok(response) => response,
            // Sender dropped: shutdown raced the job out of the queue.
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                Response::err(ErrorCode::ShuttingDown, "request dropped during shutdown")
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                Response::err(ErrorCode::Internal, "request timed out inside the server")
            }
        },
    }
}

fn info_fields(engine: &Engine, batcher: &Batcher) -> Json {
    let stats = batcher.stats();
    let mut fields: Vec<(String, Json)> = match engine.info_json() {
        Json::Object(entries) => entries,
        other => vec![("model_info".into(), other)],
    };
    fields.push((
        "batches".into(),
        Json::Int(stats.batches.load(Ordering::Relaxed) as i64),
    ));
    fields.push((
        "batched_jobs".into(),
        Json::Int(stats.jobs.load(Ordering::Relaxed) as i64),
    ));
    fields.push((
        "max_batch_observed".into(),
        Json::Int(stats.max_batch.load(Ordering::Relaxed) as i64),
    ));
    fields.push((
        "shed_deadline".into(),
        Json::Int(stats.shed_deadline.load(Ordering::Relaxed) as i64),
    ));
    fields.push((
        "batch_panics".into(),
        Json::Int(stats.panics.load(Ordering::Relaxed) as i64),
    ));
    fields.push(("in_flight".into(), Json::Int(batcher.in_flight() as i64)));
    Json::Object(fields)
}

fn write_line(writer: &mut TcpStream, response: &Response) -> std::io::Result<()> {
    let mut line = response.to_line();
    line.push('\n');
    writer.write_all(line.as_bytes())?;
    writer.flush()
}
