//! Dependency-free HTTP/1.1 framing for the serve front door.
//!
//! This is deliberately a *framing* module, not a framework: it parses one
//! request (request line, headers, `Content-Length` or `chunked` body) off
//! a byte stream with hard size bounds, and writes one response back.  The
//! routing, batching, admission control, and failure semantics all live in
//! [`crate::serve::server`] — the HTTP layer only translates them:
//!
//! | [`ErrorCode`]        | HTTP status | extra                          |
//! |----------------------|-------------|--------------------------------|
//! | `invalid_request`    | 400         |                                |
//! | `overloaded`         | 429         | `Retry-After` (seconds, ceil)  |
//! | `shutting_down`      | 503         |                                |
//! | `deadline_exceeded`  | 504         |                                |
//! | `internal`           | 500         |                                |
//!
//! Framing failures have their own statuses: an unparseable request line
//! or malformed chunked body is `400`, headers past
//! [`Limits::max_header_bytes`] are `431`, a body past
//! [`Limits::max_body_bytes`] is `413`.
//!
//! [`Conn`] is generic over `Read` so every parse path is unit-testable on
//! in-memory buffers; over a `TcpStream` the caller sets a read timeout
//! and gets [`HttpError::Idle`] back while a keep-alive connection sits
//! quiet, which is what lets the server poll its stop flag between
//! requests.  A small blocking client ([`http_call`]) rides the same
//! parser for tests and `servebench --http`.

use std::io::{self, Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use crate::serve::protocol::ErrorCode;
use crate::util::json::Json;

/// Hard size bounds on one request.  Both are generous for an inference
/// API (prompts are bounded by the engine's own token caps long before
/// this) and small enough that a hostile peer cannot balloon memory.
#[derive(Debug, Clone, Copy)]
pub struct Limits {
    /// Request line + headers, bytes, including the terminating CRLFCRLF.
    pub max_header_bytes: usize,
    /// Decoded body bytes (`Content-Length` value or summed chunk sizes).
    pub max_body_bytes: usize,
}

impl Default for Limits {
    fn default() -> Limits {
        Limits { max_header_bytes: 16 * 1024, max_body_bytes: 1024 * 1024 }
    }
}

/// One parsed request.  Header names are lowercased at parse time;
/// values keep their bytes (trimmed).
#[derive(Debug)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
    /// What the client asked for (HTTP/1.1 defaults to keep-alive,
    /// HTTP/1.0 to close, `Connection:` overrides either way).  The
    /// server may still choose to close — e.g. after an SSE stream.
    pub keep_alive: bool,
}

impl HttpRequest {
    /// Case-insensitive header lookup (names were lowercased at parse).
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(k, _)| *k == name).map(|(_, v)| v.as_str())
    }
}

/// Why a request could not be read.  `Idle` and `Closed` are normal
/// connection-lifecycle events, not protocol errors.
#[derive(Debug)]
pub enum HttpError {
    /// Read timeout with no request bytes pending: the keep-alive
    /// connection is just quiet.  Poll your stop flag and call again.
    Idle,
    /// Clean EOF with no request bytes pending: the peer hung up.
    Closed,
    /// Timeout or EOF *mid-request*: the peer stalled or died partway.
    Stalled,
    /// Headers exceeded [`Limits::max_header_bytes`] → `431`.
    HeadersTooLarge,
    /// Body exceeded [`Limits::max_body_bytes`] → `413`.
    BodyTooLarge,
    /// Unparseable request line / header / chunk framing → `400`.
    Bad(String),
    Io(io::Error),
}

/// Buffered request reader over one connection.  Bytes that arrive ahead
/// of a full request survive across [`Conn::read_request`] calls, so a
/// poll-timeout mid-headers resumes where it left off.
pub struct Conn<R: Read> {
    r: R,
    buf: Vec<u8>,
}

impl<R: Read> Conn<R> {
    pub fn new(r: R) -> Conn<R> {
        Conn { r, buf: Vec::new() }
    }

    /// Whether a partial request is already buffered (distinguishes an
    /// idle keep-alive connection from one that stalled mid-request).
    pub fn pending(&self) -> bool {
        !self.buf.is_empty()
    }

    /// Read and parse one request, honoring `lim`.
    pub fn read_request(&mut self, lim: &Limits) -> Result<HttpRequest, HttpError> {
        let head_end = loop {
            if let Some(end) = find(&self.buf, b"\r\n\r\n") {
                break end;
            }
            if self.buf.len() > lim.max_header_bytes {
                return Err(HttpError::HeadersTooLarge);
            }
            self.fill()?;
        };
        if head_end + 4 > lim.max_header_bytes {
            return Err(HttpError::HeadersTooLarge);
        }
        let head = String::from_utf8_lossy(&self.buf[..head_end]).into_owned();
        self.buf.drain(..head_end + 4);

        let mut lines = head.split("\r\n");
        let request_line = lines.next().unwrap_or_default();
        let mut parts = request_line.split(' ');
        let method = parts.next().unwrap_or_default().to_string();
        let path = parts.next().unwrap_or_default().to_string();
        let version = parts.next().unwrap_or_default();
        if method.is_empty()
            || path.is_empty()
            || parts.next().is_some()
            || !method.chars().all(|c| c.is_ascii_uppercase())
            || !version.starts_with("HTTP/1.")
        {
            return Err(HttpError::Bad(format!("malformed request line {request_line:?}")));
        }

        let mut headers = Vec::new();
        for line in lines {
            let (name, value) = line
                .split_once(':')
                .ok_or_else(|| HttpError::Bad(format!("malformed header line {line:?}")))?;
            if name.is_empty() || name.ends_with(' ') || name.ends_with('\t') {
                return Err(HttpError::Bad(format!("malformed header name {name:?}")));
            }
            headers.push((name.to_ascii_lowercase(), value.trim().to_string()));
        }

        let header = |name: &str| {
            headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
        };
        let mut keep_alive = version != "HTTP/1.0";
        if let Some(conn) = header("connection") {
            let conn = conn.to_ascii_lowercase();
            if conn.contains("close") {
                keep_alive = false;
            } else if conn.contains("keep-alive") {
                keep_alive = true;
            }
        }

        let chunked = header("transfer-encoding")
            .map(|v| v.to_ascii_lowercase().contains("chunked"))
            .unwrap_or(false);
        let body = if chunked {
            self.read_chunked_body(lim)?
        } else if let Some(cl) = header("content-length") {
            let n: usize = cl
                .trim()
                .parse()
                .map_err(|_| HttpError::Bad(format!("bad content-length {cl:?}")))?;
            if n > lim.max_body_bytes {
                return Err(HttpError::BodyTooLarge);
            }
            self.take(n)?
        } else {
            Vec::new()
        };

        Ok(HttpRequest { method, path, headers, body, keep_alive })
    }

    fn read_chunked_body(&mut self, lim: &Limits) -> Result<Vec<u8>, HttpError> {
        let mut body = Vec::new();
        loop {
            let line = self.read_line()?;
            let size_hex = line.split(';').next().unwrap_or_default().trim();
            let size = usize::from_str_radix(size_hex, 16)
                .map_err(|_| HttpError::Bad(format!("bad chunk size {line:?}")))?;
            if body.len() + size > lim.max_body_bytes {
                return Err(HttpError::BodyTooLarge);
            }
            if size == 0 {
                // Trailer section: header lines until a blank one.
                loop {
                    if self.read_line()?.is_empty() {
                        break;
                    }
                }
                return Ok(body);
            }
            body.extend_from_slice(&self.take(size)?);
            if self.take(2)? != b"\r\n" {
                return Err(HttpError::Bad("chunk data not CRLF-terminated".into()));
            }
        }
    }

    /// One CRLF-terminated line (CRLF consumed, not returned); bounded so
    /// a hostile chunk header cannot grow the buffer unboundedly.
    fn read_line(&mut self) -> Result<String, HttpError> {
        loop {
            if let Some(end) = find(&self.buf, b"\r\n") {
                let line = String::from_utf8_lossy(&self.buf[..end]).into_owned();
                self.buf.drain(..end + 2);
                return Ok(line);
            }
            if self.buf.len() > 8 * 1024 {
                return Err(HttpError::Bad("chunk/trailer line too long".into()));
            }
            self.fill().map_err(HttpError::mid_request)?;
        }
    }

    /// Exactly `n` bytes off the front of the stream.
    fn take(&mut self, n: usize) -> Result<Vec<u8>, HttpError> {
        while self.buf.len() < n {
            self.fill().map_err(HttpError::mid_request)?;
        }
        let rest = self.buf.split_off(n);
        Ok(std::mem::replace(&mut self.buf, rest))
    }

    /// Pull more bytes off the stream into the buffer.
    fn fill(&mut self) -> Result<(), HttpError> {
        let mut chunk = [0u8; 4096];
        match self.r.read(&mut chunk) {
            Ok(0) => Err(if self.buf.is_empty() { HttpError::Closed } else { HttpError::Stalled }),
            Ok(n) => {
                self.buf.extend_from_slice(&chunk[..n]);
                Ok(())
            }
            Err(e)
                if e.kind() == io::ErrorKind::WouldBlock
                    || e.kind() == io::ErrorKind::TimedOut =>
            {
                Err(if self.buf.is_empty() { HttpError::Idle } else { HttpError::Stalled })
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => Ok(()),
            Err(e) => Err(HttpError::Io(e)),
        }
    }
}

impl HttpError {
    /// Once a request's header section has been consumed, "no bytes
    /// pending" no longer means idle/closed — the peer stalled mid-body.
    fn mid_request(self) -> HttpError {
        match self {
            HttpError::Idle | HttpError::Closed => HttpError::Stalled,
            other => other,
        }
    }
}

fn find(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

/// The HTTP status a structured serve error maps onto.
pub fn status_for(code: ErrorCode) -> u32 {
    match code {
        ErrorCode::InvalidRequest => 400,
        ErrorCode::Overloaded => 429,
        ErrorCode::ShuttingDown => 503,
        ErrorCode::DeadlineExceeded => 504,
        ErrorCode::Internal => 500,
    }
}

pub fn status_text(status: u32) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

/// The JSON error body every non-2xx API response carries:
/// `{"error":{"code":...,"message":...[,"retry_after_ms":...]}}`.
pub fn error_body(code: ErrorCode, message: &str, retry_after_ms: Option<u64>) -> String {
    let mut fields = vec![
        ("code", Json::str(code.as_str())),
        ("message", Json::str(message)),
    ];
    if let Some(ms) = retry_after_ms {
        fields.push(("retry_after_ms", Json::Int(ms as i64)));
    }
    let mut body = Json::obj(vec![("error", Json::obj(fields))]).to_string();
    body.push('\n');
    body
}

/// `Retry-After` is whole seconds; round the millisecond hint up so a
/// client that honors it never retries early.
pub fn retry_after_secs(retry_after_ms: u64) -> u64 {
    let secs = retry_after_ms / 1000 + u64::from(retry_after_ms % 1000 != 0);
    secs.max(1)
}

/// Write one complete response with `Content-Length` framing.
pub fn write_response(
    w: &mut impl Write,
    status: u32,
    content_type: &str,
    extra_headers: &[(&str, String)],
    body: &[u8],
    keep_alive: bool,
) -> io::Result<()> {
    let mut head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\n",
        status,
        status_text(status),
        content_type,
        body.len()
    );
    for (name, value) in extra_headers {
        head.push_str(name);
        head.push_str(": ");
        head.push_str(value);
        head.push_str("\r\n");
    }
    head.push_str(if keep_alive {
        "Connection: keep-alive\r\n\r\n"
    } else {
        "Connection: close\r\n\r\n"
    });
    w.write_all(head.as_bytes())?;
    w.write_all(body)?;
    w.flush()
}

/// Write a structured serve error as an HTTP response (status per
/// [`status_for`], `Retry-After` from the admission-control hint).
pub fn write_error(
    w: &mut impl Write,
    code: ErrorCode,
    message: &str,
    retry_after_ms: Option<u64>,
    keep_alive: bool,
) -> io::Result<()> {
    let mut extra = Vec::new();
    if let Some(ms) = retry_after_ms {
        extra.push(("Retry-After", retry_after_secs(ms).to_string()));
    }
    write_response(
        w,
        status_for(code),
        "application/json",
        &extra,
        error_body(code, message, retry_after_ms).as_bytes(),
        keep_alive,
    )
}

/// Minimal blocking HTTP client over one connection: used by
/// `servebench --http` and the conformance tests, so the bench drives the
/// server through exactly the parser-visible wire format.
pub fn http_call(
    addr: &str,
    method: &str,
    path: &str,
    body: &[u8],
    timeout: Duration,
) -> io::Result<(u32, Vec<(String, String)>, Vec<u8>)> {
    let stream = TcpStream::connect(addr)?;
    stream.set_read_timeout(Some(timeout))?;
    stream.set_write_timeout(Some(timeout))?;
    let mut stream = stream;
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body)?;
    stream.flush()?;
    read_http_response(&mut stream)
}

/// Parse one `(status, headers, body)` response off a stream.  The body is
/// read to `Content-Length` when present, else to EOF (SSE responses
/// arrive whole this way once the server closes).
pub fn read_http_response(
    r: &mut impl Read,
) -> io::Result<(u32, Vec<(String, String)>, Vec<u8>)> {
    let mut raw = Vec::new();
    let mut chunk = [0u8; 4096];
    let head_end = loop {
        if let Some(end) = find(&raw, b"\r\n\r\n") {
            break end;
        }
        match r.read(&mut chunk) {
            Ok(0) => {
                return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "eof in response head"))
            }
            Ok(n) => raw.extend_from_slice(&chunk[..n]),
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    };
    let head = String::from_utf8_lossy(&raw[..head_end]).into_owned();
    let mut body: Vec<u8> = raw[head_end + 4..].to_vec();
    let mut lines = head.split("\r\n");
    let status: u32 = lines
        .next()
        .and_then(|l| l.split(' ').nth(1))
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| io::Error::new(io::ErrorKind::InvalidData, "bad status line"))?;
    let headers: Vec<(String, String)> = lines
        .filter_map(|l| l.split_once(':'))
        .map(|(k, v)| (k.to_ascii_lowercase(), v.trim().to_string()))
        .collect();
    let content_length = headers
        .iter()
        .find(|(k, _)| k == "content-length")
        .and_then(|(_, v)| v.parse::<usize>().ok());
    loop {
        if let Some(cl) = content_length {
            if body.len() >= cl {
                body.truncate(cl);
                break;
            }
        }
        match r.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => body.extend_from_slice(&chunk[..n]),
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok((status, headers, body))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(raw: &[u8]) -> Result<HttpRequest, HttpError> {
        Conn::new(raw).read_request(&Limits::default())
    }

    #[test]
    fn parses_simple_get() {
        let req = parse(b"GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/healthz");
        assert_eq!(req.header("host"), Some("x"));
        assert!(req.body.is_empty());
        assert!(req.keep_alive, "HTTP/1.1 defaults to keep-alive");
    }

    #[test]
    fn parses_content_length_body_and_pipelining() {
        let mut conn = Conn::new(
            &b"POST /v1/score HTTP/1.1\r\nContent-Length: 4\r\n\r\nabcdGET / HTTP/1.1\r\n\r\n"
                [..],
        );
        let req = conn.read_request(&Limits::default()).unwrap();
        assert_eq!(req.body, b"abcd");
        // Bytes past the body belong to the next request.
        let next = conn.read_request(&Limits::default()).unwrap();
        assert_eq!(next.method, "GET");
    }

    #[test]
    fn parses_chunked_body() {
        let raw = b"POST /v1/score HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n\
                    4\r\nWiki\r\n5\r\npedia\r\n0\r\n\r\n";
        let req = parse(raw).unwrap();
        assert_eq!(req.body, b"Wikipedia");
    }

    #[test]
    fn connection_header_overrides_version_default() {
        let req = parse(b"GET / HTTP/1.1\r\nConnection: close\r\n\r\n").unwrap();
        assert!(!req.keep_alive);
        let req = parse(b"GET / HTTP/1.0\r\n\r\n").unwrap();
        assert!(!req.keep_alive, "HTTP/1.0 defaults to close");
        let req = parse(b"GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n").unwrap();
        assert!(req.keep_alive);
    }

    #[test]
    fn rejects_malformed_framing() {
        assert!(matches!(parse(b"BLARG\r\n\r\n"), Err(HttpError::Bad(_))));
        assert!(matches!(parse(b"GET\r\n\r\n"), Err(HttpError::Bad(_))));
        assert!(matches!(parse(b"get / HTTP/1.1\r\n\r\n"), Err(HttpError::Bad(_))));
        assert!(matches!(parse(b"GET / SPDY/3\r\n\r\n"), Err(HttpError::Bad(_))));
        assert!(matches!(
            parse(b"GET / HTTP/1.1\r\nno-colon-here\r\n\r\n"),
            Err(HttpError::Bad(_))
        ));
        assert!(matches!(
            parse(b"POST / HTTP/1.1\r\nContent-Length: pony\r\n\r\n"),
            Err(HttpError::Bad(_))
        ));
        assert!(matches!(
            parse(b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\nzz\r\n"),
            Err(HttpError::Bad(_))
        ));
    }

    #[test]
    fn bounds_are_enforced() {
        let lim = Limits { max_header_bytes: 64, max_body_bytes: 8 };
        let big_header = format!("GET / HTTP/1.1\r\nX-Pad: {}\r\n\r\n", "a".repeat(256));
        assert!(matches!(
            Conn::new(big_header.as_bytes()).read_request(&lim),
            Err(HttpError::HeadersTooLarge)
        ));
        let big_body = b"POST / HTTP/1.1\r\nContent-Length: 64\r\n\r\n";
        assert!(matches!(
            Conn::new(&big_body[..]).read_request(&lim),
            Err(HttpError::BodyTooLarge)
        ));
        let big_chunk = b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\nff\r\n";
        assert!(matches!(
            Conn::new(&big_chunk[..]).read_request(&lim),
            Err(HttpError::BodyTooLarge)
        ));
    }

    #[test]
    fn eof_classification() {
        assert!(matches!(parse(b""), Err(HttpError::Closed)));
        assert!(matches!(parse(b"GET / HT"), Err(HttpError::Stalled)));
        assert!(matches!(
            parse(b"POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nab"),
            Err(HttpError::Stalled)
        ));
    }

    #[test]
    fn error_code_status_map_is_total() {
        for code in ErrorCode::ALL {
            let status = status_for(code);
            assert!((400..=504).contains(&status), "{code:?} -> {status}");
            assert_ne!(status_text(status), "Unknown");
        }
        assert_eq!(status_for(ErrorCode::InvalidRequest), 400);
        assert_eq!(status_for(ErrorCode::Overloaded), 429);
        assert_eq!(status_for(ErrorCode::ShuttingDown), 503);
        assert_eq!(status_for(ErrorCode::DeadlineExceeded), 504);
        assert_eq!(status_for(ErrorCode::Internal), 500);
    }

    #[test]
    fn error_body_shape_and_retry_after() {
        let body = error_body(ErrorCode::Overloaded, "queue full", Some(40));
        let j = Json::parse(&body).unwrap();
        let err = j.req("error").unwrap();
        assert_eq!(err.req("code").unwrap().as_str(), Some("overloaded"));
        assert_eq!(err.req("retry_after_ms").unwrap().as_i64(), Some(40));
        assert_eq!(retry_after_secs(40), 1, "sub-second hints round up to 1s");
        assert_eq!(retry_after_secs(2_400), 3);
    }

    #[test]
    fn response_writer_round_trips_through_response_reader() {
        let mut wire = Vec::new();
        write_response(
            &mut wire,
            429,
            "application/json",
            &[("Retry-After", "1".to_string())],
            b"{}",
            true,
        )
        .unwrap();
        let (status, headers, body) = read_http_response(&mut &wire[..]).unwrap();
        assert_eq!(status, 429);
        assert_eq!(body, b"{}");
        let get = |n: &str| headers.iter().find(|(k, _)| k == n).map(|(_, v)| v.clone());
        assert_eq!(get("retry-after"), Some("1".to_string()));
        assert_eq!(get("content-length"), Some("2".to_string()));
        assert_eq!(get("connection"), Some("keep-alive".to_string()));
    }
}
