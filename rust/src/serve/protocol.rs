//! Line-delimited JSON wire protocol: one request object per line in, one
//! response object per line out, over a plain TCP stream.
//!
//! Requests (`op` selects the endpoint; batchable ops may carry a
//! `deadline_ms` budget — the server sheds the job with
//! `deadline_exceeded` instead of running kernels for an answer nobody is
//! waiting for):
//!
//! ```text
//! {"op":"generate","prompt":"...","max_tokens":32,"top_k":8,"temperature":0.7,"seed":1,"deadline_ms":250}
//! {"op":"score","text":"...","deadline_ms":250}
//! {"op":"info"}
//! {"op":"metrics"}
//! {"op":"shutdown"}
//! ```
//!
//! Batchable ops may also set `"trace":true` to have the server echo a
//! per-request `timings` object (queue/assembly/kernel microseconds —
//! see [`crate::obs::StageTimings`]) next to the normal response fields.
//!
//! Responses always carry `"ok"`; successes echo `"op"`, failures carry a
//! machine-readable `code` (see [`ErrorCode`]) next to the human-readable
//! `error`, plus `retry_after_ms` when the server can estimate when retry
//! will succeed (`overloaded`):
//!
//! ```text
//! {"ok":true,"op":"generate","text":"...","tokens":[...],"logprobs":[...]}
//! {"ok":true,"op":"score","nll":2.1,"perplexity":8.2,"count":12,"logprobs":[...]}
//! {"ok":true,"op":"info", ...model/server fields...}
//! {"ok":true,"op":"metrics", ...metric families...}
//! {"ok":true,"op":"shutdown"}
//! {"ok":false,"code":"overloaded","error":"...","retry_after_ms":40}
//! ```
//!
//! Everything is built on [`crate::util::json`] — no external crates, and
//! the same parser both sides of the wire.

use anyhow::{anyhow, bail, Result};

use crate::util::json::Json;

/// Sampling parameters of one `generate` request.
///
/// `temperature == 0` is greedy argmax; `top_k == 0` with a positive
/// temperature samples the full vocabulary (blocked Gumbel-max); `top_k >=
/// 1` restricts sampling to the k best tokens.  `seed` makes the request
/// reproducible.
#[derive(Debug, Clone, PartialEq)]
pub struct GenParams {
    pub prompt: String,
    pub max_tokens: usize,
    pub top_k: usize,
    pub temperature: f32,
    pub seed: u64,
    /// Latency budget in milliseconds, measured from server receipt.
    /// `0` = no deadline.  An expired job is shed *before* kernel work
    /// with a `deadline_exceeded` error.
    pub deadline_ms: u64,
    /// Echo per-request stage timings (`timings` object) in the response.
    pub trace: bool,
    /// Routing tag for multi-checkpoint servers (`--checkpoint tag=path`).
    /// `None` routes to the server's default model; an unknown tag is an
    /// `invalid_request`.  Stays off the wire when unset so single-model
    /// deployments never see it.
    pub model: Option<String>,
}

impl Default for GenParams {
    fn default() -> GenParams {
        GenParams {
            prompt: String::new(),
            max_tokens: 32,
            top_k: 0,
            temperature: 0.0,
            seed: 0,
            deadline_ms: 0,
            trace: false,
            model: None,
        }
    }
}

impl GenParams {
    /// Parse sampling parameters out of a request body (the `generate`
    /// fields minus `op`) — shared by the line-JSON protocol and the HTTP
    /// `POST /v1/generate` body, which carry the same field set.
    pub fn from_json(j: &Json) -> Result<GenParams> {
        let defaults = GenParams::default();
        Ok(GenParams {
            prompt: j
                .get("prompt")
                .and_then(|v| v.as_str())
                .unwrap_or_default()
                .to_string(),
            max_tokens: get_usize(j, "max_tokens", defaults.max_tokens)?,
            top_k: get_usize(j, "top_k", defaults.top_k)?,
            temperature: match j.get("temperature") {
                None => defaults.temperature,
                Some(v) => v
                    .as_f64()
                    .ok_or_else(|| anyhow!("temperature must be a number"))?
                    as f32,
            },
            seed: get_u64_wire(j, "seed", 0)?,
            deadline_ms: get_u64_wire(j, "deadline_ms", 0)?,
            trace: get_trace(j),
            model: get_model(j),
        })
    }
}

/// A parsed client request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Generate(GenParams),
    Score { text: String, deadline_ms: u64, trace: bool, model: Option<String> },
    Info,
    Metrics,
    Shutdown,
}

impl Request {
    pub fn to_json(&self) -> Json {
        match self {
            Request::Generate(p) => {
                let mut entries = vec![
                    ("op", Json::str("generate")),
                    ("prompt", Json::str(&p.prompt)),
                    ("max_tokens", Json::Int(p.max_tokens as i64)),
                    ("top_k", Json::Int(p.top_k as i64)),
                    ("temperature", Json::Float(p.temperature as f64)),
                    ("seed", Json::Int(p.seed as i64)),
                ];
                if p.deadline_ms > 0 {
                    entries.push(("deadline_ms", Json::Int(p.deadline_ms as i64)));
                }
                if p.trace {
                    entries.push(("trace", Json::Bool(true)));
                }
                if let Some(m) = &p.model {
                    entries.push(("model", Json::str(m)));
                }
                Json::obj(entries)
            }
            Request::Score { text, deadline_ms, trace, model } => {
                let mut entries = vec![("op", Json::str("score")), ("text", Json::str(text))];
                if *deadline_ms > 0 {
                    entries.push(("deadline_ms", Json::Int(*deadline_ms as i64)));
                }
                if *trace {
                    entries.push(("trace", Json::Bool(true)));
                }
                if let Some(m) = model {
                    entries.push(("model", Json::str(m)));
                }
                Json::obj(entries)
            }
            Request::Info => Json::obj(vec![("op", Json::str("info"))]),
            Request::Metrics => Json::obj(vec![("op", Json::str("metrics"))]),
            Request::Shutdown => Json::obj(vec![("op", Json::str("shutdown"))]),
        }
    }

    pub fn from_json(j: &Json) -> Result<Request> {
        let op = j.req("op")?.as_str().ok_or_else(|| anyhow!("op must be a string"))?;
        match op {
            "generate" => Ok(Request::Generate(GenParams::from_json(j)?)),
            "score" => score_from_json(j),
            "info" => Ok(Request::Info),
            "metrics" => Ok(Request::Metrics),
            "shutdown" => Ok(Request::Shutdown),
            other => bail!("unknown op {other:?} (generate|score|info|metrics|shutdown)"),
        }
    }

    /// Parse one wire line.
    pub fn parse(line: &str) -> Result<Request> {
        Request::from_json(&Json::parse(line.trim())?)
    }

    /// Serialize as one wire line (no trailing newline).
    pub fn to_line(&self) -> String {
        self.to_json().to_string()
    }

    /// The request's latency budget, if it set one (`deadline_ms > 0`).
    pub fn deadline_ms(&self) -> Option<u64> {
        match self {
            Request::Generate(p) if p.deadline_ms > 0 => Some(p.deadline_ms),
            Request::Score { deadline_ms, .. } if *deadline_ms > 0 => Some(*deadline_ms),
            _ => None,
        }
    }

    /// Whether the request asked for per-request stage timings.
    pub fn trace(&self) -> bool {
        match self {
            Request::Generate(p) => p.trace,
            Request::Score { trace, .. } => *trace,
            _ => false,
        }
    }

    /// The routing tag the request asked for, if any.
    pub fn model(&self) -> Option<&str> {
        match self {
            Request::Generate(p) => p.model.as_deref(),
            Request::Score { model, .. } => model.as_deref(),
            _ => None,
        }
    }
}

/// Parse a `score` request body (the `score` fields minus `op`) — shared
/// by the line-JSON protocol and the HTTP `POST /v1/score` body.
pub fn score_from_json(j: &Json) -> Result<Request> {
    let text = j
        .req("text")?
        .as_str()
        .ok_or_else(|| anyhow!("text must be a string"))?;
    Ok(Request::Score {
        text: text.to_string(),
        deadline_ms: get_u64_wire(j, "deadline_ms", 0)?,
        trace: get_trace(j),
        model: get_model(j),
    })
}

/// Machine-readable failure class of an error response — what a client
/// switches on to decide retry vs give up (the human-readable `error`
/// message is for logs, not control flow).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorCode {
    /// The request itself is unusable (parse failure, bad parameters,
    /// oversized text).  Retrying the same bytes cannot succeed.
    InvalidRequest,
    /// Admission control shed the request: the bounded queue is full.
    /// Retry after `retry_after_ms`.
    Overloaded,
    /// The request's own `deadline_ms` expired before kernel work started.
    DeadlineExceeded,
    /// The server failed internally (e.g. a panic isolated at the batch
    /// boundary).  The request was not necessarily at fault.
    Internal,
    /// The server is draining; no new work is admitted.
    ShuttingDown,
}

impl ErrorCode {
    /// Every code, for exhaustive round-trip tests.
    pub const ALL: [ErrorCode; 5] = [
        ErrorCode::InvalidRequest,
        ErrorCode::Overloaded,
        ErrorCode::DeadlineExceeded,
        ErrorCode::Internal,
        ErrorCode::ShuttingDown,
    ];

    pub fn as_str(&self) -> &'static str {
        match self {
            ErrorCode::InvalidRequest => "invalid_request",
            ErrorCode::Overloaded => "overloaded",
            ErrorCode::DeadlineExceeded => "deadline_exceeded",
            ErrorCode::Internal => "internal",
            ErrorCode::ShuttingDown => "shutting_down",
        }
    }

    /// Lenient parse: unknown codes (from a newer server) degrade to
    /// `internal` rather than failing the whole response.
    pub fn parse(s: &str) -> ErrorCode {
        match s {
            "invalid_request" => ErrorCode::InvalidRequest,
            "overloaded" => ErrorCode::Overloaded,
            "deadline_exceeded" => ErrorCode::DeadlineExceeded,
            "shutting_down" => ErrorCode::ShuttingDown,
            _ => ErrorCode::Internal,
        }
    }

    /// Whether the same request can succeed on a later attempt.
    pub fn retryable(&self) -> bool {
        matches!(self, ErrorCode::Overloaded)
    }
}

/// A server response.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Generate { text: String, tokens: Vec<i32>, logprobs: Vec<f32> },
    Score { nll: f64, perplexity: f64, count: usize, logprobs: Vec<f32> },
    /// `info` payload: an open field set (model dims, step, peak workspace,
    /// batcher counters) so the endpoint can grow without protocol breaks.
    Info(Json),
    /// `metrics` payload: one field per registered metric family (counters
    /// and gauges as numbers, histograms as `{count,sum,p50,p90,p99}`) —
    /// the line-JSON twin of `GET /metrics`, open like `info`.
    Metrics(Json),
    /// Shutdown acknowledged.
    Shutdown,
    Error {
        code: ErrorCode,
        message: String,
        /// Server's estimate of when a retry will be admitted
        /// (`overloaded` only), from live queue depth × service time.
        retry_after_ms: Option<u64>,
    },
}

impl Response {
    /// An `internal` error (the legacy constructor — prefer [`Response::err`]
    /// with a precise code).
    pub fn error(message: impl Into<String>) -> Response {
        Response::err(ErrorCode::Internal, message)
    }

    pub fn err(code: ErrorCode, message: impl Into<String>) -> Response {
        Response::Error { code, message: message.into(), retry_after_ms: None }
    }

    /// An `overloaded` error carrying the admission-control retry hint.
    pub fn overloaded(message: impl Into<String>, retry_after_ms: u64) -> Response {
        Response::Error {
            code: ErrorCode::Overloaded,
            message: message.into(),
            retry_after_ms: Some(retry_after_ms),
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            Response::Generate { text, tokens, logprobs } => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("op", Json::str("generate")),
                ("text", Json::str(text)),
                ("tokens", Json::arr(tokens.iter().map(|&t| Json::Int(t as i64)))),
                ("logprobs", Json::arr(logprobs.iter().map(|&p| Json::Float(p as f64)))),
            ]),
            Response::Score { nll, perplexity, count, logprobs } => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("op", Json::str("score")),
                ("nll", Json::Float(*nll)),
                ("perplexity", Json::Float(*perplexity)),
                ("count", Json::Int(*count as i64)),
                ("logprobs", Json::arr(logprobs.iter().map(|&p| Json::Float(p as f64)))),
            ]),
            Response::Info(fields) => {
                let mut entries = vec![
                    ("ok".to_string(), Json::Bool(true)),
                    ("op".to_string(), Json::str("info")),
                ];
                if let Some(obj) = fields.as_object() {
                    entries.extend(obj.iter().cloned());
                }
                Json::Object(entries)
            }
            Response::Metrics(fields) => {
                let mut entries = vec![
                    ("ok".to_string(), Json::Bool(true)),
                    ("op".to_string(), Json::str("metrics")),
                ];
                if let Some(obj) = fields.as_object() {
                    entries.extend(obj.iter().cloned());
                }
                Json::Object(entries)
            }
            Response::Shutdown => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("op", Json::str("shutdown")),
            ]),
            Response::Error { code, message, retry_after_ms } => {
                let mut entries = vec![
                    ("ok", Json::Bool(false)),
                    ("code", Json::str(code.as_str())),
                    ("error", Json::str(message)),
                ];
                if let Some(ms) = retry_after_ms {
                    entries.push(("retry_after_ms", Json::Int(*ms as i64)));
                }
                Json::obj(entries)
            }
        }
    }

    pub fn from_json(j: &Json) -> Result<Response> {
        let ok = j.req("ok")?.as_bool().ok_or_else(|| anyhow!("ok must be a bool"))?;
        if !ok {
            return Ok(Response::Error {
                // Pre-PR-6 servers send no code: degrade to `internal`.
                code: ErrorCode::parse(
                    j.get("code").and_then(|v| v.as_str()).unwrap_or("internal"),
                ),
                message: j
                    .get("error")
                    .and_then(|v| v.as_str())
                    .unwrap_or("unspecified error")
                    .to_string(),
                retry_after_ms: j
                    .get("retry_after_ms")
                    .and_then(|v| v.as_i64())
                    .map(|ms| ms.max(0) as u64),
            });
        }
        let op = j.req("op")?.as_str().ok_or_else(|| anyhow!("op must be a string"))?;
        match op {
            "generate" => Ok(Response::Generate {
                text: j
                    .get("text")
                    .and_then(|v| v.as_str())
                    .unwrap_or_default()
                    .to_string(),
                tokens: get_i32_array(j, "tokens")?,
                logprobs: get_f32_array(j, "logprobs")?,
            }),
            "score" => Ok(Response::Score {
                nll: j.req("nll")?.as_f64().ok_or_else(|| anyhow!("nll must be a number"))?,
                perplexity: j
                    .req("perplexity")?
                    .as_f64()
                    .ok_or_else(|| anyhow!("perplexity must be a number"))?,
                count: get_usize(j, "count", 0)?,
                logprobs: get_f32_array(j, "logprobs")?,
            }),
            "info" => {
                let fields: Vec<(String, Json)> = j
                    .as_object()
                    .unwrap_or(&[])
                    .iter()
                    .filter(|(k, _)| k != "ok" && k != "op")
                    .cloned()
                    .collect();
                Ok(Response::Info(Json::Object(fields)))
            }
            "metrics" => {
                let fields: Vec<(String, Json)> = j
                    .as_object()
                    .unwrap_or(&[])
                    .iter()
                    .filter(|(k, _)| k != "ok" && k != "op")
                    .cloned()
                    .collect();
                Ok(Response::Metrics(Json::Object(fields)))
            }
            "shutdown" => Ok(Response::Shutdown),
            other => bail!("unknown response op {other:?}"),
        }
    }

    pub fn parse(line: &str) -> Result<Response> {
        Response::from_json(&Json::parse(line.trim())?)
    }

    pub fn to_line(&self) -> String {
        self.to_json().to_string()
    }
}

/// u64 carried over a JSON int: values above `i64::MAX` travel as their
/// two's-complement negative and wrap back losslessly here, so the full
/// seed space round-trips (matches `Json::Int(seed as i64)` on the way
/// out).
fn get_u64_wire(j: &Json, key: &str, default: u64) -> Result<u64> {
    match j.get(key) {
        None => Ok(default),
        Some(v) => Ok(v.as_i64().ok_or_else(|| anyhow!("{key} must be an integer"))? as u64),
    }
}

/// Lenient `trace` flag parse: anything but a literal `true` is off, so
/// malformed flags never fail an otherwise-good request.
fn get_trace(j: &Json) -> bool {
    j.get("trace").and_then(|v| v.as_bool()).unwrap_or(false)
}

/// Lenient `model` routing-tag parse: a missing or non-string tag routes
/// to the default model (the server rejects *unknown* tags, not absent
/// ones).
fn get_model(j: &Json) -> Option<String> {
    j.get("model").and_then(|v| v.as_str()).map(|s| s.to_string())
}

fn get_usize(j: &Json, key: &str, default: usize) -> Result<usize> {
    match j.get(key) {
        None => Ok(default),
        Some(v) => {
            let i = v.as_i64().ok_or_else(|| anyhow!("{key} must be an integer"))?;
            if i < 0 {
                bail!("{key} must be >= 0, got {i}");
            }
            Ok(i as usize)
        }
    }
}

fn get_f32_array(j: &Json, key: &str) -> Result<Vec<f32>> {
    Ok(j.get(key)
        .and_then(|v| v.as_array())
        .unwrap_or(&[])
        .iter()
        .filter_map(|v| v.as_f64().map(|f| f as f32))
        .collect())
}

fn get_i32_array(j: &Json, key: &str) -> Result<Vec<i32>> {
    Ok(j.get(key)
        .and_then(|v| v.as_array())
        .unwrap_or(&[])
        .iter()
        .filter_map(|v| v.as_i64().map(|i| i as i32))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let reqs = vec![
            Request::Generate(GenParams {
                prompt: "the cat".into(),
                max_tokens: 8,
                top_k: 4,
                temperature: 0.7,
                seed: 42,
                deadline_ms: 0,
                trace: false,
                model: None,
            }),
            Request::Generate(GenParams { deadline_ms: 250, ..GenParams::default() }),
            Request::Generate(GenParams { trace: true, ..GenParams::default() }),
            Request::Generate(GenParams { model: Some("draft".into()), ..GenParams::default() }),
            Request::Score {
                text: "hello \"world\"\n".into(),
                deadline_ms: 0,
                trace: false,
                model: None,
            },
            Request::Score {
                text: "budgeted".into(),
                deadline_ms: 125,
                trace: false,
                model: None,
            },
            Request::Score { text: "traced".into(), deadline_ms: 0, trace: true, model: None },
            Request::Score {
                text: "routed".into(),
                deadline_ms: 0,
                trace: false,
                model: Some("big".into()),
            },
            Request::Info,
            Request::Metrics,
            Request::Shutdown,
        ];
        for req in reqs {
            let line = req.to_line();
            assert!(!line.contains('\n'), "line framing broken: {line:?}");
            assert_eq!(Request::parse(&line).unwrap(), req);
        }
    }

    #[test]
    fn generate_defaults_fill_in() {
        let req = Request::parse(r#"{"op":"generate","prompt":"hi"}"#).unwrap();
        match req {
            Request::Generate(p) => {
                assert_eq!(p.prompt, "hi");
                assert_eq!(p.max_tokens, GenParams::default().max_tokens);
                assert_eq!(p.top_k, 0);
                assert_eq!(p.temperature, 0.0);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn response_roundtrip() {
        let resps = vec![
            Response::Generate {
                text: "out".into(),
                tokens: vec![5, 6, 2],
                logprobs: vec![-0.5, -1.25, -2.0],
            },
            Response::Score { nll: 2.5, perplexity: 12.18, count: 3, logprobs: vec![-2.5] },
            Response::Info(Json::obj(vec![("vocab", Json::Int(512))])),
            Response::Metrics(Json::obj(vec![
                ("serve_requests_total", Json::Int(7)),
                ("train_step_loss", Json::Float(2.5)),
            ])),
            Response::Shutdown,
            Response::error("queue full"),
            Response::overloaded("admission control shed this request", 40),
        ];
        for resp in resps {
            let line = resp.to_line();
            assert!(!line.contains('\n'));
            assert_eq!(Response::parse(&line).unwrap(), resp);
        }
    }

    #[test]
    fn every_error_code_roundtrips() {
        for code in ErrorCode::ALL {
            // String form survives its own parse…
            assert_eq!(ErrorCode::parse(code.as_str()), code, "{code:?}");
            // …and the full response wire form survives, with and without
            // the retry hint.
            for retry_after_ms in [None, Some(25u64)] {
                let resp = Response::Error {
                    code,
                    message: format!("synthetic {} failure", code.as_str()),
                    retry_after_ms,
                };
                let line = resp.to_line();
                assert!(line.contains(code.as_str()), "{line}");
                assert_eq!(Response::parse(&line).unwrap(), resp);
            }
        }
        // Only overload is worth retrying verbatim.
        assert!(ErrorCode::Overloaded.retryable());
        assert!(!ErrorCode::InvalidRequest.retryable());
        assert!(!ErrorCode::DeadlineExceeded.retryable());
        assert!(!ErrorCode::Internal.retryable());
        assert!(!ErrorCode::ShuttingDown.retryable());
    }

    #[test]
    fn legacy_codeless_errors_degrade_to_internal() {
        // A pre-PR-6 peer sends {"ok":false,"error":"..."} with no code.
        let resp = Response::parse(r#"{"ok":false,"error":"boom"}"#).unwrap();
        assert_eq!(
            resp,
            Response::Error {
                code: ErrorCode::Internal,
                message: "boom".into(),
                retry_after_ms: None
            }
        );
        // Unknown future codes degrade rather than fail.
        let resp = Response::parse(r#"{"ok":false,"code":"quota_exceeded","error":"x"}"#).unwrap();
        match resp {
            Response::Error { code, .. } => assert_eq!(code, ErrorCode::Internal),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn deadline_budget_is_exposed_only_when_set() {
        let none = Request::Generate(GenParams::default());
        assert_eq!(none.deadline_ms(), None);
        assert!(!none.to_line().contains("deadline_ms"), "unset budget stays off the wire");
        let some = Request::Score { text: "x".into(), deadline_ms: 75, trace: false, model: None };
        assert_eq!(some.deadline_ms(), Some(75));
        assert_eq!(Request::parse(&some.to_line()).unwrap().deadline_ms(), Some(75));
        assert_eq!(Request::Info.deadline_ms(), None);
    }

    #[test]
    fn model_tag_is_exposed_only_when_set() {
        let none = Request::Generate(GenParams::default());
        assert_eq!(none.model(), None);
        assert!(!none.to_line().contains("model"), "unset tag stays off the wire");
        let some = Request::Generate(GenParams { model: Some("a".into()), ..GenParams::default() });
        assert_eq!(some.model(), Some("a"));
        assert_eq!(Request::parse(&some.to_line()).unwrap().model(), Some("a"));
        // Lenient parse: a non-string tag routes to the default model.
        let weird = Request::parse(r#"{"op":"score","text":"x","model":7}"#).unwrap();
        assert_eq!(weird.model(), None);
        assert_eq!(Request::Info.model(), None);
    }

    #[test]
    fn trace_flag_is_exposed_only_when_set() {
        let off = Request::Score { text: "x".into(), deadline_ms: 0, trace: false, model: None };
        assert!(!off.trace());
        assert!(!off.to_line().contains("trace"), "unset trace stays off the wire");
        let on = Request::Generate(GenParams { trace: true, ..GenParams::default() });
        assert!(on.trace());
        assert!(Request::parse(&on.to_line()).unwrap().trace());
        // Lenient parse: a malformed flag is off, not an error.
        let weird = Request::parse(r#"{"op":"score","text":"x","trace":"yes"}"#).unwrap();
        assert!(!weird.trace());
        assert!(!Request::Info.trace());
        assert!(!Request::Metrics.trace());
    }

    #[test]
    fn full_seed_space_roundtrips() {
        for seed in [0u64, 1, i64::MAX as u64, i64::MAX as u64 + 1, u64::MAX] {
            let req = Request::Generate(GenParams { seed, ..GenParams::default() });
            match Request::parse(&req.to_line()).unwrap() {
                Request::Generate(p) => assert_eq!(p.seed, seed),
                other => panic!("{other:?}"),
            }
        }
    }

    #[test]
    fn bad_requests_rejected() {
        assert!(Request::parse("{}").is_err());
        assert!(Request::parse(r#"{"op":"evaporate"}"#).is_err());
        assert!(Request::parse(r#"{"op":"score"}"#).is_err());
        assert!(Request::parse(r#"{"op":"generate","max_tokens":-3}"#).is_err());
        assert!(Request::parse("not json").is_err());
    }
}
