//! Micro-batching scheduler: per-model admission lanes → round-robin
//! batch assembly by deadline/size → kernel dispatch → response routing.
//!
//! Architecture (all `std`, no async runtime):
//!
//! * submission goes through **bounded per-model lanes** ([`Queues`]) —
//!   each routed engine gets its own FIFO with its own `queue_depth`
//!   budget, so a hot model saturating its lane backpressures *its own*
//!   clients while a cold model's requests still admit and still get
//!   picked up (the PR 8 follow-up the ROADMAP names explicitly).  When a
//!   lane is full, [`Batcher::submit`] fails immediately and the server
//!   surfaces backpressure instead of buffering unboundedly;
//! * `workers` threads share the lanes behind one mutex + condvar.  A
//!   worker blocks for the first job, then keeps the lock only while it
//!   drains up to `max_batch − 1` more jobs **round-robin across lanes**
//!   or until `max_wait` elapses (the latency/throughput knob), then
//!   releases the queue and executes the batch — so one worker assembles
//!   while the others run kernels;
//! * each job carries its own response [`std::sync::mpsc::Sender`]; results
//!   route back to exactly the connection that asked.
//!
//! Failure domains (PR 6): each engine call is wrapped in
//! [`std::panic::catch_unwind`], so a poisoned request answers `internal`
//! while the worker, the rest of the batch, and the server survive (the
//! same discipline [`crate::exec::pool`] applies one level down).  Jobs
//! whose [`Job::deadline`] expired while queued are shed *before* kernel
//! work with `deadline_exceeded`.  Live queue depth and a service-time
//! EWMA feed [`Batcher::retry_after_ms`], the admission-control hint on
//! `overloaded` responses, and [`Batcher::drain`] bounds graceful
//! shutdown.
//!
//! Lifecycle hardening (PR 9): jobs may carry a
//! [`CancelToken`](crate::serve::engine::CancelToken) — a dead SSE client
//! or an expired `deadline_ms` cancels the remaining decode steps at the
//! next lockstep step boundary ([`Engine::generate_batch_ctl`]) and frees
//! the batch slot, counted by `serve_cancelled_{disconnect,deadline}_total`.
//! Under sustained overload — the queue-wait EWMA (`serve_queue_ewma_us`)
//! above `--brownout-queue-ms` — **brownout** degrades generate requests
//! (clamp `max_tokens`, shrink top-k) with a `degraded:true` response
//! field *before* admission control starts shedding with 429.
//!
//! Telemetry (PR 7): every counter lives in a per-batcher
//! [`crate::obs::Registry`] (`serve_*` families) — one source of truth
//! feeding `{"op":"info"}` (byte-compatible field names), the
//! `{"op":"metrics"}` endpoint, and `GET /metrics`.  The registry is
//! per-instance rather than process-global so concurrent servers in one
//! process (the test suite) never mix counts.  Each job carries its
//! submit time; [`run_batch`] turns that into queue/assembly/kernel
//! [`StageTimings`], feeds the per-stage histograms, and echoes the
//! timings back on jobs whose request set `"trace":true`.
//!
//! Generate jobs in one batch decode in lockstep through a single blocked
//! kernel per step ([`Engine::generate_batch`]); score jobs fuse into a
//! single teacher-forced problem ([`Engine::score_batch`]).

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::obs::{Counter, Gauge, Histogram, Registry, StageTimings};
use crate::serve::engine::{CancelReason, CancelToken, Engine, StepCtl};
use crate::serve::protocol::{ErrorCode, GenParams, Request, Response};
use crate::util::faults;

/// How long an idle worker waits on the queue before re-checking the stop
/// flag (bounds shutdown latency).
const IDLE_POLL: Duration = Duration::from_millis(25);

/// Brownout cap on `max_tokens` for degraded generate requests.
pub const BROWNOUT_MAX_TOKENS: usize = 8;
/// Brownout cap on `top_k` for degraded sampled requests (greedy rows are
/// already top-1 and stay untouched).
pub const BROWNOUT_TOP_K: usize = 4;

/// What the batcher routes back per job: the response plus the job's stage
/// timings (populated when the batch executed; `None` on paths that never
/// reached execution, e.g. a non-batchable op).
pub struct Reply {
    pub response: Response,
    pub timings: Option<StageTimings>,
    /// True when brownout degraded this request's parameters before
    /// execution; the server echoes it as a `degraded:true` field.
    pub degraded: bool,
}

impl Reply {
    /// A reply with no stage timings (inline answers, rejected jobs).
    pub fn bare(response: Response) -> Reply {
        Reply { response, timings: None, degraded: false }
    }
}

/// One decoded token forwarded mid-flight to a streaming connection
/// (`POST /v1/generate` with `"stream":true`): what one SSE event carries.
#[derive(Debug, Clone)]
pub struct StreamDelta {
    pub token: i32,
    pub logprob: f32,
    /// The token's own decoded piece (specials drop to the empty string).
    pub text: String,
}

/// Per-stream channel bound.  Strictly larger than the engine's hard
/// `max_gen_tokens` cap (256), so a stream's `try_send`s can never hit a
/// full channel even if the consumer has not started draining yet.
pub const STREAM_CHANNEL_DEPTH: usize = 512;

/// One queued request plus its response channel.
pub struct Job {
    pub request: Request,
    pub respond: mpsc::Sender<Reply>,
    /// Per-token delta channel for streaming generate jobs.  The batcher
    /// `try_send`s each decoded token as it leaves the lockstep kernel
    /// loop; dropping the job (any completion path) hangs the channel up,
    /// which is the consumer's end-of-stream signal.  Size the channel
    /// with [`STREAM_CHANNEL_DEPTH`] so tokens are never dropped.
    pub stream: Option<mpsc::SyncSender<StreamDelta>>,
    /// Engine override for multi-model routing (`None` = the batcher's
    /// default engine).  Each distinct engine gets its own admission lane
    /// and executes as its own kernel sub-batch.
    pub engine: Option<Arc<Engine>>,
    /// Absolute shed deadline derived from the request's `deadline_ms`;
    /// checked when the batch is assembled (shed before any kernel work)
    /// *and* at every decode-step boundary once executing.
    pub deadline: Option<Instant>,
    /// When the job entered the queue — the start of its queue-wait span.
    pub submitted: Instant,
    /// Echo this job's [`StageTimings`] in its response.
    pub trace: bool,
    /// Cooperative cancel handle: the connection cancels it when the
    /// client disappears, and the engine stops the job's decode at the
    /// next lockstep step boundary, freeing the slot.
    pub cancel: Option<CancelToken>,
    /// Set by [`Batcher::submit`] when brownout degraded the request.
    pub degraded: bool,
}

impl Job {
    /// Build a job, starting the request's `deadline_ms` clock now.
    pub fn new(request: Request, respond: mpsc::Sender<Reply>) -> Job {
        let submitted = Instant::now();
        let deadline = request
            .deadline_ms()
            .and_then(|ms| submitted.checked_add(Duration::from_millis(ms)));
        let trace = request.trace();
        Job {
            request,
            respond,
            stream: None,
            engine: None,
            deadline,
            submitted,
            trace,
            cancel: None,
            degraded: false,
        }
    }
}

/// Batcher counters — registry-backed handles whose storage is the
/// batcher's own [`Registry`] (so `info`, `metrics`, and `/metrics` all
/// read the same atomics).  Field names mirror the pre-registry struct;
/// reads are `.get()` instead of `.load(..)`.
pub struct BatchStats {
    registry: Registry,
    pub batches: Arc<Counter>,
    pub jobs: Arc<Counter>,
    pub max_batch: Arc<Gauge>,
    /// Jobs shed because their `deadline_ms` expired while queued.
    pub shed_deadline: Arc<Counter>,
    /// Engine panics isolated at the batch boundary (the workers survive).
    pub panics: Arc<Counter>,
    /// Requests refused by admission control (queue full).
    pub overloaded: Arc<Counter>,
    /// Requests answered by the server, any op, any outcome.
    pub requests: Arc<Counter>,
    /// Decodes cancelled mid-flight because the client disconnected.
    pub cancelled_disconnect: Arc<Counter>,
    /// Decodes cancelled mid-flight because `deadline_ms` expired.
    pub cancelled_deadline: Arc<Counter>,
    /// Generate requests degraded (clamped) by brownout before execution.
    pub brownout_degraded: Arc<Counter>,
    /// EWMA of queue wait in µs — the brownout trigger signal.
    pub queue_ewma: Arc<Gauge>,
    /// 1 while the queue-wait EWMA sits above the brownout threshold.
    pub brownout_active: Arc<Gauge>,
    /// Child restarts performed by the supervisor (seeded from the
    /// `CCE_SUPERVISOR_RESTARTS` env the supervisor sets on each child, so
    /// the *child's* `/metrics` exposes supervisor state).
    pub supervisor_restarts: Arc<Counter>,
    /// 1 when this process runs as a `--supervise` child.
    pub supervisor_enabled: Arc<Gauge>,
    /// Jobs submitted but not yet picked up by a worker.
    queued: Arc<Gauge>,
    /// Jobs submitted but not yet answered (queued + executing).
    in_flight: Arc<Gauge>,
    /// EWMA of per-job service time in microseconds (0 until first batch).
    job_micros: Arc<Gauge>,
    /// Per-stage latency histograms (µs).
    pub stage_queue: Arc<Histogram>,
    pub stage_assemble: Arc<Histogram>,
    pub stage_kernel: Arc<Histogram>,
    pub stage_serialize: Arc<Histogram>,
    /// End-to-end request latency (receipt → response written), µs.
    pub request_us: Arc<Histogram>,
    /// Brownout threshold in µs of queue-wait EWMA; 0 disables brownout.
    brownout_us: u64,
}

impl BatchStats {
    fn new(brownout_us: u64) -> BatchStats {
        let r = Registry::new();
        let supervisor_restarts = r.counter(
            "serve_supervisor_restarts_total",
            "Child restarts performed by the supervisor so far",
        );
        if let Ok(v) = std::env::var("CCE_SUPERVISOR_RESTARTS") {
            if let Ok(n) = v.trim().parse::<u64>() {
                supervisor_restarts.add(n);
            }
        }
        let supervisor_enabled =
            r.gauge("serve_supervisor_enabled", "1 when serving as a --supervise child");
        if std::env::var("CCE_SUPERVISED").as_deref() == Ok("1") {
            supervisor_enabled.set(1);
        }
        BatchStats {
            batches: r.counter("serve_batches_total", "Batches executed by the micro-batcher"),
            jobs: r.counter("serve_batched_jobs_total", "Jobs executed through batches"),
            max_batch: r.gauge("serve_batch_max", "Largest batch assembled so far"),
            shed_deadline: r.counter(
                "serve_shed_deadline_total",
                "Jobs shed before kernel work because their deadline_ms expired",
            ),
            panics: r.counter(
                "serve_batch_panics_total",
                "Engine panics isolated at the batch boundary",
            ),
            overloaded: r.counter(
                "serve_overloaded_total",
                "Requests refused by admission control (bounded queue full)",
            ),
            requests: r.counter("serve_requests_total", "Requests answered, any op, any outcome"),
            cancelled_disconnect: r.counter(
                "serve_cancelled_disconnect_total",
                "Decodes cancelled at a step boundary: client disconnected",
            ),
            cancelled_deadline: r.counter(
                "serve_cancelled_deadline_total",
                "Decodes cancelled at a step boundary: deadline_ms expired mid-decode",
            ),
            brownout_degraded: r.counter(
                "serve_brownout_degraded_total",
                "Generate requests degraded (clamped) by brownout",
            ),
            queue_ewma: r.gauge("serve_queue_ewma_us", "EWMA of job queue wait in microseconds"),
            brownout_active: r.gauge(
                "serve_brownout_active",
                "1 while sustained queue delay holds brownout engaged",
            ),
            supervisor_restarts,
            supervisor_enabled,
            queued: r.gauge("serve_queue_depth", "Jobs waiting for a batch worker"),
            in_flight: r.gauge("serve_in_flight", "Jobs submitted but not yet answered"),
            job_micros: r.gauge(
                "serve_job_service_us",
                "EWMA of per-job service time in microseconds",
            ),
            stage_queue: r.histogram("serve_stage_queue_us", "Queue wait per job"),
            stage_assemble: r.histogram("serve_stage_assemble_us", "Batch-assembly window"),
            stage_kernel: r.histogram("serve_stage_kernel_us", "Kernel execution per sub-batch"),
            stage_serialize: r.histogram(
                "serve_stage_serialize_us",
                "Response serialization + socket write per request",
            ),
            request_us: r.histogram(
                "serve_request_us",
                "End-to-end request latency, receipt to response written",
            ),
            registry: r,
            brownout_us,
        }
    }

    /// The registry holding every `serve_*` family (for exporters).
    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    fn record(&self, batch_len: usize) {
        self.batches.inc();
        self.jobs.add(batch_len as u64);
        self.max_batch.set_max(batch_len as i64);
    }

    /// True while sustained queue delay (the EWMA, not one spike) sits at
    /// or above the configured brownout threshold.
    pub fn in_brownout(&self) -> bool {
        self.brownout_us > 0 && self.queue_ewma.get().max(0) as u64 >= self.brownout_us
    }
}

impl Default for BatchStats {
    fn default() -> BatchStats {
        BatchStats::new(0)
    }
}

/// One model's FIFO admission lane, keyed by its engine's pointer
/// identity (the same identity [`run_batch`] buckets sub-batches by).
struct Lane {
    key: usize,
    jobs: VecDeque<Job>,
}

/// The lanes plus round-robin cursor, behind [`Queues`]' mutex.
struct QueueState {
    lanes: Vec<Lane>,
    /// Round-robin cursor over `lanes`; advances on every probe so no
    /// lane is favoured across batches.
    rr: usize,
    total: usize,
    closed: bool,
}

impl QueueState {
    /// Pop the next job round-robin across non-empty lanes.
    fn take_rr(&mut self) -> Option<Job> {
        let n = self.lanes.len();
        for _ in 0..n {
            let i = self.rr % n;
            self.rr = self.rr.wrapping_add(1);
            if let Some(job) = self.lanes[i].jobs.pop_front() {
                self.total -= 1;
                return Some(job);
            }
        }
        None
    }
}

/// Bounded per-model admission lanes.  `depth` bounds each lane
/// *independently*, so one model's backlog never consumes another
/// model's admission budget.
struct Queues {
    state: Mutex<QueueState>,
    cv: Condvar,
    depth: usize,
    default_key: usize,
}

impl Queues {
    fn new(default_key: usize, depth: usize) -> Queues {
        Queues {
            state: Mutex::new(QueueState { lanes: Vec::new(), rr: 0, total: 0, closed: false }),
            cv: Condvar::new(),
            depth: depth.max(1),
            default_key,
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, QueueState> {
        match self.state.lock() {
            Ok(guard) => guard,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    /// Enqueue into `key`'s lane; `Err(job)` when the lane is full or the
    /// queues are closed.
    fn push(&self, key: usize, job: Job) -> Result<(), Job> {
        let mut state = self.lock();
        if state.closed {
            return Err(job);
        }
        let idx = match state.lanes.iter().position(|lane| lane.key == key) {
            Some(idx) => idx,
            None => {
                state.lanes.push(Lane { key, jobs: VecDeque::new() });
                state.lanes.len() - 1
            }
        };
        if state.lanes[idx].jobs.len() >= self.depth {
            return Err(job);
        }
        state.lanes[idx].jobs.push_back(job);
        state.total += 1;
        drop(state);
        self.cv.notify_one();
        Ok(())
    }

    /// Refuse new work and wake every waiting worker.
    fn close(&self) {
        self.lock().closed = true;
        self.cv.notify_all();
    }

    /// Drain every remaining queued job (shutdown cleanup).
    fn clear(&self) -> Vec<Job> {
        let mut state = self.lock();
        let mut left = Vec::with_capacity(state.total);
        for lane in state.lanes.iter_mut() {
            left.extend(lane.jobs.drain(..));
        }
        state.total = 0;
        left
    }
}

/// The micro-batching scheduler.
pub struct Batcher {
    queues: Arc<Queues>,
    workers: Mutex<Vec<JoinHandle<()>>>,
    worker_count: usize,
    stats: Arc<BatchStats>,
    stop: Arc<AtomicBool>,
}

impl Batcher {
    /// Spawn `workers` batch workers over per-model lanes of depth
    /// `queue_depth` each.  `brownout_queue_ms` is the sustained
    /// queue-delay threshold that engages brownout (0 disables it).
    pub fn start(
        engine: Arc<Engine>,
        workers: usize,
        max_batch: usize,
        max_wait: Duration,
        queue_depth: usize,
        brownout_queue_ms: u64,
    ) -> Batcher {
        let queues = Arc::new(Queues::new(Arc::as_ptr(&engine) as usize, queue_depth));
        let stats = Arc::new(BatchStats::new(brownout_queue_ms.saturating_mul(1000)));
        let stop = Arc::new(AtomicBool::new(false));
        let max_batch = max_batch.max(1);
        let worker_count = workers.max(1);
        let handles = (0..worker_count)
            .map(|_| {
                let engine = engine.clone();
                let queues = queues.clone();
                let stats = stats.clone();
                let stop = stop.clone();
                std::thread::spawn(move || {
                    worker_loop(WorkerCtx {
                        engine: &engine,
                        queues: &queues,
                        stats: &stats,
                        stop: &stop,
                        max_batch,
                        max_wait,
                    })
                })
            })
            .collect();
        Batcher { queues, workers: Mutex::new(handles), worker_count, stats, stop }
    }

    /// Enqueue a job.  `Err(job)` means the model's lane is full
    /// (backpressure) or the batcher has shut down; the job is handed back
    /// so the caller can answer the client.  While brownout is engaged,
    /// generate jobs are degraded (clamped `max_tokens`/`top_k`) before
    /// admission and marked [`Job::degraded`].
    pub fn submit(&self, mut job: Job) -> Result<(), Job> {
        if self.stop.load(Ordering::SeqCst) {
            return Err(job);
        }
        if self.stats.in_brownout() {
            if let Request::Generate(params) = &mut job.request {
                if degrade(params) {
                    job.degraded = true;
                    self.stats.brownout_degraded.inc();
                }
            }
        }
        let key = job
            .engine
            .as_ref()
            .map(|engine| Arc::as_ptr(engine) as usize)
            .unwrap_or(self.queues.default_key);
        // Count optimistically so a racing drain() can never observe the
        // queue push without the in-flight credit.
        self.stats.queued.add(1);
        self.stats.in_flight.add(1);
        self.queues.push(key, job).map_err(|job| {
            self.stats.queued.sub(1);
            self.stats.in_flight.sub(1);
            job
        })
    }

    pub fn stats(&self) -> &BatchStats {
        &self.stats
    }

    /// Jobs submitted but not yet answered.
    pub fn in_flight(&self) -> u64 {
        self.stats.in_flight.get().max(0) as u64
    }

    /// Admission-control hint for `overloaded` responses: roughly how long
    /// until the current queue has been served, from live depth × the
    /// service-time EWMA ÷ workers.  Clamped to `[5 ms, 5 s]`; before any
    /// batch has completed the EWMA defaults to 10 ms/job.
    pub fn retry_after_ms(&self) -> u64 {
        let queued = self.stats.queued.get().max(0) as u64;
        let per_job_micros = match self.stats.job_micros.get() {
            0 => 10_000,
            micros => micros.max(1) as u64,
        };
        let workers = self.worker_count.max(1) as u64;
        ((queued + 1).saturating_mul(per_job_micros) / workers / 1000).clamp(5, 5_000)
    }

    /// Graceful drain: wait (bounded) until every submitted job has been
    /// answered.  Returns `false` if the deadline hit first.  Workers keep
    /// running during the drain; pair with a stopped accept loop so no new
    /// work arrives.
    pub fn drain(&self, deadline: Duration) -> bool {
        let until = Instant::now() + deadline;
        while self.stats.in_flight.get() > 0 {
            if Instant::now() >= until {
                return false;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        true
    }

    /// Stop the workers.  Queued-but-unprocessed jobs are dropped, which
    /// closes their response channels — waiting connections observe the
    /// hangup and answer "shutting down".  Call [`Batcher::drain`] first
    /// for a graceful shutdown.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        self.queues.close();
        let mut workers = match self.workers.lock() {
            Ok(guard) => guard,
            Err(poisoned) => poisoned.into_inner(),
        };
        for handle in workers.drain(..) {
            let _ = handle.join();
        }
        // Release the gauge credit of jobs the workers never picked up;
        // dropping them hangs up their response channels.
        for _job in self.queues.clear() {
            self.stats.queued.sub(1);
            self.stats.in_flight.sub(1);
        }
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Brownout degradation: clamp the expensive knobs of a generate request.
/// Returns `true` when anything changed (the job is marked `degraded`).
fn degrade(params: &mut GenParams) -> bool {
    let mut changed = false;
    if params.max_tokens > BROWNOUT_MAX_TOKENS {
        params.max_tokens = BROWNOUT_MAX_TOKENS;
        changed = true;
    }
    if params.temperature > 0.0 && (params.top_k == 0 || params.top_k > BROWNOUT_TOP_K) {
        params.top_k = BROWNOUT_TOP_K;
        changed = true;
    }
    changed
}

/// Fold one job's queue wait into the brownout EWMA (`new = 7/8 old +
/// 1/8 sample`, no bootstrap jump — brownout must reflect *sustained*
/// delay, so a single spike moves the signal only an eighth of the way).
fn note_queue_delay(stats: &BatchStats, queue_us: u64) {
    let sample = queue_us.min(i64::MAX as u64) as i64;
    let old = stats.queue_ewma.get().max(0);
    let next = (old - old / 8 + sample / 8).max(0);
    stats.queue_ewma.set(next);
    if stats.brownout_us > 0 {
        stats.brownout_active.set((next as u64 >= stats.brownout_us) as i64);
    }
}

/// Everything one batch worker needs (bundled to keep the spawn site and
/// signatures readable).
struct WorkerCtx<'a> {
    engine: &'a Arc<Engine>,
    queues: &'a Queues,
    stats: &'a BatchStats,
    stop: &'a AtomicBool,
    max_batch: usize,
    max_wait: Duration,
}

fn worker_loop(ctx: WorkerCtx<'_>) {
    loop {
        if ctx.stop.load(Ordering::SeqCst) {
            return;
        }
        let mut jobs: Vec<Job> = Vec::new();
        let assemble_started;
        {
            let mut state = ctx.queues.lock();
            loop {
                if ctx.stop.load(Ordering::SeqCst) {
                    return;
                }
                if let Some(job) = state.take_rr() {
                    jobs.push(job);
                    break;
                }
                if state.closed {
                    return;
                }
                let (guard, _) = match ctx.queues.cv.wait_timeout(state, IDLE_POLL) {
                    Ok(res) => res,
                    Err(poisoned) => poisoned.into_inner(),
                };
                state = guard;
            }
            assemble_started = Instant::now();
            let deadline = assemble_started + ctx.max_wait;
            while jobs.len() < ctx.max_batch && !ctx.stop.load(Ordering::SeqCst) {
                if let Some(job) = state.take_rr() {
                    jobs.push(job);
                    continue;
                }
                let now = Instant::now();
                if now >= deadline || state.closed {
                    break;
                }
                let (guard, _) = match ctx.queues.cv.wait_timeout(state, deadline - now) {
                    Ok(res) => res,
                    Err(poisoned) => poisoned.into_inner(),
                };
                state = guard;
            }
        }
        let assemble_us = assemble_started.elapsed().as_micros() as u64;
        ctx.stats.queued.sub(jobs.len() as i64);
        ctx.stats.record(jobs.len());
        ctx.stats.stage_assemble.record(assemble_us);
        let batch_len = jobs.len();
        let started = Instant::now();
        // Belt + braces: run_batch already isolates engine panics per
        // sub-batch; this outer guard keeps the worker alive even if the
        // routing code itself has a bug.  Jobs consumed by such a panic
        // drop their response senders — connections observe the hangup.
        let routed = catch_unwind(AssertUnwindSafe(|| {
            run_batch(ctx.engine, jobs, ctx.stats, assemble_us)
        }));
        if routed.is_err() {
            ctx.stats.panics.inc();
            eprintln!("[batcher] worker survived a panic outside the batch boundary");
        }
        // Service-time EWMA (per job, in µs): new = 7/8 old + 1/8 sample.
        if batch_len > 0 {
            let sample = (started.elapsed().as_micros() as i64 / batch_len as i64).max(1);
            let old = ctx.stats.job_micros.get();
            let next = if old == 0 { sample } else { old - old / 8 + sample / 8 };
            ctx.stats.job_micros.set(next);
        }
    }
}

/// Best-effort text of a caught panic payload.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s
    } else {
        "non-string panic payload"
    }
}

/// One job of an executing sub-batch: the kernel input plus everything
/// needed to route and trace the answer.
struct Pending<T> {
    payload: T,
    respond: mpsc::Sender<Reply>,
    stream: Option<mpsc::SyncSender<StreamDelta>>,
    /// Step-boundary controls (cancel token + absolute deadline) the
    /// engine consults between lockstep decode steps.
    ctl: StepCtl,
    queue_us: u64,
    trace: bool,
    degraded: bool,
}

/// Append `pending` to the sub-batch bucket of `engine`, opening a new
/// bucket for an engine the batch has not seen yet (multi-model batches
/// execute one kernel sub-batch per distinct engine).
fn bucket_for<T>(
    groups: &mut Vec<(Arc<Engine>, Vec<Pending<T>>)>,
    engine: Arc<Engine>,
    pending: Pending<T>,
) {
    for (existing, bucket) in groups.iter_mut() {
        if Arc::ptr_eq(existing, &engine) {
            bucket.push(pending);
            return;
        }
    }
    groups.push((engine, vec![pending]));
}

/// Route one executed job: record its stage histograms, attach timings
/// when the request asked for a trace, answer, release in-flight credit.
fn resolve<T>(
    stats: &BatchStats,
    p: &Pending<T>,
    response: Response,
    assemble_us: u64,
    kernel_us: u64,
) {
    stats.stage_queue.record(p.queue_us);
    stats.stage_kernel.record(kernel_us);
    let timings = StageTimings { queue_us: p.queue_us, assemble_us, kernel_us };
    let _ = p.respond.send(Reply {
        response,
        timings: p.trace.then_some(timings),
        degraded: p.degraded,
    });
    stats.in_flight.sub(1);
}

/// Execute one assembled batch and route the responses.  Every job is
/// answered exactly once and decrements `in_flight` exactly once, on every
/// path — success, engine error, shed deadline, cancellation, or isolated
/// panic.  Multi-model batches split into one kernel sub-batch per
/// distinct engine; jobs carrying a [`Job::stream`] channel get their
/// tokens forwarded as the lockstep decode loop emits them.
fn run_batch(default_engine: &Arc<Engine>, jobs: Vec<Job>, stats: &BatchStats, assemble_us: u64) {
    let answer = |respond: &mpsc::Sender<Reply>, reply: Reply| {
        let _ = respond.send(reply); // client may have hung up
        stats.in_flight.sub(1);
    };
    let now = Instant::now();
    let mut gens: Vec<(Arc<Engine>, Vec<Pending<GenParams>>)> = Vec::new();
    let mut scores: Vec<(Arc<Engine>, Vec<Pending<String>>)> = Vec::new();
    for job in jobs {
        let queue_us = now.saturating_duration_since(job.submitted).as_micros() as u64;
        note_queue_delay(stats, queue_us);
        // Deadline shed happens here — after queueing, before kernels.
        if job.deadline.is_some_and(|deadline| now >= deadline) {
            stats.shed_deadline.inc();
            answer(
                &job.respond,
                Reply::bare(Response::err(
                    ErrorCode::DeadlineExceeded,
                    "deadline_ms expired while queued; shed before execution",
                )),
            );
            continue;
        }
        let trace = job.trace;
        let degraded = job.degraded;
        let ctl = StepCtl { cancel: job.cancel, deadline: job.deadline };
        let engine = job.engine.unwrap_or_else(|| default_engine.clone());
        match job.request {
            Request::Generate(params) => bucket_for(
                &mut gens,
                engine,
                Pending {
                    payload: params,
                    respond: job.respond,
                    stream: job.stream,
                    ctl,
                    queue_us,
                    trace,
                    degraded,
                },
            ),
            Request::Score { text, .. } => bucket_for(
                &mut scores,
                engine,
                Pending {
                    payload: text,
                    respond: job.respond,
                    stream: None,
                    ctl,
                    queue_us,
                    trace,
                    degraded,
                },
            ),
            // Info/metrics/shutdown are answered inline by the connection;
            // they never enter the queue.
            other => answer(
                &job.respond,
                Reply::bare(Response::err(
                    ErrorCode::InvalidRequest,
                    format!("op {other:?} is not batchable"),
                )),
            ),
        }
    }
    for (engine, group) in &gens {
        let params: Vec<GenParams> = group.iter().map(|p| p.payload.clone()).collect();
        let ctls: Vec<StepCtl> = group.iter().map(|p| p.ctl.clone()).collect();
        let streams: Vec<Option<mpsc::SyncSender<StreamDelta>>> =
            group.iter().map(|p| p.stream.clone()).collect();
        let kernel_started = Instant::now();
        let results = catch_unwind(AssertUnwindSafe(|| {
            faults::maybe_panic("batcher.panic");
            engine.generate_batch_ctl(&params, &ctls, &mut |row, token, logprob| {
                if let Some(tx) = &streams[row] {
                    // try_send: the channel is sized past the token cap
                    // (STREAM_CHANNEL_DEPTH), so Full is impossible; a
                    // Disconnected receiver means the client hung up,
                    // and the decode simply finishes unobserved.
                    let _ = tx.try_send(StreamDelta {
                        token,
                        logprob,
                        text: engine.decode_token(token),
                    });
                }
            })
        }));
        let kernel_us = kernel_started.elapsed().as_micros() as u64;
        match results {
            Ok(results) => {
                for (pending, result) in group.iter().zip(results) {
                    let response = match result {
                        Ok(out) => match out.cancelled {
                            // The client is gone: count it, route the
                            // partial output for uniform accounting (the
                            // hangup means nobody reads it).
                            Some(CancelReason::Disconnect) => {
                                stats.cancelled_disconnect.inc();
                                Response::Generate {
                                    text: out.text,
                                    tokens: out.tokens,
                                    logprobs: out.logprobs,
                                }
                            }
                            Some(CancelReason::Deadline) => {
                                stats.cancelled_deadline.inc();
                                Response::err(
                                    ErrorCode::DeadlineExceeded,
                                    format!(
                                        "deadline_ms expired mid-decode; {} token(s) decoded \
                                         before cancellation",
                                        out.tokens.len()
                                    ),
                                )
                            }
                            None => Response::Generate {
                                text: out.text,
                                tokens: out.tokens,
                                logprobs: out.logprobs,
                            },
                        },
                        // Engine-level rejections are request-shaped
                        // problems (bad temperature/top_k, oversize).
                        Err(err) => Response::err(ErrorCode::InvalidRequest, format!("{err:#}")),
                    };
                    resolve(stats, pending, response, assemble_us, kernel_us);
                }
            }
            Err(payload) => {
                stats.panics.inc();
                let msg = format!(
                    "batch execution panicked: {} (request isolated; server still serving)",
                    panic_message(&payload)
                );
                for pending in group {
                    answer(&pending.respond, Reply::bare(Response::err(ErrorCode::Internal, &msg)));
                }
            }
        }
    }
    for (engine, group) in &scores {
        let texts: Vec<String> = group.iter().map(|p| p.payload.clone()).collect();
        let kernel_started = Instant::now();
        let results = catch_unwind(AssertUnwindSafe(|| {
            faults::maybe_panic("batcher.panic");
            engine.score_batch(&texts)
        }));
        let kernel_us = kernel_started.elapsed().as_micros() as u64;
        match results {
            Ok(results) => {
                for (pending, result) in group.iter().zip(results) {
                    let response = match result {
                        Ok(res) => Response::Score {
                            nll: res.nll,
                            perplexity: res.perplexity,
                            count: res.count,
                            logprobs: res.logprobs,
                        },
                        Err(err) => Response::err(ErrorCode::InvalidRequest, format!("{err:#}")),
                    };
                    resolve(stats, pending, response, assemble_us, kernel_us);
                }
            }
            Err(payload) => {
                stats.panics.inc();
                let msg = format!(
                    "batch execution panicked: {} (request isolated; server still serving)",
                    panic_message(&payload)
                );
                for pending in group {
                    answer(&pending.respond, Reply::bare(Response::err(ErrorCode::Internal, &msg)));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::KernelOptions;

    fn tiny_engine() -> Arc<Engine> {
        let opts =
            KernelOptions { n_block: 16, v_block: 64, threads: 1, ..KernelOptions::default() };
        Arc::new(Engine::demo(384, 16, 2, opts).unwrap())
    }

    #[test]
    fn jobs_roundtrip_through_workers() {
        let batcher = Batcher::start(
            tiny_engine(),
            2,
            4,
            Duration::from_millis(2),
            16,
            0,
        );
        let mut rxs = Vec::new();
        for i in 0..6 {
            let (tx, rx) = mpsc::channel();
            let request = if i % 2 == 0 {
                Request::Generate(GenParams {
                    prompt: "the".into(),
                    max_tokens: 3,
                    ..GenParams::default()
                })
            } else {
                Request::Score {
                    text: "the cat sat".into(),
                    deadline_ms: 0,
                    trace: false,
                    model: None,
                }
            };
            batcher.submit(Job::new(request, tx)).map_err(|_| ()).unwrap();
            rxs.push((i, rx));
        }
        for (i, rx) in rxs {
            let reply = rx.recv_timeout(Duration::from_secs(30)).expect("response");
            match (i % 2, reply.response) {
                (0, Response::Generate { tokens, .. }) => assert!(!tokens.is_empty()),
                (1, Response::Score { count, .. }) => assert!(count > 0),
                (_, other) => panic!("unexpected response: {other:?}"),
            }
        }
        let stats = batcher.stats();
        assert_eq!(stats.jobs.get(), 6);
        assert!(stats.batches.get() >= 1);
        // Every executed job fed the stage histograms.
        assert_eq!(stats.stage_queue.count(), 6);
        assert_eq!(stats.stage_kernel.count(), 6);
        assert!(stats.stage_assemble.count() >= 1);
        assert_eq!(batcher.in_flight(), 0, "all jobs answered");
        assert!(batcher.drain(Duration::from_millis(50)), "drained batcher reports done");
        // The service-time EWMA is live, so retry hints are data-driven.
        assert!(batcher.retry_after_ms() >= 5);
        batcher.shutdown();
    }

    #[test]
    fn traced_jobs_echo_stage_timings() {
        let batcher = Batcher::start(tiny_engine(), 1, 2, Duration::from_millis(1), 8, 0);
        let (tx, rx) = mpsc::channel();
        let request =
            Request::Score { text: "the cat sat".into(), deadline_ms: 0, trace: true, model: None };
        batcher.submit(Job::new(request, tx)).map_err(|_| ()).unwrap();
        let reply = rx.recv_timeout(Duration::from_secs(30)).expect("response");
        assert!(matches!(reply.response, Response::Score { .. }), "{:?}", reply.response);
        let timings = reply.timings.expect("traced job must carry timings");
        assert!(timings.kernel_us > 0, "kernel time must be measured: {timings:?}");
        // An identical untraced job carries none.
        let (tx, rx) = mpsc::channel();
        let request =
            Request::Score { text: "the cat sat".into(), deadline_ms: 0, trace: false, model: None };
        batcher.submit(Job::new(request, tx)).map_err(|_| ()).unwrap();
        let reply = rx.recv_timeout(Duration::from_secs(30)).expect("response");
        assert!(reply.timings.is_none(), "untraced job must not carry timings");
        batcher.shutdown();
    }

    #[test]
    fn streaming_jobs_forward_every_token_and_engines_split_sub_batches() {
        let engine_a = tiny_engine();
        let engine_b = tiny_engine();
        let batcher = Batcher::start(engine_a.clone(), 1, 8, Duration::from_millis(10), 16, 0);
        let mk = || {
            Request::Generate(GenParams {
                prompt: "the".into(),
                max_tokens: 4,
                ..GenParams::default()
            })
        };
        // One streaming job on the default engine…
        let (tx_a, rx_a) = mpsc::channel();
        let (stream_tx, stream_rx) = mpsc::sync_channel(STREAM_CHANNEL_DEPTH);
        let mut job_a = Job::new(mk(), tx_a);
        job_a.stream = Some(stream_tx);
        // …and one routed to a different engine in the same batch window.
        let (tx_b, rx_b) = mpsc::channel();
        let mut job_b = Job::new(mk(), tx_b);
        job_b.engine = Some(engine_b.clone());
        batcher.submit(job_a).map_err(|_| ()).unwrap();
        batcher.submit(job_b).map_err(|_| ()).unwrap();
        // The stream ends by hangup: the batcher drops the sender once the
        // job is answered.
        let mut deltas: Vec<StreamDelta> = Vec::new();
        loop {
            match stream_rx.recv_timeout(Duration::from_secs(30)) {
                Ok(delta) => deltas.push(delta),
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
                Err(mpsc::RecvTimeoutError::Timeout) => panic!("stream never completed"),
            }
        }
        let reply = rx_a.recv_timeout(Duration::from_secs(5)).expect("streamed job answered");
        match reply.response {
            Response::Generate { tokens, logprobs, .. } => {
                let streamed: Vec<i32> = deltas.iter().map(|d| d.token).collect();
                assert_eq!(streamed, tokens, "stream must carry exactly the decoded tokens");
                assert_eq!(deltas.len(), logprobs.len());
            }
            other => panic!("unexpected response: {other:?}"),
        }
        match rx_b.recv_timeout(Duration::from_secs(30)).expect("routed job answered").response {
            Response::Generate { tokens, .. } => assert!(!tokens.is_empty()),
            other => panic!("unexpected response: {other:?}"),
        }
        assert!(engine_b.served() >= 1, "routed job must run on its own engine");
        batcher.shutdown();
    }

    #[test]
    fn backpressure_bounds_the_queue() {
        // No workers consuming fast enough: depth-1 queue + a stopped
        // batcher cannot accept a second job.
        let batcher = Batcher::start(
            tiny_engine(),
            1,
            1,
            Duration::from_millis(1),
            1,
            0,
        );
        batcher.shutdown(); // workers gone; queue still bounded
        let (tx, _rx) = mpsc::channel();
        let job = Job::new(Request::Info, tx);
        assert!(batcher.submit(job).is_err(), "submit after shutdown must fail");
        assert_eq!(batcher.in_flight(), 0, "rejected submits leave no credit");
    }

    #[test]
    fn expired_deadline_is_shed_before_kernel_work() {
        let engine = tiny_engine();
        let served_before = engine.served();
        let batcher = Batcher::start(
            engine.clone(),
            1,
            4,
            Duration::from_millis(1),
            16,
            0,
        );
        let (tx, rx) = mpsc::channel();
        // A deadline already in the past when the worker assembles.
        let mut job = Job::new(
            Request::Generate(GenParams {
                prompt: "the".into(),
                max_tokens: 64,
                deadline_ms: 1,
                ..GenParams::default()
            }),
            tx,
        );
        job.deadline = Some(Instant::now() - Duration::from_millis(5));
        batcher.submit(job).map_err(|_| ()).unwrap();
        match rx.recv_timeout(Duration::from_secs(10)).expect("response").response {
            Response::Error { code, .. } => assert_eq!(code, ErrorCode::DeadlineExceeded),
            other => panic!("unexpected response: {other:?}"),
        }
        assert_eq!(batcher.stats().shed_deadline.get(), 1);
        assert_eq!(
            engine.served(),
            served_before,
            "a shed job must never reach the engine"
        );
        batcher.shutdown();
    }

    #[test]
    fn round_robin_interleaves_lanes() {
        let mk = |text: &str| {
            let (tx, _rx) = mpsc::channel();
            Job::new(
                Request::Score { text: text.into(), deadline_ms: 0, trace: false, model: None },
                tx,
            )
        };
        let mut hot = Lane { key: 1, jobs: VecDeque::new() };
        hot.jobs.extend([mk("hot"), mk("hot"), mk("hot")]);
        let mut cold = Lane { key: 2, jobs: VecDeque::new() };
        cold.jobs.extend([mk("cold"), mk("cold")]);
        let mut state = QueueState { lanes: vec![hot, cold], rr: 0, total: 5, closed: false };
        let mut order = Vec::new();
        while let Some(job) = state.take_rr() {
            if let Request::Score { text, .. } = &job.request {
                order.push(text.clone());
            }
        }
        // A 3-deep hot lane cannot starve the cold lane: strict alternation
        // until the cold lane runs dry.
        assert_eq!(order, ["hot", "cold", "hot", "cold", "hot"]);
        assert_eq!(state.total, 0);
    }

    #[test]
    fn per_lane_depth_bounds_each_model_independently() {
        let queues = Queues::new(7, 2);
        let mk = || {
            let (tx, _rx) = mpsc::channel();
            Job::new(Request::Info, tx)
        };
        assert!(queues.push(7, mk()).is_ok());
        assert!(queues.push(7, mk()).is_ok());
        assert!(queues.push(7, mk()).is_err(), "default lane at depth must refuse");
        assert!(queues.push(9, mk()).is_ok(), "another model's lane has its own budget");
        queues.close();
        assert!(queues.push(9, mk()).is_err(), "closed queues accept nothing");
        assert_eq!(queues.clear().len(), 3);
    }

    #[test]
    fn brownout_degrades_generate_params_before_shedding() {
        let batcher = Batcher::start(tiny_engine(), 1, 4, Duration::from_millis(1), 16, 1);
        // Force the queue-delay EWMA over the 1 ms threshold directly;
        // submit() reads it through BatchStats::in_brownout.
        batcher.stats().queue_ewma.set(1_000_000);
        assert!(batcher.stats().in_brownout());
        let (tx, rx) = mpsc::channel();
        let request = Request::Generate(GenParams {
            prompt: "the".into(),
            max_tokens: 64,
            temperature: 0.7,
            top_k: 0,
            seed: 7,
            ..GenParams::default()
        });
        batcher.submit(Job::new(request, tx)).map_err(|_| ()).unwrap();
        let reply = rx.recv_timeout(Duration::from_secs(30)).expect("response");
        assert!(reply.degraded, "brownout must mark the reply degraded");
        match reply.response {
            Response::Generate { tokens, .. } => assert!(
                tokens.len() <= BROWNOUT_MAX_TOKENS,
                "degraded job must respect the clamped budget: {} tokens",
                tokens.len()
            ),
            other => panic!("unexpected response: {other:?}"),
        }
        assert_eq!(batcher.stats().brownout_degraded.get(), 1);
        // Scores pass through undegraded (nothing to clamp).
        batcher.stats().queue_ewma.set(1_000_000);
        let (tx, rx) = mpsc::channel();
        let request =
            Request::Score { text: "the cat sat".into(), deadline_ms: 0, trace: false, model: None };
        batcher.submit(Job::new(request, tx)).map_err(|_| ()).unwrap();
        let reply = rx.recv_timeout(Duration::from_secs(30)).expect("response");
        assert!(!reply.degraded, "scores are never degraded");
        assert_eq!(batcher.stats().brownout_degraded.get(), 1);
        batcher.shutdown();
    }

    #[test]
    fn a_disconnected_clients_job_is_cancelled_and_counted() {
        let engine = tiny_engine();
        let batcher = Batcher::start(engine.clone(), 1, 2, Duration::from_millis(1), 8, 0);
        let (tx, rx) = mpsc::channel();
        let token = CancelToken::new();
        token.cancel(); // the client is already gone when the batch assembles
        let mut job = Job::new(
            Request::Generate(GenParams {
                prompt: "the".into(),
                max_tokens: 64,
                ..GenParams::default()
            }),
            tx,
        );
        job.cancel = Some(token);
        batcher.submit(job).map_err(|_| ()).unwrap();
        let reply = rx.recv_timeout(Duration::from_secs(30)).expect("response");
        match reply.response {
            Response::Generate { tokens, .. } => assert!(
                tokens.is_empty(),
                "cancelled before the first step boundary must decode nothing: {tokens:?}"
            ),
            other => panic!("unexpected response: {other:?}"),
        }
        assert_eq!(batcher.stats().cancelled_disconnect.get(), 1);
        assert_eq!(batcher.in_flight(), 0, "the cancelled job released its slot");
        batcher.shutdown();
    }
}
