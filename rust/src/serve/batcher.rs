//! Micro-batching scheduler: request queue → batch assembly by
//! deadline/size → kernel dispatch → response routing.
//!
//! Architecture (all `std`, no async runtime):
//!
//! * submission goes through a **bounded** [`std::sync::mpsc::sync_channel`]
//!   — when `queue_depth` jobs are already waiting, [`Batcher::submit`]
//!   fails immediately and the server surfaces backpressure to the client
//!   instead of buffering unboundedly;
//! * `workers` threads share the receiver behind a mutex.  A worker blocks
//!   for the first job, then keeps the lock only while it drains up to
//!   `max_batch − 1` more jobs or until `max_wait` elapses (the
//!   latency/throughput knob), then releases the queue and executes the
//!   batch — so one worker assembles while the others run kernels;
//! * each job carries its own response [`std::sync::mpsc::Sender`]; results
//!   route back to exactly the connection that asked.
//!
//! Failure domains (PR 6): each engine call is wrapped in
//! [`std::panic::catch_unwind`], so a poisoned request answers `internal`
//! while the worker, the rest of the batch, and the server survive (the
//! same discipline [`crate::exec::pool`] applies one level down).  Jobs
//! whose [`Job::deadline`] expired while queued are shed *before* kernel
//! work with `deadline_exceeded`.  Live queue depth and a service-time
//! EWMA feed [`Batcher::retry_after_ms`], the admission-control hint on
//! `overloaded` responses, and [`Batcher::drain`] bounds graceful
//! shutdown.
//!
//! Generate jobs in one batch decode in lockstep through a single blocked
//! kernel per step ([`Engine::generate_batch`]); score jobs fuse into a
//! single teacher-forced problem ([`Engine::score_batch`]).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::serve::engine::Engine;
use crate::serve::protocol::{ErrorCode, GenParams, Request, Response};
use crate::util::faults;

/// How long an idle worker waits on the queue before re-checking the stop
/// flag (bounds shutdown latency).
const IDLE_POLL: Duration = Duration::from_millis(25);

/// One queued request plus its response channel.
pub struct Job {
    pub request: Request,
    pub respond: mpsc::Sender<Response>,
    /// Absolute shed deadline derived from the request's `deadline_ms`;
    /// checked when the batch is assembled, before any kernel work.
    pub deadline: Option<Instant>,
}

impl Job {
    /// Build a job, starting the request's `deadline_ms` clock now.
    pub fn new(request: Request, respond: mpsc::Sender<Response>) -> Job {
        let deadline = request
            .deadline_ms()
            .and_then(|ms| Instant::now().checked_add(Duration::from_millis(ms)));
        Job { request, respond, deadline }
    }
}

/// Batcher counters, exposed by the `info` endpoint.
#[derive(Debug, Default)]
pub struct BatchStats {
    pub batches: AtomicU64,
    pub jobs: AtomicU64,
    pub max_batch: AtomicU64,
    /// Jobs shed because their `deadline_ms` expired while queued.
    pub shed_deadline: AtomicU64,
    /// Engine panics isolated at the batch boundary (the workers survive).
    pub panics: AtomicU64,
}

impl BatchStats {
    fn record(&self, batch_len: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.jobs.fetch_add(batch_len as u64, Ordering::Relaxed);
        self.max_batch.fetch_max(batch_len as u64, Ordering::Relaxed);
    }
}

/// The micro-batching scheduler.
pub struct Batcher {
    tx: mpsc::SyncSender<Job>,
    workers: Mutex<Vec<JoinHandle<()>>>,
    worker_count: usize,
    stats: Arc<BatchStats>,
    stop: Arc<AtomicBool>,
    /// Jobs submitted but not yet picked up by a worker.
    queued: Arc<AtomicU64>,
    /// Jobs submitted but not yet answered (queued + executing).
    in_flight: Arc<AtomicU64>,
    /// EWMA of per-job service time in microseconds (0 until first batch).
    job_micros: Arc<AtomicU64>,
}

impl Batcher {
    /// Spawn `workers` batch workers over a queue of depth `queue_depth`.
    pub fn start(
        engine: Arc<Engine>,
        workers: usize,
        max_batch: usize,
        max_wait: Duration,
        queue_depth: usize,
    ) -> Batcher {
        let (tx, rx) = mpsc::sync_channel::<Job>(queue_depth.max(1));
        let rx = Arc::new(Mutex::new(rx));
        let stats = Arc::new(BatchStats::default());
        let stop = Arc::new(AtomicBool::new(false));
        let queued = Arc::new(AtomicU64::new(0));
        let in_flight = Arc::new(AtomicU64::new(0));
        let job_micros = Arc::new(AtomicU64::new(0));
        let max_batch = max_batch.max(1);
        let worker_count = workers.max(1);
        let handles = (0..worker_count)
            .map(|_| {
                let engine = engine.clone();
                let rx = rx.clone();
                let stats = stats.clone();
                let stop = stop.clone();
                let queued = queued.clone();
                let in_flight = in_flight.clone();
                let job_micros = job_micros.clone();
                std::thread::spawn(move || {
                    worker_loop(WorkerCtx {
                        engine: &engine,
                        rx: &rx,
                        stats: &stats,
                        stop: &stop,
                        queued: &queued,
                        in_flight: &in_flight,
                        job_micros: &job_micros,
                        max_batch,
                        max_wait,
                    })
                })
            })
            .collect();
        Batcher {
            tx,
            workers: Mutex::new(handles),
            worker_count,
            stats,
            stop,
            queued,
            in_flight,
            job_micros,
        }
    }

    /// Enqueue a job.  `Err(job)` means the queue is full (backpressure) or
    /// the batcher has shut down; the job is handed back so the caller can
    /// answer the client.
    pub fn submit(&self, job: Job) -> Result<(), Job> {
        if self.stop.load(Ordering::SeqCst) {
            return Err(job);
        }
        // Count optimistically so a racing drain() can never observe the
        // queue push without the in-flight credit.
        self.queued.fetch_add(1, Ordering::SeqCst);
        self.in_flight.fetch_add(1, Ordering::SeqCst);
        self.tx
            .try_send(job)
            .map_err(|err| {
                self.queued.fetch_sub(1, Ordering::SeqCst);
                self.in_flight.fetch_sub(1, Ordering::SeqCst);
                match err {
                    mpsc::TrySendError::Full(job) => job,
                    mpsc::TrySendError::Disconnected(job) => job,
                }
            })
    }

    pub fn stats(&self) -> &BatchStats {
        &self.stats
    }

    /// Jobs submitted but not yet answered.
    pub fn in_flight(&self) -> u64 {
        self.in_flight.load(Ordering::SeqCst)
    }

    /// Admission-control hint for `overloaded` responses: roughly how long
    /// until the current queue has been served, from live depth × the
    /// service-time EWMA ÷ workers.  Clamped to `[5 ms, 5 s]`; before any
    /// batch has completed the EWMA defaults to 10 ms/job.
    pub fn retry_after_ms(&self) -> u64 {
        let queued = self.queued.load(Ordering::SeqCst);
        let per_job_micros = match self.job_micros.load(Ordering::Relaxed) {
            0 => 10_000,
            micros => micros,
        };
        let workers = self.worker_count.max(1) as u64;
        ((queued + 1).saturating_mul(per_job_micros) / workers / 1000).clamp(5, 5_000)
    }

    /// Graceful drain: wait (bounded) until every submitted job has been
    /// answered.  Returns `false` if the deadline hit first.  Workers keep
    /// running during the drain; pair with a stopped accept loop so no new
    /// work arrives.
    pub fn drain(&self, deadline: Duration) -> bool {
        let until = Instant::now() + deadline;
        while self.in_flight.load(Ordering::SeqCst) > 0 {
            if Instant::now() >= until {
                return false;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        true
    }

    /// Stop the workers.  Queued-but-unprocessed jobs are dropped, which
    /// closes their response channels — waiting connections observe the
    /// hangup and answer "shutting down".  Call [`Batcher::drain`] first
    /// for a graceful shutdown.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        let mut workers = match self.workers.lock() {
            Ok(guard) => guard,
            Err(poisoned) => poisoned.into_inner(),
        };
        for handle in workers.drain(..) {
            let _ = handle.join();
        }
    }
}

/// Everything one batch worker needs (bundled to keep the spawn site and
/// signatures readable).
struct WorkerCtx<'a> {
    engine: &'a Engine,
    rx: &'a Mutex<mpsc::Receiver<Job>>,
    stats: &'a BatchStats,
    stop: &'a AtomicBool,
    queued: &'a AtomicU64,
    in_flight: &'a AtomicU64,
    job_micros: &'a AtomicU64,
    max_batch: usize,
    max_wait: Duration,
}

fn worker_loop(ctx: WorkerCtx<'_>) {
    loop {
        if ctx.stop.load(Ordering::SeqCst) {
            return;
        }
        let mut jobs: Vec<Job> = Vec::new();
        {
            let guard = match ctx.rx.lock() {
                Ok(guard) => guard,
                Err(poisoned) => poisoned.into_inner(),
            };
            match guard.recv_timeout(IDLE_POLL) {
                Ok(job) => jobs.push(job),
                Err(mpsc::RecvTimeoutError::Timeout) => continue,
                Err(mpsc::RecvTimeoutError::Disconnected) => return,
            }
            let deadline = Instant::now() + ctx.max_wait;
            while jobs.len() < ctx.max_batch {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match guard.recv_timeout(deadline - now) {
                    Ok(job) => jobs.push(job),
                    Err(_) => break,
                }
            }
        }
        ctx.queued.fetch_sub(jobs.len() as u64, Ordering::SeqCst);
        ctx.stats.record(jobs.len());
        let batch_len = jobs.len();
        let started = Instant::now();
        // Belt + braces: run_batch already isolates engine panics per
        // sub-batch; this outer guard keeps the worker alive even if the
        // routing code itself has a bug.  Jobs consumed by such a panic
        // drop their response senders — connections observe the hangup.
        let routed = catch_unwind(AssertUnwindSafe(|| {
            run_batch(ctx.engine, jobs, ctx.stats, ctx.in_flight)
        }));
        if routed.is_err() {
            ctx.stats.panics.fetch_add(1, Ordering::Relaxed);
            eprintln!("[batcher] worker survived a panic outside the batch boundary");
        }
        // Service-time EWMA (per job, in µs): new = 7/8 old + 1/8 sample.
        if batch_len > 0 {
            let sample = (started.elapsed().as_micros() as u64 / batch_len as u64).max(1);
            let old = ctx.job_micros.load(Ordering::Relaxed);
            let next = if old == 0 { sample } else { old - old / 8 + sample / 8 };
            ctx.job_micros.store(next, Ordering::Relaxed);
        }
    }
}

/// Best-effort text of a caught panic payload.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s
    } else {
        "non-string panic payload"
    }
}

/// Execute one assembled batch and route the responses.  Every job is
/// answered exactly once and decrements `in_flight` exactly once, on every
/// path — success, engine error, shed deadline, or isolated panic.
fn run_batch(engine: &Engine, jobs: Vec<Job>, stats: &BatchStats, in_flight: &AtomicU64) {
    let answer = |respond: &mpsc::Sender<Response>, response: Response| {
        let _ = respond.send(response); // client may have hung up
        in_flight.fetch_sub(1, Ordering::SeqCst);
    };
    let now = Instant::now();
    let mut gens: Vec<(GenParams, mpsc::Sender<Response>)> = Vec::new();
    let mut scores: Vec<(String, mpsc::Sender<Response>)> = Vec::new();
    for job in jobs {
        // Deadline shed happens here — after queueing, before kernels.
        if job.deadline.is_some_and(|deadline| now >= deadline) {
            stats.shed_deadline.fetch_add(1, Ordering::Relaxed);
            answer(
                &job.respond,
                Response::err(
                    ErrorCode::DeadlineExceeded,
                    "deadline_ms expired while queued; shed before execution",
                ),
            );
            continue;
        }
        match job.request {
            Request::Generate(params) => gens.push((params, job.respond)),
            Request::Score { text, .. } => scores.push((text, job.respond)),
            // Info/shutdown are answered inline by the connection; they
            // never enter the queue.
            other => answer(
                &job.respond,
                Response::err(ErrorCode::InvalidRequest, format!("op {other:?} is not batchable")),
            ),
        }
    }
    if !gens.is_empty() {
        let params: Vec<GenParams> = gens.iter().map(|(p, _)| p.clone()).collect();
        let results = catch_unwind(AssertUnwindSafe(|| {
            faults::maybe_panic("batcher.panic");
            engine.generate_batch(&params)
        }));
        match results {
            Ok(results) => {
                for ((_, respond), result) in gens.iter().zip(results) {
                    let response = match result {
                        Ok(out) => Response::Generate {
                            text: out.text,
                            tokens: out.tokens,
                            logprobs: out.logprobs,
                        },
                        // Engine-level rejections are request-shaped
                        // problems (bad temperature/top_k, oversize).
                        Err(err) => Response::err(ErrorCode::InvalidRequest, format!("{err:#}")),
                    };
                    answer(respond, response);
                }
            }
            Err(payload) => {
                stats.panics.fetch_add(1, Ordering::Relaxed);
                let msg = format!(
                    "batch execution panicked: {} (request isolated; server still serving)",
                    panic_message(&payload)
                );
                for (_, respond) in &gens {
                    answer(respond, Response::err(ErrorCode::Internal, &msg));
                }
            }
        }
    }
    if !scores.is_empty() {
        let texts: Vec<String> = scores.iter().map(|(t, _)| t.clone()).collect();
        let results = catch_unwind(AssertUnwindSafe(|| {
            faults::maybe_panic("batcher.panic");
            engine.score_batch(&texts)
        }));
        match results {
            Ok(results) => {
                for ((_, respond), result) in scores.iter().zip(results) {
                    let response = match result {
                        Ok(res) => Response::Score {
                            nll: res.nll,
                            perplexity: res.perplexity,
                            count: res.count,
                            logprobs: res.logprobs,
                        },
                        Err(err) => Response::err(ErrorCode::InvalidRequest, format!("{err:#}")),
                    };
                    answer(respond, response);
                }
            }
            Err(payload) => {
                stats.panics.fetch_add(1, Ordering::Relaxed);
                let msg = format!(
                    "batch execution panicked: {} (request isolated; server still serving)",
                    panic_message(&payload)
                );
                for (_, respond) in &scores {
                    answer(respond, Response::err(ErrorCode::Internal, &msg));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::KernelOptions;

    fn tiny_engine() -> Arc<Engine> {
        let opts =
            KernelOptions { n_block: 16, v_block: 64, threads: 1, ..KernelOptions::default() };
        Arc::new(Engine::demo(384, 16, 2, opts).unwrap())
    }

    #[test]
    fn jobs_roundtrip_through_workers() {
        let batcher = Batcher::start(
            tiny_engine(),
            2,
            4,
            Duration::from_millis(2),
            16,
        );
        let mut rxs = Vec::new();
        for i in 0..6 {
            let (tx, rx) = mpsc::channel();
            let request = if i % 2 == 0 {
                Request::Generate(GenParams {
                    prompt: "the".into(),
                    max_tokens: 3,
                    ..GenParams::default()
                })
            } else {
                Request::Score { text: "the cat sat".into(), deadline_ms: 0 }
            };
            batcher.submit(Job::new(request, tx)).map_err(|_| ()).unwrap();
            rxs.push((i, rx));
        }
        for (i, rx) in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(30)).expect("response");
            match (i % 2, resp) {
                (0, Response::Generate { tokens, .. }) => assert!(!tokens.is_empty()),
                (1, Response::Score { count, .. }) => assert!(count > 0),
                (_, other) => panic!("unexpected response: {other:?}"),
            }
        }
        let stats = batcher.stats();
        assert_eq!(stats.jobs.load(Ordering::Relaxed), 6);
        assert!(stats.batches.load(Ordering::Relaxed) >= 1);
        assert_eq!(batcher.in_flight(), 0, "all jobs answered");
        assert!(batcher.drain(Duration::from_millis(50)), "drained batcher reports done");
        // The service-time EWMA is live, so retry hints are data-driven.
        assert!(batcher.retry_after_ms() >= 5);
        batcher.shutdown();
    }

    #[test]
    fn backpressure_bounds_the_queue() {
        // No workers consuming fast enough: depth-1 queue + a stopped
        // batcher cannot accept a second job.
        let batcher = Batcher::start(
            tiny_engine(),
            1,
            1,
            Duration::from_millis(1),
            1,
        );
        batcher.shutdown(); // workers gone; queue still bounded
        let (tx, _rx) = mpsc::channel();
        let job = Job::new(Request::Info, tx);
        assert!(batcher.submit(job).is_err(), "submit after shutdown must fail");
        assert_eq!(batcher.in_flight(), 0, "rejected submits leave no credit");
    }

    #[test]
    fn expired_deadline_is_shed_before_kernel_work() {
        let engine = tiny_engine();
        let served_before = engine.served();
        let batcher = Batcher::start(
            engine.clone(),
            1,
            4,
            Duration::from_millis(1),
            16,
        );
        let (tx, rx) = mpsc::channel();
        // A deadline already in the past when the worker assembles.
        let mut job = Job::new(
            Request::Generate(GenParams {
                prompt: "the".into(),
                max_tokens: 64,
                deadline_ms: 1,
                ..GenParams::default()
            }),
            tx,
        );
        job.deadline = Some(Instant::now() - Duration::from_millis(5));
        batcher.submit(job).map_err(|_| ()).unwrap();
        match rx.recv_timeout(Duration::from_secs(10)).expect("response") {
            Response::Error { code, .. } => assert_eq!(code, ErrorCode::DeadlineExceeded),
            other => panic!("unexpected response: {other:?}"),
        }
        assert_eq!(batcher.stats().shed_deadline.load(Ordering::Relaxed), 1);
        assert_eq!(
            engine.served(),
            served_before,
            "a shed job must never reach the engine"
        );
        batcher.shutdown();
    }
}
