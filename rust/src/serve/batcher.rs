//! Micro-batching scheduler: request queue → batch assembly by
//! deadline/size → kernel dispatch → response routing.
//!
//! Architecture (all `std`, no async runtime):
//!
//! * submission goes through a **bounded** [`std::sync::mpsc::sync_channel`]
//!   — when `queue_depth` jobs are already waiting, [`Batcher::submit`]
//!   fails immediately and the server surfaces backpressure to the client
//!   instead of buffering unboundedly;
//! * `workers` threads share the receiver behind a mutex.  A worker blocks
//!   for the first job, then keeps the lock only while it drains up to
//!   `max_batch − 1` more jobs or until `max_wait` elapses (the
//!   latency/throughput knob), then releases the queue and executes the
//!   batch — so one worker assembles while the others run kernels;
//! * each job carries its own response [`std::sync::mpsc::Sender`]; results
//!   route back to exactly the connection that asked.
//!
//! Generate jobs in one batch decode in lockstep through a single blocked
//! kernel per step ([`Engine::generate_batch`]); score jobs fuse into a
//! single teacher-forced problem ([`Engine::score_batch`]).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::serve::engine::Engine;
use crate::serve::protocol::{GenParams, Request, Response};

/// How long an idle worker waits on the queue before re-checking the stop
/// flag (bounds shutdown latency).
const IDLE_POLL: Duration = Duration::from_millis(25);

/// One queued request plus its response channel.
pub struct Job {
    pub request: Request,
    pub respond: mpsc::Sender<Response>,
}

/// Batcher counters, exposed by the `info` endpoint.
#[derive(Debug, Default)]
pub struct BatchStats {
    pub batches: AtomicU64,
    pub jobs: AtomicU64,
    pub max_batch: AtomicU64,
}

impl BatchStats {
    fn record(&self, batch_len: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.jobs.fetch_add(batch_len as u64, Ordering::Relaxed);
        self.max_batch.fetch_max(batch_len as u64, Ordering::Relaxed);
    }
}

/// The micro-batching scheduler.
pub struct Batcher {
    tx: mpsc::SyncSender<Job>,
    workers: Mutex<Vec<JoinHandle<()>>>,
    stats: Arc<BatchStats>,
    stop: Arc<AtomicBool>,
}

impl Batcher {
    /// Spawn `workers` batch workers over a queue of depth `queue_depth`.
    pub fn start(
        engine: Arc<Engine>,
        workers: usize,
        max_batch: usize,
        max_wait: Duration,
        queue_depth: usize,
    ) -> Batcher {
        let (tx, rx) = mpsc::sync_channel::<Job>(queue_depth.max(1));
        let rx = Arc::new(Mutex::new(rx));
        let stats = Arc::new(BatchStats::default());
        let stop = Arc::new(AtomicBool::new(false));
        let max_batch = max_batch.max(1);
        let handles = (0..workers.max(1))
            .map(|_| {
                let engine = engine.clone();
                let rx = rx.clone();
                let stats = stats.clone();
                let stop = stop.clone();
                std::thread::spawn(move || {
                    worker_loop(&engine, &rx, &stats, &stop, max_batch, max_wait)
                })
            })
            .collect();
        Batcher { tx, workers: Mutex::new(handles), stats, stop }
    }

    /// Enqueue a job.  `Err(job)` means the queue is full (backpressure) or
    /// the batcher has shut down; the job is handed back so the caller can
    /// answer the client.
    pub fn submit(&self, job: Job) -> Result<(), Job> {
        if self.stop.load(Ordering::SeqCst) {
            return Err(job);
        }
        self.tx.try_send(job).map_err(|err| match err {
            mpsc::TrySendError::Full(job) => job,
            mpsc::TrySendError::Disconnected(job) => job,
        })
    }

    pub fn stats(&self) -> &BatchStats {
        &self.stats
    }

    /// Stop the workers.  Queued-but-unprocessed jobs are dropped, which
    /// closes their response channels — waiting connections observe the
    /// hangup and answer "shutting down".
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        let mut workers = match self.workers.lock() {
            Ok(guard) => guard,
            Err(poisoned) => poisoned.into_inner(),
        };
        for handle in workers.drain(..) {
            let _ = handle.join();
        }
    }
}

fn worker_loop(
    engine: &Engine,
    rx: &Mutex<mpsc::Receiver<Job>>,
    stats: &BatchStats,
    stop: &AtomicBool,
    max_batch: usize,
    max_wait: Duration,
) {
    loop {
        if stop.load(Ordering::SeqCst) {
            return;
        }
        let mut jobs: Vec<Job> = Vec::new();
        {
            let guard = match rx.lock() {
                Ok(guard) => guard,
                Err(poisoned) => poisoned.into_inner(),
            };
            match guard.recv_timeout(IDLE_POLL) {
                Ok(job) => jobs.push(job),
                Err(mpsc::RecvTimeoutError::Timeout) => continue,
                Err(mpsc::RecvTimeoutError::Disconnected) => return,
            }
            let deadline = Instant::now() + max_wait;
            while jobs.len() < max_batch {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match guard.recv_timeout(deadline - now) {
                    Ok(job) => jobs.push(job),
                    Err(_) => break,
                }
            }
        }
        stats.record(jobs.len());
        run_batch(engine, jobs);
    }
}

/// Execute one assembled batch and route the responses.
fn run_batch(engine: &Engine, jobs: Vec<Job>) {
    let mut gens: Vec<(GenParams, mpsc::Sender<Response>)> = Vec::new();
    let mut scores: Vec<(String, mpsc::Sender<Response>)> = Vec::new();
    for job in jobs {
        match job.request {
            Request::Generate(params) => gens.push((params, job.respond)),
            Request::Score { text } => scores.push((text, job.respond)),
            // Info/shutdown are answered inline by the connection; they
            // never enter the queue.
            other => {
                let _ = job
                    .respond
                    .send(Response::error(format!("op {other:?} is not batchable")));
            }
        }
    }
    if !gens.is_empty() {
        let params: Vec<GenParams> = gens.iter().map(|(p, _)| p.clone()).collect();
        for ((_, respond), result) in gens.iter().zip(engine.generate_batch(&params)) {
            let response = match result {
                Ok(out) => Response::Generate {
                    text: out.text,
                    tokens: out.tokens,
                    logprobs: out.logprobs,
                },
                Err(err) => Response::error(format!("{err:#}")),
            };
            let _ = respond.send(response); // client may have hung up
        }
    }
    if !scores.is_empty() {
        let texts: Vec<String> = scores.iter().map(|(t, _)| t.clone()).collect();
        for ((_, respond), result) in scores.iter().zip(engine.score_batch(&texts)) {
            let response = match result {
                Ok(res) => Response::Score {
                    nll: res.nll,
                    perplexity: res.perplexity,
                    count: res.count,
                    logprobs: res.logprobs,
                },
                Err(err) => Response::error(format!("{err:#}")),
            };
            let _ = respond.send(response);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::KernelOptions;

    fn tiny_engine() -> Arc<Engine> {
        let opts =
            KernelOptions { n_block: 16, v_block: 64, threads: 1, ..KernelOptions::default() };
        Arc::new(Engine::demo(384, 16, 2, opts).unwrap())
    }

    #[test]
    fn jobs_roundtrip_through_workers() {
        let batcher = Batcher::start(
            tiny_engine(),
            2,
            4,
            Duration::from_millis(2),
            16,
        );
        let mut rxs = Vec::new();
        for i in 0..6 {
            let (tx, rx) = mpsc::channel();
            let request = if i % 2 == 0 {
                Request::Generate(GenParams {
                    prompt: "the".into(),
                    max_tokens: 3,
                    ..GenParams::default()
                })
            } else {
                Request::Score { text: "the cat sat".into() }
            };
            batcher.submit(Job { request, respond: tx }).map_err(|_| ()).unwrap();
            rxs.push((i, rx));
        }
        for (i, rx) in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(30)).expect("response");
            match (i % 2, resp) {
                (0, Response::Generate { tokens, .. }) => assert!(!tokens.is_empty()),
                (1, Response::Score { count, .. }) => assert!(count > 0),
                (_, other) => panic!("unexpected response: {other:?}"),
            }
        }
        let stats = batcher.stats();
        assert_eq!(stats.jobs.load(Ordering::Relaxed), 6);
        assert!(stats.batches.load(Ordering::Relaxed) >= 1);
        batcher.shutdown();
    }

    #[test]
    fn backpressure_bounds_the_queue() {
        // No workers consuming fast enough: depth-1 queue + a stopped
        // batcher cannot accept a second job.
        let batcher = Batcher::start(
            tiny_engine(),
            1,
            1,
            Duration::from_millis(1),
            1,
        );
        batcher.shutdown(); // workers gone; queue still bounded
        let (tx, _rx) = mpsc::channel();
        let job = Job { request: Request::Info, respond: tx };
        assert!(batcher.submit(job).is_err(), "submit after shutdown must fail");
    }
}
