//! Server-Sent Events framing for `POST /v1/generate` with `"stream":true`.
//!
//! The stream is deliberately minimal — `data:` lines only, one JSON
//! object per event, flushed per token straight out of the engine's
//! lockstep decode loop:
//!
//! ```text
//! HTTP/1.1 200 OK
//! Content-Type: text/event-stream
//! Cache-Control: no-cache
//! Connection: close
//!
//! data: {"token":17,"logprob":-0.41,"text":" the"}
//!
//! data: {"token":93,"logprob":-1.07,"text":" mat"}
//!
//! data: {"done":true,"text":" the mat","tokens":2}
//!
//! data: [DONE]
//! ```
//!
//! Mid-stream failures keep the framing: the error travels as a
//! `data: {"error":{...}}` event (same body shape as non-streaming HTTP
//! errors) followed by the terminal `data: [DONE]`, because the `200 OK`
//! status is already on the wire once streaming starts.  The response has
//! no `Content-Length` and is never chunked — the server closes the
//! connection to end the stream, which every SSE client treats as EOF.
//!
//! [`parse_data_events`] is the client half, shared by the conformance
//! tests and `servebench --http`.

use std::io::{self, Write};

/// Writer half: wraps the connection once the route decides to stream.
pub struct SseWriter<W: Write> {
    w: W,
    events: u64,
}

impl<W: Write> SseWriter<W> {
    /// Write the response head and lock the connection into event framing.
    pub fn start(mut w: W) -> io::Result<SseWriter<W>> {
        w.write_all(
            b"HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\n\
              Cache-Control: no-cache\r\nConnection: close\r\n\r\n",
        )?;
        w.flush()?;
        Ok(SseWriter { w, events: 0 })
    }

    /// One event, flushed immediately — this is the per-token latency
    /// path, so nothing here may buffer.  `data` must be a single line
    /// (the JSON serializer never emits newlines).
    pub fn event(&mut self, data: &str) -> io::Result<()> {
        debug_assert!(!data.contains('\n'), "SSE data must be single-line: {data:?}");
        self.w.write_all(b"data: ")?;
        self.w.write_all(data.as_bytes())?;
        self.w.write_all(b"\n\n")?;
        self.w.flush()?;
        self.events += 1;
        Ok(())
    }

    /// Terminal sentinel: every stream ends with `data: [DONE]`.
    pub fn done(mut self) -> io::Result<u64> {
        self.w.write_all(b"data: [DONE]\n\n")?;
        self.w.flush()?;
        Ok(self.events + 1)
    }

    /// Events written so far (the terminal `[DONE]` counts once sent).
    pub fn events(&self) -> u64 {
        self.events
    }
}

/// Client half: split a raw SSE body into its `data:` payloads, in order,
/// including the terminal `[DONE]`.
pub fn parse_data_events(body: &str) -> Vec<String> {
    body.split("\n\n")
        .filter_map(|block| {
            let line = block.trim();
            line.strip_prefix("data:").map(|rest| rest.trim_start().to_string())
        })
        .filter(|s| !s.is_empty())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frames_events_and_terminal_done() {
        let mut wire = Vec::new();
        {
            let mut sse = SseWriter::start(&mut wire).unwrap();
            sse.event(r#"{"token":1,"logprob":-0.5,"text":"a"}"#).unwrap();
            sse.event(r#"{"token":2,"logprob":-0.25,"text":"b"}"#).unwrap();
            assert_eq!(sse.events(), 2);
            assert_eq!(sse.done().unwrap(), 3);
        }
        let raw = String::from_utf8(wire).unwrap();
        let head_end = raw.find("\r\n\r\n").expect("response head");
        assert!(raw[..head_end].contains("Content-Type: text/event-stream"));
        assert!(raw[..head_end].contains("Connection: close"));
        let events = parse_data_events(&raw[head_end + 4..]);
        assert_eq!(events.len(), 3);
        assert!(events[0].contains("\"token\":1"));
        assert_eq!(events.last().unwrap(), "[DONE]");
    }

    #[test]
    fn parser_ignores_noise_between_events() {
        let events = parse_data_events("data: {\"a\":1}\n\n\n\ndata: [DONE]\n\n");
        assert_eq!(events, vec!["{\"a\":1}".to_string(), "[DONE]".to_string()]);
        assert!(parse_data_events("").is_empty());
    }
}
