//! Blocking line-protocol client — used by `cce client`, the serve bench,
//! the roundtrip example, and the integration tests.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};

use anyhow::{anyhow, bail, Context, Result};

use crate::serve::protocol::{GenParams, Request, Response};

/// One connection to a serve instance.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: impl ToSocketAddrs + std::fmt::Debug) -> Result<Client> {
        let stream = TcpStream::connect(&addr)
            .with_context(|| format!("connecting to {addr:?}"))?;
        stream.set_nodelay(true).ok();
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { reader, writer: stream })
    }

    /// One request/response roundtrip.
    pub fn call(&mut self, request: &Request) -> Result<Response> {
        let mut line = request.to_line();
        line.push('\n');
        self.writer.write_all(line.as_bytes())?;
        self.writer.flush()?;
        let mut reply = String::new();
        let n = self.reader.read_line(&mut reply)?;
        if n == 0 {
            bail!("server closed the connection");
        }
        Response::parse(&reply)
    }

    /// `call` that promotes protocol-level errors to `Err`.
    pub fn call_ok(&mut self, request: &Request) -> Result<Response> {
        match self.call(request)? {
            Response::Error { message } => Err(anyhow!("server error: {message}")),
            response => Ok(response),
        }
    }

    pub fn generate(&mut self, params: GenParams) -> Result<Response> {
        self.call_ok(&Request::Generate(params))
    }

    pub fn score(&mut self, text: &str) -> Result<Response> {
        self.call_ok(&Request::Score { text: text.to_string() })
    }

    pub fn info(&mut self) -> Result<Response> {
        self.call_ok(&Request::Info)
    }

    pub fn shutdown(&mut self) -> Result<Response> {
        self.call_ok(&Request::Shutdown)
    }
}
