//! Blocking line-protocol client — used by `cce client`, the serve bench,
//! the roundtrip example, and the integration tests.
//!
//! Resilience (PR 6): [`ClientConfig`] adds connect/read timeouts and a
//! bounded [`RetryPolicy`].  Retry applies only to *retryable* failures —
//! `overloaded` responses (honoring the server's `retry_after_ms`
//! admission hint) and transport errors (reconnect + resend) — with
//! exponential backoff plus jitter so a thundering herd of clients does
//! not re-arrive in lockstep.  [`Client::stats`] counts what happened
//! (sheds observed, retries spent, reconnects) for `cce servebench`.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::serve::protocol::{ErrorCode, GenParams, Request, Response};
use crate::util::rng::Rng;

/// Bounded retry with exponential backoff + jitter.
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Extra attempts after the first (0 = fail fast, the old behavior).
    pub retries: u32,
    /// First backoff step; doubles per attempt up to `max_backoff`.  The
    /// server's `retry_after_ms` hint overrides when larger.
    pub base_backoff: Duration,
    pub max_backoff: Duration,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy {
            retries: 0,
            base_backoff: Duration::from_millis(25),
            max_backoff: Duration::from_secs(2),
        }
    }
}

/// Connection knobs.  `None` timeouts mean "block forever" (the old
/// behavior, still the [`Client::connect`] default).
#[derive(Debug, Clone, Default)]
pub struct ClientConfig {
    pub connect_timeout: Option<Duration>,
    /// Read AND write bound per roundtrip leg.
    pub io_timeout: Option<Duration>,
    pub retry: RetryPolicy,
}

/// What the retry machinery observed (monotone counters).
#[derive(Debug, Default)]
pub struct ClientStats {
    /// `overloaded` responses received (whether or not retried).
    pub shed: AtomicU64,
    /// Attempts re-issued after a retryable failure.
    pub retries: AtomicU64,
    /// Transport-error recoveries that re-dialed the server.
    pub reconnects: AtomicU64,
}

/// Distinguishes client instances in the jitter seed so identical
/// configurations still back off differently.
static CLIENT_SEQ: AtomicU64 = AtomicU64::new(0);

/// One connection to a serve instance.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    /// Resolved once at connect so retries can re-dial without re-resolving.
    addrs: Vec<SocketAddr>,
    cfg: ClientConfig,
    rng: Rng,
    pub stats: ClientStats,
}

impl Client {
    /// Connect with default config: no timeouts, no retries.
    pub fn connect(addr: impl ToSocketAddrs + std::fmt::Debug) -> Result<Client> {
        Client::connect_with(addr, ClientConfig::default())
    }

    /// Connect with explicit timeout/retry behavior.
    pub fn connect_with(
        addr: impl ToSocketAddrs + std::fmt::Debug,
        cfg: ClientConfig,
    ) -> Result<Client> {
        let addrs: Vec<SocketAddr> = addr
            .to_socket_addrs()
            .with_context(|| format!("resolving {addr:?}"))?
            .collect();
        if addrs.is_empty() {
            bail!("no addresses for {addr:?}");
        }
        let (reader, writer) = dial(&addrs, &cfg)?;
        let seq = CLIENT_SEQ.fetch_add(1, Ordering::Relaxed);
        let port_salt = (addrs[0].port() as u64) << 32;
        Ok(Client {
            reader,
            writer,
            addrs,
            cfg,
            rng: Rng::new(0xC11E_47B0 ^ port_salt ^ seq),
            stats: ClientStats::default(),
        })
    }

    /// One request/response roundtrip, no retry.
    pub fn call(&mut self, request: &Request) -> Result<Response> {
        let mut line = request.to_line();
        line.push('\n');
        self.writer.write_all(line.as_bytes())?;
        self.writer.flush()?;
        let mut reply = String::new();
        let n = self.reader.read_line(&mut reply)?;
        if n == 0 {
            bail!("server closed the connection");
        }
        Response::parse(&reply)
    }

    /// `call` under the retry policy: `overloaded` responses and transport
    /// errors are retried (with backoff + jitter, honoring the server's
    /// `retry_after_ms` hint) up to `retries` extra attempts; every other
    /// outcome — including non-retryable errors like `invalid_request` —
    /// returns immediately.
    pub fn call_retry(&mut self, request: &Request) -> Result<Response> {
        let retries = self.cfg.retry.retries;
        let mut attempt: u32 = 0;
        loop {
            match self.call(request) {
                Ok(Response::Error { code, message, retry_after_ms }) if code.retryable() => {
                    self.stats.shed.fetch_add(1, Ordering::Relaxed);
                    if attempt >= retries {
                        return Ok(Response::Error { code, message, retry_after_ms });
                    }
                    self.stats.retries.fetch_add(1, Ordering::Relaxed);
                    self.sleep_backoff(attempt, retry_after_ms);
                }
                Ok(response) => return Ok(response),
                Err(err) => {
                    if attempt >= retries {
                        return Err(err);
                    }
                    self.stats.retries.fetch_add(1, Ordering::Relaxed);
                    self.sleep_backoff(attempt, None);
                    // The old stream may be torn mid-line; start clean.
                    if let Ok((reader, writer)) = dial(&self.addrs, &self.cfg) {
                        self.reader = reader;
                        self.writer = writer;
                        self.stats.reconnects.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
            attempt += 1;
        }
    }

    /// Exponential backoff with full-range jitter: the server hint (when
    /// larger) sets the base, doubled per attempt, capped, then scaled by
    /// a uniform factor in `[0.5, 1.0]`.
    fn sleep_backoff(&mut self, attempt: u32, hint_ms: Option<u64>) {
        let base = (self.cfg.retry.base_backoff.as_millis() as u64) << attempt.min(10);
        let ms = hint_ms
            .unwrap_or(0)
            .max(base)
            .min(self.cfg.retry.max_backoff.as_millis() as u64);
        let jittered = ((ms as f64) * (0.5 + 0.5 * self.rng.f64())) as u64;
        std::thread::sleep(Duration::from_millis(jittered.max(1)));
    }

    /// `call_retry` that promotes protocol-level errors to `Err`.
    pub fn call_ok(&mut self, request: &Request) -> Result<Response> {
        match self.call_retry(request)? {
            Response::Error { code, message, .. } => {
                Err(anyhow!("server error [{}]: {message}", code.as_str()))
            }
            response => Ok(response),
        }
    }

    pub fn generate(&mut self, params: GenParams) -> Result<Response> {
        self.call_ok(&Request::Generate(params))
    }

    pub fn score(&mut self, text: &str) -> Result<Response> {
        self.call_ok(&Request::Score {
            text: text.to_string(),
            deadline_ms: 0,
            trace: false,
            model: None,
        })
    }

    pub fn info(&mut self) -> Result<Response> {
        self.call_ok(&Request::Info)
    }

    /// Snapshot the server's metric families (`{"op":"metrics"}`).
    pub fn metrics(&mut self) -> Result<Response> {
        self.call_ok(&Request::Metrics)
    }

    pub fn shutdown(&mut self) -> Result<Response> {
        self.call_ok(&Request::Shutdown)
    }
}

/// Dial the first address that answers, applying the configured timeouts.
fn dial(addrs: &[SocketAddr], cfg: &ClientConfig) -> Result<(BufReader<TcpStream>, TcpStream)> {
    let mut last_err = None;
    for addr in addrs {
        let dialed = match cfg.connect_timeout {
            Some(t) => TcpStream::connect_timeout(addr, t),
            None => TcpStream::connect(addr),
        };
        match dialed {
            Ok(stream) => {
                stream.set_nodelay(true).ok();
                stream.set_read_timeout(cfg.io_timeout).ok();
                stream.set_write_timeout(cfg.io_timeout).ok();
                let reader = BufReader::new(stream.try_clone()?);
                return Ok((reader, stream));
            }
            Err(err) => last_err = Some(err),
        }
    }
    Err(anyhow!("connect failed: {}", last_err.expect("addrs checked non-empty")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn retryable_is_exactly_overloaded() {
        // call_retry's loop keys off this; pin the contract here too.
        assert!(ErrorCode::Overloaded.retryable());
        for code in [
            ErrorCode::InvalidRequest,
            ErrorCode::DeadlineExceeded,
            ErrorCode::Internal,
            ErrorCode::ShuttingDown,
        ] {
            assert!(!code.retryable(), "{code:?} must not be retried");
        }
    }

    #[test]
    fn backoff_honors_hint_and_cap() {
        // White-box the arithmetic (not the sleep): hint wins when larger,
        // the cap always wins, jitter keeps at least half.
        let retry = RetryPolicy::default();
        let base = |attempt: u32| (retry.base_backoff.as_millis() as u64) << attempt.min(10);
        assert_eq!(base(0), 25);
        assert_eq!(base(2), 100);
        let capped = base(20).min(retry.max_backoff.as_millis() as u64);
        assert_eq!(capped, 2_000, "cap bounds runaway exponentials");
        let with_hint = 150u64.max(base(0));
        assert_eq!(with_hint, 150, "server hint overrides a smaller base");
    }
}
