//! The coordinator side: a [`Fleet`] of shard workers driven in lockstep.
//!
//! Collectives are send-all-then-receive-all over the per-worker
//! [`Transport`] links, so shard work overlaps while the coordinator
//! blocks only on the slowest reply.  Replies are folded strictly in
//! ascending shard order, which makes every merge independent of reply
//! *arrival* order:
//!
//! * **LSE** ([`merge_lse`]): `lse = m + ln Σ_k exp(lse_k − m)` with
//!   `m = max_k lse_k`, folded in f64 — exact in real arithmetic because
//!   the vocabulary ranges are disjoint.  The 1-shard merge is bitwise
//!   the identity (`exp(0) = 1`, `ln 1 = 0`, and f32 → f64 → f32 of the
//!   same value round-trips), so a 1-shard fleet reproduces
//!   [`crate::exec::cce_forward`] bit-for-bit.
//! * **top-k / sampling**: candidates carry the kernels' raw comparison
//!   keys (untempered logits, perturbed Gumbel scores) and global token
//!   ids, merged under the kernels' exact total orders — merged *tokens*
//!   are bitwise identical to the single-process kernels for any shard
//!   count; reported log-probabilities differ from single-process only
//!   through the merged LSE's final rounding (≤ a few ulps).
//! * **gradients**: per-shard partial `dE` sums fold in f64; `dC` never
//!   travels — each worker applies its own SGD slice update in place.
//!
//! Failure semantics: any worker error — an `{"ok":false}` reply, a
//! severed connection, a read timeout — fails the whole collective with
//! a pointed error naming the worker.  Surviving workers are sent a
//! best-effort `abort` (request *and* reply, keeping their links in
//! sync) so the fleet is reusable when the caller continues; a dead
//! worker cannot be rejoined — callers abort the step (train) or surface
//! a structured `internal` error (serve), never hang.

use std::io::BufRead;
use std::process::{Child, ChildStdout, Command, Stdio};
use std::sync::Mutex;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::exec::{
    topk_candidate_order, FilterStats, KernelOptions, ParamBuf, SampleOut, ScoreOut, TopKOut,
    TopKRow,
};
use crate::obs;
use crate::util::json::Json;

use super::protocol::{
    check_ok, floats_field, ints_field, req_abort, req_fetch, req_hello, req_load, req_merge,
    req_sample, req_shutdown, req_step, req_topk, SHARD_PROTO_VERSION,
};
use super::transport::{LocalTransport, TcpTransport, Transport};
use super::{split_vocab, ShardSpec};

/// Merged forward collective: exactly the fields the trainer's step and
/// the engine's scorer need, with [`ShardStep::loss`] computed the same
/// way as [`crate::exec::ForwardOut::loss`].
pub struct ShardStep {
    pub lse: Vec<f32>,
    pub target_logit: Vec<f32>,
    pub loss: f64,
    pub count: usize,
}

/// Merged backward collective.  `dC` stays on the workers (applied in
/// place when a learning rate rides the `merge` request); the coordinator
/// receives only the summed `dE` and the scalars it reports.
pub struct ShardMerge {
    pub d_e: Vec<f32>,
    /// `Σ_k |dC_k|²` in f64 — the classifier's share of the grad norm.
    pub dc_sqnorm: f64,
    pub stats: FilterStats,
}

/// Merge per-shard partial LSEs (disjoint vocabulary ranges) into the
/// global per-row LSE.  Folded in f64 in ascending shard order: the
/// result is independent of reply arrival order, and the 1-shard case is
/// bitwise the identity.
pub fn merge_lse(parts: &[Vec<f32>], n: usize) -> Vec<f32> {
    (0..n).map(|i| merge_lse_row(parts.iter().map(|p| p[i]))).collect()
}

fn merge_lse_row(parts: impl Iterator<Item = f32> + Clone) -> f32 {
    let m = parts.clone().fold(f32::NEG_INFINITY, f32::max);
    if !m.is_finite() {
        return m;
    }
    let s: f64 = parts.map(|lse| f64::exp((lse - m) as f64)).sum();
    (m as f64 + s.ln()) as f32
}

struct FleetInner {
    links: Vec<Box<dyn Transport>>,
    children: Vec<Child>,
    /// Spawned workers' stdout pipes, held open so their clean-shutdown
    /// marker has somewhere to go.
    keepalive: Vec<std::io::BufReader<ChildStdout>>,
}

/// A fleet of vocabulary-shard workers.  All collectives take `&self`
/// (links behind a mutex), so an `Arc<Fleet>` drops into the serve
/// engine and the trainer unchanged.
pub struct Fleet {
    v: usize,
    d: usize,
    specs: Vec<ShardSpec>,
    inner: Mutex<FleetInner>,
}

impl Fleet {
    /// In-process fleet over [`LocalTransport`] workers — unit tests and
    /// single-machine debugging; exercises the full wire encoding.
    pub fn local(count: usize, v: usize, d: usize) -> Result<Fleet> {
        let specs = split_vocab(v, count)?;
        let links: Vec<Box<dyn Transport>> =
            (0..count).map(|k| Box::new(LocalTransport::new(k)) as Box<dyn Transport>).collect();
        Fleet::finish(v, d, specs, links, Vec::new(), Vec::new())
    }

    /// Connect to already-running `cce shard-worker` processes
    /// (`--shard-endpoints`); shard `k` is `endpoints[k]`.  This is the
    /// multi-node path: the endpoints just stop being loopback.
    pub fn connect(endpoints: &[String], v: usize, d: usize) -> Result<Fleet> {
        let specs = split_vocab(v, endpoints.len())?;
        let mut links: Vec<Box<dyn Transport>> = Vec::with_capacity(endpoints.len());
        for ep in endpoints {
            links.push(Box::new(TcpTransport::connect(ep)?));
        }
        Fleet::finish(v, d, specs, links, Vec::new(), Vec::new())
    }

    /// Spawn `count` workers of this same binary on loopback ephemeral
    /// ports (`--shards N`), parsing each `[shard] ready` announce for
    /// the bound address.  The fleet owns the children: they are shut
    /// down (or killed) on drop.
    pub fn spawn(count: usize, v: usize, d: usize) -> Result<Fleet> {
        let specs = split_vocab(v, count)?;
        let exe = std::env::current_exe().context("locating the cce binary to spawn workers")?;
        let mut children = Vec::with_capacity(count);
        let mut keepalive = Vec::with_capacity(count);
        let mut links: Vec<Box<dyn Transport>> = Vec::with_capacity(count);
        for k in 0..count {
            let mut child = Command::new(&exe)
                .args(["shard-worker", "--host", "127.0.0.1", "--port", "0"])
                .stdout(Stdio::piped())
                .spawn()
                .with_context(|| format!("spawning shard worker {k}"))?;
            let stdout = child.stdout.take().expect("stdout was piped");
            let mut reader = std::io::BufReader::new(stdout);
            let mut line = String::new();
            let addr = loop {
                line.clear();
                let n = reader.read_line(&mut line).context("reading worker announce")?;
                if n == 0 {
                    let _ = child.kill();
                    bail!("shard worker {k} exited before announcing an address");
                }
                if let Some(rest) = line.trim().strip_prefix("[shard] ready proto=line addr=") {
                    break rest.to_string();
                }
            };
            links.push(Box::new(TcpTransport::connect(&addr)?));
            children.push(child);
            keepalive.push(reader);
        }
        Fleet::finish(v, d, specs, links, children, keepalive)
    }

    fn finish(
        v: usize,
        d: usize,
        specs: Vec<ShardSpec>,
        links: Vec<Box<dyn Transport>>,
        children: Vec<Child>,
        keepalive: Vec<std::io::BufReader<ChildStdout>>,
    ) -> Result<Fleet> {
        let fleet = Fleet { v, d, specs, inner: Mutex::new(FleetInner { links, children, keepalive }) };
        let replies = fleet.collective("hello", |_| req_hello(), false)?;
        for (spec, reply) in fleet.specs.iter().zip(&replies) {
            let proto = reply.get("proto").and_then(|p| p.as_i64()).unwrap_or(0);
            if proto != SHARD_PROTO_VERSION {
                bail!(
                    "shard {} speaks protocol v{proto}, this build speaks v{SHARD_PROTO_VERSION}",
                    spec.index
                );
            }
        }
        super::record_workers(fleet.specs.len());
        Ok(fleet)
    }

    pub fn shard_count(&self) -> usize {
        self.specs.len()
    }

    pub fn vocab(&self) -> usize {
        self.v
    }

    pub fn dim(&self) -> usize {
        self.d
    }

    pub fn specs(&self) -> &[ShardSpec] {
        &self.specs
    }

    /// Peer descriptions, shard order (for `/v1/models` and logs).
    pub fn endpoints(&self) -> Vec<String> {
        let inner = self.lock();
        inner.links.iter().map(|l| l.describe()).collect()
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, FleetInner> {
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Run one collective: build a request per shard, send all, receive
    /// all, fail with a pointed error (and best-effort `abort` resync of
    /// survivors) if any worker fails.
    fn collective(&self, op: &str, req_for: impl Fn(&ShardSpec) -> Json, is_step: bool) -> Result<Vec<Json>> {
        let sw = obs::Stopwatch::start();
        let mut inner = self.lock();
        let reqs: Vec<Json> = self.specs.iter().map(&req_for).collect();
        let mut bytes = 0usize;
        let mut first_err: Option<String> = None;
        let mut sent = vec![false; reqs.len()];
        for (i, (link, req)) in inner.links.iter_mut().zip(&reqs).enumerate() {
            match link.send(req) {
                Ok(n) => {
                    bytes += n;
                    sent[i] = true;
                }
                Err(e) => {
                    first_err.get_or_insert_with(|| format!("shard {i} ({}): {e}", link.describe()));
                }
            }
        }
        let mut replies: Vec<Option<Json>> = Vec::with_capacity(reqs.len());
        for (i, link) in inner.links.iter_mut().enumerate() {
            if !sent[i] {
                replies.push(None);
                continue;
            }
            // Receive from every link we wrote to, even after an earlier
            // failure: a surviving worker's reply must be consumed or the
            // next collective would read stale lines.
            match link.recv().and_then(|(reply, n)| {
                bytes += n;
                check_ok(&reply).map(|()| reply)
            }) {
                Ok(reply) => replies.push(Some(reply)),
                Err(e) => {
                    first_err.get_or_insert_with(|| format!("shard {i} ({}): {e}", link.describe()));
                    replies.push(None);
                }
            }
        }
        if let Some(msg) = first_err {
            super::record_worker_error();
            if op != "abort" && op != "shutdown" {
                abort_links(&mut inner.links);
            }
            bail!(
                "shard {op} collective failed at {msg}; the step was aborted \
                 (a crashed worker cannot rejoin — restart the fleet)"
            );
        }
        super::record_exchange(bytes, sw.elapsed_us(), is_step);
        Ok(replies.into_iter().map(|r| r.expect("no error implies reply")).collect())
    }

    /// Ship the classifier to the workers, one contiguous column slice
    /// each (widened to f32 on the wire — exact for both dtypes).
    pub fn load(&self, cls: &ParamBuf, opts: &KernelOptions) -> Result<()> {
        if cls.len() != self.v * self.d {
            bail!("classifier has {} values, fleet expects {}×{}", cls.len(), self.v, self.d);
        }
        let full = cls.to_f32_vec();
        let dtype = cls.dtype();
        let d = self.d;
        self.collective(
            "load",
            |spec| req_load(spec, self.v, d, dtype, opts, &full[spec.j0 * d..spec.j1 * d]),
            false,
        )?;
        Ok(())
    }

    /// Forward collective: broadcast `(E, labels)`, merge per-shard LSEs
    /// exactly, pick each row's target logit off its owner shard, and
    /// reduce the loss the same way [`crate::exec::cce_forward`] does.
    pub fn step(&self, e: &[f32], x: &[i32]) -> Result<ShardStep> {
        let n = x.len();
        if e.len() != n * self.d {
            bail!("step: e has {} values, want n×d = {}×{}", e.len(), n, self.d);
        }
        let replies = self.collective("step", |_| req_step(e, x), true)?;
        let mut lse_parts = Vec::with_capacity(replies.len());
        let mut tgt_parts = Vec::with_capacity(replies.len());
        for reply in &replies {
            lse_parts.push(floats_field(reply, "lse", n)?);
            tgt_parts.push(floats_field(reply, "tgt", n)?);
        }
        let lse = merge_lse(&lse_parts, n);
        let mut target_logit = vec![0.0f32; n];
        for (i, &t) in x.iter().enumerate() {
            if t >= 0 {
                let owner = self
                    .specs
                    .iter()
                    .position(|s| s.owns(t))
                    .ok_or_else(|| anyhow!("label {t} outside vocab {}", self.v))?;
                target_logit[i] = tgt_parts[owner][i];
            }
        }
        let count = x.iter().filter(|&&t| t >= 0).count();
        let loss_sum: f64 = x
            .iter()
            .enumerate()
            .filter(|(_, &t)| t >= 0)
            .map(|(i, _)| (lse[i] - target_logit[i]) as f64)
            .sum();
        let loss = if count == 0 { 0.0 } else { loss_sum / count as f64 };
        Ok(ShardStep { lse, target_logit, loss, count })
    }

    /// Backward collective: broadcast the merged LSE (so every shard's
    /// §4.3 filter skips against the *global* distribution), the global
    /// active count, and optionally the SGD learning rate the workers
    /// apply to their own slices.  Must follow a [`Fleet::step`].
    pub fn merge_grads(&self, lse: &[f32], lr: Option<f32>, count: usize) -> Result<ShardMerge> {
        let n = lse.len();
        let replies = self.collective("merge", |_| req_merge(lse, lr, count), false)?;
        let mut d_e = vec![0.0f64; n * self.d];
        let mut dc_sqnorm = 0.0f64;
        let mut stats = FilterStats::default();
        for reply in &replies {
            let part = floats_field(reply, "de", n * self.d)?;
            for (acc, &g) in d_e.iter_mut().zip(&part) {
                *acc += g as f64;
            }
            dc_sqnorm += reply
                .req("dc_sqnorm")?
                .as_f64()
                .ok_or_else(|| anyhow!("dc_sqnorm must be a number"))?;
            stats.merge(&FilterStats {
                blocks_total: stat_u64(reply, "blocks_total")?,
                blocks_skipped: stat_u64(reply, "blocks_skipped")?,
                sig_entries: stat_u64(reply, "sig_entries")?,
            });
        }
        Ok(ShardMerge { d_e: d_e.iter().map(|&g| g as f32).collect(), dc_sqnorm, stats })
    }

    /// Merged top-k: per-shard bounded heaps carry raw logits + global
    /// token ids; the union re-sorts under the kernel's exact candidate
    /// order, so merged tokens are bitwise [`crate::exec::topk`]'s for
    /// any shard count.  Log-probabilities renormalize against the
    /// merged LSE.
    pub fn topk(&self, e: &[f32], rows: usize, k: usize) -> Result<TopKOut> {
        if k == 0 || k > self.v {
            bail!("top-k k={k} out of range for vocab {}", self.v);
        }
        if e.len() != rows * self.d {
            bail!("topk: e has {} values, want rows×d = {}×{}", e.len(), rows, self.d);
        }
        let replies = self.collective("topk", |_| req_topk(e, rows, k), false)?;
        let parts = parse_topk_parts(&replies, rows, k)?;
        let mut out_rows = Vec::with_capacity(rows);
        for i in 0..rows {
            let lse = merge_lse_row(parts.iter().map(|p| p[i].lse));
            let mut cands: Vec<(f32, i32)> = parts
                .iter()
                .flat_map(|p| p[i].z.iter().copied().zip(p[i].t.iter().copied()))
                .collect();
            cands.sort_by(|a, b| topk_candidate_order(*a, *b));
            cands.truncate(k);
            out_rows.push(TopKRow {
                tokens: cands.iter().map(|c| c.1).collect(),
                logprobs: cands.iter().map(|c| c.0 - lse).collect(),
                lse,
            });
        }
        let workspace_bytes = rows * k * 8 * self.specs.len();
        Ok(TopKOut { rows: out_rows, workspace_bytes })
    }

    /// Merged Gumbel-max sampling: noise is keyed on global column ids on
    /// the workers, so the per-shard winners are the same perturbed
    /// scores the single-process kernel compares; ascending-shard strict
    /// `>` reproduces its first-max tie-breaking exactly — merged tokens
    /// are bitwise [`crate::exec::sample`]'s for any shard count.
    pub fn sample(&self, e: &[f32], rows: usize, temperature: f32, seeds: &[u64]) -> Result<SampleOut> {
        if seeds.len() != rows {
            bail!("sample: {} seeds for {rows} rows", seeds.len());
        }
        if e.len() != rows * self.d {
            bail!("sample: e has {} values, want rows×d = {}×{}", e.len(), rows, self.d);
        }
        let replies =
            self.collective("sample", |_| req_sample(e, rows, temperature, seeds), false)?;
        let mut tokens_parts = Vec::with_capacity(replies.len());
        let mut scores_parts = Vec::with_capacity(replies.len());
        let mut logits_parts = Vec::with_capacity(replies.len());
        let mut lse_parts = Vec::with_capacity(replies.len());
        for reply in &replies {
            tokens_parts.push(ints_field(reply, "tokens", rows)?);
            scores_parts.push(floats_field(reply, "scores", rows)?);
            logits_parts.push(floats_field(reply, "logits", rows)?);
            lse_parts.push(floats_field(reply, "lse", rows)?);
        }
        let mut tokens = Vec::with_capacity(rows);
        let mut logprobs = Vec::with_capacity(rows);
        for i in 0..rows {
            let lse = merge_lse_row(lse_parts.iter().map(|p| p[i]));
            let mut win = 0usize;
            for s in 1..self.specs.len() {
                if scores_parts[s][i] > scores_parts[win][i] {
                    win = s;
                }
            }
            tokens.push(tokens_parts[win][i]);
            logprobs.push(logits_parts[win][i] - lse);
        }
        Ok(SampleOut { tokens, logprobs, workspace_bytes: rows * 16 * self.specs.len() })
    }

    /// Teacher-forced scoring over the fleet: one forward collective,
    /// per-row `log p(x_i) = z_{x_i} − lse_i`, then an `abort` so the
    /// workers drop the cached step state no backward will consume.
    pub fn score(&self, e: &[f32], x: &[i32]) -> Result<ScoreOut> {
        let st = self.step(e, x)?;
        self.abort()?;
        let logprobs: Vec<f32> = x
            .iter()
            .enumerate()
            .map(|(i, &t)| if t >= 0 { st.target_logit[i] - st.lse[i] } else { 0.0 })
            .collect();
        Ok(ScoreOut {
            logprobs,
            nll: st.loss,
            perplexity: st.loss.exp(),
            count: st.count,
            workspace_bytes: x.len() * 8 * self.specs.len(),
        })
    }

    /// Gather the classifier back (checkpointing): shard slices
    /// concatenate in column order into the full `V×D` table, bit-exact.
    pub fn fetch(&self) -> Result<Vec<f32>> {
        let d = self.d;
        let replies = self.collective("fetch", |_| req_fetch(), false)?;
        let mut full = Vec::with_capacity(self.v * d);
        for (spec, reply) in self.specs.iter().zip(&replies) {
            full.extend(floats_field(reply, "c", spec.width() * d)?);
        }
        Ok(full)
    }

    /// Drop cached step state on every worker (a step whose backward was
    /// abandoned).
    pub fn abort(&self) -> Result<()> {
        self.collective("abort", |_| req_abort(), false)?;
        Ok(())
    }

    /// Clean shutdown: every worker replies, spawned children are reaped
    /// (killed if they linger).  Also runs on drop.
    pub fn shutdown(&self) {
        let mut inner = self.lock();
        shutdown_inner(&mut inner);
        super::record_workers(0);
    }
}

fn abort_links(links: &mut [Box<dyn Transport>]) {
    for link in links {
        if link.send(&req_abort()).is_ok() {
            let _ = link.recv();
        }
    }
}

fn shutdown_inner(inner: &mut FleetInner) {
    for link in inner.links.iter_mut() {
        if link.send(&req_shutdown()).is_ok() {
            let _ = link.recv();
        }
    }
    inner.links.clear();
    for child in inner.children.iter_mut() {
        let mut done = false;
        for _ in 0..100 {
            if matches!(child.try_wait(), Ok(Some(_))) {
                done = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        if !done {
            let _ = child.kill();
            let _ = child.wait();
        }
    }
    inner.children.clear();
    inner.keepalive.clear();
}

impl Drop for Fleet {
    fn drop(&mut self) {
        let mut inner = self.lock();
        if !inner.links.is_empty() || !inner.children.is_empty() {
            shutdown_inner(&mut inner);
        }
    }
}

fn stat_u64(reply: &Json, key: &str) -> Result<u64> {
    let i = reply.req(key)?.as_i64().ok_or_else(|| anyhow!("{key} must be an integer"))?;
    Ok(i.max(0) as u64)
}

struct TopKPart {
    t: Vec<i32>,
    z: Vec<f32>,
    lse: f32,
}

fn parse_topk_parts(replies: &[Json], rows: usize, k: usize) -> Result<Vec<Vec<TopKPart>>> {
    replies
        .iter()
        .map(|reply| {
            let arr = reply
                .req("rows")?
                .as_array()
                .ok_or_else(|| anyhow!("topk reply rows must be an array"))?;
            if arr.len() != rows {
                bail!("topk reply has {} rows, want {rows}", arr.len());
            }
            arr.iter()
                .map(|row| {
                    let t_arr = row
                        .req("t")?
                        .as_array()
                        .ok_or_else(|| anyhow!("topk row t must be an array"))?;
                    let got = t_arr.len();
                    if got > k {
                        bail!("topk row returned {got} candidates, want <= {k}");
                    }
                    let t = ints_field(row, "t", got)?;
                    let z = floats_field(row, "z", got)?;
                    let lse = row
                        .req("lse")?
                        .as_f64()
                        .ok_or_else(|| anyhow!("topk row lse must be a number"))?
                        as f32;
                    Ok(TopKPart { t, z, lse })
                })
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{cce_forward, Problem};
    use crate::util::rng::Rng;

    #[test]
    fn merge_lse_single_shard_is_bitwise_identity() {
        let part = vec![vec![-3.25f32, 0.0, 17.5, 1.0e-20, 88.6]];
        let merged = merge_lse(&part, 5);
        for (a, b) in part[0].iter().zip(&merged) {
            assert_eq!(a.to_bits(), b.to_bits(), "{a} changed under 1-shard merge");
        }
    }

    #[test]
    fn merge_lse_matches_direct_logsumexp() {
        // Two shards of known exps: lse of the union must come back.
        // exp parts: ln(2) and ln(6) → merged = ln(8).
        let parts = vec![vec![2.0f64.ln() as f32], vec![6.0f64.ln() as f32]];
        let merged = merge_lse(&parts, 1);
        assert!((merged[0] as f64 - 8.0f64.ln()).abs() < 1e-6);
    }

    #[test]
    fn local_fleet_forward_matches_single_process() {
        let (n, d, v) = (6, 8, 50);
        let mut rng = Rng::new(7);
        let e: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32 * 0.4).collect();
        let c: Vec<f32> = (0..v * d).map(|_| rng.normal() as f32 * 0.4).collect();
        let x: Vec<i32> = vec![3, 49, -1, 0, 17, 25];
        let opts = KernelOptions { threads: 1, ..KernelOptions::default() };

        let p = Problem::new(&e, &c, &x, n, d, v).unwrap();
        let single = cce_forward(&p, &opts);

        for shards in [1usize, 2, 3] {
            let fleet = Fleet::local(shards, v, d).unwrap();
            fleet.load(&ParamBuf::from_f32_vec(c.clone(), crate::exec::StoreDtype::F32), &opts)
                .unwrap();
            let step = fleet.step(&e, &x).unwrap();
            assert_eq!(step.count, single.count);
            assert!(
                (step.loss - single.loss).abs() < 1e-5,
                "{shards} shards: loss {} vs {}",
                step.loss,
                single.loss
            );
            for i in 0..n {
                assert!(
                    (step.lse[i] - single.lse[i]).abs() < 1e-5,
                    "{shards} shards row {i}: lse {} vs {}",
                    step.lse[i],
                    single.lse[i]
                );
                if x[i] >= 0 {
                    assert_eq!(
                        step.target_logit[i].to_bits(),
                        single.target_logit[i].to_bits(),
                        "target logits come off the owner shard bit-exactly"
                    );
                }
            }
            fleet.shutdown();
        }
    }
}
