//! The shard wire protocol: one line-JSON request, one line-JSON reply,
//! strictly in order, over whatever [`super::Transport`] carries them.
//!
//! Every message is documented field-by-field in `docs/sharding.md`
//! (versioned; `tools/check_docs.sh` pins the op names below and the
//! protocol version to that document).  Design rules:
//!
//! * **Floats cross the wire exactly.**  `util::json` renders `f64` with
//!   Rust's shortest-roundtrip formatting, and every f32 is exact as f64,
//!   so an f32 survives f32 → f64 → text → f64 → f32 bit-for-bit.  That
//!   is what lets the coordinator's merges reproduce single-process
//!   results: hidden states, classifier slices, logits, and LSEs are the
//!   *same bits* on both sides of the socket.
//! * **Seeds are bit-cast.**  JSON integers are `i64`; `u64` sampling
//!   seeds ride as their two's-complement `i64` rendering
//!   ([`seed_to_wire`] / [`seed_from_wire`]).
//! * **Errors are replies, not disconnects.**  A worker that cannot honor
//!   a request answers `{"ok":false,"error":...}` and keeps serving; only
//!   crashes and kills sever the connection (which the coordinator's
//!   transport turns into a structured error — see `docs/sharding.md`
//!   failure semantics).

use anyhow::{anyhow, bail, Result};

use crate::exec::{KernelOptions, StoreDtype};
use crate::util::json::Json;

use super::ShardSpec;

/// Protocol version spoken by this build.  Bumped on any incompatible
/// message change; `hello` fails closed on mismatch.
pub const SHARD_PROTO_VERSION: i64 = 1;

/// Every operation in the protocol, coordinator → worker.  Pinned to
/// `docs/sharding.md` by `tools/check_docs.sh`.
pub const SHARD_OPS: &[&str] = &[
    "hello", "load", "step", "merge", "topk", "sample", "fetch", "abort", "shutdown",
];

// ------------------------------------------------------------ wire helpers

pub(crate) fn floats_json(v: &[f32]) -> Json {
    Json::arr(v.iter().map(|&x| Json::Float(x as f64)))
}

pub(crate) fn ints_json(v: &[i32]) -> Json {
    Json::arr(v.iter().map(|&x| Json::Int(x as i64)))
}

pub(crate) fn floats_field(j: &Json, key: &str, want: usize) -> Result<Vec<f32>> {
    let arr = j.req(key)?.as_array().ok_or_else(|| anyhow!("{key} must be an array"))?;
    if arr.len() != want {
        bail!("{key} has {} elements, want {want}", arr.len());
    }
    arr.iter()
        .map(|v| v.as_f64().map(|f| f as f32).ok_or_else(|| anyhow!("{key} must hold numbers")))
        .collect()
}

pub(crate) fn ints_field(j: &Json, key: &str, want: usize) -> Result<Vec<i32>> {
    let arr = j.req(key)?.as_array().ok_or_else(|| anyhow!("{key} must be an array"))?;
    if arr.len() != want {
        bail!("{key} has {} elements, want {want}", arr.len());
    }
    arr.iter()
        .map(|v| v.as_i64().map(|i| i as i32).ok_or_else(|| anyhow!("{key} must hold integers")))
        .collect()
}

pub(crate) fn usize_field(j: &Json, key: &str) -> Result<usize> {
    let i = j.req(key)?.as_i64().ok_or_else(|| anyhow!("{key} must be an integer"))?;
    if i < 0 {
        bail!("{key} must be >= 0, got {i}");
    }
    Ok(i as usize)
}

/// `u64` seed → wire `i64` (bit-cast; documented in docs/sharding.md).
pub fn seed_to_wire(seed: u64) -> i64 {
    seed as i64
}

/// Wire `i64` → `u64` seed (bit-cast).
pub fn seed_from_wire(wire: i64) -> u64 {
    wire as u64
}

// --------------------------------------------------------------- requests

pub fn req_hello() -> Json {
    Json::obj(vec![("op", Json::str("hello")), ("proto", Json::Int(SHARD_PROTO_VERSION))])
}

/// Ship one shard's classifier slice (widened to f32 — exact for both
/// storage dtypes) plus the kernel configuration.
pub fn req_load(
    spec: &ShardSpec,
    v: usize,
    d: usize,
    dtype: StoreDtype,
    opts: &KernelOptions,
    c_rows: &[f32],
) -> Json {
    Json::obj(vec![
        ("op", Json::str("load")),
        ("proto", Json::Int(SHARD_PROTO_VERSION)),
        ("index", Json::Int(spec.index as i64)),
        ("count", Json::Int(spec.count as i64)),
        ("j0", Json::Int(spec.j0 as i64)),
        ("j1", Json::Int(spec.j1 as i64)),
        ("v", Json::Int(v as i64)),
        ("d", Json::Int(d as i64)),
        ("dtype", Json::str(dtype.name())),
        (
            "opts",
            Json::obj(vec![
                ("n_block", Json::Int(opts.n_block as i64)),
                ("v_block", Json::Int(opts.v_block as i64)),
                ("threads", Json::Int(opts.threads as i64)),
                ("filter", Json::Bool(opts.filter)),
                ("sort", Json::Bool(opts.sort)),
                ("kahan", Json::Bool(opts.kahan)),
                ("full_c", Json::Bool(opts.full_c)),
                ("full_e", Json::Bool(opts.full_e)),
            ]),
        ),
        ("c", floats_json(c_rows)),
    ])
}

/// Forward collective: hidden states + **global** labels (the worker maps
/// them to its local range; `-1` stays ignored everywhere).
pub fn req_step(e: &[f32], x: &[i32]) -> Json {
    Json::obj(vec![
        ("op", Json::str("step")),
        ("n", Json::Int(x.len() as i64)),
        ("e", floats_json(e)),
        ("x", ints_json(x)),
    ])
}

/// Backward collective: broadcast the merged global LSE, the global
/// active-token count, and (when training) the SGD learning rate the
/// worker applies to its own classifier slice.
pub fn req_merge(lse: &[f32], lr: Option<f32>, count: usize) -> Json {
    Json::obj(vec![
        ("op", Json::str("merge")),
        ("lse", floats_json(lse)),
        ("lr", lr.map(|v| Json::Float(v as f64)).unwrap_or(Json::Null)),
        ("count", Json::Int(count as i64)),
    ])
}

pub fn req_topk(e: &[f32], rows: usize, k: usize) -> Json {
    Json::obj(vec![
        ("op", Json::str("topk")),
        ("rows", Json::Int(rows as i64)),
        ("k", Json::Int(k as i64)),
        ("e", floats_json(e)),
    ])
}

pub fn req_sample(e: &[f32], rows: usize, temperature: f32, seeds: &[u64]) -> Json {
    Json::obj(vec![
        ("op", Json::str("sample")),
        ("rows", Json::Int(rows as i64)),
        ("temperature", Json::Float(temperature as f64)),
        ("seeds", Json::arr(seeds.iter().map(|&s| Json::Int(seed_to_wire(s))))),
        ("e", floats_json(e)),
    ])
}

pub fn req_fetch() -> Json {
    Json::obj(vec![("op", Json::str("fetch"))])
}

pub fn req_abort() -> Json {
    Json::obj(vec![("op", Json::str("abort"))])
}

pub fn req_shutdown() -> Json {
    Json::obj(vec![("op", Json::str("shutdown"))])
}

// ---------------------------------------------------------------- replies

/// Successful reply skeleton.
pub fn resp_ok(mut fields: Vec<(&str, Json)>) -> Json {
    let mut all = vec![("ok", Json::Bool(true))];
    all.append(&mut fields);
    Json::obj(all)
}

/// Error reply: the worker stays up, the coordinator surfaces the text.
pub fn resp_err(msg: &str) -> Json {
    Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::str(msg))])
}

/// Check a reply's `ok` field, surfacing the worker's error text.
pub fn check_ok(resp: &Json) -> Result<()> {
    match resp.get("ok").and_then(|v| v.as_bool()) {
        Some(true) => Ok(()),
        Some(false) => {
            let msg = resp.get("error").and_then(|v| v.as_str()).unwrap_or("unspecified error");
            bail!("worker error: {msg}")
        }
        None => bail!("malformed worker reply (no ok field): {}", resp.to_string()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn floats_survive_the_wire_bit_exactly() {
        // Shortest-roundtrip f64 rendering makes f32 → text → f32 an
        // identity — the property the whole shard layer leans on.
        let vals: Vec<f32> = vec![
            0.0,
            -0.0,
            1.0,
            std::f32::consts::PI,
            1.1754944e-38,
            3.4028235e38,
            -2.7182817,
            1e-45,
        ];
        let line = floats_json(&vals).to_string();
        let back = floats_field(&Json::obj(vec![("v", Json::parse(&line).unwrap())]), "v", vals.len())
            .unwrap();
        for (a, b) in vals.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits(), "{a} changed on the wire");
        }
    }

    #[test]
    fn seeds_bitcast_roundtrip() {
        for s in [0u64, 1, u64::MAX, 0x9E3779B97F4A7C15, i64::MAX as u64 + 7] {
            assert_eq!(seed_from_wire(seed_to_wire(s)), s);
        }
    }

    #[test]
    fn ops_cover_the_request_builders() {
        let reqs = vec![
            req_hello(),
            req_step(&[0.0], &[0]),
            req_merge(&[0.0], Some(0.1), 1),
            req_topk(&[0.0], 1, 1),
            req_sample(&[0.0], 1, 1.0, &[1]),
            req_fetch(),
            req_abort(),
            req_shutdown(),
        ];
        for req in &reqs {
            let op = req.get("op").and_then(|v| v.as_str()).unwrap();
            assert!(SHARD_OPS.contains(&op), "op {op} missing from SHARD_OPS");
        }
        // load needs a spec; cover it separately.
        let spec = ShardSpec { index: 0, count: 1, j0: 0, j1: 2 };
        let load = req_load(&spec, 2, 1, StoreDtype::F32, &KernelOptions::default(), &[0.0, 1.0]);
        assert_eq!(load.get("op").and_then(|v| v.as_str()), Some("load"));
        assert_eq!(SHARD_OPS.len(), 9);
    }

    #[test]
    fn check_ok_surfaces_worker_errors() {
        assert!(check_ok(&resp_ok(vec![])).is_ok());
        let err = check_ok(&resp_err("no shard loaded")).unwrap_err();
        assert!(err.to_string().contains("no shard loaded"), "{err}");
        assert!(check_ok(&Json::obj(vec![("x", Json::Int(1))])).is_err());
    }
}
