//! Vocabulary-sharded CCE: multi-process tensor parallelism along `V`.
//!
//! The paper's online log-sum-exp is associative: partial `(m, s)` pairs
//! computed over disjoint vocabulary column ranges merge exactly, so the
//! classifier `C (V×D)` can be split into contiguous column shards owned
//! by worker processes while the coordinator keeps the embedding table,
//! the data pipeline, and the event loop.  One step exchanges only the
//! tiny per-row scalar state — never logits, never `N×V` anything:
//!
//! ```text
//! coordinator                         worker k (owns C[j0_k .. j1_k))
//!   hidden states E (N×D), labels ──► step:   local forward sweep
//!   merge per-row partial LSEs    ◄── per-row lse_k, target logit
//!   global LSE + lr + count      ──►  merge:  shard-local backward
//!   Σ partial dE, update E        ◄── partial dE, |dC|² (dC applied
//!                                     in place by the worker's SGD)
//! ```
//!
//! The merge is the log-sum-exp of the partial log-sum-exps: with
//! `lse_k = m_k + ln s_k` finished per shard, the global value is
//! `lse = m + ln Σ_k exp(lse_k − m)`, `m = max_k lse_k` — exact in real
//! arithmetic because `exp` of a disjoint union sums, and computed here
//! in f64 in ascending shard order so the result is independent of reply
//! arrival order (see [`merge_lse`]).  The §4.3 gradient filter runs on
//! each worker against the broadcast *global* LSE, so its sub-`eps`
//! skip bound (every dropped softmax entry is a true global probability
//! `< 2^-12`) is the same bound as the single-process kernel.
//!
//! Inference merges shard-local candidates at the coordinator: top-k
//! heaps carry **raw logits** and globally-offset token ids (the kernel's
//! exact comparison keys — see [`crate::exec::infer`]'s shard entries),
//! and Gumbel-max winners carry perturbed scores keyed on global column
//! indices, so merged greedy/top-k/sampled tokens are bitwise identical
//! to the single-process kernels for any shard count.
//!
//! Layout:
//!
//! * [`protocol`]  — the versioned line-JSON wire messages
//!   ([`SHARD_OPS`]), documented field-by-field in `docs/sharding.md`.
//! * [`transport`] — the [`Transport`] trait with an in-process
//!   [`LocalTransport`] (unit tests) and a [`TcpTransport`] (real process
//!   boundaries; multi-node is a config change).
//! * [`worker`]    — the stateless kernel server behind `cce
//!   shard-worker`: holds one classifier slice, answers collectives.
//! * [`fleet`]     — the coordinator side: spawns/connects workers, runs
//!   collectives, owns the merge math and the failure semantics (a dead
//!   worker is a structured error, never a hang — transports carry read
//!   timeouts and EOF detection).
//!
//! Memory invariant: the coordinator never materializes per-shard logits
//! or gradients of the classifier; its transient state per collective is
//! `O(N)` scalars per shard plus one `N×D` partial-`dE` accumulator.
//! Workers hold their `(V/S)×D` classifier slice plus the standard
//! blocked kernel workspace.

pub mod fleet;
pub mod protocol;
pub mod transport;
pub mod worker;

pub use fleet::{merge_lse, Fleet, ShardMerge, ShardStep};
pub use protocol::{SHARD_OPS, SHARD_PROTO_VERSION};
pub use transport::{LocalTransport, TcpTransport, Transport};
pub use worker::{run_worker, ShardWorker};

use std::sync::{Arc, OnceLock};

use anyhow::{bail, Result};

use crate::obs;

/// One shard's slice of the global vocabulary: contiguous columns
/// `[j0, j1)` of `C`, shard `index` of `count`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSpec {
    pub index: usize,
    pub count: usize,
    pub j0: usize,
    pub j1: usize,
}

impl ShardSpec {
    /// Columns this shard owns.
    pub fn width(&self) -> usize {
        self.j1 - self.j0
    }

    /// Does this shard own global token `t`?
    pub fn owns(&self, t: i32) -> bool {
        t >= 0 && (t as usize) >= self.j0 && (t as usize) < self.j1
    }
}

/// Split `v` vocabulary columns into `count` contiguous shards, widths
/// differing by at most one (the remainder goes to the leading shards).
pub fn split_vocab(v: usize, count: usize) -> Result<Vec<ShardSpec>> {
    if count == 0 {
        bail!("shard count must be >= 1");
    }
    if count > v {
        bail!("cannot split vocab {v} into {count} shards (more shards than columns)");
    }
    let base = v / count;
    let rem = v % count;
    Ok((0..count)
        .map(|k| {
            let j0 = k * base + k.min(rem);
            let j1 = j0 + base + usize::from(k < rem);
            ShardSpec { index: k, count, j0, j1 }
        })
        .collect())
}

// ---------------------------------------------------------------- telemetry

/// Handles into the process-global registry for the `shard_*` families
/// (pre-registered by [`obs::global`], same pattern as the exec kernels).
struct ShardObs {
    workers: Arc<obs::Gauge>,
    exchange_bytes: Arc<obs::Histogram>,
    exchange_us: Arc<obs::Histogram>,
    step_us: Arc<obs::Histogram>,
    merges_total: Arc<obs::Counter>,
    worker_errors: Arc<obs::Counter>,
}

fn shard_obs() -> &'static ShardObs {
    static OBS: OnceLock<ShardObs> = OnceLock::new();
    OBS.get_or_init(|| {
        let r = obs::global();
        ShardObs {
            workers: r.gauge("shard_workers", ""),
            exchange_bytes: r.histogram("shard_exchange_bytes", ""),
            exchange_us: r.histogram("shard_exchange_us", ""),
            step_us: r.histogram("shard_step_us", ""),
            merges_total: r.counter("shard_merges_total", ""),
            worker_errors: r.counter("shard_worker_errors_total", ""),
        }
    })
}

pub(crate) fn record_workers(n: usize) {
    if !obs::enabled() {
        return;
    }
    shard_obs().workers.set(n as i64);
}

pub(crate) fn record_exchange(bytes: usize, us: Option<u64>, is_step: bool) {
    if !obs::enabled() {
        return;
    }
    let o = shard_obs();
    o.exchange_bytes.record(bytes as u64);
    if let Some(us) = us {
        o.exchange_us.record(us);
        if is_step {
            o.step_us.record(us);
        }
    }
    o.merges_total.inc();
}

pub(crate) fn record_worker_error() {
    if !obs::enabled() {
        return;
    }
    shard_obs().worker_errors.inc();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_vocab_covers_contiguously() {
        for (v, count) in [(8, 1), (8, 2), (97, 4), (5, 5), (513, 3)] {
            let specs = split_vocab(v, count).unwrap();
            assert_eq!(specs.len(), count);
            assert_eq!(specs[0].j0, 0);
            assert_eq!(specs[count - 1].j1, v);
            for w in specs.windows(2) {
                assert_eq!(w[0].j1, w[1].j0, "shards must tile contiguously");
            }
            let widths: Vec<usize> = specs.iter().map(|s| s.width()).collect();
            let (lo, hi) = (widths.iter().min().unwrap(), widths.iter().max().unwrap());
            assert!(hi - lo <= 1, "widths must differ by at most one: {widths:?}");
            assert!(widths.iter().all(|&w| w > 0));
        }
        assert!(split_vocab(4, 0).is_err());
        assert!(split_vocab(4, 5).is_err());
    }

    #[test]
    fn shard_spec_ownership() {
        let specs = split_vocab(10, 3).unwrap();
        // 10 into 3: widths 4, 3, 3.
        assert_eq!(specs[0], ShardSpec { index: 0, count: 3, j0: 0, j1: 4 });
        for t in 0..10i32 {
            let owners = specs.iter().filter(|s| s.owns(t)).count();
            assert_eq!(owners, 1, "token {t} must have exactly one owner");
        }
        assert!(!specs[0].owns(-1), "ignored labels have no owner");
    }
}
