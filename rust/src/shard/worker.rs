//! The shard worker: one classifier slice, one request at a time.
//!
//! A worker is a *stateless kernel server* — it holds no checkpoint, no
//! tokenizer, no data pipeline.  The coordinator ships it a contiguous
//! slice of classifier columns (`load`), then drives collectives against
//! it: `step` (shard-local forward), `merge` (shard-local backward
//! against the broadcast global LSE, plus the in-place SGD update of its
//! own columns), `topk` / `sample` (shard-local inference candidates),
//! `fetch` (return the columns for checkpointing), `abort` (drop cached
//! step state), `shutdown`.
//!
//! [`ShardWorker::handle`] is the whole behavior; [`run_worker`] wraps it
//! in the TCP accept loop behind `cce shard-worker`, and
//! [`super::LocalTransport`] calls it in-process.  Both paths serialize
//! through the same line-JSON text, so unit tests exercise the exact
//! wire encoding the sockets carry.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpListener;

use anyhow::{anyhow, bail, Context, Result};

use crate::exec::{
    cce_backward_sharded, cce_forward, sample_shard, simd, topk_shard, FilterStats, InferProblem,
    KernelOptions, ParamBuf, Problem, Store, StoreDtype,
};
use crate::util::faults;
use crate::util::json::Json;

use super::protocol::{
    self, floats_field, floats_json, ints_field, ints_json, resp_ok, seed_from_wire, usize_field,
    SHARD_OPS, SHARD_PROTO_VERSION,
};
use super::ShardSpec;

/// Cached inputs of the last `step`, consumed by the following `merge`.
struct StepState {
    e: Vec<f32>,
    x_local: Vec<i32>,
    x_global: Vec<i32>,
}

/// State installed by `load`.
struct Loaded {
    spec: ShardSpec,
    v: usize,
    d: usize,
    opts: KernelOptions,
    cls: ParamBuf,
    step: Option<StepState>,
}

/// One shard worker.  Drive it with [`ShardWorker::handle`]; protocol
/// errors become `{"ok":false,...}` replies, never panics or hangs.
pub struct ShardWorker {
    /// `--threads` override from the worker's own CLI: a multi-node
    /// deployment sizes each worker for its own machine rather than
    /// inheriting the coordinator's thread count.
    threads_override: Option<usize>,
    state: Option<Loaded>,
}

impl ShardWorker {
    pub fn new(threads_override: Option<usize>) -> ShardWorker {
        ShardWorker { threads_override, state: None }
    }

    /// Answer one request.  Infallible at the connection level: every
    /// failure is an error *reply*.
    pub fn handle(&mut self, req: &Json) -> Json {
        match self.dispatch(req) {
            Ok(resp) => resp,
            Err(e) => protocol::resp_err(&format!("{e}")),
        }
    }

    fn dispatch(&mut self, req: &Json) -> Result<Json> {
        let op = req
            .get("op")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow!("request has no op field"))?;
        match op {
            "hello" => {
                let proto = req.get("proto").and_then(|v| v.as_i64()).unwrap_or(0);
                if proto != SHARD_PROTO_VERSION {
                    bail!(
                        "shard protocol mismatch: coordinator speaks v{proto}, worker speaks v{SHARD_PROTO_VERSION}"
                    );
                }
                Ok(resp_ok(vec![("proto", Json::Int(SHARD_PROTO_VERSION))]))
            }
            "load" => self.op_load(req),
            "step" => self.op_step(req),
            "merge" => self.op_merge(req),
            "topk" => self.op_topk(req),
            "sample" => self.op_sample(req),
            "fetch" => self.op_fetch(),
            "abort" => {
                if let Some(l) = &mut self.state {
                    l.step = None;
                }
                Ok(resp_ok(vec![]))
            }
            "shutdown" => Ok(resp_ok(vec![])),
            other => bail!("unknown op {other:?} (known ops: {})", SHARD_OPS.join(", ")),
        }
    }

    fn loaded(&mut self) -> Result<&mut Loaded> {
        self.state.as_mut().ok_or_else(|| anyhow!("no shard loaded (send load first)"))
    }

    fn op_load(&mut self, req: &Json) -> Result<Json> {
        let spec = ShardSpec {
            index: usize_field(req, "index")?,
            count: usize_field(req, "count")?,
            j0: usize_field(req, "j0")?,
            j1: usize_field(req, "j1")?,
        };
        let v = usize_field(req, "v")?;
        let d = usize_field(req, "d")?;
        if spec.index >= spec.count || spec.j0 >= spec.j1 || spec.j1 > v {
            bail!(
                "bad shard spec: index {} of {}, columns [{}, {}) of vocab {v}",
                spec.index,
                spec.count,
                spec.j0,
                spec.j1
            );
        }
        let dtype =
            StoreDtype::parse(req.req("dtype")?.as_str().ok_or_else(|| anyhow!("dtype must be a string"))?)?;
        let o = req.req("opts")?;
        let mut opts = KernelOptions {
            n_block: usize_field(o, "n_block")?,
            v_block: usize_field(o, "v_block")?,
            threads: usize_field(o, "threads")?,
            filter: o.get("filter").and_then(|v| v.as_bool()).unwrap_or(true),
            sort: o.get("sort").and_then(|v| v.as_bool()).unwrap_or(true),
            kahan: o.get("kahan").and_then(|v| v.as_bool()).unwrap_or(false),
            full_c: o.get("full_c").and_then(|v| v.as_bool()).unwrap_or(false),
            full_e: o.get("full_e").and_then(|v| v.as_bool()).unwrap_or(false),
            dtype,
        };
        if let Some(t) = self.threads_override {
            opts.threads = t;
        }
        let c = floats_field(req, "c", spec.width() * d)?;
        let cls = ParamBuf::from_f32_vec(c, dtype);
        self.state = Some(Loaded { spec, v, d, opts, cls, step: None });
        Ok(resp_ok(vec![("rows", Json::Int((spec.j1 - spec.j0) as i64))]))
    }

    fn op_step(&mut self, req: &Json) -> Result<Json> {
        let l = self.loaded()?;
        let n = usize_field(req, "n")?;
        if n == 0 {
            bail!("step with n=0");
        }
        let e = floats_field(req, "e", n * l.d)?;
        let x_global = ints_field(req, "x", n)?;
        if let Some(&bad) = x_global.iter().find(|&&t| t < -1 || t >= l.v as i32) {
            bail!("global label {bad} out of range for vocab {}", l.v);
        }
        // Remap to the local column range: remote labels become ignored
        // locally (their softmax mass still accumulates — the backward
        // consults the *global* labels for row activity).
        let x_local: Vec<i32> = x_global
            .iter()
            .map(|&t| if l.spec.owns(t) { t - l.spec.j0 as i32 } else { -1 })
            .collect();
        let (lse, tgt) = match &l.cls {
            ParamBuf::F32(c) => forward_t::<f32>(c, &e, &x_local, l.d, l.spec.width(), &l.opts)?,
            ParamBuf::Bf16(c) => forward_t::<crate::exec::BF16>(c, &e, &x_local, l.d, l.spec.width(), &l.opts)?,
        };
        l.step = Some(StepState { e, x_local, x_global });
        Ok(resp_ok(vec![("lse", floats_json(&lse)), ("tgt", floats_json(&tgt))]))
    }

    fn op_merge(&mut self, req: &Json) -> Result<Json> {
        let l = self.loaded()?;
        let st = l
            .step
            .take()
            .ok_or_else(|| anyhow!("merge without a preceding step (no cached state)"))?;
        let n = st.x_local.len();
        let lse = floats_field(req, "lse", n)?;
        let count = usize_field(req, "count")?;
        let lr = match req.get("lr") {
            None | Some(Json::Null) => None,
            Some(v) => Some(v.as_f64().ok_or_else(|| anyhow!("lr must be a number"))? as f32),
        };
        let (d, width, opts) = (l.d, l.spec.width(), l.opts);
        let (de, dc_sqnorm, stats) = match &mut l.cls {
            ParamBuf::F32(c) => merge_t::<f32>(c, &st, &lse, lr, count, d, width, &opts)?,
            ParamBuf::Bf16(c) => merge_t::<crate::exec::BF16>(c, &st, &lse, lr, count, d, width, &opts)?,
        };
        Ok(resp_ok(vec![
            ("de", floats_json(&de)),
            ("dc_sqnorm", Json::Float(dc_sqnorm)),
            ("blocks_total", Json::Int(stats.blocks_total as i64)),
            ("blocks_skipped", Json::Int(stats.blocks_skipped as i64)),
            ("sig_entries", Json::Int(stats.sig_entries as i64)),
        ]))
    }

    fn op_topk(&mut self, req: &Json) -> Result<Json> {
        let l = self.loaded()?;
        let rows = usize_field(req, "rows")?;
        let k = usize_field(req, "k")?;
        if k == 0 {
            bail!("topk with k=0");
        }
        let e = floats_field(req, "e", rows * l.d)?;
        // A narrow shard answers with every column it has; the merge
        // still sees >= k candidates over the union whenever k <= V.
        let k_local = k.min(l.spec.width());
        let out = match &l.cls {
            ParamBuf::F32(c) => {
                let p = InferProblem::new(&e, c, rows, l.d, l.spec.width())?;
                topk_shard(&p, &l.opts, k_local, l.spec.j0)?
            }
            ParamBuf::Bf16(c) => {
                let p = InferProblem::new(&e, c, rows, l.d, l.spec.width())?;
                topk_shard(&p, &l.opts, k_local, l.spec.j0)?
            }
        };
        let rows_json = Json::arr(out.rows.iter().map(|r| {
            Json::obj(vec![
                ("t", ints_json(&r.tokens)),
                ("z", floats_json(&r.logits)),
                ("lse", Json::Float(r.lse as f64)),
            ])
        }));
        Ok(resp_ok(vec![("rows", rows_json)]))
    }

    fn op_sample(&mut self, req: &Json) -> Result<Json> {
        let l = self.loaded()?;
        let rows = usize_field(req, "rows")?;
        let temperature = req
            .req("temperature")?
            .as_f64()
            .ok_or_else(|| anyhow!("temperature must be a number"))? as f32;
        let e = floats_field(req, "e", rows * l.d)?;
        let seeds_arr =
            req.req("seeds")?.as_array().ok_or_else(|| anyhow!("seeds must be an array"))?;
        if seeds_arr.len() != rows {
            bail!("seeds has {} elements, want {rows}", seeds_arr.len());
        }
        let seeds: Vec<u64> = seeds_arr
            .iter()
            .map(|v| {
                v.as_i64().map(seed_from_wire).ok_or_else(|| anyhow!("seeds must hold integers"))
            })
            .collect::<Result<_>>()?;
        let out = match &l.cls {
            ParamBuf::F32(c) => {
                let p = InferProblem::new(&e, c, rows, l.d, l.spec.width())?;
                sample_shard(&p, &l.opts, temperature, &seeds, l.spec.j0)?
            }
            ParamBuf::Bf16(c) => {
                let p = InferProblem::new(&e, c, rows, l.d, l.spec.width())?;
                sample_shard(&p, &l.opts, temperature, &seeds, l.spec.j0)?
            }
        };
        Ok(resp_ok(vec![
            ("tokens", ints_json(&out.tokens)),
            ("scores", floats_json(&out.scores)),
            ("logits", floats_json(&out.logits)),
            ("lse", floats_json(&out.lse)),
        ]))
    }

    fn op_fetch(&mut self) -> Result<Json> {
        let l = self.loaded()?;
        Ok(resp_ok(vec![("c", floats_json(&l.cls.to_f32_vec()))]))
    }
}

fn forward_t<S: Store>(
    cls: &[S],
    e: &[f32],
    x_local: &[i32],
    d: usize,
    width: usize,
    opts: &KernelOptions,
) -> Result<(Vec<f32>, Vec<f32>)> {
    let n = x_local.len();
    let e_s = S::narrow_cow(e);
    let p = Problem::new(e_s.as_ref(), cls, x_local, n, d, width)?;
    let fwd = cce_forward(&p, opts);
    Ok((fwd.lse, fwd.target_logit))
}

#[allow(clippy::too_many_arguments)]
fn merge_t<S: Store>(
    cls: &mut [S],
    st: &StepState,
    lse: &[f32],
    lr: Option<f32>,
    count: usize,
    d: usize,
    width: usize,
    opts: &KernelOptions,
) -> Result<(Vec<f32>, f64, FilterStats)> {
    let n = st.x_local.len();
    let e_s = S::narrow_cow(&st.e);
    let p = Problem::new(e_s.as_ref(), cls, &st.x_local, n, d, width)?;
    let bwd = cce_backward_sharded(&p, opts, lse, &st.x_global, count);
    let de = S::widen_vec(&bwd.d_e);
    let dc_sqnorm: f64 = bwd
        .d_c
        .iter()
        .map(|&g| {
            let g = g.to_f32() as f64;
            g * g
        })
        .sum();
    if let Some(lr) = lr {
        // The SGD axpy is element-wise, so updating the slice here is
        // bit-identical to the single-process trainer updating the same
        // rows of the full table.
        simd::with_lanes!(lanes => S::lanes_axpy_store_s(lanes, cls, -lr, &bwd.d_c));
    }
    Ok((de, dc_sqnorm, bwd.stats))
}

/// The TCP accept loop behind `cce shard-worker`: announce the bound
/// address, then answer one line-JSON request per line until `shutdown`.
/// A dropped connection returns the worker to `accept` (the classifier
/// slice survives, so a coordinator may reconnect); `shutdown` replies,
/// prints the clean-exit marker, and returns.
pub fn run_worker(host: &str, port: u16, threads_override: Option<usize>) -> Result<()> {
    let listener = TcpListener::bind((host, port))
        .with_context(|| format!("shard-worker failed to bind {host}:{port}"))?;
    let addr = listener.local_addr()?;
    // The `[serve] ready`-style announce contract: scripts parse the
    // resolved address from this exact line (docs/sharding.md).
    println!("[shard] ready proto=line addr={addr}");
    std::io::stdout().flush().ok();
    let mut worker = ShardWorker::new(threads_override);
    let mut requests_seen: u64 = 0;
    for stream in listener.incoming() {
        let stream = match stream {
            Ok(s) => s,
            Err(_) => continue,
        };
        stream.set_nodelay(true).ok();
        let mut reader = BufReader::new(stream.try_clone().context("clone worker stream")?);
        let mut out = stream;
        let mut line = String::new();
        loop {
            line.clear();
            let nread = reader.read_line(&mut line).unwrap_or(0);
            if nread == 0 {
                break; // coordinator went away; await a new connection
            }
            let trimmed = line.trim();
            if trimmed.is_empty() {
                continue;
            }
            // Chaos hook (`CCE_FAULTS=shard.worker_crash=K`): the K-th
            // request kills the process the way an OOM kill would —
            // mid-request, no reply, no shutdown handshake.  K=3 lets
            // hello + load succeed and dies on the step; the coordinator
            // must surface it as a structured error, never hang
            // (rust/tests/shard.rs).
            requests_seen += 1;
            if faults::value("shard.worker_crash").is_some_and(|k| requests_seen >= k as u64) {
                eprintln!("[shard] fault shard.worker_crash fired on request {requests_seen}; exiting");
                std::process::exit(3);
            }
            let req = match Json::parse(trimmed) {
                Ok(j) => j,
                Err(e) => {
                    let resp = protocol::resp_err(&format!("bad request line: {e}"));
                    if write_line(&mut out, &resp).is_err() {
                        break;
                    }
                    continue;
                }
            };
            let is_shutdown = req.get("op").and_then(|v| v.as_str()) == Some("shutdown");
            let resp = worker.handle(&req);
            if write_line(&mut out, &resp).is_err() {
                break;
            }
            if is_shutdown {
                println!("[shard] shut down cleanly");
                return Ok(());
            }
        }
    }
    Ok(())
}

fn write_line(out: &mut std::net::TcpStream, resp: &Json) -> std::io::Result<()> {
    let mut text = resp.to_string();
    text.push('\n');
    out.write_all(text.as_bytes())?;
    out.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::KernelOptions;
    use crate::shard::protocol::{req_fetch, req_hello, req_load, req_step};
    use crate::shard::split_vocab;
    use crate::util::rng::Rng;

    fn check(resp: &Json) {
        assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(true), "{}", resp.to_string());
    }

    #[test]
    fn worker_lifecycle_load_step_fetch() {
        let (v, d, n) = (12, 4, 3);
        let mut rng = Rng::new(41);
        let c: Vec<f32> = (0..v * d).map(|_| rng.normal() as f32 * 0.3).collect();
        let e: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32 * 0.3).collect();
        let x = vec![0i32, 7, -1];
        let spec = split_vocab(v, 2).unwrap()[1];
        let mut w = ShardWorker::new(None);

        // Ops before load fail as replies, not panics.
        let early = w.handle(&req_step(&e, &x));
        assert_eq!(early.get("ok").and_then(|j| j.as_bool()), Some(false));

        check(&w.handle(&req_hello()));
        let opts = KernelOptions { threads: 1, ..KernelOptions::default() };
        let slice = &c[spec.j0 * d..spec.j1 * d];
        check(&w.handle(&req_load(&spec, v, d, StoreDtype::F32, &opts, slice)));
        let step = w.handle(&req_step(&e, &x));
        check(&step);
        assert_eq!(step.get("lse").and_then(|j| j.as_array()).unwrap().len(), n);
        // Row 1's label (7) is owned by shard [6, 12): its target logit is
        // nonzero here; row 0's label (0) is remote: zero.
        let tgt: Vec<f64> =
            step.get("tgt").unwrap().as_array().unwrap().iter().map(|j| j.as_f64().unwrap()).collect();
        assert_eq!(tgt[0], 0.0);
        assert_ne!(tgt[1], 0.0);
        // fetch returns the slice bit-exactly.
        let fetched = w.handle(&req_fetch());
        check(&fetched);
        let got: Vec<f32> = fetched
            .get("c")
            .unwrap()
            .as_array()
            .unwrap()
            .iter()
            .map(|j| j.as_f64().unwrap() as f32)
            .collect();
        assert_eq!(got, slice);
    }

    #[test]
    fn worker_rejects_protocol_mismatch_and_unknown_ops() {
        let mut w = ShardWorker::new(None);
        let bad = Json::obj(vec![("op", Json::str("hello")), ("proto", Json::Int(99))]);
        let resp = w.handle(&bad);
        assert_eq!(resp.get("ok").and_then(|j| j.as_bool()), Some(false));
        assert!(resp.get("error").unwrap().as_str().unwrap().contains("protocol mismatch"));
        let resp = w.handle(&Json::obj(vec![("op", Json::str("evaluate"))]));
        assert!(resp.get("error").unwrap().as_str().unwrap().contains("unknown op"));
    }
}
