//! Transports carry the shard protocol's request/reply lines.
//!
//! The [`Transport`] trait is the seam that makes multi-node a config
//! change: the fleet's collectives are written against `send`/`recv`
//! pairs and never mention sockets.  Two implementations ship:
//!
//! * [`LocalTransport`] — an in-process worker behind the same line-JSON
//!   text encoding the sockets carry (requests and replies really are
//!   serialized and re-parsed), for unit tests and single-machine debug.
//! * [`TcpTransport`]  — one TCP connection per worker with read/write
//!   timeouts, so a dead or wedged worker surfaces as a structured error
//!   within [`IO_TIMEOUT`], never a hang.
//!
//! Byte counts returned by `send`/`recv` feed the `shard_exchange_bytes`
//! histogram — the number the paper's "tiny scalar exchange" claim is
//! audited by (`docs/sharding.md`).

use std::collections::VecDeque;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::util::faults;
use crate::util::json::Json;

use super::worker::ShardWorker;

/// Read/write deadline on worker links: a worker that neither answers
/// nor disconnects inside this window is treated as dead.
pub const IO_TIMEOUT: Duration = Duration::from_secs(30);

/// One ordered request/reply channel to a shard worker.
pub trait Transport: Send {
    /// Ship one request line.  Returns the wire bytes written.
    fn send(&mut self, req: &Json) -> Result<usize>;

    /// Await the matching reply line.  Returns `(reply, wire bytes)`.
    fn recv(&mut self) -> Result<(Json, usize)>;

    /// Peer description for error messages (`local#2`, `127.0.0.1:4831`).
    fn describe(&self) -> String;
}

// ------------------------------------------------------------------- local

/// An in-process worker reached through the real text encoding: `send`
/// serializes the request to a line and parses it back before handing it
/// to the worker, so every byte of the wire format is exercised without
/// a socket.
pub struct LocalTransport {
    worker: ShardWorker,
    label: String,
    pending: VecDeque<String>,
    requests_seen: u64,
}

impl LocalTransport {
    pub fn new(index: usize) -> LocalTransport {
        LocalTransport {
            worker: ShardWorker::new(None),
            label: format!("local#{index}"),
            pending: VecDeque::new(),
            requests_seen: 0,
        }
    }
}

impl Transport for LocalTransport {
    fn send(&mut self, req: &Json) -> Result<usize> {
        // Same chaos site the TCP worker honors, same K-th-request
        // semantics (`shard.worker_crash=K`): a "crashed" local worker
        // drops the request on the floor and severs the link.
        self.requests_seen += 1;
        if faults::value("shard.worker_crash").is_some_and(|k| self.requests_seen >= k as u64) {
            bail!("worker {} closed the connection mid-request (crash)", self.label);
        }
        let line = req.to_string();
        let parsed = Json::parse(&line)
            .with_context(|| format!("worker {}: request did not survive encoding", self.label))?;
        let reply = self.worker.handle(&parsed).to_string();
        let bytes = line.len() + 1;
        self.pending.push_back(reply);
        Ok(bytes)
    }

    fn recv(&mut self) -> Result<(Json, usize)> {
        let line = self
            .pending
            .pop_front()
            .ok_or_else(|| anyhow::anyhow!("worker {}: recv with no request in flight", self.label))?;
        let bytes = line.len() + 1;
        let reply = Json::parse(&line)
            .with_context(|| format!("worker {}: reply did not survive encoding", self.label))?;
        Ok((reply, bytes))
    }

    fn describe(&self) -> String {
        self.label.clone()
    }
}

// --------------------------------------------------------------------- tcp

/// One TCP connection to a `cce shard-worker` process.
pub struct TcpTransport {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    peer: String,
}

impl TcpTransport {
    /// Connect to `host:port` and arm the I/O deadlines.
    pub fn connect(addr: &str) -> Result<TcpTransport> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting to shard worker at {addr}"))?;
        stream.set_nodelay(true).ok();
        stream
            .set_read_timeout(Some(IO_TIMEOUT))
            .with_context(|| format!("arming read timeout on {addr}"))?;
        stream
            .set_write_timeout(Some(IO_TIMEOUT))
            .with_context(|| format!("arming write timeout on {addr}"))?;
        let reader = BufReader::new(stream.try_clone().context("cloning worker stream")?);
        Ok(TcpTransport { reader, writer: stream, peer: addr.to_string() })
    }
}

impl Transport for TcpTransport {
    fn send(&mut self, req: &Json) -> Result<usize> {
        let mut line = req.to_string();
        line.push('\n');
        self.writer
            .write_all(line.as_bytes())
            .and_then(|()| self.writer.flush())
            .with_context(|| format!("worker {} is unreachable (send failed)", self.peer))?;
        Ok(line.len())
    }

    fn recv(&mut self) -> Result<(Json, usize)> {
        let mut line = String::new();
        let n = self
            .reader
            .read_line(&mut line)
            .with_context(|| format!("worker {} did not answer within {IO_TIMEOUT:?}", self.peer))?;
        if n == 0 {
            bail!("worker {} closed the connection mid-request (crash?)", self.peer);
        }
        let reply = Json::parse(line.trim())
            .with_context(|| format!("worker {} sent a malformed reply", self.peer))?;
        Ok((reply, n))
    }

    fn describe(&self) -> String {
        self.peer.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shard::protocol::{check_ok, req_hello, req_shutdown};

    #[test]
    fn local_transport_roundtrips_through_text() {
        let mut t = LocalTransport::new(0);
        assert!(t.recv().is_err(), "recv with nothing in flight must fail");
        let sent = t.send(&req_hello()).unwrap();
        assert!(sent > 10);
        let (reply, got) = t.recv().unwrap();
        assert!(got > 10);
        check_ok(&reply).unwrap();
        assert_eq!(reply.get("proto").and_then(|v| v.as_i64()), Some(1));
        // Ordered channel: a second recv has nothing to return.
        assert!(t.recv().is_err());
        t.send(&req_shutdown()).unwrap();
        check_ok(&t.recv().unwrap().0).unwrap();
    }
}
