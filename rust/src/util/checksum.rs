//! CRC-32 (IEEE 802.3, reflected polynomial 0xEDB88320) — the checkpoint
//! and sidecar integrity checksum.
//!
//! Bitwise, table-free: checkpoint payloads here are megabytes at most and
//! integrity checking is off the serving hot path, so simplicity wins over
//! a 1 KB lookup table.  The polynomial matches zlib/`cksum -o 3`, so a
//! stored checksum can be cross-checked with standard tooling.

/// CRC-32 of `bytes` (init `0xFFFFFFFF`, final XOR, reflected).
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut crc: u32 = 0xFFFF_FFFF;
    for &b in bytes {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_the_standard_check_value() {
        // The canonical CRC-32 test vector.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
    }

    #[test]
    fn empty_and_sensitivity() {
        assert_eq!(crc32(b""), 0);
        let a = crc32(b"checkpoint payload");
        let mut flipped = b"checkpoint payload".to_vec();
        flipped[3] ^= 0x01; // single bit flip
        assert_ne!(a, crc32(&flipped));
        // Truncation changes the checksum too.
        assert_ne!(a, crc32(b"checkpoint payloa"));
    }
}
