//! Deterministic PRNG (splitmix64 seeded xoshiro256**) — `rand` stand-in.
//!
//! Used by the synthetic-corpus generators, the batch shufflers, and the
//! property-testing harness.  Deterministic across platforms so every
//! experiment in EXPERIMENTS.md is exactly reproducible from its seed.

/// xoshiro256** with splitmix64 seeding.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream (for per-worker / per-epoch RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA076_1D64_78BD_642F))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)` (Lemire rejection, unbiased).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul_wide(x, n);
            if lo >= n || lo >= x.wrapping_neg() % n {
                return hi;
            }
        }
    }

    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Standard normal (Box–Muller).
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as i64
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.usize_below(i + 1);
            items.swap(i, j);
        }
    }

    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.usize_below(items.len())]
    }

    /// Sample from a Zipf distribution via a precomputed CDF table.
    pub fn zipf(&mut self, table: &ZipfTable) -> usize {
        table.sample(self)
    }
}

fn mul_wide(a: u64, b: u64) -> (u64, u64) {
    let w = (a as u128) * (b as u128);
    ((w >> 64) as u64, w as u64)
}

/// Precomputed Zipf CDF for O(log n) sampling — token frequencies in natural
/// corpora are Zipfian, which is exactly what makes the paper's softmax
/// sparsity (Fig. 3) appear; the synthetic corpus reproduces it.
pub struct ZipfTable {
    cdf: Vec<f64>,
}

impl ZipfTable {
    pub fn new(n: usize, s: f64) -> Self {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for c in &mut cdf {
            *c /= total;
        }
        ZipfTable { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        self.cdf.partition_point(|&c| c < u).min(self.cdf.len() - 1)
    }

    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = rng.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut rng = Rng::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(11);
        let n = 20_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn zipf_is_heavy_headed() {
        let table = ZipfTable::new(1000, 1.2);
        let mut rng = Rng::new(5);
        let mut counts = vec![0usize; 1000];
        for _ in 0..50_000 {
            counts[table.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[10] && counts[10] > counts[500]);
        assert!(counts[0] as f64 / 50_000.0 > 0.10);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(9);
        let mut v: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn forked_streams_differ() {
        let mut root = Rng::new(1);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }
}
