//! From-scratch substrates: JSON, CLI parsing, RNG, property testing,
//! stats, checksums, fault injection.
//!
//! This environment is fully offline with only `xla` + `anyhow` vendored, so
//! everything a framework would normally pull from crates.io (serde_json,
//! clap, rand, proptest, criterion) is implemented here from scratch —
//! small, tested, and sufficient for the coordinator's needs.

pub mod checksum;
pub mod cli;
pub mod faults;
pub mod json;
pub mod prop;
pub mod rng;
pub mod signal;
pub mod stats;

pub use checksum::crc32;
pub use json::Json;
pub use rng::Rng;
