//! Timing / summary statistics for the benchmark harness (criterion
//! stand-in) and the metrics registry.

use std::time::{Duration, Instant};

/// Summary statistics over a set of f64 samples.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "no samples");
        let n = samples.len();
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = sorted.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / (n.max(2) - 1) as f64;
        let q = |p: f64| -> f64 {
            let idx = (p * (n - 1) as f64).round() as usize;
            sorted[idx.min(n - 1)]
        };
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            p50: q(0.5),
            p90: q(0.9),
            p99: q(0.99),
            max: sorted[n - 1],
        }
    }
}

/// Measure `f` with warmup; returns per-iteration wall times in seconds.
pub fn measure<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times
}

/// Adaptive measurement: run until `min_iters` and `min_time` are both met
/// (bounded by `max_iters`) — keeps fast cases statistical and slow cases
/// bounded, like criterion's auto mode.
pub fn measure_adaptive<F: FnMut()>(
    warmup: usize,
    min_iters: usize,
    max_iters: usize,
    min_time: Duration,
    mut f: F,
) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::new();
    let start = Instant::now();
    while times.len() < max_iters
        && (times.len() < min_iters || start.elapsed() < min_time)
    {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times
}

/// Render a duration in engineer-friendly units.
pub fn fmt_duration(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.2} s")
    } else if secs >= 1e-3 {
        format!("{:.1} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.1} µs", secs * 1e6)
    } else {
        format!("{:.0} ns", secs * 1e9)
    }
}

/// Render a byte count in MB (the paper's tables use MB).
pub fn fmt_mb(bytes: u64) -> String {
    let mb = bytes as f64 / (1024.0 * 1024.0);
    if mb >= 100.0 {
        format!("{:.0} MB", mb)
    } else if mb >= 1.0 {
        format!("{:.1} MB", mb)
    } else {
        format!("{:.2} MB", mb)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
        assert!((s.std - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_single_sample() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.mean, 7.0);
        assert_eq!(s.p99, 7.0);
    }

    #[test]
    fn measure_counts() {
        let mut calls = 0;
        let t = measure(2, 5, || calls += 1);
        assert_eq!(t.len(), 5);
        assert_eq!(calls, 7);
    }

    #[test]
    fn fmt_units() {
        assert_eq!(fmt_duration(2.0), "2.00 s");
        assert_eq!(fmt_duration(0.0031), "3.1 ms");
        assert_eq!(fmt_mb(24_000 * 1024 * 1024), "24000 MB");
        assert_eq!(fmt_mb(1024 * 1024 / 2), "0.50 MB");
    }
}
