//! Minimal JSON parser / writer (serde_json stand-in).
//!
//! Supports the full JSON grammar; numbers are kept as `f64` with an `i64`
//! fast path (shapes, counts).  Object key order is preserved (insertion
//! order) so round-tripped manifests diff cleanly.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    /// Integral number (no fraction/exponent in the source).
    Int(i64),
    Float(f64),
    Str(String),
    Array(Vec<Json>),
    Object(Vec<(String, Json)>),
}

impl Json {
    // ------------------------------------------------------------ accessors

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Object(map) => map.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// `get` that errors with the key name (for required manifest fields).
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(i) => Some(*i),
            Json::Float(f) if f.fract() == 0.0 => Some(*f as i64),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Int(i) => Some(*i as f64),
            Json::Float(f) => Some(*f),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Object(o) => Some(o),
            _ => None,
        }
    }

    /// Convenience: object -> BTreeMap view (sorted iteration).
    pub fn to_map(&self) -> BTreeMap<String, Json> {
        match self {
            Json::Object(o) => o.iter().cloned().collect(),
            _ => BTreeMap::new(),
        }
    }

    // ----------------------------------------------------------- builders

    pub fn obj(entries: Vec<(&str, Json)>) -> Json {
        Json::Object(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Array(items.into_iter().collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // ------------------------------------------------------------ parsing

    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing data at byte {}", p.pos);
        }
        Ok(v)
    }

    // ------------------------------------------------------------ writing

    // An inherent `to_string` (rather than a Display impl) is deliberate:
    // serialization is an explicit act here, not formatting.
    #[allow(clippy::inherent_to_string)]
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(1), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::Float(f) => {
                if f.is_finite() {
                    let _ = write!(out, "{f}");
                } else {
                    out.push_str("null"); // JSON has no inf/nan
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Array(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Object(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected {:?} at byte {}", b as char, self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        let mut is_float = false;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while let Some(c) = self.peek() {
            match c {
                b'0'..=b'9' => self.pos += 1,
                b'.' | b'e' | b'E' | b'+' | b'-' => {
                    is_float = true;
                    self.pos += 1;
                }
                _ => break,
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        if is_float {
            Ok(Json::Float(text.parse::<f64>()?))
        } else {
            match text.parse::<i64>() {
                Ok(i) => Ok(Json::Int(i)),
                Err(_) => Ok(Json::Float(text.parse::<f64>()?)),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| anyhow!("bad \\u escape"))?;
                            let code =
                                u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            self.pos += 4;
                            // Surrogate pairs: rare in our data; combine if present.
                            if (0xD800..0xDC00).contains(&code) {
                                if self.bytes.get(self.pos + 1) == Some(&b'\\')
                                    && self.bytes.get(self.pos + 2) == Some(&b'u')
                                {
                                    let hex2 = self
                                        .bytes
                                        .get(self.pos + 3..self.pos + 7)
                                        .ok_or_else(|| anyhow!("bad surrogate"))?;
                                    let low = u32::from_str_radix(
                                        std::str::from_utf8(hex2)?,
                                        16,
                                    )?;
                                    self.pos += 6;
                                    let c = 0x10000
                                        + ((code - 0xD800) << 10)
                                        + (low - 0xDC00);
                                    s.push(
                                        char::from_u32(c)
                                            .ok_or_else(|| anyhow!("bad codepoint"))?,
                                    );
                                } else {
                                    bail!("lone surrogate");
                                }
                            } else {
                                s.push(
                                    char::from_u32(code)
                                        .ok_or_else(|| anyhow!("bad codepoint"))?,
                                );
                            }
                        }
                        other => bail!("bad escape {:?}", other.map(|c| c as char)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 character (multi-byte safe).
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let ch = rest.chars().next().unwrap();
                    s.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Array(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Array(items));
                }
                _ => bail!("expected , or ] at byte {}", self.pos),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Object(map));
                }
                _ => bail!("expected , or }} at byte {}", self.pos),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-42").unwrap(), Json::Int(-42));
        assert_eq!(Json::parse("2.5e2").unwrap(), Json::Float(250.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, {"b": "c"}, null], "d": {}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_array().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_array().unwrap()[1].get("b").unwrap().as_str(),
            Some("c")
        );
        assert!(v.get("d").unwrap().as_object().unwrap().is_empty());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"shape":[2048,576],"dtype":"float32","nested":{"x":1.5,"y":[true,false,null]},"s":"he\"llo\n"}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        let v3 = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn big_ints_preserved() {
        let v = Json::parse("9007199254740993").unwrap();
        assert_eq!(v.as_i64(), Some(9007199254740993));
    }
}
