//! Tiny CLI argument parser (clap stand-in).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional
//! arguments, with typed accessors and a generated usage string.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Parsed command-line arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    /// Option keys that were consumed via a typed accessor (for validation).
    seen: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse `argv[1..]`.  `flag_names` lists options that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(
        argv: I,
        flag_names: &[&str],
    ) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&stripped) {
                    out.flags.push(stripped.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| anyhow!("--{stripped} expects a value"))?;
                    out.options.insert(stripped.to_string(), v);
                }
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.seen.borrow_mut().push(name.to_string());
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.opt(name) {
            None => Ok(default),
            Some(s) => s
                .parse::<T>()
                .map_err(|e| anyhow!("--{name}={s}: {e}")),
        }
    }

    pub fn require(&self, name: &str) -> Result<&str> {
        self.opt(name)
            .ok_or_else(|| anyhow!("missing required option --{name}"))
    }

    /// Error on unknown options (catches typos like `--stpes`).
    pub fn finish(&self, known_flags: &[&str]) -> Result<()> {
        let seen = self.seen.borrow();
        for key in self.options.keys() {
            if !seen.iter().any(|s| s == key) {
                bail!("unknown option --{key}");
            }
        }
        for f in &self.flags {
            if !known_flags.contains(&f.as_str()) {
                bail!("unknown flag --{f}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_mixed() {
        let a = Args::parse(argv("train --steps 100 --out=dir --verbose pos1"),
                            &["verbose"]).unwrap();
        assert_eq!(a.positional, vec!["train", "pos1"]);
        assert_eq!(a.opt("steps"), Some("100"));
        assert_eq!(a.opt("out"), Some("dir"));
        assert!(a.flag("verbose"));
    }

    #[test]
    fn typed_defaults() {
        let a = Args::parse(argv("--n 8"), &[]).unwrap();
        assert_eq!(a.get("n", 1usize).unwrap(), 8);
        assert_eq!(a.get("m", 3usize).unwrap(), 3);
        assert!(a.get::<usize>("n", 0).is_ok());
    }

    #[test]
    fn bad_value_errors() {
        let a = Args::parse(argv("--n x"), &[]).unwrap();
        assert!(a.get("n", 1usize).is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(argv("--n"), &[]).is_err());
    }

    #[test]
    fn unknown_option_rejected() {
        let a = Args::parse(argv("--steps 5 --stpes 9"), &[]).unwrap();
        let _ = a.opt("steps");
        assert!(a.finish(&[]).is_err());
    }
}
