//! Tiny CLI argument parser (clap stand-in).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional
//! arguments, with typed accessors and a generated usage string.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Parsed command-line arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    /// Every value of every option, in argv order — [`Args::opt`] reads
    /// the last occurrence, [`Args::opt_all`] reads all of them
    /// (repeatable options like `cce serve --checkpoint tag=path`).
    pub repeated: BTreeMap<String, Vec<String>>,
    pub flags: Vec<String>,
    /// Option keys that were consumed via a typed accessor (for validation).
    seen: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse `argv[1..]`.  `flag_names` lists options that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(
        argv: I,
        flag_names: &[&str],
    ) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.push_option(k, v.to_string());
                } else if flag_names.contains(&stripped) {
                    out.flags.push(stripped.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| anyhow!("--{stripped} expects a value"))?;
                    out.push_option(stripped, v);
                }
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    fn push_option(&mut self, name: &str, value: String) {
        self.repeated.entry(name.to_string()).or_default().push(value.clone());
        self.options.insert(name.to_string(), value);
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.seen.borrow_mut().push(name.to_string());
        self.options.get(name).map(|s| s.as_str())
    }

    /// Every occurrence of a repeatable option, in argv order (empty when
    /// the option was never given).
    pub fn opt_all(&self, name: &str) -> Vec<String> {
        self.seen.borrow_mut().push(name.to_string());
        self.repeated.get(name).cloned().unwrap_or_default()
    }

    pub fn get<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.opt(name) {
            None => Ok(default),
            Some(s) => s
                .parse::<T>()
                .map_err(|e| anyhow!("--{name}={s}: {e}")),
        }
    }

    pub fn require(&self, name: &str) -> Result<&str> {
        self.opt(name)
            .ok_or_else(|| anyhow!("missing required option --{name}"))
    }

    /// Error on unknown options (catches typos like `--stpes`).
    pub fn finish(&self, known_flags: &[&str]) -> Result<()> {
        let seen = self.seen.borrow();
        for key in self.options.keys() {
            if !seen.iter().any(|s| s == key) {
                bail!("unknown option --{key}");
            }
        }
        for f in &self.flags {
            if !known_flags.contains(&f.as_str()) {
                bail!("unknown flag --{f}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_mixed() {
        let a = Args::parse(argv("train --steps 100 --out=dir --verbose pos1"),
                            &["verbose"]).unwrap();
        assert_eq!(a.positional, vec!["train", "pos1"]);
        assert_eq!(a.opt("steps"), Some("100"));
        assert_eq!(a.opt("out"), Some("dir"));
        assert!(a.flag("verbose"));
    }

    #[test]
    fn typed_defaults() {
        let a = Args::parse(argv("--n 8"), &[]).unwrap();
        assert_eq!(a.get("n", 1usize).unwrap(), 8);
        assert_eq!(a.get("m", 3usize).unwrap(), 3);
        assert!(a.get::<usize>("n", 0).is_ok());
    }

    #[test]
    fn bad_value_errors() {
        let a = Args::parse(argv("--n x"), &[]).unwrap();
        assert!(a.get("n", 1usize).is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(argv("--n"), &[]).is_err());
    }

    #[test]
    fn repeated_options_accumulate_and_last_wins() {
        let a = Args::parse(
            argv("serve --checkpoint a=x.ckpt --checkpoint=b=y.ckpt --port 0"),
            &[],
        )
        .unwrap();
        assert_eq!(a.opt_all("checkpoint"), vec!["a=x.ckpt".to_string(), "b=y.ckpt".to_string()]);
        assert_eq!(a.opt("checkpoint"), Some("b=y.ckpt"), "single-value view sees the last");
        assert!(a.opt_all("missing").is_empty());
        assert!(a.finish(&[]).is_ok(), "opt_all marks the option as consumed");
    }

    #[test]
    fn unknown_option_rejected() {
        let a = Args::parse(argv("--steps 5 --stpes 9"), &[]).unwrap();
        let _ = a.opt("steps");
        assert!(a.finish(&[]).is_err());
    }
}
