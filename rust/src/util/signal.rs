//! Minimal, `libc`-crate-free POSIX signal hook for graceful drain.
//!
//! `cce serve` (and the `--supervise` parent) need exactly one thing from
//! the OS signal machinery: "a SIGTERM/SIGINT arrived, start draining".
//! This module provides that as an atomic flag set from a hand-declared
//! `sigaction` shim — no `libc` crate, no signal-fd, no handler logic
//! beyond two relaxed stores (the only async-signal-safe things a handler
//! may do).  Serving loops poll [`drain_requested`] at their existing
//! poll boundaries (accept loop: 200 ms, supervisor: 50 ms), so delivery
//! latency is bounded by a poll tick, not by the handler.
//!
//! The shim binds the C library's `sigaction`/`kill` symbols directly
//! with the glibc/musl `struct sigaction` layout shared by `x86_64` and
//! `aarch64` Linux (`sa_handler` at offset 0, a 128-byte `sa_mask`, then
//! `sa_flags`).  Other targets get a no-op fallback: [`install`] returns
//! `false` and only `{"op":"shutdown"}` drains, same as before this
//! module existed.
//!
//! [`send`] is the other half: the supervisor forwards SIGTERM to its
//! child as a drain request (`std::process::Child::kill` is always
//! SIGKILL, which is precisely the thing we are trying to avoid).

use std::sync::atomic::{AtomicBool, AtomicI32, Ordering};
use std::sync::Once;

/// Interactive interrupt (Ctrl-C).
pub const SIGINT: i32 = 2;
/// Polite termination request — the orchestrator/`kill` default.
pub const SIGTERM: i32 = 15;

/// Set by the handler; never cleared except by [`reset`] (tests).
static DRAIN: AtomicBool = AtomicBool::new(false);
/// Which signal set the flag (0 = none yet).
static LAST: AtomicI32 = AtomicI32::new(0);
static INSTALL: Once = Once::new();
static INSTALLED: AtomicBool = AtomicBool::new(false);

/// The actual handler: two stores and nothing else.  Async-signal-safe
/// by construction — no allocation, no locks, no formatting.
extern "C" fn on_signal(sig: i32) {
    LAST.store(sig, Ordering::SeqCst);
    DRAIN.store(true, Ordering::SeqCst);
}

/// Arm the SIGTERM + SIGINT handlers (idempotent).  Returns `true` when
/// the handlers are installed, `false` on targets without the shim or if
/// `sigaction` itself failed — callers treat `false` as "signals won't
/// drain; the shutdown op still does".
pub fn install() -> bool {
    INSTALL.call_once(|| {
        if imp::install_handler(SIGTERM) && imp::install_handler(SIGINT) {
            INSTALLED.store(true, Ordering::SeqCst);
        }
    });
    INSTALLED.load(Ordering::SeqCst)
}

/// True once any armed signal has been delivered: time to drain.
pub fn drain_requested() -> bool {
    DRAIN.load(Ordering::SeqCst)
}

/// The signal number that requested the drain (0 when none has).
pub fn last_signal() -> i32 {
    LAST.load(Ordering::SeqCst)
}

/// Clear the drain flag (tests only — a real process drains once).
pub fn reset() {
    LAST.store(0, Ordering::SeqCst);
    DRAIN.store(false, Ordering::SeqCst);
}

/// Deliver `sig` to `pid` (supervisor → child drain forwarding).
/// Returns `false` if delivery failed or the target has no shim.
pub fn send(pid: u32, sig: i32) -> bool {
    imp::send(pid, sig)
}

#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
mod imp {
    /// glibc/musl `struct sigaction` for x86_64 + aarch64 Linux:
    /// `sa_handler` (8 B, nullable fn pointer), `sa_mask` (128 B),
    /// `sa_flags` (4 B), `sa_restorer` (8 B after padding; aarch64's
    /// struct simply ends earlier and ignores the extra bytes we carry).
    #[repr(C)]
    struct SigAction {
        handler: Option<extern "C" fn(i32)>,
        mask: [u64; 16],
        flags: i32,
        restorer: Option<extern "C" fn()>,
    }

    /// Restart interrupted syscalls so a drain signal never surfaces as a
    /// spurious EINTR inside unrelated I/O (the loops poll the flag).
    const SA_RESTART: i32 = 0x1000_0000;

    extern "C" {
        fn sigaction(signum: i32, act: *const SigAction, oldact: *mut SigAction) -> i32;
        fn kill(pid: i32, sig: i32) -> i32;
    }

    pub(super) fn install_handler(sig: i32) -> bool {
        let act = SigAction {
            handler: Some(super::on_signal),
            mask: [0; 16],
            flags: SA_RESTART,
            restorer: None,
        };
        unsafe { sigaction(sig, &act, std::ptr::null_mut()) == 0 }
    }

    pub(super) fn send(pid: u32, sig: i32) -> bool {
        unsafe { kill(pid as i32, sig) == 0 }
    }
}

#[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
mod imp {
    pub(super) fn install_handler(_sig: i32) -> bool {
        false
    }

    pub(super) fn send(_pid: u32, _sig: i32) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::{Duration, Instant};

    // The one test that touches process-global signal state.  SIGTERM is
    // delivered to this very test process; the installed handler absorbs
    // it (the default disposition would kill the harness), so this also
    // proves the handler replaces the default, not just that kill works.
    #[test]
    fn sigterm_sets_the_drain_flag_without_killing_the_process() {
        if !install() {
            return; // no shim on this target; nothing to verify
        }
        reset();
        assert!(send(std::process::id(), SIGTERM), "kill(self, SIGTERM) failed");
        let start = Instant::now();
        while !drain_requested() && start.elapsed() < Duration::from_secs(2) {
            std::thread::sleep(Duration::from_millis(1));
        }
        assert!(drain_requested(), "drain flag never set after SIGTERM");
        assert_eq!(last_signal(), SIGTERM);
        reset();
        assert!(!drain_requested(), "reset must clear the flag");
    }
}
