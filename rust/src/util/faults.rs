//! Env-driven fault injection (failpoints) for the chaos harness.
//!
//! `CCE_FAULTS="batcher.panic=0.05,ckpt.short_write=1,conn.stall_ms=500"`
//! arms named failpoints at process start; code under test asks the
//! registry at each site:
//!
//! * [`fire`] — one evaluation of a probabilistic site.  `p >= 1` always
//!   fires; `0 < p < 1` fires deterministically from a seeded hash of the
//!   site's own evaluation counter, so a given spec reproduces the same
//!   firing pattern on every run (no wall-clock, no global RNG).
//! * [`maybe_panic`] — panic with `"fault injected: <site>"` when the site
//!   fires (exercises the `catch_unwind` isolation boundaries).
//! * [`stall`] — sleep for the configured value in milliseconds (for
//!   `*_ms` sites such as `conn.stall_ms`), every evaluation.
//!
//! Zero-cost when unset: every query short-circuits on one relaxed atomic
//! load before touching the registry.  Tests replace the registry in
//! process with [`install`] / [`clear`] (the chaos suite serializes on a
//! lock of its own — faults are process-global).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, Once, OnceLock};
use std::time::Duration;

use anyhow::{bail, Context, Result};

/// One armed failpoint.
struct Site {
    name: String,
    /// Probability in `[0, 1)`, or `>= 1` for "always"; `*_ms` sites carry
    /// a duration in milliseconds instead.
    value: f64,
    /// Per-site evaluation counter — the deterministic "randomness" input.
    hits: AtomicU64,
    /// Seed for the per-evaluation hash, derived from the site name.
    seed: u64,
}

#[derive(Default)]
struct Registry {
    sites: Vec<Site>,
}

/// Fast-path guard: false ⇒ no failpoint is armed anywhere.
static ACTIVE: AtomicBool = AtomicBool::new(false);
static ENV_INIT: Once = Once::new();

fn registry() -> &'static Mutex<Registry> {
    static REG: OnceLock<Mutex<Registry>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(Registry::default()))
}

fn lock_registry() -> std::sync::MutexGuard<'static, Registry> {
    registry().lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

fn fnv64(s: &str) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Hash of (site seed, evaluation index) mapped to `[0, 1)`.
fn unit_hash(seed: u64, n: u64) -> f64 {
    (splitmix64(seed ^ n.wrapping_mul(0xA076_1D64_78BD_642F)) >> 11) as f64
        * (1.0 / (1u64 << 53) as f64)
}

fn parse_spec(spec: &str) -> Result<Vec<Site>> {
    let mut sites = Vec::new();
    for part in spec.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (name, value) = part
            .split_once('=')
            .with_context(|| format!("fault {part:?}: want site=value"))?;
        let name = name.trim();
        let value: f64 = value
            .trim()
            .parse()
            .with_context(|| format!("fault {name:?}: bad value {value:?}"))?;
        if name.is_empty() {
            bail!("fault {part:?}: empty site name");
        }
        if !value.is_finite() || value < 0.0 {
            bail!("fault {name:?}: value must be finite and >= 0, got {value}");
        }
        sites.push(Site {
            seed: fnv64(name) ^ 0x5EED_FA17,
            name: name.to_string(),
            value,
            hits: AtomicU64::new(0),
        });
    }
    Ok(sites)
}

fn load_env_once() {
    ENV_INIT.call_once(|| {
        let spec = match std::env::var("CCE_FAULTS") {
            Ok(spec) if !spec.trim().is_empty() => spec,
            _ => return,
        };
        match parse_spec(&spec) {
            Ok(sites) => {
                let armed = !sites.is_empty();
                lock_registry().sites = sites;
                ACTIVE.store(armed, Ordering::SeqCst);
                if armed {
                    eprintln!("[faults] CCE_FAULTS armed: {}", spec.trim());
                }
            }
            Err(err) => eprintln!("[faults] ignoring CCE_FAULTS: {err:#}"),
        }
    });
}

/// True when any failpoint is armed (env or [`install`]).
pub fn enabled() -> bool {
    load_env_once();
    ACTIVE.load(Ordering::Relaxed)
}

/// Replace the active fault set (tests).  Empty spec disarms everything.
pub fn install(spec: &str) -> Result<()> {
    // Mark env consumed so a later lazy load cannot clobber the install.
    ENV_INIT.call_once(|| {});
    let sites = parse_spec(spec)?;
    let armed = !sites.is_empty();
    lock_registry().sites = sites;
    ACTIVE.store(armed, Ordering::SeqCst);
    Ok(())
}

/// Disarm every failpoint.
pub fn clear() {
    ENV_INIT.call_once(|| {});
    lock_registry().sites.clear();
    ACTIVE.store(false, Ordering::SeqCst);
}

/// The raw configured value of `site`, if armed (no counter advance).
pub fn value(site: &str) -> Option<f64> {
    if !enabled() {
        return None;
    }
    lock_registry().sites.iter().find(|s| s.name == site).map(|s| s.value)
}

/// One evaluation of probabilistic failpoint `site`: advances its counter
/// and reports whether it fires this time.
pub fn fire(site: &str) -> bool {
    if !enabled() {
        return false;
    }
    let reg = lock_registry();
    match reg.sites.iter().find(|s| s.name == site) {
        None => false,
        Some(s) => {
            let n = s.hits.fetch_add(1, Ordering::Relaxed);
            s.value >= 1.0 || unit_hash(s.seed, n) < s.value
        }
    }
}

/// Panic if `site` fires — the payload names the site so isolation layers
/// can surface a precise `internal` error.
pub fn maybe_panic(site: &str) {
    if fire(site) {
        panic!("fault injected: {site}");
    }
}

/// Sleep for the configured milliseconds of `site` (e.g. `conn.stall_ms`),
/// every evaluation while armed.
pub fn stall(site: &str) {
    if let Some(ms) = value(site) {
        if ms > 0.0 {
            std::thread::sleep(Duration::from_millis(ms as u64));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Faults are process-global; these tests serialize on one lock so they
    // cannot interleave arm/disarm with each other.
    fn serial() -> std::sync::MutexGuard<'static, ()> {
        static GATE: Mutex<()> = Mutex::new(());
        GATE.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
    }

    // These tests arm `unit.*` site names on purpose: lib tests run
    // concurrently in one process, and arming a *live* site (say
    // `batcher.panic`) here would fire inside whichever batcher/engine/
    // checkpoint test happens to be running at the same time.
    #[test]
    fn unarmed_is_silent() {
        let _gate = serial();
        clear();
        assert!(!fire("unit.panic"));
        assert_eq!(value("unit.stall_ms"), None);
        maybe_panic("unit.panic"); // must not panic
    }

    #[test]
    fn spec_parses_the_documented_forms() {
        let _gate = serial();
        install("unit.panic=0.05, unit.write=1 ,unit.stall_ms=500").unwrap();
        assert_eq!(value("unit.panic"), Some(0.05));
        assert_eq!(value("unit.write"), Some(1.0));
        assert_eq!(value("unit.stall_ms"), Some(500.0));
        assert!(fire("unit.write"), "p >= 1 always fires");
        clear();
        assert!(!fire("unit.write"));
    }

    #[test]
    fn bad_specs_rejected() {
        let _gate = serial();
        assert!(install("nodelimiter").is_err());
        assert!(install("site=notanumber").is_err());
        assert!(install("site=-1").is_err());
        assert!(install("=5").is_err());
        clear();
    }

    #[test]
    fn probability_is_deterministic_and_roughly_calibrated() {
        let _gate = serial();
        install("unit.prob=0.25").unwrap();
        let first: Vec<bool> = (0..400).map(|_| fire("unit.prob")).collect();
        // Re-arm: the counter resets, so the firing pattern replays exactly.
        install("unit.prob=0.25").unwrap();
        let second: Vec<bool> = (0..400).map(|_| fire("unit.prob")).collect();
        assert_eq!(first, second, "same spec must reproduce the same pattern");
        let hits = first.iter().filter(|&&b| b).count();
        assert!(
            (40..=160).contains(&hits),
            "p=0.25 over 400 draws fired {hits} times — hash badly skewed"
        );
        clear();
    }
}
