//! Minimal property-based testing harness (proptest stand-in).
//!
//! Runs a property over many random cases from a deterministic seed and, on
//! failure, reports the failing case's seed so it can be replayed.  A simple
//! integer/vec shrinker narrows failures when the generator supports it.

use crate::util::rng::Rng;

/// Number of random cases per property (override with `CCE_PROP_CASES`).
pub fn default_cases() -> usize {
    std::env::var("CCE_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(128)
}

/// Run `prop(rng)` over `cases` random inputs; panic with the case seed on
/// the first failure.
pub fn check<F: FnMut(&mut Rng) -> Result<(), String>>(name: &str, mut prop: F) {
    let cases = default_cases();
    let base_seed = 0xC0FFEE_u64;
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property {name:?} failed on case {case} (seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Property over a generated value with shrinking: `gen` produces a value
/// from the RNG, `shrink` yields smaller candidates, `prop` tests it.
pub fn check_shrink<T, G, S, P>(name: &str, mut gen: G, shrink: S, mut prop: P)
where
    T: Clone + std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    S: Fn(&T) -> Vec<T>,
    P: FnMut(&T) -> Result<(), String>,
{
    let cases = default_cases();
    for case in 0..cases {
        let seed = 0xBADC0DE_u64.wrapping_add(case as u64);
        let mut rng = Rng::new(seed);
        let value = gen(&mut rng);
        if let Err(first_msg) = prop(&value) {
            // Greedy shrink: repeatedly take the first failing candidate.
            // Bounded so a shrinker that fails to make progress can't hang.
            let mut cur = value;
            let mut msg = first_msg;
            let mut rounds = 0;
            'outer: while rounds < 1000 {
                rounds += 1;
                for cand in shrink(&cur) {
                    if let Err(m) = prop(&cand) {
                        cur = cand;
                        msg = m;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property {name:?} failed (seed {seed:#x})\n  minimal case: {cur:?}\n  error: {msg}"
            );
        }
    }
}

/// Shrinker for vectors: halves, then element-dropping.  Every candidate is
/// strictly shorter than the input, so greedy shrinking always terminates.
pub fn shrink_vec<T: Clone>(v: &Vec<T>) -> Vec<Vec<T>> {
    let mut out = Vec::new();
    if v.is_empty() {
        return out;
    }
    if v.len() >= 2 {
        out.push(v[..v.len() / 2].to_vec());
        out.push(v[v.len() / 2..].to_vec());
    }
    if v.len() <= 8 {
        for i in 0..v.len() {
            let mut w = v.clone();
            w.remove(i);
            out.push(w);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("u64 addition commutes", |rng| {
            let (a, b) = (rng.next_u64() >> 1, rng.next_u64() >> 1);
            if a + b == b + a {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "always fails")]
    fn failing_property_panics_with_seed() {
        check("always fails", |_| Err("always fails".into()));
    }

    #[test]
    #[should_panic(expected = "minimal case")]
    fn shrinking_reduces_case() {
        check_shrink(
            "vec with any element > 10 fails",
            |rng| (0..20).map(|_| rng.usize_below(100)).collect::<Vec<_>>(),
            shrink_vec,
            |v| {
                if v.iter().any(|&x| x > 10) {
                    Err(format!("{v:?} has big element"))
                } else {
                    Ok(())
                }
            },
        );
    }
}
