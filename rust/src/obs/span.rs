//! Per-request trace spans.
//!
//! A request passing through the serve stack is decomposed into stages —
//! queue wait, batch assembly, kernel execution, response serialization —
//! each timed with a [`Stopwatch`] and aggregated into the per-stage
//! histograms of the batcher's registry.  When a request sets
//! `"trace":true`, its own [`StageTimings`] are additionally echoed back
//! in the response as a `timings` object (serialize time is only in the
//! histograms: it cannot be known before the response is written).

use std::time::Instant;

use crate::util::json::Json;

/// A start-time capture that is inert when the observability layer is
/// disabled: no clock read, and every elapsed query returns `None`.
#[derive(Debug, Clone, Copy)]
pub struct Stopwatch(Option<Instant>);

impl Stopwatch {
    /// Capture now — or nothing, when `CCE_OBS` disabled the layer.
    pub fn start() -> Stopwatch {
        if crate::obs::enabled() {
            Stopwatch(Some(Instant::now()))
        } else {
            Stopwatch(None)
        }
    }

    /// Microseconds since [`Stopwatch::start`], `None` when disabled.
    pub fn elapsed_us(&self) -> Option<u64> {
        self.0.map(|t| t.elapsed().as_micros() as u64)
    }
}

/// Stage timings of one request, in microseconds.
///
/// * `queue_us` — submit until batch execution began (includes waiting out
///   the batch-assembly window while stragglers were collected);
/// * `assemble_us` — the batch-assembly window of the batch this request
///   rode in (shared by every request in the batch);
/// * `kernel_us` — engine execution time of the request's sub-batch.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StageTimings {
    pub queue_us: u64,
    pub assemble_us: u64,
    pub kernel_us: u64,
}

impl StageTimings {
    /// The `timings` object echoed in traced responses.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("queue_us", Json::Int(self.queue_us as i64)),
            ("assemble_us", Json::Int(self.assemble_us as i64)),
            ("kernel_us", Json::Int(self.kernel_us as i64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timings_serialize_all_stages() {
        let t = StageTimings { queue_us: 12, assemble_us: 3, kernel_us: 450 };
        let j = t.to_json();
        assert_eq!(j.get("queue_us").and_then(Json::as_i64), Some(12));
        assert_eq!(j.get("assemble_us").and_then(Json::as_i64), Some(3));
        assert_eq!(j.get("kernel_us").and_then(Json::as_i64), Some(450));
    }

    #[test]
    fn stopwatch_measures_when_enabled() {
        // The obs layer defaults to enabled; a stopwatch must yield a
        // finite elapsed time.
        if crate::obs::enabled() {
            let sw = Stopwatch::start();
            assert!(sw.elapsed_us().is_some());
        }
    }
}
