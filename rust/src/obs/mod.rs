//! Dependency-free observability: a metrics registry (counters, gauges,
//! log-bucket histograms), per-request trace spans, and rendering to both
//! Prometheus text exposition and the line-JSON `{"op":"metrics"}` answer.
//!
//! Layout follows the rest of the substrate — `std` only, lock-free hot
//! paths, and the same zero-cost-when-unused discipline as
//! [`crate::util::faults`]: every timing hook short-circuits on one relaxed
//! atomic load ([`enabled`]), and recording a sample is a handful of
//! relaxed `AtomicU64` operations on a handle resolved once at startup.
//! `CCE_OBS=0` (or `off`/`false`) disarms the layer at process start.
//!
//! Two scopes of registry exist on purpose:
//!
//! * [`global`] — the process-wide registry for singleton subsystems: the
//!   exec kernels (`exec_*` families: sweep timings, filter survival, pool
//!   occupancy, workspace high-water marks) and the trainer (`train_*`).
//!   Its standard families are pre-registered so an exporter always shows
//!   them, even before the first sweep or step.
//! * instance registries ([`Registry::new`]) — the serve stack creates one
//!   per batcher (`serve_*` families), so concurrent servers in one
//!   process (the test suite, future multi-tenant serving) never mix
//!   counts and `{"op":"info"}` stays exact per instance.

pub mod histogram;
pub mod span;

pub use histogram::Histogram;
pub use span::{StageTimings, Stopwatch};

use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, Once, OnceLock};

use crate::util::json::Json;

// ------------------------------------------------------------------ gating

/// Fast-path guard: false ⇒ every timing hook is inert.
static ACTIVE: AtomicBool = AtomicBool::new(true);
static ENV_INIT: Once = Once::new();

fn load_env_once() {
    ENV_INIT.call_once(|| {
        if let Ok(v) = std::env::var("CCE_OBS") {
            let v = v.trim().to_ascii_lowercase();
            if v == "0" || v == "off" || v == "false" {
                ACTIVE.store(false, Ordering::SeqCst);
            }
        }
    });
}

/// True unless `CCE_OBS=0|off|false` disarmed the layer (or a test did).
pub fn enabled() -> bool {
    load_env_once();
    ACTIVE.load(Ordering::Relaxed)
}

/// Flip the layer on/off in-process (tests).
pub fn set_enabled(on: bool) {
    ENV_INIT.call_once(|| {});
    ACTIVE.store(on, Ordering::SeqCst);
}

// ----------------------------------------------------------------- metrics

/// Monotone counter.
pub struct Counter {
    name: String,
    help: String,
    value: AtomicU64,
}

impl Counter {
    pub fn inc(&self) {
        self.value.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }

    pub fn name(&self) -> &str {
        &self.name
    }
}

/// Integer gauge (up/down, or high-water via [`Gauge::set_max`]).  `add`
/// and `sub` are sequentially consistent so credit/debit pairs that other
/// threads poll (queue depth, in-flight) never transiently disagree.
pub struct Gauge {
    name: String,
    help: String,
    value: AtomicI64,
}

impl Gauge {
    pub fn set(&self, v: i64) {
        self.value.store(v, Ordering::SeqCst);
    }

    pub fn add(&self, n: i64) {
        self.value.fetch_add(n, Ordering::SeqCst);
    }

    pub fn sub(&self, n: i64) {
        self.value.fetch_sub(n, Ordering::SeqCst);
    }

    /// Raise to `v` if larger (high-water marks).
    pub fn set_max(&self, v: i64) {
        self.value.fetch_max(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.value.load(Ordering::SeqCst)
    }

    pub fn name(&self) -> &str {
        &self.name
    }
}

/// Float gauge (ratios, losses, rates) — an f64 stored as bits.
pub struct GaugeF {
    name: String,
    help: String,
    bits: AtomicU64,
}

impl GaugeF {
    pub fn set(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }

    pub fn name(&self) -> &str {
        &self.name
    }
}

enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    GaugeF(Arc<GaugeF>),
    Histogram(Arc<Histogram>),
}

impl Metric {
    fn name(&self) -> &str {
        match self {
            Metric::Counter(m) => m.name(),
            Metric::Gauge(m) => m.name(),
            Metric::GaugeF(m) => m.name(),
            Metric::Histogram(m) => m.name(),
        }
    }
}

// ---------------------------------------------------------------- registry

/// An ordered set of named metric families.  Cheap to clone (shared
/// handle); lookups lock a mutex, so resolve handles once at startup and
/// record through the returned `Arc`s.
#[derive(Clone)]
pub struct Registry {
    metrics: Arc<Mutex<Vec<Metric>>>,
}

impl Default for Registry {
    fn default() -> Registry {
        Registry::new()
    }
}

impl Registry {
    pub fn new() -> Registry {
        Registry { metrics: Arc::new(Mutex::new(Vec::new())) }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Vec<Metric>> {
        self.metrics.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
    }

    /// Get-or-create a counter named `name`.
    pub fn counter(&self, name: &str, help: &str) -> Arc<Counter> {
        let mut metrics = self.lock();
        for m in metrics.iter() {
            if let Metric::Counter(c) = m {
                if c.name() == name {
                    return c.clone();
                }
            }
        }
        let c = Arc::new(Counter {
            name: name.to_string(),
            help: help.to_string(),
            value: AtomicU64::new(0),
        });
        metrics.push(Metric::Counter(c.clone()));
        c
    }

    /// Get-or-create an integer gauge named `name`.
    pub fn gauge(&self, name: &str, help: &str) -> Arc<Gauge> {
        let mut metrics = self.lock();
        for m in metrics.iter() {
            if let Metric::Gauge(g) = m {
                if g.name() == name {
                    return g.clone();
                }
            }
        }
        let g = Arc::new(Gauge {
            name: name.to_string(),
            help: help.to_string(),
            value: AtomicI64::new(0),
        });
        metrics.push(Metric::Gauge(g.clone()));
        g
    }

    /// Get-or-create a float gauge named `name`.
    pub fn gauge_f(&self, name: &str, help: &str) -> Arc<GaugeF> {
        let mut metrics = self.lock();
        for m in metrics.iter() {
            if let Metric::GaugeF(g) = m {
                if g.name() == name {
                    return g.clone();
                }
            }
        }
        let g = Arc::new(GaugeF {
            name: name.to_string(),
            help: help.to_string(),
            bits: AtomicU64::new(0f64.to_bits()),
        });
        metrics.push(Metric::GaugeF(g.clone()));
        g
    }

    /// Get-or-create a histogram named `name`.
    pub fn histogram(&self, name: &str, help: &str) -> Arc<Histogram> {
        let mut metrics = self.lock();
        for m in metrics.iter() {
            if let Metric::Histogram(h) = m {
                if h.name() == name {
                    return h.clone();
                }
            }
        }
        let h = Arc::new(Histogram::new(name, help));
        metrics.push(Metric::Histogram(h.clone()));
        h
    }

    /// Number of registered metric families.
    pub fn family_count(&self) -> usize {
        self.lock().len()
    }

    /// Append Prometheus text exposition (format 0.0.4) for every family.
    pub fn render_prometheus(&self, out: &mut String) {
        use std::fmt::Write as _;
        for m in self.lock().iter() {
            match m {
                Metric::Counter(c) => {
                    let _ = writeln!(out, "# HELP {} {}", c.name, c.help);
                    let _ = writeln!(out, "# TYPE {} counter", c.name);
                    let _ = writeln!(out, "{} {}", c.name, c.get());
                }
                Metric::Gauge(g) => {
                    let _ = writeln!(out, "# HELP {} {}", g.name, g.help);
                    let _ = writeln!(out, "# TYPE {} gauge", g.name);
                    let _ = writeln!(out, "{} {}", g.name, g.get());
                }
                Metric::GaugeF(g) => {
                    let _ = writeln!(out, "# HELP {} {}", g.name, g.help);
                    let _ = writeln!(out, "# TYPE {} gauge", g.name);
                    let _ = writeln!(out, "{} {}", g.name, g.get());
                }
                Metric::Histogram(h) => h.render_prometheus(out),
            }
        }
    }

    /// JSON snapshot: one field per family, in registration order.
    /// Histograms become `{count, sum, p50, p90, p99}` objects.
    pub fn to_json_fields(&self) -> Vec<(String, Json)> {
        self.lock()
            .iter()
            .map(|m| {
                let value = match m {
                    Metric::Counter(c) => Json::Int(c.get() as i64),
                    Metric::Gauge(g) => Json::Int(g.get()),
                    Metric::GaugeF(g) => Json::Float(g.get()),
                    Metric::Histogram(h) => h.to_json(),
                };
                (m.name().to_string(), value)
            })
            .collect()
    }
}

/// The process-global registry (exec + train families).  Standard families
/// are pre-registered so exporters always show the full set, zero-valued,
/// before the first sweep or train step.
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(|| {
        let r = Registry::new();
        r.histogram("exec_fwd_sweep_us", "CCE forward sweep wall time per call");
        r.histogram("exec_bwd_sweep_us", "CCE backward sweep wall time per call");
        r.histogram("exec_infer_sweep_us", "Inference kernel (topk/sample/score) wall time");
        r.gauge_f(
            "exec_filter_survival",
            "Measured fraction of gradient blocks surviving the section-4.3 filter (last sweep)",
        );
        r.gauge_f(
            "exec_filter_survival_predicted",
            "BlockFilterModel-predicted block survival for the same shape",
        );
        r.counter("exec_filter_blocks_total", "Gradient blocks considered by the filter");
        r.counter("exec_filter_blocks_skipped_total", "Gradient blocks skipped by the filter");
        r.gauge("exec_pool_workers", "Live fork-join pool worker threads");
        r.counter("exec_pool_inline_total", "Pool runs served entirely on the inline fast path");
        r.counter("exec_pool_dispatch_total", "Pool runs fanned out to worker threads");
        r.gauge("exec_workspace_peak_bytes", "High-water mark of kernel workspace bytes");
        r.counter("train_steps_total", "Optimizer steps completed");
        r.gauge_f("train_step_loss", "Loss of the most recent train step");
        r.gauge_f("train_grad_norm", "Gradient norm of the most recent train step");
        r.gauge_f("train_tokens_per_sec", "Training throughput of the most recent step");
        r.gauge("shard_workers", "Vocabulary-shard workers attached to this process");
        r.histogram("shard_exchange_bytes", "Wire bytes per shard collective (requests + replies)");
        r.histogram("shard_exchange_us", "Wall time per shard collective, send through last reply");
        r.histogram("shard_step_us", "Wall time per sharded forward step collective");
        r.counter("shard_merges_total", "Coordinator merges of per-shard partial results");
        r.counter("shard_worker_errors_total", "Shard collectives failed by a worker error");
        r
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn handles_are_shared_by_name_and_render_both_formats() {
        let r = Registry::new();
        let c = r.counter("unit_requests_total", "requests");
        let c2 = r.counter("unit_requests_total", "requests");
        c.inc();
        c2.add(2);
        assert_eq!(c.get(), 3, "same-name handles must share storage");
        let g = r.gauge("unit_depth", "queue depth");
        g.add(5);
        g.sub(2);
        assert_eq!(g.get(), 3);
        g.set_max(10);
        g.set_max(7);
        assert_eq!(g.get(), 10, "set_max keeps the high-water mark");
        let f = r.gauge_f("unit_ratio", "a ratio");
        f.set(0.25);
        assert_eq!(f.get(), 0.25);
        let h = r.histogram("unit_latency_us", "latency");
        h.record(100);
        assert_eq!(r.family_count(), 4);

        let mut text = String::new();
        r.render_prometheus(&mut text);
        assert!(text.contains("# TYPE unit_requests_total counter"), "{text}");
        assert!(text.contains("unit_requests_total 3"), "{text}");
        assert!(text.contains("# TYPE unit_depth gauge"), "{text}");
        assert!(text.contains("# TYPE unit_latency_us histogram"), "{text}");
        assert!(text.contains("unit_latency_us_bucket{le=\"+Inf\"} 1"), "{text}");
        assert!(text.contains("unit_latency_us_count 1"), "{text}");

        let json = Json::Object(r.to_json_fields());
        assert_eq!(json.get("unit_requests_total").and_then(Json::as_i64), Some(3));
        assert_eq!(json.get("unit_depth").and_then(Json::as_i64), Some(10));
        let hist = json.get("unit_latency_us").expect("histogram field");
        assert_eq!(hist.get("count").and_then(Json::as_i64), Some(1));
    }

    #[test]
    fn global_registry_preregisters_exec_and_train_families() {
        let fields = global().to_json_fields();
        let names: Vec<&str> = fields.iter().map(|(k, _)| k.as_str()).collect();
        for want in [
            "exec_fwd_sweep_us",
            "exec_bwd_sweep_us",
            "exec_filter_survival",
            "exec_pool_workers",
            "exec_workspace_peak_bytes",
            "train_steps_total",
            "train_tokens_per_sec",
        ] {
            assert!(names.contains(&want), "missing pre-registered family {want}");
        }
        assert!(global().family_count() >= 12);
    }
}
