//! Lock-free fixed-boundary log-bucket histogram.
//!
//! Buckets are geometric with four per octave (ratio `2^(1/4) ≈ 1.19`),
//! spanning `[1, 2^26]` in the recorded unit (microseconds for every
//! latency family) plus one overflow bucket.  Recording is three relaxed
//! `AtomicU64` operations — count, sum, one bucket — so concurrent
//! recorders never contend on a lock and totals are exact (atomic adds
//! commute).  Percentiles are reconstructed from the bucket counts: the
//! estimate is the geometric midpoint of the bucket holding the target
//! rank, so its error is bounded by half a bucket width (`2^(1/8) ≈ 9%`
//! either way) — pinned by the tests below against sorted references.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::json::Json;

/// Bucket resolution: four buckets per factor of two.
pub const BUCKETS_PER_OCTAVE: u32 = 4;
/// Octaves covered by finite buckets: `[1, 2^26]` (~67 s in µs).
const OCTAVES: u32 = 26;
/// Finite buckets plus the overflow bucket.
pub const BUCKET_COUNT: usize = (OCTAVES * BUCKETS_PER_OCTAVE) as usize + 1;

/// Upper bound of finite bucket `i`: `2^((i+1)/4)`.
pub fn bucket_bound(i: usize) -> f64 {
    2f64.powf((i as f64 + 1.0) / BUCKETS_PER_OCTAVE as f64)
}

/// Index of the bucket whose `(lower, upper]` range holds `value`.
/// `log2` of an exact power of two is exact in f64, so boundary values
/// land deterministically; everything past the last finite bound goes to
/// the overflow bucket.
fn bucket_index(value: u64) -> usize {
    if value <= 1 {
        return 0;
    }
    let i = ((value as f64).log2() * BUCKETS_PER_OCTAVE as f64).ceil() as usize;
    i.saturating_sub(1).min(BUCKET_COUNT - 1)
}

/// A named histogram family registered in a [`crate::obs::Registry`].
pub struct Histogram {
    name: String,
    help: String,
    count: AtomicU64,
    sum: AtomicU64,
    buckets: [AtomicU64; BUCKET_COUNT],
}

impl Histogram {
    pub(crate) fn new(name: &str, help: &str) -> Histogram {
        Histogram {
            name: name.to_string(),
            help: help.to_string(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Record one observation (three relaxed atomics).
    pub fn record(&self, value: u64) {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(value, Ordering::Relaxed);
        self.buckets[bucket_index(value)].fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    fn snapshot(&self) -> [u64; BUCKET_COUNT] {
        std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed))
    }

    /// Representative value reported for bucket `i`: the geometric
    /// midpoint of its range (the lower edge for the overflow bucket,
    /// since its range is unbounded above).
    fn representative(i: usize) -> f64 {
        if i == 0 {
            1.0
        } else if i == BUCKET_COUNT - 1 {
            bucket_bound(BUCKET_COUNT - 2)
        } else {
            (bucket_bound(i - 1) * bucket_bound(i)).sqrt()
        }
    }

    /// Reconstruct the `q`-quantile (`0 < q <= 1`) from the bucket counts.
    /// Returns 0 for an empty histogram.  The estimate is within half a
    /// bucket (`2^(1/8)`) of the true sample quantile at the same rank.
    pub fn quantile(&self, q: f64) -> f64 {
        let counts = self.snapshot();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let rank = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut seen = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Histogram::representative(i);
            }
        }
        Histogram::representative(BUCKET_COUNT - 1)
    }

    /// Prometheus text exposition: cumulative `_bucket{le=...}` lines for
    /// every non-empty bucket (plus the mandatory `+Inf`), then `_sum` and
    /// `_count`.
    pub(crate) fn render_prometheus(&self, out: &mut String) {
        use std::fmt::Write as _;
        let counts = self.snapshot();
        let _ = writeln!(out, "# HELP {} {}", self.name, self.help);
        let _ = writeln!(out, "# TYPE {} histogram", self.name);
        let mut cumulative = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            cumulative += c;
            if c > 0 && i < BUCKET_COUNT - 1 {
                let _ = writeln!(
                    out,
                    "{}_bucket{{le=\"{:.3}\"}} {cumulative}",
                    self.name,
                    bucket_bound(i)
                );
            }
        }
        let _ = writeln!(out, "{}_bucket{{le=\"+Inf\"}} {cumulative}", self.name);
        let _ = writeln!(out, "{}_sum {}", self.name, self.sum());
        let _ = writeln!(out, "{}_count {}", self.name, self.count());
    }

    /// JSON summary: exact count/sum plus reconstructed p50/p90/p99.
    pub(crate) fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::Int(self.count() as i64)),
            ("sum", Json::Int(self.sum() as i64)),
            ("p50", Json::Float(self.quantile(0.50))),
            ("p90", Json::Float(self.quantile(0.90))),
            ("p99", Json::Float(self.quantile(0.99))),
        ])
    }
}

impl std::fmt::Debug for Histogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Histogram")
            .field("name", &self.name)
            .field("count", &self.count())
            .field("sum", &self.sum())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Max ratio between the reconstructed quantile and the sorted-sample
    /// reference: half a bucket either way, plus float slack.
    const HALF_BUCKET: f64 = 1.0905077327; // 2^(1/8)
    const SLACK: f64 = 1.0001;

    fn reference_quantile(sorted: &[u64], q: f64) -> f64 {
        let rank = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
        sorted[rank - 1] as f64
    }

    fn check_against_reference(values: &[u64], label: &str) {
        let h = Histogram::new("test_us", "test");
        for &v in values {
            h.record(v);
        }
        let mut sorted = values.to_vec();
        sorted.sort_unstable();
        for q in [0.50, 0.90, 0.99] {
            let est = h.quantile(q);
            let want = reference_quantile(&sorted, q);
            let ratio = est / want;
            assert!(
                (1.0 / (HALF_BUCKET * SLACK)..=HALF_BUCKET * SLACK).contains(&ratio),
                "{label} p{:.0}: estimate {est:.2} vs reference {want:.2} \
                 (ratio {ratio:.4} breaks the half-bucket bound)",
                q * 100.0
            );
        }
        assert_eq!(h.count(), values.len() as u64);
        assert_eq!(h.sum(), values.iter().sum::<u64>());
    }

    #[test]
    fn percentiles_track_sorted_reference_on_known_distributions() {
        let mut rng = Rng::new(0x0B5);
        // Uniform latencies in [2, 100_000] µs.
        let uniform: Vec<u64> = (0..5000).map(|_| 2 + rng.usize_below(99_999) as u64).collect();
        check_against_reference(&uniform, "uniform");
        // Log-uniform (heavy-tailed, like real service times): 2^u for
        // u uniform in [1, 20).
        let loguni: Vec<u64> = (0..5000)
            .map(|_| 2f64.powf(1.0 + rng.f64() * 19.0) as u64)
            .collect();
        check_against_reference(&loguni, "log-uniform");
        // Bimodal: fast path ~30 µs, slow path ~40 ms.
        let bimodal: Vec<u64> = (0..5000)
            .map(|_| if rng.bool(0.8) { 25 + rng.usize_below(10) as u64 } else { 40_000 })
            .collect();
        check_against_reference(&bimodal, "bimodal");
    }

    #[test]
    fn concurrent_recording_keeps_exact_totals() {
        let h = Histogram::new("test_concurrent_us", "test");
        const THREADS: u64 = 4;
        const PER_THREAD: u64 = 10_000;
        std::thread::scope(|scope| {
            for t in 0..THREADS {
                let h = &h;
                scope.spawn(move || {
                    for i in 0..PER_THREAD {
                        // Deterministic per-thread value stream.
                        h.record(1 + (t * PER_THREAD + i) % 5000);
                    }
                });
            }
        });
        assert_eq!(h.count(), THREADS * PER_THREAD);
        // Exact sum: every (t, i) value summed sequentially.
        let want: u64 = (0..THREADS)
            .flat_map(|t| (0..PER_THREAD).map(move |i| 1 + (t * PER_THREAD + i) % 5000))
            .sum();
        assert_eq!(h.sum(), want, "concurrent adds must commute exactly");
        // Bucket totals equal a single-threaded replay.
        let replay = Histogram::new("test_replay_us", "test");
        for t in 0..THREADS {
            for i in 0..PER_THREAD {
                replay.record(1 + (t * PER_THREAD + i) % 5000);
            }
        }
        assert_eq!(h.snapshot(), replay.snapshot());
    }

    #[test]
    fn overflow_bucket_catches_out_of_range_values() {
        let h = Histogram::new("test_overflow_us", "test");
        h.record(10); // one in-range value
        let huge = 1_000_000_000_000u64; // ~11.5 days in µs, far past 2^26
        h.record(huge);
        h.record(u64::MAX / 2);
        assert_eq!(h.count(), 3);
        assert_eq!(h.sum(), 10 + huge + u64::MAX / 2);
        // The top quantile reports the overflow bucket's lower edge — the
        // last finite bound — not garbage or infinity.
        let top = h.quantile(1.0);
        assert!(top.is_finite());
        assert!((top - bucket_bound(BUCKET_COUNT - 2)).abs() < 1e-6, "{top}");
        // The +Inf cumulative line covers all three observations.
        let mut text = String::new();
        h.render_prometheus(&mut text);
        assert!(text.contains("test_overflow_us_bucket{le=\"+Inf\"} 3"), "{text}");
    }

    #[test]
    fn bucket_index_is_monotone_and_boundary_exact() {
        let mut last = 0;
        for v in 1..10_000u64 {
            let i = bucket_index(v);
            assert!(i >= last, "index must be monotone in the value");
            assert!(v as f64 <= bucket_bound(i) + 1e-9, "value {v} above its bucket bound");
            if i > 0 {
                assert!(v as f64 > bucket_bound(i - 1) - 1e-9, "value {v} below its bucket");
            }
            last = i;
        }
        // Exact powers of two land on their boundary bucket.
        assert_eq!(bucket_index(2), (BUCKETS_PER_OCTAVE - 1) as usize);
        assert_eq!(bucket_index(4), (2 * BUCKETS_PER_OCTAVE - 1) as usize);
    }
}
