//! Run configuration: JSON config files + CLI overrides.
//!
//! A run is fully described by a small JSON document (see `configs/*.json`),
//! so experiments are launch-by-config like any production trainer:
//!
//! ```json
//! {
//!   "tag": "e2e", "method": "cce", "steps": 300, "seed": 0,
//!   "corpus": {"kind": "web", "docs": 2000},
//!   "eval_every": 50, "checkpoint_every": 100, "out_dir": "runs/demo"
//! }
//! ```

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::cli::Args;
use crate::util::json::Json;

/// Which synthetic corpus a run trains on.
#[derive(Debug, Clone, PartialEq)]
pub enum CorpusKind {
    /// OpenWebText analogue (packed pretraining).
    Web,
    /// Alpaca analogue (padded fine-tuning with masked prompts).
    Instruct,
}

#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Model artifact tag (`e2e`, `tiny`, ... from the manifest).
    pub tag: String,
    /// Loss method (must have a `{tag}_train_step_{method}` artifact).
    pub method: String,
    pub steps: u64,
    pub seed: u64,
    pub corpus: CorpusKind,
    pub corpus_docs: usize,
    pub vocab_size: usize,
    pub eval_every: u64,
    pub checkpoint_every: u64,
    pub log_every: u64,
    pub out_dir: String,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            tag: "e2e".into(),
            method: "cce".into(),
            steps: 300,
            seed: 0,
            corpus: CorpusKind::Web,
            corpus_docs: 4000,
            vocab_size: 4096,
            eval_every: 50,
            checkpoint_every: 0,
            log_every: 10,
            out_dir: "runs/default".into(),
        }
    }
}

impl RunConfig {
    pub fn from_json(json: &Json) -> Result<RunConfig> {
        let mut cfg = RunConfig::default();
        let gets = |k: &str, d: &str| -> String {
            json.get(k).and_then(|v| v.as_str()).unwrap_or(d).to_string()
        };
        let geti = |k: &str, d: i64| -> i64 {
            json.get(k).and_then(|v| v.as_i64()).unwrap_or(d)
        };
        cfg.tag = gets("tag", &cfg.tag);
        cfg.method = gets("method", &cfg.method);
        cfg.steps = geti("steps", cfg.steps as i64) as u64;
        cfg.seed = geti("seed", cfg.seed as i64) as u64;
        cfg.eval_every = geti("eval_every", cfg.eval_every as i64) as u64;
        cfg.checkpoint_every =
            geti("checkpoint_every", cfg.checkpoint_every as i64) as u64;
        cfg.log_every = geti("log_every", cfg.log_every as i64) as u64;
        cfg.out_dir = gets("out_dir", &cfg.out_dir);
        cfg.vocab_size = geti("vocab_size", cfg.vocab_size as i64) as usize;
        if let Some(corpus) = json.get("corpus") {
            cfg.corpus_docs = corpus
                .get("docs")
                .and_then(|v| v.as_i64())
                .unwrap_or(cfg.corpus_docs as i64) as usize;
            cfg.corpus = match corpus.get("kind").and_then(|v| v.as_str()) {
                Some("instruct") => CorpusKind::Instruct,
                Some("web") | None => CorpusKind::Web,
                Some(other) => return Err(anyhow!("unknown corpus kind {other:?}")),
            };
        }
        Ok(cfg)
    }

    pub fn load(path: impl AsRef<Path>) -> Result<RunConfig> {
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading config {:?}", path.as_ref()))?;
        Self::from_json(&Json::parse(&text)?)
    }

    /// Apply `--key value` CLI overrides on top of the config file.
    pub fn apply_args(&mut self, args: &Args) -> Result<()> {
        if let Some(v) = args.opt("tag") {
            self.tag = v.into();
        }
        if let Some(v) = args.opt("method") {
            self.method = v.into();
        }
        self.steps = args.get("steps", self.steps)?;
        self.seed = args.get("seed", self.seed)?;
        self.eval_every = args.get("eval-every", self.eval_every)?;
        self.checkpoint_every = args.get("checkpoint-every", self.checkpoint_every)?;
        self.log_every = args.get("log-every", self.log_every)?;
        self.corpus_docs = args.get("corpus-docs", self.corpus_docs)?;
        if let Some(v) = args.opt("out-dir") {
            self.out_dir = v.into();
        }
        if let Some(v) = args.opt("corpus") {
            self.corpus = match v {
                "web" => CorpusKind::Web,
                "instruct" => CorpusKind::Instruct,
                other => return Err(anyhow!("unknown corpus {other:?}")),
            };
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("tag", Json::str(&self.tag)),
            ("method", Json::str(&self.method)),
            ("steps", Json::Int(self.steps as i64)),
            ("seed", Json::Int(self.seed as i64)),
            (
                "corpus",
                Json::obj(vec![
                    (
                        "kind",
                        Json::str(match self.corpus {
                            CorpusKind::Web => "web",
                            CorpusKind::Instruct => "instruct",
                        }),
                    ),
                    ("docs", Json::Int(self.corpus_docs as i64)),
                ]),
            ),
            ("vocab_size", Json::Int(self.vocab_size as i64)),
            ("eval_every", Json::Int(self.eval_every as i64)),
            ("checkpoint_every", Json::Int(self.checkpoint_every as i64)),
            ("log_every", Json::Int(self.log_every as i64)),
            ("out_dir", Json::str(&self.out_dir)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let cfg = RunConfig {
            method: "cce_kahan_fullc".into(),
            corpus: CorpusKind::Instruct,
            steps: 77,
            ..Default::default()
        };
        let cfg2 = RunConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(cfg2.method, "cce_kahan_fullc");
        assert_eq!(cfg2.steps, 77);
        assert_eq!(cfg2.corpus, CorpusKind::Instruct);
    }

    #[test]
    fn cli_overrides() {
        let mut cfg = RunConfig::default();
        let args = Args::parse(
            ["--steps", "5", "--method", "baseline", "--corpus", "instruct"]
                .iter()
                .map(|s| s.to_string()),
            &[],
        )
        .unwrap();
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.steps, 5);
        assert_eq!(cfg.method, "baseline");
        assert_eq!(cfg.corpus, CorpusKind::Instruct);
    }

    #[test]
    fn defaults_for_missing_keys() {
        let cfg = RunConfig::from_json(&Json::parse(r#"{"steps": 9}"#).unwrap()).unwrap();
        assert_eq!(cfg.steps, 9);
        assert_eq!(cfg.tag, "e2e");
    }

    #[test]
    fn bad_corpus_rejected() {
        assert!(RunConfig::from_json(
            &Json::parse(r#"{"corpus": {"kind": "bogus"}}"#).unwrap()
        )
        .is_err());
    }
}
