//! Native end-to-end trainer: the full coordinator pipeline (corpus → BPE →
//! packed dataset → step batches → metrics → checkpoints) driving the
//! native CCE kernels — zero artifacts, zero shared libraries.
//!
//! The model is a bag-of-context classifier head: position `i` predicts the
//! next token from the mean of the last `window` token embeddings,
//!
//! ```text
//! h_i = mean(emb[t_{i-w+1}], ..., emb[t_i])      logits_i = h_i · clsᵀ
//! ```
//!
//! which is exactly the workload the paper's loss layer sees (an `(N, D)`
//! activation against a `(V, D)` classifier), with the loss + gradients
//! computed by any [`Backend`] method (`--method cce|baseline|...`).  The
//! trainer exists to exercise the hot path end-to-end and to measure the
//! loss-method ablations on a real training loop, not to be a transformer:
//! the transformer lives in the AOT artifacts behind the `pjrt` feature.
//! The bag reduction, the dH scatter, and the SGD update all run on the
//! same SIMD layer as the kernels (`crate::exec::simd`, dispatch resolved
//! once per step) and the same persistent fork-join pool
//! (`crate::exec::pool`); `--method` accepts every native key, including
//! the `cce_kahan*` variants.

use anyhow::{anyhow, bail, Result};

use crate::coordinator::checkpoint::Checkpoint;
use crate::coordinator::config::{CorpusKind, RunConfig};
use crate::coordinator::metrics::Metrics;
use crate::data::{instruct_corpus, web_corpus, Dataset, DatasetConfig, StepBatch};
use crate::exec::simd::{self, Lanes};
use crate::exec::{pool, Backend, BackwardOut, KernelOptions, NativeBackend, Problem};
use crate::runtime::HostTensor;
use crate::tokenizer::{Tokenizer, TokenizerConfig};
use crate::util::rng::Rng;

/// Model hyperparameters for the native trainer.
#[derive(Debug, Clone, Copy)]
pub struct NativeModelConfig {
    /// Embedding / classifier width.
    pub d_model: usize,
    /// Bag-of-context window (tokens averaged into each hidden state).
    pub window: usize,
    /// SGD learning rate.
    pub lr: f32,
    /// Sequences per optimizer step.
    pub batch: usize,
    /// Tokens per sequence.
    pub seq_len: usize,
}

impl Default for NativeModelConfig {
    fn default() -> NativeModelConfig {
        NativeModelConfig { d_model: 64, window: 8, lr: 0.5, batch: 8, seq_len: 128 }
    }
}

/// Mutable training state: embedding table + classifier + step counter.
pub struct NativeState {
    pub emb: Vec<f32>,
    pub cls: Vec<f32>,
    pub step: u64,
}

impl NativeState {
    pub fn param_count(&self) -> usize {
        self.emb.len() + self.cls.len()
    }

    /// Serialize as a [`Checkpoint`] (`emb`/`cls` tensors + step).
    pub fn to_checkpoint(&self, vocab: usize, d: usize) -> Result<Checkpoint> {
        Ok(Checkpoint {
            step: self.step,
            tensors: vec![
                ("emb".into(), HostTensor::f32(vec![vocab, d], self.emb.clone())?),
                ("cls".into(), HostTensor::f32(vec![vocab, d], self.cls.clone())?),
            ],
        })
    }

    /// Load a state plus its sibling tokenizer (`<path>.vocab.json`) and
    /// model hyperparameters (`<path>.model.json`), as written by
    /// [`NativeTrainer::save_checkpoint`].  `(vocab, d)` come from the
    /// checkpoint's own tensor shapes — the serving path needs no run
    /// config to open a trained model.  `window` is `None` for pre-PR-2
    /// checkpoints without the model sidecar.
    pub fn load_bundle(path: &std::path::Path) -> Result<NativeBundle> {
        let ckpt = Checkpoint::load(path)?;
        let (vocab, d_model) = ckpt
            .tensors
            .iter()
            .find(|(name, t)| name == "emb" && t.shape.len() == 2)
            .map(|(_, t)| (t.shape[0], t.shape[1]))
            .ok_or_else(|| anyhow!("checkpoint {path:?} has no rank-2 emb tensor"))?;
        let state = NativeState::from_checkpoint(ckpt, vocab, d_model)?;
        let tokenizer = Tokenizer::load(path.with_extension("vocab.json"))?;
        if tokenizer.vocab_size() != vocab {
            bail!(
                "tokenizer vocab {} does not match checkpoint vocab {vocab}",
                tokenizer.vocab_size()
            );
        }
        let (window, seq_len) = match std::fs::read_to_string(path.with_extension("model.json")) {
            Err(_) => (None, None), // older checkpoint without the sidecar
            Ok(text) => {
                let meta = crate::util::Json::parse(&text)?;
                let field = |key: &str| meta.get(key).and_then(|v| v.as_i64()).map(|x| x as usize);
                (field("window"), field("seq_len"))
            }
        };
        Ok(NativeBundle { state, tokenizer, vocab, d_model, window, seq_len })
    }

    pub fn from_checkpoint(ckpt: Checkpoint, vocab: usize, d: usize) -> Result<NativeState> {
        let mut emb = None;
        let mut cls = None;
        for (name, t) in ckpt.tensors {
            if t.shape != vec![vocab, d] {
                bail!("checkpoint tensor {name:?} has shape {:?}, want [{vocab}, {d}]", t.shape);
            }
            match name.as_str() {
                "emb" => emb = Some(t.as_f32()?.to_vec()),
                "cls" => cls = Some(t.as_f32()?.to_vec()),
                other => bail!("unexpected checkpoint tensor {other:?}"),
            }
        }
        Ok(NativeState {
            emb: emb.ok_or_else(|| anyhow!("checkpoint missing emb"))?,
            cls: cls.ok_or_else(|| anyhow!("checkpoint missing cls"))?,
            step: ckpt.step,
        })
    }
}

/// Everything a serving/measurement path needs from a saved native run:
/// the weights, the tokenizer, the shape inferred from the tensors, and
/// (when the `.model.json` sidecar exists) the training context window.
pub struct NativeBundle {
    pub state: NativeState,
    pub tokenizer: Tokenizer,
    pub vocab: usize,
    pub d_model: usize,
    pub window: Option<usize>,
    pub seq_len: Option<usize>,
}

/// Bag-of-context hidden states for packed sequences: position `i`
/// averages the embeddings of the last `window` tokens within its
/// `seq_len`-aligned sequence.  Shared by the trainer, the fig3 native
/// harness, and (per-context, without the sequence resets) the serving
/// engine's decode path.
///
/// `threads` sizes the fork-join spans (`0` = auto); positions are
/// independent and spans align to sequence boundaries, so the result is
/// bitwise identical for every thread count.
pub fn bag_hidden(
    tokens: &[i32],
    emb: &[f32],
    d: usize,
    window: usize,
    seq_len: usize,
    threads: usize,
) -> Vec<f32> {
    simd::with_lanes!(lanes => bag_hidden_with(tokens, emb, d, window, seq_len, threads, lanes))
}

fn bag_hidden_with<L: Lanes>(
    tokens: &[i32],
    emb: &[f32],
    d: usize,
    window: usize,
    seq_len: usize,
    threads: usize,
    lanes: L,
) -> Vec<f32> {
    let w = window.max(1);
    let seq = seq_len.max(1);
    let n = tokens.len();
    let mut h = vec![0f32; n * d];
    // Whole sequences per span: a position's window never crosses its own
    // sequence, so each span reads only its own token slice.
    let seqs = crate::exec::ceil_div(n, seq);
    let span_seqs = crate::exec::ceil_div(seqs, crate::exec::resolve_threads(threads)).max(1);
    let tasks: Vec<_> = h
        .chunks_mut(span_seqs * seq * d)
        .enumerate()
        .map(|(ti, h_chunk)| {
            let pos0 = ti * span_seqs * seq;
            move || {
                for (r, chunk) in h_chunk.chunks_mut(d).enumerate() {
                    let i = pos0 + r;
                    let q = i % seq;
                    let lo = i - q.min(w - 1);
                    let len = (i - lo + 1) as f32;
                    for &tok in &tokens[lo..=i] {
                        let row = &emb[tok as usize * d..(tok as usize + 1) * d];
                        lanes.add_assign(chunk, row);
                    }
                    lanes.scale(chunk, 1.0 / len);
                }
            }
        })
        .collect();
    pool::global().run(tasks);
    h
}

/// A ready-to-train native bundle: data + tokenizer + kernel backend.
pub struct NativeTrainer {
    pub cfg: RunConfig,
    pub model: NativeModelConfig,
    pub tokenizer: Tokenizer,
    pub dataset: Dataset,
    pub backend: NativeBackend,
    pub vocab: usize,
}

impl NativeTrainer {
    /// Build the pipeline: generate the corpus, train the BPE vocabulary,
    /// pack the dataset, and resolve `cfg.method` to a native backend.
    pub fn build(
        cfg: RunConfig,
        model: NativeModelConfig,
        opts: KernelOptions,
    ) -> Result<NativeTrainer> {
        let backend = NativeBackend::from_key(&cfg.method, opts)
            .map_err(|e| anyhow!("--method {:?} on the native backend: {e:#}", cfg.method))?;
        let docs = match cfg.corpus {
            CorpusKind::Web => web_corpus(cfg.corpus_docs, cfg.seed),
            CorpusKind::Instruct => instruct_corpus(cfg.corpus_docs, cfg.seed),
        };
        let texts: Vec<String> = docs.iter().map(|d| d.text.clone()).collect();
        let tokenizer = Tokenizer::train(&texts, &TokenizerConfig {
            vocab_size: cfg.vocab_size,
            min_pair_freq: 2,
        })?;
        let dataset = Dataset::build(&docs, &tokenizer, &DatasetConfig {
            seq_len: model.seq_len,
            val_fraction: 0.02,
            seed: cfg.seed,
            pad_per_doc: cfg.corpus == CorpusKind::Instruct,
        })?;
        let vocab = tokenizer.vocab_size();
        Ok(NativeTrainer { cfg, model, tokenizer, dataset, backend, vocab })
    }

    /// Fresh state: small random embeddings, near-zero classifier (uniform
    /// initial softmax => initial loss ≈ ln |V|).
    pub fn init(&self, seed: u64) -> NativeState {
        let d = self.model.d_model;
        let mut rng = Rng::new(seed ^ 0xCCE_5EED);
        let emb = (0..self.vocab * d).map(|_| (rng.normal() * 0.5) as f32).collect();
        let cls = (0..self.vocab * d).map(|_| (rng.normal() * 0.01) as f32).collect();
        NativeState { emb, cls, step: 0 }
    }

    pub fn tokens_per_step(&self) -> u64 {
        (self.model.batch * self.model.seq_len) as u64
    }

    /// Hidden states for a flat token buffer of `rows` sequences.  Public
    /// so measurement harnesses (`fig3 --backend native`) can probe the
    /// model head directly.
    pub fn hidden(&self, tokens: &[i32], state: &NativeState) -> Vec<f32> {
        bag_hidden(
            tokens,
            &state.emb,
            self.model.d_model,
            self.model.window,
            self.model.seq_len,
            self.backend.opts.threads,
        )
    }

    /// One SGD step on a batch; returns `(loss, grad_norm)`.
    pub fn step(&self, state: &mut NativeState, batch: &StepBatch) -> Result<(f64, f64)> {
        let tokens = batch.tokens.as_i32()?;
        let targets = batch.targets.as_i32()?;
        let h = self.hidden(tokens, state);
        let n = tokens.len();
        let problem = Problem::new(&h, &state.cls, targets, n, self.model.d_model, self.vocab)?;
        let (fwd, bwd) = self.backend.forward_backward(&problem)?;
        let grad_norm = simd::with_lanes!(lanes => self.apply_update(state, tokens, &bwd, lanes));
        state.step += 1;
        Ok((fwd.loss, grad_norm))
    }

    /// Scatter `dH` through the bag-of-context mean into `dEmb`, then apply
    /// the SGD update — both on the fork-join pool with a resolved SIMD
    /// token.  The scatter is **token-span parallel**: a sequential
    /// pre-pass buckets window visits per contiguous embedding-row span
    /// (in ascending position order), and each task drains only its own
    /// bucket — so each `dEmb` row receives its contributions in exactly
    /// the sequential order and the result is bitwise invariant in the
    /// thread count (same argument as the backward's column-parallel
    /// `dC`).  The SGD `axpy` is elementwise; its chunk boundaries are
    /// rounded to the SIMD lane width so every element keeps the same
    /// FMA-body/scalar-tail role as in the single-chunk sweep — bitwise
    /// neutral too.  Returns the gradient norm.
    fn apply_update<L: Lanes>(
        &self,
        state: &mut NativeState,
        tokens: &[i32],
        bwd: &BackwardOut,
        lanes: L,
    ) -> f64 {
        let d = self.model.d_model;
        let w = self.model.window.max(1);
        let seq = self.model.seq_len.max(1);
        let n = tokens.len();
        let threads = self.backend.opts.resolved_threads();
        let mut d_emb = vec![0f32; state.emb.len()];
        let span_rows = crate::exec::ceil_div(self.vocab, threads).max(1);
        let n_spans = crate::exec::ceil_div(self.vocab, span_rows);
        // One sequential O(n·window) pre-pass buckets `(token, position,
        // 1/len)` visits per owning token span, so total scan work stays
        // O(n·window) no matter the thread count (a per-task rescan would
        // grow linearly with it).  Bucket order is the sequential visiting
        // order, so every dEmb row still accumulates in exactly the
        // single-threaded order — bitwise thread-invariant.
        let mut buckets: Vec<Vec<(u32, u32, f32)>> = vec![Vec::new(); n_spans];
        for i in 0..n {
            let q = i % seq;
            let lo = i - q.min(w - 1);
            let inv_len = 1.0 / (i - lo + 1) as f32;
            for &tok in &tokens[lo..=i] {
                let t = tok as usize;
                buckets[t / span_rows].push((t as u32, i as u32, inv_len));
            }
        }
        let tasks: Vec<_> = d_emb
            .chunks_mut(span_rows * d)
            .zip(&buckets)
            .enumerate()
            .map(|(ti, (chunk, bucket))| {
                let tok0 = ti * span_rows;
                move || {
                    for &(t, i, inv_len) in bucket {
                        let (t, i) = (t as usize, i as usize);
                        let dh_row = &bwd.d_e[i * d..(i + 1) * d];
                        let row = &mut chunk[(t - tok0) * d..(t - tok0 + 1) * d];
                        lanes.axpy(row, inv_len, dh_row);
                    }
                }
            })
            .collect();
        pool::global().run(tasks);
        let sq: f64 = bwd.d_c.iter().chain(d_emb.iter()).map(|&g| (g as f64) * g as f64).sum();
        let lr = self.model.lr;
        for (params, grads) in [
            (&mut state.cls[..], &bwd.d_c[..]),
            (&mut state.emb[..], &d_emb[..]),
        ] {
            // Lane-aligned spans (multiples of 8): an 8-aligned boundary
            // keeps the AVX2 axpy's vector-body vs scalar-tail split — and
            // therefore the FMA rounding of every element — identical to
            // the unchunked sweep, for any thread count.
            let per = crate::exec::ceil_div(params.len(), threads).max(1);
            let span = crate::exec::ceil_div(per, 8) * 8;
            let tasks: Vec<_> = params
                .chunks_mut(span)
                .zip(grads.chunks(span))
                .map(|(pc, gc)| move || lanes.axpy(pc, -lr, gc))
                .collect();
            pool::global().run(tasks);
        }
        sq.sqrt()
    }

    /// Mean validation NLL over all validation batches.
    pub fn evaluate(&self, state: &NativeState) -> Result<f64> {
        let batches = self.dataset.val_batches(self.model.batch);
        if batches.is_empty() {
            bail!("validation set smaller than one batch");
        }
        let (mut loss_sum, mut count) = (0.0f64, 0usize);
        for b in &batches {
            let h = self.hidden(b.tokens.as_i32()?, state);
            let targets = b.targets.as_i32()?;
            let problem =
                Problem::new(&h, &state.cls, targets, targets.len(), self.model.d_model, self.vocab)?;
            let fwd = self.backend.forward(&problem)?;
            loss_sum += fwd.loss * fwd.count as f64;
            count += fwd.count;
        }
        Ok(loss_sum / count.max(1) as f64)
    }

    /// Run the training loop for `cfg.steps` optimizer steps.
    pub fn train(&self, mut state: NativeState, metrics: &mut Metrics) -> Result<NativeState> {
        let mut done = state.step;
        let mut epoch: u64 = 0;
        'outer: loop {
            let mut saw_batch = false;
            for batch in self.dataset.step_batches(1, self.model.batch, epoch) {
                saw_batch = true;
                let (loss, gnorm) = self.step(&mut state, &batch)?;
                done += 1;
                metrics.log_step(done, loss, gnorm, self.tokens_per_step());
                if done % self.cfg.log_every.max(1) == 0 || done == 1 {
                    eprintln!(
                        "[train native/{}] step {done}/{} loss {loss:.4} gnorm {gnorm:.3} ({:.0} tok/s)",
                        self.cfg.method,
                        self.cfg.steps,
                        metrics.steps.last().map(|r| r.tokens_per_sec).unwrap_or(0.0)
                    );
                }
                if self.cfg.eval_every > 0 && done % self.cfg.eval_every == 0 {
                    let val = self.evaluate(&state)?;
                    metrics.log_eval(done, val);
                    eprintln!(
                        "[eval  native/{}] step {done} val_loss {val:.4} ppl {:.2}",
                        self.cfg.method,
                        val.exp()
                    );
                }
                if done >= self.cfg.steps {
                    break 'outer;
                }
            }
            if !saw_batch {
                return Err(anyhow!(
                    "dataset too small: no step batches (need {} sequences/step)",
                    self.model.batch
                ));
            }
            epoch += 1;
        }
        Ok(state)
    }

    /// Save checkpoint + tokenizer vocabulary + model hyperparameters
    /// (`.model.json` sidecar, so serving needs no training flags).
    pub fn save_checkpoint(&self, state: &NativeState, path: &std::path::Path) -> Result<()> {
        state.to_checkpoint(self.vocab, self.model.d_model)?.save(path)?;
        self.tokenizer.save(path.with_extension("vocab.json"))?;
        let meta = crate::util::Json::obj(vec![
            ("d_model", crate::util::Json::Int(self.model.d_model as i64)),
            ("window", crate::util::Json::Int(self.model.window as i64)),
            ("seq_len", crate::util::Json::Int(self.model.seq_len as i64)),
            ("vocab", crate::util::Json::Int(self.vocab as i64)),
        ]);
        std::fs::write(path.with_extension("model.json"), meta.to_string_pretty())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg(method: &str, steps: u64) -> RunConfig {
        RunConfig {
            tag: "native".into(),
            method: method.into(),
            steps,
            seed: 7,
            corpus: CorpusKind::Web,
            corpus_docs: 200,
            vocab_size: 512,
            eval_every: 0,
            checkpoint_every: 0,
            log_every: u64::MAX,
            out_dir: std::env::temp_dir().join("cce_native_it").to_string_lossy().into(),
        }
    }

    fn tiny_model() -> NativeModelConfig {
        NativeModelConfig { d_model: 32, window: 4, lr: 0.5, batch: 4, seq_len: 64 }
    }

    fn fast_opts() -> KernelOptions {
        KernelOptions { n_block: 32, v_block: 128, threads: 2, ..KernelOptions::default() }
    }

    #[test]
    fn native_training_reduces_loss() {
        let trainer = NativeTrainer::build(tiny_cfg("cce", 30), tiny_model(), fast_opts()).unwrap();
        let state = trainer.init(7);
        let mut metrics = Metrics::in_memory();
        let state = trainer.train(state, &mut metrics).unwrap();
        assert_eq!(state.step, 30);
        assert_eq!(metrics.steps.len(), 30);
        let first = metrics.steps[0].loss;
        let last = metrics.steps.last().unwrap().loss;
        // Initial loss ≈ ln|V|; the bag-of-context model learns at least
        // the unigram structure within 30 SGD steps.
        assert!((first - (trainer.vocab as f64).ln()).abs() < 0.5, "first {first}");
        assert!(last < first - 0.1, "loss did not decrease: {first:.4} -> {last:.4}");
        let val = trainer.evaluate(&state).unwrap();
        assert!(val.is_finite() && val > 0.0);
    }

    #[test]
    fn cce_and_baseline_native_curves_match() {
        // The Fig. 4 claim on the native path: same seed + same data =>
        // same curve whether the head is CCE or the materializing baseline.
        let run = |method: &str| {
            let trainer =
                NativeTrainer::build(tiny_cfg(method, 8), tiny_model(), fast_opts()).unwrap();
            let state = trainer.init(7);
            let mut metrics = Metrics::in_memory();
            trainer.train(state, &mut metrics).unwrap();
            metrics
        };
        let cce = run("cce");
        let base = run("baseline");
        let div = crate::coordinator::curve_max_divergence(&cce.steps, &base.steps);
        let scale = cce.steps[0].loss;
        assert!(div < 0.01 * scale, "curves diverged: {div:.4e} (scale {scale:.3})");
    }

    #[test]
    fn checkpoint_roundtrip() {
        let trainer = NativeTrainer::build(tiny_cfg("cce", 2), tiny_model(), fast_opts()).unwrap();
        let state = trainer.init(1);
        let mut metrics = Metrics::in_memory();
        let state = trainer.train(state, &mut metrics).unwrap();
        let path = std::env::temp_dir().join("cce_native_ckpt.bin");
        trainer.save_checkpoint(&state, &path).unwrap();
        let restored = NativeState::from_checkpoint(
            Checkpoint::load(&path).unwrap(),
            trainer.vocab,
            trainer.model.d_model,
        )
        .unwrap();
        assert_eq!(restored.step, 2);
        assert_eq!(restored.emb, state.emb);
        let a = trainer.evaluate(&state).unwrap();
        let b = trainer.evaluate(&restored).unwrap();
        assert!((a - b).abs() < 1e-12);
    }

    #[test]
    fn load_bundle_infers_shape_and_loads_tokenizer() {
        let trainer = NativeTrainer::build(tiny_cfg("cce", 1), tiny_model(), fast_opts()).unwrap();
        let state = trainer.init(3);
        let path = std::env::temp_dir().join("cce_native_bundle.ckpt");
        trainer.save_checkpoint(&state, &path).unwrap();
        let bundle = NativeState::load_bundle(&path).unwrap();
        assert_eq!(bundle.vocab, trainer.vocab);
        assert_eq!(bundle.d_model, trainer.model.d_model);
        assert_eq!(bundle.window, Some(trainer.model.window));
        assert_eq!(bundle.seq_len, Some(trainer.model.seq_len));
        assert_eq!(bundle.tokenizer.vocab_size(), trainer.vocab);
        assert_eq!(bundle.state.emb, state.emb);
        assert_eq!(bundle.state.cls, state.cls);
        // A pre-sidecar checkpoint still loads, with unknown window.
        std::fs::remove_file(path.with_extension("model.json")).unwrap();
        let old = NativeState::load_bundle(&path).unwrap();
        assert_eq!(old.window, None);
        assert_eq!(old.state.emb, state.emb);
    }

    #[test]
    fn unknown_method_is_rejected() {
        let err = NativeTrainer::build(tiny_cfg("fused", 1), tiny_model(), fast_opts())
            .err()
            .expect("fused must be rejected natively");
        assert!(format!("{err:#}").contains("fused"), "{err:#}");
    }
}
