//! Native end-to-end trainer: the full coordinator pipeline (corpus → BPE →
//! packed dataset → step batches → metrics → checkpoints) driving the
//! native CCE kernels — zero artifacts, zero shared libraries.
//!
//! The model is a bag-of-context classifier head: position `i` predicts the
//! next token from the mean of the last `window` token embeddings,
//!
//! ```text
//! h_i = mean(emb[t_{i-w+1}], ..., emb[t_i])      logits_i = h_i · clsᵀ
//! ```
//!
//! which is exactly the workload the paper's loss layer sees (an `(N, D)`
//! activation against a `(V, D)` classifier), with the loss + gradients
//! computed by any [`crate::exec::Backend`] method (`--method
//! cce|baseline|...`).  The
//! trainer exists to exercise the hot path end-to-end and to measure the
//! loss-method ablations on a real training loop, not to be a transformer:
//! the transformer lives in the AOT artifacts behind the `pjrt` feature.
//!
//! **Storage dtype** (`--dtype f32|bf16`): the embedding table and the
//! classifier live in a dtype-tagged [`ParamBuf`]; with bf16 the kernels
//! read half-width parameters (widen-on-load), the per-step activations
//! are narrowed to bf16 (the mixed-precision setting the paper measures),
//! the gradients come back bf16, and the SGD update runs in f32 with one
//! RNE narrow on store.  The bag reduction, the dH scatter, and the SGD
//! update all run on the same SIMD layer as the kernels
//! (`crate::exec::simd`, dispatch resolved once per step) and the same
//! persistent fork-join pool (`crate::exec::pool`); `--method` accepts
//! every native key, including the `cce_kahan*` variants.

use anyhow::{anyhow, bail, Result};

use crate::coordinator::checkpoint::Checkpoint;
use crate::coordinator::config::{CorpusKind, RunConfig};
use crate::coordinator::metrics::Metrics;
use crate::data::{instruct_corpus, web_corpus, Dataset, DatasetConfig, StepBatch};
use crate::exec::simd::{self, Lanes};
use crate::exec::{
    pool, BackwardOut, KernelOptions, NativeBackend, ParamBuf, Problem, Store, StoreDtype,
};
use crate::runtime::{Data, HostTensor};
use crate::tokenizer::{Tokenizer, TokenizerConfig};
use crate::util::rng::Rng;

/// Model hyperparameters for the native trainer.
#[derive(Debug, Clone, Copy)]
pub struct NativeModelConfig {
    /// Embedding / classifier width.
    pub d_model: usize,
    /// Bag-of-context window (tokens averaged into each hidden state).
    pub window: usize,
    /// SGD learning rate.
    pub lr: f32,
    /// Sequences per optimizer step.
    pub batch: usize,
    /// Tokens per sequence.
    pub seq_len: usize,
}

impl Default for NativeModelConfig {
    fn default() -> NativeModelConfig {
        NativeModelConfig { d_model: 64, window: 8, lr: 0.5, batch: 8, seq_len: 128 }
    }
}

/// Mutable training state: embedding table + classifier (dtype-tagged
/// storage) + step counter.
pub struct NativeState {
    pub emb: ParamBuf,
    pub cls: ParamBuf,
    pub step: u64,
}

impl NativeState {
    pub fn param_count(&self) -> usize {
        self.emb.len() + self.cls.len()
    }

    /// Measured parameter footprint in bytes (half under bf16 storage).
    pub fn param_bytes(&self) -> usize {
        self.emb.size_bytes() + self.cls.size_bytes()
    }

    /// Storage dtype of the parameters (emb and cls always agree).
    pub fn dtype(&self) -> StoreDtype {
        self.emb.dtype()
    }

    /// Convert the whole state to `want` (no-op when already there) — the
    /// single conversion path train/eval/serve all share.
    pub fn into_dtype(self, want: StoreDtype) -> NativeState {
        if want == self.dtype() {
            self
        } else {
            NativeState {
                emb: self.emb.to_dtype(want),
                cls: self.cls.to_dtype(want),
                step: self.step,
            }
        }
    }

    /// Serialize as a [`Checkpoint`] (`emb`/`cls` tensors + step), in the
    /// state's storage dtype — a bf16 run writes half-size checkpoints.
    pub fn to_checkpoint(&self, vocab: usize, d: usize) -> Result<Checkpoint> {
        let tensor = |buf: &ParamBuf| -> Result<HostTensor> {
            match buf {
                ParamBuf::F32(v) => HostTensor::f32(vec![vocab, d], v.clone()),
                ParamBuf::Bf16(v) => HostTensor::bf16(vec![vocab, d], v.clone()),
            }
        };
        Ok(Checkpoint {
            step: self.step,
            tensors: vec![
                ("emb".into(), tensor(&self.emb)?),
                ("cls".into(), tensor(&self.cls)?),
            ],
        })
    }

    /// Load a state plus its sibling tokenizer (`<path>.vocab.json`) and
    /// model hyperparameters (`<path>.model.json`), as written by
    /// [`NativeTrainer::save_checkpoint`].  `(vocab, d)` come from the
    /// checkpoint's own tensor shapes — the serving path needs no run
    /// config to open a trained model.  The state keeps the checkpoint's
    /// stored dtype; `window` is `None` for pre-PR-2 checkpoints without
    /// the model sidecar.
    pub fn load_bundle(path: &std::path::Path) -> Result<NativeBundle> {
        let ckpt = Checkpoint::load(path)?;
        let (vocab, d_model) = ckpt
            .tensors
            .iter()
            .find(|(name, t)| name == "emb" && t.shape.len() == 2)
            .map(|(_, t)| (t.shape[0], t.shape[1]))
            .ok_or_else(|| anyhow!("checkpoint {path:?} has no rank-2 emb tensor"))?;
        let state = NativeState::from_checkpoint(ckpt, vocab, d_model, None)?;
        let tokenizer = Tokenizer::load(path.with_extension("vocab.json"))?;
        if tokenizer.vocab_size() != vocab {
            bail!(
                "tokenizer vocab {} does not match checkpoint vocab {vocab}",
                tokenizer.vocab_size()
            );
        }
        let sidecar = path.with_extension("model.json");
        let (window, seq_len) = match std::fs::read_to_string(&sidecar) {
            Err(_) => (None, None), // older checkpoint without the sidecar
            Ok(text) => {
                let meta = crate::util::Json::parse(&text)?;
                verify_sidecar(&meta, &sidecar)?;
                let field = |key: &str| meta.get(key).and_then(|v| v.as_i64()).map(|x| x as usize);
                (field("window"), field("seq_len"))
            }
        };
        Ok(NativeBundle { state, tokenizer, vocab, d_model, window, seq_len })
    }

    /// Rebuild a state from a checkpoint.  `dtype` selects the in-memory
    /// storage: `None` keeps whatever the checkpoint stored; `Some(want)`
    /// up/down-converts at load (so an old f32 checkpoint opens under
    /// `--dtype bf16` and vice versa — widening is exact, narrowing is one
    /// RNE rounding).
    pub fn from_checkpoint(
        ckpt: Checkpoint,
        vocab: usize,
        d: usize,
        dtype: Option<StoreDtype>,
    ) -> Result<NativeState> {
        let mut emb = None;
        let mut cls = None;
        for (name, t) in ckpt.tensors {
            if t.shape != vec![vocab, d] {
                bail!("checkpoint tensor {name:?} has shape {:?}, want [{vocab}, {d}]", t.shape);
            }
            let buf = match t.data {
                Data::F32(v) => ParamBuf::F32(v),
                Data::BF16(v) => ParamBuf::Bf16(v),
                other => bail!("checkpoint tensor {name:?} has dtype {:?}", other.dtype()),
            };
            let buf = match dtype {
                Some(want) if want != buf.dtype() => buf.to_dtype(want),
                _ => buf,
            };
            match name.as_str() {
                "emb" => emb = Some(buf),
                "cls" => cls = Some(buf),
                other => bail!("unexpected checkpoint tensor {other:?}"),
            }
        }
        Ok(NativeState {
            emb: emb.ok_or_else(|| anyhow!("checkpoint missing emb"))?,
            cls: cls.ok_or_else(|| anyhow!("checkpoint missing cls"))?,
            step: ckpt.step,
        })
    }
}

/// Everything a serving/measurement path needs from a saved native run:
/// the weights, the tokenizer, the shape inferred from the tensors, and
/// (when the `.model.json` sidecar exists) the training context window.
pub struct NativeBundle {
    pub state: NativeState,
    pub tokenizer: Tokenizer,
    pub vocab: usize,
    pub d_model: usize,
    pub window: Option<usize>,
    pub seq_len: Option<usize>,
}

/// Bag-of-context hidden states for packed sequences: position `i`
/// averages the embeddings of the last `window` tokens within its
/// `seq_len`-aligned sequence.  Shared by the trainer, the fig3 native
/// harness, and (per-context, without the sequence resets) the serving
/// engine's decode path.  Generic over the embedding storage dtype: bf16
/// rows widen on load inside the SIMD accumulate; the hidden output is
/// always f32.
///
/// `threads` sizes the fork-join spans (`0` = auto); positions are
/// independent and spans align to sequence boundaries, so the result is
/// bitwise identical for every thread count.
pub fn bag_hidden<S: Store>(
    tokens: &[i32],
    emb: &[S],
    d: usize,
    window: usize,
    seq_len: usize,
    threads: usize,
) -> Vec<f32> {
    simd::with_lanes!(lanes => bag_hidden_with(tokens, emb, d, window, seq_len, threads, lanes))
}

fn bag_hidden_with<S: Store, L: Lanes>(
    tokens: &[i32],
    emb: &[S],
    d: usize,
    window: usize,
    seq_len: usize,
    threads: usize,
    lanes: L,
) -> Vec<f32> {
    let w = window.max(1);
    let seq = seq_len.max(1);
    let n = tokens.len();
    let mut h = vec![0f32; n * d];
    // Whole sequences per span: a position's window never crosses its own
    // sequence, so each span reads only its own token slice.
    let seqs = crate::exec::ceil_div(n, seq);
    let span_seqs = crate::exec::ceil_div(seqs, crate::exec::resolve_threads(threads)).max(1);
    let tasks: Vec<_> = h
        .chunks_mut(span_seqs * seq * d)
        .enumerate()
        .map(|(ti, h_chunk)| {
            let pos0 = ti * span_seqs * seq;
            move || {
                for (r, chunk) in h_chunk.chunks_mut(d).enumerate() {
                    let i = pos0 + r;
                    let q = i % seq;
                    let lo = i - q.min(w - 1);
                    let len = (i - lo + 1) as f32;
                    for &tok in &tokens[lo..=i] {
                        let row = &emb[tok as usize * d..(tok as usize + 1) * d];
                        S::lanes_add_acc(lanes, chunk, row);
                    }
                    lanes.scale(chunk, 1.0 / len);
                }
            }
        })
        .collect();
    pool::global().run(tasks);
    h
}

/// A ready-to-train native bundle: data + tokenizer + kernel backend.
pub struct NativeTrainer {
    pub cfg: RunConfig,
    pub model: NativeModelConfig,
    pub tokenizer: Tokenizer,
    pub dataset: Dataset,
    pub backend: NativeBackend,
    pub vocab: usize,
    /// Vocabulary-shard fleet (`--shards` / `--shard-endpoints`): when
    /// attached, the classifier lives on the workers — forward/backward
    /// sweeps and the classifier SGD update run shard-local, the trainer
    /// keeps the embedding side and merges the per-row scalar exchange
    /// (see [`crate::shard`]).  [`NativeTrainer::train`] ships the
    /// classifier out at the start and fetches it back before returning,
    /// so checkpoints are oblivious to sharding.
    fleet: Option<std::sync::Arc<crate::shard::Fleet>>,
}

impl NativeTrainer {
    /// Build the pipeline: generate the corpus, train the BPE vocabulary,
    /// pack the dataset, and resolve `cfg.method` to a native backend.
    pub fn build(
        cfg: RunConfig,
        model: NativeModelConfig,
        opts: KernelOptions,
    ) -> Result<NativeTrainer> {
        let backend = NativeBackend::from_key(&cfg.method, opts)
            .map_err(|e| anyhow!("--method {:?} on the native backend: {e:#}", cfg.method))?;
        let docs = match cfg.corpus {
            CorpusKind::Web => web_corpus(cfg.corpus_docs, cfg.seed),
            CorpusKind::Instruct => instruct_corpus(cfg.corpus_docs, cfg.seed),
        };
        let texts: Vec<String> = docs.iter().map(|d| d.text.clone()).collect();
        let tokenizer = Tokenizer::train(&texts, &TokenizerConfig {
            vocab_size: cfg.vocab_size,
            min_pair_freq: 2,
        })?;
        let dataset = Dataset::build(&docs, &tokenizer, &DatasetConfig {
            seq_len: model.seq_len,
            val_fraction: 0.02,
            seed: cfg.seed,
            pad_per_doc: cfg.corpus == CorpusKind::Instruct,
        })?;
        let vocab = tokenizer.vocab_size();
        Ok(NativeTrainer { cfg, model, tokenizer, dataset, backend, vocab, fleet: None })
    }

    /// Route the classifier through a vocabulary-shard fleet.  Only the
    /// `cce*` methods shard (their blocked kernels run shard-local with
    /// the §4.3 filter against the broadcast global LSE); `baseline` and
    /// `chunked<k>` materialize logits and stay single-process.
    pub fn attach_fleet(&mut self, fleet: std::sync::Arc<crate::shard::Fleet>) -> Result<()> {
        if fleet.vocab() != self.vocab || fleet.dim() != self.model.d_model {
            bail!(
                "fleet shape {}×{} does not match model vocab {} × d {}",
                fleet.vocab(),
                fleet.dim(),
                self.vocab,
                self.model.d_model
            );
        }
        if self.backend.method != crate::exec::NativeMethod::Cce {
            bail!(
                "--method {:?} cannot shard along V; vocabulary sharding needs a cce* method",
                self.cfg.method
            );
        }
        self.fleet = Some(fleet);
        Ok(())
    }

    /// Ship `state`'s classifier to the attached fleet (no-op without
    /// one).  [`NativeTrainer::train`] calls this itself; eval-only
    /// drivers call it once before [`NativeTrainer::evaluate`].
    pub fn fleet_load(&self, state: &NativeState) -> Result<()> {
        if let Some(fleet) = &self.fleet {
            fleet.load(&state.cls, &self.backend.opts)?;
        }
        Ok(())
    }

    /// Fresh state in the backend's storage dtype: small random embeddings,
    /// near-zero classifier (uniform initial softmax => initial loss ≈
    /// ln |V|).  The f32 draw happens first so f32 and bf16 runs start
    /// from the same values up to one storage rounding.
    pub fn init(&self, seed: u64) -> NativeState {
        let d = self.model.d_model;
        let mut rng = Rng::new(seed ^ 0xCCE_5EED);
        let emb: Vec<f32> = (0..self.vocab * d).map(|_| (rng.normal() * 0.5) as f32).collect();
        let cls: Vec<f32> = (0..self.vocab * d).map(|_| (rng.normal() * 0.01) as f32).collect();
        let dtype = self.backend.opts.dtype;
        NativeState {
            emb: ParamBuf::from_f32_vec(emb, dtype),
            cls: ParamBuf::from_f32_vec(cls, dtype),
            step: 0,
        }
    }

    pub fn tokens_per_step(&self) -> u64 {
        (self.model.batch * self.model.seq_len) as u64
    }

    /// Hidden states for a flat token buffer of `rows` sequences.  Public
    /// so measurement harnesses (`fig3 --backend native`) can probe the
    /// model head directly.
    pub fn hidden(&self, tokens: &[i32], state: &NativeState) -> Vec<f32> {
        let (d, w, seq) = (self.model.d_model, self.model.window, self.model.seq_len);
        let threads = self.backend.opts.threads;
        match &state.emb {
            ParamBuf::F32(emb) => bag_hidden(tokens, emb, d, w, seq, threads),
            ParamBuf::Bf16(emb) => bag_hidden(tokens, emb, d, w, seq, threads),
        }
    }

    /// One SGD step on a batch; returns `(loss, grad_norm)`.
    pub fn step(&self, state: &mut NativeState, batch: &StepBatch) -> Result<(f64, f64)> {
        let tokens = batch.tokens.as_i32()?;
        let targets = batch.targets.as_i32()?;
        let out = if self.fleet.is_some() {
            self.step_sharded(state, tokens, targets)?
        } else {
            let NativeState { emb, cls, .. } = state;
            match (emb, cls) {
                (ParamBuf::F32(emb), ParamBuf::F32(cls)) => self.step_t(emb, cls, tokens, targets)?,
                (ParamBuf::Bf16(emb), ParamBuf::Bf16(cls)) => {
                    self.step_t(emb, cls, tokens, targets)?
                }
                _ => bail!("state mixes storage dtypes (emb vs cls)"),
            }
        };
        state.step += 1;
        Ok(out)
    }

    /// The sharded step body: bag hidden locally (f32, identical to the
    /// single-process path), one `step` collective (shard-local forward,
    /// exact LSE merge), one `merge` collective (shard-local backward
    /// against the global LSE + the workers' in-place classifier SGD),
    /// then the embedding scatter and update locally.  A worker failure
    /// aborts the step with a pointed error — surviving workers only
    /// apply SGD inside a successful merge, so their slices are
    /// unchanged.
    fn step_sharded(
        &self,
        state: &mut NativeState,
        tokens: &[i32],
        targets: &[i32],
    ) -> Result<(f64, f64)> {
        let fleet = self.fleet.as_ref().expect("step_sharded requires an attached fleet");
        let h = self.hidden(tokens, state);
        let st = fleet.step(&h, targets)?;
        let mg = fleet.merge_grads(&st.lse, Some(self.model.lr), st.count)?;
        let gnorm = match &mut state.emb {
            ParamBuf::F32(emb) => {
                simd::with_lanes!(lanes => self.apply_update_emb(emb, tokens, &mg.d_e, mg.dc_sqnorm, lanes))
            }
            ParamBuf::Bf16(emb) => {
                simd::with_lanes!(lanes => self.apply_update_emb(emb, tokens, &mg.d_e, mg.dc_sqnorm, lanes))
            }
        };
        Ok((st.loss, gnorm))
    }

    /// The monomorphized step body: bag hidden (f32) → activations in the
    /// storage dtype → forward/backward → scatter + SGD update.
    fn step_t<S: Store>(
        &self,
        emb: &mut [S],
        cls: &mut [S],
        tokens: &[i32],
        targets: &[i32],
    ) -> Result<(f64, f64)> {
        let d = self.model.d_model;
        let h = bag_hidden(tokens, emb, d, self.model.window, self.model.seq_len,
                           self.backend.opts.threads);
        // Activations take the storage dtype too (a borrow for f32, one
        // narrowing pass for bf16 — the mixed-precision setting).
        let h_s = S::narrow_cow(&h);
        let n = tokens.len();
        let (fwd, bwd) = {
            let problem = Problem::new(&h_s, cls, targets, n, d, self.vocab)?;
            self.backend.forward_backward_t(&problem)?
        };
        let grad_norm =
            simd::with_lanes!(lanes => self.apply_update(emb, cls, tokens, &bwd, lanes));
        Ok((fwd.loss, grad_norm))
    }

    /// Scatter `dH` through the bag-of-context mean into `dEmb`, then apply
    /// the SGD update — both on the fork-join pool with a resolved SIMD
    /// token.  The scatter is **token-span parallel**: a sequential
    /// pre-pass buckets window visits per contiguous embedding-row span
    /// (in ascending position order), and each task drains only its own
    /// bucket — so each `dEmb` row receives its contributions in exactly
    /// the sequential order and the result is bitwise invariant in the
    /// thread count (same argument as the backward's column-parallel
    /// `dC`).  The scatter accumulates in f32 (widening bf16 `dH` rows on
    /// load); the parameter update itself runs in f32 per element with one
    /// narrow on store (`Store::lanes_axpy_store`) — for f32 storage that
    /// is the same lane-aligned pooled `axpy` as before, bitwise.  Returns
    /// the gradient norm.
    fn apply_update<S: Store, L: Lanes>(
        &self,
        emb: &mut [S],
        cls: &mut [S],
        tokens: &[i32],
        bwd: &BackwardOut<S>,
        lanes: L,
    ) -> f64 {
        let d = self.model.d_model;
        let w = self.model.window.max(1);
        let seq = self.model.seq_len.max(1);
        let n = tokens.len();
        let threads = self.backend.opts.resolved_threads();
        let mut d_emb = vec![0f32; emb.len()];
        let span_rows = crate::exec::ceil_div(self.vocab, threads).max(1);
        let n_spans = crate::exec::ceil_div(self.vocab, span_rows);
        // One sequential O(n·window) pre-pass buckets `(token, position,
        // 1/len)` visits per owning token span, so total scan work stays
        // O(n·window) no matter the thread count (a per-task rescan would
        // grow linearly with it).  Bucket order is the sequential visiting
        // order, so every dEmb row still accumulates in exactly the
        // single-threaded order — bitwise thread-invariant.
        let mut buckets: Vec<Vec<(u32, u32, f32)>> = vec![Vec::new(); n_spans];
        for i in 0..n {
            let q = i % seq;
            let lo = i - q.min(w - 1);
            let inv_len = 1.0 / (i - lo + 1) as f32;
            for &tok in &tokens[lo..=i] {
                let t = tok as usize;
                buckets[t / span_rows].push((t as u32, i as u32, inv_len));
            }
        }
        {
            let tasks: Vec<_> = d_emb
                .chunks_mut(span_rows * d)
                .zip(&buckets)
                .enumerate()
                .map(|(ti, (chunk, bucket))| {
                    let tok0 = ti * span_rows;
                    move || {
                        for &(t, i, inv_len) in bucket {
                            let (t, i) = (t as usize, i as usize);
                            let dh_row = &bwd.d_e[i * d..(i + 1) * d];
                            let row = &mut chunk[(t - tok0) * d..(t - tok0 + 1) * d];
                            S::lanes_axpy_acc(lanes, row, inv_len, dh_row);
                        }
                    }
                })
                .collect();
            pool::global().run(tasks);
        }
        // Gradient norm: widen dC on the fly — no f32 copy of a V×D
        // gradient ever exists (the kernels just got rid of theirs).
        let sq: f64 = bwd
            .d_c
            .iter()
            .map(|&g| {
                let g = S::to_f32(g) as f64;
                g * g
            })
            .chain(d_emb.iter().map(|&g| (g as f64) * g as f64))
            .sum();
        let lr = self.model.lr;
        // Lane-aligned spans (multiples of 8): an 8-aligned boundary
        // keeps the AVX2 axpy's vector-body vs scalar-tail split — and
        // therefore the FMA rounding of every element — identical to the
        // unchunked sweep, for any thread count.  The classifier update
        // reads dC in storage dtype (widen-on-load); the embedding update
        // reads the f32 scatter buffer.
        let lane_span = |len: usize| {
            let per = crate::exec::ceil_div(len, threads).max(1);
            crate::exec::ceil_div(per, 8) * 8
        };
        {
            let span = lane_span(cls.len());
            let tasks: Vec<_> = cls
                .chunks_mut(span)
                .zip(bwd.d_c.chunks(span))
                .map(|(pc, gc)| move || S::lanes_axpy_store_s(lanes, pc, -lr, gc))
                .collect();
            pool::global().run(tasks);
        }
        {
            let span = lane_span(emb.len());
            let tasks: Vec<_> = emb
                .chunks_mut(span)
                .zip(d_emb.chunks(span))
                .map(|(pc, gc)| move || S::lanes_axpy_store(lanes, pc, -lr, gc))
                .collect();
            pool::global().run(tasks);
        }
        sq.sqrt()
    }

    /// The embedding half of [`NativeTrainer::apply_update`] for the
    /// sharded step, reading the fleet's merged f32 `dE` (the classifier
    /// half already ran on the workers).  Same span-bucketed scatter,
    /// same lane-aligned SGD spans; returns the global gradient norm,
    /// `sqrt(Σ_k |dC_k|² + |dEmb|²)`.
    fn apply_update_emb<S: Store, L: Lanes>(
        &self,
        emb: &mut [S],
        tokens: &[i32],
        d_e: &[f32],
        dc_sqnorm: f64,
        lanes: L,
    ) -> f64 {
        let d = self.model.d_model;
        let w = self.model.window.max(1);
        let seq = self.model.seq_len.max(1);
        let n = tokens.len();
        let threads = self.backend.opts.resolved_threads();
        let mut d_emb = vec![0f32; emb.len()];
        let span_rows = crate::exec::ceil_div(self.vocab, threads).max(1);
        let n_spans = crate::exec::ceil_div(self.vocab, span_rows);
        let mut buckets: Vec<Vec<(u32, u32, f32)>> = vec![Vec::new(); n_spans];
        for i in 0..n {
            let q = i % seq;
            let lo = i - q.min(w - 1);
            let inv_len = 1.0 / (i - lo + 1) as f32;
            for &tok in &tokens[lo..=i] {
                let t = tok as usize;
                buckets[t / span_rows].push((t as u32, i as u32, inv_len));
            }
        }
        {
            let tasks: Vec<_> = d_emb
                .chunks_mut(span_rows * d)
                .zip(&buckets)
                .enumerate()
                .map(|(ti, (chunk, bucket))| {
                    let tok0 = ti * span_rows;
                    move || {
                        for &(t, i, inv_len) in bucket {
                            let (t, i) = (t as usize, i as usize);
                            let dh_row = &d_e[i * d..(i + 1) * d];
                            let row = &mut chunk[(t - tok0) * d..(t - tok0 + 1) * d];
                            <f32 as Store>::lanes_axpy_acc(lanes, row, inv_len, dh_row);
                        }
                    }
                })
                .collect();
            pool::global().run(tasks);
        }
        let sq: f64 = dc_sqnorm + d_emb.iter().map(|&g| (g as f64) * g as f64).sum::<f64>();
        let lr = self.model.lr;
        let lane_span = |len: usize| {
            let per = crate::exec::ceil_div(len, threads).max(1);
            crate::exec::ceil_div(per, 8) * 8
        };
        {
            let span = lane_span(emb.len());
            let tasks: Vec<_> = emb
                .chunks_mut(span)
                .zip(d_emb.chunks(span))
                .map(|(pc, gc)| move || S::lanes_axpy_store(lanes, pc, -lr, gc))
                .collect();
            pool::global().run(tasks);
        }
        sq.sqrt()
    }

    /// Mean validation NLL over all validation batches.  With a fleet
    /// attached the forward runs sharded (the workers hold the current
    /// classifier — mid-train evals see the live weights); `abort` drops
    /// the step state no backward will consume.
    pub fn evaluate(&self, state: &NativeState) -> Result<f64> {
        let batches = self.dataset.val_batches(self.model.batch);
        if batches.is_empty() {
            bail!("validation set smaller than one batch");
        }
        let (mut loss_sum, mut count) = (0.0f64, 0usize);
        for b in &batches {
            let tokens = b.tokens.as_i32()?;
            let targets = b.targets.as_i32()?;
            if let Some(fleet) = &self.fleet {
                let h = self.hidden(tokens, state);
                let st = fleet.step(&h, targets)?;
                fleet.abort()?;
                loss_sum += st.loss * st.count as f64;
                count += st.count;
                continue;
            }
            let fwd = match (&state.emb, &state.cls) {
                (ParamBuf::F32(emb), ParamBuf::F32(cls)) => {
                    self.eval_batch_t(emb, cls, tokens, targets)?
                }
                (ParamBuf::Bf16(emb), ParamBuf::Bf16(cls)) => {
                    self.eval_batch_t(emb, cls, tokens, targets)?
                }
                _ => bail!("state mixes storage dtypes (emb vs cls)"),
            };
            loss_sum += fwd.0 * fwd.1 as f64;
            count += fwd.1;
        }
        Ok(loss_sum / count.max(1) as f64)
    }

    fn eval_batch_t<S: Store>(
        &self,
        emb: &[S],
        cls: &[S],
        tokens: &[i32],
        targets: &[i32],
    ) -> Result<(f64, usize)> {
        let d = self.model.d_model;
        let h = bag_hidden(tokens, emb, d, self.model.window, self.model.seq_len,
                           self.backend.opts.threads);
        let h_s = S::narrow_cow(&h);
        let problem = Problem::new(&h_s, cls, targets, targets.len(), d, self.vocab)?;
        let fwd = self.backend.forward_t(&problem)?;
        Ok((fwd.loss, fwd.count))
    }

    /// Run the training loop for `cfg.steps` optimizer steps.
    pub fn train(&self, mut state: NativeState, metrics: &mut Metrics) -> Result<NativeState> {
        // Re-anchor the metrics clock: a resumed run carries restored step
        // history whose elapsed values came from an earlier process.
        metrics.start_run();
        // Ship the classifier out to the shard workers (no-op unsharded).
        self.fleet_load(&state)?;
        let mut done = state.step;
        let mut epoch: u64 = 0;
        'outer: loop {
            let mut saw_batch = false;
            for batch in self.dataset.step_batches(1, self.model.batch, epoch) {
                saw_batch = true;
                let (loss, gnorm) = self.step(&mut state, &batch)?;
                done += 1;
                metrics.log_step(done, loss, gnorm, self.tokens_per_step());
                if done % self.cfg.log_every.max(1) == 0 || done == 1 {
                    eprintln!(
                        "[train native/{}] step {done}/{} loss {loss:.4} gnorm {gnorm:.3} ({:.0} tok/s)",
                        self.cfg.method,
                        self.cfg.steps,
                        metrics.steps.last().map(|r| r.tokens_per_sec).unwrap_or(0.0)
                    );
                }
                if self.cfg.eval_every > 0 && done % self.cfg.eval_every == 0 {
                    let val = self.evaluate(&state)?;
                    metrics.log_eval(done, val);
                    eprintln!(
                        "[eval  native/{}] step {done} val_loss {val:.4} ppl {:.2}",
                        self.cfg.method,
                        val.exp()
                    );
                }
                if done >= self.cfg.steps {
                    break 'outer;
                }
            }
            if !saw_batch {
                return Err(anyhow!(
                    "dataset too small: no step batches (need {} sequences/step)",
                    self.model.batch
                ));
            }
            epoch += 1;
        }
        if let Some(fleet) = &self.fleet {
            // Bring the trained classifier home: checkpoints and eval-only
            // paths are oblivious to sharding.  The f32 wire round-trip is
            // exact for both storage dtypes.
            let dtype = state.cls.dtype();
            state.cls = ParamBuf::from_f32_vec(fleet.fetch()?, dtype);
        }
        Ok(state)
    }

    /// Save checkpoint + tokenizer vocabulary + model hyperparameters
    /// (`.model.json` sidecar, so serving needs no training flags; the
    /// sidecar carries the storage dtype tag next to the per-tensor dtype
    /// in the checkpoint header).  Like the checkpoint itself, the sidecar
    /// is written atomically (tmp + fsync + rename) and carries a `crc32`
    /// over the compact serialization of its other fields, verified by
    /// [`NativeState::load_bundle`].
    pub fn save_checkpoint(&self, state: &NativeState, path: &std::path::Path) -> Result<()> {
        state.to_checkpoint(self.vocab, self.model.d_model)?.save(path)?;
        self.tokenizer.save(path.with_extension("vocab.json"))?;
        let mut meta = crate::util::Json::obj(vec![
            ("d_model", crate::util::Json::Int(self.model.d_model as i64)),
            ("window", crate::util::Json::Int(self.model.window as i64)),
            ("seq_len", crate::util::Json::Int(self.model.seq_len as i64)),
            ("vocab", crate::util::Json::Int(self.vocab as i64)),
            ("dtype", crate::util::Json::str(state.dtype().name())),
        ]);
        // Checksum over the compact form of everything above; key order is
        // preserved by the JSON layer, so the loader can reproduce it.
        let body = meta.to_string();
        if let crate::util::Json::Object(fields) = &mut meta {
            fields.push((
                "crc32".into(),
                crate::util::Json::Int(crate::util::crc32(body.as_bytes()) as i64),
            ));
        }
        write_atomic(&path.with_extension("model.json"), &meta.to_string_pretty())?;
        Ok(())
    }
}

/// Write a small text file atomically: `<path>.tmp` + fsync + rename, so a
/// crash mid-write never leaves a torn file at `path`.
fn write_atomic(path: &std::path::Path, contents: &str) -> Result<()> {
    let mut tmp_name = path.as_os_str().to_owned();
    tmp_name.push(".tmp");
    let tmp = std::path::PathBuf::from(tmp_name);
    {
        use std::io::Write as _;
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(contents.as_bytes())?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Validate a parsed `.model.json` sidecar against its embedded `crc32`
/// (over the compact serialization of the other fields, in stored key
/// order).  Sidecars from before the checksum existed load with a warning.
fn verify_sidecar(meta: &crate::util::Json, path: &std::path::Path) -> Result<()> {
    use crate::util::Json;
    let fields = match meta {
        Json::Object(fields) => fields,
        other => bail!("model sidecar {path:?} is not a JSON object: {other:?}"),
    };
    match meta.get("crc32").and_then(Json::as_i64) {
        None => {
            eprintln!(
                "[checkpoint] warning: {path:?} predates sidecar checksums; \
                 integrity not verified"
            );
            Ok(())
        }
        Some(expect) => {
            let body: Vec<(String, Json)> =
                fields.iter().filter(|(k, _)| k != "crc32").cloned().collect();
            let got = crate::util::crc32(Json::Object(body).to_string().as_bytes());
            if got as i64 != expect {
                bail!(
                    "corrupt model sidecar {path:?}: checksum mismatch \
                     (crc32 {got:#010x}, file says {:#010x})",
                    expect as u32
                );
            }
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg(method: &str, steps: u64) -> RunConfig {
        RunConfig {
            tag: "native".into(),
            method: method.into(),
            steps,
            seed: 7,
            corpus: CorpusKind::Web,
            corpus_docs: 200,
            vocab_size: 512,
            eval_every: 0,
            checkpoint_every: 0,
            log_every: u64::MAX,
            out_dir: std::env::temp_dir().join("cce_native_it").to_string_lossy().into(),
        }
    }

    fn tiny_model() -> NativeModelConfig {
        NativeModelConfig { d_model: 32, window: 4, lr: 0.5, batch: 4, seq_len: 64 }
    }

    fn fast_opts() -> KernelOptions {
        KernelOptions { n_block: 32, v_block: 128, threads: 2, ..KernelOptions::default() }
    }

    fn bf16_opts() -> KernelOptions {
        KernelOptions { dtype: StoreDtype::Bf16, ..fast_opts() }
    }

    #[test]
    fn native_training_reduces_loss() {
        let trainer = NativeTrainer::build(tiny_cfg("cce", 30), tiny_model(), fast_opts()).unwrap();
        let state = trainer.init(7);
        let mut metrics = Metrics::in_memory();
        let state = trainer.train(state, &mut metrics).unwrap();
        assert_eq!(state.step, 30);
        assert_eq!(metrics.steps.len(), 30);
        let first = metrics.steps[0].loss;
        let last = metrics.steps.last().unwrap().loss;
        // Initial loss ≈ ln|V|; the bag-of-context model learns at least
        // the unigram structure within 30 SGD steps.
        assert!((first - (trainer.vocab as f64).ln()).abs() < 0.5, "first {first}");
        assert!(last < first - 0.1, "loss did not decrease: {first:.4} -> {last:.4}");
        let val = trainer.evaluate(&state).unwrap();
        assert!(val.is_finite() && val > 0.0);
    }

    #[test]
    fn cce_and_baseline_native_curves_match() {
        // The Fig. 4 claim on the native path: same seed + same data =>
        // same curve whether the head is CCE or the materializing baseline.
        let run = |method: &str| {
            let trainer =
                NativeTrainer::build(tiny_cfg(method, 8), tiny_model(), fast_opts()).unwrap();
            let state = trainer.init(7);
            let mut metrics = Metrics::in_memory();
            trainer.train(state, &mut metrics).unwrap();
            metrics
        };
        let cce = run("cce");
        let base = run("baseline");
        let div = crate::coordinator::curve_max_divergence(&cce.steps, &base.steps);
        let scale = cce.steps[0].loss;
        assert!(div < 0.01 * scale, "curves diverged: {div:.4e} (scale {scale:.3})");
    }

    #[test]
    fn bf16_storage_curve_tracks_f32_within_tolerance() {
        // The documented bf16-storage tolerance: training the same seed
        // grid with bf16 parameters/activations/gradients stays within 1%
        // of the f32 curve (python-simulated drift at this scale: ~0.15%).
        // Storage halves; the loss trajectory must not care.
        let run = |opts: KernelOptions| {
            let trainer = NativeTrainer::build(tiny_cfg("cce", 10), tiny_model(), opts).unwrap();
            let state = trainer.init(7);
            assert_eq!(state.dtype(), opts.dtype);
            let mut metrics = Metrics::in_memory();
            let state = trainer.train(state, &mut metrics).unwrap();
            (metrics, state.param_bytes())
        };
        let (f32_run, f32_bytes) = run(fast_opts());
        let (bf16_run, bf16_bytes) = run(bf16_opts());
        assert_eq!(bf16_bytes * 2, f32_bytes, "bf16 params must be half the footprint");
        let div = crate::coordinator::curve_max_divergence(&f32_run.steps, &bf16_run.steps);
        let scale = f32_run.steps[0].loss;
        assert!(
            div < 0.01 * scale,
            "bf16 curve diverged from f32: {div:.4e} (scale {scale:.3})"
        );
    }

    #[test]
    fn checkpoint_roundtrip() {
        let trainer = NativeTrainer::build(tiny_cfg("cce", 2), tiny_model(), fast_opts()).unwrap();
        let state = trainer.init(1);
        let mut metrics = Metrics::in_memory();
        let state = trainer.train(state, &mut metrics).unwrap();
        let path = std::env::temp_dir().join("cce_native_ckpt.bin");
        trainer.save_checkpoint(&state, &path).unwrap();
        let restored = NativeState::from_checkpoint(
            Checkpoint::load(&path).unwrap(),
            trainer.vocab,
            trainer.model.d_model,
            None,
        )
        .unwrap();
        assert_eq!(restored.step, 2);
        assert_eq!(restored.emb, state.emb);
        let a = trainer.evaluate(&state).unwrap();
        let b = trainer.evaluate(&restored).unwrap();
        assert!((a - b).abs() < 1e-12);
    }

    #[test]
    fn bf16_checkpoint_roundtrip_and_cross_dtype_load() {
        // bf16 run -> bf16 checkpoint (bit-exact reload, half the bytes);
        // f32 checkpoint -> bf16 load obeys the RNE bound per element.
        let trainer = NativeTrainer::build(tiny_cfg("cce", 2), tiny_model(), bf16_opts()).unwrap();
        let state = trainer.init(3);
        let mut metrics = Metrics::in_memory();
        let state = trainer.train(state, &mut metrics).unwrap();
        let path = std::env::temp_dir().join("cce_native_ckpt_bf16.bin");
        trainer.save_checkpoint(&state, &path).unwrap();
        let restored = NativeState::from_checkpoint(
            Checkpoint::load(&path).unwrap(),
            trainer.vocab,
            trainer.model.d_model,
            None,
        )
        .unwrap();
        assert_eq!(restored.dtype(), StoreDtype::Bf16, "stored dtype must survive the roundtrip");
        assert_eq!(restored.emb, state.emb, "bf16 reload must be bit-exact");
        // The sidecar carries the dtype tag.
        let sidecar = std::fs::read_to_string(path.with_extension("model.json")).unwrap();
        assert!(sidecar.contains("\"dtype\""), "{sidecar}");
        assert!(sidecar.contains("bf16"), "{sidecar}");

        // Cross-dtype: an f32 checkpoint loaded as bf16 (and back) stays
        // within one RNE rounding of the original values.
        let f32_trainer =
            NativeTrainer::build(tiny_cfg("cce", 1), tiny_model(), fast_opts()).unwrap();
        let f32_state = f32_trainer.init(3);
        let f32_path = std::env::temp_dir().join("cce_native_ckpt_f32src.bin");
        f32_trainer.save_checkpoint(&f32_state, &f32_path).unwrap();
        let as_bf16 = NativeState::from_checkpoint(
            Checkpoint::load(&f32_path).unwrap(),
            f32_trainer.vocab,
            f32_trainer.model.d_model,
            Some(StoreDtype::Bf16),
        )
        .unwrap();
        assert_eq!(as_bf16.dtype(), StoreDtype::Bf16);
        let orig = f32_state.emb.to_f32_vec();
        let wide = as_bf16.emb.to_f32_vec();
        for (a, b) in orig.iter().zip(&wide) {
            // RNE narrowing error <= 2^-9 relative (half a bf16 ulp) for
            // normal values; the init draw has no subnormals.
            assert!((a - b).abs() <= a.abs() * 3.9e-3 + 1e-30, "{a} vs {b}");
        }
        // And the cross-loaded model still evaluates sanely.
        let val = f32_trainer.evaluate(&as_bf16).unwrap();
        let val_f32 = f32_trainer.evaluate(&f32_state).unwrap();
        assert!((val - val_f32).abs() < 0.02 * val_f32.abs().max(1.0), "{val} vs {val_f32}");
    }

    #[test]
    fn load_bundle_infers_shape_and_loads_tokenizer() {
        let trainer = NativeTrainer::build(tiny_cfg("cce", 1), tiny_model(), fast_opts()).unwrap();
        let state = trainer.init(3);
        let path = std::env::temp_dir().join("cce_native_bundle.ckpt");
        trainer.save_checkpoint(&state, &path).unwrap();
        let bundle = NativeState::load_bundle(&path).unwrap();
        assert_eq!(bundle.vocab, trainer.vocab);
        assert_eq!(bundle.d_model, trainer.model.d_model);
        assert_eq!(bundle.window, Some(trainer.model.window));
        assert_eq!(bundle.seq_len, Some(trainer.model.seq_len));
        assert_eq!(bundle.tokenizer.vocab_size(), trainer.vocab);
        assert_eq!(bundle.state.emb, state.emb);
        assert_eq!(bundle.state.cls, state.cls);
        // A tampered sidecar fails its checksum with a pointed error.
        let sidecar = path.with_extension("model.json");
        let pristine = std::fs::read_to_string(&sidecar).unwrap();
        assert!(pristine.contains("crc32"), "sidecar must carry a checksum");
        std::fs::write(&sidecar, pristine.replace("\"seq_len\": 64", "\"seq_len\": 65")).unwrap();
        let err = NativeState::load_bundle(&path).unwrap_err().to_string();
        assert!(err.contains("corrupt model sidecar"), "got: {err}");
        // A checksum-less (pre-PR-6) sidecar still loads, with a warning.
        let stripped = crate::util::Json::parse(&pristine)
            .map(|meta| match meta {
                crate::util::Json::Object(fields) => crate::util::Json::Object(
                    fields.into_iter().filter(|(k, _)| k != "crc32").collect(),
                ),
                other => other,
            })
            .unwrap();
        std::fs::write(&sidecar, stripped.to_string_pretty()).unwrap();
        let legacy = NativeState::load_bundle(&path).unwrap();
        assert_eq!(legacy.seq_len, Some(trainer.model.seq_len));
        // A pre-sidecar checkpoint still loads, with unknown window.
        std::fs::remove_file(sidecar).unwrap();
        let old = NativeState::load_bundle(&path).unwrap();
        assert_eq!(old.window, None);
        assert_eq!(old.state.emb, state.emb);
    }

    #[test]
    fn unknown_method_is_rejected() {
        let err = NativeTrainer::build(tiny_cfg("fused", 1), tiny_model(), fast_opts())
            .err()
            .expect("fused must be rejected natively");
        assert!(format!("{err:#}").contains("fused"), "{err:#}");
    }

    #[test]
    fn sharded_training_curve_matches_single_process() {
        // The tentpole contract at trainer level: same seed + same data,
        // 2-shard local fleet vs single process, filter off (the skip mask
        // partitions differently under sharding, so filtered runs only
        // match approximately — see docs/sharding.md).  The only float
        // difference left is the (m, s) LSE merge regrouping, ~1 ulp/row.
        let run = |shards: Option<usize>| {
            let mut trainer =
                NativeTrainer::build(tiny_cfg("cce_no_filter", 6), tiny_model(), fast_opts())
                    .unwrap();
            if let Some(k) = shards {
                let fleet = crate::shard::Fleet::local(k, trainer.vocab, trainer.model.d_model)
                    .unwrap();
                trainer.attach_fleet(std::sync::Arc::new(fleet)).unwrap();
            }
            let state = trainer.init(7);
            let mut metrics = Metrics::in_memory();
            let state = trainer.train(state, &mut metrics).unwrap();
            let val = trainer.evaluate(&state).unwrap();
            (metrics, state, val)
        };
        let (single, s_state, s_val) = run(None);
        let (sharded, f_state, f_val) = run(Some(2));
        let div = crate::coordinator::curve_max_divergence(&single.steps, &sharded.steps);
        let scale = single.steps[0].loss;
        assert!(div < 1e-5 * scale.max(1.0), "sharded curve diverged: {div:.4e}");
        assert!((s_val - f_val).abs() < 1e-5, "val loss diverged: {s_val} vs {f_val}");
        // The classifier came home from the workers: same shape, and the
        // trained parameters agree to the merge tolerance.
        let a = s_state.cls.to_f32_vec();
        let b = f_state.cls.to_f32_vec();
        assert_eq!(a.len(), b.len());
        let worst =
            a.iter().zip(&b).map(|(x, y)| (x - y).abs() as f64).fold(0.0f64, f64::max);
        assert!(worst < 1e-4, "classifier drifted across the fleet roundtrip: {worst:.3e}");
    }

    #[test]
    fn attach_fleet_rejects_unshardable_methods_and_shapes() {
        let mut trainer =
            NativeTrainer::build(tiny_cfg("baseline", 1), tiny_model(), fast_opts()).unwrap();
        let fleet = std::sync::Arc::new(
            crate::shard::Fleet::local(2, trainer.vocab, trainer.model.d_model).unwrap(),
        );
        let err = trainer.attach_fleet(fleet).unwrap_err().to_string();
        assert!(err.contains("cannot shard"), "got: {err}");

        let mut trainer =
            NativeTrainer::build(tiny_cfg("cce", 1), tiny_model(), fast_opts()).unwrap();
        let wrong =
            std::sync::Arc::new(crate::shard::Fleet::local(2, trainer.vocab + 1, 8).unwrap());
        let err = trainer.attach_fleet(wrong).unwrap_err().to_string();
        assert!(err.contains("does not match"), "got: {err}");
    }
}
