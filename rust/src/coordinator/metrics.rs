//! Metrics registry: in-memory history + JSONL/CSV sinks.
//!
//! Every training run writes `metrics.jsonl` (one JSON object per event)
//! and `loss_curve.csv` under its `out_dir`; the Fig. 4/5 harnesses read
//! the in-memory history to compare methods' curves.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::{Arc, OnceLock};
use std::time::Instant;

use anyhow::Result;

use crate::obs;
use crate::util::json::Json;

/// One logged training step.
#[derive(Debug, Clone, Copy)]
pub struct StepRecord {
    pub step: u64,
    pub loss: f64,
    pub grad_norm: f64,
    pub tokens_per_sec: f64,
    pub elapsed: f64,
}

/// One validation measurement.
#[derive(Debug, Clone, Copy)]
pub struct EvalRecord {
    pub step: u64,
    pub val_loss: f64,
    pub perplexity: f64,
}

/// Collects records and streams them to disk.
pub struct Metrics {
    pub steps: Vec<StepRecord>,
    pub evals: Vec<EvalRecord>,
    jsonl: Option<BufWriter<File>>,
    started: Instant,
    /// Wall-clock seconds already on the books when [`Metrics::start_run`]
    /// last re-anchored `started` — keeps `elapsed` monotone across
    /// resumed runs.
    elapsed_offset: f64,
}

/// Handles into the process-global registry for the `train_*` families
/// (pre-registered by [`obs::global`]), resolved once.
struct TrainObs {
    steps: Arc<obs::Counter>,
    loss: Arc<obs::GaugeF>,
    grad_norm: Arc<obs::GaugeF>,
    tokens_per_sec: Arc<obs::GaugeF>,
}

fn train_obs() -> &'static TrainObs {
    static OBS: OnceLock<TrainObs> = OnceLock::new();
    OBS.get_or_init(|| {
        let r = obs::global();
        TrainObs {
            steps: r.counter("train_steps_total", ""),
            loss: r.gauge_f("train_step_loss", ""),
            grad_norm: r.gauge_f("train_grad_norm", ""),
            tokens_per_sec: r.gauge_f("train_tokens_per_sec", ""),
        }
    })
}

impl Metrics {
    /// In-memory only (benches, tests).
    pub fn in_memory() -> Metrics {
        Metrics {
            steps: Vec::new(),
            evals: Vec::new(),
            jsonl: None,
            started: Instant::now(),
            elapsed_offset: 0.0,
        }
    }

    /// Stream to `out_dir/metrics.jsonl` as well.
    pub fn with_dir(out_dir: impl AsRef<Path>) -> Result<Metrics> {
        std::fs::create_dir_all(&out_dir)?;
        let file = File::create(out_dir.as_ref().join("metrics.jsonl"))?;
        Ok(Metrics {
            steps: Vec::new(),
            evals: Vec::new(),
            jsonl: Some(BufWriter::new(file)),
            started: Instant::now(),
            elapsed_offset: 0.0,
        })
    }

    /// Re-anchor the wall clock at the start of a (possibly resumed) run.
    ///
    /// A `Metrics` may be constructed long before training begins, or
    /// carry step history restored from a checkpoint whose `elapsed`
    /// values came from an earlier process.  Without re-anchoring, the
    /// first step of the new run is charged the entire gap (or, with
    /// restored history, a *negative* delta that the `dt` clamp turns
    /// into an absurd throughput).  After this call `elapsed` continues
    /// monotonically from the last recorded step.
    pub fn start_run(&mut self) {
        self.elapsed_offset = self.steps.last().map(|r| r.elapsed).unwrap_or(0.0);
        self.started = Instant::now();
    }

    pub fn elapsed(&self) -> f64 {
        self.elapsed_offset + self.started.elapsed().as_secs_f64()
    }

    pub fn log_step(&mut self, step: u64, loss: f64, grad_norm: f64, tokens: u64) {
        let elapsed = self.elapsed();
        let dt = elapsed
            - self.steps.last().map(|r| r.elapsed).unwrap_or(0.0);
        let rec = StepRecord {
            step,
            loss,
            grad_norm,
            tokens_per_sec: tokens as f64 / dt.max(1e-9),
            elapsed,
        };
        self.steps.push(rec);
        if obs::enabled() {
            let o = train_obs();
            o.steps.inc();
            o.loss.set(loss);
            o.grad_norm.set(grad_norm);
            o.tokens_per_sec.set(rec.tokens_per_sec);
        }
        self.write_json(&Json::obj(vec![
            ("kind", Json::str("step")),
            ("step", Json::Int(step as i64)),
            ("loss", Json::Float(loss)),
            ("grad_norm", Json::Float(grad_norm)),
            ("tokens_per_sec", Json::Float(rec.tokens_per_sec)),
            ("elapsed", Json::Float(elapsed)),
        ]));
    }

    pub fn log_eval(&mut self, step: u64, val_loss: f64) {
        let rec = EvalRecord { step, val_loss, perplexity: val_loss.exp() };
        self.evals.push(rec);
        self.write_json(&Json::obj(vec![
            ("kind", Json::str("eval")),
            ("step", Json::Int(step as i64)),
            ("val_loss", Json::Float(val_loss)),
            ("perplexity", Json::Float(rec.perplexity)),
        ]));
    }

    fn write_json(&mut self, json: &Json) {
        if let Some(w) = &mut self.jsonl {
            let _ = writeln!(w, "{}", json.to_string());
            let _ = w.flush();
        }
    }

    /// Write the loss curve as CSV (step, loss[, val columns at eval steps]).
    pub fn write_csv(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut w = BufWriter::new(File::create(path)?);
        writeln!(w, "step,loss,grad_norm,tokens_per_sec")?;
        for r in &self.steps {
            writeln!(w, "{},{:.6},{:.4},{:.0}", r.step, r.loss, r.grad_norm,
                     r.tokens_per_sec)?;
        }
        Ok(())
    }

    /// Smoothed loss at each eval point (for curve comparisons).
    pub fn smoothed_losses(&self, window: usize) -> Vec<(u64, f64)> {
        let w = window.max(1);
        self.steps
            .windows(w)
            .map(|chunk| {
                let mean = chunk.iter().map(|r| r.loss).sum::<f64>() / w as f64;
                (chunk[w - 1].step, mean)
            })
            .collect()
    }

    /// Mean tokens/sec over the run (skipping the first compile-heavy step).
    pub fn mean_throughput(&self) -> f64 {
        let steps = self.steps.iter().skip(1).collect::<Vec<_>>();
        if steps.is_empty() {
            return 0.0;
        }
        steps.iter().map(|r| r.tokens_per_sec).sum::<f64>() / steps.len() as f64
    }
}

/// Maximum absolute difference between two loss curves sampled at the same
/// steps — the Fig. 4/5 "indistinguishable curves" metric.
pub fn curve_max_divergence(a: &[StepRecord], b: &[StepRecord]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(x, y)| {
            assert_eq!(x.step, y.step, "curves sampled at different steps");
            (x.loss - y.loss).abs()
        })
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_smooths() {
        let mut m = Metrics::in_memory();
        for s in 0..10 {
            m.log_step(s, 5.0 - s as f64 * 0.1, 1.0, 4096);
        }
        m.log_eval(9, 4.0);
        assert_eq!(m.steps.len(), 10);
        assert!((m.evals[0].perplexity - 4.0f64.exp()).abs() < 1e-9);
        let sm = m.smoothed_losses(3);
        assert_eq!(sm.len(), 8);
        assert!(sm[0].1 > sm.last().unwrap().1);
    }

    #[test]
    fn divergence() {
        let mk = |losses: &[f64]| -> Vec<StepRecord> {
            losses
                .iter()
                .enumerate()
                .map(|(i, &l)| StepRecord {
                    step: i as u64,
                    loss: l,
                    grad_norm: 0.0,
                    tokens_per_sec: 0.0,
                    elapsed: 0.0,
                })
                .collect()
        };
        let a = mk(&[3.0, 2.0, 1.0]);
        let b = mk(&[3.0, 2.2, 1.05]);
        assert!((curve_max_divergence(&a, &b) - 0.2).abs() < 1e-12);
    }

    #[test]
    fn start_run_reanchors_elapsed_for_resumed_runs() {
        let mut m = Metrics::in_memory();
        // Simulate a checkpoint-restored history: the prior run's last step
        // finished at elapsed = 100 s, but this process's clock just
        // started.  Without `start_run`, the next step's delta would be
        // ~0 − 100 s; the `dt` clamp would then report an absurd
        // throughput and a non-monotone elapsed column.
        m.steps.push(StepRecord {
            step: 9,
            loss: 3.0,
            grad_norm: 1.0,
            tokens_per_sec: 1000.0,
            elapsed: 100.0,
        });
        m.start_run();
        assert!(m.elapsed() >= 100.0, "elapsed must continue from the restored history");
        std::thread::sleep(std::time::Duration::from_millis(10));
        m.log_step(10, 2.9, 1.0, 1024);
        let r = *m.steps.last().unwrap();
        assert!(r.elapsed >= 100.0, "elapsed went backwards: {}", r.elapsed);
        assert!(
            r.tokens_per_sec.is_finite() && r.tokens_per_sec > 0.0,
            "throughput must be positive, got {}",
            r.tokens_per_sec
        );
        // 1024 tokens over >= 10 ms: anything near the clamp floor
        // (tokens / 1e-9) means the negative delta came back.
        assert!(r.tokens_per_sec < 1.0e9, "clamped stale delta: {}", r.tokens_per_sec);
    }

    #[test]
    fn jsonl_sink_writes() {
        let dir = std::env::temp_dir().join("cce_metrics_test");
        let mut m = Metrics::with_dir(&dir).unwrap();
        m.log_step(1, 2.5, 0.7, 512);
        drop(m);
        let text = std::fs::read_to_string(dir.join("metrics.jsonl")).unwrap();
        let parsed = Json::parse(text.lines().next().unwrap()).unwrap();
        assert_eq!(parsed.get("kind").unwrap().as_str(), Some("step"));
        assert_eq!(parsed.get("step").unwrap().as_i64(), Some(1));
    }
}
