//! Checkpointing: params + optimizer state + step, in a self-describing
//! binary format (JSON header + raw little-endian payload).
//!
//! Format:
//! ```text
//! magic "CCECKPT1" (8 bytes)
//! header_len: u64 LE
//! header: JSON  { step, tensors: [{name, shape, dtype, offset, bytes}],
//!                 payload_bytes, payload_crc32 }
//! payload: concatenated raw tensor data
//! ```
//!
//! Crash safety (PR 6): [`Checkpoint::save`] writes to `*.tmp`, fsyncs,
//! then atomically renames — a crash mid-save can never corrupt a
//! previously published checkpoint, and a torn `*.tmp` never loads (wrong
//! name AND failing integrity checks).  The header's `payload_bytes` +
//! `payload_crc32` ([`crate::util::crc32`]) let [`Checkpoint::load`]
//! reject truncation and bit-rot with a precise error; headers written
//! before these fields existed still load, with a warning.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::runtime::{DType, Data, HostTensor};
use crate::util::json::Json;
use crate::util::{crc32, faults};

const MAGIC: &[u8; 8] = b"CCECKPT1";

/// A named tensor collection with a step counter.
#[derive(Debug)]
pub struct Checkpoint {
    pub step: u64,
    pub tensors: Vec<(String, HostTensor)>,
}

impl Checkpoint {
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut payload: Vec<u8> = Vec::new();
        let mut entries = Vec::new();
        for (name, t) in &self.tensors {
            let offset = payload.len();
            write_data(&mut payload, &t.data);
            entries.push(Json::obj(vec![
                ("name", Json::str(name)),
                (
                    "shape",
                    Json::Array(t.shape.iter().map(|&d| Json::Int(d as i64)).collect()),
                ),
                ("dtype", Json::str(t.dtype().name())),
                ("offset", Json::Int(offset as i64)),
                ("bytes", Json::Int((payload.len() - offset) as i64)),
            ]));
        }
        let header = Json::obj(vec![
            ("step", Json::Int(self.step as i64)),
            ("tensors", Json::Array(entries)),
            // Integrity fields: the loader verifies both before trusting
            // any tensor bytes.
            ("payload_bytes", Json::Int(payload.len() as i64)),
            ("payload_crc32", Json::Int(crc32(&payload) as i64)),
        ])
        .to_string();

        let tmp = path.as_ref().with_extension("tmp");
        {
            let f = std::fs::File::create(&tmp)?;
            let mut w = std::io::BufWriter::new(&f);
            w.write_all(MAGIC)?;
            w.write_all(&(header.len() as u64).to_le_bytes())?;
            w.write_all(header.as_bytes())?;
            // Chaos site: a crash mid-payload leaves a torn tmp file and
            // must never reach the rename below.
            if faults::fire("ckpt.short_write") {
                w.write_all(&payload[..payload.len() / 2])?;
                w.flush()?;
                bail!(
                    "fault injected: ckpt.short_write (simulated crash before atomic \
                     publish; previous checkpoint untouched)"
                );
            }
            w.write_all(&payload)?;
            w.flush()?;
            // Durability before visibility: the rename must not land
            // before the bytes do.
            f.sync_all()?;
        }
        std::fs::rename(&tmp, path.as_ref())?; // atomic publish
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(&path)
                .with_context(|| format!("opening checkpoint {:?}", path.as_ref()))?,
        );
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("not a CCE checkpoint (bad magic)");
        }
        let mut len_bytes = [0u8; 8];
        f.read_exact(&mut len_bytes)?;
        let header_len = u64::from_le_bytes(len_bytes) as usize;
        let mut header_bytes = vec![0u8; header_len];
        f.read_exact(&mut header_bytes)?;
        let header = Json::parse(std::str::from_utf8(&header_bytes)?)?;
        let mut payload = Vec::new();
        f.read_to_end(&mut payload)?;

        // Integrity gate before any tensor is trusted.  Old headers
        // (pre-checksum) lack both fields — load them, but say so.
        match header.get("payload_bytes").and_then(Json::as_i64) {
            Some(expect) if expect as usize != payload.len() => bail!(
                "corrupt/truncated checkpoint {:?}: payload is {} bytes, header says {}",
                path.as_ref(),
                payload.len(),
                expect
            ),
            Some(_) => {
                if let Some(expect) = header.get("payload_crc32").and_then(Json::as_i64) {
                    let got = crc32(&payload);
                    if got as i64 != expect {
                        bail!(
                            "corrupt checkpoint {:?}: payload checksum mismatch \
                             (crc32 {got:#010x}, header says {:#010x})",
                            path.as_ref(),
                            expect as u32
                        );
                    }
                }
            }
            None => eprintln!(
                "[checkpoint] warning: {:?} predates payload checksums; \
                 integrity not verified",
                path.as_ref()
            ),
        }

        let step = header.req("step")?.as_i64().unwrap_or(0) as u64;
        let mut tensors = Vec::new();
        for e in header.req("tensors")?.as_array().unwrap_or(&[]) {
            let name = e.req("name")?.as_str().unwrap_or("").to_string();
            let shape: Vec<usize> = e
                .req("shape")?
                .as_array()
                .unwrap_or(&[])
                .iter()
                .filter_map(|v| v.as_i64().map(|i| i as usize))
                .collect();
            let dtype = DType::parse(e.req("dtype")?.as_str().unwrap_or(""))?;
            let offset = e.req("offset")?.as_i64().unwrap_or(0) as usize;
            let bytes = e.req("bytes")?.as_i64().unwrap_or(0) as usize;
            let slice = payload
                .get(offset..offset + bytes)
                .ok_or_else(|| anyhow!("checkpoint payload truncated"))?;
            let data = read_data(dtype, slice)?;
            tensors.push((name, HostTensor::new(shape, data)?));
        }
        Ok(Checkpoint { step, tensors })
    }
}

fn write_data(out: &mut Vec<u8>, data: &Data) {
    match data {
        Data::F32(v) => v.iter().for_each(|x| out.extend_from_slice(&x.to_le_bytes())),
        Data::I32(v) => v.iter().for_each(|x| out.extend_from_slice(&x.to_le_bytes())),
        Data::U32(v) => v.iter().for_each(|x| out.extend_from_slice(&x.to_le_bytes())),
        Data::F64(v) => v.iter().for_each(|x| out.extend_from_slice(&x.to_le_bytes())),
        Data::BF16(v) => v.iter().for_each(|x| out.extend_from_slice(&x.0.to_le_bytes())),
    }
}

fn read_data(dtype: DType, bytes: &[u8]) -> Result<Data> {
    let n = bytes.len() / dtype.size_bytes();
    Ok(match dtype {
        DType::F32 => Data::F32(
            bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect(),
        ),
        DType::I32 => Data::I32(
            bytes.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect(),
        ),
        DType::U32 => Data::U32(
            bytes.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect(),
        ),
        DType::F64 => Data::F64(
            bytes.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect(),
        ),
        DType::BF16 => Data::BF16(
            bytes
                .chunks_exact(2)
                .map(|c| crate::exec::BF16(u16::from_le_bytes(c.try_into().unwrap())))
                .collect(),
        ),
    })
    .and_then(|d: Data| {
        if d.len() == n {
            Ok(d)
        } else {
            bail!("payload size mismatch")
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let ckpt = Checkpoint {
            step: 123,
            tensors: vec![
                (
                    "embed".into(),
                    HostTensor::f32(vec![4, 3], (0..12).map(|i| i as f32 * 0.5).collect())
                        .unwrap(),
                ),
                ("step_tensor".into(), HostTensor::scalar_i32(9)),
            ],
        };
        let path = std::env::temp_dir().join("cce_ckpt_test.bin");
        ckpt.save(&path).unwrap();
        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(loaded.step, 123);
        assert_eq!(loaded.tensors.len(), 2);
        assert_eq!(loaded.tensors[0].0, "embed");
        assert_eq!(loaded.tensors[0].1, ckpt.tensors[0].1);
        assert_eq!(loaded.tensors[1].1.scalar().unwrap(), 9.0);
    }

    #[test]
    fn bf16_tensors_roundtrip() {
        use crate::exec::BF16;
        let vals: Vec<BF16> =
            [0.5f32, -1.25, 3.0e4, -7.5e-3].iter().map(|&x| BF16::from_f32(x)).collect();
        let ckpt = Checkpoint {
            step: 7,
            tensors: vec![("w".into(), HostTensor::bf16(vec![2, 2], vals.clone()).unwrap())],
        };
        let path = std::env::temp_dir().join("cce_ckpt_bf16.bin");
        ckpt.save(&path).unwrap();
        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(loaded.tensors[0].1.dtype(), DType::BF16);
        assert_eq!(loaded.tensors[0].1, ckpt.tensors[0].1, "bf16 payload must be bit-exact");
        // The payload really is half-width on disk: 8 header-described
        // bytes for 4 elements.
        assert_eq!(loaded.tensors[0].1.size_bytes(), 8);
    }

    #[test]
    fn rejects_bad_magic() {
        let path = std::env::temp_dir().join("cce_ckpt_bad.bin");
        std::fs::write(&path, b"NOTACKPT12345678").unwrap();
        assert!(Checkpoint::load(&path).is_err());
    }

    #[test]
    fn truncated_payload_detected() {
        let ckpt = Checkpoint {
            step: 1,
            tensors: vec![("x".into(), HostTensor::f32(vec![8], vec![1.0; 8]).unwrap())],
        };
        let path = std::env::temp_dir().join("cce_ckpt_trunc.bin");
        ckpt.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 8]).unwrap();
        let err = Checkpoint::load(&path).unwrap_err().to_string();
        assert!(err.contains("corrupt/truncated checkpoint"), "got: {err}");
    }

    #[test]
    fn bit_flip_in_payload_detected() {
        let ckpt = Checkpoint {
            step: 2,
            tensors: vec![("x".into(), HostTensor::f32(vec![8], vec![1.0; 8]).unwrap())],
        };
        let path = std::env::temp_dir().join("cce_ckpt_flip.bin");
        ckpt.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let last = bytes.len() - 3;
        bytes[last] ^= 0x10; // flip one payload bit; length unchanged
        std::fs::write(&path, &bytes).unwrap();
        let err = Checkpoint::load(&path).unwrap_err().to_string();
        assert!(err.contains("checksum mismatch"), "got: {err}");
    }

    #[test]
    fn legacy_checkpoints_without_checksums_still_load() {
        // Hand-build a pre-PR-6 file: same format, header without the
        // payload_bytes/payload_crc32 fields.
        let t = HostTensor::f32(vec![3], vec![1.0, 2.0, 3.0]).unwrap();
        let mut payload = Vec::new();
        write_data(&mut payload, &t.data);
        let header = Json::obj(vec![
            ("step", Json::Int(42)),
            (
                "tensors",
                Json::Array(vec![Json::obj(vec![
                    ("name", Json::str("x")),
                    ("shape", Json::Array(vec![Json::Int(3)])),
                    ("dtype", Json::str(DType::F32.name())),
                    ("offset", Json::Int(0)),
                    ("bytes", Json::Int(payload.len() as i64)),
                ])]),
            ),
        ])
        .to_string();
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&(header.len() as u64).to_le_bytes());
        bytes.extend_from_slice(header.as_bytes());
        bytes.extend_from_slice(&payload);
        let path = std::env::temp_dir().join("cce_ckpt_legacy.bin");
        std::fs::write(&path, &bytes).unwrap();
        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(loaded.step, 42);
        assert_eq!(loaded.tensors[0].1, t);
    }
}
