//! The L3 coordinator: configuration, training orchestration, checkpoints,
//! and metrics.  See [`trainer::Trainer`] for the event loop.

pub mod checkpoint;
pub mod config;
pub mod metrics;
pub mod trainer;

pub use checkpoint::Checkpoint;
pub use config::{CorpusKind, RunConfig};
pub use metrics::{curve_max_divergence, EvalRecord, Metrics, StepRecord};
pub use trainer::{TrainState, Trainer};
