//! The L3 coordinator: configuration, training orchestration, checkpoints,
//! and metrics.
//!
//! Two trainers share the data pipeline and metrics:
//!
//! * [`trainer::Trainer`] (behind the `pjrt` feature) drives the AOT
//!   transformer train-step artifacts through the PJRT runtime.
//! * [`native::NativeTrainer`] trains a bag-of-context classifier head
//!   end-to-end with the native CCE kernels ([`crate::exec`]) — zero
//!   artifacts, zero shared libraries.  `cce train --backend native`.

pub mod checkpoint;
pub mod config;
pub mod metrics;
pub mod native;
#[cfg(feature = "pjrt")]
pub mod trainer;

pub use checkpoint::Checkpoint;
pub use config::{CorpusKind, RunConfig};
pub use metrics::{curve_max_divergence, EvalRecord, Metrics, StepRecord};
pub use native::{bag_hidden, NativeBundle, NativeModelConfig, NativeState, NativeTrainer};
#[cfg(feature = "pjrt")]
pub use trainer::{TrainState, Trainer};
