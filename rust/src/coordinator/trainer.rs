//! The training orchestrator: drives the AOT train/eval artifacts.
//!
//! The Rust side owns everything around the compute: corpus generation, BPE
//! vocabulary, packing, the microbatch schedule (the `(accum, batch, seq)`
//! layout the artifact consumes), parameter/optimizer-state round-tripping,
//! evaluation, checkpointing and metrics.  One `train_step` call = one
//! optimizer step over `accum` microbatches (gradients accumulate *inside*
//! the artifact, so state crosses the PJRT boundary once per step).

use anyhow::{anyhow, bail, Result};

use crate::coordinator::checkpoint::Checkpoint;
use crate::coordinator::config::{CorpusKind, RunConfig};
use crate::coordinator::metrics::Metrics;
use crate::data::{instruct_corpus, web_corpus, Dataset, DatasetConfig, StepBatch};
use crate::runtime::{Executable, HostTensor, ModelMeta, Runtime};
use crate::tokenizer::{Tokenizer, TokenizerConfig};

/// Mutable training state: flat params + Adam moments + step counter.
pub struct TrainState {
    pub params: Vec<HostTensor>,
    pub m: Vec<HostTensor>,
    pub v: Vec<HostTensor>,
    pub step: i32,
}

impl TrainState {
    /// Fresh state from the `{tag}_init` artifact.
    pub fn init(rt: &Runtime, meta: &ModelMeta, seed: i32) -> Result<TrainState> {
        let init = rt.load(&format!("{}_init", meta.tag))?;
        let params = init.run(&[HostTensor::i32(vec![1], vec![seed])?])?;
        let zeros_like = |ps: &[HostTensor]| {
            ps.iter()
                .map(|p| HostTensor::zeros(crate::runtime::DType::F32, p.shape.clone()))
                .collect::<Vec<_>>()
        };
        let m = zeros_like(&params);
        let v = zeros_like(&params);
        Ok(TrainState { params, m, v, step: 0 })
    }

    pub fn param_bytes(&self) -> usize {
        self.params.iter().map(|p| p.size_bytes()).sum()
    }

    pub fn to_checkpoint(&self, meta: &ModelMeta) -> Checkpoint {
        let mut tensors = Vec::new();
        for (spec, t) in meta.params.iter().zip(&self.params) {
            tensors.push((format!("param:{}", spec.name), t.clone()));
        }
        for (spec, t) in meta.params.iter().zip(&self.m) {
            tensors.push((format!("m:{}", spec.name), t.clone()));
        }
        for (spec, t) in meta.params.iter().zip(&self.v) {
            tensors.push((format!("v:{}", spec.name), t.clone()));
        }
        Checkpoint { step: self.step as u64, tensors }
    }

    pub fn from_checkpoint(ckpt: Checkpoint, meta: &ModelMeta) -> Result<TrainState> {
        let n = meta.params.len();
        if ckpt.tensors.len() != 3 * n {
            bail!("checkpoint has {} tensors, expected {}", ckpt.tensors.len(), 3 * n);
        }
        let mut tensors = ckpt.tensors;
        let v = tensors.split_off(2 * n).into_iter().map(|(_, t)| t).collect();
        let m = tensors.split_off(n).into_iter().map(|(_, t)| t).collect();
        let params = tensors.into_iter().map(|(_, t)| t).collect();
        Ok(TrainState { params, m, v, step: ckpt.step as i32 })
    }
}

/// A ready-to-train bundle: runtime + artifacts + data + tokenizer.
pub struct Trainer<'rt> {
    pub rt: &'rt Runtime,
    pub meta: ModelMeta,
    pub cfg: RunConfig,
    pub tokenizer: Tokenizer,
    pub dataset: Dataset,
    train_exe: std::rc::Rc<Executable>,
    eval_exe: std::rc::Rc<Executable>,
}

impl<'rt> Trainer<'rt> {
    /// Build the full pipeline for `cfg`: generate the corpus, train the
    /// BPE vocabulary, pack the dataset, and load the artifacts.
    pub fn build(rt: &'rt Runtime, cfg: RunConfig) -> Result<Trainer<'rt>> {
        let meta = rt.manifest.model(&cfg.tag)?.clone();
        let docs = match cfg.corpus {
            CorpusKind::Web => web_corpus(cfg.corpus_docs, cfg.seed),
            CorpusKind::Instruct => instruct_corpus(cfg.corpus_docs, cfg.seed),
        };
        let texts: Vec<String> = docs.iter().map(|d| d.text.clone()).collect();
        // The artifact's embedding table is sized for the config vocab; the
        // tokenizer must not exceed it.
        let tok = Tokenizer::train(&texts, &TokenizerConfig {
            vocab_size: meta.vocab_size.min(cfg.vocab_size),
            min_pair_freq: 2,
        })?;
        let dataset = Dataset::build(&docs, &tok, &DatasetConfig {
            seq_len: meta.seq,
            val_fraction: 0.02,
            seed: cfg.seed,
            pad_per_doc: cfg.corpus == CorpusKind::Instruct,
        })?;
        let train_exe = rt.load(&format!("{}_train_step_{}", cfg.tag, cfg.method))?;
        let eval_exe = rt.load(&format!("{}_eval_step", cfg.tag))?;
        Ok(Trainer { rt, meta, cfg, tokenizer: tok, dataset, train_exe, eval_exe })
    }

    pub fn tokens_per_step(&self) -> u64 {
        (self.meta.accum * self.meta.batch * self.meta.seq) as u64
    }

    /// One optimizer step.  Consumes and returns the state (the artifact
    /// round-trips all tensors).
    pub fn step(&self, state: TrainState, batch: &StepBatch) -> Result<(TrainState, f64, f64)> {
        let n = state.params.len();
        let mut inputs =
            Vec::with_capacity(3 * n + 3);
        inputs.extend(state.params);
        inputs.extend(state.m);
        inputs.extend(state.v);
        inputs.push(HostTensor::scalar_i32(state.step));
        inputs.push(batch.tokens.clone());
        inputs.push(batch.targets.clone());

        let mut out = self.train_exe.run(&inputs)?;
        if out.len() != 3 * n + 3 {
            bail!("train_step returned {} outputs, expected {}", out.len(), 3 * n + 3);
        }
        let grad_norm = out.pop().unwrap().scalar()?;
        let loss = out.pop().unwrap().scalar()?;
        let step = out.pop().unwrap().scalar()? as i32;
        let v = out.split_off(2 * n);
        let m = out.split_off(n);
        let params = out;
        Ok((TrainState { params, m, v, step }, loss, grad_norm))
    }

    /// Mean validation NLL over all validation batches.
    pub fn evaluate(&self, state: &TrainState) -> Result<f64> {
        let batches = self.dataset.val_batches(self.meta.batch);
        if batches.is_empty() {
            bail!("validation set smaller than one batch");
        }
        let (mut loss_sum, mut count) = (0.0, 0.0);
        for b in &batches {
            let mut inputs = state.params.clone();
            inputs.push(b.tokens.clone());
            inputs.push(b.targets.clone());
            let out = self.eval_exe.run(&inputs)?;
            loss_sum += out[0].scalar()?;
            count += out[1].scalar()?;
        }
        Ok(loss_sum / count.max(1.0))
    }

    /// Run the full training loop; returns the final state.
    pub fn train(&self, mut state: TrainState, metrics: &mut Metrics) -> Result<TrainState> {
        // Re-anchor the metrics clock: a resumed run carries restored step
        // history whose elapsed values came from an earlier process.
        metrics.start_run();
        let mut done: u64 = state.step as u64;
        let mut epoch: u64 = 0;
        let out_dir = std::path::Path::new(&self.cfg.out_dir);
        'outer: loop {
            let mut saw_batch = false;
            for batch in self
                .dataset
                .step_batches(self.meta.accum, self.meta.batch, epoch)
            {
                saw_batch = true;
                let (next, loss, gnorm) = self.step(state, &batch)?;
                state = next;
                done += 1;
                if done % self.cfg.log_every.max(1) == 0 || done == 1 {
                    metrics.log_step(done, loss, gnorm, self.tokens_per_step());
                    eprintln!(
                        "[train {}/{}] step {done}/{} loss {loss:.4} gnorm {gnorm:.3} ({:.0} tok/s)",
                        self.cfg.tag,
                        self.cfg.method,
                        self.cfg.steps,
                        metrics.steps.last().map(|r| r.tokens_per_sec).unwrap_or(0.0)
                    );
                } else {
                    metrics.log_step(done, loss, gnorm, self.tokens_per_step());
                }
                if self.cfg.eval_every > 0 && done % self.cfg.eval_every == 0 {
                    let val = self.evaluate(&state)?;
                    metrics.log_eval(done, val);
                    eprintln!(
                        "[eval  {}/{}] step {done} val_loss {val:.4} ppl {:.2}",
                        self.cfg.tag,
                        self.cfg.method,
                        val.exp()
                    );
                }
                if self.cfg.checkpoint_every > 0 && done % self.cfg.checkpoint_every == 0 {
                    let path = out_dir.join(format!("ckpt_{done}.bin"));
                    self.to_checkpoint_with_vocab(&state, &path)?;
                }
                if done >= self.cfg.steps {
                    break 'outer;
                }
            }
            if !saw_batch {
                return Err(anyhow!(
                    "dataset too small: no step batches (need {} sequences/step)",
                    self.meta.accum * self.meta.batch
                ));
            }
            epoch += 1;
        }
        Ok(state)
    }

    /// Save checkpoint + tokenizer next to it.
    pub fn to_checkpoint_with_vocab(
        &self,
        state: &TrainState,
        path: &std::path::Path,
    ) -> Result<()> {
        state.to_checkpoint(&self.meta).save(path)?;
        self.tokenizer
            .save(path.with_extension("vocab.json"))?;
        Ok(())
    }
}
