//! `cce` — the launcher CLI for the Cut Cross-Entropy reproduction.
//!
//! ```text
//! cce train   [--backend native|pjrt] [--method cce] [--steps N] ...
//! cce eval    --checkpoint path [--backend native|pjrt] [--tag e2e]
//! cce serve   --checkpoint [tag=]path ... | --demo  [--port 7343, 0 = ephemeral]
//!             [--max-batch 8] [--max-wait-ms 3] [--queue-depth 64]
//!             [--http-addr 127.0.0.1:8080 — REST front door: POST
//!              /v1/generate (SSE with "stream":true), POST /v1/score,
//!              GET /metrics, GET /healthz; see docs/http_api.md]
//!             [--metrics-addr — legacy alias for --http-addr]
//!             [--supervise — run the listener as a restarted-on-crash
//!              child: --supervise-max-failures 5 --supervise-window-ms
//!              60000 --supervise-backoff-ms 200; crash loop → exit 86]
//!             [--brownout-queue-ms 0 — degrade generate requests when the
//!              queue-delay EWMA exceeds this (0 = off)]
//!             [--max-workspace-bytes 0 — reject score requests whose
//!              O(N·D + threads·N_B·V_B) workspace would exceed this]
//!             (--checkpoint repeats: the first entry is the default model,
//!              requests route with their "model" field; SIGTERM/SIGINT
//!              drain gracefully)
//! cce client  --port P [--op generate|score|info|metrics|shutdown]
//!             [--prompt "..."] [--text "..."] [--top-k K] [--temperature T]
//!             [--model TAG — route to a named model]
//!             [--trace — echo per-stage timings in the response]
//! cce servebench [--demo | --checkpoint path] [--requests 64]
//!             [--concurrency 8] [--repeats 3] [--dtype f32|bf16]
//!             [--http — drive POST /v1/generate instead of line-JSON]
//!             [--scrape — persist server-side histograms]
//!             [--json BENCH_serve.json]
//! cce table1  [--backend native|pjrt] [--json BENCH_table1.json]
//!             [--n 1024 --d 256 --v 4096] [--threads N] [--dtype f32|bf16]
//!             [--small-n 8] [--check]
//! cce tableA1 (= table1 with the Appendix B ignored-token filter)
//! cce tableA2 / tableA3
//! cce fig1    [--tokens 65536] [--gpus 16] [--gpu-gb 75]
//! cce fig3    [--backend native|pjrt] [--checkpoint path | --warm-steps N]
//! cce fig4 / fig5 [--steps N] [--tag e2e|tiny]
//! cce figA1   [--backend native|pjrt] [--budget-ms 2000] [--dtype f32|bf16]
//!             [--json BENCH_figA1.json]
//! cce info    — backend + manifest summary
//! cce shard-worker [--host 127.0.0.1] [--port 0 = ephemeral]
//!             [--threads 0 = use the coordinator's kernel options]
//!             — one vocabulary-shard worker process; announces
//!             `[shard] ready proto=line addr=HOST:PORT` on stdout
//!             (see docs/sharding.md)
//! ```
//!
//! Vocabulary sharding (train/eval/serve/servebench): `--shards N`
//! auto-spawns N loopback worker processes; `--shard-endpoints
//! host:port,...` attaches already-running `cce shard-worker` processes
//! (shard k = entry k — the multi-node path).  The classifier splits into
//! contiguous column shards; see docs/sharding.md for the protocol and
//! exactness contract.
//!
//! `--backend native` (the default in builds without the `pjrt` feature)
//! runs the multi-threaded SIMD Rust kernels with zero artifacts;
//! `--backend pjrt` replays the AOT HLO artifacts and needs the `pjrt`
//! feature plus `make artifacts`.  `--threads N` sizes the native worker
//! spans (`0` = auto = available parallelism, the default; workers live in
//! a persistent process-wide pool).  `--dtype f32|bf16` selects the
//! *storage* dtype of parameters/activations/gradients on
//! train/eval/table1/figA1/servebench (accumulation stays f32/f64; serve
//! defaults to the checkpoint's stored dtype).  Native `--method` keys:
//! `cce`, `cce_no_sort`, `cce_no_filter`, `cce_kahan`, `cce_kahan_fullc`,
//! `cce_kahan_fulle`, `chunked<k>`, `baseline`.

use anyhow::{bail, Result};

use cce::bench;
use cce::coordinator::{Metrics, NativeModelConfig, NativeTrainer, RunConfig};
use cce::exec::{self, KernelOptions, StoreDtype};
use cce::util::cli::Args;

#[cfg(feature = "pjrt")]
use cce::coordinator::{Checkpoint, CorpusKind, TrainState, Trainer};
#[cfg(feature = "pjrt")]
use cce::runtime;

fn main() {
    if let Err(err) = run() {
        eprintln!("error: {err:#}");
        std::process::exit(1);
    }
}

fn usage() -> ! {
    eprintln!(
        "usage: cce <command> [options]\n\ncommands:\n  \
         train      run a training job (--backend/--method/--steps/--corpus/...)\n  \
         eval       evaluate a checkpoint (--checkpoint) [--backend]\n  \
         serve      serve checkpoints over TCP + HTTP (--checkpoint [tag=]path\n             \
                    repeatable, --demo, --port, --http-addr, --drain-ms,\n             \
                    --idle-timeout-ms, --supervise, --brownout-queue-ms,\n             \
                    --max-workspace-bytes; --metrics-addr = legacy --http-addr)\n  \
         client     one-shot client for a running server (--port, --op,\n             \
                    --model, --timeout-ms, --retries, --deadline-ms, --trace)\n  \
         servebench serving throughput/latency harness [--json]\n             \
                    (--timeout-ms, --retries, --scrape, --http)\n  \
         table1     Table 1: memory & time per method [--backend/--json]\n  \
         tableA1    Table A1: Table 1 with ignored tokens removed\n  \
         tableA2    Table A2: backward-pass breakdown (pjrt)\n  \
         tableA3    Table A3: additional models memory\n  \
         fig1       Fig. 1 / Table A4: model-zoo memory & max batch\n  \
         fig3       Fig. 3: softmax rank probabilities [--backend]\n  \
         fig4       Fig. 4: fine-tune loss curves, cce vs fused (pjrt)\n  \
         fig5       Fig. 5: pretrain val perplexity (pjrt)\n  \
         figA1      Figs. A1/A2: time/memory vs token count [--backend]\n  \
         info       backend + manifest summary\n  \
         shard-worker  one vocabulary-shard worker (--host, --port,\n             \
                    --threads; coordinator flags: --shards N or\n             \
                    --shard-endpoints host:port,... on train/eval/serve)"
    );
    std::process::exit(2);
}

/// Which compute backend a command should use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum BackendChoice {
    Native,
    Pjrt,
}

fn backend_choice(args: &Args) -> Result<BackendChoice> {
    let default = if cfg!(feature = "pjrt") { "pjrt" } else { "native" };
    match args.get("backend", default.to_string())?.as_str() {
        "native" => Ok(BackendChoice::Native),
        "pjrt" => {
            if cfg!(feature = "pjrt") {
                Ok(BackendChoice::Pjrt)
            } else {
                bail!(
                    "this binary was built without the `pjrt` feature; \
                     rebuild with `cargo build --features pjrt` (needs the \
                     real xla bindings) or use --backend native"
                )
            }
        }
        other => bail!("unknown backend {other:?} (native|pjrt)"),
    }
}

/// Native kernel options from the shared CLI flags.  `--threads 0` means
/// "auto" (available parallelism) on every path — train, eval, serve,
/// servebench, table1, fig3, figA1, info — and the resolved count is what
/// `{"op":"info"}` and the BENCH metadata report.  `--dtype f32|bf16`
/// selects the storage dtype of parameters / activations / gradients
/// (accumulation stays f32/f64; serve defaults to the checkpoint's own
/// dtype instead — see [`dtype_override`]).
fn kernel_options(args: &Args) -> Result<KernelOptions> {
    let defaults = KernelOptions::default();
    Ok(KernelOptions {
        threads: exec::resolve_threads(args.get("threads", 0usize)?),
        n_block: args.get("n-block", defaults.n_block)?,
        v_block: args.get("v-block", defaults.v_block)?,
        dtype: match args.opt("dtype") {
            None => defaults.dtype,
            Some(s) => StoreDtype::parse(s)?,
        },
        ..defaults
    })
}

/// An *explicit* `--dtype` flag, or `None` when absent — the serving path
/// keeps the checkpoint's stored dtype unless the operator asks for a
/// load-time conversion.
fn dtype_override(args: &Args) -> Result<Option<StoreDtype>> {
    args.opt("dtype").map(StoreDtype::parse).transpose()
}

/// Optional vocabulary-shard fleet from the shared CLI flags:
/// `--shards N` auto-spawns N loopback `cce shard-worker` children on
/// ephemeral ports; `--shard-endpoints host:port,...` attaches workers
/// already running elsewhere (shard k serves `endpoints[k]` — the
/// multi-node deployment path).  The two are mutually exclusive.
fn shard_fleet(args: &Args, v: usize, d: usize) -> Result<Option<std::sync::Arc<cce::shard::Fleet>>> {
    let shards = args.get("shards", 0usize)?;
    let endpoints = args.opt("shard-endpoints");
    match (shards, endpoints) {
        (0, None) => Ok(None),
        (n, None) => Ok(Some(std::sync::Arc::new(cce::shard::Fleet::spawn(n, v, d)?))),
        (0, Some(list)) => {
            let eps: Vec<String> = list
                .split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect();
            if eps.is_empty() {
                bail!("--shard-endpoints needs at least one host:port");
            }
            Ok(Some(std::sync::Arc::new(cce::shard::Fleet::connect(&eps, v, d)?)))
        }
        (_, Some(_)) => bail!("--shards and --shard-endpoints are mutually exclusive"),
    }
}

/// Whether either shard flag is present (used to fail fast on
/// configurations sharding does not cover before any model loads).
fn shard_requested(args: &Args) -> bool {
    args.get("shards", 0usize).map(|n| n > 0).unwrap_or(false)
        || args.opt("shard-endpoints").is_some()
}

/// `cce shard-worker`: one vocabulary-shard worker process.  Binds
/// `--host`/`--port` (0 = ephemeral), announces `[shard] ready
/// proto=line addr=HOST:PORT` on stdout, then serves shard collectives
/// until a `shutdown` request.  `--threads 0` (the default) runs with
/// the kernel options the coordinator ships in `load`; a nonzero value
/// overrides the thread count for this worker's machine.
fn cmd_shard_worker(args: &Args) -> Result<()> {
    let host = args.get("host", "127.0.0.1".to_string())?;
    let port = args.get("port", 0u16)?;
    let threads = match args.get("threads", 0usize)? {
        0 => None,
        t => Some(t),
    };
    cce::shard::run_worker(&host, port, threads)
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_unavailable(cmd: &str) -> Result<()> {
    bail!(
        "`cce {cmd}` drives AOT artifacts and needs the `pjrt` feature \
         (cargo build --features pjrt, plus `make artifacts`); the native \
         backend covers train/eval/serve/table1/fig3/figA1/info"
    )
}

fn run() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args =
        Args::parse(argv, &["check", "verbose", "demo", "scrape", "trace", "http", "supervise"])?;
    let cmd = match args.positional.first() {
        Some(c) => c.as_str(),
        None => usage(),
    };

    match cmd {
        "train" => cmd_train(&args),
        "eval" => cmd_eval(&args),
        "serve" => cmd_serve(&args),
        "client" => cmd_client(&args),
        "servebench" | "serve-bench" => cmd_servebench(&args),
        "table1" => cmd_table1(&args, 0.0),
        "tableA1" | "tablea1" => {
            let frac = args.get("ignored", 0.35f64)?;
            cmd_table1(&args, frac)
        }
        "tableA2" | "tablea2" => cmd_tablea2(&args),
        "tableA3" | "tablea3" => bench::tablea3::run(args.opt("csv")),
        "fig1" => bench::fig1::run(
            args.get("tokens", 65_536u64)?,
            args.get("gpus", 16u64)?,
            args.get("gpu-gb", 75u64)?,
            args.opt("csv"),
        ),
        "fig3" => cmd_fig3(&args),
        "fig4" => cmd_curves(&args, true),
        "fig5" => cmd_curves(&args, false),
        "figA1" | "figa1" | "figA2" | "figa2" => cmd_sweep(&args),
        "info" => cmd_info(&args),
        "shard-worker" => cmd_shard_worker(&args),
        other => {
            eprintln!("unknown command {other:?}\n");
            usage()
        }
    }
}

// ------------------------------------------------------------------- train

fn cmd_train(args: &Args) -> Result<()> {
    match backend_choice(args)? {
        BackendChoice::Native => cmd_train_native(args),
        BackendChoice::Pjrt => cmd_train_pjrt(args),
    }
}

fn cmd_train_native(args: &Args) -> Result<()> {
    let mut cfg = match args.opt("config") {
        Some(path) => RunConfig::load(path)?,
        None => RunConfig::default(),
    };
    cfg.apply_args(args)?;
    cfg.vocab_size = args.get("vocab-size", cfg.vocab_size.min(4096))?;
    let model = NativeModelConfig {
        d_model: args.get("dim", NativeModelConfig::default().d_model)?,
        window: args.get("window", NativeModelConfig::default().window)?,
        lr: args.get("lr", NativeModelConfig::default().lr)?,
        batch: args.get("batch", NativeModelConfig::default().batch)?,
        seq_len: args.get("seq", NativeModelConfig::default().seq_len)?,
    };
    let opts = kernel_options(args)?;
    let mut trainer = NativeTrainer::build(cfg.clone(), model, opts)?;
    if let Some(fleet) = shard_fleet(args, trainer.vocab, model.d_model)? {
        eprintln!(
            "[cce] vocab sharding: {} workers ({})",
            fleet.shard_count(),
            fleet.endpoints().join(", ")
        );
        trainer.attach_fleet(fleet)?;
    }
    eprintln!(
        "[cce] backend native ({} threads) | bag-of-context head d={} | method {}",
        opts.threads, model.d_model, cfg.method
    );
    eprintln!(
        "[cce] corpus: {} train sequences, {} val | vocab {} | ignored {:.1}%",
        trainer.dataset.train.len(),
        trainer.dataset.val.len(),
        trainer.tokenizer.vocab_size(),
        100.0 * trainer.dataset.ignored_fraction()
    );
    let state = match args.opt("checkpoint") {
        // Resuming keeps the checkpoint's stored dtype unless --dtype
        // explicitly asks for a conversion (an old f32 checkpoint keeps
        // loading under --dtype bf16, and a bf16 checkpoint is never
        // silently widened back to f32).
        Some(path) => cce::coordinator::NativeState::from_checkpoint(
            cce::coordinator::Checkpoint::load(path)?,
            trainer.vocab,
            trainer.model.d_model,
            dtype_override(args)?,
        )?,
        None => trainer.init(cfg.seed),
    };
    let mut metrics = Metrics::with_dir(&cfg.out_dir)?;
    let state = trainer.train(state, &mut metrics)?;
    let final_val = trainer.evaluate(&state)?;
    metrics.log_eval(state.step, final_val);
    metrics.write_csv(std::path::Path::new(&cfg.out_dir).join("loss_curve.csv"))?;
    let ckpt_path = std::path::Path::new(&cfg.out_dir).join("final.ckpt");
    trainer.save_checkpoint(&state, &ckpt_path)?;
    std::fs::write(
        std::path::Path::new(&cfg.out_dir).join("config.json"),
        cfg.to_json().to_string_pretty(),
    )?;
    println!(
        "[cce] done: step {} val_loss {final_val:.4} ppl {:.2} mean {:.0} tok/s -> {}",
        state.step,
        final_val.exp(),
        metrics.mean_throughput(),
        ckpt_path.display()
    );
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_train_pjrt(args: &Args) -> Result<()> {
    let mut cfg = match args.opt("config") {
        Some(path) => RunConfig::load(path)?,
        None => RunConfig::default(),
    };
    cfg.apply_args(args)?;
    let rt = runtime::open_default()?;
    eprintln!(
        "[cce] platform {} | model {} ({} params) | method {}",
        rt.platform(),
        cfg.tag,
        rt.manifest.model(&cfg.tag)?.param_count,
        cfg.method
    );
    let trainer = Trainer::build(&rt, cfg.clone())?;
    eprintln!(
        "[cce] corpus: {} train sequences, {} val | vocab {} | ignored {:.1}%",
        trainer.dataset.train.len(),
        trainer.dataset.val.len(),
        trainer.tokenizer.vocab_size(),
        100.0 * trainer.dataset.ignored_fraction()
    );
    let state = match args.opt("checkpoint") {
        Some(path) => TrainState::from_checkpoint(Checkpoint::load(path)?, &trainer.meta)?,
        None => TrainState::init(&rt, &trainer.meta, cfg.seed as i32)?,
    };
    let mut metrics = Metrics::with_dir(&cfg.out_dir)?;
    let state = trainer.train(state, &mut metrics)?;
    let final_val = trainer.evaluate(&state)?;
    metrics.log_eval(state.step as u64, final_val);
    metrics.write_csv(std::path::Path::new(&cfg.out_dir).join("loss_curve.csv"))?;
    let ckpt_path = std::path::Path::new(&cfg.out_dir).join("final.ckpt");
    trainer.to_checkpoint_with_vocab(&state, &ckpt_path)?;
    std::fs::write(
        std::path::Path::new(&cfg.out_dir).join("config.json"),
        cfg.to_json().to_string_pretty(),
    )?;
    println!(
        "[cce] done: step {} val_loss {final_val:.4} ppl {:.2} mean {:.0} tok/s -> {}",
        state.step,
        final_val.exp(),
        metrics.mean_throughput(),
        ckpt_path.display()
    );
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_train_pjrt(_args: &Args) -> Result<()> {
    pjrt_unavailable("train --backend pjrt")
}

// -------------------------------------------------------------------- eval

fn cmd_eval(args: &Args) -> Result<()> {
    match backend_choice(args)? {
        BackendChoice::Native => cmd_eval_native(args),
        BackendChoice::Pjrt => cmd_eval_pjrt(args),
    }
}

fn cmd_eval_native(args: &Args) -> Result<()> {
    let path = args.require("checkpoint")?.to_string();
    let mut cfg = RunConfig::default();
    cfg.apply_args(args)?;
    cfg.vocab_size = args.get("vocab-size", cfg.vocab_size.min(4096))?;
    let model = NativeModelConfig {
        d_model: args.get("dim", NativeModelConfig::default().d_model)?,
        window: args.get("window", NativeModelConfig::default().window)?,
        lr: args.get("lr", NativeModelConfig::default().lr)?,
        batch: args.get("batch", NativeModelConfig::default().batch)?,
        seq_len: args.get("seq", NativeModelConfig::default().seq_len)?,
    };
    let opts = kernel_options(args)?;
    let mut trainer = NativeTrainer::build(cfg, model, opts)?;
    if let Some(fleet) = shard_fleet(args, trainer.vocab, model.d_model)? {
        trainer.attach_fleet(fleet)?;
    }
    // Evaluate in the checkpoint's own dtype unless --dtype asks to
    // convert at load.
    let state = cce::coordinator::NativeState::from_checkpoint(
        cce::coordinator::Checkpoint::load(&path)?,
        trainer.vocab,
        trainer.model.d_model,
        dtype_override(args)?,
    )?;
    trainer.fleet_load(&state)?;
    let val = trainer.evaluate(&state)?;
    println!("val_loss {val:.4}  perplexity {:.2}  (step {})", val.exp(), state.step);
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_eval_pjrt(args: &Args) -> Result<()> {
    let path = args.require("checkpoint")?.to_string();
    let mut cfg = RunConfig::default();
    cfg.apply_args(args)?;
    let rt = runtime::open_default()?;
    let trainer = Trainer::build(&rt, cfg)?;
    let state = TrainState::from_checkpoint(Checkpoint::load(&path)?, &trainer.meta)?;
    let val = trainer.evaluate(&state)?;
    println!("val_loss {val:.4}  perplexity {:.2}  (step {})", val.exp(), state.step);
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_eval_pjrt(_args: &Args) -> Result<()> {
    pjrt_unavailable("eval --backend pjrt")
}

// ------------------------------------------------------------------- serve

/// Build the serving model table from `--checkpoint` (repeatable,
/// `[tag=]path`; an untagged path gets the tag `default`) or `--demo`.
/// The first entry is the default route.  With `default_demo`, a missing
/// `--checkpoint` implies `--demo` (used by `servebench`, which should run
/// out of the box) — one construction path, so `serve --demo` and
/// `servebench` always agree on the demo model.
fn build_engines(
    args: &Args,
    opts: KernelOptions,
    default_demo: bool,
) -> Result<Vec<(String, std::sync::Arc<cce::serve::Engine>)>> {
    let specs = args.opt_all("checkpoint");
    if args.flag("demo") || (default_demo && specs.is_empty()) {
        let vocab = args.get("vocab-size", 512usize)?;
        let dim = args.get("dim", 32usize)?;
        let steps = args.get("demo-steps", 4u64)?;
        eprintln!(
            "[serve] --demo: training a tiny bag-of-context model \
             ({steps} steps, vocab {vocab}, d {dim}) — no checkpoint needed"
        );
        let mut engine = cce::serve::Engine::demo(vocab, dim, steps, opts)?;
        if let Some(fleet) = shard_fleet(args, engine.vocab, engine.d_model)? {
            eprintln!(
                "[serve] vocab sharding: {} workers ({})",
                fleet.shard_count(),
                fleet.endpoints().join(", ")
            );
            engine.attach_fleet(fleet)?;
        }
        return Ok(vec![("default".to_string(), std::sync::Arc::new(engine))]);
    }
    if specs.is_empty() {
        bail!("serve needs --checkpoint [tag=]path (repeatable; or --demo for a throwaway model)");
    }
    if shard_requested(args) && specs.len() > 1 {
        bail!(
            "vocabulary sharding serves a single model: one fleet owns one \
             classifier (drop the extra --checkpoint entries or the shard flags)"
        );
    }
    // No --window flag: trust the checkpoint's .model.json sidecar.
    let window = match args.opt("window") {
        Some(w) => Some(w.parse::<usize>().map_err(|e| anyhow::anyhow!("--window={w}: {e}"))?),
        None => None,
    };
    let dtype = dtype_override(args)?;
    let mut models = Vec::new();
    for spec in &specs {
        // `tag=path`; a bare path serves under the tag `default`.
        let (tag, path) = match spec.split_once('=') {
            Some((tag, path)) => (tag.to_string(), path),
            None => ("default".to_string(), spec.as_str()),
        };
        if models.iter().any(|(seen, _)| *seen == tag) {
            bail!("duplicate model tag {tag:?} in --checkpoint");
        }
        let mut engine = cce::serve::Engine::from_checkpoint(
            std::path::Path::new(path),
            window,
            dtype,
            opts,
        )?;
        if let Some(fleet) = shard_fleet(args, engine.vocab, engine.d_model)? {
            eprintln!(
                "[serve] vocab sharding: {} workers ({})",
                fleet.shard_count(),
                fleet.endpoints().join(", ")
            );
            engine.attach_fleet(fleet)?;
        }
        models.push((tag, std::sync::Arc::new(engine)));
    }
    Ok(models)
}

fn cmd_serve(args: &Args) -> Result<()> {
    if args.flag("supervise") {
        // Parent/supervisor role: re-exec ourselves without the
        // --supervise* flags as the actual listener, restart it on crash,
        // forward SIGTERM as drain.  Checkpoints load in the child only.
        let sup = cce::serve::SupervisorConfig {
            max_failures: args.get("supervise-max-failures", 5usize)?,
            window: std::time::Duration::from_millis(args.get("supervise-window-ms", 60_000u64)?),
            backoff: std::time::Duration::from_millis(args.get("supervise-backoff-ms", 200u64)?),
            ..cce::serve::SupervisorConfig::default()
        };
        let argv: Vec<String> = std::env::args().skip(1).collect();
        let child_args = cce::serve::supervisor::strip_supervise_flags(&argv);
        let code = cce::serve::supervisor::run(&child_args, &sup)?;
        std::process::exit(code);
    }
    let opts = kernel_options(args)?;
    let models = build_engines(args, opts, false)?;
    let cfg = cce::serve::ServeConfig {
        host: args.get("host", "127.0.0.1".to_string())?,
        port: args.get("port", 7343u16)?,
        workers: args.get("workers", 2usize)?,
        max_batch: args.get("max-batch", 8usize)?,
        max_wait: std::time::Duration::from_millis(args.get("max-wait-ms", 3u64)?),
        queue_depth: args.get("queue-depth", 64usize)?,
        idle_timeout: std::time::Duration::from_millis(
            args.get("idle-timeout-ms", 300_000u64)?,
        ),
        drain: std::time::Duration::from_millis(args.get("drain-ms", 5_000u64)?),
        metrics_addr: args.opt("metrics-addr").map(|s| s.to_string()),
        http_addr: args.opt("http-addr").map(|s| s.to_string()),
        brownout_queue_ms: args.get("brownout-queue-ms", 0u64)?,
        max_workspace_bytes: args.get("max-workspace-bytes", 0u64)?,
    };
    for (tag, engine) in &models {
        eprintln!(
            "[serve] model {tag}: vocab {} d {} window {} step {} dtype {} ({:.1} MB params) | \
             {} kernel threads, {} batch workers, max batch {}",
            engine.vocab,
            engine.d_model,
            engine.window,
            engine.step(),
            engine.dtype().name(),
            engine.param_bytes() as f64 / (1024.0 * 1024.0),
            opts.threads,
            cfg.workers,
            cfg.max_batch
        );
    }
    let server = cce::serve::serve_multi(models, &cfg)?;
    // Machine-parseable announce lines on stdout (documented in
    // docs/http_api.md): the CI smoke test and scripts read the bound
    // (possibly ephemeral) ports from them.
    println!("[serve] ready proto=line addr={}", server.addr);
    if let Some(addr) = server.http_addr() {
        println!("[serve] ready proto=http addr={addr}");
    }
    use std::io::Write as _;
    std::io::stdout().flush()?;
    // SIGTERM/SIGINT → graceful drain (same path as the `shutdown` op).
    // Under `--supervise` the parent forwards its own SIGTERM here.
    if cce::util::signal::install() {
        let stopper = server.stopper();
        std::thread::spawn(move || loop {
            if cce::util::signal::drain_requested() {
                eprintln!(
                    "[serve] signal {} received; draining",
                    cce::util::signal::last_signal()
                );
                stopper.stop();
                return;
            }
            if stopper.stopped() {
                return;
            }
            std::thread::sleep(std::time::Duration::from_millis(100));
        });
    }
    server.join()?;
    println!("[serve] shut down cleanly");
    Ok(())
}

fn cmd_client(args: &Args) -> Result<()> {
    use cce::serve::{Client, ClientConfig, GenParams, RetryPolicy};
    let host = args.get("host", "127.0.0.1".to_string())?;
    let port: u16 = args.get("port", 7343u16)?;
    // --timeout-ms 0 = block forever; retries cover `overloaded` responses
    // and transport failures with backoff + jitter.
    let timeout_ms = args.get("timeout-ms", 10_000u64)?;
    let timeout = (timeout_ms > 0).then(|| std::time::Duration::from_millis(timeout_ms));
    let cfg = ClientConfig {
        connect_timeout: timeout,
        io_timeout: timeout,
        retry: RetryPolicy { retries: args.get("retries", 2u32)?, ..RetryPolicy::default() },
    };
    let mut client = Client::connect_with((host.as_str(), port), cfg)?;
    let op = args.get("op", "generate".to_string())?;
    let response = match op.as_str() {
        "generate" => client.generate(GenParams {
            prompt: args.get("prompt", String::new())?,
            max_tokens: args.get("max-tokens", 32usize)?,
            top_k: args.get("top-k", 0usize)?,
            temperature: args.get("temperature", 0.0f32)?,
            seed: args.get("seed", 0u64)?,
            deadline_ms: args.get("deadline-ms", 0u64)?,
            trace: args.flag("trace"),
            model: args.opt("model").map(String::from),
        })?,
        "score" => {
            let text = args.get("text", "the cat sat on the mat".to_string())?;
            client.call_ok(&cce::serve::Request::Score {
                text,
                deadline_ms: args.get("deadline-ms", 0u64)?,
                trace: args.flag("trace"),
                model: args.opt("model").map(String::from),
            })?
        }
        "info" => client.info()?,
        "metrics" => client.metrics()?,
        "shutdown" => client.shutdown()?,
        other => bail!("unknown --op {other:?} (generate|score|info|metrics|shutdown)"),
    };
    println!("{}", response.to_line());
    Ok(())
}

fn cmd_servebench(args: &Args) -> Result<()> {
    use cce::bench::serve as sb;
    let opts = kernel_options(args)?;
    // No checkpoint: same demo engine `cce serve --demo` would run.
    let engine = build_engines(args, opts, true)?
        .into_iter()
        .next()
        .map(|(_, engine)| engine)
        .expect("build_engines returns at least one model");
    let timeout_ms = args.get("timeout-ms", 30_000u64)?;
    let cfg = sb::ServeBenchConfig {
        requests: args.get("requests", 64usize)?,
        concurrency: args.get("concurrency", 8usize)?,
        max_tokens: args.get("max-tokens", 16usize)?,
        timeout: (timeout_ms > 0).then(|| std::time::Duration::from_millis(timeout_ms)),
        retries: args.get("retries", 2u32)?,
        scrape: args.flag("scrape"),
        http: args.flag("http"),
        serve: cce::serve::ServeConfig {
            workers: args.get("workers", 2usize)?,
            max_batch: args.get("max-batch", 8usize)?,
            max_wait: std::time::Duration::from_millis(args.get("max-wait-ms", 3u64)?),
            queue_depth: args.get("queue-depth", 64usize)?,
            ..cce::serve::ServeConfig::default()
        },
    };
    let repeats = args.get("repeats", 3usize)?;
    let bench = sb::run_repeated(engine, &cfg, repeats)?;
    sb::print(&bench);
    if let Some(path) = args.opt("json") {
        sb::write_json(&bench, path)?;
        println!("  wrote {path}");
    }
    Ok(())
}

// ------------------------------------------------------------------ table1

fn cmd_table1(args: &Args, ignored: f64) -> Result<()> {
    let title_suffix = if ignored > 0.0 {
        format!("Table A1: Table 1 with {:.0}% ignored tokens", ignored * 100.0)
    } else {
        "Table 1: memory & time per cross-entropy implementation".to_string()
    };
    match backend_choice(args)? {
        BackendChoice::Native => {
            let n = args.get("n", 1024usize)?;
            let d = args.get("d", 256usize)?;
            let v = args.get("v", 4096usize)?;
            let budget = args.get("budget-ms", 2000u64)?;
            let seed = args.get("seed", 0u64)?;
            let opts = kernel_options(args)?;
            // The decode-shape row (0 disables): per-call orchestration
            // overhead shows here, not at the big grid.
            let small_n = args.get("small-n", 8usize)?;
            let rows = bench::table1::run_native(n, d, v, ignored, budget, opts, seed)?;
            let small = if small_n > 0 {
                Some(bench::table1::run_native_small(small_n, d, v, ignored, budget, opts, seed)?)
            } else {
                None
            };
            bench::table1::print(&rows, &format!("{title_suffix} — native, N={n} D={d} V={v}"));
            if let Some(path) = args.opt("json") {
                bench::table1::write_json(
                    &rows,
                    (n, d, v),
                    opts.resolved_threads(),
                    exec::pool_workers(),
                    small.as_ref(),
                    path,
                )?;
                println!("  wrote {path}");
            }
            if args.flag("check") {
                bench::table1::check_native(&rows)?;
                println!("\n  [check] native Table 1 claims hold (incl. filter speedup)");
            }
            Ok(())
        }
        BackendChoice::Pjrt => cmd_table1_pjrt(args, ignored, &title_suffix),
    }
}

#[cfg(feature = "pjrt")]
fn cmd_table1_pjrt(args: &Args, ignored: f64, title: &str) -> Result<()> {
    let rt = runtime::open_default()?;
    let budget = args.get("budget-ms", 4000u64)?;
    let rows = bench::table1::run(&rt, ignored, budget)?;
    bench::table1::print(&rows, title);
    if let Some(path) = args.opt("json") {
        let bench_meta = rt.manifest.raw_meta.get("bench");
        let get = |k: &str| -> usize {
            bench_meta
                .and_then(|b| b.get(k))
                .and_then(|j| j.as_i64())
                .unwrap_or(0) as usize
        };
        bench::table1::write_json(&rows, (get("n"), get("d"), get("v")), 1, 0, None, path)?;
        println!("  wrote {path}");
    }
    if args.flag("check") {
        bench::table1::check(&rows)?;
        println!("\n  [check] all Table 1 shape claims hold");
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_table1_pjrt(_args: &Args, _ignored: f64, _title: &str) -> Result<()> {
    pjrt_unavailable("table1 --backend pjrt")
}

// ------------------------------------------------- artifact-only harnesses

#[cfg(feature = "pjrt")]
fn cmd_tablea2(args: &Args) -> Result<()> {
    let rt = runtime::open_default()?;
    let budget = args.get("budget-ms", 4000u64)?;
    let b = bench::breakdown::run(&rt, budget)?;
    bench::breakdown::print(&b);
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_tablea2(_args: &Args) -> Result<()> {
    pjrt_unavailable("tableA2")
}

fn cmd_fig3(args: &Args) -> Result<()> {
    match backend_choice(args)? {
        BackendChoice::Native => {
            let warm = args.get("warm-steps", 120u64)?;
            let seed = args.get("seed", 0u64)?;
            let vocab = args.get("vocab-size", 1024usize)?;
            let docs = args.get("corpus-docs", 800usize)?;
            let stats = bench::fig3::run_native(
                args.opt("checkpoint"),
                warm,
                seed,
                vocab,
                docs,
                kernel_options(args)?,
            )?;
            bench::fig3::print(&stats, args.opt("csv"))?;
            if args.flag("check") {
                bench::fig3::check(&stats)?;
                println!("\n  [check] Fig. 3 sparsity claims hold (native, zero artifacts)");
            }
            Ok(())
        }
        BackendChoice::Pjrt => cmd_fig3_pjrt(args),
    }
}

#[cfg(feature = "pjrt")]
fn cmd_fig3_pjrt(args: &Args) -> Result<()> {
    let rt = runtime::open_default()?;
    let tag = args.get("tag", "e2e".to_string())?;
    let warm = args.get("warm-steps", 150u64)?;
    let seed = args.get("seed", 0u64)?;
    let stats = bench::fig3::run(&rt, &tag, args.opt("checkpoint"), warm, seed)?;
    bench::fig3::print(&stats, args.opt("csv"))?;
    if args.flag("check") {
        bench::fig3::check(&stats)?;
        println!("\n  [check] Fig. 3 sparsity claims hold");
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_fig3_pjrt(_args: &Args) -> Result<()> {
    pjrt_unavailable("fig3 --backend pjrt")
}

#[cfg(feature = "pjrt")]
fn cmd_curves(args: &Args, fig4: bool) -> Result<()> {
    let rt = runtime::open_default()?;
    let tag = args.get("tag", "e2e".to_string())?;
    let steps = args.get("steps", 120u64)?;
    let seed = args.get("seed", 0u64)?;
    let pair = if fig4 {
        bench::curves::compare(&rt, &tag, CorpusKind::Instruct, "cce", "fused",
                               steps, 0, seed)?
    } else {
        let eval_every = args.get("eval-every", (steps / 4).max(1))?;
        bench::curves::compare(&rt, &tag, CorpusKind::Web, "cce_kahan_fullc",
                               "fused", steps, eval_every, seed)?
    };
    let title = if fig4 {
        "Fig. 4: fine-tuning loss curves (CCE vs torch.compile analogue)"
    } else {
        "Fig. 5: pretraining validation perplexity (CCE-Kahan-FullC vs compile)"
    };
    bench::curves::print(&pair, title, args.opt("csv"))?;
    if args.flag("check") {
        bench::curves::check(&pair, 0.02)?;
        println!("\n  [check] convergence-equivalence claim holds");
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_curves(args: &Args, fig4: bool) -> Result<()> {
    let _ = args;
    pjrt_unavailable(if fig4 { "fig4" } else { "fig5" })
}

// ------------------------------------------------------------------- sweep

fn cmd_sweep(args: &Args) -> Result<()> {
    match backend_choice(args)? {
        BackendChoice::Native => {
            let d = args.get("d", 256usize)?;
            let v = args.get("v", 4096usize)?;
            let budget = args.get("budget-ms", 1000u64)?;
            let seed = args.get("seed", 0u64)?;
            let ns: Vec<usize> = match args.opt("ns") {
                Some(list) => list
                    .split(',')
                    .map(|s| s.trim().parse::<usize>())
                    .collect::<Result<_, _>>()
                    .map_err(|e| anyhow::anyhow!("--ns: {e}"))?,
                None => vec![256, 512, 1024, 2048],
            };
            let opts = kernel_options(args)?;
            let points = bench::sweep::run_native(d, v, &ns, budget, opts, seed)?;
            bench::sweep::print(&points, args.opt("csv"))?;
            if let Some(path) = args.opt("json") {
                bench::sweep::write_json(&points, d, v, opts.dtype, opts.resolved_threads(), path)?;
                println!("  wrote {path}");
            }
            if args.flag("check") {
                bench::sweep::check(&points)?;
                println!("\n  [check] sweep scaling claims hold");
            }
            Ok(())
        }
        BackendChoice::Pjrt => cmd_sweep_pjrt(args),
    }
}

#[cfg(feature = "pjrt")]
fn cmd_sweep_pjrt(args: &Args) -> Result<()> {
    let rt = runtime::open_default()?;
    let budget = args.get("budget-ms", 2000u64)?;
    let points = bench::sweep::run(&rt, budget)?;
    bench::sweep::print(&points, args.opt("csv"))?;
    if args.flag("check") {
        bench::sweep::check(&points)?;
        println!("\n  [check] sweep scaling claims hold");
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_sweep_pjrt(_args: &Args) -> Result<()> {
    pjrt_unavailable("figA1 --backend pjrt")
}

// -------------------------------------------------------------------- info

fn cmd_info(args: &Args) -> Result<()> {
    let opts = kernel_options(args)?;
    println!("native backend: available");
    println!(
        "  threads: {} (resolved; --threads 0 = auto = available parallelism = {})",
        opts.resolved_threads(),
        exec::default_threads()
    );
    println!(
        "  pool: {} persistent workers spawned (lazy; grows to the largest \
         span count requested)",
        exec::pool_workers()
    );
    println!("  blocking: N_B={} V_B={}", opts.n_block, opts.v_block);
    println!(
        "  methods: baseline, chunked<k>, cce, cce_no_filter, cce_no_sort, \
         cce_kahan, cce_kahan_fullc, cce_kahan_fulle"
    );
    println!(
        "  simd: 8-lane f32, dispatch: {} (resolved once per kernel sweep)",
        exec::simd_dispatch()
    );
    println!(
        "  dtype: {} (--dtype f32|bf16: storage of params/activations/grads; \
         accumulation stays f32/f64)",
        opts.dtype.name()
    );
    print_pjrt_info()
}

#[cfg(feature = "pjrt")]
fn print_pjrt_info() -> Result<()> {
    println!("pjrt backend: compiled in");
    let rt = match runtime::open_default() {
        Ok(rt) => rt,
        Err(err) => {
            println!("  (artifacts unavailable: {err:#})");
            return Ok(());
        }
    };
    println!("  platform: {}", rt.platform());
    println!("  artifacts: {}", rt.manifest.artifacts.len());
    for (tag, m) in &rt.manifest.models {
        println!(
            "  model {tag}: {} params, batch {}x{}x{} (accum x batch x seq), vocab {}",
            m.param_count, m.accum, m.batch, m.seq, m.vocab_size
        );
    }
    let mut kinds = std::collections::BTreeMap::new();
    for a in rt.manifest.artifacts.values() {
        let kind = a
            .extra
            .get("kind")
            .and_then(|j| j.as_str())
            .unwrap_or("model")
            .to_string();
        *kinds.entry(kind).or_insert(0usize) += 1;
    }
    for (kind, count) in kinds {
        println!("  {kind}: {count} artifacts");
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn print_pjrt_info() -> Result<()> {
    println!(
        "pjrt backend: not compiled (enable with `cargo build --features pjrt`)"
    );
    Ok(())
}
