//! `cce` — the launcher CLI for the Cut Cross-Entropy reproduction.
//!
//! ```text
//! cce train   [--config cfg.json] [--method cce] [--steps N] ...
//! cce eval    --checkpoint path [--tag e2e]
//! cce table1  [--ignored 0.35] [--budget-ms 4000] [--check]
//! cce tableA1 (= table1 with the Appendix B ignored-token filter)
//! cce tableA2 / tableA3
//! cce fig1    [--tokens 65536] [--gpus 16] [--gpu-gb 75]
//! cce fig3    [--checkpoint path | --warm-steps N]
//! cce fig4 / fig5 [--steps N] [--tag e2e|tiny]
//! cce figA1   [--budget-ms 2000]
//! cce info    — manifest + runtime summary
//! ```

use anyhow::Result;

use cce::bench;
use cce::coordinator::{Checkpoint, CorpusKind, Metrics, RunConfig, TrainState,
                       Trainer};
use cce::runtime;
use cce::util::cli::Args;

fn main() {
    if let Err(err) = run() {
        eprintln!("error: {err:#}");
        std::process::exit(1);
    }
}

fn usage() -> ! {
    eprintln!(
        "usage: cce <command> [options]\n\ncommands:\n  \
         train    run a training job (--config/--method/--steps/--corpus/...)\n  \
         eval     evaluate a checkpoint (--checkpoint)\n  \
         table1   Table 1: memory & time per method\n  \
         tableA1  Table A1: Table 1 with ignored tokens removed\n  \
         tableA2  Table A2: backward-pass breakdown\n  \
         tableA3  Table A3: additional models memory\n  \
         fig1     Fig. 1 / Table A4: model-zoo memory & max batch\n  \
         fig3     Fig. 3: softmax rank probabilities (trained model)\n  \
         fig4     Fig. 4: fine-tune loss curves, cce vs fused\n  \
         fig5     Fig. 5: pretrain val perplexity, cce_kahan_fullc vs fused\n  \
         figA1    Figs. A1/A2: time/memory vs token count\n  \
         info     manifest summary"
    );
    std::process::exit(2);
}

fn run() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(argv, &["check", "verbose"])?;
    let cmd = match args.positional.first() {
        Some(c) => c.as_str(),
        None => usage(),
    };

    match cmd {
        "train" => cmd_train(&args),
        "eval" => cmd_eval(&args),
        "table1" => cmd_table1(&args, 0.0),
        "tableA1" | "tablea1" => {
            let frac = args.get("ignored", 0.35f64)?;
            cmd_table1(&args, frac)
        }
        "tableA2" | "tablea2" => cmd_tablea2(&args),
        "tableA3" | "tablea3" => bench::tablea3::run(args.opt("csv")),
        "fig1" => bench::fig1::run(
            args.get("tokens", 65_536u64)?,
            args.get("gpus", 16u64)?,
            args.get("gpu-gb", 75u64)?,
            args.opt("csv"),
        ),
        "fig3" => cmd_fig3(&args),
        "fig4" => cmd_curves(&args, true),
        "fig5" => cmd_curves(&args, false),
        "figA1" | "figa1" | "figA2" | "figa2" => cmd_sweep(&args),
        "info" => cmd_info(),
        other => {
            eprintln!("unknown command {other:?}\n");
            usage()
        }
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let mut cfg = match args.opt("config") {
        Some(path) => RunConfig::load(path)?,
        None => RunConfig::default(),
    };
    cfg.apply_args(args)?;
    let rt = runtime::open_default()?;
    eprintln!(
        "[cce] platform {} | model {} ({} params) | method {}",
        rt.platform(),
        cfg.tag,
        rt.manifest.model(&cfg.tag)?.param_count,
        cfg.method
    );
    let trainer = Trainer::build(&rt, cfg.clone())?;
    eprintln!(
        "[cce] corpus: {} train sequences, {} val | vocab {} | ignored {:.1}%",
        trainer.dataset.train.len(),
        trainer.dataset.val.len(),
        trainer.tokenizer.vocab_size(),
        100.0 * trainer.dataset.ignored_fraction()
    );
    let state = match args.opt("checkpoint") {
        Some(path) => TrainState::from_checkpoint(Checkpoint::load(path)?, &trainer.meta)?,
        None => TrainState::init(&rt, &trainer.meta, cfg.seed as i32)?,
    };
    let mut metrics = Metrics::with_dir(&cfg.out_dir)?;
    let state = trainer.train(state, &mut metrics)?;
    let final_val = trainer.evaluate(&state)?;
    metrics.log_eval(state.step as u64, final_val);
    metrics.write_csv(std::path::Path::new(&cfg.out_dir).join("loss_curve.csv"))?;
    let ckpt_path = std::path::Path::new(&cfg.out_dir).join("final.ckpt");
    trainer.to_checkpoint_with_vocab(&state, &ckpt_path)?;
    std::fs::write(
        std::path::Path::new(&cfg.out_dir).join("config.json"),
        cfg.to_json().to_string_pretty(),
    )?;
    println!(
        "[cce] done: step {} val_loss {final_val:.4} ppl {:.2} mean {:.0} tok/s -> {}",
        state.step,
        final_val.exp(),
        metrics.mean_throughput(),
        ckpt_path.display()
    );
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let path = args.require("checkpoint")?.to_string();
    let mut cfg = RunConfig::default();
    cfg.apply_args(args)?;
    let rt = runtime::open_default()?;
    let trainer = Trainer::build(&rt, cfg)?;
    let state = TrainState::from_checkpoint(Checkpoint::load(&path)?, &trainer.meta)?;
    let val = trainer.evaluate(&state)?;
    println!("val_loss {val:.4}  perplexity {:.2}  (step {})", val.exp(), state.step);
    Ok(())
}

fn cmd_table1(args: &Args, ignored: f64) -> Result<()> {
    let rt = runtime::open_default()?;
    let budget = args.get("budget-ms", 4000u64)?;
    let rows = bench::table1::run(&rt, ignored, budget)?;
    let title = if ignored > 0.0 {
        format!("Table A1: Table 1 with {:.0}% ignored tokens", ignored * 100.0)
    } else {
        "Table 1: memory & time per cross-entropy implementation".to_string()
    };
    bench::table1::print(&rows, &title);
    if args.flag("check") {
        bench::table1::check(&rows)?;
        println!("\n  [check] all Table 1 shape claims hold");
    }
    Ok(())
}

fn cmd_tablea2(args: &Args) -> Result<()> {
    let rt = runtime::open_default()?;
    let budget = args.get("budget-ms", 4000u64)?;
    let b = bench::breakdown::run(&rt, budget)?;
    bench::breakdown::print(&b);
    Ok(())
}

fn cmd_fig3(args: &Args) -> Result<()> {
    let rt = runtime::open_default()?;
    let tag = args.get("tag", "e2e".to_string())?;
    let warm = args.get("warm-steps", 150u64)?;
    let seed = args.get("seed", 0u64)?;
    let stats = bench::fig3::run(&rt, &tag, args.opt("checkpoint"), warm, seed)?;
    bench::fig3::print(&stats, args.opt("csv"))?;
    if args.flag("check") {
        bench::fig3::check(&stats)?;
        println!("\n  [check] Fig. 3 sparsity claims hold");
    }
    Ok(())
}

fn cmd_curves(args: &Args, fig4: bool) -> Result<()> {
    let rt = runtime::open_default()?;
    let tag = args.get("tag", "e2e".to_string())?;
    let steps = args.get("steps", 120u64)?;
    let seed = args.get("seed", 0u64)?;
    let pair = if fig4 {
        bench::curves::compare(&rt, &tag, CorpusKind::Instruct, "cce", "fused",
                               steps, 0, seed)?
    } else {
        let eval_every = args.get("eval-every", (steps / 4).max(1))?;
        bench::curves::compare(&rt, &tag, CorpusKind::Web, "cce_kahan_fullc",
                               "fused", steps, eval_every, seed)?
    };
    let title = if fig4 {
        "Fig. 4: fine-tuning loss curves (CCE vs torch.compile analogue)"
    } else {
        "Fig. 5: pretraining validation perplexity (CCE-Kahan-FullC vs compile)"
    };
    bench::curves::print(&pair, title, args.opt("csv"))?;
    if args.flag("check") {
        bench::curves::check(&pair, 0.02)?;
        println!("\n  [check] convergence-equivalence claim holds");
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let rt = runtime::open_default()?;
    let budget = args.get("budget-ms", 2000u64)?;
    let points = bench::sweep::run(&rt, budget)?;
    bench::sweep::print(&points, args.opt("csv"))?;
    if args.flag("check") {
        bench::sweep::check(&points)?;
        println!("\n  [check] sweep scaling claims hold");
    }
    Ok(())
}

fn cmd_info() -> Result<()> {
    let rt = runtime::open_default()?;
    println!("platform: {}", rt.platform());
    println!("artifacts: {}", rt.manifest.artifacts.len());
    for (tag, m) in &rt.manifest.models {
        println!(
            "  model {tag}: {} params, batch {}x{}x{} (accum x batch x seq), vocab {}",
            m.param_count, m.accum, m.batch, m.seq, m.vocab_size
        );
    }
    let mut kinds = std::collections::BTreeMap::new();
    for a in rt.manifest.artifacts.values() {
        let kind = a
            .extra
            .get("kind")
            .and_then(|j| j.as_str())
            .unwrap_or("model")
            .to_string();
        *kinds.entry(kind).or_insert(0usize) += 1;
    }
    for (kind, count) in kinds {
        println!("  {kind}: {count} artifacts");
    }
    Ok(())
}
