//! Softmax sparsity analysis — Fig. 3 and the gradient-filter accounting.
//!
//! The backward-pass filtering of §4.3 works because the softmax over a
//! large vocabulary is extremely sparse: by ~rank 50 the probability falls
//! below the bf16 threshold `eps = 2^-12`.  This module turns the
//! rank-probability curve (measured by the `softmax_ranks` artifact, or the
//! trained checkpoint) into the quantities the paper discusses:
//!
//! * the log-log rank/probability series of Fig. 3;
//! * the count of above-threshold entries per row;
//! * the expected fraction of `(N_B, V_B)` blocks that survive filtering,
//!   with and without vocabulary sorting — which predicts the backward-pass
//!   speedup of Table 1 rows 1/6/7.

/// The paper's gradient-filter threshold: the smallest bf16 value that is
/// not truncated when summed into an O(1) accumulator (§4.3, footnote 1).
pub const FILTER_EPS: f64 = 1.0 / 4096.0; // 2^-12

/// Summary of a rank-probability curve.
#[derive(Debug, Clone)]
pub struct RankStats {
    /// Mean probability of the i-th most likely token (descending).
    pub probs: Vec<f64>,
    /// Number of ranks with mean probability >= eps.
    pub significant_ranks: usize,
    /// Zipf-like slope of log(prob) vs log(rank) over the significant head.
    pub loglog_slope: f64,
}

impl RankStats {
    pub fn from_probs(probs: Vec<f64>, eps: f64) -> RankStats {
        let significant_ranks = probs.iter().take_while(|&&p| p >= eps).count();
        let loglog_slope = fit_loglog(&probs, significant_ranks.max(3));
        RankStats { probs, significant_ranks, loglog_slope }
    }

    /// Fraction of softmax entries below `eps` (the sparsity the paper
    /// reports as "<0.02% of elements are non-zero").
    pub fn sparsity(&self, eps: f64) -> f64 {
        let above = self.probs.iter().filter(|&&p| p >= eps).count();
        1.0 - above as f64 / self.probs.len() as f64
    }

    /// Fig. 3 series, decimated to `points` log-spaced ranks.
    pub fn fig3_series(&self, points: usize) -> Vec<(usize, f64)> {
        let n = self.probs.len();
        let mut out = Vec::with_capacity(points);
        let mut last = usize::MAX;
        for i in 0..points {
            let rank = ((n as f64).powf(i as f64 / (points - 1) as f64)) as usize;
            let rank = rank.clamp(1, n) - 1;
            if rank != last {
                out.push((rank + 1, self.probs[rank]));
                last = rank;
            }
        }
        out
    }
}

fn fit_loglog(probs: &[f64], head: usize) -> f64 {
    // Least-squares slope of ln p vs ln rank over ranks [2, head].
    let pts: Vec<(f64, f64)> = probs
        .iter()
        .enumerate()
        .skip(1)
        .take(head.saturating_sub(1))
        .filter(|(_, &p)| p > 0.0)
        .map(|(i, &p)| ((i as f64 + 1.0).ln(), p.ln()))
        .collect();
    if pts.len() < 2 {
        return 0.0;
    }
    let n = pts.len() as f64;
    let sx: f64 = pts.iter().map(|p| p.0).sum();
    let sy: f64 = pts.iter().map(|p| p.1).sum();
    let sxx: f64 = pts.iter().map(|p| p.0 * p.0).sum();
    let sxy: f64 = pts.iter().map(|p| p.0 * p.1).sum();
    (n * sxy - sx * sy) / (n * sxx - sx * sx)
}

/// Expected block survival under gradient filtering.
///
/// With `k` significant tokens per row, `N_B` rows per block and the vocab
/// divided into `V / V_B` column blocks:
///
/// * **unsorted**: significant tokens land in uniformly-random column
///   blocks, so a block survives with
///   `p = 1 - (1 - V_B/V)^(k * N_B)` (independence approximation);
/// * **sorted**: the significant tokens of *all* rows concentrate into the
///   same leading blocks, so approximately `ceil(c·k / V_B)` column blocks
///   survive per row-block, where `c` measures row agreement (1 = perfect).
#[derive(Debug, Clone, Copy)]
pub struct BlockFilterModel {
    pub vocab: usize,
    pub v_block: usize,
    pub n_block: usize,
    /// Significant (>= eps) tokens per row.
    pub sig_per_row: usize,
    /// Cross-row agreement of the significant set under sorting (0..=1;
    /// measured ~0.5-0.9 in practice — hot tokens are shared across rows).
    pub sort_agreement: f64,
}

impl BlockFilterModel {
    /// Distinct significant tokens across the `n_block` rows of one block
    /// row.  Rows share most of their significant set (the same hot tokens
    /// dominate every context — that is what `sort_agreement` measures), so
    /// the distinct count grows only logarithmically with rows.
    fn distinct_per_rowblock(&self) -> f64 {
        self.sig_per_row as f64
            * (1.0 + (1.0 - self.sort_agreement) * (self.n_block as f64).ln())
    }

    /// Fraction of blocks that survive (must run the grad matmuls) without
    /// vocabulary sorting: the distinct significant tokens land in random
    /// column blocks.
    pub fn survival_unsorted(&self) -> f64 {
        let n_vblocks = (self.vocab as f64 / self.v_block as f64).max(1.0);
        1.0 - (-self.distinct_per_rowblock() / n_vblocks).exp()
    }

    /// Fraction of blocks that survive with vocabulary sorting: the same
    /// distinct tokens are contiguous, so they cover only
    /// `ceil(distinct / V_B)` blocks.
    pub fn survival_sorted(&self) -> f64 {
        let n_vblocks = (self.vocab as f64 / self.v_block as f64).max(1.0);
        let blocks = (self.distinct_per_rowblock() / self.v_block as f64).ceil();
        (blocks / n_vblocks).min(1.0)
    }

    /// Predicted backward speedup from filtering (unsorted), relative to
    /// computing every block: `1 / survival`, capped by the non-matmul work
    /// fraction `overhead` (the logit rematerialization is never skipped).
    pub fn predicted_speedup(&self, survival: f64, overhead: f64) -> f64 {
        speedup_at_survival(survival, overhead)
    }
}

/// Amdahl form of the filter speedup at a given block-survival fraction:
/// `1 / (overhead + (1 − overhead)·survival)`.  Used both for the model's
/// predictions and to convert a *measured* survival (from
/// `exec::FilterStats`) into an expected wall-clock gain.
pub fn speedup_at_survival(survival: f64, overhead: f64) -> f64 {
    1.0 / (overhead + (1.0 - overhead) * survival)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn zipf_probs(v: usize, s: f64) -> Vec<f64> {
        let mut p: Vec<f64> = (1..=v).map(|k| 1.0 / (k as f64).powf(s)).collect();
        let z: f64 = p.iter().sum();
        p.iter_mut().for_each(|x| *x /= z);
        p
    }

    #[test]
    fn rank_stats_on_zipf() {
        let stats = RankStats::from_probs(zipf_probs(32_768, 1.5), FILTER_EPS);
        // Significant head is tiny compared to |V| (the Fig. 3 observation).
        assert!(stats.significant_ranks < 200, "{}", stats.significant_ranks);
        assert!(stats.sparsity(FILTER_EPS) > 0.99);
        // Slope should recover ~ -1.5.
        assert!((stats.loglog_slope + 1.5).abs() < 0.2, "{}", stats.loglog_slope);
    }

    #[test]
    fn fig3_series_is_decreasing_logspaced() {
        let stats = RankStats::from_probs(zipf_probs(10_000, 1.2), FILTER_EPS);
        let series = stats.fig3_series(20);
        assert!(series.len() >= 10);
        for w in series.windows(2) {
            assert!(w[0].0 < w[1].0);
            assert!(w[0].1 >= w[1].1);
        }
    }

    #[test]
    fn sorting_concentrates_blocks() {
        let m = BlockFilterModel {
            vocab: 256_000,
            v_block: 256,
            n_block: 128,
            sig_per_row: 50,
            sort_agreement: 0.7,
        };
        let unsorted = m.survival_unsorted();
        let sorted = m.survival_sorted();
        assert!(sorted < unsorted, "sorted {sorted} unsorted {unsorted}");
        // Paper: filtering alone gives ~3.5x; sorting adds ~15% more.
        let su = m.predicted_speedup(unsorted, 0.40);
        let ss = m.predicted_speedup(sorted, 0.40);
        assert!(su > 1.5 && su < 3.0, "unsorted speedup {su}");
        assert!(ss > su, "sorted {ss} <= unsorted {su}");
        assert!(ss / su < 1.5, "sorting gain implausibly large: {}", ss / su);
    }

    #[test]
    fn denser_vocab_blocks_filter_better() {
        // Growing |V| at fixed significant count improves the win — the
        // paper's "sparsity grows with vocabulary size".
        let base = BlockFilterModel {
            vocab: 32_000,
            v_block: 256,
            n_block: 128,
            sig_per_row: 50,
            sort_agreement: 0.7,
        };
        let big = BlockFilterModel { vocab: 256_000, ..base };
        assert!(big.survival_unsorted() < base.survival_unsorted());
    }
}
