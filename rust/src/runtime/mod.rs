//! Runtime layer: PJRT client + artifact manifest + host tensors.
//!
//! This is the only module that touches the `xla` crate.  Everything above
//! it (coordinator, benches, examples) speaks [`HostTensor`]s and artifact
//! names.

pub mod client;
pub mod manifest;
pub mod tensor;

pub use client::{Executable, Runtime};
pub use manifest::{ArtifactEntry, Manifest, ModelMeta, ParamSpec, Spec};
pub use tensor::{DType, Data, HostTensor};

use anyhow::Result;

/// Resolve the artifact directory: `CCE_ARTIFACTS` env var or `./artifacts`.
pub fn artifact_dir() -> std::path::PathBuf {
    std::env::var_os("CCE_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}

/// Open the default runtime (most binaries start here).
pub fn open_default() -> Result<Runtime> {
    Runtime::new(artifact_dir())
}
