//! Runtime layer: artifact manifest + host tensors, plus (behind the
//! `pjrt` feature) the PJRT client that executes AOT artifacts.
//!
//! [`client`] is the only module in the crate that touches the `xla` crate,
//! and it only exists when the `pjrt` feature is enabled.  Everything above
//! it (coordinator, benches, examples) speaks [`HostTensor`]s and artifact
//! names; without the feature, the native backend ([`crate::exec`]) is the
//! compute path and nothing here needs a shared library.

#[cfg(feature = "pjrt")]
pub mod client;
pub mod manifest;
pub mod tensor;

#[cfg(feature = "pjrt")]
pub use client::{Executable, Runtime};
pub use manifest::{ArtifactEntry, Manifest, ModelMeta, ParamSpec, Spec};
pub use tensor::{DType, Data, HostTensor};

#[cfg(feature = "pjrt")]
use anyhow::Result;

/// Resolve the artifact directory: `CCE_ARTIFACTS` env var or `./artifacts`.
pub fn artifact_dir() -> std::path::PathBuf {
    std::env::var_os("CCE_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}

/// Open the default runtime (most PJRT-backed binaries start here).
#[cfg(feature = "pjrt")]
pub fn open_default() -> Result<Runtime> {
    Runtime::new(artifact_dir())
}
