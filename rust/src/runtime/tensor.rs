//! Host-side tensors and conversion to/from XLA literals.
//!
//! The coordinator's data plane: batches, parameters, optimizer state and
//! metrics all travel as [`HostTensor`]s.  Conversions are exact-size checked
//! against the artifact manifest before anything reaches PJRT.

use anyhow::{bail, Result};

use crate::exec::dtype::BF16;

/// Element dtype of a tensor (the subset our artifacts and native
/// checkpoints use).  `BF16` is native-only: checkpoints store it, the
/// kernels read it, but there is no PJRT literal bridge for it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    I32,
    U32,
    F64,
    BF16,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        Ok(match s {
            "float32" | "f32" => DType::F32,
            "int32" | "i32" => DType::I32,
            "uint32" | "u32" => DType::U32,
            "float64" | "f64" => DType::F64,
            "bfloat16" | "bf16" => DType::BF16,
            other => bail!("unsupported dtype {other:?}"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            DType::F32 => "float32",
            DType::I32 => "int32",
            DType::U32 => "uint32",
            DType::F64 => "float64",
            DType::BF16 => "bfloat16",
        }
    }

    pub fn size_bytes(&self) -> usize {
        match self {
            DType::BF16 => 2,
            DType::F64 => 8,
            _ => 4,
        }
    }
}

/// Typed storage for a host tensor.
#[derive(Debug, Clone, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U32(Vec<u32>),
    F64(Vec<f64>),
    BF16(Vec<BF16>),
}

impl Data {
    pub fn len(&self) -> usize {
        match self {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::U32(v) => v.len(),
            Data::F64(v) => v.len(),
            Data::BF16(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> DType {
        match self {
            Data::F32(_) => DType::F32,
            Data::I32(_) => DType::I32,
            Data::U32(_) => DType::U32,
            Data::F64(_) => DType::F64,
            Data::BF16(_) => DType::BF16,
        }
    }
}

/// An n-dimensional host tensor (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Data,
}

impl HostTensor {
    pub fn new(shape: Vec<usize>, data: Data) -> Result<HostTensor> {
        let expect: usize = shape.iter().product();
        if data.len() != expect {
            bail!(
                "shape {:?} needs {} elements, got {}",
                shape,
                expect,
                data.len()
            );
        }
        Ok(HostTensor { shape, data })
    }

    pub fn f32(shape: Vec<usize>, v: Vec<f32>) -> Result<HostTensor> {
        Self::new(shape, Data::F32(v))
    }

    pub fn i32(shape: Vec<usize>, v: Vec<i32>) -> Result<HostTensor> {
        Self::new(shape, Data::I32(v))
    }

    pub fn bf16(shape: Vec<usize>, v: Vec<BF16>) -> Result<HostTensor> {
        Self::new(shape, Data::BF16(v))
    }

    pub fn scalar_f32(v: f32) -> HostTensor {
        HostTensor { shape: vec![], data: Data::F32(vec![v]) }
    }

    pub fn scalar_i32(v: i32) -> HostTensor {
        HostTensor { shape: vec![], data: Data::I32(vec![v]) }
    }

    pub fn zeros(dtype: DType, shape: Vec<usize>) -> HostTensor {
        let n: usize = shape.iter().product();
        let data = match dtype {
            DType::F32 => Data::F32(vec![0.0; n]),
            DType::I32 => Data::I32(vec![0; n]),
            DType::U32 => Data::U32(vec![0; n]),
            DType::F64 => Data::F64(vec![0.0; n]),
            DType::BF16 => Data::BF16(vec![BF16::ZERO; n]),
        };
        HostTensor { shape, data }
    }

    pub fn dtype(&self) -> DType {
        self.data.dtype()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn size_bytes(&self) -> usize {
        self.len() * self.dtype().size_bytes()
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            Data::F32(v) => Ok(v),
            other => bail!("expected f32 tensor, got {:?}", other.dtype()),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            Data::I32(v) => Ok(v),
            other => bail!("expected i32 tensor, got {:?}", other.dtype()),
        }
    }

    /// Widened float view for measurement/printing paths: exact for f32
    /// and bf16 (int tensors are rejected — widening labels would hide a
    /// schema error).
    pub fn to_f32_vec(&self) -> Result<Vec<f32>> {
        match &self.data {
            Data::F32(v) => Ok(v.clone()),
            Data::BF16(v) => Ok(v.iter().map(|&x| x.to_f32()).collect()),
            other => bail!("expected a float tensor, got {:?}", other.dtype()),
        }
    }

    /// Scalar extraction (0-d or 1-element tensors).
    pub fn scalar(&self) -> Result<f64> {
        if self.len() != 1 {
            bail!("scalar() on tensor with {} elements", self.len());
        }
        Ok(match &self.data {
            Data::F32(v) => v[0] as f64,
            Data::I32(v) => v[0] as f64,
            Data::U32(v) => v[0] as f64,
            Data::F64(v) => v[0],
            Data::BF16(v) => v[0].to_f32() as f64,
        })
    }

    // ------------------------------------------------------ literal bridge
    // (only meaningful when the PJRT client is compiled in)

    #[cfg(feature = "pjrt")]
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        let lit = match &self.data {
            Data::F32(v) => xla::Literal::vec1(v),
            Data::I32(v) => xla::Literal::vec1(v),
            Data::U32(v) => xla::Literal::vec1(v),
            Data::F64(v) => xla::Literal::vec1(v),
            Data::BF16(_) => bail!("bf16 tensors are native-only (no PJRT literal bridge)"),
        };
        Ok(lit.reshape(&dims)?)
    }

    #[cfg(feature = "pjrt")]
    pub fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = match shape.ty() {
            xla::ElementType::F32 => Data::F32(lit.to_vec::<f32>()?),
            xla::ElementType::S32 => Data::I32(lit.to_vec::<i32>()?),
            xla::ElementType::U32 => Data::U32(lit.to_vec::<u32>()?),
            xla::ElementType::F64 => Data::F64(lit.to_vec::<f64>()?),
            other => bail!("unsupported literal element type {other:?}"),
        };
        HostTensor::new(dims, data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checked() {
        assert!(HostTensor::f32(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(HostTensor::f32(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn scalar_roundtrip() {
        let t = HostTensor::scalar_i32(7);
        assert_eq!(t.scalar().unwrap(), 7.0);
        assert!(t.shape.is_empty());
    }

    #[test]
    fn zeros_sizes() {
        let t = HostTensor::zeros(DType::F32, vec![4, 5]);
        assert_eq!(t.len(), 20);
        assert_eq!(t.size_bytes(), 80);
    }

    #[test]
    fn dtype_parse() {
        assert_eq!(DType::parse("float32").unwrap(), DType::F32);
        assert_eq!(DType::parse("int32").unwrap(), DType::I32);
        assert_eq!(DType::parse("bfloat16").unwrap(), DType::BF16);
        assert_eq!(DType::BF16.size_bytes(), 2);
        assert!(DType::parse("fp8").is_err());
    }

    #[test]
    fn bf16_tensor_roundtrips_and_widens() {
        let vals: Vec<BF16> = [1.0f32, -0.5, 3.25].iter().map(|&x| BF16::from_f32(x)).collect();
        let t = HostTensor::bf16(vec![3], vals).unwrap();
        assert_eq!(t.dtype(), DType::BF16);
        assert_eq!(t.size_bytes(), 6, "bf16 is 2 bytes per element");
        assert_eq!(t.to_f32_vec().unwrap(), vec![1.0, -0.5, 3.25]);
        assert!(t.as_f32().is_err(), "as_f32 must not silently widen");
        let z = HostTensor::zeros(DType::BF16, vec![2, 2]);
        assert_eq!(z.to_f32_vec().unwrap(), vec![0.0; 4]);
    }

    // Literal round-trips are covered by integration tests (tests/runtime.rs)
    // since they need the PJRT shared library at link/run time.
}
