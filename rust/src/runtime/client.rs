//! PJRT runtime: load HLO-text artifacts, compile once, execute many.
//!
//! Wraps the `xla` crate (PJRT C API) following the pattern validated by
//! /opt/xla-example/load_hlo: `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `PjRtClient::compile` → `execute`.
//!
//! Executables are cached per artifact name; compilation happens at most
//! once per process.  All calls are shape/dtype-validated against the
//! manifest first.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::runtime::manifest::{ArtifactEntry, Manifest};
use crate::runtime::tensor::HostTensor;

/// The process-wide runtime: one PJRT CPU client + compiled-executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
    /// Cumulative compile time (reported by `cce --timings`).
    compile_secs: RefCell<f64>,
}

/// A compiled artifact ready to execute.
pub struct Executable {
    pub name: String,
    pub inputs: Vec<crate::runtime::manifest::Spec>,
    pub outputs: Vec<crate::runtime::manifest::Spec>,
    exe: xla::PjRtLoadedExecutable,
}

impl Runtime {
    /// Create a runtime over an artifact directory (with manifest.json).
    pub fn new(artifact_dir: impl AsRef<std::path::Path>) -> Result<Runtime> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
            compile_secs: RefCell::new(0.0),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load (compile-once) an artifact by manifest name.
    pub fn load(&self, name: &str) -> Result<Rc<Executable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let entry = self.manifest.entry(name)?.clone();
        let exe = Rc::new(self.compile_entry(&entry)?);
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    fn compile_entry(&self, entry: &ArtifactEntry) -> Result<Executable> {
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&entry.file)
            .with_context(|| format!("loading HLO text {:?}", entry.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {:?}", entry.name))?;
        *self.compile_secs.borrow_mut() += t0.elapsed().as_secs_f64();
        Ok(Executable {
            name: entry.name.clone(),
            inputs: entry.inputs.clone(),
            outputs: entry.outputs.clone(),
            exe,
        })
    }

    pub fn total_compile_secs(&self) -> f64 {
        *self.compile_secs.borrow()
    }

    /// Convenience: load + run in one call.
    pub fn run(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        self.load(name)?.run(inputs)
    }
}

impl Executable {
    /// Execute with host tensors; returns host tensors.
    ///
    /// The artifact was lowered with `return_tuple=True`, so PJRT returns a
    /// single tuple buffer which we decompose into the manifest's outputs.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        Manifest::validate(&self.inputs, inputs)
            .with_context(|| format!("inputs of {:?}", self.name))?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let tuple = result[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        if parts.len() != self.outputs.len() {
            anyhow::bail!(
                "{:?}: executable returned {} outputs, manifest says {}",
                self.name,
                parts.len(),
                self.outputs.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        for (lit, spec) in parts.iter().zip(&self.outputs) {
            let t = HostTensor::from_literal(lit)
                .with_context(|| format!("output {:?} of {:?}", spec.name, self.name))?;
            out.push(t);
        }
        Ok(out)
    }

    /// Execute keeping results on device (for state round-tripping).
    ///
    /// Returns the raw PJRT buffers of the result tuple; pair with
    /// [`Executable::run_buffers`] to chain steps without host copies.
    pub fn run_to_buffers(
        &self,
        inputs: &[HostTensor],
    ) -> Result<Vec<xla::PjRtBuffer>> {
        Manifest::validate(&self.inputs, inputs)?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let mut result = self.exe.execute::<xla::Literal>(&literals)?;
        Ok(result.remove(0))
    }
}

#[cfg(test)]
mod tests {
    // Execution paths require libxla_extension at runtime; exercised by the
    // integration tests in rust/tests/runtime.rs against the tiny artifacts.
}
