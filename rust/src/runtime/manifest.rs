//! Artifact manifest: the contract between `python/compile/aot.py` (which
//! writes `artifacts/manifest.json`) and the Rust runtime (which loads it).
//!
//! Every artifact entry carries its full I/O signature, so every call is
//! shape/dtype validated *before* it reaches PJRT — a wrong batch shape
//! fails with a readable error instead of an XLA internal one.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::runtime::tensor::DType;
use crate::util::json::Json;

/// Shape+dtype of one artifact input or output.
#[derive(Debug, Clone, PartialEq)]
pub struct Spec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl Spec {
    fn from_json(j: &Json) -> Result<Spec> {
        let name = j.req("name")?.as_str().unwrap_or_default().to_string();
        let shape = j
            .req("shape")?
            .as_array()
            .ok_or_else(|| anyhow!("shape not an array"))?
            .iter()
            .map(|v| v.as_i64().map(|i| i as usize))
            .collect::<Option<Vec<_>>>()
            .ok_or_else(|| anyhow!("non-integer dim"))?;
        let dtype = DType::parse(
            j.req("dtype")?.as_str().ok_or_else(|| anyhow!("dtype not a string"))?,
        )?;
        Ok(Spec { name, shape, dtype })
    }

    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn size_bytes(&self) -> usize {
        self.elements() * self.dtype.size_bytes()
    }
}

/// One AOT-compiled computation.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<Spec>,
    pub outputs: Vec<Spec>,
    /// Loss-bench metadata when present (`method`, `n`, `d`, `v`, `kind`).
    pub extra: BTreeMap<String, Json>,
}

/// A parameter leaf of a model config (name + spec), in artifact order.
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

/// Metadata for one lowered model (the `meta.<tag>` manifest block).
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub tag: String,
    pub params: Vec<ParamSpec>,
    pub param_count: u64,
    pub batch: usize,
    pub seq: usize,
    pub accum: usize,
    pub vocab_size: usize,
    pub d_model: usize,
    pub raw: Json,
}

/// The parsed manifest.
#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactEntry>,
    pub models: BTreeMap<String, ModelMeta>,
    pub raw_meta: Json,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        let json = Json::parse(&text).with_context(|| format!("parsing {path:?}"))?;

        let mut artifacts = BTreeMap::new();
        for (name, entry) in json
            .req("artifacts")?
            .as_object()
            .ok_or_else(|| anyhow!("artifacts not an object"))?
        {
            let file = dir.join(
                entry
                    .req("file")?
                    .as_str()
                    .ok_or_else(|| anyhow!("file not a string"))?,
            );
            let parse_specs = |key: &str| -> Result<Vec<Spec>> {
                entry
                    .req(key)?
                    .as_array()
                    .ok_or_else(|| anyhow!("{key} not an array"))?
                    .iter()
                    .map(Spec::from_json)
                    .collect()
            };
            let mut extra = BTreeMap::new();
            for (k, v) in entry.as_object().unwrap() {
                if !matches!(k.as_str(), "file" | "inputs" | "outputs") {
                    extra.insert(k.clone(), v.clone());
                }
            }
            artifacts.insert(
                name.clone(),
                ArtifactEntry {
                    name: name.clone(),
                    file,
                    inputs: parse_specs("inputs")?,
                    outputs: parse_specs("outputs")?,
                    extra,
                },
            );
        }

        let meta = json.req("meta")?;
        let mut models = BTreeMap::new();
        for (tag, m) in meta.as_object().unwrap_or(&[]) {
            if m.get("params").is_none() {
                continue; // not a model block (e.g. "bench")
            }
            let params = m
                .req("params")?
                .as_array()
                .unwrap_or(&[])
                .iter()
                .map(|p| {
                    Ok(ParamSpec {
                        name: p.req("name")?.as_str().unwrap_or_default().into(),
                        shape: p
                            .req("shape")?
                            .as_array()
                            .unwrap_or(&[])
                            .iter()
                            .filter_map(|v| v.as_i64().map(|i| i as usize))
                            .collect(),
                        dtype: DType::parse(
                            p.req("dtype")?.as_str().unwrap_or("float32"),
                        )?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let train = m.req("train")?;
            let model = m.req("model")?;
            let geti = |j: &Json, k: &str| -> Result<usize> {
                j.req(k)?
                    .as_i64()
                    .map(|i| i as usize)
                    .ok_or_else(|| anyhow!("{k} not an int"))
            };
            models.insert(
                tag.clone(),
                ModelMeta {
                    tag: tag.clone(),
                    params,
                    param_count: m.req("param_count")?.as_i64().unwrap_or(0) as u64,
                    batch: geti(train, "batch")?,
                    seq: geti(train, "seq")?,
                    accum: geti(train, "accum")?,
                    vocab_size: geti(model, "vocab_size")?,
                    d_model: geti(model, "d_model")?,
                    raw: m.clone(),
                },
            );
        }

        Ok(Manifest { dir, artifacts, models, raw_meta: meta.clone() })
    }

    pub fn entry(&self, name: &str) -> Result<&ArtifactEntry> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest"))
    }

    pub fn model(&self, tag: &str) -> Result<&ModelMeta> {
        self.models
            .get(tag)
            .ok_or_else(|| anyhow!("model meta {tag:?} not in manifest"))
    }

    /// All loss-bench artifacts matching a (kind, n) filter.
    pub fn loss_artifacts(
        &self,
        kind: &str,
        n: Option<usize>,
    ) -> Vec<&ArtifactEntry> {
        self.artifacts
            .values()
            .filter(|a| {
                a.extra.get("kind").and_then(|j| j.as_str()) == Some(kind)
                    && n.map_or(true, |want| {
                        a.extra.get("n").and_then(|j| j.as_i64())
                            == Some(want as i64)
                    })
            })
            .collect()
    }

    /// Validate that `values` matches `specs` (count, shape, dtype).
    pub fn validate(specs: &[Spec], values: &[crate::runtime::HostTensor]) -> Result<()> {
        if specs.len() != values.len() {
            bail!("expected {} inputs, got {}", specs.len(), values.len());
        }
        for (spec, val) in specs.iter().zip(values) {
            if spec.shape != val.shape {
                bail!(
                    "input {:?}: expected shape {:?}, got {:?}",
                    spec.name,
                    spec.shape,
                    val.shape
                );
            }
            if spec.dtype != val.dtype() {
                bail!(
                    "input {:?}: expected dtype {:?}, got {:?}",
                    spec.name,
                    spec.dtype,
                    val.dtype()
                );
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_manifest() -> &'static str {
        r#"{
  "artifacts": {
    "tiny_eval_step": {
      "file": "tiny_eval_step.hlo.txt",
      "inputs": [{"name": "param:embed", "shape": [512, 64], "dtype": "float32"},
                  {"name": "tokens", "shape": [2, 32], "dtype": "int32"}],
      "outputs": [{"name": "loss_sum", "shape": [], "dtype": "float32"}]
    },
    "loss_fwd_cce_n128": {
      "file": "x.hlo.txt",
      "inputs": [], "outputs": [],
      "method": "cce", "n": 128, "kind": "fwd"
    }
  },
  "meta": {
    "tiny": {
      "model": {"vocab_size": 512, "d_model": 64},
      "train": {"batch": 2, "seq": 32, "accum": 2},
      "param_count": 99,
      "params": [{"name": "embed", "shape": [512, 64], "dtype": "float32"}]
    },
    "bench": {"n": 2048}
  }
}"#
    }

    #[test]
    fn parses_manifest() {
        let dir = std::env::temp_dir().join("cce_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), sample_manifest()).unwrap();
        let m = Manifest::load(&dir).unwrap();
        let e = m.entry("tiny_eval_step").unwrap();
        assert_eq!(e.inputs.len(), 2);
        assert_eq!(e.inputs[0].shape, vec![512, 64]);
        assert_eq!(e.inputs[1].dtype, DType::I32);
        let model = m.model("tiny").unwrap();
        assert_eq!(model.vocab_size, 512);
        assert_eq!(model.params.len(), 1);
        assert_eq!(m.loss_artifacts("fwd", Some(128)).len(), 1);
        assert_eq!(m.loss_artifacts("fwd", Some(4096)).len(), 0);
        assert!(m.entry("nope").is_err());
    }

    #[test]
    fn validates_shapes() {
        let specs = vec![Spec {
            name: "x".into(),
            shape: vec![2, 3],
            dtype: DType::F32,
        }];
        let good = vec![crate::runtime::HostTensor::f32(vec![2, 3], vec![0.0; 6]).unwrap()];
        let bad = vec![crate::runtime::HostTensor::f32(vec![3, 2], vec![0.0; 6]).unwrap()];
        assert!(Manifest::validate(&specs, &good).is_ok());
        assert!(Manifest::validate(&specs, &bad).is_err());
        assert!(Manifest::validate(&specs, &[]).is_err());
    }
}
