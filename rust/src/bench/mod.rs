//! Benchmark harnesses — one per table/figure of the paper's evaluation.
//!
//! | Harness                | Paper artifact | CLI |
//! |------------------------|----------------|-----|
//! | [`table1`]             | Table 1 (+A1 via `--ignored`) | `cce table1` |
//! | [`breakdown`]          | Table A2       | `cce tableA2` |
//! | [`tablea3`]            | Table A3       | `cce tableA3` |
//! | [`fig1`]               | Fig. 1 / Table A4 | `cce fig1` |
//! | [`fig3`]               | Fig. 3         | `cce fig3` |
//! | [`curves`]             | Figs. 4 & 5    | `cce fig4`, `cce fig5` |
//! | [`sweep`]              | Figs. A1 / A2  | `cce figA1` |
//!
//! Time columns are measured on this substrate (CPU PJRT, scaled grid —
//! see DESIGN.md "Numerical-scale policy"); memory columns are analytic and
//! exact at paper scale.  Each harness has a `check()` that asserts the
//! paper's *shape* claims and is exercised by `cargo test` / `cargo bench`.

pub mod breakdown;
pub mod curves;
pub mod fig1;
pub mod fig3;
pub mod harness;
pub mod sweep;
pub mod table1;
pub mod tablea3;

pub use harness::{time_artifact, BenchResult, Table};
