//! Benchmark harnesses — one per table/figure of the paper's evaluation.
//!
//! | Harness                | Paper artifact | CLI |
//! |------------------------|----------------|-----|
//! | [`table1`]             | Table 1 (+A1 via `--ignored`) | `cce table1` |
//! | [`breakdown`]          | Table A2       | `cce tableA2` (pjrt) |
//! | [`tablea3`]            | Table A3       | `cce tableA3` |
//! | [`fig1`]               | Fig. 1 / Table A4 | `cce fig1` |
//! | [`fig3`]               | Fig. 3         | `cce fig3` |
//! | [`curves`]             | Figs. 4 & 5    | `cce fig4`, `cce fig5` (pjrt) |
//! | [`sweep`]              | Figs. A1 / A2  | `cce figA1` |
//! | [`serve`]              | — (serving workload) | `cce servebench` |
//!
//! `table1`, `sweep`, and `fig3` run on either backend: `--backend native`
//! measures the multi-threaded Rust kernels in [`crate::exec`] with zero
//! artifacts (and `table1 --json` / `servebench --json` emit
//! `BENCH_*.json` for cross-PR tracking); `--backend pjrt` times the AOT
//! artifacts.  The artifact-only harnesses (`breakdown`, `curves`) need
//! the `pjrt` feature.  [`serve`] drives the full inference stack (TCP →
//! micro-batcher → blocked kernels) and reports req/s + latency
//! percentiles + peak inference workspace.  Memory columns are analytic
//! and exact at paper scale; each harness has a `check()` that asserts the
//! paper's *shape* claims.

#[cfg(feature = "pjrt")]
pub mod breakdown;
#[cfg(feature = "pjrt")]
pub mod curves;
pub mod fig1;
pub mod fig3;
pub mod harness;
pub mod serve;
pub mod sweep;
pub mod table1;
pub mod tablea3;

#[cfg(feature = "pjrt")]
pub use harness::time_artifact;
pub use harness::{BenchResult, Table};
