//! Figs. A1/A2 harness: loss+gradient time and memory vs token count.
//!
//! `run_native` sweeps the native kernels over a list of token counts with
//! zero artifacts; `run` (pjrt) times the AOT artifacts in the manifest.

use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::bench::harness::Table;
use crate::memmodel::{method_memory, LossMethod, Workload};
use crate::util::json::Json;
use crate::util::stats::{fmt_duration, fmt_mb};

#[cfg(feature = "pjrt")]
use crate::bench::harness::time_artifact;
#[cfg(feature = "pjrt")]
use crate::runtime::Runtime;

pub struct SweepPoint {
    pub method: String,
    pub n_tokens: u64,
    pub secs: f64,
    /// Analytic memory model at this point.
    pub mem_bytes: u64,
    /// Measured forward kernel workspace (native path): the scaling-gate
    /// quantity — flat in N for cce (O(N) vectors + fixed tiles), ~linear
    /// in N for the materialized baseline (the N×V logit matrix).
    pub fwd_workspace_bytes: Option<u64>,
    /// Measured peak loss+gradient memory (native path; see
    /// [`crate::bench::table1::measured_combined_bytes`]).
    pub measured_bytes: Option<u64>,
}

fn method_of_key(key: &str) -> Option<LossMethod> {
    Some(match key {
        "cce" => LossMethod::Cce,
        "baseline" => LossMethod::Baseline,
        "fused" => LossMethod::TorchCompile,
        "chunked8" => LossMethod::Chunked(8),
        "liger" => LossMethod::Liger,
        _ => return None,
    })
}

/// Sweep the native kernels over `ns` token counts at a fixed `(d, v)`
/// grid — the Fig. A1/A2 time/memory-vs-N curves with zero artifacts.
/// Each point also records the *measured* forward workspace and peak
/// loss+gradient memory, which is what the CI scaling gate asserts on
/// (cce flat in N, baseline ~linear).
pub fn run_native(
    d: usize,
    v: usize,
    ns: &[usize],
    budget_ms: u64,
    opts: crate::exec::KernelOptions,
    seed: u64,
) -> Result<Vec<SweepPoint>> {
    use crate::bench::harness::gen_loss_inputs;
    use crate::exec::{Problem, Store, StoreDtype, BF16};
    use crate::util::rng::Rng;

    let mut out = Vec::new();
    let mut sorted_ns = ns.to_vec();
    sorted_ns.sort_unstable();
    for &n in &sorted_ns {
        let mut rng = Rng::new(seed ^ (n as u64).wrapping_mul(0x9E37_79B9));
        let inputs = gen_loss_inputs(n, d, v, &mut rng, 0.0);
        match opts.dtype {
            StoreDtype::F32 => {
                let problem = Problem::from_tensors(&inputs)?;
                sweep_point(&problem, budget_ms, opts, &mut out)?;
            }
            StoreDtype::Bf16 => {
                let e = BF16::narrow_vec(inputs[0].as_f32()?);
                let c = BF16::narrow_vec(inputs[1].as_f32()?);
                let problem = Problem::new(&e, &c, inputs[2].as_i32()?, n, d, v)?;
                sweep_point(&problem, budget_ms, opts, &mut out)?;
            }
        }
    }
    Ok(out)
}

fn sweep_point<S: crate::exec::Store>(
    problem: &crate::exec::Problem<S>,
    budget_ms: u64,
    opts: crate::exec::KernelOptions,
    out: &mut Vec<SweepPoint>,
) -> Result<()> {
    use crate::bench::harness::time_fn;
    use crate::bench::table1::measured_combined_bytes;
    use crate::exec::NativeBackend;

    let budget = Duration::from_millis(budget_ms);
    let (n, d, v) = (problem.n, problem.d, problem.v);
    for key in ["baseline", "cce"] {
        let backend = NativeBackend::from_key(key, opts)?;
        // Untimed warmup pass doubles as the memory measurement.
        let (fwd0, bwd0) = backend.forward_backward_t(problem)?;
        let res = time_fn(&format!("sweep_{key}_n{n}"), budget, || {
            std::hint::black_box(
                backend.forward_backward_t(problem).expect("native sweep"),
            );
        });
        let w = Workload {
            n_tokens: n as u64,
            vocab: v as u64,
            hidden: d as u64,
            act_bytes: S::BYTES as u64,
            softcap: false,
        };
        let mem = method_of_key(key)
            .map(|lm| method_memory(lm, &w).combined)
            .unwrap_or(0);
        eprintln!("  [sweep/native] n={n} {key}: {}", fmt_duration(res.mean()));
        out.push(SweepPoint {
            method: key.to_string(),
            n_tokens: n as u64,
            secs: res.mean(),
            mem_bytes: mem,
            fwd_workspace_bytes: Some(fwd0.workspace_bytes as u64),
            measured_bytes: Some(measured_combined_bytes(n, d, v, &fwd0, &bwd0)),
        });
    }
    Ok(())
}

/// Time `loss_fwdbwd_{method}` for every token count in the manifest sweep.
#[cfg(feature = "pjrt")]
pub fn run(rt: &Runtime, budget_ms: u64) -> Result<Vec<SweepPoint>> {
    let bench = rt
        .manifest
        .raw_meta
        .get("bench")
        .ok_or_else(|| anyhow!("no bench meta"))?;
    let d = bench.req("d")?.as_i64().unwrap() as u64;
    let v = bench.req("v")?.as_i64().unwrap() as u64;
    let ns: Vec<u64> = bench
        .req("sweep_ns")?
        .as_array()
        .unwrap_or(&[])
        .iter()
        .filter_map(|j| j.as_i64().map(|i| i as u64))
        .collect();
    let methods: Vec<String> = bench
        .req("sweep_methods")?
        .as_array()
        .unwrap_or(&[])
        .iter()
        .filter_map(|j| j.as_str().map(String::from))
        .collect();

    let mut out = Vec::new();
    let mut sorted_ns = ns.clone();
    sorted_ns.sort_unstable();
    for n in sorted_ns {
        for m in &methods {
            let name = format!("loss_fwdbwd_{m}_n{n}_d{d}_v{v}");
            if rt.manifest.entry(&name).is_err() {
                continue;
            }
            let res = time_artifact(rt, &name, 0.0, Duration::from_millis(budget_ms))?;
            let w = Workload { n_tokens: n, vocab: v, hidden: d, act_bytes: 4,
                               softcap: false };
            let mem = method_of_key(m)
                .map(|lm| method_memory(lm, &w).combined)
                .unwrap_or(0);
            eprintln!("  [sweep] n={n} {m}: {}", fmt_duration(res.mean()));
            out.push(SweepPoint {
                method: m.clone(),
                n_tokens: n,
                secs: res.mean(),
                mem_bytes: mem,
                fwd_workspace_bytes: None,
                measured_bytes: None,
            });
        }
    }
    Ok(out)
}

pub fn print(points: &[SweepPoint], csv_path: Option<&str>) -> Result<()> {
    println!("\n== Figs. A1/A2: loss+gradient time & memory vs token count ==");
    let mut t = Table::new(&[
        "N tokens", "Method", "Time", "Memory (analytic)", "Fwd ws (measured)", "Measured",
    ]);
    for p in points {
        t.row(vec![
            p.n_tokens.to_string(),
            p.method.clone(),
            fmt_duration(p.secs),
            fmt_mb(p.mem_bytes),
            p.fwd_workspace_bytes.map(fmt_mb).unwrap_or_default(),
            p.measured_bytes.map(fmt_mb).unwrap_or_default(),
        ]);
    }
    t.print();
    if let Some(path) = csv_path {
        let mut csv = Table::new(&["n", "method", "secs", "bytes"]);
        for p in points {
            csv.row(vec![
                p.n_tokens.to_string(),
                p.method.clone(),
                format!("{:.6}", p.secs),
                p.mem_bytes.to_string(),
            ]);
        }
        csv.write_csv(path)?;
        println!("  wrote {path}");
    }
    Ok(())
}

/// Shape checks for the sweep: time grows ~linearly in N for every method,
/// CCE's memory stays flat while baseline's grows linearly — asserted on
/// the analytic model always, and on the **measured** forward workspace
/// when the points carry it (the native path; this is the CI scaling
/// gate's contract, re-checked by `tools/check_bench.sh --figa1` on the
/// persisted JSON).
pub fn check(points: &[SweepPoint]) -> Result<()> {
    let series = |m: &str| -> Vec<&SweepPoint> {
        let mut v: Vec<&SweepPoint> =
            points.iter().filter(|p| p.method == m).collect();
        v.sort_by_key(|p| p.n_tokens);
        v
    };
    let cce = series("cce");
    let base = series("baseline");
    if cce.len() >= 2 && base.len() >= 2 {
        let n_ratio = (base.last().unwrap().n_tokens / base[0].n_tokens) as f64;
        let base_mem_ratio =
            base.last().unwrap().mem_bytes as f64 / base[0].mem_bytes as f64;
        let cce_mem_ratio =
            cce.last().unwrap().mem_bytes as f64 / cce[0].mem_bytes.max(1) as f64;
        if (base_mem_ratio / n_ratio - 1.0).abs() > 0.2 {
            return Err(anyhow!("baseline memory not ~linear in N"));
        }
        if cce_mem_ratio > base_mem_ratio / 2.0 {
            return Err(anyhow!("CCE memory grows too fast"));
        }
        // Measured counterpart (native points): cce's forward workspace is
        // O(N) vectors + fixed tiles — near-flat; the baseline's is the
        // N×V logit matrix — within 30% of linear.
        if let (Some(c0), Some(c1), Some(b0), Some(b1)) = (
            cce[0].fwd_workspace_bytes,
            cce.last().unwrap().fwd_workspace_bytes,
            base[0].fwd_workspace_bytes,
            base.last().unwrap().fwd_workspace_bytes,
        ) {
            let cce_ws_ratio = c1 as f64 / c0.max(1) as f64;
            let base_ws_ratio = b1 as f64 / b0.max(1) as f64;
            if cce_ws_ratio > 1.5 {
                return Err(anyhow!(
                    "measured cce forward workspace grew {cce_ws_ratio:.2}x over a \
                     {n_ratio:.0}x N sweep — the O(N_B·V_B) bound broke"
                ));
            }
            if base_ws_ratio < 0.7 * n_ratio {
                return Err(anyhow!(
                    "measured baseline workspace grew only {base_ws_ratio:.2}x over a \
                     {n_ratio:.0}x N sweep — it stopped materializing N×V?"
                ));
            }
        }
    }
    Ok(())
}

/// Persist the sweep as `BENCH_figA1.json` for the CI scaling gate
/// (`tools/check_bench.sh --figa1`): a *structural* shape check — cce's
/// measured workspace flat in N, baseline's ~linear — not a timing gate.
pub fn write_json(
    points: &[SweepPoint],
    d: usize,
    v: usize,
    dtype: crate::exec::StoreDtype,
    threads: usize,
    path: impl AsRef<std::path::Path>,
) -> Result<()> {
    let jpoints: Vec<Json> = points
        .iter()
        .map(|p| {
            let mut fields = vec![
                ("method", Json::str(p.method.as_str())),
                ("n", Json::Int(p.n_tokens as i64)),
                ("fwdbwd_ms", Json::Float(p.secs * 1e3)),
                ("mem_analytic_bytes", Json::Int(p.mem_bytes as i64)),
            ];
            if let Some(w) = p.fwd_workspace_bytes {
                fields.push(("fwd_workspace_bytes", Json::Int(w as i64)));
            }
            if let Some(m) = p.measured_bytes {
                fields.push(("measured_bytes", Json::Int(m as i64)));
            }
            Json::obj(fields)
        })
        .collect();
    let doc = Json::obj(vec![
        ("bench", Json::str("figA1")),
        ("schema", Json::Int(1)),
        ("simd", Json::str(crate::exec::simd_dispatch())),
        ("dtype", Json::str(dtype.name())),
        (
            "grid",
            Json::obj(vec![("d", Json::Int(d as i64)), ("v", Json::Int(v as i64))]),
        ),
        ("threads", Json::Int(threads as i64)),
        ("points", Json::arr(jpoints)),
    ]);
    if let Some(parent) = path.as_ref().parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(&path, doc.to_string_pretty())?;
    Ok(())
}
