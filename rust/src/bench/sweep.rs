//! Figs. A1/A2 harness: loss+gradient time and memory vs token count.
//!
//! `run_native` sweeps the native kernels over a list of token counts with
//! zero artifacts; `run` (pjrt) times the AOT artifacts in the manifest.

use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::bench::harness::Table;
use crate::memmodel::{method_memory, LossMethod, Workload};
use crate::util::stats::{fmt_duration, fmt_mb};

#[cfg(feature = "pjrt")]
use crate::bench::harness::time_artifact;
#[cfg(feature = "pjrt")]
use crate::runtime::Runtime;

pub struct SweepPoint {
    pub method: String,
    pub n_tokens: u64,
    pub secs: f64,
    pub mem_bytes: u64,
}

fn method_of_key(key: &str) -> Option<LossMethod> {
    Some(match key {
        "cce" => LossMethod::Cce,
        "baseline" => LossMethod::Baseline,
        "fused" => LossMethod::TorchCompile,
        "chunked8" => LossMethod::Chunked(8),
        "liger" => LossMethod::Liger,
        _ => return None,
    })
}

/// Sweep the native kernels over `ns` token counts at a fixed `(d, v)`
/// grid — the Fig. A1/A2 time/memory-vs-N curves with zero artifacts.
pub fn run_native(
    d: usize,
    v: usize,
    ns: &[usize],
    budget_ms: u64,
    opts: crate::exec::KernelOptions,
    seed: u64,
) -> Result<Vec<SweepPoint>> {
    use crate::bench::harness::{gen_loss_inputs, time_fn};
    use crate::exec::{Backend, NativeBackend, Problem};
    use crate::util::rng::Rng;

    let budget = Duration::from_millis(budget_ms);
    let mut out = Vec::new();
    let mut sorted_ns = ns.to_vec();
    sorted_ns.sort_unstable();
    for &n in &sorted_ns {
        let mut rng = Rng::new(seed ^ (n as u64).wrapping_mul(0x9E37_79B9));
        let inputs = gen_loss_inputs(n, d, v, &mut rng, 0.0);
        let problem = Problem::from_tensors(&inputs)?;
        for key in ["baseline", "cce"] {
            let backend = NativeBackend::from_key(key, opts)?;
            let res = time_fn(&format!("sweep_{key}_n{n}"), budget, || {
                std::hint::black_box(
                    backend.forward_backward(&problem).expect("native sweep"),
                );
            });
            let w = Workload {
                n_tokens: n as u64,
                vocab: v as u64,
                hidden: d as u64,
                act_bytes: 4,
                softcap: false,
            };
            let mem = method_of_key(key)
                .map(|lm| method_memory(lm, &w).combined)
                .unwrap_or(0);
            eprintln!("  [sweep/native] n={n} {key}: {}", fmt_duration(res.mean()));
            out.push(SweepPoint {
                method: key.to_string(),
                n_tokens: n as u64,
                secs: res.mean(),
                mem_bytes: mem,
            });
        }
    }
    Ok(out)
}

/// Time `loss_fwdbwd_{method}` for every token count in the manifest sweep.
#[cfg(feature = "pjrt")]
pub fn run(rt: &Runtime, budget_ms: u64) -> Result<Vec<SweepPoint>> {
    let bench = rt
        .manifest
        .raw_meta
        .get("bench")
        .ok_or_else(|| anyhow!("no bench meta"))?;
    let d = bench.req("d")?.as_i64().unwrap() as u64;
    let v = bench.req("v")?.as_i64().unwrap() as u64;
    let ns: Vec<u64> = bench
        .req("sweep_ns")?
        .as_array()
        .unwrap_or(&[])
        .iter()
        .filter_map(|j| j.as_i64().map(|i| i as u64))
        .collect();
    let methods: Vec<String> = bench
        .req("sweep_methods")?
        .as_array()
        .unwrap_or(&[])
        .iter()
        .filter_map(|j| j.as_str().map(String::from))
        .collect();

    let mut out = Vec::new();
    let mut sorted_ns = ns.clone();
    sorted_ns.sort_unstable();
    for n in sorted_ns {
        for m in &methods {
            let name = format!("loss_fwdbwd_{m}_n{n}_d{d}_v{v}");
            if rt.manifest.entry(&name).is_err() {
                continue;
            }
            let res = time_artifact(rt, &name, 0.0, Duration::from_millis(budget_ms))?;
            let w = Workload { n_tokens: n, vocab: v, hidden: d, act_bytes: 4,
                               softcap: false };
            let mem = method_of_key(m)
                .map(|lm| method_memory(lm, &w).combined)
                .unwrap_or(0);
            eprintln!("  [sweep] n={n} {m}: {}", fmt_duration(res.mean()));
            out.push(SweepPoint {
                method: m.clone(),
                n_tokens: n,
                secs: res.mean(),
                mem_bytes: mem,
            });
        }
    }
    Ok(out)
}

pub fn print(points: &[SweepPoint], csv_path: Option<&str>) -> Result<()> {
    println!("\n== Figs. A1/A2: loss+gradient time & memory vs token count ==");
    let mut t = Table::new(&["N tokens", "Method", "Time", "Memory (analytic)"]);
    for p in points {
        t.row(vec![
            p.n_tokens.to_string(),
            p.method.clone(),
            fmt_duration(p.secs),
            fmt_mb(p.mem_bytes),
        ]);
    }
    t.print();
    if let Some(path) = csv_path {
        let mut csv = Table::new(&["n", "method", "secs", "bytes"]);
        for p in points {
            csv.row(vec![
                p.n_tokens.to_string(),
                p.method.clone(),
                format!("{:.6}", p.secs),
                p.mem_bytes.to_string(),
            ]);
        }
        csv.write_csv(path)?;
        println!("  wrote {path}");
    }
    Ok(())
}

/// Shape checks for the sweep: time grows ~linearly in N for every method,
/// and CCE's memory stays flat while baseline's grows linearly.
pub fn check(points: &[SweepPoint]) -> Result<()> {
    let series = |m: &str| -> Vec<&SweepPoint> {
        let mut v: Vec<&SweepPoint> =
            points.iter().filter(|p| p.method == m).collect();
        v.sort_by_key(|p| p.n_tokens);
        v
    };
    let cce = series("cce");
    let base = series("baseline");
    if cce.len() >= 2 && base.len() >= 2 {
        let n_ratio = (base.last().unwrap().n_tokens / base[0].n_tokens) as f64;
        let base_mem_ratio =
            base.last().unwrap().mem_bytes as f64 / base[0].mem_bytes as f64;
        let cce_mem_ratio =
            cce.last().unwrap().mem_bytes as f64 / cce[0].mem_bytes.max(1) as f64;
        if (base_mem_ratio / n_ratio - 1.0).abs() > 0.2 {
            return Err(anyhow!("baseline memory not ~linear in N"));
        }
        if cce_mem_ratio > base_mem_ratio / 2.0 {
            return Err(anyhow!("CCE memory grows too fast"));
        }
    }
    Ok(())
}
