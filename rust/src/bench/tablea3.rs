//! Table A3 harness: memory (analytic, full scale) for the additional
//! models of Appendix C.2, all methods.
//!
//! Wall-clock at each model's full dims is out of reach for the CPU
//! substrate, so this table reports the analytic memory model per model —
//! which is what changes across Table A3's blocks — alongside the paper's
//! published values; the latency ordering is measured once at the scaled
//! grid by `cce table1`.

use crate::bench::harness::Table;
use crate::memmodel::models::BENCH_MODELS;
use crate::memmodel::{method_memory, LossMethod, Workload};
use crate::util::stats::fmt_mb;

/// Paper Table A3 loss+gradient memory (MB) per (model, method key).
pub const PAPER_A3_COMBINED_MB: &[(&str, &[(&str, u64)])] = &[
    ("Gemma 2 (9B)", &[("cce", 1_809), ("liger", 2_119), ("chunked8", 11_264),
                       ("fused", 16_000), ("baseline", 28_000), ("cce_kahan_fullc", 3_559)]),
    ("Gemma 2 (27B)", &[("cce", 2_325), ("liger", 2_948), ("chunked8", 12_768),
                        ("fused", 16_000), ("baseline", 28_000), ("cce_kahan_fullc", 4_575)]),
    ("Mistral NeMo", &[("cce", 1_362), ("liger", 1_872), ("chunked8", 5_396),
                       // the baseline combined cell is garbled in the paper's
                       // Table A3; 12_288 = 12 B/elem is the derived value
                       ("fused", 8_192), ("baseline", 12_288), ("cce_kahan_fullc", 2_642)]),
    ("Phi 3.5 Mini", &[("cce", 236), ("liger", 488), ("chunked8", 953),
                       ("fused", 2_006), ("baseline", 3_006), ("cce_kahan_fullc", 424)]),
    ("Qwen 2.5 (7B)", &[("cce", 1_097), ("liger", 1_394), ("chunked8", 4_921),
                        ("fused", 9_504), ("baseline", 14_256), ("cce_kahan_fullc", 2_138)]),
    ("Qwen 2.5 (32B)", &[("cce", 1_567), ("liger", 2_161), ("chunked8", 6_259),
                         ("fused", 9_504), ("baseline", 14_256), ("cce_kahan_fullc", 3_053)]),
];

const METHODS: &[LossMethod] = &[
    LossMethod::Cce,
    LossMethod::Liger,
    LossMethod::Chunked(8),
    LossMethod::TorchCompile,
    LossMethod::Baseline,
    LossMethod::CceKahanFullC,
];

pub fn run(csv: Option<&str>) -> anyhow::Result<()> {
    println!("\n== Table A3: loss+gradient memory for additional models ==");
    println!("   analytic model at full scale (N=8192 tokens, bf16 grads)\n");
    let mut t = Table::new(&["Model", "Method", "Memory (ours)", "Memory (paper)"]);
    for &(name, vocab, hidden) in BENCH_MODELS {
        if name == "Gemma 2 (2B)" {
            continue; // that column is Table 1
        }
        let w = Workload { n_tokens: 8192, vocab, hidden, act_bytes: 2,
                           softcap: vocab == 256_000 };
        for method in METHODS {
            let mem = method_memory(*method, &w).combined;
            let paper = PAPER_A3_COMBINED_MB
                .iter()
                .find(|(m, _)| *m == name)
                .and_then(|(_, rows)| {
                    rows.iter().find(|(k, _)| *k == method.key())
                })
                .map(|(_, mb)| format!("{mb} MB"))
                .unwrap_or_default();
            t.row(vec![
                name.to_string(),
                method.label(),
                fmt_mb(mem),
                paper,
            ]);
        }
    }
    t.print();
    if let Some(path) = csv {
        t.write_csv(path)?;
        println!("  wrote {path}");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Our analytic model should land within 25% of the paper's A3 cells
    /// for the structural methods (baseline / fused / CCE-class).  The
    /// chunked rows (Torch Tune, Liger) depend on PyTorch allocator
    /// behaviour the paper doesn't specify; they are displayed but checked
    /// only loosely (within 2.5x).
    #[test]
    fn within_tolerance_of_paper() {
        for &(name, rows) in PAPER_A3_COMBINED_MB {
            let &(_, vocab, hidden) = BENCH_MODELS
                .iter()
                .find(|(n, _, _)| *n == name)
                .unwrap();
            let w = Workload { n_tokens: 8192, vocab, hidden, act_bytes: 2,
                               softcap: vocab == 256_000 };
            for &(key, paper_mb) in rows {
                let method = METHODS.iter().find(|m| m.key() == key).unwrap();
                let ours_mb = method_memory(*method, &w).combined / crate::memmodel::MB;
                let rel = (ours_mb as f64 - paper_mb as f64).abs() / paper_mb as f64;
                let tol = match key {
                    "chunked8" | "liger" => 1.5,
                    _ => 0.25,
                };
                assert!(
                    rel < tol,
                    "{name}/{key}: ours {ours_mb} MB vs paper {paper_mb} MB ({rel:.2})"
                );
            }
        }
    }
}
