//! Table 1 / Table A1 harness: per-method memory and time for the loss, the
//! gradient, and their combination.
//!
//! Two execution paths share the [`Row`] shape and the printers:
//!
//! * [`run_native`] measures the multi-threaded Rust kernels
//!   ([`crate::exec`]) on Zipf-peaked trained-like inputs
//!   ([`gen_loss_inputs`]) with the vocabulary ids shuffled, so the
//!   filtered/sorted backward has real work to do.  Zero artifacts.  The
//!   measured block survival is printed next to
//!   [`crate::sparsity::BlockFilterModel`]'s prediction, and `--json`
//!   persists the rows as `BENCH_table1.json` for cross-PR perf tracking.
//! * [`run`] (behind the `pjrt` feature) times the AOT loss artifacts.
//!
//! Memory columns are analytic ([`crate::memmodel`], exact at the paper's
//! scale); the native path additionally reports each kernel's *measured*
//! working set.  Gradient time is reported as `fwdbwd − fwd`.

use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::bench::harness::{gen_loss_inputs, time_fn, Table};
use crate::exec::{
    BackwardOut, FilterStats, ForwardOut, KernelOptions, NativeBackend, Problem, Store, StoreDtype,
    BF16,
};
use crate::memmodel::{method_memory, LossMethod, Workload, MB};
use crate::runtime::{Data, HostTensor};
use crate::sparsity::speedup_at_survival;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::stats::{fmt_duration, fmt_mb};

#[cfg(feature = "pjrt")]
use crate::bench::harness::time_artifact;
#[cfg(feature = "pjrt")]
use crate::runtime::Runtime;

/// Paper Table 1 values (Gemma 2 2B, A100) for side-by-side display:
/// (method key, loss MB, grad MB, combined MB, loss ms, grad ms, comb ms).
pub const PAPER_TABLE1: &[(&str, u64, u64, u64, u64, u64, u64)] = &[
    ("cce", 1, 1_163, 1_164, 46, 100, 145),
    ("liger", 1_474, 0, 1_474, 304, 0, 304),
    ("chunked8", 8_000, 1_630, 9_631, 55, 115, 169),
    ("fused", 4_000, 12_000, 16_000, 49, 92, 143),
    ("baseline", 24_000, 16_000, 28_000, 82, 122, 208),
    ("cce_no_sort", 0, 1_162, 1_162, 45, 115, 159),
    ("cce_no_filter", 0, 1_163, 1_162, 45, 314, 357),
    ("cce_kahan", 1, 2_325, 2_326, 47, 114, 160),
    ("cce_kahan_fullc", 1, 2_326, 2_326, 47, 268, 313),
    ("cce_kahan_fulle", 1, 2_326, 2_326, 47, 247, 292),
];

/// One measured row.
#[derive(Debug, Clone)]
pub struct Row {
    pub method: LossMethod,
    /// Which backend produced the timings: `"native"` or `"pjrt"`.
    pub backend: &'static str,
    /// Storage dtype the row was measured under (`--dtype`).
    pub dtype: StoreDtype,
    pub fwd_secs: f64,
    pub fwdbwd_secs: f64,
    /// Measured loss (native path; used for cross-method parity checks).
    pub loss: Option<f64>,
    /// Measured peak working memory over the forward+backward pass: the
    /// larger of the two phases (the backward phase still holds the
    /// forward's O(N) lse/target vectors).  Excludes the gradient
    /// outputs — [`Row::measured_bytes`] is the full memory column.
    pub working_bytes: Option<u64>,
    /// Measured forward-only working memory (native path).
    pub fwd_working_bytes: Option<u64>,
    /// Measured gradient-output bytes (`dE` + `dC` in the storage dtype —
    /// the paper's `G` lower bound, measured).
    pub grad_bytes: Option<u64>,
    /// The **measured memory column**: gradient outputs + peak concurrent
    /// workspace (see [`measured_combined_bytes`]) — what the analytic
    /// `mem_scaled` models, measured from real allocations.
    pub measured_bytes: Option<u64>,
    /// Gradient-filter accounting (native cce variants).
    pub stats: Option<FilterStats>,
    pub mem_scaled: crate::memmodel::MethodMemory,
    pub mem_paper: crate::memmodel::MethodMemory,
}

impl Row {
    pub fn bwd_secs(&self) -> f64 {
        (self.fwdbwd_secs - self.fwd_secs).max(0.0)
    }
}

/// The measured loss+gradient memory of one native forward+backward at
/// grid `(n, d, v)`: the gradient outputs (`(N+V)·D` elements in the
/// storage dtype — the analytic model's `G`) plus the peak *concurrent*
/// kernel workspace (the forward's O(N) lse/target vectors span both
/// passes; its tile buffers are freed before the backward allocates).
/// This is the number the `--dtype bf16` acceptance check pins within 15%
/// of the analytic model at the CI grid.
pub fn measured_combined_bytes<S: Store>(
    n: usize,
    d: usize,
    v: usize,
    fwd: &ForwardOut,
    bwd: &BackwardOut<S>,
) -> u64 {
    let grads = ((n + v) * d * S::BYTES) as u64;
    let fwd_peak = fwd.workspace_bytes as u64;
    let bwd_peak = grads + bwd.workspace_bytes as u64 + (n * 8) as u64;
    fwd_peak.max(bwd_peak)
}

/// The methods the native backend implements, in Table-1 display order —
/// every paper row except `liger`/`fused`, which are third-party GPU
/// implementations with no native analogue.
pub fn native_methods() -> Vec<LossMethod> {
    vec![
        LossMethod::Cce,
        LossMethod::Chunked(8),
        LossMethod::Baseline,
        LossMethod::CceNoSort,
        LossMethod::CceNoFilter,
        LossMethod::CceKahan,
        LossMethod::CceKahanFullC,
        LossMethod::CceKahanFullE,
    ]
}

/// Shuffle vocabulary identities in-place (classifier rows + labels) so
/// token frequency is uncorrelated with token id — real vocabularies are
/// not frequency-sorted, which is exactly why §4.3 sorts them.
fn shuffle_vocab_ids(inputs: &mut [HostTensor], rng: &mut Rng) {
    let v = inputs[1].shape[0];
    let d = inputs[1].shape[1];
    let mut sigma: Vec<usize> = (0..v).collect();
    rng.shuffle(&mut sigma);
    let c_old = inputs[1].as_f32().expect("c tensor").to_vec();
    if let Data::F32(c_new) = &mut inputs[1].data {
        for j in 0..v {
            let nj = sigma[j];
            c_new[nj * d..(nj + 1) * d].copy_from_slice(&c_old[j * d..(j + 1) * d]);
        }
    }
    if let Data::I32(labels) = &mut inputs[2].data {
        for t in labels.iter_mut() {
            if *t >= 0 {
                *t = sigma[*t as usize] as i32;
            }
        }
    }
}

/// Measure all native methods on a `(n, d, v)` grid of trained-like inputs
/// under `opts.dtype` storage: with `--dtype bf16` the inputs are narrowed
/// once (the paper measures under trained bf16 weights) and every kernel
/// reads/writes half-width storage.
pub fn run_native(
    n: usize,
    d: usize,
    v: usize,
    ignored_frac: f64,
    budget_ms: u64,
    opts: KernelOptions,
    seed: u64,
) -> Result<Vec<Row>> {
    let mut rng = Rng::new(seed ^ 0x7AB1E);
    let mut inputs = gen_loss_inputs(n, d, v, &mut rng, ignored_frac);
    shuffle_vocab_ids(&mut inputs, &mut rng);
    match opts.dtype {
        StoreDtype::F32 => {
            let problem = Problem::from_tensors(&inputs)?;
            run_native_rows(&problem, budget_ms, opts)
        }
        StoreDtype::Bf16 => {
            let e = BF16::narrow_vec(inputs[0].as_f32()?);
            let c = BF16::narrow_vec(inputs[1].as_f32()?);
            let problem = Problem::new(&e, &c, inputs[2].as_i32()?, n, d, v)?;
            run_native_rows(&problem, budget_ms, opts)
        }
    }
}

fn run_native_rows<S: Store>(
    problem: &Problem<S>,
    budget_ms: u64,
    opts: KernelOptions,
) -> Result<Vec<Row>> {
    let (n, d, v) = (problem.n, problem.d, problem.v);
    let budget = Duration::from_millis(budget_ms);
    let scaled = Workload {
        n_tokens: n as u64,
        vocab: v as u64,
        hidden: d as u64,
        act_bytes: S::BYTES as u64,
        softcap: false,
    };
    let paper = Workload::gemma2_2b();

    let mut rows = Vec::new();
    for method in native_methods() {
        let key = method.key();
        let backend = NativeBackend::from_key(&key, opts)?;
        // One untimed pass doubles as warmup and yields loss/stats/memory.
        let (fwd0, bwd0) = backend.forward_backward_t(problem)?;
        let fwd_res = time_fn(&format!("fwd_{key}"), budget, || {
            std::hint::black_box(backend.forward_t(problem).expect("native forward"));
        });
        let fwdbwd_res = time_fn(&format!("fwdbwd_{key}"), budget, || {
            std::hint::black_box(
                backend.forward_backward_t(problem).expect("native forward_backward"),
            );
        });
        eprintln!(
            "  [table1/native] {key}: fwd {} fwd+bwd {} (survival {:.0}%)",
            fmt_duration(fwd_res.median()),
            fmt_duration(fwdbwd_res.median()),
            100.0 * bwd0.stats.survival()
        );
        rows.push(Row {
            method,
            backend: "native",
            dtype: S::DTYPE,
            // Medians, not means: the CI regression gate
            // (tools/check_bench.sh) compares these across PRs, and the
            // median is robust to scheduler hiccups on shared runners.
            fwd_secs: fwd_res.median(),
            fwdbwd_secs: fwdbwd_res.median(),
            loss: Some(fwd0.loss),
            // Peak, not sum: forward block buffers are freed before the
            // backward allocates; the O(N) lse/target vectors span both.
            working_bytes: Some(
                fwd0.workspace_bytes.max(bwd0.workspace_bytes + n * 8) as u64,
            ),
            fwd_working_bytes: Some(fwd0.workspace_bytes as u64),
            grad_bytes: Some(((n + v) * d * S::BYTES) as u64),
            measured_bytes: Some(measured_combined_bytes(n, d, v, &fwd0, &bwd0)),
            stats: Some(bwd0.stats),
            mem_scaled: method_memory(method, &scaled),
            mem_paper: method_memory(method, &paper),
        });
    }
    Ok(rows)
}

/// The decode-shape measurement: `cce` forward / forward+backward at a
/// small N (CI uses 8 — one lockstep micro-batch of decode steps) on the
/// same `(D, V)` grid.  At this shape per-call *orchestration* cost —
/// thread spawn/join, dispatch probes — dominates the FLOPs, which is
/// exactly what the persistent pool and the once-per-sweep SIMD token
/// remove; `tools/check_bench.sh` gates this row so that overhead cannot
/// silently creep back.
#[derive(Debug, Clone, Copy)]
pub struct SmallN {
    pub n: usize,
    pub fwd_secs: f64,
    pub fwdbwd_secs: f64,
}

impl SmallN {
    pub fn bwd_secs(&self) -> f64 {
        (self.fwdbwd_secs - self.fwd_secs).max(0.0)
    }
}

/// Measure the small-N decode-shape row (native `cce` only).
pub fn run_native_small(
    n: usize,
    d: usize,
    v: usize,
    ignored_frac: f64,
    budget_ms: u64,
    opts: KernelOptions,
    seed: u64,
) -> Result<SmallN> {
    let mut rng = Rng::new(seed ^ 0x5_0411);
    let mut inputs = gen_loss_inputs(n, d, v, &mut rng, ignored_frac);
    shuffle_vocab_ids(&mut inputs, &mut rng);
    match opts.dtype {
        StoreDtype::F32 => {
            let problem = Problem::from_tensors(&inputs)?;
            run_native_small_rows(&problem, budget_ms, opts)
        }
        StoreDtype::Bf16 => {
            let e = BF16::narrow_vec(inputs[0].as_f32()?);
            let c = BF16::narrow_vec(inputs[1].as_f32()?);
            let problem = Problem::new(&e, &c, inputs[2].as_i32()?, n, d, v)?;
            run_native_small_rows(&problem, budget_ms, opts)
        }
    }
}

fn run_native_small_rows<S: Store>(
    problem: &Problem<S>,
    budget_ms: u64,
    opts: KernelOptions,
) -> Result<SmallN> {
    let n = problem.n;
    let backend = NativeBackend::from_key("cce", opts)?;
    let budget = Duration::from_millis(budget_ms);
    let _ = backend.forward_backward_t(problem)?; // warmup
    let fwd = time_fn("small_n_fwd_cce", budget, || {
        std::hint::black_box(backend.forward_t(problem).expect("native forward"));
    });
    let fwdbwd = time_fn("small_n_fwdbwd_cce", budget, || {
        std::hint::black_box(backend.forward_backward_t(problem).expect("native fwdbwd"));
    });
    eprintln!(
        "  [table1/native] cce @ N={n} (decode shape): fwd {} fwd+bwd {}",
        fmt_duration(fwd.median()),
        fmt_duration(fwdbwd.median())
    );
    Ok(SmallN { n, fwd_secs: fwd.median(), fwdbwd_secs: fwdbwd.median() })
}

/// Measure all methods at the benchmark grid in the manifest (AOT
/// artifacts through PJRT).
#[cfg(feature = "pjrt")]
pub fn run(rt: &Runtime, ignored_frac: f64, budget_ms: u64) -> Result<Vec<Row>> {
    let bench = rt
        .manifest
        .raw_meta
        .get("bench")
        .ok_or_else(|| anyhow!("no bench meta in manifest"))?;
    let n = bench.req("n")?.as_i64().unwrap() as u64;
    let d = bench.req("d")?.as_i64().unwrap() as u64;
    let v = bench.req("v")?.as_i64().unwrap() as u64;
    let size_tag = format!("n{n}_d{d}_v{v}");
    // Our substrate runs f32 (act_bytes 4); the paper column uses bf16.
    let scaled = Workload { n_tokens: n, vocab: v, hidden: d, act_bytes: 4,
                            softcap: false };
    let paper = Workload::gemma2_2b();
    let budget = Duration::from_millis(budget_ms);

    let mut rows = Vec::new();
    for method in LossMethod::table1_order() {
        let key = method.key();
        let fwd = time_artifact(rt, &format!("loss_fwd_{key}_{size_tag}"),
                                ignored_frac, budget)?;
        let fwdbwd = time_artifact(rt, &format!("loss_fwdbwd_{key}_{size_tag}"),
                                   ignored_frac, budget)?;
        eprintln!(
            "  [table1] {key}: fwd {} fwd+bwd {}",
            fmt_duration(fwd.median()),
            fmt_duration(fwdbwd.median())
        );
        rows.push(Row {
            method,
            backend: "pjrt",
            dtype: StoreDtype::F32,
            fwd_secs: fwd.median(),
            fwdbwd_secs: fwdbwd.median(),
            loss: None,
            working_bytes: None,
            fwd_working_bytes: None,
            grad_bytes: None,
            measured_bytes: None,
            stats: None,
            mem_scaled: method_memory(method, &scaled),
            mem_paper: method_memory(method, &paper),
        });
    }
    Ok(rows)
}

/// Render the table (measured time + analytic memory at both scales +
/// measured working set where available + the paper's published numbers).
pub fn print(rows: &[Row], title: &str) {
    println!("\n== {title} ==");
    let backend = rows.first().map(|r| r.backend).unwrap_or("native");
    let dtype = rows.first().map(|r| r.dtype.name()).unwrap_or("f32");
    println!(
        "   time: measured on this substrate ({backend} backend, {dtype} storage, scaled grid)"
    );
    println!(
        "   memory: 'Measured' = real allocations (grads + peak workspace); 'Mem scaled' = \
         analytic model at the measured grid ({dtype}); 'Mem paper' at Gemma 2 2B (N=8192, \
         |V|=256000, D=2304, bf16)"
    );
    println!("   working set: measured kernel buffers, outputs excluded (native backend only)\n");
    let mut t = Table::new(&[
        "Method", "Loss t", "Grad t", "L+G t", "Measured", "Working set",
        "Mem scaled", "Mem paper", "Paper mem", "Paper t",
    ]);
    for r in rows {
        let paper_row = PAPER_TABLE1
            .iter()
            .find(|p| p.0 == r.method.key());
        t.row(vec![
            r.method.label(),
            fmt_duration(r.fwd_secs),
            fmt_duration(r.bwd_secs()),
            fmt_duration(r.fwdbwd_secs),
            r.measured_bytes.map(fmt_mb).unwrap_or_default(),
            r.working_bytes.map(fmt_mb).unwrap_or_default(),
            fmt_mb(r.mem_scaled.combined),
            fmt_mb(r.mem_paper.combined),
            paper_row.map(|p| format!("{} MB", p.3)).unwrap_or_default(),
            paper_row.map(|p| format!("{} ms", p.6)).unwrap_or_default(),
        ]);
    }
    t.print();
    if let Some((measured, predicted, survival)) = filter_speedup(rows) {
        println!(
            "\n  gradient filter: measured bwd speedup {measured:.2}x vs \
             {predicted:.2}x predicted by BlockFilterModel at the measured \
             {:.1}% block survival",
            100.0 * survival
        );
    }
}

/// Measured filtered-vs-unfiltered backward speedup, the model's prediction
/// at the measured survival, and that survival.  `None` unless the row set
/// has native cce + cce_no_filter rows.
pub fn filter_speedup(rows: &[Row]) -> Option<(f64, f64, f64)> {
    let cce = rows.iter().find(|r| r.method == LossMethod::Cce)?;
    let nofilter = rows.iter().find(|r| r.method == LossMethod::CceNoFilter)?;
    let stats = cce.stats?;
    if cce.backend != "native" {
        return None;
    }
    let survival = stats.survival();
    let predicted = speedup_at_survival(survival, BWD_FIXED_FRACTION);
    Some((nofilter.bwd_secs() / cce.bwd_secs().max(1e-9), predicted, survival))
}

/// Fraction of the backward's matmul-sized work the filter can never skip.
/// The column-parallel backward runs four such passes — the dE phase's
/// rematerialization (always), its dE accumulation, and the dC phase's
/// rematerialization + accumulation (all three survival-scaled, because
/// the dC phase consults the dE phase's skip mask *before*
/// rematerializing) => overhead 1/4.
pub const BWD_FIXED_FRACTION: f64 = 0.25;

/// Persist rows as machine-readable JSON (`BENCH_table1.json`) so the perf
/// trajectory is trackable across PRs.  `threads` is the *resolved* worker
/// count (`--threads 0` = auto already applied), `pool_workers` the shared
/// pool's spawned-worker count after the run, and `small_n` the optional
/// decode-shape row.
pub fn write_json(
    rows: &[Row],
    grid: (usize, usize, usize),
    threads: usize,
    pool_workers: usize,
    small_n: Option<&SmallN>,
    path: impl AsRef<std::path::Path>,
) -> Result<()> {
    let jrows: Vec<Json> = rows
        .iter()
        .map(|r| {
            let mut fields = vec![
                ("method", Json::str(r.method.key())),
                ("backend", Json::str(r.backend)),
                ("fwd_ms", Json::Float(r.fwd_secs * 1e3)),
                ("bwd_ms", Json::Float(r.bwd_secs() * 1e3)),
                ("fwdbwd_ms", Json::Float(r.fwdbwd_secs * 1e3)),
                (
                    "mem_scaled_mb",
                    Json::Float(r.mem_scaled.combined as f64 / MB as f64),
                ),
                (
                    "mem_paper_mb",
                    Json::Float(r.mem_paper.combined as f64 / MB as f64),
                ),
            ];
            if let Some(loss) = r.loss {
                fields.push(("loss", Json::Float(loss)));
            }
            if let Some(w) = r.working_bytes {
                fields.push(("working_mb", Json::Float(w as f64 / MB as f64)));
            }
            if let Some(w) = r.fwd_working_bytes {
                fields.push(("fwd_working_mb", Json::Float(w as f64 / MB as f64)));
            }
            if let Some(g) = r.grad_bytes {
                fields.push(("grad_mb", Json::Float(g as f64 / MB as f64)));
            }
            if let Some(m) = r.measured_bytes {
                fields.push(("measured_mb", Json::Float(m as f64 / MB as f64)));
            }
            if let Some(s) = r.stats {
                fields.push(("block_survival", Json::Float(s.survival())));
                fields.push(("sig_entries", Json::Int(s.sig_entries as i64)));
            }
            Json::obj(fields)
        })
        .collect();
    let dtype = rows.first().map(|r| r.dtype).unwrap_or(StoreDtype::F32);
    let mut doc = vec![
        ("bench", Json::str("table1")),
        // Schema 2 (PR 5): measured memory columns (grad_mb/measured_mb),
        // the dtype tag, and the backward's new peak-concurrent workspace
        // semantics.  check_bench treats a schema change as a bootstrap.
        ("schema", Json::Int(2)),
        // Timings from different SIMD dispatch levels or storage dtypes
        // are not comparable; check_bench treats a change in either as a
        // bootstrap, not a diff.
        ("simd", Json::str(crate::exec::simd_dispatch())),
        ("dtype", Json::str(dtype.name())),
        (
            "grid",
            Json::obj(vec![
                ("n", Json::Int(grid.0 as i64)),
                ("d", Json::Int(grid.1 as i64)),
                ("v", Json::Int(grid.2 as i64)),
            ]),
        ),
        ("threads", Json::Int(threads as i64)),
        ("pool_workers", Json::Int(pool_workers as i64)),
        ("rows", Json::arr(jrows)),
    ];
    if let Some(small) = small_n {
        doc.push((
            "small_n",
            Json::obj(vec![
                ("n", Json::Int(small.n as i64)),
                ("fwd_ms", Json::Float(small.fwd_secs * 1e3)),
                ("bwd_ms", Json::Float(small.bwd_secs() * 1e3)),
                ("fwdbwd_ms", Json::Float(small.fwdbwd_secs * 1e3)),
            ]),
        ));
    }
    if let Some((measured, predicted, survival)) = filter_speedup(rows) {
        doc.push((
            "filter_speedup",
            Json::obj(vec![
                ("measured", Json::Float(measured)),
                ("predicted", Json::Float(predicted)),
                ("survival", Json::Float(survival)),
            ]),
        ));
    }
    let json = Json::obj(doc);
    if let Some(parent) = path.as_ref().parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(&path, json.to_string_pretty())?;
    Ok(())
}

/// Shape assertions behind the headline claims (used by `cce table1
/// --check` and the integration tests):
///
/// 1. CCE's analytic memory is >=20x below Baseline's at paper scale.
/// 2. gradient filtering adds no measurable overhead (see inline note on
///    why the paper's 3.4x *gain* needs finer blocks than the artifact
///    substrate provides — the native backend *does* reproduce the gain,
///    see [`check_native`]).
/// 3. CCE fwd+bwd is within 10x of the fused (compile) baseline.  The
///    paper's parity claim holds on GPU where the blockwise tiles live in
///    SRAM next to the tensor cores; interpret-mode Pallas emulates each
///    grid step as a sequential HLO loop iteration, so a constant-factor
///    emulation overhead over the single-GEMM baseline is expected on that
///    substrate (see DESIGN.md §Hardware-Adaptation).
pub fn check(rows: &[Row]) -> Result<()> {
    let get = |m: &LossMethod| -> Option<&Row> {
        rows.iter().find(|r| &r.method == m)
    };
    let cce = get(&LossMethod::Cce).ok_or_else(|| anyhow!("no cce row"))?;
    let base = get(&LossMethod::Baseline).ok_or_else(|| anyhow!("no baseline"))?;
    let fused = get(&LossMethod::TorchCompile).ok_or_else(|| anyhow!("no fused"))?;
    let nofilter = get(&LossMethod::CceNoFilter);

    if base.mem_paper.combined < 20 * cce.mem_paper.combined {
        return Err(anyhow!(
            "memory claim failed: baseline {} vs cce {}",
            base.mem_paper.combined,
            cce.mem_paper.combined
        ));
    }
    if let Some(nf) = nofilter {
        // On the artifact substrate the bench tiles are 512x2048 (required
        // to make interpret-mode tractable), which leaves only 16
        // vocabulary blocks — too coarse for the eps-filter to skip whole
        // blocks, so the paper's 3.4x no-filter gap does not reproduce in
        // artifact wall time.  The wall-clock claim checked here is the
        // weaker one that filtering costs nothing: cce bwd within 25% of
        // the unfiltered backward.  (The native backend's finer blocks do
        // show the gain; `check_native` asserts it.)
        if cce.bwd_secs() > 1.25 * nf.bwd_secs() {
            return Err(anyhow!(
                "filter overhead claim failed: cce bwd {:.3}s >> no-filter bwd {:.3}s",
                cce.bwd_secs(),
                nf.bwd_secs()
            ));
        }
    }
    if cce.fwdbwd_secs > 10.0 * fused.fwdbwd_secs {
        return Err(anyhow!(
            "latency claim failed: cce {:.3}s vs fused {:.3}s",
            cce.fwdbwd_secs,
            fused.fwdbwd_secs
        ));
    }
    Ok(())
}

/// Native-path claims: the memory ordering holds, every method computes
/// the same loss, and filtering makes the backward measurably faster on
/// Zipf-peaked inputs (the paper's Table-1 rows 1 vs 7).
///
/// The wall-clock assertion at the end is inherently timing-sensitive, so
/// it belongs to `cce table1 --check` (real grids, real budgets); unit
/// tests use [`check_native_deterministic`].
pub fn check_native(rows: &[Row]) -> Result<()> {
    check_native_deterministic(rows)?;
    let cce = rows.iter().find(|r| r.method == LossMethod::Cce).unwrap();
    let nofilter = rows
        .iter()
        .find(|r| r.method == LossMethod::CceNoFilter)
        .ok_or_else(|| anyhow!("missing cce_no_filter row"))?;
    // The headline throughput claim: filtering speeds up the backward.
    if cce.bwd_secs() * 1.1 > nofilter.bwd_secs() {
        return Err(anyhow!(
            "filter speedup claim failed: cce bwd {:.4}s vs no-filter bwd {:.4}s",
            cce.bwd_secs(),
            nofilter.bwd_secs()
        ));
    }
    Ok(())
}

/// The timing-free subset of [`check_native`]: loss parity, the analytic
/// memory ordering, the measured forward working set, and the *structural*
/// filter win (blocks actually skipped, predicted speedup > 1).
pub fn check_native_deterministic(rows: &[Row]) -> Result<()> {
    let get = |m: LossMethod| -> Result<&Row> {
        rows.iter()
            .find(|r| r.method == m)
            .ok_or_else(|| anyhow!("missing row {:?}", m.key()))
    };
    let cce = get(LossMethod::Cce)?;
    let base = get(LossMethod::Baseline)?;
    let _ = get(LossMethod::CceNoFilter)?;

    if base.mem_paper.combined < 20 * cce.mem_paper.combined {
        return Err(anyhow!("memory claim failed at paper scale"));
    }
    // Loss parity across implementations (same inputs, same reduction).
    let base_loss = base.loss.ok_or_else(|| anyhow!("baseline loss missing"))?;
    for r in rows {
        let loss = r.loss.ok_or_else(|| anyhow!("loss missing for {}", r.method.key()))?;
        if (loss - base_loss).abs() > 1e-3 * base_loss.abs().max(1.0) {
            return Err(anyhow!(
                "loss parity failed: {} gives {loss}, baseline {base_loss}",
                r.method.key()
            ));
        }
    }
    // CCE's measured *forward* working set must be far below the
    // baseline's materialized N×V (the O(N·D + N_B·V_B) claim, measured;
    // the backward's O(V·D)-total column-parallel accumulator is asserted
    // separately by the kernel tests).
    let (cce_ws, base_ws) = (
        cce.fwd_working_bytes.unwrap_or(0),
        base.fwd_working_bytes.unwrap_or(u64::MAX),
    );
    if cce_ws * 4 > base_ws {
        return Err(anyhow!(
            "forward working-set claim failed: cce {cce_ws} B vs baseline {base_ws} B"
        ));
    }
    // Structural filter win: real blocks skipped, so the Amdahl model
    // predicts a >1 speedup regardless of timing noise.
    let stats = cce.stats.ok_or_else(|| anyhow!("cce row missing filter stats"))?;
    if stats.blocks_skipped == 0 {
        return Err(anyhow!("gradient filter skipped no blocks on Zipf-peaked inputs"));
    }
    if speedup_at_survival(stats.survival(), BWD_FIXED_FRACTION) <= 1.2 {
        return Err(anyhow!(
            "predicted filter speedup too small: survival {:.2}",
            stats.survival()
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_table_runs_checks_and_serializes() {
        // Small grid (d >= 128 keeps the generator's softmax peaked enough
        // for real block skipping); a 50 ms budget keeps the timing means
        // stable enough for check_native's 1.1x speedup floor.
        let opts = KernelOptions {
            n_block: 32,
            v_block: 64,
            threads: 2,
            ..KernelOptions::default()
        };
        let rows = run_native(256, 128, 1024, 0.1, 50, opts, 0).unwrap();
        assert_eq!(rows.len(), native_methods().len());
        // The kahan long-tail rows must be present (acceptance criterion).
        for key in ["cce_kahan", "cce_kahan_fullc", "cce_kahan_fulle"] {
            assert!(
                rows.iter().any(|r| r.method.key() == key),
                "missing native Table-1 row {key}"
            );
        }
        // Timing-free claims only: wall-clock assertions (check_native)
        // belong to `cce table1 --check`, not to tier-1 unit tests.
        check_native_deterministic(&rows).expect("native Table-1 claims");
        let (measured, predicted, survival) = filter_speedup(&rows).expect("speedup");
        assert!(measured > 0.0, "measured speedup {measured}");
        // Amdahl cap at 1/4 fixed work: 1 < speedup <= 4.
        assert!(predicted > 1.0 && predicted <= 4.0, "{predicted}");
        assert!(survival > 0.0 && survival < 1.0);

        let small = run_native_small(8, 128, 1024, 0.1, 20, opts, 0).unwrap();
        assert_eq!(small.n, 8);
        assert!(small.fwd_secs > 0.0 && small.fwdbwd_secs >= small.fwd_secs);

        let path = std::env::temp_dir().join("cce_bench_table1_test.json");
        write_json(
            &rows,
            (256, 128, 1024),
            opts.resolved_threads(),
            crate::exec::pool_workers(),
            Some(&small),
            &path,
        )
        .unwrap();
        let parsed = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(parsed.get("bench").unwrap().as_str(), Some("table1"));
        assert_eq!(parsed.get("schema").unwrap().as_i64(), Some(2));
        assert_eq!(parsed.get("dtype").unwrap().as_str(), Some("f32"));
        assert!(parsed.get("simd").and_then(Json::as_str).is_some());
        assert!(parsed.get("pool_workers").and_then(Json::as_i64).is_some());
        let first_row = &parsed.get("rows").unwrap().as_array().unwrap()[0];
        assert!(first_row.get("measured_mb").is_some(), "measured memory column missing");
        assert!(first_row.get("grad_mb").is_some());
        assert_eq!(
            parsed.get("rows").unwrap().as_array().unwrap().len(),
            rows.len()
        );
        assert!(parsed.get("filter_speedup").is_some());
        let small_json = parsed.get("small_n").expect("small_n section");
        assert_eq!(small_json.get("n").unwrap().as_i64(), Some(8));
        assert!(small_json.get("fwdbwd_ms").is_some());
        assert_eq!(
            parsed.get("grid").unwrap().get("v").unwrap().as_i64(),
            Some(1024)
        );
    }

    #[test]
    fn bf16_table_matches_f32_within_documented_tolerance() {
        // The acceptance criterion: `cce table1 --dtype bf16` reports a
        // loss within the documented bf16 tolerance (1% relative — inputs
        // round once at 2^-9 relative, python-simulated deviation at this
        // grid: ~0.2%) of the f32 run, passes the same deterministic
        // claims, and reports a measured memory column that shrinks with
        // the storage width.
        let opts = KernelOptions {
            n_block: 32,
            v_block: 64,
            threads: 2,
            ..KernelOptions::default()
        };
        let bf_opts = KernelOptions { dtype: StoreDtype::Bf16, ..opts };
        let f32_rows = run_native(256, 128, 1024, 0.1, 10, opts, 0).unwrap();
        let bf_rows = run_native(256, 128, 1024, 0.1, 10, bf_opts, 0).unwrap();
        check_native_deterministic(&bf_rows).expect("bf16 Table-1 claims");
        let cce_of = |rows: &[Row]| {
            rows.iter().find(|r| r.method == LossMethod::Cce).cloned().unwrap()
        };
        let (f, b) = (cce_of(&f32_rows), cce_of(&bf_rows));
        assert_eq!(b.dtype, StoreDtype::Bf16);
        let (lf, lb) = (f.loss.unwrap(), b.loss.unwrap());
        assert!(
            (lf - lb).abs() <= 0.01 * lf.abs().max(0.1),
            "bf16 cce loss {lb} vs f32 {lf} beyond the documented 1% tolerance"
        );
        // Measured memory: gradients halve exactly; the combined measured
        // column shrinks accordingly (workspace is dtype-light).
        assert_eq!(b.grad_bytes.unwrap() * 2, f.grad_bytes.unwrap());
        assert!(b.measured_bytes.unwrap() < f.measured_bytes.unwrap());
        // The baseline's measured N×V materialization also halves.
        let base_of = |rows: &[Row]| {
            rows.iter().find(|r| r.method == LossMethod::Baseline).cloned().unwrap()
        };
        assert!(
            base_of(&bf_rows).fwd_working_bytes.unwrap()
                < base_of(&f32_rows).fwd_working_bytes.unwrap() * 3 / 4,
            "bf16 baseline must materialize half-width logits"
        );
    }

    #[test]
    fn shuffle_vocab_preserves_problem_semantics() {
        let mut rng = Rng::new(3);
        let (n, d, v) = (64, 8, 128);
        let mut inputs = gen_loss_inputs(n, d, v, &mut rng, 0.2);
        let before = Problem::from_tensors(&inputs).unwrap();
        let opts = KernelOptions { threads: 1, ..KernelOptions::default() };
        let loss_before = crate::exec::baseline_forward(&before, &opts).loss;
        shuffle_vocab_ids(&mut inputs, &mut rng);
        let after = Problem::from_tensors(&inputs).unwrap();
        let loss_after = crate::exec::baseline_forward(&after, &opts).loss;
        // Renaming vocabulary ids permutes logits within each row's
        // softmax, so the loss is unchanged (up to f32 reorder round-off).
        assert!(
            (loss_before - loss_after).abs() < 1e-4,
            "{loss_before} vs {loss_after}"
        );
    }
}
