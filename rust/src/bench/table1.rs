//! Table 1 / Table A1 harness: per-method memory and time for the loss, the
//! gradient, and their combination.
//!
//! Memory is analytic (exact at the paper's scale — [`crate::memmodel`]);
//! time is measured on this substrate by executing the AOT loss artifacts.
//! Gradient time is reported as `fwdbwd - fwd` (the artifacts expose the
//! forward and the differentiated computation; the paper's kernel-level
//! split is approximated by the difference).

use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::bench::harness::{time_artifact, Table};
use crate::memmodel::{method_memory, LossMethod, Workload};
use crate::runtime::Runtime;
use crate::util::stats::{fmt_duration, fmt_mb};

/// Paper Table 1 values (Gemma 2 2B, A100) for side-by-side display:
/// (method key, loss MB, grad MB, combined MB, loss ms, grad ms, comb ms).
pub const PAPER_TABLE1: &[(&str, u64, u64, u64, u64, u64, u64)] = &[
    ("cce", 1, 1_163, 1_164, 46, 100, 145),
    ("liger", 1_474, 0, 1_474, 304, 0, 304),
    ("chunked8", 8_000, 1_630, 9_631, 55, 115, 169),
    ("fused", 4_000, 12_000, 16_000, 49, 92, 143),
    ("baseline", 24_000, 16_000, 28_000, 82, 122, 208),
    ("cce_no_sort", 0, 1_162, 1_162, 45, 115, 159),
    ("cce_no_filter", 0, 1_163, 1_162, 45, 314, 357),
    ("cce_kahan", 1, 2_325, 2_326, 47, 114, 160),
    ("cce_kahan_fullc", 1, 2_326, 2_326, 47, 268, 313),
    ("cce_kahan_fulle", 1, 2_326, 2_326, 47, 247, 292),
];

/// One measured row.
#[derive(Debug, Clone)]
pub struct Row {
    pub method: LossMethod,
    pub fwd_secs: f64,
    pub fwdbwd_secs: f64,
    pub mem_scaled: crate::memmodel::MethodMemory,
    pub mem_paper: crate::memmodel::MethodMemory,
}

/// Measure all methods at the benchmark grid in the manifest.
pub fn run(rt: &Runtime, ignored_frac: f64, budget_ms: u64) -> Result<Vec<Row>> {
    let bench = rt
        .manifest
        .raw_meta
        .get("bench")
        .ok_or_else(|| anyhow!("no bench meta in manifest"))?;
    let n = bench.req("n")?.as_i64().unwrap() as u64;
    let d = bench.req("d")?.as_i64().unwrap() as u64;
    let v = bench.req("v")?.as_i64().unwrap() as u64;
    let size_tag = format!("n{n}_d{d}_v{v}");
    // Our substrate runs f32 (act_bytes 4); the paper column uses bf16.
    let scaled = Workload { n_tokens: n, vocab: v, hidden: d, act_bytes: 4,
                            softcap: false };
    let paper = Workload::gemma2_2b();
    let budget = Duration::from_millis(budget_ms);

    let mut rows = Vec::new();
    for method in LossMethod::table1_order() {
        let key = method.key();
        let fwd = time_artifact(rt, &format!("loss_fwd_{key}_{size_tag}"),
                                ignored_frac, budget)?;
        let fwdbwd = time_artifact(rt, &format!("loss_fwdbwd_{key}_{size_tag}"),
                                   ignored_frac, budget)?;
        eprintln!(
            "  [table1] {key}: fwd {} fwd+bwd {}",
            fmt_duration(fwd.mean()),
            fmt_duration(fwdbwd.mean())
        );
        rows.push(Row {
            method,
            fwd_secs: fwd.mean(),
            fwdbwd_secs: fwdbwd.mean(),
            mem_scaled: method_memory(method, &scaled),
            mem_paper: method_memory(method, &paper),
        });
    }
    Ok(rows)
}

/// Render the table (measured time at the scaled grid + analytic memory at
/// both scales + the paper's published numbers).
pub fn print(rows: &[Row], title: &str) {
    println!("\n== {title} ==");
    println!("   time: measured on this substrate (CPU PJRT, f32, scaled grid)");
    println!("   memory: analytic model — 'scaled' at the measured grid, 'paper' at Gemma 2 2B (N=8192, |V|=256000, D=2304, bf16)\n");
    let mut t = Table::new(&[
        "Method", "Loss t", "Grad t", "L+G t", "Mem scaled", "Mem paper",
        "Paper mem", "Paper t",
    ]);
    for r in rows {
        let paper_row = PAPER_TABLE1
            .iter()
            .find(|p| p.0 == r.method.key());
        t.row(vec![
            r.method.label(),
            fmt_duration(r.fwd_secs),
            fmt_duration((r.fwdbwd_secs - r.fwd_secs).max(0.0)),
            fmt_duration(r.fwdbwd_secs),
            fmt_mb(r.mem_scaled.combined),
            fmt_mb(r.mem_paper.combined),
            paper_row.map(|p| format!("{} MB", p.3)).unwrap_or_default(),
            paper_row.map(|p| format!("{} ms", p.6)).unwrap_or_default(),
        ]);
    }
    t.print();
}

/// Shape assertions behind the headline claims (used by `cce table1
/// --check` and the integration tests):
///
/// 1. CCE's analytic memory is >=20x below Baseline's at paper scale.
/// 2. gradient filtering adds no measurable overhead (see inline note on
///    why the paper's 3.4x *gain* needs finer blocks than this substrate).
/// 3. CCE fwd+bwd is within 10x of the fused (compile) baseline.  The
///    paper's parity claim holds on GPU where the blockwise tiles live in
///    SRAM next to the tensor cores; interpret-mode Pallas emulates each
///    grid step as a sequential HLO loop iteration, so a constant-factor
///    emulation overhead over the single-GEMM baseline is expected on this
///    substrate (see DESIGN.md §Hardware-Adaptation).
pub fn check(rows: &[Row]) -> Result<()> {
    let get = |m: &LossMethod| -> Option<&Row> {
        rows.iter().find(|r| &r.method == m)
    };
    let cce = get(&LossMethod::Cce).ok_or_else(|| anyhow!("no cce row"))?;
    let base = get(&LossMethod::Baseline).ok_or_else(|| anyhow!("no baseline"))?;
    let fused = get(&LossMethod::TorchCompile).ok_or_else(|| anyhow!("no fused"))?;
    let nofilter = get(&LossMethod::CceNoFilter);

    if base.mem_paper.combined < 20 * cce.mem_paper.combined {
        return Err(anyhow!(
            "memory claim failed: baseline {} vs cce {}",
            base.mem_paper.combined,
            cce.mem_paper.combined
        ));
    }
    if let Some(nf) = nofilter {
        // On this substrate the bench tiles are 512x2048 (required to make
        // interpret-mode tractable), which leaves only 16 vocabulary blocks
        // — too coarse for the eps-filter to skip whole blocks, so the
        // paper's 3.4x no-filter gap does not reproduce in wall time here.
        // The mechanism itself is validated at kernel granularity by
        // python/tests/test_numerics.py (blocks below eps are provably
        // skipped and the error bound holds) and by the block-survival
        // model in `sparsity`.  The wall-clock claim checked here is the
        // weaker one that filtering costs nothing: cce bwd within 25% of
        // the unfiltered backward.
        let bwd_nf = nf.fwdbwd_secs - nf.fwd_secs;
        let bwd_cce = cce.fwdbwd_secs - cce.fwd_secs;
        if bwd_cce > 1.25 * bwd_nf {
            return Err(anyhow!(
                "filter overhead claim failed: cce bwd {bwd_cce:.3}s >> no-filter bwd {bwd_nf:.3}s"
            ));
        }
    }
    if cce.fwdbwd_secs > 10.0 * fused.fwdbwd_secs {
        return Err(anyhow!(
            "latency claim failed: cce {:.3}s vs fused {:.3}s",
            cce.fwdbwd_secs,
            fused.fwdbwd_secs
        ));
    }
    Ok(())
}
