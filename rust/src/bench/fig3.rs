//! Fig. 3 harness: average softmax probability of the i-th most likely
//! token, measured from a *trained* model checkpoint via the
//! `{tag}_rank_stats` artifact, plus the gradient-filter accounting that
//! this sparsity implies (§4.3 / §5.2).

use anyhow::{anyhow, Result};

use crate::bench::harness::Table;
use crate::coordinator::{Checkpoint, CorpusKind, Metrics, RunConfig, TrainState,
                         Trainer};
use crate::runtime::{HostTensor, Runtime};
use crate::sparsity::{BlockFilterModel, RankStats, FILTER_EPS};

/// Obtain rank statistics: from `checkpoint` if given, otherwise by training
/// `tag` for `warm_steps` first (an untrained model's softmax is near
/// uniform and would say nothing about filtering).
pub fn run(
    rt: &Runtime,
    tag: &str,
    checkpoint: Option<&str>,
    warm_steps: u64,
    seed: u64,
) -> Result<RankStats> {
    let cfg = RunConfig {
        tag: tag.into(),
        method: "cce".into(),
        steps: warm_steps,
        seed,
        corpus: CorpusKind::Web,
        corpus_docs: if tag == "tiny" { 400 } else { 4000 },
        eval_every: 0,
        checkpoint_every: 0,
        log_every: 25,
        out_dir: format!("runs/fig3_{tag}"),
        ..Default::default()
    };
    let trainer = Trainer::build(rt, cfg)?;

    let state = match checkpoint {
        Some(path) => {
            eprintln!("  [fig3] loading checkpoint {path}");
            TrainState::from_checkpoint(Checkpoint::load(path)?, &trainer.meta)?
        }
        None => {
            eprintln!("  [fig3] no checkpoint given; training {warm_steps} steps first");
            let init = TrainState::init(rt, &trainer.meta, seed as i32)?;
            let mut metrics = Metrics::in_memory();
            trainer.train(init, &mut metrics)?
        }
    };

    // Mean rank-probabilities over a few validation batches.
    let exe = rt.load(&format!("{tag}_rank_stats"))?;
    let batches = trainer.dataset.val_batches(trainer.meta.batch);
    if batches.is_empty() {
        return Err(anyhow!("no validation batches"));
    }
    let mut acc: Vec<f64> = Vec::new();
    let n_batches = batches.len().min(4);
    for b in &batches[..n_batches] {
        let mut inputs: Vec<HostTensor> = state.params.clone();
        inputs.push(b.tokens.clone());
        let out = exe.run(&inputs)?;
        let probs = out[0].as_f32()?;
        if acc.is_empty() {
            acc = probs.iter().map(|&p| p as f64).collect();
        } else {
            for (a, &p) in acc.iter_mut().zip(probs) {
                *a += p as f64;
            }
        }
    }
    for a in &mut acc {
        *a /= n_batches as f64;
    }
    Ok(RankStats::from_probs(acc, FILTER_EPS))
}

pub fn print(stats: &RankStats, csv: Option<&str>) -> Result<()> {
    println!("\n== Fig. 3: average probability of the i-th most likely token ==\n");
    let mut t = Table::new(&["rank", "mean probability", "log10 p"]);
    for (rank, p) in stats.fig3_series(24) {
        t.row(vec![
            rank.to_string(),
            format!("{p:.3e}"),
            format!("{:.2}", p.max(1e-300).log10()),
        ]);
    }
    t.print();
    println!(
        "\n  ranks above eps=2^-12: {}   softmax sparsity: {:.4}%   log-log slope: {:.2}",
        stats.significant_ranks,
        100.0 * stats.sparsity(FILTER_EPS),
        stats.loglog_slope
    );

    // Filter accounting at the paper's blocking.
    let model = BlockFilterModel {
        vocab: stats.probs.len(),
        v_block: 256,
        n_block: 128,
        sig_per_row: stats.significant_ranks.max(1),
        sort_agreement: 0.7,
    };
    println!(
        "  block survival: unsorted {:.2}%  sorted {:.2}%  -> predicted bwd speedup {:.1}x (unsorted), {:.1}x (sorted)",
        100.0 * model.survival_unsorted(),
        100.0 * model.survival_sorted(),
        model.predicted_speedup(model.survival_unsorted(), 0.4),
        model.predicted_speedup(model.survival_sorted(), 0.4),
    );

    if let Some(path) = csv {
        let mut csv_t = Table::new(&["rank", "prob"]);
        for (rank, p) in stats.fig3_series(200) {
            csv_t.row(vec![rank.to_string(), format!("{p:.6e}")]);
        }
        csv_t.write_csv(path)?;
        println!("  wrote {path}");
    }
    Ok(())
}

/// Fig. 3 shape claims: monotone decay, rapid vanishing, high sparsity.
pub fn check(stats: &RankStats) -> Result<()> {
    if stats.significant_ranks > stats.probs.len() / 4 {
        anyhow::bail!(
            "softmax not sparse: {} significant of {}",
            stats.significant_ranks,
            stats.probs.len()
        );
    }
    if stats.sparsity(FILTER_EPS) < 0.75 {
        anyhow::bail!("sparsity too low: {}", stats.sparsity(FILTER_EPS));
    }
    let head = stats.probs[0];
    let mid = stats.probs[stats.probs.len() / 2];
    if head < mid * 100.0 {
        anyhow::bail!("no head concentration: p1={head} p_mid={mid}");
    }
    Ok(())
}
