//! Fig. 3 harness: average softmax probability of the i-th most likely
//! token, measured from a *trained* model, plus the gradient-filter
//! accounting that this sparsity implies (§4.3 / §5.2).
//!
//! Two measurement paths share [`RankStats`] and the printers:
//!
//! * [`run_native`] — zero artifacts: train (or load) a native
//!   bag-of-context checkpoint and probe its softmax on validation rows.
//!   Materializing one `V`-vector per row here is the *measurement*, not
//!   the hot path — rank statistics are a full-distribution property.
//! * [`run`] (behind the `pjrt` feature) — the `{tag}_rank_stats` AOT
//!   artifact on the transformer.

use anyhow::Result;

use crate::bench::harness::Table;
use crate::sparsity::{BlockFilterModel, RankStats, FILTER_EPS};

#[cfg(feature = "pjrt")]
use crate::coordinator::{Checkpoint, CorpusKind, Metrics, RunConfig, TrainState,
                         Trainer};
#[cfg(feature = "pjrt")]
use crate::runtime::{HostTensor, Runtime};

/// Obtain rank statistics natively: from `checkpoint` if given (a `cce
/// train --backend native` checkpoint — its tokenizer, dims, and window
/// come from the checkpoint bundle, not from CLI flags), otherwise by
/// training for `warm_steps` first (an untrained model's softmax is near
/// uniform and would say nothing about filtering).
pub fn run_native(
    checkpoint: Option<&str>,
    warm_steps: u64,
    seed: u64,
    vocab_size: usize,
    corpus_docs: usize,
    opts: crate::exec::KernelOptions,
) -> Result<RankStats> {
    use crate::coordinator::{
        CorpusKind as Corpus, Metrics as M, NativeModelConfig, NativeState, NativeTrainer,
        RunConfig as Cfg,
    };
    let model = NativeModelConfig::default();
    if let Some(path) = checkpoint {
        eprintln!("  [fig3] loading native checkpoint bundle {path}");
        let bundle = NativeState::load_bundle(std::path::Path::new(path))?;
        // Hyperparameters come from the checkpoint's .model.json sidecar,
        // not from CLI flags (pre-sidecar checkpoints fall back to the
        // trainer defaults).
        let window = bundle.window.unwrap_or(model.window);
        let seq_len = bundle.seq_len.unwrap_or(model.seq_len);
        // Fresh measurement corpus, tokenized with the *checkpoint's* own
        // vocabulary so token identities line up with the trained head.
        let docs = crate::data::web_corpus(corpus_docs, seed);
        let config = crate::data::DatasetConfig {
            seq_len,
            val_fraction: 0.02,
            seed,
            pad_per_doc: false,
        };
        let dataset = crate::data::Dataset::build(&docs, &bundle.tokenizer, &config)?;
        return rank_stats_native(
            &dataset,
            &bundle.state,
            bundle.vocab,
            bundle.d_model,
            window,
            seq_len,
            model.batch,
        );
    }
    let cfg = Cfg {
        tag: "fig3-native".into(),
        method: "cce".into(),
        steps: warm_steps,
        seed,
        corpus: Corpus::Web,
        corpus_docs,
        vocab_size,
        eval_every: 0,
        checkpoint_every: 0,
        log_every: 25,
        out_dir: std::env::temp_dir().join("cce_fig3_native").to_string_lossy().into(),
    };
    let trainer = NativeTrainer::build(cfg, model, opts)?;
    eprintln!("  [fig3] no checkpoint given; training {warm_steps} native steps first");
    let mut metrics = M::in_memory();
    let state = trainer.train(trainer.init(seed), &mut metrics)?;
    rank_stats_native(
        &trainer.dataset,
        &state,
        trainer.vocab,
        trainer.model.d_model,
        trainer.model.window,
        trainer.model.seq_len,
        trainer.model.batch,
    )
}

/// Mean rank-probability curve of a trained bag-of-context head over up to
/// four validation batches.
fn rank_stats_native(
    dataset: &crate::data::Dataset,
    state: &crate::coordinator::NativeState,
    v: usize,
    d: usize,
    window: usize,
    seq_len: usize,
    batch: usize,
) -> Result<RankStats> {
    // Measurement batch: bounded by the val split so small corpora still
    // yield at least one batch (val_batches drops partial batches).
    let eval_batch = batch.min(dataset.val.len()).max(1);
    let batches = dataset.val_batches(eval_batch);
    if batches.is_empty() {
        anyhow::bail!("no validation batches");
    }
    let max_batches = 4usize;
    let mut acc = vec![0f64; v];
    let mut rows: u64 = 0;
    let mut probs = vec![0f64; v];
    // Measurement path, not the hot path: widen the (possibly bf16)
    // parameters to f32 once — rank statistics are a full-distribution
    // property and materialize V-vectors anyway.
    let emb = state.emb.to_f32_vec();
    let cls = state.cls.to_f32_vec();
    for b in batches.iter().take(max_batches) {
        let tokens = b.tokens.as_i32()?;
        let h = crate::coordinator::bag_hidden(tokens, &emb[..], d, window, seq_len, 0);
        for h_row in h.chunks(d) {
            // One V-vector of logits -> softmax -> sorted descending.
            let mut m = f64::NEG_INFINITY;
            for (j, slot) in probs.iter_mut().enumerate() {
                let z = h_row
                    .iter()
                    .zip(&cls[j * d..(j + 1) * d])
                    .map(|(&a, &b)| (a as f64) * b as f64)
                    .sum::<f64>();
                *slot = z;
                m = m.max(z);
            }
            let mut total = 0.0;
            for p in probs.iter_mut() {
                *p = (*p - m).exp();
                total += *p;
            }
            for p in probs.iter_mut() {
                *p /= total;
            }
            probs.sort_by(|a, b| b.partial_cmp(a).unwrap_or(std::cmp::Ordering::Equal));
            for (slot, &p) in acc.iter_mut().zip(probs.iter()) {
                *slot += p;
            }
            rows += 1;
        }
    }
    for slot in acc.iter_mut() {
        *slot /= rows.max(1) as f64;
    }
    Ok(RankStats::from_probs(acc, FILTER_EPS))
}

/// Obtain rank statistics via the `{tag}_rank_stats` artifact: from
/// `checkpoint` if given, otherwise by training `tag` for `warm_steps`
/// first.
#[cfg(feature = "pjrt")]
pub fn run(
    rt: &Runtime,
    tag: &str,
    checkpoint: Option<&str>,
    warm_steps: u64,
    seed: u64,
) -> Result<RankStats> {
    use anyhow::anyhow;
    let cfg = RunConfig {
        tag: tag.into(),
        method: "cce".into(),
        steps: warm_steps,
        seed,
        corpus: CorpusKind::Web,
        corpus_docs: if tag == "tiny" { 400 } else { 4000 },
        eval_every: 0,
        checkpoint_every: 0,
        log_every: 25,
        out_dir: format!("runs/fig3_{tag}"),
        ..Default::default()
    };
    let trainer = Trainer::build(rt, cfg)?;

    let state = match checkpoint {
        Some(path) => {
            eprintln!("  [fig3] loading checkpoint {path}");
            TrainState::from_checkpoint(Checkpoint::load(path)?, &trainer.meta)?
        }
        None => {
            eprintln!("  [fig3] no checkpoint given; training {warm_steps} steps first");
            let init = TrainState::init(rt, &trainer.meta, seed as i32)?;
            let mut metrics = Metrics::in_memory();
            trainer.train(init, &mut metrics)?
        }
    };

    // Mean rank-probabilities over a few validation batches.
    let exe = rt.load(&format!("{tag}_rank_stats"))?;
    let batches = trainer.dataset.val_batches(trainer.meta.batch);
    if batches.is_empty() {
        return Err(anyhow!("no validation batches"));
    }
    let mut acc: Vec<f64> = Vec::new();
    let n_batches = batches.len().min(4);
    for b in &batches[..n_batches] {
        let mut inputs: Vec<HostTensor> = state.params.clone();
        inputs.push(b.tokens.clone());
        let out = exe.run(&inputs)?;
        let probs = out[0].as_f32()?;
        if acc.is_empty() {
            acc = probs.iter().map(|&p| p as f64).collect();
        } else {
            for (a, &p) in acc.iter_mut().zip(probs) {
                *a += p as f64;
            }
        }
    }
    for a in &mut acc {
        *a /= n_batches as f64;
    }
    Ok(RankStats::from_probs(acc, FILTER_EPS))
}

pub fn print(stats: &RankStats, csv: Option<&str>) -> Result<()> {
    println!("\n== Fig. 3: average probability of the i-th most likely token ==\n");
    let mut t = Table::new(&["rank", "mean probability", "log10 p"]);
    for (rank, p) in stats.fig3_series(24) {
        t.row(vec![
            rank.to_string(),
            format!("{p:.3e}"),
            format!("{:.2}", p.max(1e-300).log10()),
        ]);
    }
    t.print();
    println!(
        "\n  ranks above eps=2^-12: {}   softmax sparsity: {:.4}%   log-log slope: {:.2}",
        stats.significant_ranks,
        100.0 * stats.sparsity(FILTER_EPS),
        stats.loglog_slope
    );

    // Filter accounting at the paper's blocking.
    let model = BlockFilterModel {
        vocab: stats.probs.len(),
        v_block: 256,
        n_block: 128,
        sig_per_row: stats.significant_ranks.max(1),
        sort_agreement: 0.7,
    };
    println!(
        "  block survival: unsorted {:.2}%  sorted {:.2}%  -> predicted bwd speedup {:.1}x (unsorted), {:.1}x (sorted)",
        100.0 * model.survival_unsorted(),
        100.0 * model.survival_sorted(),
        model.predicted_speedup(model.survival_unsorted(), 0.4),
        model.predicted_speedup(model.survival_sorted(), 0.4),
    );

    if let Some(path) = csv {
        let mut csv_t = Table::new(&["rank", "prob"]);
        for (rank, p) in stats.fig3_series(200) {
            csv_t.row(vec![rank.to_string(), format!("{p:.6e}")]);
        }
        csv_t.write_csv(path)?;
        println!("  wrote {path}");
    }
    Ok(())
}

/// Fig. 3 shape claims: monotone decay, rapid vanishing, high sparsity.
pub fn check(stats: &RankStats) -> Result<()> {
    if stats.significant_ranks > stats.probs.len() / 4 {
        anyhow::bail!(
            "softmax not sparse: {} significant of {}",
            stats.significant_ranks,
            stats.probs.len()
        );
    }
    if stats.sparsity(FILTER_EPS) < 0.75 {
        anyhow::bail!("sparsity too low: {}", stats.sparsity(FILTER_EPS));
    }
    let head = stats.probs[0];
    let mid = stats.probs[stats.probs.len() / 2];
    if head < mid * 100.0 {
        anyhow::bail!("no head concentration: p1={head} p_mid={mid}");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::KernelOptions;

    #[test]
    fn native_rank_stats_are_a_distribution() {
        let opts =
            KernelOptions { n_block: 32, v_block: 128, threads: 2, ..KernelOptions::default() };
        let stats = run_native(None, 12, 5, 512, 200, opts).unwrap();
        // Mean of per-row softmax distributions is itself a distribution.
        let total: f64 = stats.probs.iter().sum();
        assert!((total - 1.0).abs() < 1e-6, "probs sum to {total}");
        // Sorted descending by construction.
        for w in stats.probs.windows(2) {
            assert!(w[0] >= w[1]);
        }
        // Even 12 warm steps concentrate the head well above uniform.
        assert!(
            stats.probs[0] > 4.0 / stats.probs.len() as f64,
            "head {} vs uniform {}",
            stats.probs[0],
            1.0 / stats.probs.len() as f64
        );
    }
}
